"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Every kernel is swept over shapes with hypothesis and checked against
``kernels.ref`` with assert_allclose; algebraic identities (Q orthogonal,
A = QR reconstruction) are checked directly as well.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import hh_update, ref

jax.config.update("jax_enable_x64", False)

RTOL = 2e-4
ATOL = 2e-5


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def upper(rng, b):
    return jnp.triu(rand(rng, b, b))


# ---------------------------------------------------------------------------
# Householder QR oracle self-consistency (the oracle everything trusts).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,b", [(8, 4), (16, 4), (32, 8), (64, 16), (128, 32)])
def test_householder_qr_reconstructs(m, b):
    rng = np.random.default_rng(m * 1000 + b)
    a = rand(rng, m, b)
    y, t, r = ref.householder_qr(a)
    # Q = I - Y T Y^T ; A should equal Q @ [R; 0]
    q = jnp.eye(m) - y @ t @ y.T
    r_full = jnp.zeros((m, b)).at[:b].set(r)
    assert_allclose(np.asarray(q @ r_full), np.asarray(a), rtol=1e-3, atol=1e-4)
    # orthogonality
    assert_allclose(np.asarray(q @ q.T), np.eye(m), rtol=1e-3, atol=1e-4)
    # unit-lower structure of Y
    yl = np.asarray(y)
    assert_allclose(np.triu(yl[:b], 1), 0.0, atol=1e-6)
    assert_allclose(np.diag(yl[:b]), 1.0, atol=1e-6)
    # R upper-triangular
    assert_allclose(np.tril(np.asarray(r), -1), 0.0, atol=1e-6)


def test_householder_qr_zero_row_padding_exact():
    """Zero-row padding must leave R untouched and Y zero in padded rows."""
    rng = np.random.default_rng(7)
    a = rand(rng, 24, 8)
    pad = jnp.zeros((16, 8), jnp.float32)
    y1, t1, r1 = ref.householder_qr(a)
    y2, t2, r2 = ref.householder_qr(jnp.concatenate([a, pad]))
    assert_allclose(np.asarray(r2), np.asarray(r1), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(t2), np.asarray(t1), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(y2[:24]), np.asarray(y1), rtol=1e-5, atol=1e-6)
    assert_allclose(np.asarray(y2[24:]), 0.0, atol=1e-6)


def test_householder_qr_zero_matrix():
    y, t, r = ref.householder_qr(jnp.zeros((8, 4), jnp.float32))
    assert np.all(np.isfinite(np.asarray(y)))
    assert_allclose(np.asarray(r), 0.0, atol=0)
    assert_allclose(np.asarray(t), 0.0, atol=0)


def test_tsqr_merge_y0_is_identity_for_triangular_inputs():
    """Paper III-C assumes the merge reflector is [I; Y1]; verify it."""
    rng = np.random.default_rng(3)
    r0, r1 = upper(rng, 8), upper(rng, 8)
    y0, y1, t, r = ref.tsqr_merge(r0, r1)
    assert_allclose(np.asarray(y0), np.eye(8), atol=1e-6)


@pytest.mark.parametrize("b", [2, 4, 8, 16])
def test_tsqr_merge_matches_stacked_qr(b):
    rng = np.random.default_rng(b)
    r0, r1 = upper(rng, b), upper(rng, b)
    y0, y1, t, r = ref.tsqr_merge(r0, r1)
    stacked = jnp.concatenate([r0, r1])
    # R^T R invariant (Cholesky of the Gram matrix is unique up to signs)
    assert_allclose(
        np.asarray(r.T @ r),
        np.asarray(stacked.T @ stacked),
        rtol=1e-3,
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# Pallas kernels vs oracle.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,b,n", [(16, 4, 8), (32, 8, 16), (64, 16, 64), (128, 32, 256), (64, 16, 128)]
)
def test_leaf_apply_pallas_matches_ref(m, b, n):
    rng = np.random.default_rng(m + b + n)
    a = rand(rng, m, b)
    y, t, _ = ref.householder_qr(a)
    c = rand(rng, m, n)
    got = hh_update.leaf_apply_pallas(y, t, c)
    want = ref.leaf_apply(y, t, c)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("b,n", [(4, 8), (8, 32), (16, 128), (32, 256), (32, 512)])
def test_tree_update_pallas_matches_ref(b, n):
    rng = np.random.default_rng(b * n)
    r0, r1 = upper(rng, b), upper(rng, b)
    _, y1, t, _ = ref.tsqr_merge(r0, r1)
    c0, c1 = rand(rng, b, n), rand(rng, b, n)
    w, o0, o1 = hh_update.tree_update_pallas(c0, c1, y1, t)
    we, e0, e1 = ref.tree_update(c0, c1, y1, t)
    assert_allclose(np.asarray(w), np.asarray(we), rtol=RTOL, atol=ATOL)
    assert_allclose(np.asarray(o0), np.asarray(e0), rtol=RTOL, atol=ATOL)
    assert_allclose(np.asarray(o1), np.asarray(e1), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("b,n", [(4, 8), (16, 64), (32, 512)])
def test_recover_pallas_matches_ref(b, n):
    rng = np.random.default_rng(b + n)
    c, w = rand(rng, b, n), rand(rng, b, n)
    y = rand(rng, b, b)
    got = hh_update.recover_pallas(c, y, w)
    assert_allclose(
        np.asarray(got), np.asarray(ref.recover(c, y, w)), rtol=RTOL, atol=ATOL
    )


def test_tree_update_equals_full_stacked_apply():
    """The distributed pair step must equal applying the merged Q^T to the
    stacked [C0; C1] — the algebra Algorithm 1/2 relies on."""
    rng = np.random.default_rng(11)
    b, n = 8, 32
    r0, r1 = upper(rng, b), upper(rng, b)
    y0, y1, t, _ = ref.tsqr_merge(r0, r1)
    c0, c1 = rand(rng, b, n), rand(rng, b, n)
    _, o0, o1 = ref.tree_update(c0, c1, y1, t)
    y = jnp.concatenate([y0, y1])
    full = ref.leaf_apply(y, t, jnp.concatenate([c0, c1]))
    assert_allclose(np.asarray(o0), np.asarray(full[:b]), rtol=1e-3, atol=1e-4)
    assert_allclose(np.asarray(o1), np.asarray(full[b:]), rtol=1e-3, atol=1e-4)


def test_recovery_identity():
    """Paper III-C: C1_hat recomputed from (C1, Y1, W) equals the original
    computation — the single-buddy recovery invariant."""
    rng = np.random.default_rng(13)
    b, n = 16, 64
    r0, r1 = upper(rng, b), upper(rng, b)
    _, y1, t, _ = ref.tsqr_merge(r0, r1)
    c0, c1 = rand(rng, b, n), rand(rng, b, n)
    w, o0, o1 = ref.tree_update(c0, c1, y1, t)
    # bottom buddy recovery
    rec1 = hh_update.recover_pallas(c1, y1, w)
    assert_allclose(np.asarray(rec1), np.asarray(o1), rtol=RTOL, atol=ATOL)
    # top buddy recovery (Y = I)
    rec0 = hh_update.recover_pallas(c0, jnp.eye(b), w)
    assert_allclose(np.asarray(rec0), np.asarray(o0), rtol=RTOL, atol=ATOL)


# ---------------------------------------------------------------------------
# Hypothesis sweeps: shapes, seeds, tiles.
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    b_log=st.integers(1, 4),
    n_mult=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_hyp_tree_update(b_log, n_mult, seed):
    b = 2**b_log
    n = b * n_mult
    rng = np.random.default_rng(seed)
    r0, r1 = upper(rng, b), upper(rng, b)
    _, y1, t, _ = ref.tsqr_merge(r0, r1)
    c0, c1 = rand(rng, b, n), rand(rng, b, n)
    w, o0, o1 = hh_update.tree_update_pallas(c0, c1, y1, t)
    we, e0, e1 = ref.tree_update(c0, c1, y1, t)
    assert_allclose(np.asarray(w), np.asarray(we), rtol=1e-3, atol=1e-4)
    assert_allclose(np.asarray(o0), np.asarray(e0), rtol=1e-3, atol=1e-4)
    assert_allclose(np.asarray(o1), np.asarray(e1), rtol=1e-3, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    m_mult=st.integers(1, 8),
    b_log=st.integers(1, 4),
    n_mult=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_hyp_leaf_apply(m_mult, b_log, n_mult, seed):
    b = 2**b_log
    m = b * m_mult
    n = b * n_mult
    rng = np.random.default_rng(seed)
    y, t, _ = ref.householder_qr(rand(rng, m, b))
    c = rand(rng, m, n)
    got = hh_update.leaf_apply_pallas(y, t, c)
    want = ref.leaf_apply(y, t, c)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(m_log=st.integers(2, 6), b_log=st.integers(1, 4), seed=st.integers(0, 9999))
def test_hyp_householder_qr_gram_invariant(m_log, b_log, seed):
    """R^T R == A^T A for any panel (the sign-free QR correctness check)."""
    m, b = 2**m_log, 2**b_log
    if b > m:
        b = m
    rng = np.random.default_rng(seed)
    a = rand(rng, m, b)
    _, _, r = ref.householder_qr(a)
    assert_allclose(
        np.asarray(r.T @ r), np.asarray(a.T @ a), rtol=5e-3, atol=5e-4
    )


def test_vmem_estimates_within_budget():
    from compile.aot import VMEM_BUDGET, check_vmem, default_profile

    for op, params in default_profile():
        v = check_vmem(op, params)
        if v is not None:
            assert v <= VMEM_BUDGET
