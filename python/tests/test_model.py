"""L2 model + AOT path tests: op registry shapes, blocked-QR composition,
manifest generation round-trip (smoke profile)."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import aot, model
from compile.kernels import ref


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def test_ops_registry_complete():
    assert set(model.OPS) == {
        "panel_qr",
        "tsqr_merge",
        "leaf_apply",
        "tree_update",
        "recover",
    }


@pytest.mark.parametrize(
    "op,params",
    [
        ("panel_qr", {"m": 16, "b": 4}),
        ("tsqr_merge", {"b": 4}),
        ("leaf_apply", {"m": 16, "b": 4, "n": 8}),
        ("tree_update", {"b": 4, "n": 8}),
        ("recover", {"b": 4, "n": 8}),
    ],
)
def test_ops_jit_and_shapes(op, params):
    fn, builder = model.OPS[op]
    specs = builder(**params)
    out = jax.eval_shape(fn, *specs)
    leaves = jax.tree_util.tree_leaves(out)
    assert all(l.dtype == jnp.float32 for l in leaves)
    if op == "panel_qr":
        m, b = params["m"], params["b"]
        assert [tuple(l.shape) for l in leaves] == [(m, b), (b, b), (b, b)]
    elif op == "tsqr_merge":
        b = params["b"]
        assert [tuple(l.shape) for l in leaves] == [(b, b)] * 4
    elif op == "tree_update":
        b, n = params["b"], params["n"]
        assert [tuple(l.shape) for l in leaves] == [(b, n)] * 3


def test_blocked_qr_matches_dense_gram():
    """Reference blocked QR (the composition the coordinator mirrors) must
    satisfy R^T R = A^T A."""
    rng = np.random.default_rng(21)
    a = rand(rng, 64, 32)
    r = ref.blocked_qr(a, 8)
    assert_allclose(
        np.asarray(r.T @ r), np.asarray(a.T @ a), rtol=5e-3, atol=5e-4
    )
    assert_allclose(np.tril(np.asarray(r), -1), 0.0, atol=1e-5)


def test_tsqr_matches_monolithic_qr():
    rng = np.random.default_rng(5)
    blocks = [rand(rng, 32, 8) for _ in range(4)]
    r_tree = ref.tsqr(blocks)
    a = jnp.concatenate(blocks)
    _, _, r_mono = ref.householder_qr(a)
    assert_allclose(
        np.asarray(r_tree.T @ r_tree),
        np.asarray(r_mono.T @ r_mono),
        rtol=5e-3,
        atol=5e-4,
    )


def test_tsqr_non_power_of_two():
    rng = np.random.default_rng(6)
    blocks = [rand(rng, 16, 4) for _ in range(5)]
    r = ref.tsqr(blocks)
    a = jnp.concatenate(blocks)
    assert_allclose(
        np.asarray(r.T @ r), np.asarray(a.T @ a), rtol=5e-3, atol=5e-4
    )


def test_aot_smoke_profile_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        man = aot.build(d, profile="smoke")
        assert len(man["artifacts"]) == 5
        for e in man["artifacts"]:
            p = os.path.join(d, e["file"])
            assert os.path.exists(p)
            text = open(p).read()
            assert "HloModule" in text
        # idempotent second run
        man2 = aot.build(d, profile="smoke")
        assert {e["file"] for e in man2["artifacts"]} == {
            e["file"] for e in man["artifacts"]
        }
        # manifest JSON is loadable and shape metadata is sane
        j = json.load(open(os.path.join(d, "manifest.json")))
        leaf = next(e for e in j["artifacts"] if e["op"] == "leaf_apply")
        assert leaf["inputs"] == [[16, 4], [4, 4], [16, 8]]
        assert leaf["outputs"] == [[16, 8]]


def test_artifact_names_unique():
    names = [aot.artifact_name(op, p) for op, p in aot.default_profile()]
    assert len(names) == len(set(names))
