"""L1 Pallas kernel: blocked Householder application (the flops hot-spot).

CAQR's dominant cost is applying compact-WY reflectors to the trailing
matrix: per panel it is O(m * n * b) flops versus O(m * b^2) for the panel
factorization itself. This module implements that application as a Pallas
kernel, tiled along the trailing-matrix columns so each tile's working set
fits VMEM.

TPU mapping (DESIGN.md "Hardware adaptation"):
  * grid = (ceil(n / nt),): one program per column tile of C.
  * Y (m, b) and T (b, b) are small and column-tile-invariant, so their
    BlockSpecs pin them in VMEM across the whole grid (index_map -> (0, 0)).
  * Each program runs a chain of three MXU matmuls entirely in VMEM:
        P = Y^T C_tile        (b, nt)
        W = T^T P             (b, nt)
        out = C_tile - Y W    (m, nt)
  * VMEM footprint per program: (m*b + b*b + 2*m*nt + 2*b*nt) * 4 bytes;
    the aot manifest asserts this stays under the 16 MiB budget per shape.

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs on the Rust CPU client (and validates the numerics that a
real-TPU build would produce).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["leaf_apply_pallas", "tree_update_pallas", "recover_pallas"]

# Default column-tile width. 128 matches the MXU lane width; shapes smaller
# than this fall back to a single tile.
DEFAULT_TILE = 128


def _leaf_kernel(y_ref, t_ref, c_ref, out_ref):
    """out = C - Y (T^T (Y^T C)) for one column tile of C."""
    y = y_ref[...]
    t = t_ref[...]
    c = c_ref[...]
    p = jnp.dot(y.T, c)  # (b, nt)   MXU
    w = jnp.dot(t.T, p)  # (b, nt)   MXU
    out_ref[...] = c - jnp.dot(y, w)  # (m, nt)   MXU


def _pick_tile(n: int, tile: int | None) -> int:
    tile = tile or DEFAULT_TILE
    if n <= tile:
        return n
    # Require an exact tiling; the aot manifest only emits n that are
    # multiples of the tile (the Rust side zero-pads up to that).
    while n % tile != 0:
        tile //= 2
    return max(tile, 1)


def leaf_apply_pallas(y, t, c, *, tile: int | None = None):
    """C_hat = (I - Y T Y^T)^T C, column-tiled Pallas kernel.

    Args:
      y: (m, b) unit-lower Householder vectors.
      t: (b, b) upper-triangular T factor.
      c: (m, n) trailing block; n must be a multiple of the chosen tile.
    """
    m, b = y.shape
    n = c.shape[1]
    nt = _pick_tile(n, tile)
    grid = (n // nt,)
    return pl.pallas_call(
        _leaf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, b), lambda i: (0, 0)),  # Y resident
            pl.BlockSpec((b, b), lambda i: (0, 0)),  # T resident
            pl.BlockSpec((m, nt), lambda i: (0, i)),  # C column tiles
        ],
        out_specs=pl.BlockSpec((m, nt), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), c.dtype),
        interpret=True,
    )(y, t, c)


def _tree_kernel(y1_ref, t_ref, c0_ref, c1_ref, w_ref, o0_ref, o1_ref):
    """One pairwise tree-update step for one column tile.

    Structured reflector Q = I - [I; Y1] T [I; Y1]^T:
      W  = T^T (C0 + Y1^T C1)
      O0 = C0 - W
      O1 = C1 - Y1 W
    W is emitted as a first-class output: it is the redundancy payload the
    fault-tolerant protocol keeps for recovery (paper III-C).
    """
    y1 = y1_ref[...]
    t = t_ref[...]
    c0 = c0_ref[...]
    c1 = c1_ref[...]
    s = c0 + jnp.dot(y1.T, c1)  # (b, nt)  MXU
    w = jnp.dot(t.T, s)  # (b, nt)  MXU
    w_ref[...] = w
    o0_ref[...] = c0 - w
    o1_ref[...] = c1 - jnp.dot(y1, w)  # MXU


def tree_update_pallas(c0, c1, y1, t, *, tile: int | None = None):
    """Pairwise trailing-update step (paper Algorithm 1/2 compute core).

    Args:
      c0: (b, n) top buddy's C' rows.
      c1: (b, n) bottom buddy's C' rows.
      y1: (b, b) bottom part of the merge reflectors.
      t:  (b, b) T factor of the merge.
    Returns (w, c0_hat, c1_hat), each (b, n).
    """
    b, n = c0.shape
    nt = _pick_tile(n, tile)
    grid = (n // nt,)
    shp = jax.ShapeDtypeStruct((b, n), c0.dtype)
    return pl.pallas_call(
        _tree_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, b), lambda i: (0, 0)),  # Y1 resident
            pl.BlockSpec((b, b), lambda i: (0, 0)),  # T resident
            pl.BlockSpec((b, nt), lambda i: (0, i)),
            pl.BlockSpec((b, nt), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((b, nt), lambda i: (0, i)),
            pl.BlockSpec((b, nt), lambda i: (0, i)),
            pl.BlockSpec((b, nt), lambda i: (0, i)),
        ],
        out_shape=[shp, shp, shp],
        interpret=True,
    )(y1, t, c0, c1)


def _recover_kernel(y_ref, c_ref, w_ref, out_ref):
    """out = C - Y W : the single-buddy recovery recompute (paper III-C)."""
    out_ref[...] = c_ref[...] - jnp.dot(y_ref[...], w_ref[...])


def recover_pallas(c, y, w, *, tile: int | None = None):
    """Recompute a failed rank's update from buddy data: C_hat = C - Y W."""
    b, n = c.shape
    nt = _pick_tile(n, tile)
    grid = (n // nt,)
    return pl.pallas_call(
        _recover_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, b), lambda i: (0, 0)),  # Y resident
            pl.BlockSpec((b, nt), lambda i: (0, i)),
            pl.BlockSpec((b, nt), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, nt), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), c.dtype),
        interpret=True,
    )(y, c, w)


@functools.lru_cache(maxsize=None)
def vmem_bytes_leaf(m: int, b: int, nt: int, itemsize: int = 4) -> int:
    """Per-program VMEM estimate for the leaf kernel (see module docstring)."""
    return (m * b + b * b + 2 * m * nt + 2 * b * nt) * itemsize


@functools.lru_cache(maxsize=None)
def vmem_bytes_tree(b: int, nt: int, itemsize: int = 4) -> int:
    """Per-program VMEM estimate for the tree-update kernel."""
    return (2 * b * b + 7 * b * nt) * itemsize
