"""Pure-jnp reference oracles for every kernel and model-level op.

These are the ground truth the Pallas kernels (and, transitively, the HLO
artifacts the Rust coordinator executes) are validated against in pytest.
Everything here is written for clarity, not speed.

Conventions (LAPACK compact-WY):
  * ``Y`` is unit-lower-trapezoidal (m, b): the implicit 1.0 on the diagonal
    is stored explicitly so the Rust side never re-materializes it.
  * ``T`` is upper-triangular (b, b) with ``Q = I - Y T Y^T``.
  * ``R`` is upper-triangular; we do NOT enforce a positive diagonal (the
    factorization is unique only up to column signs, so tests compare
    ``R^T R`` or sign-normalized factors).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "householder_qr",
    "tsqr_merge",
    "leaf_apply",
    "tree_update",
    "recover",
    "tsqr",
    "blocked_qr",
]


def _house(x: jnp.ndarray, j):
    """Householder vector for column ``x`` with rows ``< j`` masked out.

    Returns ``(v, tau, beta)`` with ``v`` unit at position ``j`` (v[j] == 1
    whenever tau != 0) and ``(I - tau v v^T) x = beta e_j``.
    Handles the x == 0 edge case with tau = 0 (H = I).
    """
    m = x.shape[0]
    rows = jnp.arange(m)
    mask = rows >= j
    x = jnp.where(mask, x, 0.0)
    x0 = jnp.sum(jnp.where(rows == j, x, 0.0))
    normx = jnp.sqrt(jnp.sum(x * x))
    sign = jnp.where(x0 >= 0.0, 1.0, -1.0)
    beta = -sign * normx  # new diagonal entry
    v0 = x0 - beta  # v[j] before normalization
    # Unnormalized v = x - beta e_j; tau_unnorm = 2 / (v^T v).
    v = jnp.where(rows == j, v0, x)
    vtv = jnp.sum(v * v)
    nonzero = vtv > 0.0
    # Normalize so v[j] == 1: v_unit = v / v0, tau = 2 v0^2 / vtv.
    safe_v0 = jnp.where(jnp.abs(v0) > 0.0, v0, 1.0)
    ok = nonzero & (jnp.abs(v0) > 0.0)
    v_unit = jnp.where(ok, v / safe_v0, 0.0)
    v_unit = jnp.where(rows == j, jnp.where(ok, 1.0, 0.0), v_unit)
    tau = jnp.where(ok, 2.0 * v0 * v0 / vtv, 0.0)
    beta = jnp.where(nonzero, beta, x0)
    return v_unit, tau, beta


def householder_qr(a: jnp.ndarray):
    """Blocked Householder QR of an (m, b) panel.

    Returns ``(y, t, r)``:
      * ``y``: (m, b) unit-lower-trapezoidal Householder vectors,
      * ``t``: (b, b) upper-triangular with ``Q = I - Y T Y^T``,
      * ``r``: (b, b) upper-triangular factor (top b rows of the reduced A).

    Zero-row padding is exact: appended zero rows yield zero rows in ``y``
    and leave ``r`` unchanged.
    """
    m, b = a.shape

    def body(j, carry):
        a, y, taus = carry
        v, tau, _beta = _house(a[:, j], j)
        # Apply H = I - tau v v^T to the whole panel (columns < j have zeros
        # below the diagonal already and v has zeros above row j, so they
        # are untouched -- applying to all columns keeps shapes static).
        w = tau * (v @ a)  # (b,)
        a = a - jnp.outer(v, w)
        y = y.at[:, j].set(v)
        taus = taus.at[j].set(tau)
        return a, y, taus

    a_out, y, taus = jax.lax.fori_loop(
        0, b, body, (a, jnp.zeros_like(a), jnp.zeros((b,), a.dtype))
    )
    r = jnp.triu(a_out[:b, :])

    # Accumulate T: T[:j, j] = -tau_j * T[:j, :j] @ (Y^T y_j); T[j, j] = tau_j
    yty = y.T @ y  # (b, b); column j rows :j give Y[:, :j]^T y_j

    def t_body(j, t):
        col = -taus[j] * (t @ jnp.where(jnp.arange(b) < j, yty[:, j], 0.0))
        col = jnp.where(jnp.arange(b) == j, taus[j], col)
        col = jnp.where(jnp.arange(b) <= j, col, 0.0)
        return t.at[:, j].set(col)

    t = jax.lax.fori_loop(0, b, t_body, jnp.zeros((b, b), a.dtype))
    return y, t, r


def tsqr_merge(r0: jnp.ndarray, r1: jnp.ndarray):
    """QR of the stacked pair ``[r0; r1]`` (each (b, b) upper-triangular).

    Returns ``(y0, y1, t, r)`` where the merged Q = I - [Y0; Y1] T [Y0; Y1]^T.
    When ``r0``/``r1`` are exactly upper-triangular, ``y0 == I`` structurally
    (the paper's ``[I; Y1]`` form); we return it anyway so the Rust side can
    stay fully general (e.g. padded/perturbed inputs).
    """
    b = r0.shape[0]
    stacked = jnp.concatenate([r0, r1], axis=0)
    y, t, r = householder_qr(stacked)
    return y[:b], y[b:], t, r


def leaf_apply(y: jnp.ndarray, t: jnp.ndarray, c: jnp.ndarray):
    """Apply the local Q^T to a trailing block: C <- (I - Y T Y^T)^T C.

    (I - Y T Y^T)^T = I - Y T^T Y^T, so:
      W = T^T (Y^T C);  C_hat = C - Y W.
    """
    w = t.T @ (y.T @ c)
    return c - y @ w


def tree_update(c0: jnp.ndarray, c1: jnp.ndarray, y1: jnp.ndarray, t: jnp.ndarray):
    """One pairwise step of the trailing-matrix update tree (paper Alg 1/2).

    Uses the structured merge Q = I - [I; Y1] T [I; Y1]^T:
      W      = T^T (C0 + Y1^T C1)
      C0_hat = C0 - W
      C1_hat = C1 - Y1 W
    Returns ``(w, c0_hat, c1_hat)``. ``w`` is returned because it is exactly
    the payload the fault-tolerant recovery protocol stores (paper III-C).
    """
    w = t.T @ (c0 + y1.T @ c1)
    return w, c0 - w, c1 - y1 @ w


def recover(c: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray):
    """Recompute a failed process's update from buddy data (paper III-C):
    ``C_hat = C - Y W``. For the top ('even') member of a pair Y == I.
    """
    return c - y @ w


# ---------------------------------------------------------------------------
# Whole-algorithm references (used by pytest to validate the composition the
# Rust coordinator performs, and to cross-check the Rust oracle itself).
# ---------------------------------------------------------------------------


def tsqr(blocks):
    """Reference TSQR over a list of (m_i, b) blocks -> R (b, b).

    Binary tree over the list; lengths that are not powers of two are
    handled by promoting the odd block unchanged (same as the Rust tree).
    """
    rs = [householder_qr(blk)[2] for blk in blocks]
    while len(rs) > 1:
        nxt = []
        for i in range(0, len(rs) - 1, 2):
            _, _, _, r = tsqr_merge(rs[i], rs[i + 1])
            nxt.append(r)
        if len(rs) % 2 == 1:
            nxt.append(rs[-1])
        rs = nxt
    return rs[0]


def blocked_qr(a: jnp.ndarray, b: int):
    """Reference right-looking blocked QR of (m, n) ``a`` with panel width b.

    Returns R (n, n). Used to validate the distributed CAQR composition
    end-to-end (compare R^T R against the coordinator's output).
    """
    m, n = a.shape
    r_out = jnp.zeros((n, n), a.dtype)
    work = a
    for k in range(0, n, b):
        bw = min(b, n - k)
        panel = work[k:, k : k + bw]
        y, t, r = householder_qr(panel)
        r_out = r_out.at[k : k + bw, k : k + bw].set(r[:bw, :bw])
        if k + bw < n:
            trail = leaf_apply(y, t, work[k:, k + bw :])
            work = work.at[k:, k + bw :].set(trail)
            r_out = r_out.at[k : k + bw, k + bw :].set(trail[:bw, :])
    return r_out
