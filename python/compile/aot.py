"""AOT lowering: jit each (op, shape) to HLO *text* + a JSON manifest.

This is the only place Python touches the build: ``make artifacts`` runs it
once, the Rust coordinator then loads ``artifacts/manifest.json`` and the
``*.hlo.txt`` files through the PJRT CPU client and never imports Python
again.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Shape strategy: artifacts are static-shaped; the Rust runtime zero-pads a
request up to the smallest artifact that fits (exact for Householder QR and
for trailing updates -- see DESIGN.md "Shape strategy"). The default
profile below enumerates the shape ladder used by the examples, tests and
benches.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import hh_update

# VMEM budget we assert per-program for the Pallas kernels (16 MiB, the
# per-core VMEM of current TPUs). interpret=True doesn't enforce this; the
# manifest check is the documented stand-in for real-TPU compilation.
VMEM_BUDGET = 16 * 1024 * 1024


def _ladder(b: int, n_max: int):
    """Column ladder {b, 2b, 4b, ...} up to n_max."""
    out, n = [], b
    while n <= n_max:
        out.append(n)
        n *= 2
    return out


def default_profile():
    """(op, params) list covering examples/, tests/ and benches/."""
    entries = []
    panel = [(64, 8), (64, 16), (128, 16), (128, 32), (256, 32)]
    for m, b in panel:
        entries.append(("panel_qr", {"m": m, "b": b}))
    for b in (8, 16, 32):
        entries.append(("tsqr_merge", {"b": b}))
    ladders = {8: _ladder(8, 64), 16: _ladder(16, 256), 32: _ladder(32, 512)}
    for m, b in panel:
        for n in ladders[b]:
            entries.append(("leaf_apply", {"m": m, "b": b, "n": n}))
    for b, ns in ladders.items():
        for n in ns:
            entries.append(("tree_update", {"b": b, "n": n}))
            entries.append(("recover", {"b": b, "n": n}))
    return entries


def smoke_profile():
    """Tiny set for fast CI of the aot path itself."""
    return [
        ("panel_qr", {"m": 16, "b": 4}),
        ("tsqr_merge", {"b": 4}),
        ("leaf_apply", {"m": 16, "b": 4, "n": 8}),
        ("tree_update", {"b": 4, "n": 8}),
        ("recover", {"b": 4, "n": 8}),
    ]


PROFILES = {"default": default_profile, "smoke": smoke_profile}


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifact_name(op: str, params: dict) -> str:
    tag = "_".join(f"{k}{v}" for k, v in sorted(params.items()))
    return f"{op}_{tag}"


def check_vmem(op: str, params: dict) -> int | None:
    """Per-program VMEM estimate for the Pallas-backed ops (bytes)."""
    nt = min(params.get("n", 0), hh_update.DEFAULT_TILE) or None
    if op == "leaf_apply":
        v = hh_update.vmem_bytes_leaf(params["m"], params["b"], nt)
    elif op in ("tree_update", "recover"):
        v = hh_update.vmem_bytes_tree(params["b"], nt)
    else:
        return None
    assert v <= VMEM_BUDGET, f"{op} {params}: VMEM estimate {v} > budget"
    return v


def lower_one(op: str, params: dict, out_dir: str) -> dict:
    fn, builder = model.OPS[op]
    specs = builder(**params)
    t0 = time.time()
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    name = artifact_name(op, params)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_shapes = [
        list(s.shape) for s in jax.tree_util.tree_leaves(lowered.out_info)
    ]
    entry = {
        "op": op,
        "params": params,
        "file": os.path.basename(path),
        "inputs": [list(s.shape) for s in specs],
        "outputs": out_shapes,
        "dtype": "f32",
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "lower_seconds": round(time.time() - t0, 3),
    }
    vmem = check_vmem(op, params)
    if vmem is not None:
        entry["vmem_bytes_per_program"] = vmem
    return entry


def build(out_dir: str, profile: str = "default", force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    entries = PROFILES[profile]()
    # Incremental: if the manifest exists and covers the same profile with
    # all files present, `make artifacts` is a no-op.
    if not force and os.path.exists(manifest_path):
        try:
            old = json.load(open(manifest_path))
            want = {artifact_name(op, p) for op, p in entries}
            have = {artifact_name(e["op"], e["params"]) for e in old["artifacts"]}
            files_ok = all(
                os.path.exists(os.path.join(out_dir, e["file"]))
                for e in old["artifacts"]
            )
            if want <= have and files_ok and old.get("profile") == profile:
                # keep the rust-readable twin in sync
                if not os.path.exists(os.path.join(out_dir, "manifest.txt")):
                    write_text_manifest(out_dir, old)
                print(f"artifacts up-to-date ({len(old['artifacts'])} entries)")
                return old
        except (json.JSONDecodeError, KeyError):
            pass

    arts = []
    for i, (op, params) in enumerate(entries):
        e = lower_one(op, params, out_dir)
        arts.append(e)
        print(
            f"[{i + 1}/{len(entries)}] {e['file']}"
            f" ({e['lower_seconds']}s)",
            flush=True,
        )
    manifest = {
        "version": 1,
        "profile": profile,
        "jax_version": jax.__version__,
        "tile": hh_update.DEFAULT_TILE,
        "artifacts": arts,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    write_text_manifest(out_dir, manifest)
    print(f"wrote {manifest_path}: {len(arts)} artifacts")
    return manifest


def write_text_manifest(out_dir: str, manifest: dict) -> None:
    """Plain-text manifest for the offline Rust loader (no JSON parser in
    the image's crate set). One line per artifact:

        artifact|<op>|<file>|k=v,k=v|RxC;RxC|RxC;RxC
    """
    lines = [
        f"# ftcaqr manifest v{manifest['version']}",
        f"profile={manifest['profile']}",
        f"jax={manifest['jax_version']}",
        f"tile={manifest['tile']}",
    ]
    for e in manifest["artifacts"]:
        params = ",".join(f"{k}={v}" for k, v in sorted(e["params"].items()))
        ins = ";".join("x".join(str(d) for d in s) for s in e["inputs"])
        outs = ";".join("x".join(str(d) for d in s) for s in e["outputs"])
        lines.append(f"artifact|{e['op']}|{e['file']}|{params}|{ins}|{outs}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--profile", default="default", choices=sorted(PROFILES))
    ap.add_argument("--force", action="store_true", help="rebuild everything")
    args = ap.parse_args(argv)
    build(args.out, args.profile, args.force)
    return 0


if __name__ == "__main__":
    sys.exit(main())
