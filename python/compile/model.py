"""L2: the paper's compute graph in JAX, calling the L1 Pallas kernels.

Five operations make up the whole distributed algorithm; each is a jitted
function that ``aot.py`` lowers to one HLO-text artifact per static shape.
The Rust coordinator composes them across simulated MPI ranks:

  panel_qr    (m, b)        -> (Y, T, R)       local leaf factorization
  tsqr_merge  (b, b)x2      -> (Y0, Y1, T, R)  TSQR tree merge step
  leaf_apply  (m,b),(b,b),(m,n) -> C_hat       apply local Q^T to trailing
  tree_update (b,n)x2,(b,b)x2   -> (W, C0_hat, C1_hat)  pairwise tree step
  recover     (b,n),(b,b),(b,n) -> C_hat       single-buddy recovery

The flops-heavy ops (leaf_apply, tree_update, recover) go through the
Pallas kernels in ``kernels/hh_update.py``; the panel factorization is a
pure-jnp Householder loop (it is O(m b^2), not the hot-spot, and a
sequential scalar loop gains nothing from Pallas on the MXU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import hh_update, ref

__all__ = [
    "panel_qr",
    "tsqr_merge",
    "leaf_apply",
    "tree_update",
    "recover",
    "OPS",
]


def panel_qr(a):
    """Local panel factorization: (m, b) -> (Y (m,b), T (b,b), R (b,b))."""
    return ref.householder_qr(a)


def tsqr_merge(r0, r1):
    """TSQR merge: QR of [r0; r1] -> (Y0 (b,b), Y1 (b,b), T (b,b), R (b,b)).

    Y0 is returned even though it is structurally I for exactly-triangular
    inputs -- the artifact stays correct for padded / perturbed inputs and
    the Rust side can assert the structure instead of assuming it.
    """
    return ref.tsqr_merge(r0, r1)


def leaf_apply(y, t, c):
    """Trailing-block application of the local reflectors (Pallas)."""
    return hh_update.leaf_apply_pallas(y, t, c)


def tree_update(c0, c1, y1, t):
    """Pairwise trailing-update tree step (Pallas): returns (W, C0h, C1h)."""
    return hh_update.tree_update_pallas(c0, c1, y1, t)


def recover(c, y, w):
    """Single-buddy recovery recompute (Pallas): C_hat = C - Y W."""
    return hh_update.recover_pallas(c, y, w)


def _spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# Registry consumed by aot.py: op name -> (callable, example-args builder).
# Each builder takes the shape params relevant to that op and returns the
# ShapeDtypeStructs to lower with.
OPS = {
    "panel_qr": (panel_qr, lambda m, b: (_spec(m, b),)),
    "tsqr_merge": (tsqr_merge, lambda b: (_spec(b, b), _spec(b, b))),
    "leaf_apply": (
        leaf_apply,
        lambda m, b, n: (_spec(m, b), _spec(b, b), _spec(m, n)),
    ),
    "tree_update": (
        tree_update,
        lambda b, n: (_spec(b, n), _spec(b, n), _spec(b, b), _spec(b, b)),
    ),
    "recover": (
        recover,
        lambda b, n: (_spec(b, n), _spec(b, b), _spec(b, n)),
    ),
}
