//! Reproduce paper Fig 2: FT-TSQR's redundancy doubles at every step of
//! the all-exchange reduction tree, while the plain reduction keeps a
//! single holder of each intermediate R.
//!
//! ```text
//! cargo run --release --example tsqr_tree
//! ```

use ftcaqr::backend::Backend;
use ftcaqr::coordinator::{run_tsqr, TsqrMode};
use ftcaqr::linalg::{gram_residual, Matrix};
use ftcaqr::sim::CostModel;

fn main() -> anyhow::Result<()> {
    println!("== E1: TSQR redundancy per tree step (paper Fig 2) ==\n");
    println!("{:>6} {:>10} {:>24} {:>14}", "procs", "mode", "redundancy per step", "final holders");
    for procs in [2usize, 4, 8, 16] {
        let a = Matrix::randn(procs * 64, 16, 42);
        for (name, mode) in [("plain", TsqrMode::Plain), ("ft", TsqrMode::FaultTolerant)] {
            let out = run_tsqr(&a, procs, mode, Backend::native(), CostModel::default())?;
            assert!(gram_residual(&a, &out.r) < 1e-3);
            println!(
                "{procs:>6} {name:>10} {:>24} {:>11}/{procs}",
                format!("{:?}", out.redundancy),
                out.final_holders
            );
        }
    }
    println!("\nFT doubles the holders of the root-path R at every step (2,4,8,...)");
    println!("=> after step s, up to 2^(s+1) process failures leave a live copy.");

    // Critical-path comparison (the §III-B low-overhead claim).
    println!("\n{:>6} {:>14} {:>14} {:>8}", "procs", "cp plain (us)", "cp ft (us)", "ratio");
    for procs in [4usize, 8, 16, 32] {
        let a = Matrix::randn(procs * 64, 16, 7);
        let p = run_tsqr(&a, procs, TsqrMode::Plain, Backend::native(), CostModel::default())?;
        let f = run_tsqr(&a, procs, TsqrMode::FaultTolerant, Backend::native(), CostModel::default())?;
        let (cp, cf) = (p.report.critical_path * 1e6, f.report.critical_path * 1e6);
        println!("{procs:>6} {cp:>14.3} {cf:>14.3} {:>8.3}", cf / cp);
    }
    Ok(())
}
