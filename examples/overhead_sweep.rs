//! E2: failure-free overhead of the fault-tolerant algorithm (paper C1).
//!
//! Sweeps process count and matrix size, comparing Algorithm 1 (plain)
//! against Algorithm 2 (FT) on: critical path (dual-channel cost model),
//! messages/exchanges, bytes, and flops (the paper's traded energy, C4).
//! Also shows the single-channel variant, where the paper's "exchange
//! overlaps" argument no longer holds.
//!
//! ```text
//! cargo run --release --example overhead_sweep
//! ```

use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::run_caqr_simple;
use ftcaqr::sim::CostModel;

fn run(cfg: RunConfig) -> anyhow::Result<ftcaqr::coordinator::CaqrOutcome> {
    Ok(run_caqr_simple(cfg)?)
}

fn main() -> anyhow::Result<()> {
    println!("== E2: failure-free overhead, FT (Alg 2) vs plain (Alg 1) ==\n");
    println!(
        "{:>5} {:>10} {:>12} {:>12} {:>8} {:>9} {:>12} {:>9}",
        "P", "matrix", "cp plain us", "cp ft us", "cp ratio", "msg p/f", "bytes p/f", "flop f/p"
    );
    // P >= 32 rows run on the pooled scheduler exactly like P = 2 — rank
    // tasks park on communication instead of holding an OS thread each.
    for procs in [2usize, 4, 8, 16, 32, 64] {
        for (rows, cols, block) in [(procs * 64, 128, 32), (procs * 128, 256, 32)] {
            if cols > rows {
                continue;
            }
            let mk = |alg| RunConfig {
                rows,
                cols,
                block,
                procs,
                algorithm: alg,
                verify: false,
                ..Default::default()
            };
            let p = run(mk(Algorithm::Plain))?;
            let f = run(mk(Algorithm::FaultTolerant))?;
            println!(
                "{procs:>5} {:>10} {:>12.3} {:>12.3} {:>8.3} {:>9} {:>12} {:>9.3}",
                format!("{rows}x{cols}"),
                p.report.critical_path * 1e6,
                f.report.critical_path * 1e6,
                f.report.critical_path / p.report.critical_path,
                format!("{}/{}", p.report.messages, f.report.exchanges),
                format!("{}/{}", p.report.bytes, f.report.bytes),
                f.backend_flops as f64 / p.backend_flops as f64,
            );
        }
    }

    println!("\n-- dual-channel vs single-channel (the overlap assumption) --");
    println!("{:>5} {:>14} {:>16} {:>9}", "P", "cp ft dual us", "cp ft single us", "ratio");
    for procs in [4usize, 8, 16] {
        let mk = |cost| RunConfig {
            rows: procs * 128,
            cols: 256,
            block: 32,
            procs,
            algorithm: Algorithm::FaultTolerant,
            cost,
            verify: false,
            ..Default::default()
        };
        let dual = run(mk(CostModel::default()))?;
        let single = run(mk(CostModel::single_channel()))?;
        println!(
            "{procs:>5} {:>14.3} {:>16.3} {:>9.3}",
            dual.report.critical_path * 1e6,
            single.report.critical_path * 1e6,
            single.report.critical_path / dual.report.critical_path
        );
    }
    println!("\nPaper C1 holds on dual-channel links: cp ratio ~1; the FT cost");
    println!("is paid in flops (C4), not in critical-path communication.");
    Ok(())
}
