//! E3: kill a rank mid-factorization, REBUILD it, recover its state from
//! single-buddy retained data, and verify the result is *identical* to
//! the failure-free run (paper §III-C).
//!
//! ```text
//! cargo run --release --example ft_recovery
//! ```

use ftcaqr::backend::Backend;
use ftcaqr::config::RunConfig;
use ftcaqr::coordinator::run_caqr_matrix;
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::linalg::Matrix;
use ftcaqr::trace::Trace;

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        rows: 1024,
        cols: 256,
        block: 32,
        procs: 8,
        ..Default::default()
    };
    let a = Matrix::randn(cfg.rows, cfg.cols, 123);

    println!("== E3: single-buddy recovery (paper III-C) ==");
    println!("matrix {}x{}, b={}, P={}\n", cfg.rows, cfg.cols, cfg.block, cfg.procs);

    let clean = run_caqr_matrix(
        cfg.clone(),
        a.clone(),
        Backend::native(),
        FaultPlan::none(),
        Trace::disabled(),
    )?;
    println!("failure-free: cp={:.3}us residual={:.2e}",
        clean.report.critical_path * 1e6, clean.residual.unwrap());

    println!(
        "\n{:>7} {:>7} {:>12} {:>12} {:>10} {:>11}",
        "victim", "panel", "cp (us)", "cp overhead", "fetches", "identical R"
    );
    for (victim, panel) in [(3usize, 0usize), (5, 1), (2, 3), (6, 5)] {
        let trace = Trace::new();
        let fault =
            FaultPlan::schedule(vec![ScheduledKill::new(victim, panel, 0, Phase::Update)]);
        let out = run_caqr_matrix(cfg.clone(), a.clone(), Backend::native(), fault, trace.clone())?;
        assert_eq!(out.report.failures, 1);
        assert_eq!(out.report.recoveries, 1);
        let identical = out.r == clean.r;
        println!(
            "{victim:>7} {panel:>7} {:>12.3} {:>11.2}% {:>10} {:>11}",
            out.report.critical_path * 1e6,
            (out.report.critical_path / clean.report.critical_path - 1.0) * 100.0,
            trace.of_kind("recovery_fetch").len(),
            identical
        );
        assert!(identical, "recovered factorization must be bit-identical");
    }

    // -- multi-failure scenarios (k >= 2) ---------------------------------
    println!("\n== multi-failure scenarios ==");

    // k = 3 independent kills across panels and phases: every replacement
    // replays from single-buddy state; the result is still bit-identical.
    let fault = FaultPlan::schedule(vec![
        ScheduledKill::new(3, 0, 0, Phase::Update),
        ScheduledKill::new(5, 2, 1, Phase::Tsqr),
        ScheduledKill::new(1, 4, 0, Phase::Update),
    ]);
    let out = run_caqr_matrix(cfg.clone(), a.clone(), Backend::native(), fault, Trace::disabled())?;
    assert_eq!(out.report.failures, 3);
    assert!(out.r == clean.r);
    println!(
        "  k=3 disjoint kills   : {} failures, {} recoveries, identical R — OK",
        out.report.failures, out.report.recoveries
    );

    // A failure DURING recovery: the first replacement of rank 3 dies at
    // the start of its replay; the second replacement completes it.
    let fault = FaultPlan::schedule(vec![
        ScheduledKill::new(3, 2, 0, Phase::Update),
        ScheduledKill::new(3, 0, 0, Phase::Tsqr).at_incarnation(1),
    ]);
    let out = run_caqr_matrix(cfg.clone(), a.clone(), Backend::native(), fault, Trace::disabled())?;
    assert_eq!(out.report.failures, 2);
    assert!(out.r == clean.r);
    println!(
        "  kill during REBUILD  : {} failures, {} recoveries, identical R — OK",
        out.report.failures, out.report.recoveries
    );

    // A correlated buddy-pair crash: ranks 2 and 3 (step-0 exchange
    // buddies) die at the same instant AFTER completing a shared step —
    // both copies of that step's {W, T, Y1} are lost, which the paper's
    // single-buddy protocol cannot survive. The run reports it instead
    // of hanging.
    let fault = FaultPlan::kill_pair_at((2, 3), 0, 1, Phase::Tsqr);
    let res = run_caqr_matrix(cfg.clone(), a.clone(), Backend::native(), fault, Trace::disabled());
    let err = format!("{:#}", res.expect_err("buddy-pair crash must fail"));
    assert!(err.contains("unrecoverable"));
    println!("  buddy-pair crash     : reported unrecoverable (no hang) — OK");

    println!("\nEvery recovery reconstructed the failed rank from its initial");
    println!("block + per-step {{W, T, Y1}} held by ONE buddy per step (C2).");
    Ok(())
}
