//! E5: the four FT-MPI / ULFM error-handling semantics (paper §II)
//! exercised at the simulation level: SHRINK, BLANK, REBUILD, ABORT.
//!
//! ```text
//! cargo run --release --example semantics
//! ```

use std::sync::Arc;

use ftcaqr::config::RunConfig;
use ftcaqr::coordinator::run_caqr_matrix;
use ftcaqr::backend::Backend;
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::ft::{Fail, Semantics};
use ftcaqr::linalg::Matrix;
use ftcaqr::sim::{CostModel, MsgData, Tag, TagKind, World};
use ftcaqr::trace::Trace;

/// BLANK: survivors keep their ranks; ops to the hole error; everything
/// else proceeds.
fn demo_blank() {
    let w = World::new(4, CostModel::default(), FaultPlan::none());
    let res = w.run_all(|mut ctx| {
        let tag = Tag::plain(TagKind::Misc(0));
        match ctx.rank {
            1 => Err(Fail::Killed), // simulated death; mailbox closes below
            0 => {
                // Communication avoiding the hole proceeds (ULFM).
                ctx.send(2, tag, MsgData::Ctrl(7))?;
                Ok(0u64)
            }
            2 => {
                let v = ctx.recv(0, tag)?.into_ctrl();
                // Talking to the hole errors but does NOT kill us.
                ctx.router().kill(1);
                match ctx.recv(1, tag) {
                    Err(Fail::RankFailed { rank: 1 }) => Ok(v),
                    other => panic!("expected hole error, got {other:?}"),
                }
            }
            _ => Ok(99),
        }
    });
    assert_eq!(res[2], Ok(7));
    println!("  BLANK  : hole at rank 1; rank 0->2 proceeded; ops to rank 1 error. OK");
}

/// SHRINK: survivors renumber into a dense [0, N-2] communicator.
fn demo_shrink() {
    let w = World::new(4, CostModel::default(), FaultPlan::none());
    w.router().kill(2);
    // Renumbering: live ranks in order get new contiguous ids.
    let live: Vec<usize> = (0..4).filter(|r| w.router().is_alive(*r)).collect();
    let renumber: std::collections::HashMap<usize, usize> =
        live.iter().enumerate().map(|(new, old)| (*old, new)).collect();
    assert_eq!(renumber[&0], 0);
    assert_eq!(renumber[&1], 1);
    assert_eq!(renumber[&3], 2);
    assert_eq!(w.router().alive_count(), 3);
    println!("  SHRINK : {{0,1,3}} renumbered to {{0,1,2}}; size 4 -> 3. OK");
}

/// REBUILD: the full recovery path through the CAQR driver.
fn demo_rebuild() {
    let cfg = RunConfig { rows: 512, cols: 128, block: 32, procs: 4, ..Default::default() };
    let a = Matrix::randn(cfg.rows, cfg.cols, 1);
    let fault = FaultPlan::schedule(vec![ScheduledKill::new(2, 1, 0, Phase::Update)]);
    let out = run_caqr_matrix(cfg, a, Backend::native(), fault, Trace::disabled()).unwrap();
    assert_eq!(out.report.failures, 1);
    assert_eq!(out.report.recoveries, 1);
    assert!(out.residual.unwrap() < 1e-3);
    println!("  REBUILD: rank 2 killed at panel 1, replaced + recovered; VERIFIED. OK");
}

/// ABORT: conventional behaviour — the whole run fails.
fn demo_abort() {
    let cfg = RunConfig {
        rows: 512,
        cols: 128,
        block: 32,
        procs: 4,
        semantics: Semantics::Abort,
        ..Default::default()
    };
    let a = Matrix::randn(cfg.rows, cfg.cols, 1);
    let fault = FaultPlan::schedule(vec![ScheduledKill::new(2, 1, 0, Phase::Update)]);
    let res = run_caqr_matrix(cfg, a, Backend::native(), fault, Trace::disabled());
    assert!(res.is_err());
    println!("  ABORT  : failure propagated, run aborted as configured. OK");
}

fn main() {
    println!("== E5: FT-MPI / ULFM semantics matrix (paper II) ==\n");
    demo_blank();
    demo_shrink();
    demo_rebuild();
    demo_abort();
    println!("\nAll four semantics behave per the paper's description.");
    let _ = Arc::strong_count(&FaultPlan::none()); // keep Arc import used
}
