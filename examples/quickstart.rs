//! Quickstart: factorize a 512x128 matrix with fault-tolerant CAQR on 4
//! simulated ranks and verify the result.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use ftcaqr::config::RunConfig;
use ftcaqr::coordinator::run_caqr_simple;

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        rows: 512,
        cols: 128,
        block: 32,
        procs: 4,
        ..Default::default() // FT algorithm, Rebuild semantics, native backend
    };
    println!("FT-CAQR quickstart: {}x{} matrix, b={}, P={}", cfg.rows, cfg.cols, cfg.block, cfg.procs);

    let out = run_caqr_simple(cfg)?;

    println!("  messages        : {}", out.report.messages);
    println!("  exchanges       : {}", out.report.exchanges);
    println!("  bytes moved     : {}", out.report.bytes);
    println!("  flops           : {}", out.report.flops);
    println!("  critical path   : {:.2} us (dual-channel model)", out.report.critical_path * 1e6);
    println!("  wallclock       : {:?}", out.elapsed);
    println!("  R is triangular : {}", out.r.is_upper_triangular(1e-6));
    let res = out.residual.expect("verification on");
    println!("  gram residual   : {res:.3e}");
    assert!(res < 1e-3);
    println!("OK: ‖AᵀA − RᵀR‖/‖AᵀA‖ = {res:.3e} — factorization verified");
    Ok(())
}
