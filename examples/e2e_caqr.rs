//! End-to-end driver (E6): the full three-layer stack on a real workload.
//!
//! Loads the AOT artifacts (JAX/Pallas kernels lowered to HLO, executed
//! through the PJRT CPU client), factorizes a 1024x512 matrix across 8
//! simulated MPI ranks with the fault-tolerant algorithm, injects two
//! failures, recovers, and verifies the result — proving L1 (Pallas
//! kernels), L2 (JAX graph) and L3 (rust coordinator) compose.
//!
//! ```text
//! make artifacts && cargo run --release --example e2e_caqr
//! ```

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::run_caqr_matrix;
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::linalg::Matrix;
use ftcaqr::runtime::Engine;
use ftcaqr::trace::Trace;

fn main() -> anyhow::Result<()> {
    let cfg = RunConfig {
        rows: 1024,
        cols: 512,
        block: 32,
        procs: 8,
        algorithm: Algorithm::FaultTolerant,
        ..Default::default()
    };
    println!("== E6: end-to-end FT-CAQR over the PJRT runtime ==");
    println!(
        "matrix {}x{}  b={}  P={}  backend=xla (AOT JAX/Pallas artifacts)\n",
        cfg.rows, cfg.cols, cfg.block, cfg.procs
    );

    let engine = Engine::start("artifacts")?;
    println!(
        "loaded manifest: {} artifacts (profile '{}', jax {})",
        engine.manifest().artifacts.len(),
        engine.manifest().profile,
        engine.manifest().jax_version
    );
    let backend = Backend::xla(engine.clone());

    let a = Matrix::randn(cfg.rows, cfg.cols, 2026);
    let fault = FaultPlan::schedule(vec![
        ScheduledKill::new(3, 2, 0, Phase::Update),
        ScheduledKill::new(6, 7, 1, Phase::Tsqr),
    ]);
    let trace = Trace::new();
    let t0 = std::time::Instant::now();
    let out = run_caqr_matrix(cfg.clone(), a, backend, fault, trace.clone())?;
    let wall = t0.elapsed();

    let (execs, compiles, exec_s, compile_s) = engine.stats().snapshot();
    println!("\nresults:");
    println!("  failures injected   : {}", out.report.failures);
    println!("  recoveries          : {}", out.report.recoveries);
    println!("  recovery fetches    : {}", trace.of_kind("recovery_fetch").len());
    println!("  exchanges           : {}", out.report.exchanges);
    println!("  bytes moved         : {:.2} MiB", out.report.bytes as f64 / (1 << 20) as f64);
    println!("  model flops         : {:.2} GF", out.backend_flops as f64 / 1e9);
    println!("  critical path       : {:.1} us (dual-channel model)", out.report.critical_path * 1e6);
    println!("  store peak          : {:.2} MiB", out.store_peak_bytes as f64 / (1 << 20) as f64);
    println!("  wallclock           : {wall:?}");
    println!("  pjrt executions     : {execs} ({exec_s:.3}s exec, {compiles} compiles {compile_s:.3}s)");
    println!("  throughput          : {:.2} GFLOP/s host", out.backend_flops as f64 / 1e9 / wall.as_secs_f64());

    let res = out.residual.expect("verify on");
    println!("  gram residual       : {res:.3e}");
    println!("  lower defect        : {:.3e}", out.lower_defect);
    assert_eq!(out.report.failures, 2);
    assert_eq!(out.report.recoveries, 2);
    assert!(res < 1e-3, "residual too large");
    println!("\nVERIFIED: all three layers compose; 2 failures recovered from");
    println!("single-buddy state; factorization correct.");
    Ok(())
}
