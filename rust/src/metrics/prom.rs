//! Prometheus text-exposition rendering of a [`Report`].
//!
//! Hand-rolled (offline build, no client library): [`render`] emits one
//! `# HELP` / `# TYPE` header pair per metric followed by a single
//! sample carrying the caller's label set, in a fixed metric order so
//! the snapshot is deterministic and diffable. `ftcaqr run
//! --metrics-out` writes one snapshot per run; `ftcaqr serve` rewrites
//! its snapshot as jobs complete (see `Service::metrics_text`).

use super::Report;

/// Render a label set as `{k="v",...}` (empty string for no labels).
/// Values are escaped per the text-exposition rules (backslash, quote,
/// newline).
pub fn fmt_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let body = labels
        .iter()
        .map(|(k, v)| {
            let v = v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n");
            format!("{k}=\"{v}\"")
        })
        .collect::<Vec<_>>()
        .join(",");
    format!("{{{body}}}")
}

/// One complete metric block: HELP, TYPE, and a single sample.
pub fn sample(name: &str, kind: &str, help: &str, labels: &str, value: &str) -> String {
    format!("# HELP {name} {help}\n# TYPE {name} {kind}\n{name}{labels} {value}\n")
}

/// Deterministic float rendering: finite values in `{:e}` form (valid
/// Prometheus floats), non-finite as `NaN`.
fn fmt_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        String::from("NaN")
    }
}

/// Render `report` as a Prometheus text-exposition snapshot with the
/// given label set on every sample (e.g. `[("job", "run")]` or a
/// per-tenant label from the service).
pub fn render(report: &Report, labels: &[(&str, &str)]) -> String {
    let l = fmt_labels(labels);
    let mut out = String::new();
    let counters: &[(&str, &str, u64)] = &[
        ("ftcaqr_messages_total", "One-way messages sent.", report.messages),
        ("ftcaqr_exchanges_total", "Pairwise exchanges (sendrecv calls).", report.exchanges),
        ("ftcaqr_bytes_total", "Payload bytes moved.", report.bytes),
        ("ftcaqr_flops_total", "Flops issued by the backend.", report.flops),
        ("ftcaqr_failures_total", "Failures injected.", report.failures),
        ("ftcaqr_detects_total", "Failure detections (revival claims).", report.detects),
        ("ftcaqr_recoveries_total", "Recovery events completed.", report.recoveries),
        ("ftcaqr_rebuilds_total", "REBUILD replacements that finished.", report.rebuilds),
        ("ftcaqr_checkpoints_total", "Checkpoint exchanges completed.", report.checkpoints),
        (
            "ftcaqr_checkpoint_bytes_total",
            "Payload bytes written by checkpoints.",
            report.checkpoint_bytes,
        ),
        (
            "ftcaqr_bcast_bytes_total",
            "Payload bytes moved by factor row-broadcast hops.",
            report.bcast_bytes,
        ),
        (
            "ftcaqr_bcast_hops_total",
            "Factor row-broadcast hops (tree-edge sends + store pulls).",
            report.bcast_hops,
        ),
        ("ftcaqr_sched_parks_total", "Scheduler task parks.", report.parks),
        ("ftcaqr_sched_stalls_total", "Tasks failed by the stall detector.", report.stalls),
    ];
    for &(name, help, v) in counters {
        out.push_str(&sample(name, "counter", help, &l, &v.to_string()));
    }
    let gauges: &[(&str, &str, f64)] = &[
        (
            "ftcaqr_critical_path_seconds",
            "Max over ranks of the final logical clock.",
            report.critical_path,
        ),
        (
            "ftcaqr_compute_path_seconds",
            "Max over ranks of the compute share of the clock.",
            report.compute_path,
        ),
        (
            "ftcaqr_comm_path_seconds",
            "Max over ranks of the communication share of the clock.",
            report.comm_path,
        ),
        (
            "ftcaqr_overhead_pct",
            "Failure-free FT-vs-plain critical-path overhead, percent.",
            report.overhead_pct,
        ),
        (
            "ftcaqr_detect_seconds_total",
            "Summed time-to-detect over all detections.",
            report.detect_s_total,
        ),
        ("ftcaqr_detect_seconds_max", "Worst single time-to-detect.", report.detect_s_max),
        (
            "ftcaqr_detect_seconds_mean",
            "Mean time-to-detect over all detections.",
            report.detect_mean_s(),
        ),
        (
            "ftcaqr_rebuild_seconds_total",
            "Summed time-to-rebuild over all rebuilds.",
            report.rebuild_s_total,
        ),
        ("ftcaqr_rebuild_seconds_max", "Worst single time-to-rebuild.", report.rebuild_s_max),
        (
            "ftcaqr_rebuild_seconds_mean",
            "Mean time-to-rebuild over all rebuilds.",
            report.rebuild_mean_s(),
        ),
        (
            "ftcaqr_store_peak_bytes",
            "Retention-store bytes high-water.",
            report.store_peak_bytes as f64,
        ),
        (
            "ftcaqr_bcast_depth",
            "Deepest planned broadcast schedule, in hops.",
            report.bcast_depth as f64,
        ),
    ];
    for &(name, help, v) in gauges {
        out.push_str(&sample(name, "gauge", help, &l, &fmt_f(v)));
    }
    // Per-phase busy time as one metric with a phase label.
    out.push_str("# HELP ftcaqr_phase_seconds_total Busy seconds per phase, summed over ranks.\n");
    out.push_str("# TYPE ftcaqr_phase_seconds_total counter\n");
    let phases: &[(&str, f64)] = &[
        ("tsqr", report.tsqr_s),
        ("bcast", report.bcast_s),
        ("update", report.update_s),
        ("checkpoint", report.checkpoint_s),
        ("recovery", report.recovery_s),
    ];
    for &(phase, v) in phases {
        let mut with_phase: Vec<(&str, &str)> = labels.to_vec();
        with_phase.push(("phase", phase));
        out.push_str(&format!(
            "ftcaqr_phase_seconds_total{} {}\n",
            fmt_labels(&with_phase),
            fmt_f(v)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_render_and_escape() {
        assert_eq!(fmt_labels(&[]), "");
        assert_eq!(fmt_labels(&[("job", "run")]), "{job=\"run\"}");
        assert_eq!(fmt_labels(&[("a", "x\"y")]), "{a=\"x\\\"y\"}");
    }

    #[test]
    fn render_contains_every_metric_family() {
        let r = Report {
            messages: 7,
            failures: 1,
            detects: 1,
            detect_s_total: 0.5,
            rebuilds: 1,
            rebuild_s_total: 0.25,
            store_peak_bytes: 1024,
            checkpoint_bytes: 2048,
            bcast_bytes: 4096,
            bcast_hops: 6,
            bcast_depth: 3,
            overhead_pct: 3.5,
            tsqr_s: 1.0,
            ..Default::default()
        };
        let text = render(&r, &[("tenant", "t0")]);
        for name in [
            "ftcaqr_messages_total",
            "ftcaqr_failures_total",
            "ftcaqr_detect_seconds_total",
            "ftcaqr_detect_seconds_mean",
            "ftcaqr_rebuild_seconds_total",
            "ftcaqr_store_peak_bytes",
            "ftcaqr_checkpoint_bytes_total",
            "ftcaqr_bcast_bytes_total",
            "ftcaqr_bcast_hops_total",
            "ftcaqr_bcast_depth",
            "ftcaqr_overhead_pct",
            "ftcaqr_phase_seconds_total",
        ] {
            assert!(text.contains(&format!("# TYPE {name}")), "missing {name}:\n{text}");
        }
        assert!(text.contains("ftcaqr_messages_total{tenant=\"t0\"} 7"));
        assert!(text.contains("ftcaqr_bcast_bytes_total{tenant=\"t0\"} 4096"));
        assert!(text.contains("ftcaqr_bcast_hops_total{tenant=\"t0\"} 6"));
        assert!(text.contains("ftcaqr_bcast_depth{tenant=\"t0\"} 3e0"));
        assert!(text.contains("{tenant=\"t0\",phase=\"tsqr\"} 1e0"));
        // Deterministic: same report renders byte-identically.
        assert_eq!(text, render(&r, &[("tenant", "t0")]));
    }
}
