//! Hand-rolled flat-record JSON output (offline build: no serde).
//!
//! One [`JsonSink`] collects flat objects and writes them as an array —
//! the machine-readable channel CI archives so perf/survival trajectories
//! are tracked across PRs. Formatting is deterministic: floats use the
//! round-tripping `{:e}` form, non-finite values become `null`, and
//! records appear exactly in insertion order, so two identical runs
//! produce byte-identical files (the campaign reproducibility contract).
//!
//! Lives in the library (rather than `benches/common`) so the `campaign`
//! subcommand and the bench binaries share one implementation.

use std::path::{Path, PathBuf};

/// One JSON field value.
pub enum JsonVal<'a> {
    /// String field.
    S(&'a str),
    /// Float field (written with enough digits to round-trip).
    F(f64),
    /// Integer field.
    I(i64),
}

/// Collects flat JSON records and writes them as an array — to the path
/// in `FTCAQR_BENCH_JSON` if set, else to `<bench>.json` under the crate
/// root (or to an explicit path via [`JsonSink::write_to`]).
pub struct JsonSink {
    records: Vec<String>,
}

impl Default for JsonSink {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonSink {
    /// An empty sink.
    pub fn new() -> Self {
        Self { records: Vec::new() }
    }

    /// Append one flat object.
    pub fn rec(&mut self, fields: &[(&str, JsonVal<'_>)]) {
        let body: Vec<String> = fields
            .iter()
            .map(|(k, v)| {
                let val = match v {
                    JsonVal::S(s) => format!("\"{}\"", escape(s)),
                    JsonVal::F(f) if f.is_finite() => format!("{f:e}"),
                    JsonVal::F(_) => "null".to_string(),
                    JsonVal::I(i) => i.to_string(),
                };
                format!("\"{}\":{}", escape(k), val)
            })
            .collect();
        self.records.push(format!("{{{}}}", body.join(",")));
    }

    /// Number of records collected so far.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when no record has been collected.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// The serialized array body (what [`JsonSink::write_to`] writes).
    pub fn body(&self) -> String {
        format!("[\n{}\n]\n", self.records.join(",\n"))
    }

    /// Write the array to an explicit path.
    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.body())
    }

    /// Write the array to the conventional bench location and report
    /// where it went: `FTCAQR_BENCH_JSON` if set, else `<bench>.json`
    /// under the crate root. Returns the path used.
    pub fn finish(self, bench: &str) -> PathBuf {
        let path = match std::env::var("FTCAQR_BENCH_JSON") {
            Ok(p) => PathBuf::from(p),
            Err(_) => {
                Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("{bench}.json"))
            }
        };
        match self.write_to(&path) {
            Ok(()) => println!(
                "\njson: {} records -> {}",
                self.records.len(),
                path.display()
            ),
            Err(e) => println!("\njson: write to {} failed: {e}", path.display()),
        }
        path
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_are_deterministic_and_escaped() {
        let mut s = JsonSink::new();
        s.rec(&[
            ("name", JsonVal::S("a\"b\\c")),
            ("x", JsonVal::F(0.5)),
            ("bad", JsonVal::F(f64::NAN)),
            ("n", JsonVal::I(-3)),
        ]);
        let body = s.body();
        assert!(body.contains("\"name\":\"a\\\"b\\\\c\""), "{body}");
        assert!(body.contains("\"x\":5e-1"), "{body}");
        assert!(body.contains("\"bad\":null"), "{body}");
        assert!(body.contains("\"n\":-3"), "{body}");
        let mut s2 = JsonSink::new();
        s2.rec(&[
            ("name", JsonVal::S("a\"b\\c")),
            ("x", JsonVal::F(0.5)),
            ("bad", JsonVal::F(f64::NAN)),
            ("n", JsonVal::I(-3)),
        ]);
        assert_eq!(body, s2.body(), "same records, same bytes");
    }

    #[test]
    fn empty_sink_is_an_empty_array() {
        let s = JsonSink::new();
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert_eq!(s.body(), "[\n\n]\n");
    }
}
