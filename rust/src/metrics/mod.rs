//! Run-wide counters and per-phase reports.
//!
//! Everything the benchmark harness prints — message counts, bytes moved,
//! flops, the dual-channel critical-path estimate and wallclock — flows
//! through one [`Metrics`] instance shared by every simulated rank.

pub mod json;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Lock-free counters, cheap enough for the per-message hot path.
#[derive(Debug, Default)]
pub struct Metrics {
    /// One-way messages sent.
    pub messages: AtomicU64,
    /// Pairwise exchanges (sendrecv) performed.
    pub exchanges: AtomicU64,
    /// Total payload bytes moved (each direction counted).
    pub bytes: AtomicU64,
    /// Flops issued (from the backend flop model).
    pub flops: AtomicU64,
    /// Recovery events completed.
    pub recoveries: AtomicU64,
    /// Failures injected.
    pub failures: AtomicU64,
    /// Final logical clock per rank (the dual-channel cost model).
    clocks: Mutex<Vec<f64>>,
    /// Per-rank (compute seconds, communication seconds) split of the
    /// logical clock — communication includes time spent *waiting* on a
    /// peer (everything that is not local compute).
    times: Mutex<Vec<(f64, f64)>>,
}

impl Metrics {
    pub fn new(ranks: usize) -> Arc<Self> {
        Arc::new(Self {
            clocks: Mutex::new(vec![0.0; ranks]),
            times: Mutex::new(vec![(0.0, 0.0); ranks]),
            ..Default::default()
        })
    }

    pub fn record_message(&self, bytes: usize) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// One `sendrecv` *call* (each member of an exchanging pair makes
    /// one); `bytes_out` is that caller's outgoing payload, so summing
    /// over both callers gives the true bytes on the wire.
    pub fn record_exchange(&self, bytes_out: usize) {
        self.exchanges.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes_out as u64, Ordering::Relaxed);
    }

    pub fn record_flops(&self, f: u64) {
        self.flops.fetch_add(f, Ordering::Relaxed);
    }

    pub fn record_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_recovery(&self) {
        self.recoveries.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish a rank's final logical clock.
    pub fn set_clock(&self, rank: usize, t: f64) {
        let mut c = self.clocks.lock().unwrap();
        if rank >= c.len() {
            c.resize(rank + 1, 0.0);
        }
        c[rank] = c[rank].max(t);
    }

    /// Publish a rank's compute/communication split of its logical clock
    /// (max-merged across incarnations, like [`Metrics::set_clock`]).
    pub fn set_rank_times(&self, rank: usize, compute_s: f64, comm_s: f64) {
        let mut t = self.times.lock().unwrap();
        if rank >= t.len() {
            t.resize(rank + 1, (0.0, 0.0));
        }
        t[rank].0 = t[rank].0.max(compute_s);
        t[rank].1 = t[rank].1.max(comm_s);
    }

    /// Critical path = max over ranks of the logical clock.
    pub fn critical_path(&self) -> f64 {
        self.clocks.lock().unwrap().iter().cloned().fold(0.0, f64::max)
    }

    pub fn snapshot(&self) -> Report {
        let (compute_path, comm_path) = {
            let t = self.times.lock().unwrap();
            (
                t.iter().map(|p| p.0).fold(0.0, f64::max),
                t.iter().map(|p| p.1).fold(0.0, f64::max),
            )
        };
        Report {
            messages: self.messages.load(Ordering::Relaxed),
            exchanges: self.exchanges.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            flops: self.flops.load(Ordering::Relaxed),
            recoveries: self.recoveries.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            critical_path: self.critical_path(),
            compute_path,
            comm_path,
        }
    }
}

/// Immutable snapshot for printing / serialization.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Report {
    /// One-way messages sent.
    pub messages: u64,
    /// Pairwise exchanges (sendrecv calls) performed.
    pub exchanges: u64,
    /// Total payload bytes moved.
    pub bytes: u64,
    /// Flops issued (from the backend flop model).
    pub flops: u64,
    /// Recovery events completed.
    pub recoveries: u64,
    /// Failures injected.
    pub failures: u64,
    /// Max over ranks of the final logical clock, seconds.
    pub critical_path: f64,
    /// Max over ranks of the *compute* share of the logical clock,
    /// seconds — with [`Report::comm_path`], the first-class readout of
    /// the paper's failure-free FT-vs-plain overhead claim (redundancy
    /// shows up as compute, not as critical-path communication).
    pub compute_path: f64,
    /// Max over ranks of the *communication* share of the logical clock
    /// (transfer time plus waiting on peers), seconds.
    pub comm_path: f64,
}

impl Report {
    /// Fold another job's counters into this one — the service's
    /// per-tenant [`Metrics`] stay isolated, and its *totals* row is the
    /// sum of every completed job's report. Counters add; the critical
    /// path of a set of concurrent jobs is the max over jobs (each job's
    /// logical clock starts at zero in its own world).
    pub fn absorb(&mut self, other: &Report) {
        self.messages += other.messages;
        self.exchanges += other.exchanges;
        self.bytes += other.bytes;
        self.flops += other.flops;
        self.recoveries += other.recoveries;
        self.failures += other.failures;
        self.critical_path = self.critical_path.max(other.critical_path);
        self.compute_path = self.compute_path.max(other.compute_path);
        self.comm_path = self.comm_path.max(other.comm_path);
    }

    /// Difference against an earlier snapshot (for per-phase accounting).
    pub fn since(&self, earlier: &Report) -> Report {
        Report {
            messages: self.messages - earlier.messages,
            exchanges: self.exchanges - earlier.exchanges,
            bytes: self.bytes - earlier.bytes,
            flops: self.flops - earlier.flops,
            recoveries: self.recoveries - earlier.recoveries,
            failures: self.failures - earlier.failures,
            critical_path: self.critical_path,
            compute_path: self.compute_path,
            comm_path: self.comm_path,
        }
    }
}

impl std::fmt::Display for Report {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "msgs={} exch={} bytes={} flops={} fail={} recov={} cp={:.6}s \
             (compute={:.6}s comm={:.6}s)",
            self.messages,
            self.exchanges,
            self.bytes,
            self.flops,
            self.failures,
            self.recoveries,
            self.critical_path,
            self.compute_path,
            self.comm_path
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new(4);
        m.record_message(100);
        m.record_message(50);
        m.record_exchange(20);
        m.record_flops(1000);
        let r = m.snapshot();
        assert_eq!(r.messages, 2);
        assert_eq!(r.exchanges, 1);
        assert_eq!(r.bytes, 170);
        assert_eq!(r.flops, 1000);
    }

    #[test]
    fn critical_path_is_max_clock() {
        let m = Metrics::new(3);
        m.set_clock(0, 1.0);
        m.set_clock(2, 5.0);
        m.set_clock(1, 3.0);
        assert_eq!(m.critical_path(), 5.0);
    }

    #[test]
    fn absorb_sums_counters_and_maxes_clock() {
        let mut total = Report::default();
        let a = Report { messages: 3, bytes: 100, flops: 10, critical_path: 2.0, ..Default::default() };
        let b = Report { messages: 2, bytes: 50, failures: 1, critical_path: 5.0, ..Default::default() };
        total.absorb(&a);
        total.absorb(&b);
        assert_eq!(total.messages, 5);
        assert_eq!(total.bytes, 150);
        assert_eq!(total.flops, 10);
        assert_eq!(total.failures, 1);
        assert_eq!(total.critical_path, 5.0);
    }

    #[test]
    fn rank_time_split_is_max_over_ranks() {
        let m = Metrics::new(2);
        m.set_rank_times(0, 1.0, 4.0);
        m.set_rank_times(1, 3.0, 2.0);
        let r = m.snapshot();
        assert_eq!(r.compute_path, 3.0);
        assert_eq!(r.comm_path, 4.0);
        // Re-publishing (a REBUILD incarnation) max-merges per rank.
        m.set_rank_times(0, 0.5, 5.0);
        let r2 = m.snapshot();
        assert_eq!(r2.compute_path, 3.0);
        assert_eq!(r2.comm_path, 5.0);
        // absorb maxes the paths like the critical path.
        let mut total = Report::default();
        total.absorb(&r2);
        total.absorb(&Report { compute_path: 9.0, ..Default::default() });
        assert_eq!(total.compute_path, 9.0);
        assert_eq!(total.comm_path, 5.0);
    }

    #[test]
    fn since_subtracts() {
        let m = Metrics::new(1);
        m.record_message(10);
        let a = m.snapshot();
        m.record_message(20);
        let d = m.snapshot().since(&a);
        assert_eq!(d.messages, 1);
        assert_eq!(d.bytes, 20);
    }
}
