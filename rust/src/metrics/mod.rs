//! Run-wide counters and per-phase reports.
//!
//! Everything the benchmark harness prints — message counts, bytes moved,
//! flops, the dual-channel critical-path estimate and wallclock — flows
//! through one [`Metrics`] instance shared by every simulated rank.
//!
//! Beyond the raw counters, [`Report`] carries the paper's headline
//! observability numbers as first-class fields: the failure-free
//! FT-vs-plain overhead %, per-failure time-to-detect / time-to-rebuild,
//! the retention-store and checkpoint bytes high-water, the scheduler's
//! park/stall accounting, and a per-phase split of busy time. See
//! [`prom`] for the Prometheus text-exposition rendering.

pub mod json;
pub mod prom;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Lock-free add for an `f64` stored as bits in an [`AtomicU64`] (the
/// per-phase busy-time accumulators sit on the stage-completion path).
fn add_f64(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

fn load_f64(cell: &AtomicU64) -> f64 {
    f64::from_bits(cell.load(Ordering::Relaxed))
}

/// Which busy-time bucket a completed stage belongs to (the per-phase
/// critical-path split in [`Report`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhasePath {
    /// Panel TSQR: leaf QR + merge tree.
    Tsqr,
    /// Row-broadcast of panel factors.
    Bcast,
    /// Trailing-matrix update segments.
    Update,
    /// Pairwise checkpoint exchanges.
    Checkpoint,
    /// Failure handling: detect, fetch, replay.
    Recovery,
}

/// Per-failure latency accounting: kill clocks are recorded when a kill
/// fires and matched (per dead rank, FIFO) when a survivor claims the
/// revival, yielding time-to-detect; time-to-rebuild is reported by the
/// replacement when it finishes replaying.
#[derive(Debug, Default)]
struct RecoveryTiming {
    /// Outstanding kill clocks, `(dead rank, kill clock)`.
    kill_at: Vec<(usize, f64)>,
    detect_total: f64,
    detect_max: f64,
    detects: u64,
    rebuild_total: f64,
    rebuild_max: f64,
    rebuilds: u64,
}

/// Lock-free counters, cheap enough for the per-message hot path.
#[derive(Debug, Default)]
pub struct Metrics {
    /// One-way messages sent.
    pub messages: AtomicU64,
    /// Pairwise exchanges (sendrecv) performed.
    pub exchanges: AtomicU64,
    /// Total payload bytes moved (each direction counted).
    pub bytes: AtomicU64,
    /// Flops issued (from the backend flop model).
    pub flops: AtomicU64,
    /// Recovery events completed.
    pub recoveries: AtomicU64,
    /// Failures injected.
    pub failures: AtomicU64,
    /// Task parks (scheduler: a poll returned Pending with no wakeup
    /// pending — each is one blocked-on-a-peer episode).
    pub parks: AtomicU64,
    /// Tasks failed by the scheduler's stall detector.
    pub stalls: AtomicU64,
    /// Checkpoint exchanges completed.
    pub checkpoints: AtomicU64,
    /// Payload bytes written by checkpoint exchanges.
    pub checkpoint_bytes: AtomicU64,
    /// Payload bytes moved by factor row-broadcasts (each hop — tree-edge
    /// send or store pull — counts its bytes once).
    pub bcast_bytes: AtomicU64,
    /// Factor row-broadcast hops: tree-edge sends plus store pulls.
    pub bcast_hops: AtomicU64,
    /// Deepest planned broadcast schedule, in hops (max-merged gauge;
    /// flat = 1, binomial = ⌈log₂ members⌉).
    pub bcast_depth: AtomicU64,
    /// Retention-store bytes high-water (max-merged gauge).
    pub store_peak_bytes: AtomicU64,
    /// Per-failure detect/rebuild latency accounting (off the hot path:
    /// touched only when a kill fires or a recovery completes).
    timing: Mutex<RecoveryTiming>,
    /// Per-phase busy seconds, summed over ranks (f64 bits).
    phase_tsqr: AtomicU64,
    phase_bcast: AtomicU64,
    phase_update: AtomicU64,
    phase_checkpoint: AtomicU64,
    phase_recovery: AtomicU64,
    /// Final logical clock per rank (the dual-channel cost model).
    clocks: Mutex<Vec<f64>>,
    /// Per-rank (compute seconds, communication seconds) split of the
    /// logical clock — communication includes time spent *waiting* on a
    /// peer (everything that is not local compute).
    times: Mutex<Vec<(f64, f64)>>,
}

impl Metrics {
    /// A fresh instance sized for `ranks` simulated processes.
    pub fn new(ranks: usize) -> Arc<Self> {
        Arc::new(Self {
            clocks: Mutex::new(vec![0.0; ranks]),
            times: Mutex::new(vec![(0.0, 0.0); ranks]),
            ..Default::default()
        })
    }

    /// One one-way message of `bytes` payload.
    pub fn record_message(&self, bytes: usize) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// One `sendrecv` *call* (each member of an exchanging pair makes
    /// one); `bytes_out` is that caller's outgoing payload, so summing
    /// over both callers gives the true bytes on the wire.
    pub fn record_exchange(&self, bytes_out: usize) {
        self.exchanges.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes_out as u64, Ordering::Relaxed);
    }

    /// Flops issued by the backend.
    pub fn record_flops(&self, f: u64) {
        self.flops.fetch_add(f, Ordering::Relaxed);
    }

    /// One injected failure (no kill-clock attribution).
    pub fn record_failure(&self) {
        self.failures.fetch_add(1, Ordering::Relaxed);
    }

    /// One injected failure of `rank` at logical time `clock`; the kill
    /// clock is held until [`Metrics::record_detect`] claims it.
    pub fn record_failure_at(&self, rank: usize, clock: f64) {
        self.failures.fetch_add(1, Ordering::Relaxed);
        self.timing.lock().unwrap().kill_at.push((rank, clock));
    }

    /// A survivor claimed the revival of `dead` at logical time `clock`:
    /// record time-to-detect against the oldest outstanding kill of that
    /// rank. Returns the detect latency (0 when the kill clock was not
    /// recorded — e.g. a failure injected without attribution).
    pub fn record_detect(&self, dead: usize, clock: f64) -> f64 {
        let mut g = self.timing.lock().unwrap();
        let latency = match g.kill_at.iter().position(|&(r, _)| r == dead) {
            Some(i) => {
                let (_, killed) = g.kill_at.remove(i);
                // Clocks are per-rank and only loosely ordered; clamp the
                // skew so a detector that is logically "behind" the victim
                // never records a negative latency.
                (clock - killed).max(0.0)
            }
            None => 0.0,
        };
        g.detect_total += latency;
        g.detect_max = g.detect_max.max(latency);
        g.detects += 1;
        latency
    }

    /// One completed recovery.
    pub fn record_recovery(&self) {
        self.recoveries.fetch_add(1, Ordering::Relaxed);
    }

    /// A REBUILD replacement finished `secs` after it was spawned:
    /// record time-to-rebuild.
    pub fn record_rebuild(&self, secs: f64) {
        let mut g = self.timing.lock().unwrap();
        g.rebuild_total += secs;
        g.rebuild_max = g.rebuild_max.max(secs);
        g.rebuilds += 1;
    }

    /// One scheduler park (task blocked waiting for a peer event).
    pub fn record_park(&self) {
        self.parks.fetch_add(1, Ordering::Relaxed);
    }

    /// One task failed by the stall detector.
    pub fn record_stall(&self) {
        self.stalls.fetch_add(1, Ordering::Relaxed);
    }

    /// One completed checkpoint exchange of `bytes` payload.
    pub fn record_checkpoint(&self, bytes: usize) {
        self.checkpoints.fetch_add(1, Ordering::Relaxed);
        self.checkpoint_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// `hops` broadcast hops moving `bytes` payload (a plain tree-edge
    /// send or an FT store pull is one hop carrying its payload once).
    pub fn record_bcast(&self, bytes: u64, hops: u64) {
        self.bcast_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.bcast_hops.fetch_add(hops, Ordering::Relaxed);
    }

    /// Max-merge the deepest planned broadcast schedule.
    pub fn set_bcast_depth(&self, depth: u64) {
        self.bcast_depth.fetch_max(depth, Ordering::Relaxed);
    }

    /// Max-merge the retention-store bytes high-water.
    pub fn set_store_peak(&self, bytes: u64) {
        self.store_peak_bytes.fetch_max(bytes, Ordering::Relaxed);
    }

    /// Add `secs` of busy time to `phase`'s bucket.
    pub fn record_phase(&self, phase: PhasePath, secs: f64) {
        let cell = match phase {
            PhasePath::Tsqr => &self.phase_tsqr,
            PhasePath::Bcast => &self.phase_bcast,
            PhasePath::Update => &self.phase_update,
            PhasePath::Checkpoint => &self.phase_checkpoint,
            PhasePath::Recovery => &self.phase_recovery,
        };
        add_f64(cell, secs);
    }

    /// Publish a rank's final logical clock.
    pub fn set_clock(&self, rank: usize, t: f64) {
        let mut c = self.clocks.lock().unwrap();
        if rank >= c.len() {
            c.resize(rank + 1, 0.0);
        }
        c[rank] = c[rank].max(t);
    }

    /// Publish a rank's compute/communication split of its logical clock
    /// (max-merged across incarnations, like [`Metrics::set_clock`]).
    pub fn set_rank_times(&self, rank: usize, compute_s: f64, comm_s: f64) {
        let mut t = self.times.lock().unwrap();
        if rank >= t.len() {
            t.resize(rank + 1, (0.0, 0.0));
        }
        t[rank].0 = t[rank].0.max(compute_s);
        t[rank].1 = t[rank].1.max(comm_s);
    }

    /// Critical path = max over ranks of the logical clock.
    pub fn critical_path(&self) -> f64 {
        self.clocks.lock().unwrap().iter().cloned().fold(0.0, f64::max)
    }

    /// Immutable snapshot of every counter and derived metric.
    pub fn snapshot(&self) -> Report {
        let (compute_path, comm_path) = {
            let t = self.times.lock().unwrap();
            (
                t.iter().map(|p| p.0).fold(0.0, f64::max),
                t.iter().map(|p| p.1).fold(0.0, f64::max),
            )
        };
        let timing = self.timing.lock().unwrap();
        Report {
            messages: self.messages.load(Ordering::Relaxed),
            exchanges: self.exchanges.load(Ordering::Relaxed),
            bytes: self.bytes.load(Ordering::Relaxed),
            flops: self.flops.load(Ordering::Relaxed),
            recoveries: self.recoveries.load(Ordering::Relaxed),
            failures: self.failures.load(Ordering::Relaxed),
            parks: self.parks.load(Ordering::Relaxed),
            stalls: self.stalls.load(Ordering::Relaxed),
            checkpoints: self.checkpoints.load(Ordering::Relaxed),
            checkpoint_bytes: self.checkpoint_bytes.load(Ordering::Relaxed),
            bcast_bytes: self.bcast_bytes.load(Ordering::Relaxed),
            bcast_hops: self.bcast_hops.load(Ordering::Relaxed),
            bcast_depth: self.bcast_depth.load(Ordering::Relaxed),
            store_peak_bytes: self.store_peak_bytes.load(Ordering::Relaxed),
            detects: timing.detects,
            detect_s_total: timing.detect_total,
            detect_s_max: timing.detect_max,
            rebuilds: timing.rebuilds,
            rebuild_s_total: timing.rebuild_total,
            rebuild_s_max: timing.rebuild_max,
            tsqr_s: load_f64(&self.phase_tsqr),
            bcast_s: load_f64(&self.phase_bcast),
            update_s: load_f64(&self.phase_update),
            checkpoint_s: load_f64(&self.phase_checkpoint),
            recovery_s: load_f64(&self.phase_recovery),
            overhead_pct: 0.0,
            critical_path: self.critical_path(),
            compute_path,
            comm_path,
        }
    }
}

/// Immutable snapshot for printing / serialization.
///
/// Field algebra (see [`Report::absorb`] / [`Report::since`]):
/// *counters* (message/byte/flop/failure counts, the detect/rebuild
/// totals and counts, per-phase seconds) add in `absorb` and subtract in
/// `since`; *gauges* (`critical_path` and friends, the `*_max` latency
/// fields, `store_peak_bytes`) max-merge in `absorb` and are copied from
/// `self` in `since`; `overhead_pct` is last-set-wins.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Report {
    /// One-way messages sent.
    pub messages: u64,
    /// Pairwise exchanges (sendrecv calls) performed.
    pub exchanges: u64,
    /// Total payload bytes moved.
    pub bytes: u64,
    /// Flops issued (from the backend flop model).
    pub flops: u64,
    /// Recovery events completed.
    pub recoveries: u64,
    /// Failures injected.
    pub failures: u64,
    /// Scheduler task parks (blocked-on-a-peer episodes).
    pub parks: u64,
    /// Tasks failed by the scheduler's stall detector.
    pub stalls: u64,
    /// Checkpoint exchanges completed.
    pub checkpoints: u64,
    /// Payload bytes written by checkpoint exchanges.
    pub checkpoint_bytes: u64,
    /// Payload bytes moved by factor row-broadcast hops.
    pub bcast_bytes: u64,
    /// Factor row-broadcast hops (tree-edge sends + store pulls).
    pub bcast_hops: u64,
    /// Deepest planned broadcast schedule, in hops (gauge).
    pub bcast_depth: u64,
    /// Retention-store bytes high-water (gauge).
    pub store_peak_bytes: u64,
    /// Failure detections (revival claims) recorded.
    pub detects: u64,
    /// Summed time-to-detect over all detections, seconds.
    pub detect_s_total: f64,
    /// Worst single time-to-detect, seconds (gauge).
    pub detect_s_max: f64,
    /// REBUILD replacements that finished replaying.
    pub rebuilds: u64,
    /// Summed time-to-rebuild over all rebuilds, seconds.
    pub rebuild_s_total: f64,
    /// Worst single time-to-rebuild, seconds (gauge).
    pub rebuild_s_max: f64,
    /// Busy seconds in panel TSQR, summed over ranks.
    pub tsqr_s: f64,
    /// Busy seconds in factor row-broadcast, summed over ranks.
    pub bcast_s: f64,
    /// Busy seconds in trailing-update segments, summed over ranks.
    pub update_s: f64,
    /// Busy seconds in checkpoint exchanges, summed over ranks.
    pub checkpoint_s: f64,
    /// Busy seconds in failure handling, summed over ranks.
    pub recovery_s: f64,
    /// Failure-free FT-vs-plain critical-path overhead, percent — set by
    /// contexts that measured a plain baseline (benches, campaign cells);
    /// 0 when no baseline exists (gauge, last-set-wins).
    pub overhead_pct: f64,
    /// Max over ranks of the final logical clock, seconds.
    pub critical_path: f64,
    /// Max over ranks of the *compute* share of the logical clock,
    /// seconds — with [`Report::comm_path`], the first-class readout of
    /// the paper's failure-free FT-vs-plain overhead claim (redundancy
    /// shows up as compute, not as critical-path communication).
    pub compute_path: f64,
    /// Max over ranks of the *communication* share of the logical clock
    /// (transfer time plus waiting on peers), seconds.
    pub comm_path: f64,
}

impl Report {
    /// Fold another job's counters into this one — the service's
    /// per-tenant [`Metrics`] stay isolated, and its *totals* row is the
    /// sum of every completed job's report. Counters add; the critical
    /// path of a set of concurrent jobs is the max over jobs (each job's
    /// logical clock starts at zero in its own world), as are the other
    /// gauges; `overhead_pct` is last-set-wins.
    pub fn absorb(&mut self, other: &Report) {
        self.messages += other.messages;
        self.exchanges += other.exchanges;
        self.bytes += other.bytes;
        self.flops += other.flops;
        self.recoveries += other.recoveries;
        self.failures += other.failures;
        self.parks += other.parks;
        self.stalls += other.stalls;
        self.checkpoints += other.checkpoints;
        self.checkpoint_bytes += other.checkpoint_bytes;
        self.bcast_bytes += other.bcast_bytes;
        self.bcast_hops += other.bcast_hops;
        self.bcast_depth = self.bcast_depth.max(other.bcast_depth);
        self.store_peak_bytes = self.store_peak_bytes.max(other.store_peak_bytes);
        self.detects += other.detects;
        self.detect_s_total += other.detect_s_total;
        self.detect_s_max = self.detect_s_max.max(other.detect_s_max);
        self.rebuilds += other.rebuilds;
        self.rebuild_s_total += other.rebuild_s_total;
        self.rebuild_s_max = self.rebuild_s_max.max(other.rebuild_s_max);
        self.tsqr_s += other.tsqr_s;
        self.bcast_s += other.bcast_s;
        self.update_s += other.update_s;
        self.checkpoint_s += other.checkpoint_s;
        self.recovery_s += other.recovery_s;
        if other.overhead_pct != 0.0 {
            self.overhead_pct = other.overhead_pct;
        }
        self.critical_path = self.critical_path.max(other.critical_path);
        self.compute_path = self.compute_path.max(other.compute_path);
        self.comm_path = self.comm_path.max(other.comm_path);
    }

    /// Difference against an earlier snapshot (for per-phase
    /// accounting): counters subtract, gauges are copied from `self`.
    pub fn since(&self, earlier: &Report) -> Report {
        Report {
            messages: self.messages - earlier.messages,
            exchanges: self.exchanges - earlier.exchanges,
            bytes: self.bytes - earlier.bytes,
            flops: self.flops - earlier.flops,
            recoveries: self.recoveries - earlier.recoveries,
            failures: self.failures - earlier.failures,
            parks: self.parks - earlier.parks,
            stalls: self.stalls - earlier.stalls,
            checkpoints: self.checkpoints - earlier.checkpoints,
            checkpoint_bytes: self.checkpoint_bytes - earlier.checkpoint_bytes,
            bcast_bytes: self.bcast_bytes - earlier.bcast_bytes,
            bcast_hops: self.bcast_hops - earlier.bcast_hops,
            bcast_depth: self.bcast_depth,
            store_peak_bytes: self.store_peak_bytes,
            detects: self.detects - earlier.detects,
            detect_s_total: self.detect_s_total - earlier.detect_s_total,
            detect_s_max: self.detect_s_max,
            rebuilds: self.rebuilds - earlier.rebuilds,
            rebuild_s_total: self.rebuild_s_total - earlier.rebuild_s_total,
            rebuild_s_max: self.rebuild_s_max,
            tsqr_s: self.tsqr_s - earlier.tsqr_s,
            bcast_s: self.bcast_s - earlier.bcast_s,
            update_s: self.update_s - earlier.update_s,
            checkpoint_s: self.checkpoint_s - earlier.checkpoint_s,
            recovery_s: self.recovery_s - earlier.recovery_s,
            overhead_pct: self.overhead_pct,
            critical_path: self.critical_path,
            compute_path: self.compute_path,
            comm_path: self.comm_path,
        }
    }

    /// Mean time-to-detect over the recorded failures, seconds (0 when
    /// none were detected).
    pub fn detect_mean_s(&self) -> f64 {
        if self.detects == 0 {
            0.0
        } else {
            self.detect_s_total / self.detects as f64
        }
    }

    /// Mean time-to-rebuild over the completed rebuilds, seconds (0 when
    /// none completed).
    pub fn rebuild_mean_s(&self) -> f64 {
        if self.rebuilds == 0 {
            0.0
        } else {
            self.rebuild_s_total / self.rebuilds as f64
        }
    }
}

impl std::fmt::Display for Report {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "msgs={} exch={} bytes={} flops={} fail={} recov={} cp={:.6}s \
             (compute={:.6}s comm={:.6}s)",
            self.messages,
            self.exchanges,
            self.bytes,
            self.flops,
            self.failures,
            self.recoveries,
            self.critical_path,
            self.compute_path,
            self.comm_path
        )?;
        if self.detects > 0 || self.rebuilds > 0 {
            write!(
                f,
                " detect={:.6}s/{} rebuild={:.6}s/{}",
                self.detect_mean_s(),
                self.detects,
                self.rebuild_mean_s(),
                self.rebuilds
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new(4);
        m.record_message(100);
        m.record_message(50);
        m.record_exchange(20);
        m.record_flops(1000);
        let r = m.snapshot();
        assert_eq!(r.messages, 2);
        assert_eq!(r.exchanges, 1);
        assert_eq!(r.bytes, 170);
        assert_eq!(r.flops, 1000);
    }

    #[test]
    fn critical_path_is_max_clock() {
        let m = Metrics::new(3);
        m.set_clock(0, 1.0);
        m.set_clock(2, 5.0);
        m.set_clock(1, 3.0);
        assert_eq!(m.critical_path(), 5.0);
    }

    #[test]
    fn absorb_sums_counters_and_maxes_clock() {
        let mut total = Report::default();
        let a = Report { messages: 3, bytes: 100, flops: 10, critical_path: 2.0, ..Default::default() };
        let b = Report { messages: 2, bytes: 50, failures: 1, critical_path: 5.0, ..Default::default() };
        total.absorb(&a);
        total.absorb(&b);
        assert_eq!(total.messages, 5);
        assert_eq!(total.bytes, 150);
        assert_eq!(total.flops, 10);
        assert_eq!(total.failures, 1);
        assert_eq!(total.critical_path, 5.0);
    }

    #[test]
    fn rank_time_split_is_max_over_ranks() {
        let m = Metrics::new(2);
        m.set_rank_times(0, 1.0, 4.0);
        m.set_rank_times(1, 3.0, 2.0);
        let r = m.snapshot();
        assert_eq!(r.compute_path, 3.0);
        assert_eq!(r.comm_path, 4.0);
        // Re-publishing (a REBUILD incarnation) max-merges per rank.
        m.set_rank_times(0, 0.5, 5.0);
        let r2 = m.snapshot();
        assert_eq!(r2.compute_path, 3.0);
        assert_eq!(r2.comm_path, 5.0);
        // absorb maxes the paths like the critical path.
        let mut total = Report::default();
        total.absorb(&r2);
        total.absorb(&Report { compute_path: 9.0, ..Default::default() });
        assert_eq!(total.compute_path, 9.0);
        assert_eq!(total.comm_path, 5.0);
    }

    #[test]
    fn since_subtracts() {
        let m = Metrics::new(1);
        m.record_message(10);
        let a = m.snapshot();
        m.record_message(20);
        let d = m.snapshot().since(&a);
        assert_eq!(d.messages, 1);
        assert_eq!(d.bytes, 20);
    }

    #[test]
    fn detect_and_rebuild_latencies() {
        let m = Metrics::new(4);
        m.record_failure_at(2, 1.0);
        m.record_failure_at(3, 2.0);
        assert_eq!(m.record_detect(2, 1.5), 0.5);
        // Skew clamp: a detector logically behind the victim reads 0.
        assert_eq!(m.record_detect(3, 1.0), 0.0);
        m.record_rebuild(0.25);
        m.record_rebuild(0.75);
        let r = m.snapshot();
        assert_eq!(r.failures, 2);
        assert_eq!(r.detects, 2);
        assert_eq!(r.detect_s_total, 0.5);
        assert_eq!(r.detect_s_max, 0.5);
        assert_eq!(r.detect_mean_s(), 0.25);
        assert_eq!(r.rebuilds, 2);
        assert_eq!(r.rebuild_s_total, 1.0);
        assert_eq!(r.rebuild_s_max, 0.75);
        assert_eq!(r.rebuild_mean_s(), 0.5);
    }

    #[test]
    fn phase_checkpoint_store_and_sched_counters() {
        let m = Metrics::new(2);
        m.record_phase(PhasePath::Tsqr, 1.0);
        m.record_phase(PhasePath::Tsqr, 0.5);
        m.record_phase(PhasePath::Recovery, 2.0);
        m.record_checkpoint(100);
        m.record_checkpoint(50);
        m.set_store_peak(400);
        m.set_store_peak(300); // max-merge: stays 400
        m.record_park();
        m.record_park();
        m.record_stall();
        let r = m.snapshot();
        assert_eq!(r.tsqr_s, 1.5);
        assert_eq!(r.recovery_s, 2.0);
        assert_eq!(r.update_s, 0.0);
        assert_eq!(r.checkpoints, 2);
        assert_eq!(r.checkpoint_bytes, 150);
        assert_eq!(r.store_peak_bytes, 400);
        assert_eq!(r.parks, 2);
        assert_eq!(r.stalls, 1);
    }

    #[test]
    fn bcast_counters_add_and_depth_maxes() {
        let m = Metrics::new(2);
        m.record_bcast(1000, 1);
        m.record_bcast(500, 2);
        m.set_bcast_depth(3);
        m.set_bcast_depth(1); // max-merge: stays 3
        let r = m.snapshot();
        assert_eq!(r.bcast_bytes, 1500);
        assert_eq!(r.bcast_hops, 3);
        assert_eq!(r.bcast_depth, 3);
        // Counters add in absorb, the depth gauge maxes.
        let mut total = Report::default();
        total.absorb(&r);
        let extra =
            Report { bcast_bytes: 100, bcast_hops: 1, bcast_depth: 2, ..Default::default() };
        total.absorb(&extra);
        assert_eq!(total.bcast_bytes, 1600);
        assert_eq!(total.bcast_hops, 4);
        assert_eq!(total.bcast_depth, 3);
        // Counters subtract in since; the depth gauge is copied.
        m.record_bcast(200, 1);
        let d = m.snapshot().since(&r);
        assert_eq!(d.bcast_bytes, 200);
        assert_eq!(d.bcast_hops, 1);
        assert_eq!(d.bcast_depth, 3);
    }
}
