//! Message types flowing between simulated ranks.
//!
//! The payload stays deliberately generic (`Matrix` bundles); the
//! coordinator layers its own conventions (which matrix is C', which is
//! Y, ...) on top via [`Tag`]s, exactly as MPI codes do with tags.
//!
//! Matrix payloads are [`Arc`]-shared: a message clone (router delivery,
//! exchange retransmit buffers, checkpoint fan-out) bumps a refcount
//! instead of deep-copying the buffer. The cost model still charges the
//! full matrix size — [`MsgData::nbytes`] reads through the `Arc` — so
//! simulated traffic accounting is unchanged by the sharing.
//!
//! [`MsgData::Mats`] bundles are how the service's batched TSQR lane
//! amortizes tree traffic: one exchange per step carries the
//! intermediate R of every job packed into the batch, so k same-shape
//! jobs pay one message-count budget (bytes still scale with k).

use std::sync::Arc;

use crate::linalg::Matrix;

/// Message kind — the coordinator's protocol vocabulary.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TagKind {
    /// TSQR reduction: intermediate R factor.
    TsqrR,
    /// Trailing-update tree: C' rows (Algorithm 1) or C'+Y (Algorithm 2).
    UpdateC,
    /// Trailing-update tree: the W factor sent back (Algorithm 1 only).
    UpdateW,
    /// Recovery: request for buddy-held state.
    RecoveryReq,
    /// Recovery: the {W, T, C', Y} payload (paper III-C).
    RecoveryData,
    /// Leader -> worker block distribution.
    Scatter,
    /// Worker -> leader result collection.
    Gather,
    /// Checkpointing traffic (diskless-checkpoint baseline).
    Checkpoint,
    /// Row-broadcast of a panel's WY factor bundle across a process-grid
    /// row (plain mode; FT mode publishes the bundle via the store).
    BcastFactors,
    /// Anything else (tests).
    Misc(u16),
}

/// Full message tag: kind + panel + tree step + lane. Matching is exact,
/// so concurrent panels/steps can never cross-talk — the lookahead
/// pipeline relies on this to keep several in-flight panels' exchanges
/// (and, within a panel, several column-segment update lanes) routed
/// independently on one rank pair.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Tag {
    /// Protocol message kind.
    pub kind: TagKind,
    /// CAQR panel index the message belongs to.
    pub panel: u32,
    /// Tree step the message belongs to.
    pub step: u32,
    /// Sub-phase lane: 0 for whole-width traffic (plain lockstep mode),
    /// the global column-block index for a pipelined update segment.
    pub lane: u32,
    /// Process-grid column the traffic belongs to: the grid column a
    /// column-reduction (TSQR / update tree / checkpoint pair) runs in,
    /// or the panel's grid column for a row-broadcast. Always 0 on `Px1`
    /// grids, so the 1-D path is unchanged. Part of the exact match key:
    /// same-(panel, step, lane) reductions in different grid columns can
    /// never cross-talk.
    pub gcol: u32,
}

impl Tag {
    /// Tag on the default lane 0 (whole-width traffic), grid column 0.
    pub fn new(kind: TagKind, panel: usize, step: usize) -> Self {
        Self::with_lane(kind, panel, step, 0)
    }

    /// Tag on an explicit lane (a pipelined update segment's traffic),
    /// grid column 0.
    pub fn with_lane(kind: TagKind, panel: usize, step: usize, lane: u32) -> Self {
        Self::grid(kind, panel, step, lane, 0)
    }

    /// Fully-qualified tag: lane plus process-grid column.
    pub fn grid(kind: TagKind, panel: usize, step: usize, lane: u32, gcol: u32) -> Self {
        Self { kind, panel: panel as u32, step: step as u32, lane, gcol }
    }

    /// Tag with no panel/step context.
    pub fn plain(kind: TagKind) -> Self {
        Self::new(kind, 0, 0)
    }

    /// Routing context for payload-mismatch panics: every coordinate a
    /// multi-panel grid failure needs to be attributable from the error
    /// alone.
    fn context(&self) -> String {
        format!(
            "{:?} panel {} step {} lane {} grid col {}",
            self.kind, self.panel, self.step, self.lane, self.gcol
        )
    }
}

/// Message payload: zero or more shared matrices (+ an optional small
/// control word). Sizes are accounted from the matrix buffers.
#[derive(Clone, Debug)]
pub enum MsgData {
    /// A single (shared) matrix payload.
    Mat(Arc<Matrix>),
    /// A bundle of (shared) matrices.
    Mats(Vec<Arc<Matrix>>),
    /// A small control word.
    Ctrl(u64),
}

impl MsgData {
    /// Wrap an owned matrix as a single-payload message.
    pub fn mat(m: Matrix) -> Self {
        MsgData::Mat(Arc::new(m))
    }

    /// Payload size for the cost model (full matrix bytes, regardless of
    /// how many `Arc` holders share the buffer).
    pub fn nbytes(&self) -> usize {
        match self {
            MsgData::Mat(m) => m.nbytes(),
            MsgData::Mats(v) => v.iter().map(|m| m.nbytes()).sum(),
            MsgData::Ctrl(_) => 8,
        }
    }

    /// Tag/shape summary for unwrap panics, so a protocol bug reports
    /// *what* arrived instead of a bare enum variant.
    fn describe(&self) -> String {
        match self {
            MsgData::Mat(m) => format!("Mat({}x{})", m.rows(), m.cols()),
            MsgData::Mats(v) => {
                let shapes: Vec<String> =
                    v.iter().map(|m| format!("{}x{}", m.rows(), m.cols())).collect();
                format!("Mats[{}] of shapes [{}]", v.len(), shapes.join(", "))
            }
            MsgData::Ctrl(c) => format!("Ctrl({c})"),
        }
    }

    /// Unwrap a single shared matrix (zero-copy).
    pub fn into_mat(self) -> Arc<Matrix> {
        match self {
            MsgData::Mat(m) => m,
            MsgData::Mats(mut v) if v.len() == 1 => v.pop().expect("len checked"),
            other => panic!(
                "expected Mat payload (a single matrix), got {}",
                other.describe()
            ),
        }
    }

    /// [`MsgData::into_mat`] with routing context: the panic names the
    /// tag's panel/step/lane/grid-column alongside the payload shapes.
    pub fn into_mat_for(self, tag: &Tag) -> Arc<Matrix> {
        match self {
            MsgData::Mat(m) => m,
            MsgData::Mats(mut v) if v.len() == 1 => v.pop().expect("len checked"),
            other => panic!(
                "expected Mat payload (a single matrix) for {}, got {}",
                tag.context(),
                other.describe()
            ),
        }
    }

    /// [`MsgData::into_mats`] with routing context (see
    /// [`MsgData::into_mat_for`]).
    pub fn into_mats_for(self, tag: &Tag) -> Vec<Arc<Matrix>> {
        match self {
            MsgData::Mat(m) => vec![m],
            MsgData::Mats(v) => v,
            other => panic!(
                "expected Mats payload (a bundle) for {}, got {}",
                tag.context(),
                other.describe()
            ),
        }
    }

    /// [`MsgData::into_ctrl`] with routing context (see
    /// [`MsgData::into_mat_for`]).
    pub fn into_ctrl_for(self, tag: &Tag) -> u64 {
        match self {
            MsgData::Ctrl(c) => c,
            other => panic!(
                "expected Ctrl payload for {}, got {}",
                tag.context(),
                other.describe()
            ),
        }
    }

    /// Unwrap a single matrix with ownership: free when the receiver
    /// holds the last reference (sender moved it), one copy otherwise.
    pub fn into_mat_owned(self) -> Matrix {
        match Arc::try_unwrap(self.into_mat()) {
            Ok(m) => m,
            Err(shared) => shared.as_ref().clone(),
        }
    }

    /// Unwrap a matrix bundle.
    pub fn into_mats(self) -> Vec<Arc<Matrix>> {
        match self {
            MsgData::Mat(m) => vec![m],
            MsgData::Mats(v) => v,
            other => panic!("expected Mats payload (a bundle), got {}", other.describe()),
        }
    }

    /// Unwrap a control word.
    pub fn into_ctrl(self) -> u64 {
        match self {
            MsgData::Ctrl(c) => c,
            other => panic!("expected Ctrl payload, got {}", other.describe()),
        }
    }
}

/// A routed message.
#[derive(Clone, Debug)]
pub struct Envelope {
    /// Sending rank.
    pub src: usize,
    /// Full message tag (kind + panel + step).
    pub tag: Tag,
    /// The payload.
    pub data: MsgData,
    /// Sender's logical clock at send time (cost model input).
    pub send_ts: f64,
    /// Payload bytes.
    pub bytes: usize,
    /// True when this is half of a `sendrecv` exchange (dual-channel
    /// overlap applies — paper III-C's critical-path argument).
    pub exchange: bool,
}

/// Mailbox events: messages, plus failure-detector notices.
#[derive(Clone, Debug)]
pub enum Event {
    /// A routed message.
    Msg(Envelope),
    /// Rank `0` died (ULFM failure detector).
    Death(usize),
    /// Rank `0` was rebuilt and rejoined.
    Revive(usize),
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_equality_is_exact() {
        let a = Tag::new(TagKind::TsqrR, 1, 2);
        let b = Tag::new(TagKind::TsqrR, 1, 3);
        assert_ne!(a, b);
        assert_eq!(a, Tag::new(TagKind::TsqrR, 1, 2));
        // Lanes are part of the match key: two update segments of the
        // same (panel, step) never cross-talk.
        let l1 = Tag::with_lane(TagKind::UpdateC, 1, 0, 2);
        let l2 = Tag::with_lane(TagKind::UpdateC, 1, 0, 3);
        assert_ne!(l1, l2);
        assert_eq!(Tag::new(TagKind::UpdateC, 1, 0).lane, 0);
    }

    #[test]
    fn msgdata_sizes() {
        let m = Matrix::zeros(4, 4);
        assert_eq!(MsgData::mat(m.clone()).nbytes(), 64);
        let shared = Arc::new(m);
        assert_eq!(MsgData::Mats(vec![shared.clone(), shared]).nbytes(), 128);
        assert_eq!(MsgData::Ctrl(9).nbytes(), 8);
    }

    #[test]
    fn msgdata_unwrap() {
        let m = Matrix::eye(2);
        assert_eq!(*MsgData::mat(m.clone()).into_mat(), m);
        assert_eq!(MsgData::Mats(vec![Arc::new(m.clone())]).into_mat_owned(), m);
        assert_eq!(MsgData::Ctrl(5).into_ctrl(), 5);
    }

    #[test]
    fn msgdata_owned_unwrap_is_move_when_unique() {
        let m = Matrix::randn(3, 3, 1);
        let owned = MsgData::mat(m.clone()).into_mat_owned();
        assert_eq!(owned, m);
        // Shared payloads fall back to one copy.
        let arc = Arc::new(m.clone());
        let keep = arc.clone();
        assert_eq!(MsgData::Mat(arc).into_mat_owned(), m);
        assert_eq!(*keep, m);
    }

    #[test]
    #[should_panic(expected = "expected Mat")]
    fn msgdata_wrong_unwrap_panics() {
        MsgData::Ctrl(1).into_mat();
    }

    #[test]
    #[should_panic(expected = "Mats[2] of shapes [2x2, 4x4]")]
    fn msgdata_bundle_unwrap_reports_shapes() {
        let v = vec![Arc::new(Matrix::eye(2)), Arc::new(Matrix::eye(4))];
        MsgData::Mats(v).into_mat();
    }

    #[test]
    fn grid_column_is_part_of_the_match_key() {
        let a = Tag::grid(TagKind::UpdateC, 1, 0, 2, 0);
        let b = Tag::grid(TagKind::UpdateC, 1, 0, 2, 1);
        assert_ne!(a, b, "same reduction in two grid columns must not cross-talk");
        assert_eq!(Tag::with_lane(TagKind::UpdateC, 1, 0, 2), a);
        assert_eq!(Tag::new(TagKind::TsqrR, 1, 2).gcol, 0);
    }

    #[test]
    #[should_panic(expected = "panel 3 step 1 lane 7 grid col 2")]
    fn msgdata_mismatch_panic_names_lane_and_grid() {
        let tag = Tag::grid(TagKind::UpdateC, 3, 1, 7, 2);
        MsgData::Ctrl(1).into_mat_for(&tag);
    }

    #[test]
    #[should_panic(expected = "grid col 1")]
    fn msgdata_ctrl_mismatch_panic_names_grid() {
        let tag = Tag::grid(TagKind::Checkpoint, 0, 0, 0, 1);
        MsgData::mat(Matrix::eye(2)).into_ctrl_for(&tag);
    }
}
