//! Simulated message-passing world: the MPI + ULFM substrate.
//!
//! Each rank holds a [`RankCtx`]; ranks exchange typed, tagged messages
//! through a shared [`Router`]. Failure injection kills a rank and
//! broadcasts a death notice; any operation that involves the dead rank
//! afterwards returns [`Fail::RankFailed`] — exactly ULFM's "errors
//! surface only at operations touching the failed process" (paper §II).
//! `REBUILD` re-creates the rank's mailbox and a new task continues from
//! recovered state (paper III-C).
//!
//! Two execution engines drive rank bodies (see `DESIGN.md` "Scheduler:
//! parking and wakeup"):
//!
//! * [`World::run_all`] — one OS thread per rank with *blocking*
//!   [`RankCtx::recv`] / [`RankCtx::sendrecv`]. Simple, used by small
//!   unit tests and demos; caps out at a few dozen ranks.
//! * [`World::run_tasks`] — the production engine: a bounded worker pool
//!   ([`sched`]) drives resumable [`sched::RankTask`]s that *park* on the
//!   non-blocking [`RankCtx::try_recv`] / [`RankCtx::begin_exchange`] +
//!   [`RankCtx::poll_exchange`] primitives and are woken by message
//!   delivery. P = 512–1024 ranks run comfortably on a laptop core count.
//!
//! The pool itself is a first-class, persistent object ([`sched::Pool`]):
//! `run_tasks` spins up an ephemeral one, while the multi-tenant service
//! ([`crate::service`]) keeps a single long-lived pool and submits many
//! concurrent jobs (each a `World` + task group) into it.
//!
//! Per-rank logical clocks implement the dual-channel cost model of
//! [`clock::CostModel`], which is what the overhead experiments (E2)
//! report as "critical path".

pub mod clock;
pub mod message;
pub mod sched;

pub use clock::{parse_straggler, CostModel, Stragglers};
pub use message::{Envelope, Event, MsgData, Tag, TagKind};
pub use sched::{default_workers, JobId, JobResults, Pool, RankTask, Spawner, TaskPoll};

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, RwLock};

use crate::fault::{FailSite, FaultPlan};
use crate::ft::Fail;
use crate::metrics::Metrics;

struct RankSlot {
    tx: Option<Sender<Event>>,
    alive: bool,
    incarnation: u32,
}

/// Callback invoked with a rank id whenever an event lands in that rank's
/// mailbox — the pooled scheduler registers one to unpark the rank's task.
pub type Waker = Arc<dyn Fn(usize) + Send + Sync>;

/// Shared routing fabric: senders + liveness for every rank.
pub struct Router {
    slots: RwLock<Vec<RankSlot>>,
    /// Scheduler wakeup hook (None under the thread-per-rank engine,
    /// where blocking `recv` needs no external wakeups).
    waker: RwLock<Option<Waker>>,
}

impl Router {
    fn new(n: usize) -> (Arc<Self>, Vec<Receiver<Event>>) {
        let mut slots = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            slots.push(RankSlot { tx: Some(tx), alive: true, incarnation: 0 });
            rxs.push(rx);
        }
        (Arc::new(Self { slots: RwLock::new(slots), waker: RwLock::new(None) }), rxs)
    }

    /// Install the scheduler's wakeup hook (see [`sched`]).
    pub(crate) fn set_waker(&self, w: Option<Waker>) {
        *self.waker.write().unwrap() = w;
    }

    fn wake(&self, rank: usize) {
        if let Some(w) = self.waker.read().unwrap().as_ref() {
            w(rank);
        }
    }

    fn wake_all(&self, n: usize) {
        if let Some(w) = self.waker.read().unwrap().as_ref() {
            for r in 0..n {
                w(r);
            }
        }
    }

    /// Poke a rank's task (scheduler wakeup) without delivering an event
    /// — used by the coordinator when buddy-store contents change.
    pub(crate) fn notify(&self, rank: usize) {
        self.wake(rank);
    }

    /// Is `rank` currently alive?
    pub fn is_alive(&self, rank: usize) -> bool {
        self.slots.read().unwrap().get(rank).map(|s| s.alive).unwrap_or(false)
    }

    /// Number of currently-alive ranks.
    pub fn alive_count(&self) -> usize {
        self.slots.read().unwrap().iter().filter(|s| s.alive).count()
    }

    /// Current incarnation of `rank` (0 until its first REBUILD).
    pub fn incarnation(&self, rank: usize) -> u32 {
        self.slots.read().unwrap()[rank].incarnation
    }

    /// Deliver an event; `false` if the destination is dead/closed.
    fn deliver(&self, dst: usize, ev: Event) -> bool {
        let delivered = {
            let slots = self.slots.read().unwrap();
            match slots.get(dst).and_then(|s| s.tx.as_ref()) {
                Some(tx) if slots[dst].alive => tx.send(ev).is_ok(),
                _ => false,
            }
        };
        if delivered {
            self.wake(dst);
        }
        delivered
    }

    /// Kill a rank: drop its mailbox sender and notify everyone else.
    pub fn kill(&self, rank: usize) {
        let n = {
            let mut slots = self.slots.write().unwrap();
            if !slots[rank].alive {
                return;
            }
            slots[rank].alive = false;
            slots[rank].tx = None;
            for (i, s) in slots.iter().enumerate() {
                if i != rank && s.alive {
                    if let Some(tx) = &s.tx {
                        let _ = tx.send(Event::Death(rank));
                    }
                }
            }
            slots.len()
        };
        // Death notices may unblock tasks parked on the dead rank.
        self.wake_all(n);
    }

    /// REBUILD: new mailbox + incarnation for `rank`, notify survivors.
    fn revive(&self, rank: usize) -> Receiver<Event> {
        let (rx, n) = {
            let mut slots = self.slots.write().unwrap();
            let (tx, rx) = channel();
            slots[rank].tx = Some(tx);
            slots[rank].alive = true;
            slots[rank].incarnation += 1;
            for (i, s) in slots.iter().enumerate() {
                if i != rank && s.alive {
                    if let Some(tx) = &s.tx {
                        let _ = tx.send(Event::Revive(rank));
                    }
                }
            }
            (rx, slots.len())
        };
        // Revive notices let parked detectors retry their exchange.
        self.wake_all(n);
        rx
    }
}

/// Per-rank mailbox with selective receive and failure-notice tracking.
struct Mailbox {
    rx: Receiver<Event>,
    buf: HashMap<(usize, Tag), VecDeque<Envelope>>,
    dead: HashSet<usize>,
    /// Revive notices seen per rank. `sendrecv` watches this: a peer
    /// revival means the peer's old mailbox (and any half-exchange we
    /// pushed into it) is gone, so our payload must be retransmitted.
    revives: HashMap<usize, u64>,
}

impl Mailbox {
    fn new(rx: Receiver<Event>) -> Self {
        Self { rx, buf: HashMap::new(), dead: HashSet::new(), revives: HashMap::new() }
    }

    fn revive_count(&self, rank: usize) -> u64 {
        self.revives.get(&rank).copied().unwrap_or(0)
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::Msg(env) => {
                self.buf.entry((env.src, env.tag)).or_default().push_back(env)
            }
            Event::Death(r) => {
                self.dead.insert(r);
            }
            Event::Revive(r) => {
                self.dead.remove(&r);
                *self.revives.entry(r).or_insert(0) += 1;
            }
        }
    }

    /// Pull everything already delivered into the match buffer.
    /// Returns false if the world shut down (channel closed).
    fn drain(&mut self) -> bool {
        loop {
            match self.rx.try_recv() {
                Ok(ev) => self.handle(ev),
                Err(TryRecvError::Empty) => return true,
                Err(TryRecvError::Disconnected) => return false,
            }
        }
    }

    fn take(&mut self, src: usize, tag: Tag) -> Option<Envelope> {
        self.buf.get_mut(&(src, tag)).and_then(VecDeque::pop_front)
    }
}

/// Everything a rank's task needs: identity, mailbox, clock, metrics,
/// fault injector. Dropping the ctx publishes the final logical clock.
pub struct RankCtx {
    /// This rank's id in `[0, world.n)`.
    pub rank: usize,
    /// Logical time (seconds) under the dual-channel cost model.
    pub clock: f64,
    /// Cost-model parameters shared by the whole world.
    pub cost: CostModel,
    /// Run-wide counters.
    pub metrics: Arc<Metrics>,
    /// Failure injector consulted at [`RankCtx::maybe_fail`] sites.
    pub fault: Arc<FaultPlan>,
    /// Incarnation this context was created for; a correlated (group)
    /// kill can invalidate it while the task still runs — see
    /// [`RankCtx::check_self`].
    inc: u32,
    /// Compute share of `clock` accumulated by this incarnation.
    compute_s: f64,
    /// Communication share of `clock` (transfers + waiting on peers).
    comm_s: f64,
    /// Straggler compute multiplier (1.0 for healthy ranks). Applied to
    /// every compute charge; survives REBUILD (slowness is a property of
    /// the physical slot, not the incarnation).
    slow: f64,
    router: Arc<Router>,
    mailbox: Mailbox,
}

impl Drop for RankCtx {
    fn drop(&mut self) {
        self.metrics.set_clock(self.rank, self.clock);
        self.metrics.set_rank_times(self.rank, self.compute_s, self.comm_s);
    }
}

impl RankCtx {
    /// Advance the clock for a local computation and account flops. A
    /// straggler rank's charge is multiplied by its slowdown factor.
    pub fn compute(&mut self, flops: u64) {
        let dt = self.slow * self.cost.compute_time(flops);
        self.clock += dt;
        self.compute_s += dt;
        self.metrics.record_flops(flops);
    }

    /// This rank's straggler compute multiplier (1.0 when healthy).
    pub fn slow_factor(&self) -> f64 {
        self.slow
    }

    /// Advance the clock by a communication delta (charged as comm time).
    fn advance_comm_to(&mut self, t: f64) {
        self.comm_s += t - self.clock;
        self.clock = t;
    }

    /// Charge a local retained-state read as one simulated message (the
    /// recovery fetch of paper III-C): the receive-time formula applied
    /// against our own clock, accounted as communication.
    pub fn charge_local_recv(&mut self, bytes: usize) {
        let t = self.cost.recv_time(self.clock, self.clock, bytes);
        self.advance_comm_to(t);
        self.metrics.record_message(bytes);
    }

    /// Fault-injection site: dies (and unwinds the task) when scheduled.
    /// A kill belonging to a correlated group (a simulated node crash)
    /// takes the other group members down at the same instant.
    pub fn maybe_fail(&mut self, site: FailSite) -> Result<(), Fail> {
        let inc = self.router.incarnation(self.rank);
        if self.fault.should_fail_inc(self.rank, inc, site) {
            self.metrics.record_failure_at(self.rank, self.clock);
            self.router.kill(self.rank);
            for other in self.fault.collateral_of(self.rank, site) {
                if other != self.rank && self.router.is_alive(other) {
                    self.metrics.record_failure_at(other, self.clock);
                    self.router.kill(other);
                }
            }
            return Err(Fail::Killed);
        }
        Ok(())
    }

    /// The incarnation this context was created for.
    pub fn incarnation(&self) -> u32 {
        self.inc
    }

    /// `Err(Killed)` when this context's incarnation is no longer the
    /// live one (the rank was killed out from under the task by a
    /// correlated kill, or superseded by a REBUILD).
    pub fn check_self(&self) -> Result<(), Fail> {
        if !self.router.is_alive(self.rank) || self.router.incarnation(self.rank) != self.inc {
            return Err(Fail::Killed);
        }
        Ok(())
    }

    /// Is `rank` currently alive?
    pub fn is_alive(&self, rank: usize) -> bool {
        self.router.is_alive(rank)
    }

    /// The routing fabric (liveness queries, failure injection hooks).
    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    fn push(&mut self, dst: usize, tag: Tag, data: MsgData, exchange: bool) -> Result<usize, Fail> {
        let bytes = data.nbytes();
        let env =
            Envelope { src: self.rank, tag, data, send_ts: self.clock, bytes, exchange };
        if !self.router.deliver(dst, Event::Msg(env)) {
            return Err(Fail::RankFailed { rank: dst });
        }
        Ok(bytes)
    }

    /// One-way send (Algorithm 1 style). Never blocks (the fabric is an
    /// unbounded channel); the *receiver* pays the wire time via the cost
    /// model.
    pub fn send(&mut self, dst: usize, tag: Tag, data: MsgData) -> Result<(), Fail> {
        let bytes = self.push(dst, tag, data, false)?;
        let t = self.clock + self.cost.o;
        self.advance_comm_to(t);
        self.metrics.record_message(bytes);
        Ok(())
    }

    /// One-way send that charges the *sender* full serialization time
    /// (`o + B*beta`), modelling a tree relay pushing the payload back
    /// out of its own NIC (see [`CostModel::relay_send_time`]). The
    /// envelope's `send_ts` is the pre-serialization clock, so the
    /// receiver's wire time overlaps the sender's charge rather than
    /// stacking on top of it.
    pub fn send_serialized(&mut self, dst: usize, tag: Tag, data: MsgData) -> Result<(), Fail> {
        let bytes = self.push(dst, tag, data, false)?;
        let t = self.cost.relay_send_time(self.clock, bytes);
        self.advance_comm_to(t);
        self.metrics.record_message(bytes);
        Ok(())
    }

    /// Charge a pull of a published broadcast bundle: the `ord`-th
    /// scheduled reader of a bundle published at `publish_ts`, split
    /// into `nseg` pipelined segments (see
    /// [`CostModel::bcast_pull_time`]). Accounted as one message.
    pub fn charge_bcast_pull(
        &mut self,
        publish_ts: f64,
        ord: usize,
        bytes: usize,
        nseg: usize,
    ) {
        let t = self.cost.bcast_pull_time(self.clock, publish_ts, ord, bytes, nseg);
        self.advance_comm_to(t);
        self.metrics.record_message(bytes);
    }

    /// Selective receive: blocks until a message with `(src, tag)` is
    /// available, or `src` is known dead (ULFM detection).
    pub fn recv(&mut self, src: usize, tag: Tag) -> Result<MsgData, Fail> {
        loop {
            let open = self.mailbox.drain();
            if let Some(env) = self.mailbox.take(src, tag) {
                let t = self.cost.recv_time(self.clock, env.send_ts, env.bytes);
                self.advance_comm_to(t);
                return Ok(env.data);
            }
            if !open {
                return Err(Fail::WorldGone);
            }
            if self.mailbox.dead.contains(&src) || !self.router.is_alive(src) {
                return Err(Fail::RankFailed { rank: src });
            }
            match self.mailbox.rx.recv() {
                Ok(ev) => self.mailbox.handle(ev),
                Err(_) => return Err(Fail::WorldGone),
            }
        }
    }

    /// Paired exchange (Algorithm 2's `sendrecv`): send our payload and
    /// receive the peer's; both transfers overlap on dual-channel links.
    pub fn sendrecv(&mut self, peer: usize, tag: Tag, data: MsgData) -> Result<MsgData, Fail> {
        let retrans = data.clone();
        crate::simlog!(
            "[r{}] push {tag:?} -> {peer} (inc {})",
            self.rank,
            self.router.incarnation(peer)
        );
        let bytes_out = self.push(peer, tag, data, true)?;
        self.metrics.record_exchange(bytes_out);
        // If the peer is REBUILT while we wait, its old mailbox — holding
        // the half-exchange we just pushed — is discarded; retransmit to
        // the new incarnation (the real-MPI analogue: the sender's NIC
        // retries once the replacement process re-registers).
        let mut seen_revives = self.mailbox.revive_count(peer);
        loop {
            let open = self.mailbox.drain();
            // Retransmission must be checked BEFORE consuming the peer's
            // half: when Death + Revive + the rebuilt peer's message all
            // arrive in one batch, returning early would complete OUR
            // exchange while the rebuilt peer starves waiting for the
            // half we pushed into its discarded pre-death mailbox.
            let now = self.mailbox.revive_count(peer);
            if now > seen_revives {
                seen_revives = now;
                // Best-effort: the peer may have died again already.
                let ok = self.push(peer, tag, retrans.clone(), true).is_ok();
                crate::simlog!("[r{}] RETRANSMIT to {peer} {tag:?} ok={ok}", self.rank);
            }
            if let Some(env) = self.mailbox.take(peer, tag) {
                let t =
                    self.cost.exchange_time(self.clock, env.send_ts, bytes_out, env.bytes);
                self.advance_comm_to(t);
                return Ok(env.data);
            }
            if !open {
                return Err(Fail::WorldGone);
            }
            if self.mailbox.dead.contains(&peer) || !self.router.is_alive(peer) {
                return Err(Fail::RankFailed { rank: peer });
            }
            match self.mailbox.rx.recv() {
                Ok(ev) => self.mailbox.handle(ev),
                Err(_) => return Err(Fail::WorldGone),
            }
        }
    }

    // ---- non-blocking primitives (pooled scheduler) --------------------

    /// True when a message from `src` with `tag` is already deliverable
    /// (drains delivered events first; does not consume the message).
    pub fn has_pending(&mut self, src: usize, tag: Tag) -> bool {
        let _ = self.mailbox.drain();
        self.mailbox
            .buf
            .get(&(src, tag))
            .is_some_and(|q| !q.is_empty())
    }

    /// Non-blocking selective receive for pooled tasks: `Ok(None)` means
    /// "nothing yet — park and re-poll on the next wakeup". Semantics
    /// otherwise match [`RankCtx::recv`] (messages already on the wire
    /// are delivered before death is reported).
    pub fn try_recv(&mut self, src: usize, tag: Tag) -> Result<Option<MsgData>, Fail> {
        self.check_self()?;
        let open = self.mailbox.drain();
        if let Some(env) = self.mailbox.take(src, tag) {
            let t = self.cost.recv_time(self.clock, env.send_ts, env.bytes);
            self.advance_comm_to(t);
            return Ok(Some(env.data));
        }
        if !open {
            return Err(Fail::WorldGone);
        }
        if self.mailbox.dead.contains(&src) || !self.router.is_alive(src) {
            return Err(Fail::RankFailed { rank: src });
        }
        Ok(None)
    }

    /// Start a paired exchange (Algorithm 2's `sendrecv`) without
    /// blocking: pushes our half to the peer and returns a resumable
    /// [`ExchangeOp`] to be driven by [`RankCtx::poll_exchange`].
    pub fn begin_exchange(
        &mut self,
        peer: usize,
        tag: Tag,
        data: MsgData,
    ) -> Result<ExchangeOp, Fail> {
        self.check_self()?;
        let payload = data.clone();
        let seen_revives = self.mailbox.revive_count(peer);
        crate::simlog!(
            "[r{}] push {tag:?} -> {peer} (inc {})",
            self.rank,
            self.router.incarnation(peer)
        );
        let bytes_out = self.push(peer, tag, data, true)?;
        self.metrics.record_exchange(bytes_out);
        Ok(ExchangeOp { peer, tag, payload, bytes_out, seen_revives })
    }

    /// Drive an in-flight exchange. `Ok(None)` = park; `Ok(Some(d))` =
    /// the peer's half arrived; `Err(RankFailed)` = the peer died
    /// (ULFM detection — the caller decides whether to REBUILD + retry
    /// with a fresh [`RankCtx::begin_exchange`]). Handles the
    /// retransmit-on-revive protocol exactly like blocking `sendrecv`.
    pub fn poll_exchange(&mut self, op: &mut ExchangeOp) -> Result<Option<MsgData>, Fail> {
        self.check_self()?;
        let open = self.mailbox.drain();
        // Retransmission must be checked BEFORE consuming the peer's
        // half (same reasoning as the blocking path: a Death + Revive +
        // rebuilt-peer message batch must not starve the replacement).
        let now = self.mailbox.revive_count(op.peer);
        if now > op.seen_revives {
            op.seen_revives = now;
            let ok = self.push(op.peer, op.tag, op.payload.clone(), true).is_ok();
            crate::simlog!("[r{}] RETRANSMIT to {} {:?} ok={ok}", self.rank, op.peer, op.tag);
        }
        if let Some(env) = self.mailbox.take(op.peer, op.tag) {
            let t =
                self.cost.exchange_time(self.clock, env.send_ts, op.bytes_out, env.bytes);
            self.advance_comm_to(t);
            return Ok(Some(env.data));
        }
        if !open {
            return Err(Fail::WorldGone);
        }
        if self.mailbox.dead.contains(&op.peer) || !self.router.is_alive(op.peer) {
            return Err(Fail::RankFailed { rank: op.peer });
        }
        Ok(None)
    }
}

/// State of one in-flight pairwise exchange under the pooled scheduler:
/// created by [`RankCtx::begin_exchange`], resumed by
/// [`RankCtx::poll_exchange`] each time the owning task is woken.
pub struct ExchangeOp {
    peer: usize,
    tag: Tag,
    payload: MsgData,
    bytes_out: usize,
    seen_revives: u64,
}

impl ExchangeOp {
    /// The peer rank this exchange is paired with.
    pub fn peer(&self) -> usize {
        self.peer
    }
}

/// The simulated machine: `n` ranks, a router, shared metrics + faults.
pub struct World {
    /// Number of simulated ranks.
    pub n: usize,
    /// Cost-model parameters shared by every rank.
    pub cost: CostModel,
    /// Run-wide counters.
    pub metrics: Arc<Metrics>,
    /// Failure injector shared by every rank.
    pub fault: Arc<FaultPlan>,
    /// Per-rank compute slowdown plan (straggler injection).
    stragglers: Stragglers,
    router: Arc<Router>,
    mailboxes: Mutex<Vec<Option<Receiver<Event>>>>,
}

impl World {
    pub fn new(n: usize, cost: CostModel, fault: Arc<FaultPlan>) -> Arc<Self> {
        Self::new_with_stragglers(n, cost, fault, Stragglers::none())
    }

    /// A world with straggler injection: slowed ranks multiply every
    /// local compute charge by their factor, across all incarnations.
    pub fn new_with_stragglers(
        n: usize,
        cost: CostModel,
        fault: Arc<FaultPlan>,
        stragglers: Stragglers,
    ) -> Arc<Self> {
        let (router, rxs) = Router::new(n);
        Arc::new(Self {
            n,
            cost,
            metrics: Metrics::new(n),
            fault,
            stragglers,
            router,
            mailboxes: Mutex::new(rxs.into_iter().map(Some).collect()),
        })
    }

    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// Take rank `rank`'s context (panics if taken twice without revive).
    pub fn ctx(&self, rank: usize) -> RankCtx {
        let rx = self.mailboxes.lock().unwrap()[rank]
            .take()
            .unwrap_or_else(|| panic!("rank {rank} ctx already taken"));
        RankCtx {
            rank,
            clock: 0.0,
            cost: self.cost,
            metrics: self.metrics.clone(),
            fault: self.fault.clone(),
            inc: self.router.incarnation(rank),
            compute_s: 0.0,
            comm_s: 0.0,
            slow: self.stragglers.factor_for(rank),
            router: self.router.clone(),
            mailbox: Mailbox::new(rx),
        }
    }

    /// REBUILD a dead rank: fresh mailbox/incarnation, clock preset to
    /// the recovery start time (usually the detector's clock). The preset
    /// offset is charged as *communication* time (failure detection +
    /// respawn is wait, not compute), so the replacement's published
    /// compute/comm split still decomposes its final logical clock.
    pub fn revive(&self, rank: usize, clock0: f64) -> RankCtx {
        let rx = self.router.revive(rank);
        RankCtx {
            rank,
            clock: clock0,
            cost: self.cost,
            metrics: self.metrics.clone(),
            fault: self.fault.clone(),
            inc: self.router.incarnation(rank),
            compute_s: 0.0,
            comm_s: clock0,
            slow: self.stragglers.factor_for(rank),
            router: self.router.clone(),
            mailbox: Mailbox::new(rx),
        }
    }

    /// Spawn every rank on its own OS thread with the same blocking body;
    /// join all. This is the small-world test harness — production
    /// drivers use [`World::run_tasks`], which scales to P >= 512 on a
    /// bounded pool.
    pub fn run_all<T, F>(self: &Arc<Self>, f: F) -> Vec<Result<T, Fail>>
    where
        T: Send + 'static,
        F: Fn(RankCtx) -> Result<T, Fail> + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<_> = (0..self.n)
            .map(|r| {
                let f = f.clone();
                let ctx = self.ctx(r);
                std::thread::Builder::new()
                    .name(format!("rank-{r}"))
                    .spawn(move || f(ctx))
                    .expect("spawn rank thread")
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("rank thread panicked"))
            .collect()
    }

    /// Drive resumable rank tasks on an ephemeral bounded worker pool
    /// (the engine behind the large-P sweeps and the one-shot CAQR
    /// driver). `tasks` pairs each initial task with its rank; further
    /// tasks (REBUILD replacements) can be added mid-run through the
    /// [`Spawner`] passed to every `poll`. Returns one `(rank, result)`
    /// per task ever run, in spawn order. A global stall (every live
    /// task parked with nothing in flight) is reported as
    /// [`Fail::Stalled`] instead of hanging. To share one pool across
    /// many concurrent worlds, use [`Pool::submit`] instead.
    pub fn run_tasks(
        self: &Arc<Self>,
        workers: usize,
        tasks: Vec<(usize, Box<dyn RankTask>)>,
    ) -> Vec<(usize, Result<(), Fail>)> {
        sched::run_pool(self, workers, tasks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn tag() -> Tag {
        Tag::plain(TagKind::Misc(1))
    }

    #[test]
    fn send_recv_roundtrip() {
        let w = World::new(2, CostModel::default(), FaultPlan::none());
        let res = w.run_all(|mut ctx| {
            if ctx.rank == 0 {
                ctx.send(1, tag(), MsgData::mat(Matrix::eye(4)))?;
                Ok(0usize)
            } else {
                let m = ctx.recv(0, tag())?.into_mat_owned();
                assert_eq!(m, Matrix::eye(4));
                Ok(1usize)
            }
        });
        assert!(res.iter().all(|r| r.is_ok()));
        let rep = w.metrics.snapshot();
        assert_eq!(rep.messages, 1);
        assert_eq!(rep.bytes, 64);
        assert!(rep.critical_path > 0.0);
    }

    #[test]
    fn sendrecv_exchanges_both_ways() {
        let w = World::new(2, CostModel::default(), FaultPlan::none());
        let res = w.run_all(|mut ctx| {
            let me = ctx.rank;
            let peer = 1 - me;
            let mine = Matrix::randn(4, 4, me as u64);
            let got = ctx.sendrecv(peer, tag(), MsgData::mat(mine))?.into_mat_owned();
            assert_eq!(got, Matrix::randn(4, 4, peer as u64));
            Ok(ctx.clock)
        });
        let clocks: Vec<f64> = res.into_iter().map(|r| r.unwrap()).collect();
        // Both ends of an exchange finish at the same logical time.
        assert!((clocks[0] - clocks[1]).abs() < 1e-12);
        assert_eq!(w.metrics.snapshot().exchanges, 2);
    }

    #[test]
    fn selective_receive_out_of_order() {
        let w = World::new(2, CostModel::default(), FaultPlan::none());
        let t1 = Tag::plain(TagKind::Misc(1));
        let t2 = Tag::plain(TagKind::Misc(2));
        let res = w.run_all(move |mut ctx| {
            if ctx.rank == 0 {
                ctx.send(1, t1, MsgData::Ctrl(1))?;
                ctx.send(1, t2, MsgData::Ctrl(2))?;
            } else {
                // receive in the opposite order
                assert_eq!(ctx.recv(0, t2)?.into_ctrl(), 2);
                assert_eq!(ctx.recv(0, t1)?.into_ctrl(), 1);
            }
            Ok(())
        });
        assert!(res.iter().all(|r| r.is_ok()));
    }

    #[test]
    fn recv_from_dead_rank_errors() {
        use crate::fault::{FailSite, FaultPlan, Phase};
        let fault = FaultPlan::kill_at(0, 0, 0, Phase::Update);
        let w = World::new(2, CostModel::default(), fault);
        let res = w.run_all(|mut ctx| {
            if ctx.rank == 0 {
                ctx.maybe_fail(FailSite { panel: 0, step: 0, phase: Phase::Update })?;
                unreachable!("rank 0 must die");
            } else {
                match ctx.recv(0, tag()) {
                    Err(Fail::RankFailed { rank: 0 }) => Ok(()),
                    other => panic!("expected RankFailed, got {other:?}"),
                }
            }
        });
        assert_eq!(res[0], Err(Fail::Killed));
        assert!(res[1].is_ok());
        assert_eq!(w.metrics.snapshot().failures, 1);
    }

    #[test]
    fn message_sent_before_death_is_still_deliverable() {
        // ULFM semantics: operations not involving the failure proceed;
        // a message already on the wire is delivered.
        let w = World::new(2, CostModel::default(), FaultPlan::none());
        let r = w.router().clone();
        let mut c0 = w.ctx(0);
        let mut c1 = w.ctx(1);
        c0.send(1, tag(), MsgData::Ctrl(7)).unwrap();
        r.kill(0);
        assert_eq!(c1.recv(0, tag()).unwrap().into_ctrl(), 7);
        // second recv now fails
        assert!(matches!(c1.recv(0, tag()), Err(Fail::RankFailed { rank: 0 })));
    }

    #[test]
    fn revive_restores_communication() {
        let w = World::new(2, CostModel::default(), FaultPlan::none());
        let mut c1 = w.ctx(1);
        {
            let _c0 = w.ctx(0);
            w.router().kill(0);
        }
        assert!(matches!(c1.recv(0, tag()), Err(Fail::RankFailed { rank: 0 })));
        let mut c0b = w.revive(0, 1.5);
        assert_eq!(w.router().incarnation(0), 1);
        c0b.send(1, tag(), MsgData::Ctrl(9)).unwrap();
        assert_eq!(c1.recv(0, tag()).unwrap().into_ctrl(), 9);
        assert!(c0b.clock >= 1.5);
    }

    #[test]
    fn compute_advances_clock_and_flops() {
        let w = World::new(1, CostModel::default(), FaultPlan::none());
        let mut c = w.ctx(0);
        c.compute(5_000_000);
        assert!(c.clock > 0.0);
        drop(c);
        let rep = w.metrics.snapshot();
        assert_eq!(rep.flops, 5_000_000);
        assert!(rep.critical_path > 0.0);
    }
}
