//! The dual-channel communication cost model (logical clocks).
//!
//! The paper's critical-path claim (§III-C) is that replacing Algorithm
//! 1's two one-way transfers with Algorithm 2's `sendrecv` exchange does
//! not lengthen the critical path *on dual-channel hardware*, because the
//! two transfers of an exchange overlap. We model that with per-rank
//! logical clocks in seconds and a LogP-flavoured cost model:
//!
//! * one-way message `i -> j`, `B` bytes:
//!     `t_j' = max(t_j + o, t_i_send + alpha + B * beta)`
//! * exchange (both directions overlap, dual channel):
//!     both ends finish at
//!     `max(t_i, t_j) + alpha + max(B_ij, B_ji) * beta`
//! * local compute of `F` flops: `t += F / flops_per_sec`.
//!
//! Experiment E2 sweeps these parameters (incl. a single-channel variant
//! where the exchange costs the *sum*, showing where the paper's claim
//! stops holding).

use anyhow::{ensure, Context, Result};

/// Communication/computation cost parameters.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Per-message latency, seconds.
    pub alpha: f64,
    /// Per-byte transfer time, seconds (1/bandwidth).
    pub beta: f64,
    /// CPU send/recv overhead, seconds.
    pub o: f64,
    /// Local compute rate, flops/second.
    pub flops_per_sec: f64,
    /// Dual-channel links: an exchange's two transfers overlap (max);
    /// single-channel: they serialize (sum). Paper assumes dual.
    pub dual_channel: bool,
}

impl Default for CostModel {
    fn default() -> Self {
        // Roughly a commodity cluster: 1 us latency, 10 GB/s links,
        // 0.2 us CPU overhead, 50 GF/s per-core compute.
        Self {
            alpha: 1e-6,
            beta: 1e-10,
            o: 2e-7,
            flops_per_sec: 5e10,
            dual_channel: true,
        }
    }
}

impl CostModel {
    /// Single-channel variant (exchange = sum of transfers).
    pub fn single_channel() -> Self {
        Self { dual_channel: false, ..Self::default() }
    }

    /// Receiver-side clock update for a one-way message.
    pub fn recv_time(&self, t_local: f64, send_ts: f64, bytes: usize) -> f64 {
        (t_local + self.o).max(send_ts + self.alpha + bytes as f64 * self.beta)
    }

    /// Completion time of an exchange for either end.
    pub fn exchange_time(
        &self,
        t_local: f64,
        peer_send_ts: f64,
        bytes_out: usize,
        bytes_in: usize,
    ) -> f64 {
        let start = t_local.max(peer_send_ts);
        let wire = if self.dual_channel {
            bytes_out.max(bytes_in) as f64 * self.beta
        } else {
            (bytes_out + bytes_in) as f64 * self.beta
        };
        start + self.alpha + wire + self.o
    }

    /// Sender-side clock update for a *relay* send: unlike the root of a
    /// flat broadcast (which pays only `o` per posted send, modelling an
    /// eager RDMA put), a tree relay must serialize the payload back out
    /// of its own NIC before forwarding, so each forwarded copy costs
    /// `o + B*beta` of sender time. This is what makes a flat root the
    /// bottleneck at large `Pc` and a binomial tree `O(log Pc)` deep.
    pub fn relay_send_time(&self, t_local: f64, bytes: usize) -> f64 {
        t_local + self.o + bytes as f64 * self.beta
    }

    /// Receiver-side completion time of a *pull* from a published
    /// broadcast bundle (the FT path, where receivers read the bundle
    /// out of the publisher's retained memory). The publisher's NIC
    /// serializes its readers: the `ord`-th reader (0-based, in schedule
    /// order) waits behind `ord` earlier full copies. With `nseg > 1`
    /// the copy is segmented and pipelined: the wire term becomes
    /// `(nseg + ord) * (B/nseg) * beta`, so later readers wait one
    /// *segment* per predecessor instead of one full copy — at `ord = 0`
    /// segmentation changes nothing (`(nseg)*(B/nseg) = B`).
    pub fn bcast_pull_time(
        &self,
        t_local: f64,
        publish_ts: f64,
        ord: usize,
        bytes: usize,
        nseg: usize,
    ) -> f64 {
        let nseg = nseg.max(1) as f64;
        let seg = bytes as f64 / nseg;
        (t_local + self.o).max(publish_ts + self.alpha + (nseg + ord as f64) * seg * self.beta)
    }

    /// Compute-time for `flops` floating point operations.
    pub fn compute_time(&self, flops: u64) -> f64 {
        flops as f64 / self.flops_per_sec
    }
}

/// Per-rank compute slowdown plan — straggler injection.
///
/// A straggler is *slow, not dead*: every local compute charge on its
/// logical clock is multiplied by a factor `>= 1`, while the rank keeps
/// participating in every exchange (which therefore drags its partners'
/// clocks with it). This is deliberately distinct from a kill: no
/// detection, no REBUILD — the recovery protocol never sees it, only the
/// critical path does. Communication charges are *not* scaled: exchange
/// completion is a joint function of both endpoints' clocks, and the
/// slow rank's late arrival already shows up through `max(t_i, t_j)`.
#[derive(Clone, Debug, Default)]
pub struct Stragglers {
    slow: Vec<(usize, f64)>,
}

impl Stragglers {
    /// No stragglers: every rank computes at factor 1.
    pub fn none() -> Self {
        Self::default()
    }

    /// Build from `(rank, factor)` entries; on duplicates the last wins.
    pub fn new(slow: Vec<(usize, f64)>) -> Self {
        Self { slow }
    }

    /// The compute multiplier for `rank` (1.0 when not a straggler).
    pub fn factor_for(&self, rank: usize) -> f64 {
        self.slow.iter().rev().find(|(r, _)| *r == rank).map_or(1.0, |(_, f)| *f)
    }

    /// True when no rank is slowed.
    pub fn is_empty(&self) -> bool {
        self.slow.is_empty()
    }
}

/// Parse a `rank:factor` straggler spec — e.g. `3:10` makes rank 3's
/// compute charges 10x slower. The factor must be finite and `>= 1`.
pub fn parse_straggler(spec: &str) -> Result<(usize, f64)> {
    let (rank, factor) = spec
        .split_once(':')
        .with_context(|| format!("straggler spec '{spec}' must be rank:factor"))?;
    let rank: usize =
        rank.parse().with_context(|| format!("straggler spec '{spec}': bad rank"))?;
    let factor: f64 =
        factor.parse().with_context(|| format!("straggler spec '{spec}': bad factor"))?;
    ensure!(
        factor.is_finite() && factor >= 1.0,
        "straggler spec '{spec}': factor must be finite and >= 1"
    );
    Ok((rank, factor))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_way_waits_for_sender() {
        let c = CostModel::default();
        // Receiver far behind the sender: bounded by sender + wire.
        let t = c.recv_time(0.0, 1.0, 1000);
        assert!(t >= 1.0 + c.alpha);
        // Receiver ahead: bounded by its own clock + overhead.
        let t2 = c.recv_time(5.0, 1.0, 1000);
        assert!((t2 - (5.0 + c.o)).abs() < 1e-12);
    }

    #[test]
    fn dual_channel_exchange_overlaps() {
        let dual = CostModel::default();
        let single = CostModel::single_channel();
        let b = 1_000_000;
        let td = dual.exchange_time(0.0, 0.0, b, b);
        let ts = single.exchange_time(0.0, 0.0, b, b);
        // Same-size payloads: single-channel exchange pays twice the wire.
        let wire = b as f64 * dual.beta;
        assert!((ts - td - wire).abs() < 1e-12, "td={td} ts={ts}");
    }

    #[test]
    fn exchange_equals_one_way_wire_on_dual() {
        // The paper's claim: exchange(B, B) costs the same wire time as a
        // single one-way B-byte transfer (plus constant overheads).
        let c = CostModel::default();
        let b = 1 << 20;
        let ex = c.exchange_time(0.0, 0.0, b, b);
        let one = c.recv_time(0.0, 0.0, b);
        assert!((ex - one - c.o).abs() < 1e-9);
    }

    #[test]
    fn relay_send_charges_serialization() {
        let c = CostModel::default();
        let b = 1 << 20;
        let t = c.relay_send_time(2.0, b);
        assert!((t - (2.0 + c.o + b as f64 * c.beta)).abs() < 1e-15);
        // A zero-byte relay still pays the per-send CPU overhead.
        assert!((c.relay_send_time(0.0, 0) - c.o).abs() < 1e-15);
    }

    #[test]
    fn bcast_pull_serializes_readers() {
        let c = CostModel::default();
        let b = 1 << 20;
        // Reader ord pays (ord + 1) full copies behind the publisher.
        let t0 = c.bcast_pull_time(0.0, 1.0, 0, b, 1);
        let t1 = c.bcast_pull_time(0.0, 1.0, 1, b, 1);
        let copy = b as f64 * c.beta;
        assert!((t0 - (1.0 + c.alpha + copy)).abs() < 1e-12);
        assert!((t1 - t0 - copy).abs() < 1e-12, "each later reader waits one more copy");
        // Receiver far ahead: bounded by its own clock + overhead.
        let t = c.bcast_pull_time(5.0, 1.0, 0, b, 1);
        assert!((t - (5.0 + c.o)).abs() < 1e-12);
    }

    #[test]
    fn bcast_pull_segments_pipeline() {
        let c = CostModel::default();
        let b = 1 << 20;
        // ord = 0: segmentation is free (nseg * B/nseg = B).
        let whole = c.bcast_pull_time(0.0, 1.0, 0, b, 1);
        let segged = c.bcast_pull_time(0.0, 1.0, 0, b, 8);
        assert!((whole - segged).abs() < 1e-12);
        // ord >= 1: a later reader waits one *segment* per predecessor
        // instead of one full copy — strictly cheaper.
        let whole1 = c.bcast_pull_time(0.0, 1.0, 3, b, 1);
        let segged1 = c.bcast_pull_time(0.0, 1.0, 3, b, 8);
        assert!(segged1 < whole1, "segged1={segged1} whole1={whole1}");
        let seg = b as f64 / 8.0 * c.beta;
        assert!((segged1 - segged - 3.0 * seg).abs() < 1e-12);
        // nseg = 0 is clamped to 1 rather than dividing by zero.
        assert!((c.bcast_pull_time(0.0, 1.0, 0, b, 0) - whole).abs() < 1e-12);
    }

    #[test]
    fn compute_time_linear() {
        let c = CostModel::default();
        assert_eq!(c.compute_time(0), 0.0);
        assert!((c.compute_time(100) - 2.0 * c.compute_time(50)).abs() < 1e-18);
    }

    #[test]
    fn straggler_factors_default_to_one() {
        let s = Stragglers::none();
        assert!(s.is_empty());
        assert_eq!(s.factor_for(0), 1.0);
        let s = Stragglers::new(vec![(1, 4.0), (1, 10.0)]);
        assert_eq!(s.factor_for(0), 1.0);
        assert_eq!(s.factor_for(1), 10.0, "last duplicate wins");
    }

    #[test]
    fn straggler_spec_parses() {
        assert_eq!(parse_straggler("3:10").unwrap(), (3, 10.0));
        assert_eq!(parse_straggler("0:1.5").unwrap(), (0, 1.5));
        assert!(parse_straggler("3").is_err());
        assert!(parse_straggler("x:2").is_err());
        assert!(parse_straggler("3:0.5").is_err(), "speed-ups are not stragglers");
        assert!(parse_straggler("3:inf").is_err());
    }
}
