//! Bounded worker-pool scheduler: the engine that lets one process
//! simulate P >= 512 ranks — and, since the service refactor, many
//! concurrent *jobs* (whole simulated worlds) on one persistent pool.
//!
//! The thread-per-rank engine ([`super::World::run_all`]) burns an OS
//! thread per simulated process, which caps experiments at a few dozen
//! ranks. Here instead, rank bodies are *resumable tasks* implementing
//! [`RankTask`]: `poll` runs the body forward until it either finishes or
//! would block on a receive/exchange, in which case it returns
//! [`TaskPoll::Pending`] and **parks**. A fixed set of workers (default:
//! the machine's core count) drains a run queue of unparked tasks.
//!
//! A [`Pool`] is long-lived: jobs are *submitted* into it ([`Pool::submit`])
//! as task groups, each bound to its own [`World`], and complete through a
//! caller-supplied callback — the multi-tenant factorization service
//! ([`crate::service`]) multiplexes many (FT-)CAQR/TSQR jobs over one
//! pool this way. Tasks from different jobs interleave freely on the
//! workers; mailboxes, metrics, fault plans and retained recovery state
//! are all per-[`World`], so jobs cannot observe each other.
//!
//! Wakeup protocol (see `DESIGN.md` "Scheduler: parking and wakeup"):
//!
//! * every event delivered to rank `r`'s mailbox (message, death notice,
//!   revive notice) calls the [`super::Router`]'s registered waker, which
//!   re-queues `r`'s task in its owning job if it is parked;
//! * a wake that lands while the task is mid-poll sets a *dirty* flag so
//!   the task is immediately re-queued when its poll parks — the classic
//!   lost-wakeup guard;
//! * REBUILD replacements are injected mid-run through the [`Spawner`]
//!   handed to every poll; the spawner carries the job identity, so a
//!   replacement always lands in the task group of the world it belongs
//!   to, and its result is collected with the rest of that job's.
//!
//! Because a job's events are only ever produced by that job's running
//! tasks, "none of the job's tasks queued or running but live tasks
//! remain" is a proof of deadlock *for that job*; the pool then fails the
//! job's parked tasks with [`Fail::Stalled`] and completes the job —
//! protocol bugs surface as crisp per-job errors without stalling
//! unrelated tenants.
//!
//! Stall detection is *event-structural*, never time-based: the proof
//! above reasons only about task states (queued / running / parked), not
//! about logical or wall clocks. This matters for straggler injection
//! ([`super::Stragglers`]): a slowed rank's compute charges are
//! multiplied in *logical* time, but its task still polls, parks and
//! wakes exactly like a healthy one, so an arbitrarily slow-but-alive
//! rank can never be misclassified as [`Fail::Stalled`] — and,
//! conversely, a genuine deadlock is still detected even when stragglers
//! are present.
//!
//! **Compute lane** ([`Pool::par_ctx`]): besides rank tasks, workers
//! drain a second queue of *compute tasks* — the band closures a
//! [`crate::linalg::ParCtx`] splits a large GEMM into. A rank task that
//! reaches a big kernel submits its bands here and *helps drain the
//! queue itself* until they are all taken, then waits on a per-batch
//! latch; idle workers pick bands up in between rank polls. This is how
//! intra-rank parallelism shares the machine with inter-rank simulation
//! (and with every other tenant) without spawning ad-hoc threads or
//! oversubscribing cores — and because the submitter always helps first,
//! a batch completes even when every worker is busy polling rank tasks.
//! Compute tasks are preferred over rank polls: each one unblocks an
//! in-flight poll, while rank work only grows the frontier.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

use crate::ft::Fail;
use crate::linalg::{ParCtx, ParExecutor, ParTask};

use super::{RankCtx, World};

/// Outcome of one [`RankTask::poll`] call.
pub enum TaskPoll {
    /// The task finished (successfully or with a failure).
    Ready(Result<(), Fail>),
    /// The task parked on a receive/exchange; re-poll after a wakeup.
    Pending,
}

/// A resumable rank body. `poll` must make as much progress as possible
/// and return `Pending` only after a non-blocking primitive
/// ([`RankCtx::try_recv`] / [`RankCtx::poll_exchange`]) reported
/// "nothing yet"; the scheduler re-polls after the next event delivery
/// to this rank. Polls of distinct tasks run concurrently on the pool,
/// so shared state must be synchronized (as with rank threads).
pub trait RankTask: Send {
    /// Advance the task. `sp` spawns REBUILD replacement tasks mid-run.
    fn poll(&mut self, ctx: &mut RankCtx, sp: &Spawner) -> TaskPoll;
}

/// Default pool width for `n_tasks` simulated ranks: the machine's
/// available parallelism, capped by the task count.
pub fn default_workers(n_tasks: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    hw.clamp(1, n_tasks.max(1))
}

/// Identifier of one job (task group) inside a [`Pool`].
pub type JobId = u64;

/// Per-job results: one `(rank, result)` per task ever run, spawn order.
pub type JobResults = Vec<(usize, Result<(), Fail>)>;

type OnDone = Box<dyn FnOnce(JobResults) + Send + 'static>;

enum RunState {
    /// In the run queue.
    Queued,
    /// Being polled by a worker; `dirty` records a wakeup that arrived
    /// mid-poll.
    Running { dirty: bool },
    /// Waiting for a wakeup.
    Parked,
    /// Finished; `result` is set.
    Done,
}

struct Slot {
    rank: usize,
    run: RunState,
    /// Context + task, present unless Running (a worker holds them) or
    /// Done (dropped — dropping the ctx publishes its final clock).
    cell: Option<(RankCtx, Box<dyn RankTask>)>,
    result: Option<Result<(), Fail>>,
}

/// One submitted job: a group of task slots bound to one [`World`].
struct JobState {
    slots: Vec<Slot>,
    /// rank -> live task id (the latest incarnation's task).
    rank_task: HashMap<usize, usize>,
    /// Tasks not yet Done.
    active: usize,
    /// Tasks currently being polled.
    running: usize,
    /// Tasks sitting in the run queue.
    queued: usize,
    /// Completion callback; invoked exactly once, off the core lock.
    on_done: Option<OnDone>,
}

impl JobState {
    fn take_results(&mut self) -> JobResults {
        self.slots
            .iter_mut()
            .map(|s| (s.rank, s.result.take().unwrap_or(Err(Fail::Stalled))))
            .collect()
    }

    /// Fail every unfinished task (the job can make no further progress).
    fn stall_remaining(&mut self) {
        for slot in self.slots.iter_mut() {
            if !matches!(slot.run, RunState::Done) {
                if let Some((ctx, _)) = &slot.cell {
                    ctx.metrics.record_stall();
                }
                slot.cell = None; // drop ctx -> publish final clock
                slot.run = RunState::Done;
                slot.result = Some(Err(Fail::Stalled));
            }
        }
        self.active = 0;
        self.rank_task.clear();
    }
}

/// Completion latch for one [`ParExecutor::run_scoped`] batch: counts
/// outstanding compute tasks down to zero and carries the first panic
/// message (re-raised on the submitting thread, where the rank task's
/// own `catch_unwind` turns it into [`Fail::TaskPanicked`]).
struct ComputeLatch {
    state: Mutex<(usize, Option<String>)>,
    cv: Condvar,
}

impl ComputeLatch {
    fn new(n: usize) -> Arc<Self> {
        Arc::new(Self { state: Mutex::new((n, None)), cv: Condvar::new() })
    }

    fn finish(&self, panic: Option<String>) {
        let mut g = self.state.lock().unwrap();
        g.0 -= 1;
        if g.1.is_none() {
            g.1 = panic;
        }
        if g.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until every task in the batch has run; re-raise the first
    /// task panic on the caller.
    fn wait(&self) {
        let mut g = self.state.lock().unwrap();
        while g.0 > 0 {
            g = self.cv.wait(g).unwrap();
        }
        if let Some(msg) = g.1.take() {
            drop(g);
            panic!("pool compute task panicked: {msg}");
        }
    }
}

/// One band of kernel work on the compute lane. The closure's borrows
/// are erased to `'static` by the submitter, which guarantees (by
/// blocking on `latch`) that they outlive the run.
struct ComputeTask {
    run: ParTask<'static>,
    latch: Arc<ComputeLatch>,
}

/// Run one compute task, containing panics (recorded in the latch and
/// re-raised on the submitter — never on the worker that happened to
/// execute the band).
fn run_compute(t: ComputeTask) {
    let ComputeTask { run, latch } = t;
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(run));
    latch.finish(res.err().map(|p| panic_msg(p.as_ref())));
}

struct CoreState {
    jobs: HashMap<JobId, JobState>,
    /// Global run queue of (job, slot) pairs, shared by all tenants.
    queue: VecDeque<(JobId, usize)>,
    /// Compute lane: kernel bands submitted via [`Pool::par_ctx`].
    compute: VecDeque<ComputeTask>,
    next_job: JobId,
    shutdown: bool,
}

struct Core {
    state: Mutex<CoreState>,
    cv: Condvar,
}

impl Core {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(CoreState {
                jobs: HashMap::new(),
                queue: VecDeque::new(),
                compute: VecDeque::new(),
                next_job: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        })
    }

    /// Router waker target: unpark rank `rank`'s live task in `job`.
    /// Wakes for already-completed jobs are no-ops.
    fn wake(&self, job: JobId, rank: usize) {
        let mut g = self.state.lock().unwrap();
        let gs = &mut *g;
        let Some(js) = gs.jobs.get_mut(&job) else { return };
        if let Some(&id) = js.rank_task.get(&rank) {
            match js.slots[id].run {
                RunState::Parked => {
                    js.slots[id].run = RunState::Queued;
                    js.queued += 1;
                    gs.queue.push_back((job, id));
                    self.cv.notify_one();
                }
                RunState::Running { .. } => {
                    js.slots[id].run = RunState::Running { dirty: true };
                }
                RunState::Queued | RunState::Done => {}
            }
        }
    }
}

/// If `job` can no longer make progress (finished or stalled), remove it
/// and hand back its results + completion callback — the caller invokes
/// the callback AFTER releasing the core lock (it may re-enter the pool,
/// e.g. a service admission pump submitting the next queued job).
fn settle_job(gs: &mut CoreState, job: JobId) -> Option<(JobResults, OnDone)> {
    let js = gs.jobs.get_mut(&job)?;
    if js.active > 0 && (js.running > 0 || js.queued > 0) {
        return None; // still runnable
    }
    if js.active > 0 {
        // Per-job deadlock: every live task parked, none queued, no poll
        // in flight — and a job's events are only produced by its own
        // running tasks. Fail crisply instead of hanging the tenant.
        js.stall_remaining();
    }
    let mut js = gs.jobs.remove(&job).expect("job present");
    let results = js.take_results();
    let on_done = js.on_done.take().expect("on_done invoked once");
    Some((results, on_done))
}

/// Human-readable message from a caught panic payload.
fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Invoke a job's completion callback, containing its panics: a
/// panicking `on_done` (e.g. a finalizer tripping on a protocol bug)
/// must not take down the worker thread and starve unrelated tenants.
fn run_on_done(job: JobId, on_done: OnDone, results: JobResults) {
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || on_done(results)));
    if let Err(payload) = res {
        eprintln!(
            "sim worker: completion callback for job {job} panicked: {}",
            panic_msg(payload.as_ref())
        );
    }
}

/// Handle for adding tasks to a running job (REBUILD replacements).
/// Cloneable and passed to every [`RankTask::poll`]; spawns always land
/// in the job the polled task belongs to.
#[derive(Clone)]
pub struct Spawner {
    core: Arc<Core>,
    job: JobId,
}

impl Spawner {
    /// Register `task` as rank `ctx.rank`'s live task in this job and
    /// queue it. The rank's previous task (if any) keeps running to
    /// completion but no longer receives wakeups — it is expected to be
    /// dead/superseded (see [`RankCtx::check_self`]).
    pub fn spawn(&self, ctx: RankCtx, task: Box<dyn RankTask>) {
        let mut g = self.core.state.lock().unwrap();
        let gs = &mut *g;
        let js = gs
            .jobs
            .get_mut(&self.job)
            .expect("spawn into a live job (a polled task's job cannot complete)");
        let id = js.slots.len();
        let rank = ctx.rank;
        js.slots.push(Slot { rank, run: RunState::Queued, cell: Some((ctx, task)), result: None });
        js.rank_task.insert(rank, id);
        js.active += 1;
        js.queued += 1;
        gs.queue.push_back((self.job, id));
        self.core.cv.notify_one();
    }
}

enum PollOutcome {
    Finished(Result<(), Fail>),
    Parked(RankCtx, Box<dyn RankTask>),
}

fn worker_loop(core: &Arc<Core>) {
    let mut g = core.state.lock().unwrap();
    loop {
        // Compute bands first: each unblocks an in-flight rank poll
        // waiting on its batch latch.
        if let Some(t) = g.compute.pop_front() {
            drop(g);
            run_compute(t);
            g = core.state.lock().unwrap();
            continue;
        }
        if let Some((job, id)) = g.queue.pop_front() {
            let settled = {
                let gs = &mut *g;
                let Some(js) = gs.jobs.get_mut(&job) else {
                    continue; // stale entry for a completed job
                };
                js.queued -= 1;
                let Some((mut ctx, mut task)) = js.slots[id].cell.take() else {
                    continue; // stale entry for a finished task
                };
                js.slots[id].run = RunState::Running { dirty: false };
                js.running += 1;
                drop(g);

                let sp = Spawner { core: core.clone(), job };
                let polled = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    task.poll(&mut ctx, &sp)
                }));
                let outcome = match polled {
                    Ok(TaskPoll::Ready(res)) => {
                        // Dropping the ctx publishes the final logical clock.
                        drop(ctx);
                        drop(task);
                        PollOutcome::Finished(res)
                    }
                    Ok(TaskPoll::Pending) => PollOutcome::Parked(ctx, task),
                    Err(payload) => {
                        // A panicking task must not wedge the pool: without
                        // this, the job's running count never drops, it never
                        // settles, and every waiter (JobHandle::wait,
                        // Pool::run, Pool::drop's joins) hangs forever. Fail
                        // the task, and kill its rank so same-job peers see a
                        // death notice instead of parking indefinitely.
                        eprintln!(
                            "sim worker: task for rank {} (job {job}) panicked: {}",
                            ctx.rank,
                            panic_msg(payload.as_ref())
                        );
                        ctx.router().kill(ctx.rank);
                        drop(ctx);
                        drop(task);
                        PollOutcome::Finished(Err(Fail::TaskPanicked))
                    }
                };

                g = core.state.lock().unwrap();
                let gs = &mut *g;
                let js = gs.jobs.get_mut(&job).expect("job pinned by running task");
                js.running -= 1;
                match outcome {
                    PollOutcome::Finished(res) => {
                        let rank = js.slots[id].rank;
                        js.slots[id].run = RunState::Done;
                        js.slots[id].result = Some(res);
                        if js.rank_task.get(&rank) == Some(&id) {
                            js.rank_task.remove(&rank);
                        }
                        js.active -= 1;
                    }
                    PollOutcome::Parked(ctx, task) => {
                        let dirty = matches!(js.slots[id].run, RunState::Running { dirty: true });
                        if !dirty {
                            // A true park (no wakeup raced the poll): the
                            // task now waits on a message.
                            ctx.metrics.record_park();
                        }
                        js.slots[id].cell = Some((ctx, task));
                        if dirty {
                            js.slots[id].run = RunState::Queued;
                            js.queued += 1;
                            gs.queue.push_back((job, id));
                            core.cv.notify_one();
                        } else {
                            js.slots[id].run = RunState::Parked;
                        }
                    }
                }
                settle_job(gs, job)
            };
            if let Some((results, on_done)) = settled {
                drop(g);
                run_on_done(job, on_done, results);
                g = core.state.lock().unwrap();
            }
            if g.shutdown {
                core.cv.notify_all();
            }
            continue;
        }
        if g.shutdown {
            // Queue drained. Jobs with a poll still in flight will come
            // back through the loop above; anything else can never run
            // again — fail it so no submitter waits forever.
            let stuck: Vec<JobId> = g
                .jobs
                .iter()
                .filter(|(_, js)| js.running == 0)
                .map(|(id, _)| *id)
                .collect();
            for job in stuck {
                let settled = {
                    let gs = &mut *g;
                    // Another idle worker may have drained this job while
                    // we released the lock for a previous callback.
                    let Some(js) = gs.jobs.get_mut(&job) else { continue };
                    js.stall_remaining();
                    settle_job(gs, job)
                };
                if let Some((results, on_done)) = settled {
                    drop(g);
                    run_on_done(job, on_done, results);
                    g = core.state.lock().unwrap();
                }
            }
            if g.jobs.is_empty() && g.queue.is_empty() && g.compute.is_empty() {
                core.cv.notify_all();
                return;
            }
        }
        g = core.cv.wait(g).unwrap();
    }
}

/// A persistent, multi-tenant worker pool driving [`RankTask`] groups.
///
/// One `Pool` outlives many jobs: each [`Pool::submit`] registers a task
/// group bound to one [`World`] and returns immediately; the job's
/// results are delivered to its `on_done` callback on a worker thread
/// when the last task finishes (or the job stalls). [`Pool::run`] is the
/// blocking convenience used by the one-shot drivers.
///
/// Dropping the pool stops the workers: queued work is drained first,
/// and any job that can no longer progress is failed with
/// [`Fail::Stalled`] (its callback still fires).
pub struct Pool {
    core: Arc<Core>,
    workers: usize,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Pool {
    /// Start a pool with `workers` threads (minimum 1).
    pub fn new(workers: usize) -> Self {
        let core = Core::new();
        let n = workers.max(1);
        let handles = (0..n)
            .map(|i| {
                let core = core.clone();
                std::thread::Builder::new()
                    .name(format!("sim-worker-{i}"))
                    .spawn(move || worker_loop(&core))
                    .expect("spawn pool worker")
            })
            .collect();
        Self { core, workers: n, handles }
    }

    /// The pool's worker-thread count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// A [`ParCtx`] that splits kernel work across this pool's compute
    /// lane: drivers install it on the job's [`crate::backend::Backend`]
    /// so intra-rank GEMM/QR bands run on the same workers as everyone's
    /// rank tasks — one machine-wide budget, no oversubscription, no
    /// process-global knob. `width <= 1` degenerates to serial. The
    /// handle outlives the pool safely: once the workers are gone, the
    /// submitting thread drains its own bands inline.
    pub fn par_ctx(&self, width: usize) -> ParCtx {
        if width <= 1 {
            ParCtx::serial()
        } else {
            ParCtx::with_executor(Arc::new(PoolExecutor { core: self.core.clone() }), width)
        }
    }

    /// Submit a job: drive `tasks` (each paired with its rank in
    /// `world`) to completion, then invoke `on_done` with one
    /// `(rank, result)` per task ever run, in spawn order — REBUILD
    /// replacements spawned mid-run through the [`Spawner`] are included.
    /// Installs the pool as `world`'s waker; the world must be dedicated
    /// to this job. `on_done` runs on a worker thread and may call back
    /// into the pool (e.g. submit a follow-up job), but must not block
    /// on this pool's own results.
    pub fn submit(
        &self,
        world: &Arc<World>,
        tasks: Vec<(usize, Box<dyn RankTask>)>,
        on_done: impl FnOnce(JobResults) + Send + 'static,
    ) -> JobId {
        // Register the (empty) job first so the waker target exists
        // before any task can run.
        let job = {
            let mut g = self.core.state.lock().unwrap();
            let job = g.next_job;
            g.next_job += 1;
            g.jobs.insert(
                job,
                JobState {
                    slots: Vec::new(),
                    rank_task: HashMap::new(),
                    active: 0,
                    running: 0,
                    queued: 0,
                    on_done: Some(Box::new(on_done)),
                },
            );
            job
        };
        {
            let c = self.core.clone();
            let waker: super::Waker = Arc::new(move |rank| c.wake(job, rank));
            world.router().set_waker(Some(waker));
        }
        // Take contexts outside the core lock (the world has its own).
        let cells: Vec<(RankCtx, Box<dyn RankTask>)> =
            tasks.into_iter().map(|(rank, task)| (world.ctx(rank), task)).collect();
        let settled = {
            let mut g = self.core.state.lock().unwrap();
            let gs = &mut *g;
            let js = gs.jobs.get_mut(&job).expect("just inserted");
            for (ctx, task) in cells {
                let id = js.slots.len();
                let rank = ctx.rank;
                js.slots.push(Slot {
                    rank,
                    run: RunState::Queued,
                    cell: Some((ctx, task)),
                    result: None,
                });
                js.rank_task.insert(rank, id);
                js.active += 1;
                js.queued += 1;
                gs.queue.push_back((job, id));
            }
            self.core.cv.notify_all();
            // Degenerate empty submission: complete immediately.
            settle_job(gs, job)
        };
        if let Some((results, on_done)) = settled {
            run_on_done(job, on_done, results);
        }
        job
    }

    /// Submit `tasks` and block until the job completes; returns its
    /// results (see [`Pool::submit`] for the contract).
    pub fn run(
        &self,
        world: &Arc<World>,
        tasks: Vec<(usize, Box<dyn RankTask>)>,
    ) -> JobResults {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(world, tasks, move |results| {
            let _ = tx.send(results);
        });
        rx.recv().expect("pool delivers job results")
    }
}

/// The pool-backed [`ParExecutor`] behind [`Pool::par_ctx`]: enqueue
/// every band on the compute lane, help drain the lane from the
/// submitting thread, then wait on the batch latch. Help-first makes the
/// scheme deadlock-free by construction — even with zero free workers
/// (all busy polling rank tasks, or the pool already shut down), the
/// submitter itself runs every band it popped, and whatever it did not
/// pop is held by a worker that will finish it.
struct PoolExecutor {
    core: Arc<Core>,
}

impl ParExecutor for PoolExecutor {
    fn run_scoped<'s>(&self, tasks: Vec<ParTask<'s>>) {
        let latch = ComputeLatch::new(tasks.len());
        {
            let mut g = self.core.state.lock().unwrap();
            for t in tasks {
                // SAFETY: the closure borrows operands owned by this
                // call's caller ('s). We block on `latch` below until
                // every task has run (run_compute counts panicked tasks
                // down too), so no task outlives the borrow — this is
                // `std::thread::scope`'s guarantee, enforced by the same
                // block-until-done structure.
                let run: ParTask<'static> = unsafe { std::mem::transmute::<ParTask<'s>, ParTask<'static>>(t) };
                g.compute.push_back(ComputeTask { run, latch: latch.clone() });
            }
        }
        self.core.cv.notify_all();
        // Help-first: drain the lane on this thread until it is empty.
        // (We may run bands of a concurrent batch — harmless, they are
        // pure compute and never block.)
        loop {
            let t = self.core.state.lock().unwrap().compute.pop_front();
            match t {
                Some(t) => run_compute(t),
                None => break,
            }
        }
        latch.wait();
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut g = self.core.state.lock().unwrap();
            g.shutdown = true;
        }
        self.core.cv.notify_all();
        for h in self.handles.drain(..) {
            // Workers contain task/callback panics (catch_unwind in
            // worker_loop), so joins terminate once the jobs drain.
            let _ = h.join();
        }
    }
}

/// Run `tasks` to completion on an ephemeral `workers`-thread pool (see
/// [`World::run_tasks`]). One-shot drivers use this; the multi-tenant
/// service keeps a persistent [`Pool`] instead.
pub(crate) fn run_pool(
    world: &Arc<World>,
    workers: usize,
    tasks: Vec<(usize, Box<dyn RankTask>)>,
) -> JobResults {
    let pool = Pool::new(workers);
    let results = pool.run(world, tasks);
    world.router().set_waker(None);
    results
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultPlan;
    use crate::sim::{CostModel, ExchangeOp, MsgData, Stragglers, Tag, TagKind};

    fn tag() -> Tag {
        Tag::plain(TagKind::Misc(42))
    }

    /// Even ranks send a token to rank+1 and wait for the doubled reply;
    /// odd ranks wait for the token and reply.
    struct PingPong {
        sent: bool,
    }

    impl RankTask for PingPong {
        fn poll(&mut self, ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
            let me = ctx.rank;
            if me % 2 == 0 {
                if !self.sent {
                    if let Err(e) = ctx.send(me + 1, tag(), MsgData::Ctrl(me as u64)) {
                        return TaskPoll::Ready(Err(e));
                    }
                    self.sent = true;
                }
                match ctx.try_recv(me + 1, tag()) {
                    Ok(Some(d)) => {
                        assert_eq!(d.into_ctrl(), 2 * me as u64);
                        TaskPoll::Ready(Ok(()))
                    }
                    Ok(None) => TaskPoll::Pending,
                    Err(e) => TaskPoll::Ready(Err(e)),
                }
            } else {
                match ctx.try_recv(me - 1, tag()) {
                    Ok(Some(d)) => {
                        let v = d.into_ctrl();
                        match ctx.send(me - 1, tag(), MsgData::Ctrl(2 * v)) {
                            Ok(()) => TaskPoll::Ready(Ok(())),
                            Err(e) => TaskPoll::Ready(Err(e)),
                        }
                    }
                    Ok(None) => TaskPoll::Pending,
                    Err(e) => TaskPoll::Ready(Err(e)),
                }
            }
        }
    }

    fn pingpong_tasks(n: usize) -> Vec<(usize, Box<dyn RankTask>)> {
        (0..n)
            .map(|r| (r, Box::new(PingPong { sent: false }) as Box<dyn RankTask>))
            .collect()
    }

    #[test]
    fn pool_runs_many_ranks_on_few_workers() {
        let n = 128;
        let w = World::new(n, CostModel::default(), FaultPlan::none());
        let results = w.run_tasks(4, pingpong_tasks(n));
        assert_eq!(results.len(), n);
        for (rank, res) in results {
            assert_eq!(res, Ok(()), "rank {rank}");
        }
        assert_eq!(w.metrics.snapshot().messages, n as u64);
    }

    /// Hypercube exchange at every step — the FT-TSQR communication
    /// pattern, driven through begin/poll_exchange.
    struct ExchangeChain {
        s: usize,
        steps: usize,
        op: Option<ExchangeOp>,
    }

    impl RankTask for ExchangeChain {
        fn poll(&mut self, ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
            loop {
                if let Some(op) = self.op.as_mut() {
                    match ctx.poll_exchange(op) {
                        Ok(Some(d)) => {
                            let _ = d.into_ctrl();
                            self.op = None;
                            self.s += 1;
                        }
                        Ok(None) => return TaskPoll::Pending,
                        Err(e) => return TaskPoll::Ready(Err(e)),
                    }
                }
                if self.s == self.steps {
                    return TaskPoll::Ready(Ok(()));
                }
                let peer = ctx.rank ^ (1 << self.s);
                let t = Tag::new(TagKind::Misc(1), 0, self.s);
                match ctx.begin_exchange(peer, t, MsgData::Ctrl(ctx.rank as u64)) {
                    Ok(op) => self.op = Some(op),
                    Err(e) => return TaskPoll::Ready(Err(e)),
                }
            }
        }
    }

    #[test]
    fn pooled_exchanges_run_a_hypercube() {
        let n = 64; // 6 hypercube steps
        let w = World::new(n, CostModel::default(), FaultPlan::none());
        let tasks: Vec<(usize, Box<dyn RankTask>)> = (0..n)
            .map(|r| (r, Box::new(ExchangeChain { s: 0, steps: 6, op: None }) as Box<dyn RankTask>))
            .collect();
        let results = w.run_tasks(default_workers(n), tasks);
        for (rank, res) in results {
            assert_eq!(res, Ok(()), "rank {rank}");
        }
        assert_eq!(w.metrics.snapshot().exchanges, (n * 6) as u64);
    }

    /// Two independent exchange chains multiplexed on ONE task, routed
    /// apart purely by the tag's lane — the lookahead engine's shape: a
    /// rank drives several in-flight sub-machines, each parking on its
    /// own exchange, and a single wakeup advances whichever can run.
    struct TwoLanes {
        s: [usize; 2],
        ops: [Option<ExchangeOp>; 2],
        steps: usize,
    }

    impl RankTask for TwoLanes {
        fn poll(&mut self, ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
            loop {
                let mut progressed = false;
                for lane in 0..2 {
                    if let Some(op) = self.ops[lane].as_mut() {
                        match ctx.poll_exchange(op) {
                            Ok(Some(d)) => {
                                // The payload must come from the SAME
                                // lane's chain — no cross-talk.
                                assert_eq!(d.into_ctrl(), lane as u64);
                                self.ops[lane] = None;
                                self.s[lane] += 1;
                                progressed = true;
                            }
                            Ok(None) => {}
                            Err(e) => return TaskPoll::Ready(Err(e)),
                        }
                    }
                    if self.ops[lane].is_none() && self.s[lane] < self.steps {
                        let peer = ctx.rank ^ 1;
                        let t = Tag::with_lane(TagKind::UpdateC, 0, self.s[lane], lane as u32);
                        match ctx.begin_exchange(peer, t, MsgData::Ctrl(lane as u64)) {
                            Ok(op) => {
                                self.ops[lane] = Some(op);
                                progressed = true;
                            }
                            Err(e) => return TaskPoll::Ready(Err(e)),
                        }
                    }
                }
                if self.s[0] == self.steps && self.s[1] == self.steps {
                    return TaskPoll::Ready(Ok(()));
                }
                if !progressed {
                    return TaskPoll::Pending;
                }
            }
        }
    }

    #[test]
    fn one_task_multiplexes_lane_routed_exchanges() {
        let n = 2;
        let w = World::new(n, CostModel::default(), FaultPlan::none());
        let tasks: Vec<(usize, Box<dyn RankTask>)> = (0..n)
            .map(|r| {
                (
                    r,
                    Box::new(TwoLanes { s: [0, 0], ops: [None, None], steps: 5 })
                        as Box<dyn RankTask>,
                )
            })
            .collect();
        let results = w.run_tasks(2, tasks);
        for (rank, res) in results {
            assert_eq!(res, Ok(()), "rank {rank}");
        }
        assert_eq!(w.metrics.snapshot().exchanges, (n * 2 * 5) as u64);
    }

    /// [`PingPong`] with a compute charge up front — the shape that would
    /// tempt a timeout-based stall detector, since one rank's logical
    /// clock can run far behind its peers'.
    struct BusyPingPong {
        flops: u64,
        inner: PingPong,
    }

    impl RankTask for BusyPingPong {
        fn poll(&mut self, ctx: &mut RankCtx, sp: &Spawner) -> TaskPoll {
            if self.flops > 0 {
                ctx.compute(std::mem::take(&mut self.flops));
            }
            self.inner.poll(ctx, sp)
        }
    }

    fn busy_tasks(n: usize) -> Vec<(usize, Box<dyn RankTask>)> {
        (0..n)
            .map(|r| {
                let t = BusyPingPong { flops: 1 << 22, inner: PingPong { sent: false } };
                (r, Box::new(t) as Box<dyn RankTask>)
            })
            .collect()
    }

    #[test]
    fn straggler_slowed_rank_completes_instead_of_stalling() {
        // Regression (straggler vs stall misclassification): a 10x-slowed
        // rank still polls/parks/wakes like a healthy one, so the
        // event-structural deadlock proof never fires and the job
        // completes — while the slowdown is visible in the critical path.
        let run = |stragglers: Stragglers| {
            let w = World::new_with_stragglers(
                4,
                CostModel::default(),
                FaultPlan::none(),
                stragglers,
            );
            let results = w.run_tasks(2, busy_tasks(4));
            for (rank, res) in results {
                assert_eq!(res, Ok(()), "rank {rank}");
            }
            w.metrics.snapshot().critical_path
        };
        let healthy = run(Stragglers::none());
        let slowed = run(Stragglers::new(vec![(0, 10.0)]));
        assert!(
            slowed > healthy,
            "a 10x straggler must lengthen the critical path: {slowed} vs {healthy}"
        );
    }

    #[test]
    fn genuine_stall_is_still_detected_with_a_straggler_present() {
        // The converse: stragglers do not mask a real deadlock, because
        // detection reasons about events, not elapsed logical time.
        let w = World::new_with_stragglers(
            2,
            CostModel::default(),
            FaultPlan::none(),
            Stragglers::new(vec![(0, 10.0)]),
        );
        let results = w.run_tasks(2, forever_tasks(2));
        for (_, res) in results {
            assert_eq!(res, Err(Fail::Stalled));
        }
    }

    /// A task that parks forever (waits for a message nobody sends).
    struct Forever;

    impl RankTask for Forever {
        fn poll(&mut self, ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
            match ctx.try_recv((ctx.rank + 1) % 2, tag()) {
                Ok(Some(_)) => TaskPoll::Ready(Ok(())),
                Ok(None) => TaskPoll::Pending,
                Err(e) => TaskPoll::Ready(Err(e)),
            }
        }
    }

    fn forever_tasks(n: usize) -> Vec<(usize, Box<dyn RankTask>)> {
        (0..n).map(|r| (r, Box::new(Forever) as Box<dyn RankTask>)).collect()
    }

    #[test]
    fn global_stall_is_detected_not_hung() {
        let w = World::new(2, CostModel::default(), FaultPlan::none());
        let results = w.run_tasks(2, forever_tasks(2));
        for (_, res) in results {
            assert_eq!(res, Err(Fail::Stalled));
        }
    }

    /// First poll spawns a sender task for rank 1 (carried along), then
    /// waits for its message — exercises mid-run spawning.
    struct SpawningTask {
        carried: Option<(RankCtx, Box<dyn RankTask>)>,
    }

    struct SendOnce;

    impl RankTask for SendOnce {
        fn poll(&mut self, ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
            TaskPoll::Ready(ctx.send(0, tag(), MsgData::Ctrl(99)))
        }
    }

    impl RankTask for SpawningTask {
        fn poll(&mut self, ctx: &mut RankCtx, sp: &Spawner) -> TaskPoll {
            if let Some((c, t)) = self.carried.take() {
                sp.spawn(c, t);
            }
            match ctx.try_recv(1, tag()) {
                Ok(Some(d)) => {
                    assert_eq!(d.into_ctrl(), 99);
                    TaskPoll::Ready(Ok(()))
                }
                Ok(None) => TaskPoll::Pending,
                Err(e) => TaskPoll::Ready(Err(e)),
            }
        }
    }

    #[test]
    fn tasks_spawned_mid_run_are_driven_and_reported() {
        let w = World::new(2, CostModel::default(), FaultPlan::none());
        let ctx1 = w.ctx(1);
        let t0 = SpawningTask { carried: Some((ctx1, Box::new(SendOnce) as Box<dyn RankTask>)) };
        let results = w.run_tasks(2, vec![(0, Box::new(t0) as Box<dyn RankTask>)]);
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|(_, r)| r.is_ok()));
        assert_eq!(results[1].0, 1);
    }

    #[test]
    fn one_pool_drives_many_jobs_concurrently() {
        // The multi-tenant contract in miniature: 8 independent worlds
        // submitted into one 3-worker pool, all complete, and each job's
        // per-world metrics see exactly its own traffic.
        let pool = Pool::new(3);
        let n = 16;
        let worlds: Vec<_> =
            (0..8).map(|_| World::new(n, CostModel::default(), FaultPlan::none())).collect();
        let (tx, rx) = std::sync::mpsc::channel();
        for (j, w) in worlds.iter().enumerate() {
            let tx = tx.clone();
            pool.submit(w, pingpong_tasks(n), move |results| {
                let _ = tx.send((j, results));
            });
        }
        drop(tx);
        let mut done = 0;
        while let Ok((j, results)) = rx.recv() {
            assert_eq!(results.len(), n, "job {j}");
            assert!(results.iter().all(|(_, r)| r.is_ok()), "job {j}");
            done += 1;
        }
        assert_eq!(done, 8);
        for w in &worlds {
            assert_eq!(w.metrics.snapshot().messages, n as u64);
        }
    }

    #[test]
    fn stalled_job_does_not_block_neighbors() {
        // One tenant deadlocks; the pool fails it with Stalled while the
        // healthy tenant completes normally.
        let pool = Pool::new(2);
        let bad = World::new(2, CostModel::default(), FaultPlan::none());
        let good = World::new(8, CostModel::default(), FaultPlan::none());
        let (tx_b, rx_b) = std::sync::mpsc::channel();
        let (tx_g, rx_g) = std::sync::mpsc::channel();
        pool.submit(&bad, forever_tasks(2), move |r| {
            let _ = tx_b.send(r);
        });
        pool.submit(&good, pingpong_tasks(8), move |r| {
            let _ = tx_g.send(r);
        });
        let good_res = rx_g.recv().unwrap();
        assert!(good_res.iter().all(|(_, r)| r.is_ok()));
        let bad_res = rx_b.recv().unwrap();
        assert!(bad_res.iter().all(|(_, r)| *r == Err(Fail::Stalled)));
    }

    #[test]
    fn empty_submission_completes_immediately() {
        let pool = Pool::new(1);
        let w = World::new(1, CostModel::default(), FaultPlan::none());
        let results = pool.run(&w, Vec::new());
        assert!(results.is_empty());
    }

    #[test]
    fn pool_par_ctx_gemm_matches_serial_bitwise() {
        use crate::linalg::{gemm, gemm_with, Matrix, SimdLevel, Trans};
        let pool = Pool::new(3);
        let a = Matrix::randn(150, 64, 31);
        let b = Matrix::randn(64, 220, 32);
        let serial = gemm(Trans::No, Trans::No, 1.0, &a, &b);
        let got =
            gemm_with(&pool.par_ctx(3), SimdLevel::best(), Trans::No, Trans::No, 1.0, &a, &b);
        assert_eq!(serial, got, "pool-lane split must not change results");
    }

    /// A rank task that runs one pool-parallel gemm and checks it
    /// bitwise against a precomputed serial product.
    struct GemmTask {
        par: ParCtx,
        a: crate::linalg::Matrix,
        b: crate::linalg::Matrix,
        want: crate::linalg::Matrix,
    }

    impl RankTask for GemmTask {
        fn poll(&mut self, _ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
            use crate::linalg::{gemm_with, SimdLevel, Trans};
            let got =
                gemm_with(&self.par, SimdLevel::best(), Trans::No, Trans::No, 1.0, &self.a, &self.b);
            assert_eq!(got, self.want, "pooled gemm diverged from serial");
            TaskPoll::Ready(Ok(()))
        }
    }

    #[test]
    fn busy_pool_drains_compute_bands_help_first() {
        use crate::linalg::{gemm, Matrix, Trans};
        // More rank tasks than workers, and every rank task submits a
        // 4-way parallel gemm: with both workers busy polling, the
        // batches can only complete because submitters drain the compute
        // lane themselves (help-first). A deadlock here would surface as
        // a hang; a determinism bug as the bitwise assert inside.
        let pool = Pool::new(2);
        let n = 4;
        let w = World::new(n, CostModel::default(), FaultPlan::none());
        let a = Matrix::randn(150, 64, 33);
        let b = Matrix::randn(64, 220, 34);
        let want = gemm(Trans::No, Trans::No, 1.0, &a, &b);
        let tasks: Vec<(usize, Box<dyn RankTask>)> = (0..n)
            .map(|r| {
                let t = GemmTask {
                    par: pool.par_ctx(4),
                    a: a.clone(),
                    b: b.clone(),
                    want: want.clone(),
                };
                (r, Box::new(t) as Box<dyn RankTask>)
            })
            .collect();
        let results = pool.run(&w, tasks);
        for (rank, res) in results {
            assert_eq!(res, Ok(()), "rank {rank}");
        }
        w.router().set_waker(None);
    }

    /// A rank task whose parallel batch contains a panicking band.
    struct PanickingBandTask {
        par: ParCtx,
    }

    impl RankTask for PanickingBandTask {
        fn poll(&mut self, _ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
            self.par.run(vec![
                Box::new(|| panic!("band boom")) as ParTask<'_>,
                Box::new(|| {}),
            ]);
            TaskPoll::Ready(Ok(()))
        }
    }

    #[test]
    fn compute_band_panic_fails_the_submitting_task_only() {
        // The panic is recorded in the batch latch and re-raised on the
        // submitting rank task, whose own catch_unwind turns it into
        // TaskPanicked — the worker that happened to execute the band
        // (possibly a different one) is unaffected and keeps serving.
        let pool = Pool::new(2);
        let w = World::new(1, CostModel::default(), FaultPlan::none());
        let t = PanickingBandTask { par: pool.par_ctx(2) };
        let results = pool.run(&w, vec![(0, Box::new(t) as Box<dyn RankTask>)]);
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].1, Err(Fail::TaskPanicked));
        w.router().set_waker(None);
        // The pool still works after the panic.
        let w2 = World::new(4, CostModel::default(), FaultPlan::none());
        let results = pool.run(&w2, pingpong_tasks(4));
        assert!(results.iter().all(|(_, r)| r.is_ok()));
    }
}
