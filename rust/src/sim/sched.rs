//! Bounded worker-pool scheduler: the engine that lets one process
//! simulate P >= 512 ranks.
//!
//! The thread-per-rank engine ([`super::World::run_all`]) burns an OS
//! thread per simulated process, which caps experiments at a few dozen
//! ranks. Here instead, rank bodies are *resumable tasks* implementing
//! [`RankTask`]: `poll` runs the body forward until it either finishes or
//! would block on a receive/exchange, in which case it returns
//! [`TaskPoll::Pending`] and **parks**. A fixed set of workers (default:
//! the machine's core count) drains a run queue of unparked tasks.
//!
//! Wakeup protocol (see `DESIGN.md` "Scheduler: parking and wakeup"):
//!
//! * every event delivered to rank `r`'s mailbox (message, death notice,
//!   revive notice) calls the [`super::Router`]'s registered waker, which
//!   re-queues `r`'s task if it is parked;
//! * a wake that lands while the task is mid-poll sets a *dirty* flag so
//!   the task is immediately re-queued when its poll parks — the classic
//!   lost-wakeup guard;
//! * REBUILD replacements are injected mid-run through the [`Spawner`]
//!   handed to every poll, and their results are collected with
//!   everyone else's.
//!
//! Because events are only ever produced by running tasks, "run queue
//! empty and nothing running but live tasks remain" is a proof of global
//! deadlock; the pool then fails every parked task with
//! [`Fail::Stalled`] instead of hanging the process — protocol bugs
//! surface as crisp errors even at P = 1024.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex};

use crate::ft::Fail;

use super::{RankCtx, World};

/// Outcome of one [`RankTask::poll`] call.
pub enum TaskPoll {
    /// The task finished (successfully or with a failure).
    Ready(Result<(), Fail>),
    /// The task parked on a receive/exchange; re-poll after a wakeup.
    Pending,
}

/// A resumable rank body. `poll` must make as much progress as possible
/// and return `Pending` only after a non-blocking primitive
/// ([`RankCtx::try_recv`] / [`RankCtx::poll_exchange`]) reported
/// "nothing yet"; the scheduler re-polls after the next event delivery
/// to this rank. Polls of distinct tasks run concurrently on the pool,
/// so shared state must be synchronized (as with rank threads).
pub trait RankTask: Send {
    /// Advance the task. `sp` spawns REBUILD replacement tasks mid-run.
    fn poll(&mut self, ctx: &mut RankCtx, sp: &Spawner) -> TaskPoll;
}

/// Default pool width for `n_tasks` simulated ranks: the machine's
/// available parallelism, capped by the task count.
pub fn default_workers(n_tasks: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    hw.clamp(1, n_tasks.max(1))
}

enum RunState {
    /// In the run queue.
    Queued,
    /// Being polled by a worker; `dirty` records a wakeup that arrived
    /// mid-poll.
    Running { dirty: bool },
    /// Waiting for a wakeup.
    Parked,
    /// Finished; `result` is set.
    Done,
}

struct Slot {
    rank: usize,
    run: RunState,
    /// Context + task, present unless Running (a worker holds them) or
    /// Done (dropped — dropping the ctx publishes its final clock).
    cell: Option<(RankCtx, Box<dyn RankTask>)>,
    result: Option<Result<(), Fail>>,
}

struct CoreState {
    slots: Vec<Slot>,
    queue: VecDeque<usize>,
    /// rank -> live task id (the latest incarnation's task).
    rank_task: HashMap<usize, usize>,
    /// Tasks not yet Done.
    active: usize,
    /// Tasks currently being polled.
    running: usize,
}

struct Core {
    state: Mutex<CoreState>,
    cv: Condvar,
}

impl Core {
    fn new() -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(CoreState {
                slots: Vec::new(),
                queue: VecDeque::new(),
                rank_task: HashMap::new(),
                active: 0,
                running: 0,
            }),
            cv: Condvar::new(),
        })
    }

    /// Router waker target: unpark rank `rank`'s live task.
    fn wake(&self, rank: usize) {
        let mut g = self.state.lock().unwrap();
        if let Some(&id) = g.rank_task.get(&rank) {
            match g.slots[id].run {
                RunState::Parked => {
                    g.slots[id].run = RunState::Queued;
                    g.queue.push_back(id);
                    self.cv.notify_one();
                }
                RunState::Running { .. } => {
                    g.slots[id].run = RunState::Running { dirty: true };
                }
                RunState::Queued | RunState::Done => {}
            }
        }
    }

    fn results(&self) -> Vec<(usize, Result<(), Fail>)> {
        let mut g = self.state.lock().unwrap();
        g.slots
            .iter_mut()
            .map(|s| (s.rank, s.result.take().unwrap_or(Err(Fail::Stalled))))
            .collect()
    }
}

/// Handle for adding tasks to a running pool (REBUILD replacements).
/// Cloneable and passed to every [`RankTask::poll`].
#[derive(Clone)]
pub struct Spawner {
    core: Arc<Core>,
}

impl Spawner {
    /// Register `task` as rank `ctx.rank`'s live task and queue it. The
    /// rank's previous task (if any) keeps running to completion but no
    /// longer receives wakeups — it is expected to be dead/superseded
    /// (see [`RankCtx::check_self`]).
    pub fn spawn(&self, ctx: RankCtx, task: Box<dyn RankTask>) {
        let mut g = self.core.state.lock().unwrap();
        let id = g.slots.len();
        let rank = ctx.rank;
        g.slots.push(Slot { rank, run: RunState::Queued, cell: Some((ctx, task)), result: None });
        g.rank_task.insert(rank, id);
        g.active += 1;
        g.queue.push_back(id);
        self.core.cv.notify_one();
    }
}

enum PollOutcome {
    Finished(Result<(), Fail>),
    Parked(RankCtx, Box<dyn RankTask>),
}

fn worker_loop(core: &Arc<Core>, sp: &Spawner) {
    let mut g = core.state.lock().unwrap();
    loop {
        if let Some(id) = g.queue.pop_front() {
            let Some((mut ctx, mut task)) = g.slots[id].cell.take() else {
                continue; // stale queue entry for a finished task
            };
            g.slots[id].run = RunState::Running { dirty: false };
            g.running += 1;
            drop(g);

            let outcome = match task.poll(&mut ctx, sp) {
                TaskPoll::Ready(res) => {
                    // Dropping the ctx publishes the final logical clock.
                    drop(ctx);
                    drop(task);
                    PollOutcome::Finished(res)
                }
                TaskPoll::Pending => PollOutcome::Parked(ctx, task),
            };

            g = core.state.lock().unwrap();
            g.running -= 1;
            match outcome {
                PollOutcome::Finished(res) => {
                    let rank = g.slots[id].rank;
                    g.slots[id].run = RunState::Done;
                    g.slots[id].result = Some(res);
                    if g.rank_task.get(&rank) == Some(&id) {
                        g.rank_task.remove(&rank);
                    }
                    g.active -= 1;
                    if g.active == 0 {
                        core.cv.notify_all();
                    }
                }
                PollOutcome::Parked(ctx, task) => {
                    let dirty = matches!(g.slots[id].run, RunState::Running { dirty: true });
                    g.slots[id].cell = Some((ctx, task));
                    if dirty {
                        g.slots[id].run = RunState::Queued;
                        g.queue.push_back(id);
                        core.cv.notify_one();
                    } else {
                        g.slots[id].run = RunState::Parked;
                    }
                }
            }
            continue;
        }
        if g.active == 0 {
            core.cv.notify_all();
            return;
        }
        if g.running == 0 {
            // Global stall: every live task is parked, no poll is in
            // flight, and events are only produced by running tasks —
            // nothing can ever wake anyone again. Fail crisply.
            for slot in g.slots.iter_mut() {
                if !matches!(slot.run, RunState::Done) {
                    slot.cell = None; // drop ctx -> publish final clock
                    slot.run = RunState::Done;
                    slot.result = Some(Err(Fail::Stalled));
                }
            }
            g.active = 0;
            g.rank_task.clear();
            core.cv.notify_all();
            return;
        }
        g = core.cv.wait(g).unwrap();
    }
}

/// Run `tasks` to completion on `workers` pool threads (see
/// [`World::run_tasks`]).
pub(crate) fn run_pool(
    world: &Arc<World>,
    workers: usize,
    tasks: Vec<(usize, Box<dyn RankTask>)>,
) -> Vec<(usize, Result<(), Fail>)> {
    let core = Core::new();
    {
        let c = core.clone();
        let waker: super::Waker = Arc::new(move |rank| c.wake(rank));
        world.router().set_waker(Some(waker));
    }
    let sp = Spawner { core: core.clone() };
    for (rank, task) in tasks {
        sp.spawn(world.ctx(rank), task);
    }
    let nworkers = workers.max(1);
    std::thread::scope(|s| {
        for i in 0..nworkers {
            let core = core.clone();
            let sp = sp.clone();
            std::thread::Builder::new()
                .name(format!("sim-worker-{i}"))
                .spawn_scoped(s, move || worker_loop(&core, &sp))
                .expect("spawn pool worker");
        }
    });
    world.router().set_waker(None);
    core.results()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::FaultPlan;
    use crate::sim::{CostModel, ExchangeOp, MsgData, Tag, TagKind};

    fn tag() -> Tag {
        Tag::plain(TagKind::Misc(42))
    }

    /// Even ranks send a token to rank+1 and wait for the doubled reply;
    /// odd ranks wait for the token and reply.
    struct PingPong {
        sent: bool,
    }

    impl RankTask for PingPong {
        fn poll(&mut self, ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
            let me = ctx.rank;
            if me % 2 == 0 {
                if !self.sent {
                    if let Err(e) = ctx.send(me + 1, tag(), MsgData::Ctrl(me as u64)) {
                        return TaskPoll::Ready(Err(e));
                    }
                    self.sent = true;
                }
                match ctx.try_recv(me + 1, tag()) {
                    Ok(Some(d)) => {
                        assert_eq!(d.into_ctrl(), 2 * me as u64);
                        TaskPoll::Ready(Ok(()))
                    }
                    Ok(None) => TaskPoll::Pending,
                    Err(e) => TaskPoll::Ready(Err(e)),
                }
            } else {
                match ctx.try_recv(me - 1, tag()) {
                    Ok(Some(d)) => {
                        let v = d.into_ctrl();
                        match ctx.send(me - 1, tag(), MsgData::Ctrl(2 * v)) {
                            Ok(()) => TaskPoll::Ready(Ok(())),
                            Err(e) => TaskPoll::Ready(Err(e)),
                        }
                    }
                    Ok(None) => TaskPoll::Pending,
                    Err(e) => TaskPoll::Ready(Err(e)),
                }
            }
        }
    }

    #[test]
    fn pool_runs_many_ranks_on_few_workers() {
        let n = 128;
        let w = World::new(n, CostModel::default(), FaultPlan::none());
        let tasks: Vec<(usize, Box<dyn RankTask>)> = (0..n)
            .map(|r| (r, Box::new(PingPong { sent: false }) as Box<dyn RankTask>))
            .collect();
        let results = w.run_tasks(4, tasks);
        assert_eq!(results.len(), n);
        for (rank, res) in results {
            assert_eq!(res, Ok(()), "rank {rank}");
        }
        assert_eq!(w.metrics.snapshot().messages, n as u64);
    }

    /// Hypercube exchange at every step — the FT-TSQR communication
    /// pattern, driven through begin/poll_exchange.
    struct ExchangeChain {
        s: usize,
        steps: usize,
        op: Option<ExchangeOp>,
    }

    impl RankTask for ExchangeChain {
        fn poll(&mut self, ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
            loop {
                if let Some(op) = self.op.as_mut() {
                    match ctx.poll_exchange(op) {
                        Ok(Some(d)) => {
                            let _ = d.into_ctrl();
                            self.op = None;
                            self.s += 1;
                        }
                        Ok(None) => return TaskPoll::Pending,
                        Err(e) => return TaskPoll::Ready(Err(e)),
                    }
                }
                if self.s == self.steps {
                    return TaskPoll::Ready(Ok(()));
                }
                let peer = ctx.rank ^ (1 << self.s);
                let t = Tag::new(TagKind::Misc(1), 0, self.s);
                match ctx.begin_exchange(peer, t, MsgData::Ctrl(ctx.rank as u64)) {
                    Ok(op) => self.op = Some(op),
                    Err(e) => return TaskPoll::Ready(Err(e)),
                }
            }
        }
    }

    #[test]
    fn pooled_exchanges_run_a_hypercube() {
        let n = 64; // 6 hypercube steps
        let w = World::new(n, CostModel::default(), FaultPlan::none());
        let tasks: Vec<(usize, Box<dyn RankTask>)> = (0..n)
            .map(|r| (r, Box::new(ExchangeChain { s: 0, steps: 6, op: None }) as Box<dyn RankTask>))
            .collect();
        let results = w.run_tasks(default_workers(n), tasks);
        for (rank, res) in results {
            assert_eq!(res, Ok(()), "rank {rank}");
        }
        assert_eq!(w.metrics.snapshot().exchanges, (n * 6) as u64);
    }

    /// A task that parks forever (waits for a message nobody sends).
    struct Forever;

    impl RankTask for Forever {
        fn poll(&mut self, ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
            match ctx.try_recv((ctx.rank + 1) % 2, tag()) {
                Ok(Some(_)) => TaskPoll::Ready(Ok(())),
                Ok(None) => TaskPoll::Pending,
                Err(e) => TaskPoll::Ready(Err(e)),
            }
        }
    }

    #[test]
    fn global_stall_is_detected_not_hung() {
        let w = World::new(2, CostModel::default(), FaultPlan::none());
        let tasks: Vec<(usize, Box<dyn RankTask>)> = (0..2)
            .map(|r| (r, Box::new(Forever) as Box<dyn RankTask>))
            .collect();
        let results = w.run_tasks(2, tasks);
        for (_, res) in results {
            assert_eq!(res, Err(Fail::Stalled));
        }
    }

    /// First poll spawns a sender task for rank 1 (carried along), then
    /// waits for its message — exercises mid-run spawning.
    struct SpawningTask {
        carried: Option<(RankCtx, Box<dyn RankTask>)>,
    }

    struct SendOnce;

    impl RankTask for SendOnce {
        fn poll(&mut self, ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
            TaskPoll::Ready(ctx.send(0, tag(), MsgData::Ctrl(99)))
        }
    }

    impl RankTask for SpawningTask {
        fn poll(&mut self, ctx: &mut RankCtx, sp: &Spawner) -> TaskPoll {
            if let Some((c, t)) = self.carried.take() {
                sp.spawn(c, t);
            }
            match ctx.try_recv(1, tag()) {
                Ok(Some(d)) => {
                    assert_eq!(d.into_ctrl(), 99);
                    TaskPoll::Ready(Ok(()))
                }
                Ok(None) => TaskPoll::Pending,
                Err(e) => TaskPoll::Ready(Err(e)),
            }
        }
    }

    #[test]
    fn tasks_spawned_mid_run_are_driven_and_reported() {
        let w = World::new(2, CostModel::default(), FaultPlan::none());
        let ctx1 = w.ctx(1);
        let t0 = SpawningTask { carried: Some((ctx1, Box::new(SendOnce) as Box<dyn RankTask>)) };
        let results = w.run_tasks(2, vec![(0, Box::new(t0) as Box<dyn RankTask>)]);
        assert_eq!(results.len(), 2);
        assert!(results.iter().all(|(_, r)| r.is_ok()));
        assert_eq!(results[1].0, 1);
    }
}
