//! `ftcaqr` — CLI for the fault-tolerant CAQR coordinator.
//!
//! Subcommands:
//! * `run`    — full (FT-)CAQR factorization with optional fault injection
//! * `tsqr`   — standalone TSQR (plain vs FT), printing the redundancy
//!   series of paper Fig 2
//! * `serve`  — multi-tenant service: run a jobs file of concurrent
//!   CAQR/TSQR jobs over one persistent scheduler pool
//! * `campaign` — seeded stochastic failure campaign: sweep MTBF x P x
//!   checkpoint interval, emit survival/makespan JSON
//! * `info`   — show the AOT artifact manifest the runtime would load
//!
//! Examples:
//! ```text
//! ftcaqr run --rows 1024 --cols 512 --block 32 --procs 8 --backend xla
//! ftcaqr run --rows 512 --cols 128 --procs 4 --kill 2@1:0 --algorithm ft
//! ftcaqr tsqr --rows 512 --block 16 --procs 8 --mode ft
//! ftcaqr serve --jobs jobs.txt --workers 8 --max-ranks 256 --batch 4
//! ```
//!
//! (Offline build: flag parsing is the shared hand-rolled
//! [`ftcaqr::config::Flags`] — the crate set has no clap. `--key value`
//! pairs only.)

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use ftcaqr::backend::Backend;
use ftcaqr::campaign::{run_campaign, CampaignConfig, IntervalChoice};
use ftcaqr::config::{Algorithm, BackendKind, Flags, RunConfig};
use ftcaqr::coordinator::{run_caqr, run_tsqr, run_tsqr_pooled, TsqrMode};
use ftcaqr::fault::{self, FaultPlan, FaultSpec, Hazard, ScheduledKill};
use ftcaqr::ft::Semantics;
use ftcaqr::linalg::Matrix;
use ftcaqr::metrics::json::JsonSink;
use ftcaqr::runtime::{Engine, Manifest};
use ftcaqr::service::{self, JobOutput, Service, ServiceConfig};
use ftcaqr::sim::CostModel;
use ftcaqr::trace::Trace;

/// `--kill rank@panel:step[:phase[:incarnation]]` — k independent kills
/// compose by repeating the flag; an incarnation of 1 aims the kill at
/// the first REBUILD replacement (a failure during recovery).
fn parse_kills(specs: &[String]) -> Result<Vec<ScheduledKill>> {
    specs.iter().map(|s| ScheduledKill::parse(s)).collect()
}

/// `--kill-pair a,b@panel:step[:phase]` — a correlated node crash taking
/// both ranks down at the same instant. Killing both members of a
/// retention pair makes the run unrecoverable (reported, not hung).
fn parse_kill_pairs(specs: &[String], group0: u32) -> Result<Vec<ScheduledKill>> {
    let mut out = Vec::new();
    for (i, s) in specs.iter().enumerate() {
        out.extend(fault::parse_kill_pair(s, group0 + i as u32)?);
    }
    Ok(out)
}

fn make_backend(kind: &str, artifacts: &PathBuf) -> Result<Arc<Backend>> {
    match kind {
        "native" => Ok(Backend::native()),
        "xla" => {
            let engine = Engine::start(artifacts)?;
            Ok(Backend::xla(engine))
        }
        other => bail!("unknown backend '{other}' (native|xla)"),
    }
}

const USAGE: &str = "\
ftcaqr — fault-tolerant communication-avoiding QR (Coti 2016)

USAGE:
  ftcaqr run  [--config f.kv] [--rows N] [--cols N] [--block B] [--procs P]
              [--grid PrxPc] [--workers W] [--par T] [--algorithm ft|plain]
              [--semantics rebuild|abort|shrink|blank]
              [--backend native|xla] [--artifacts DIR]
              [--kill rank@panel:step[:tsqr|update|bcast[:incarnation]]]...
              [--kill-pair a,b@panel:step[:phase]]...
              [--straggler rank:factor]...
              [--checkpoint-every K|auto] [--lookahead L] [--seed S]
              [--bcast auto|flat|binomial|segmented] [--seg-bytes N]
              [--trace-out trace.json] [--metrics-out metrics.prom]
              [--factors-out FILE]
  ftcaqr tsqr [--rows N] [--block B] [--procs P] [--workers W] [--par T]
              [--mode ft|plain] [--seed S]
  ftcaqr serve --jobs FILE [--workers W] [--max-ranks R] [--batch K]
              [--metrics-out metrics.prom]
  ftcaqr campaign [--rows N] [--cols N] [--block B] [--grid PrxPc]
              [--procs P1,P2,...] [--mtbf M1,M2,...]
              [--checkpoint K1,K2,auto,...] [--hazard poisson|weibull]
              [--shape K] [--node-width W] [--trials T] [--seed S]
              [--max-failures F] [--check-tol X] [--jobs J] [--out FILE]
  ftcaqr info [--artifacts DIR]

P is the number of simulated ranks (hundreds are fine: ranks are pooled
tasks, not OS threads); W bounds the worker pool (0 = core count); T
splits large GEMMs across T kernel threads (default 1 — leave serial
when the worker pool already owns the cores).
--grid PrxPc arranges the P ranks as a 2-D process grid (rows
block-distributed over grid rows, column blocks cyclic over grid
columns); Pr*Pc must equal P. Default Px1 — the 1-D layout, bitwise
identical to omitting the flag. Any shape passes the same Gram check,
and a Pr x Pc run's factors are bitwise identical to Pr x 1.
Repeat --kill for k independent failures; --kill ...:1 aims at the first
REBUILD replacement (failure during recovery); --kill-pair crashes both
ranks at once — on a retention pair this is reported as unrecoverable.
--lookahead L pipelines the panel loop: up to L+1 panels in flight per
rank (next panel's TSQR overlaps the far-trailing update). L = 0 is the
lockstep schedule; factors are bitwise identical for every L.

serve runs every job in FILE (one per line: 'caqr key=value ...' or
'tsqr key=value ...', '#' comments; kills use the same spec grammar as
--kill) concurrently over one persistent pool. --max-ranks bounds the
simulated ranks in flight (admission control); --batch packs up to K
same-shape TSQR jobs into one tree sweep. A job poisoned by a
double-failure fails alone; its neighbors complete.

--straggler rank:factor multiplies that rank's compute charges (slow,
not dead — no recovery fires). --checkpoint-every auto picks the
interval from the failure rate the fault plan implies.

--bcast picks the row-broadcast collective schedule for the panel
factors (Pc > 1 only): flat (root sends every copy), binomial (relay
tree), segmented (binomial with the bundle split into --seg-bytes
segments, pipelined through the relays). auto (default) picks by
member count and bundle size. Factors are bitwise identical across
all schedules — only the simulated communication time changes.
--factors-out FILE writes the assembled reduced matrix as raw
little-endian f32 bytes (cmp two runs to check factor identity).

--trace-out writes the run's span trace as Chrome trace_event JSON
(open in Perfetto / chrome://tracing; one track per rank, recovery
spans flagged). --metrics-out writes a Prometheus text snapshot of the
run's metrics; under serve it is rewritten after every completed job
and at exit, so scraping the file follows the service totals.
Same seed + --workers 1 reproduce the trace export byte-for-byte.

campaign sweeps an MTBF-driven stochastic failure process (per-rank, or
correlated per-node with --node-width > 1) across P and checkpoint
intervals: --trials seeded runs per cell, survival probability and
expected makespan out, plus a predicted-vs-measured validation of the
checkpoint model on failure-free baselines (--check-tol, default 0.5;
'off' records the errors without asserting).
All randomness derives from --seed; rerunning reproduces the JSON
bit-for-bit. --out FILE writes the records there (else campaign.json
under the crate root, FTCAQR_BENCH_JSON override respected).
";

fn cmd_run(flags: &Flags) -> Result<()> {
    let mut cfg = match flags.get("config") {
        Some(p) => RunConfig::from_kv(&std::fs::read_to_string(p)?)?,
        None => RunConfig::default(),
    };
    cfg.rows = flags.num("rows", cfg.rows)?;
    cfg.cols = flags.num("cols", cfg.cols)?;
    cfg.block = flags.num("block", cfg.block)?;
    cfg.procs = flags.num("procs", cfg.procs)?;
    if let Some(gspec) = flags.get("grid") {
        let (pr, pc) = ftcaqr::config::parse_grid(gspec)?;
        cfg.grid_rows = pr;
        cfg.grid_cols = pc;
    }
    cfg.workers = flags.num("workers", cfg.workers)?;
    cfg.par = flags.num("par", cfg.par)?;
    cfg.seed = flags.num("seed", cfg.seed)?;
    let every_default =
        if cfg.checkpoint_auto { None } else { Some(cfg.checkpoint_every) };
    match flags.num_or_auto("checkpoint-every", every_default)? {
        Some(k) => {
            cfg.checkpoint_every = k;
            cfg.checkpoint_auto = false;
        }
        None => cfg.checkpoint_auto = true,
    }
    for s in flags.all("straggler") {
        cfg.stragglers.push(ftcaqr::sim::parse_straggler(&s)?);
    }
    cfg.lookahead = flags.num("lookahead", cfg.lookahead)?;
    if let Some(b) = flags.get("bcast") {
        cfg.bcast = b.parse().map_err(anyhow::Error::msg)?;
    }
    cfg.seg_bytes = flags.num("seg-bytes", cfg.seg_bytes)?;
    if let Some(a) = flags.get("algorithm") {
        cfg.algorithm = a.parse::<Algorithm>().map_err(anyhow::Error::msg)?;
    }
    if let Some(s) = flags.get("semantics") {
        cfg.semantics = s.parse::<Semantics>().map_err(anyhow::Error::msg)?;
    }
    let backend_kind = flags.get("backend").unwrap_or("native").to_string();
    let artifacts = PathBuf::from(flags.get("artifacts").unwrap_or("artifacts"));
    let mut kills = parse_kills(&flags.all("kill"))?;
    kills.extend(parse_kill_pairs(&flags.all("kill-pair"), 0)?);
    if !kills.is_empty() {
        cfg.fault = FaultSpec::Schedule { kills };
    }
    cfg.backend = match backend_kind.as_str() {
        "xla" => BackendKind::Xla { artifact_dir: artifacts.clone() },
        _ => BackendKind::Native,
    };
    cfg.validate()?;

    let be = make_backend(&backend_kind, &artifacts)?;
    let fault = FaultPlan::new(cfg.fault.clone());
    let trace = Trace::new();
    let out = run_caqr(cfg.clone(), be, fault, trace.clone())?;

    println!("== ftcaqr run ==");
    let (gpr, gpc) = cfg.grid_shape();
    println!(
        "matrix {}x{}  block {}  procs {} (grid {}x{})  algorithm {}  lookahead {}  backend {}",
        cfg.rows, cfg.cols, cfg.block, cfg.procs, gpr, gpc, cfg.algorithm, cfg.lookahead,
        backend_kind
    );
    println!("metrics: {}", out.report);
    println!("store peak bytes: {}", out.store_peak_bytes);
    println!("backend flops: {}", out.backend_flops);
    println!("wallclock: {:?}", out.elapsed);
    if let Some(res) = out.residual {
        println!("gram residual: {res:.3e}  lower defect: {:.3e}", out.lower_defect);
        anyhow::ensure!(res < 1e-3, "residual too large — factorization invalid");
        println!("VERIFIED");
    }
    if let Some(p) = flags.get("trace-out") {
        std::fs::write(p, trace.to_perfetto())?;
        println!("trace written to {p} ({} spans dropped)", trace.dropped());
    }
    if let Some(p) = flags.get("metrics-out") {
        let text = ftcaqr::metrics::prom::render(&out.report, &[("job", "run")]);
        std::fs::write(p, text)?;
        println!("metrics snapshot written to {p}");
    }
    if let Some(p) = flags.get("factors-out") {
        // Raw little-endian f32 dump of the assembled reduced matrix —
        // `cmp` two runs' files to check bitwise factor identity across
        // --bcast schedules / lookahead depths / grid shapes.
        let mut bytes = Vec::with_capacity(out.reduced.data().len() * 4);
        for v in out.reduced.data() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(p, bytes)?;
        println!("factors written to {p}");
    }
    Ok(())
}

fn cmd_tsqr(flags: &Flags) -> Result<()> {
    let rows: usize = flags.num("rows", 512)?;
    let block: usize = flags.num("block", 16)?;
    let procs: usize = flags.num("procs", 8)?;
    let workers: usize = flags.num("workers", 0)?;
    let par: usize = flags.num("par", 1)?;
    let seed: u64 = flags.num("seed", 0)?;
    let mode_s = flags.get("mode").unwrap_or("ft");
    let a = Matrix::randn(rows, block, seed);
    let m = match mode_s {
        "plain" => TsqrMode::Plain,
        _ => TsqrMode::FaultTolerant,
    };
    // Backend-scoped intra-rank split (bitwise-identical at any width);
    // the old process-wide knob is gone.
    let be = Backend::native();
    be.set_par_ctx(ftcaqr::linalg::ParCtx::threads(par));
    let out = if workers > 0 {
        run_tsqr_pooled(&a, procs, m, be, CostModel::default(), workers)?
    } else {
        run_tsqr(&a, procs, m, be, CostModel::default())?
    };
    println!("== tsqr {mode_s} ==");
    println!("redundancy per step (paper Fig 2): {:?}", out.redundancy);
    println!("final holders of R: {}/{procs}", out.final_holders);
    println!("metrics: {}", out.report);
    Ok(())
}

fn cmd_serve(flags: &Flags) -> Result<()> {
    let jobs_path = flags
        .get("jobs")
        .context("serve needs --jobs FILE (one job per line)")?;
    let text = std::fs::read_to_string(jobs_path)
        .with_context(|| format!("reading jobs file '{jobs_path}'"))?;
    let specs = service::parse_jobs(&text)?;
    anyhow::ensure!(!specs.is_empty(), "jobs file '{jobs_path}' has no jobs");

    let svc = Service::new(ServiceConfig {
        workers: flags.num("workers", 0)?,
        max_inflight_ranks: flags.num("max-ranks", 256)?,
        batch_max: flags.num("batch", 4)?,
    });
    println!(
        "== ftcaqr serve: {} jobs on a {}-worker pool ==",
        specs.len(),
        svc.workers()
    );
    let metrics_out = flags.get("metrics-out").map(str::to_string);
    let t0 = std::time::Instant::now();
    // One burst enqueue: lets the batched lane pack same-shape TSQR jobs.
    let handles = svc.submit_all(specs)?;
    let mut failed = 0usize;
    for h in handles {
        let o = h.wait();
        // Periodic snapshot: rewritten as each job completes, so a
        // scraper tailing the file follows the service totals live.
        if let Some(p) = &metrics_out {
            std::fs::write(p, svc.metrics_text())?;
        }
        match &o.output {
            Ok(JobOutput::Caqr(out)) => {
                let verdict = match out.residual {
                    Some(res) if res < 1e-3 => format!("residual {res:.2e} VERIFIED"),
                    Some(res) => format!("residual {res:.2e} INVALID"),
                    None => "unverified".to_string(),
                };
                println!(
                    "job {:>4} caqr  ok  queued {:>8.3}s run {:>8.3}s  {}  [{}]",
                    o.id, o.queued_s, o.run_s, verdict, o.report
                );
            }
            Ok(JobOutput::Tsqr { r, batch_size }) => {
                println!(
                    "job {:>4} tsqr  ok  queued {:>8.3}s run {:>8.3}s  R {}x{} batch {batch_size}  [{}]",
                    o.id,
                    o.queued_s,
                    o.run_s,
                    r.rows(),
                    r.cols(),
                    o.report
                );
            }
            Err(e) => {
                failed += 1;
                let kind = if o.unrecoverable() { "UNRECOVERABLE" } else { "FAILED" };
                println!("job {:>4} {kind}: {}", o.id, e.message);
            }
        }
    }
    let totals = svc.totals();
    println!(
        "totals: {} ok, {} failed in {:.3}s  [{}]",
        totals.jobs_ok,
        totals.jobs_failed,
        t0.elapsed().as_secs_f64(),
        totals.report
    );
    anyhow::ensure!(failed == totals.jobs_failed as usize, "outcome accounting mismatch");
    if let Some(p) = &metrics_out {
        std::fs::write(p, svc.metrics_text())?;
        println!("metrics snapshot written to {p}");
    }
    Ok(())
}

/// Parse a comma-separated sweep list (`--procs 2,4,8`).
fn parse_list<T: std::str::FromStr>(s: &str, what: &str) -> Result<Vec<T>>
where
    T::Err: std::fmt::Display,
{
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| p.parse::<T>().map_err(|e| anyhow::anyhow!("bad {what} '{p}': {e}")))
        .collect()
}

fn cmd_campaign(flags: &Flags) -> Result<()> {
    let base = {
        let d = RunConfig::default();
        let mut b = RunConfig {
            rows: flags.num("rows", d.rows)?,
            cols: flags.num("cols", d.cols)?,
            block: flags.num("block", d.block)?,
            ..d
        };
        // Cells whose proc count does not match Pr*Pc fall back to the
        // auto (procs x 1) grid — see campaign::cell_cfg.
        if let Some(gspec) = flags.get("grid") {
            let (pr, pc) = ftcaqr::config::parse_grid(gspec)?;
            b.grid_rows = pr;
            b.grid_cols = pc;
        }
        b
    };
    let hazard = match flags.get("hazard").unwrap_or("poisson") {
        "poisson" => Hazard::Poisson,
        "weibull" => Hazard::Weibull { shape: flags.num("shape", 0.7)? },
        other => bail!("unknown hazard '{other}' (poisson|weibull)"),
    };
    let check_tol = match flags.get("check-tol") {
        None => Some(0.5),
        Some("off") => None,
        Some(v) => Some(
            v.parse::<f64>().map_err(|e| anyhow::anyhow!("bad --check-tol '{v}': {e}"))?,
        ),
    };
    let c = CampaignConfig {
        base,
        procs: match flags.get("procs") {
            Some(s) => parse_list(s, "procs")?,
            None => vec![4],
        },
        mtbf_panels: match flags.get("mtbf") {
            Some(s) => parse_list(s, "mtbf")?,
            None => vec![8.0],
        },
        intervals: match flags.get("checkpoint") {
            Some(s) => parse_list(s, "checkpoint interval")?,
            None => vec![IntervalChoice::Fixed(0)],
        },
        hazard,
        node_width: flags.num("node-width", 1)?,
        trials: flags.num("trials", 3)?,
        max_failures: flags.num("max-failures", 16)?,
        seed: flags.num("seed", 0)?,
        check_tol,
        jobs: flags.num("jobs", 0)?,
    };

    let out = run_campaign(&c)?;

    println!(
        "== ftcaqr campaign: {}x{} block {}  {} cells x {} trials  seed {} ==",
        c.base.rows,
        c.base.cols,
        c.base.block,
        out.cells.len(),
        c.trials,
        c.seed
    );
    println!("-- checkpoint model (failure-free baselines) --");
    for b in &out.baselines {
        println!(
            "procs {:>4} interval {:>3}: measured {:>10.4e}s  predicted {:>10.4e}s  rel err {:>5.1}%",
            b.procs,
            b.interval,
            b.measured,
            b.predicted,
            100.0 * b.rel_err
        );
    }
    println!("-- sweep cells --");
    for cell in &out.cells {
        let auto = if cell.auto_interval { " (auto)" } else { "" };
        println!(
            "mtbf {:>7.2} procs {:>4} interval {:>3}{auto}: survived {}/{}  \
             E[makespan] {:>10.4e}s  clean {:>10.4e}s  kills {}  recoveries {}",
            cell.mtbf_panels,
            cell.procs,
            cell.interval,
            cell.survived,
            cell.trials,
            cell.expected_makespan,
            cell.clean_makespan,
            cell.kills_scheduled,
            cell.recoveries
        );
    }

    let mut sink = JsonSink::new();
    out.emit(&c, &mut sink);
    match flags.get("out") {
        Some(p) => {
            sink.write_to(std::path::Path::new(p))
                .with_context(|| format!("writing campaign JSON to '{p}'"))?;
            println!("{} JSON records -> {p}", sink.len());
        }
        None => {
            sink.finish("campaign");
        }
    }
    Ok(())
}

fn cmd_info(flags: &Flags) -> Result<()> {
    let artifacts = PathBuf::from(flags.get("artifacts").unwrap_or("artifacts"));
    let m = Manifest::load(&artifacts)?;
    println!("manifest: profile={} jax={} tile={}", m.profile, m.jax_version, m.tile);
    for e in &m.artifacts {
        println!("  {:<34} in={:?} out={:?}", e.name(), e.inputs, e.outputs);
    }
    println!("{} artifacts", m.artifacts.len());
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "tsqr" => cmd_tsqr(&flags),
        "serve" => cmd_serve(&flags),
        "campaign" => cmd_campaign(&flags),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
}
