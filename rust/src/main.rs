//! `ftcaqr` — CLI for the fault-tolerant CAQR coordinator.
//!
//! Subcommands:
//! * `run`    — full (FT-)CAQR factorization with optional fault injection
//! * `tsqr`   — standalone TSQR (plain vs FT), printing the redundancy
//!   series of paper Fig 2
//! * `info`   — show the AOT artifact manifest the runtime would load
//!
//! Examples:
//! ```text
//! ftcaqr run --rows 1024 --cols 512 --block 32 --procs 8 --backend xla
//! ftcaqr run --rows 512 --cols 128 --procs 4 --kill 2@1:0 --algorithm ft
//! ftcaqr tsqr --rows 512 --block 16 --procs 8 --mode ft
//! ```
//!
//! (Offline build: flag parsing is hand-rolled — the crate set has no
//! clap. `--key value` pairs only.)

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, BackendKind, RunConfig};
use ftcaqr::coordinator::{run_caqr, run_tsqr, run_tsqr_pooled, TsqrMode};
use ftcaqr::fault::{FaultPlan, FaultSpec, Phase, ScheduledKill};
use ftcaqr::ft::Semantics;
use ftcaqr::linalg::Matrix;
use ftcaqr::runtime::{Engine, Manifest};
use ftcaqr::sim::CostModel;
use ftcaqr::trace::Trace;

/// Minimal `--key value` flag parser. Repeated keys accumulate.
struct Flags {
    values: HashMap<String, Vec<String>>,
}

impl Flags {
    fn parse(args: &[String]) -> Result<Self> {
        let mut values: HashMap<String, Vec<String>> = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected argument '{a}' (flags are --key value)");
            };
            let val = args
                .get(i + 1)
                .with_context(|| format!("--{key} needs a value"))?;
            values.entry(key.to_string()).or_default().push(val.clone());
            i += 2;
        }
        Ok(Self { values })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).and_then(|v| v.last()).map(String::as_str)
    }

    fn all(&self, key: &str) -> Vec<String> {
        self.values.get(key).cloned().unwrap_or_default()
    }

    fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
            None => Ok(default),
        }
    }
}

/// Parse `panel:step[:tsqr|update[:incarnation]]`.
fn parse_site(spec: &str, rest: &str) -> Result<(usize, usize, Phase, Option<u32>)> {
    let mut it = rest.split(':');
    let panel = it
        .next()
        .filter(|p| !p.is_empty())
        .with_context(|| format!("kill spec '{spec}': missing panel"))?
        .parse()?;
    let step = it
        .next()
        .with_context(|| format!("kill spec '{spec}': missing step"))?
        .parse()?;
    let phase = match it.next() {
        None | Some("update") => Phase::Update,
        Some("tsqr") => Phase::Tsqr,
        Some(other) => bail!("kill spec '{spec}': unknown phase '{other}' (tsqr|update)"),
    };
    let incarnation = it.next().map(str::parse).transpose()?;
    if it.next().is_some() {
        bail!("kill spec '{spec}': too many ':' fields");
    }
    Ok((panel, step, phase, incarnation))
}

/// `--kill rank@panel:step[:phase[:incarnation]]` — k independent kills
/// compose by repeating the flag; an incarnation of 1 aims the kill at
/// the first REBUILD replacement (a failure during recovery).
fn parse_kills(specs: &[String]) -> Result<Vec<ScheduledKill>> {
    specs
        .iter()
        .map(|s| {
            let (rank, rest) = s
                .split_once('@')
                .with_context(|| format!("kill spec '{s}' must be rank@panel:step[...]"))?;
            let (panel, step, phase, inc) = parse_site(s, rest)?;
            let mut k = ScheduledKill::new(rank.parse()?, panel, step, phase);
            if let Some(i) = inc {
                k = k.at_incarnation(i);
            }
            Ok(k)
        })
        .collect()
}

/// `--kill-pair a,b@panel:step[:phase]` — a correlated node crash taking
/// both ranks down at the same instant. Killing both members of a
/// retention pair makes the run unrecoverable (reported, not hung).
fn parse_kill_pairs(specs: &[String], group0: u32) -> Result<Vec<ScheduledKill>> {
    let mut out = Vec::new();
    for (i, s) in specs.iter().enumerate() {
        let (ranks, rest) = s
            .split_once('@')
            .with_context(|| format!("kill-pair spec '{s}' must be a,b@panel:step[...]"))?;
        let (ra, rb) = ranks
            .split_once(',')
            .with_context(|| format!("kill-pair spec '{s}': ranks must be a,b"))?;
        let (panel, step, phase, _) = parse_site(s, rest)?;
        let g = group0 + i as u32;
        out.push(ScheduledKill::new(ra.parse()?, panel, step, phase).in_group(g));
        out.push(ScheduledKill::new(rb.parse()?, panel, step, phase).in_group(g));
    }
    Ok(out)
}

fn make_backend(kind: &str, artifacts: &PathBuf) -> Result<Arc<Backend>> {
    match kind {
        "native" => Ok(Backend::native()),
        "xla" => {
            let engine = Engine::start(artifacts)?;
            Ok(Backend::xla(engine))
        }
        other => bail!("unknown backend '{other}' (native|xla)"),
    }
}

const USAGE: &str = "\
ftcaqr — fault-tolerant communication-avoiding QR (Coti 2016)

USAGE:
  ftcaqr run  [--config f.kv] [--rows N] [--cols N] [--block B] [--procs P]
              [--workers W] [--par T] [--algorithm ft|plain]
              [--semantics rebuild|abort|shrink|blank]
              [--backend native|xla] [--artifacts DIR]
              [--kill rank@panel:step[:tsqr|update[:incarnation]]]...
              [--kill-pair a,b@panel:step[:phase]]...
              [--checkpoint-every K] [--seed S] [--trace-out trace.json]
  ftcaqr tsqr [--rows N] [--block B] [--procs P] [--workers W] [--par T]
              [--mode ft|plain] [--seed S]
  ftcaqr info [--artifacts DIR]

P is the number of simulated ranks (hundreds are fine: ranks are pooled
tasks, not OS threads); W bounds the worker pool (0 = core count); T
splits large GEMMs across T kernel threads (default 1 — leave serial
when the worker pool already owns the cores).
Repeat --kill for k independent failures; --kill ...:1 aims at the first
REBUILD replacement (failure during recovery); --kill-pair crashes both
ranks at once — on a retention pair this is reported as unrecoverable.
";

fn cmd_run(flags: &Flags) -> Result<()> {
    let mut cfg = match flags.get("config") {
        Some(p) => RunConfig::from_kv(&std::fs::read_to_string(p)?)?,
        None => RunConfig::default(),
    };
    cfg.rows = flags.num("rows", cfg.rows)?;
    cfg.cols = flags.num("cols", cfg.cols)?;
    cfg.block = flags.num("block", cfg.block)?;
    cfg.procs = flags.num("procs", cfg.procs)?;
    cfg.workers = flags.num("workers", cfg.workers)?;
    cfg.par = flags.num("par", cfg.par)?;
    cfg.seed = flags.num("seed", cfg.seed)?;
    cfg.checkpoint_every = flags.num("checkpoint-every", cfg.checkpoint_every)?;
    if let Some(a) = flags.get("algorithm") {
        cfg.algorithm = a.parse::<Algorithm>().map_err(anyhow::Error::msg)?;
    }
    if let Some(s) = flags.get("semantics") {
        cfg.semantics = s.parse::<Semantics>().map_err(anyhow::Error::msg)?;
    }
    let backend_kind = flags.get("backend").unwrap_or("native").to_string();
    let artifacts = PathBuf::from(flags.get("artifacts").unwrap_or("artifacts"));
    let mut kills = parse_kills(&flags.all("kill"))?;
    kills.extend(parse_kill_pairs(&flags.all("kill-pair"), 0)?);
    if !kills.is_empty() {
        cfg.fault = FaultSpec::Schedule { kills };
    }
    cfg.backend = match backend_kind.as_str() {
        "xla" => BackendKind::Xla { artifact_dir: artifacts.clone() },
        _ => BackendKind::Native,
    };
    cfg.validate()?;

    let be = make_backend(&backend_kind, &artifacts)?;
    let fault = FaultPlan::new(cfg.fault.clone());
    let trace = Trace::new();
    let out = run_caqr(cfg.clone(), be, fault, trace.clone())?;

    println!("== ftcaqr run ==");
    println!(
        "matrix {}x{}  block {}  procs {}  algorithm {}  backend {}",
        cfg.rows, cfg.cols, cfg.block, cfg.procs, cfg.algorithm, backend_kind
    );
    println!("metrics: {}", out.report);
    println!("store peak bytes: {}", out.store_peak_bytes);
    println!("backend flops: {}", out.backend_flops);
    println!("wallclock: {:?}", out.elapsed);
    if let Some(res) = out.residual {
        println!("gram residual: {res:.3e}  lower defect: {:.3e}", out.lower_defect);
        anyhow::ensure!(res < 1e-3, "residual too large — factorization invalid");
        println!("VERIFIED");
    }
    if let Some(p) = flags.get("trace-out") {
        std::fs::write(p, trace.to_json())?;
        println!("trace written to {p}");
    }
    Ok(())
}

fn cmd_tsqr(flags: &Flags) -> Result<()> {
    let rows: usize = flags.num("rows", 512)?;
    let block: usize = flags.num("block", 16)?;
    let procs: usize = flags.num("procs", 8)?;
    let workers: usize = flags.num("workers", 0)?;
    ftcaqr::linalg::set_par_threads(flags.num("par", 1)?);
    let seed: u64 = flags.num("seed", 0)?;
    let mode_s = flags.get("mode").unwrap_or("ft");
    let a = Matrix::randn(rows, block, seed);
    let m = match mode_s {
        "plain" => TsqrMode::Plain,
        _ => TsqrMode::FaultTolerant,
    };
    let out = if workers > 0 {
        run_tsqr_pooled(&a, procs, m, Backend::native(), CostModel::default(), workers)?
    } else {
        run_tsqr(&a, procs, m, Backend::native(), CostModel::default())?
    };
    println!("== tsqr {mode_s} ==");
    println!("redundancy per step (paper Fig 2): {:?}", out.redundancy);
    println!("final holders of R: {}/{procs}", out.final_holders);
    println!("metrics: {}", out.report);
    Ok(())
}

fn cmd_info(flags: &Flags) -> Result<()> {
    let artifacts = PathBuf::from(flags.get("artifacts").unwrap_or("artifacts"));
    let m = Manifest::load(&artifacts)?;
    println!("manifest: profile={} jax={} tile={}", m.profile, m.jax_version, m.tile);
    for e in &m.artifacts {
        println!("  {:<34} in={:?} out={:?}", e.name(), e.inputs, e.outputs);
    }
    println!("{} artifacts", m.artifacts.len());
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "tsqr" => cmd_tsqr(&flags),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    }
}
