//! Stochastic failure campaigns: sweep failure rate x P x checkpoint
//! interval across the CAQR driver, measure survival probability and
//! expected makespan, and validate [`crate::checkpoint::CheckpointModel`]
//! against the measured failure-free runs.
//!
//! One campaign is reproducible from one seed: every trial's input
//! matrix and kill schedule derive from `(seed, cell, trial)` through
//! splitmix streams, the stochastic generators compile to concrete
//! schedules before any rank runs ([`StochasticSpec::kills`]), and every
//! trial's simulated world is driven by a single worker so logical
//! clocks — and therefore makespans — are bit-identical across runs.
//! Wall-clock parallelism comes from running *trials* concurrently on OS
//! threads; results land in a pre-sized table by deterministic index, so
//! the emitted JSON never depends on completion order.
//!
//! Trial seeds are shared across the checkpoint-interval axis: the same
//! (mtbf, procs, trial) triple sees the same matrix and the same failure
//! realization at every interval, so interval comparisons are paired
//! rather than confounded by fresh randomness.
//!
//! Because kills are random and plentiful, a campaign doubles as a
//! randomized soak test of the recovery protocol: any trial that ends in
//! an error other than the documented unrecoverable cases, or survives
//! with a bad residual, is a protocol bug surfaced by `--seed` replay.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::{ensure, Context, Result};

use crate::backend::Backend;
use crate::checkpoint::{auto_checkpoint_interval, failure_rate_estimate};
use crate::config::RunConfig;
use crate::coordinator::run_caqr;
use crate::fault::{FaultPlan, FaultSpec, Hazard, ScheduledKill, StochasticSpec};
use crate::metrics::json::{JsonSink, JsonVal};
use crate::metrics::Report;
use crate::service::seed_for;
use crate::trace::Trace;

/// Residual threshold above which a "completed" trial is counted as not
/// survived (the factorization came back numerically wrong — a protocol
/// bug, not a tolerable outcome).
pub const RESIDUAL_TOL: f32 = 1e-3;

/// One checkpoint-interval choice of a sweep: a concrete interval in
/// panels (0 = off) or `auto` (resolved per (mtbf, procs) cell from the
/// materialized failure rate).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IntervalChoice {
    /// Fixed interval in panels; 0 disables checkpointing.
    Fixed(usize),
    /// Resolve via [`crate::checkpoint::auto_checkpoint_interval`].
    Auto,
}

impl std::str::FromStr for IntervalChoice {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        if s == "auto" {
            Ok(IntervalChoice::Auto)
        } else {
            Ok(IntervalChoice::Fixed(
                s.parse().with_context(|| format!("bad checkpoint interval '{s}'"))?,
            ))
        }
    }
}

/// Full description of one campaign sweep.
#[derive(Clone, Debug)]
pub struct CampaignConfig {
    /// Shape/cost template for every cell; `procs`, `checkpoint_every`,
    /// `seed` and `fault` are overridden per trial, and `workers` is
    /// forced to 1 (see the module docs on determinism).
    pub base: RunConfig,
    /// Process counts to sweep.
    pub procs: Vec<usize>,
    /// MTBF values (panels per failure per unit) to sweep.
    pub mtbf_panels: Vec<f64>,
    /// Checkpoint intervals to sweep.
    pub intervals: Vec<IntervalChoice>,
    /// Inter-arrival law of the failure process.
    pub hazard: Hazard,
    /// Ranks per correlated failure unit (1 = independent ranks).
    pub node_width: usize,
    /// Trials per cell.
    pub trials: usize,
    /// Kill-schedule cap per trial.
    pub max_failures: usize,
    /// Master seed; everything else derives from it.
    pub seed: u64,
    /// Relative-error tolerance for the predicted-vs-measured makespan
    /// check on the failure-free checkpointed baselines; `None` records
    /// the errors without asserting.
    pub check_tol: Option<f64>,
    /// OS threads running trials concurrently (0 = available cores).
    pub jobs: usize,
}

impl Default for CampaignConfig {
    fn default() -> Self {
        Self {
            base: RunConfig::default(),
            procs: vec![4],
            mtbf_panels: vec![8.0],
            intervals: vec![IntervalChoice::Fixed(0)],
            hazard: Hazard::Poisson,
            node_width: 1,
            trials: 3,
            max_failures: 16,
            seed: 0,
            check_tol: Some(0.5),
            jobs: 0,
        }
    }
}

/// Outcome of one trial (one seeded run under one kill schedule).
#[derive(Clone, Debug)]
pub struct TrialResult {
    /// MTBF of the cell this trial belongs to.
    pub mtbf_panels: f64,
    /// Process count of the cell.
    pub procs: usize,
    /// Resolved checkpoint interval the trial ran with.
    pub interval: usize,
    /// Whether the interval came from `auto` resolution.
    pub auto_interval: bool,
    /// Trial index within the cell.
    pub trial: usize,
    /// Input-matrix seed.
    pub matrix_seed: u64,
    /// Kill-schedule seed.
    pub fault_seed: u64,
    /// The materialized kill schedule.
    pub kills: Vec<ScheduledKill>,
    /// Completed with an acceptable residual.
    pub survived: bool,
    /// Simulated makespan (critical path, seconds); NaN when the run
    /// died unrecoverably.
    pub makespan: f64,
    /// Failures injected (from the run's metrics; 0 when it died).
    pub failures: u64,
    /// Recoveries completed (0 when it died).
    pub recoveries: u64,
    /// Failure detections (revival claims) in the trial.
    pub detects: u64,
    /// Summed time-to-detect over the trial's detections, seconds.
    pub detect_s: f64,
    /// REBUILD replacements that finished replaying.
    pub rebuilds: u64,
    /// Summed time-to-rebuild over the trial's rebuilds, seconds.
    pub rebuild_s: f64,
    /// Retention-store bytes high-water for the trial.
    pub store_peak_bytes: u64,
    /// Checkpoint payload bytes exchanged in the trial.
    pub checkpoint_bytes: u64,
    /// Why the trial did not survive, when it didn't.
    pub error: Option<String>,
}

/// Failure-free reference for one (procs, interval) pair, and the
/// checkpoint-model validation attached to it.
#[derive(Clone, Copy, Debug)]
pub struct BaselineResult {
    /// Process count.
    pub procs: usize,
    /// Checkpoint interval (0 = the clean no-checkpoint reference).
    pub interval: usize,
    /// Measured failure-free makespan at this interval.
    pub measured: f64,
    /// Model-predicted makespan: the interval-0 measurement plus the
    /// predicted checkpoint-exchange overhead.
    pub predicted: f64,
    /// `|measured - predicted| / measured`.
    pub rel_err: f64,
}

/// Aggregated outcome of one sweep cell (mtbf x procs x interval).
#[derive(Clone, Debug)]
pub struct CellResult {
    /// MTBF of the cell.
    pub mtbf_panels: f64,
    /// Process count.
    pub procs: usize,
    /// Resolved checkpoint interval.
    pub interval: usize,
    /// Whether the interval came from `auto`.
    pub auto_interval: bool,
    /// Trials run.
    pub trials: usize,
    /// Trials that completed with an acceptable residual.
    pub survived: usize,
    /// Total kills scheduled across the cell's trials.
    pub kills_scheduled: usize,
    /// Total failures injected across surviving trials.
    pub failures: u64,
    /// Total recoveries across surviving trials.
    pub recoveries: u64,
    /// Expected makespan: mean over surviving trials (NaN if none).
    pub expected_makespan: f64,
    /// The cell's failure-free reference makespan.
    pub clean_makespan: f64,
    /// Expected-vs-clean makespan overhead, percent (NaN if no
    /// survivors): the cost of the cell's failures plus recoveries on
    /// top of the failure-free reference.
    pub overhead_pct: f64,
    /// Failure detections across surviving trials.
    pub detects: u64,
    /// Mean time-to-detect across surviving trials, seconds (NaN if no
    /// detections).
    pub detect_s_mean: f64,
    /// REBUILD replacements completed across surviving trials.
    pub rebuilds: u64,
    /// Mean time-to-rebuild across surviving trials, seconds (NaN if no
    /// rebuilds).
    pub rebuild_s_mean: f64,
    /// Max retention-store high-water over surviving trials, bytes.
    pub store_peak_bytes: u64,
    /// Total checkpoint payload bytes over surviving trials.
    pub checkpoint_bytes: u64,
}

/// Everything a campaign produced.
#[derive(Clone, Debug)]
pub struct CampaignOutcome {
    /// Failure-free references, one per distinct (procs, interval).
    pub baselines: Vec<BaselineResult>,
    /// Aggregates, one per sweep cell.
    pub cells: Vec<CellResult>,
    /// Every trial, in deterministic cell-major order.
    pub trials: Vec<TrialResult>,
}

/// The run shape of one cell: the base config with the cell's procs and
/// interval, faults cleared and the world forced single-worker.
/// Does sweeping to `procs` force the base `--grid` back to the auto
/// (`procs x 1`) shape? A fixed grid only fits its own process count.
/// The fallback is recorded in the campaign's `meta` JSON record
/// (`grid_reset_procs`) so a mismatched `--grid` is visible in the
/// artifact rather than silently rewritten.
fn grid_resets_at(c: &CampaignConfig, procs: usize) -> bool {
    let mut cfg = c.base.clone();
    cfg.procs = procs;
    let (pr, pc) = cfg.grid_shape();
    pr * pc != procs
}

fn cell_cfg(c: &CampaignConfig, procs: usize, interval: usize) -> RunConfig {
    let mut cfg = c.base.clone();
    cfg.procs = procs;
    if grid_resets_at(c, procs) {
        cfg.grid_rows = 0;
        cfg.grid_cols = 0;
    }
    cfg.checkpoint_every = interval;
    cfg.checkpoint_auto = false;
    cfg.fault = FaultSpec::None;
    // One worker per trial: REBUILD's revive clock and gate arbitration
    // depend on which detector acts first, so wider pools would make
    // makespans run-to-run noisy. Parallelism lives across trials.
    cfg.workers = 1;
    cfg
}

/// Predicted critical-path overhead of checkpointing at `cfg`'s interval:
/// per checkpointed panel, one state exchange (latency + wire + CPU
/// overhead) — counted only when the highest rank (always a participant,
/// and the longest-lived) actually pairs up under the panel's geometry.
fn predicted_checkpoint_overhead(cfg: &RunConfig) -> f64 {
    let every = cfg.checkpoint_every;
    if every == 0 {
        return 0.0;
    }
    let state_bytes = (cfg.local_rows() * cfg.cols * 4) as f64;
    let wire = if cfg.cost.dual_channel {
        state_bytes * cfg.cost.beta
    } else {
        2.0 * state_bytes * cfg.cost.beta
    };
    let per_exchange = cfg.cost.alpha + wire + cfg.cost.o;
    let m_local = cfg.local_rows();
    // Checkpoint pairs run down grid columns, so the tree extent is the
    // grid-row count (== procs on the default `Px1` grid).
    let pr = cfg.grid_shape().0;
    let mut total = 0.0;
    for k in 0..cfg.panels() {
        if (k + 1) % every != 0 {
            continue;
        }
        let owner_row = k * cfg.block / m_local;
        let q = pr - owner_row;
        let idx_last = pr - 1 - owner_row;
        if (idx_last ^ 1) < q {
            total += per_exchange;
        }
    }
    total
}

/// Run `n` jobs on up to `threads` OS threads, preserving index order.
fn run_indexed<T: Send, F: Fn(usize) -> T + Sync>(n: usize, threads: usize, f: F) -> Vec<T> {
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let width = threads.clamp(1, n.max(1));
    std::thread::scope(|s| {
        for _ in 0..width {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *slots[i].lock().unwrap() = Some(f(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("indexed job completed"))
        .collect()
}

/// One trial's measured outcome: survival, makespan, the run's full
/// metrics [`Report`], and the reason it died when it did.
struct TrialRun {
    survived: bool,
    makespan: f64,
    report: Report,
    error: Option<String>,
}

/// Run one seeded trial under a pre-materialized kill schedule.
fn run_trial(cfg: RunConfig, kills: Vec<ScheduledKill>) -> TrialRun {
    let fault = FaultPlan::new(FaultSpec::Schedule { kills });
    match run_caqr(cfg, Backend::native(), fault, Trace::disabled()) {
        Ok(out) => {
            let makespan = out.report.critical_path;
            let (survived, error) = match out.residual {
                Some(r) if r >= RESIDUAL_TOL => (false, Some(format!("bad residual {r:e}"))),
                _ => (true, None),
            };
            TrialRun { survived, makespan, report: out.report, error }
        }
        Err(e) => TrialRun {
            survived: false,
            makespan: f64::NAN,
            report: Report::default(),
            error: Some(format!("{e:#}")),
        },
    }
}

/// Execute a campaign: materialize every schedule, measure the
/// failure-free references, run every trial, aggregate, and (when
/// `check_tol` is set) assert the checkpoint model's predicted makespan
/// against the measured baselines.
pub fn run_campaign(c: &CampaignConfig) -> Result<CampaignOutcome> {
    ensure!(!c.procs.is_empty(), "campaign needs at least one procs value");
    ensure!(!c.mtbf_panels.is_empty(), "campaign needs at least one mtbf value");
    ensure!(!c.intervals.is_empty(), "campaign needs at least one checkpoint interval");
    ensure!(c.trials >= 1, "campaign needs at least one trial per cell");
    ensure!(c.node_width >= 1, "node width must be >= 1");
    for &m in &c.mtbf_panels {
        ensure!(m.is_finite() && m > 0.0, "mtbf must be finite and positive, got {m}");
    }
    for &p in &c.procs {
        cell_cfg(c, p, 0).validate().with_context(|| format!("procs {p}"))?;
    }
    let panels = c.base.panels();
    let jobs = if c.jobs > 0 {
        c.jobs
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    };

    // Materialize every (mtbf, procs) pair's trial schedules up front.
    // Trial seeds depend only on the pair and the trial index, so the
    // interval axis reuses identical failure realizations (paired
    // comparisons), and `auto` resolution can read the realized rate.
    struct Pair {
        mtbf: f64,
        procs: usize,
        // per trial: (matrix_seed, fault_seed, kills)
        trials: Vec<(u64, u64, Vec<ScheduledKill>)>,
        rate: f64,
    }
    let mut pairs: Vec<Pair> = Vec::new();
    for &mtbf in &c.mtbf_panels {
        for &procs in &c.procs {
            let pair_idx = pairs.len() as u64;
            let mut trials = Vec::with_capacity(c.trials);
            let mut total_kills = 0usize;
            for t in 0..c.trials {
                let stream = pair_idx * c.trials as u64 + t as u64;
                let matrix_seed = seed_for(c.seed, 2 * stream);
                let fault_seed = seed_for(c.seed, 2 * stream + 1);
                let spec = StochasticSpec {
                    hazard: c.hazard,
                    mtbf_panels: mtbf,
                    node_width: c.node_width,
                    max_failures: c.max_failures,
                    seed: fault_seed,
                };
                let kills = spec.kills(procs, panels);
                total_kills += kills.len();
                trials.push((matrix_seed, fault_seed, kills));
            }
            let rate = total_kills as f64 / (c.trials * panels.max(1)) as f64;
            pairs.push(Pair { mtbf, procs, trials, rate });
        }
    }

    // Resolve the interval axis per pair (auto depends on the pair's
    // realized failure rate) and collect the distinct (procs, interval)
    // baselines the sweep needs — always including interval 0, the
    // clean reference every prediction builds on.
    struct Cell {
        pair: usize,
        interval: usize,
        auto_interval: bool,
    }
    let mut cells: Vec<Cell> = Vec::new();
    let mut baseline_keys: std::collections::BTreeSet<(usize, usize)> =
        c.procs.iter().map(|&p| (p, 0)).collect();
    for (pi, pair) in pairs.iter().enumerate() {
        for &ic in &c.intervals {
            let (interval, auto_interval) = match ic {
                IntervalChoice::Fixed(k) => (k, false),
                IntervalChoice::Auto => {
                    (auto_checkpoint_interval(&cell_cfg(c, pair.procs, 0), pair.rate), true)
                }
            };
            baseline_keys.insert((pair.procs, interval));
            cells.push(Cell { pair: pi, interval, auto_interval });
        }
    }

    // Failure-free references, in parallel across (procs, interval).
    let keys: Vec<(usize, usize)> = baseline_keys.into_iter().collect();
    let measured: Vec<f64> = run_indexed(keys.len(), jobs, |i| {
        let (procs, interval) = keys[i];
        let run = run_trial(cell_cfg(c, procs, interval), Vec::new());
        debug_assert!(run.error.is_none(), "failure-free baseline died: {:?}", run.error);
        run.makespan
    });
    let clean0: BTreeMap<usize, f64> = keys
        .iter()
        .zip(&measured)
        .filter(|((_, interval), _)| *interval == 0)
        .map(|(&(procs, _), &m)| (procs, m))
        .collect();
    let mut baselines = Vec::with_capacity(keys.len());
    let mut baseline_by_key: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    for (&(procs, interval), &m) in keys.iter().zip(&measured) {
        let predicted =
            clean0[&procs] + predicted_checkpoint_overhead(&cell_cfg(c, procs, interval));
        let rel_err = (m - predicted).abs() / m.max(f64::MIN_POSITIVE);
        baselines.push(BaselineResult { procs, interval, measured: m, predicted, rel_err });
        baseline_by_key.insert((procs, interval), m);
    }

    // Every trial of every cell, flattened into one deterministic list.
    let trial_results: Vec<TrialResult> =
        run_indexed(cells.len() * c.trials, jobs, |i| {
            let cell = &cells[i / c.trials];
            let t = i % c.trials;
            let pair = &pairs[cell.pair];
            let (matrix_seed, fault_seed, kills) = &pair.trials[t];
            let (matrix_seed, fault_seed) = (*matrix_seed, *fault_seed);
            let mut cfg = cell_cfg(c, pair.procs, cell.interval);
            cfg.seed = matrix_seed;
            let run = run_trial(cfg, kills.clone());
            TrialResult {
                mtbf_panels: pair.mtbf,
                procs: pair.procs,
                interval: cell.interval,
                auto_interval: cell.auto_interval,
                trial: t,
                matrix_seed,
                fault_seed,
                kills: kills.clone(),
                survived: run.survived,
                makespan: run.makespan,
                failures: run.report.failures,
                recoveries: run.report.recoveries,
                detects: run.report.detects,
                detect_s: run.report.detect_s_total,
                rebuilds: run.report.rebuilds,
                rebuild_s: run.report.rebuild_s_total,
                store_peak_bytes: run.report.store_peak_bytes,
                checkpoint_bytes: run.report.checkpoint_bytes,
                error: run.error,
            }
        });

    // Aggregate cells from their trials.
    let mut cell_results = Vec::with_capacity(cells.len());
    for (ci, cell) in cells.iter().enumerate() {
        let pair = &pairs[cell.pair];
        let trials = &trial_results[ci * c.trials..(ci + 1) * c.trials];
        let survivors: Vec<&TrialResult> = trials.iter().filter(|t| t.survived).collect();
        let expected_makespan = if survivors.is_empty() {
            f64::NAN
        } else {
            survivors.iter().map(|t| t.makespan).sum::<f64>() / survivors.len() as f64
        };
        let clean_makespan = baseline_by_key[&(pair.procs, cell.interval)];
        let overhead_pct = if expected_makespan.is_finite() && clean_makespan > 0.0 {
            (expected_makespan / clean_makespan - 1.0) * 100.0
        } else {
            f64::NAN
        };
        let detects: u64 = survivors.iter().map(|t| t.detects).sum();
        let detect_s: f64 = survivors.iter().map(|t| t.detect_s).sum();
        let rebuilds: u64 = survivors.iter().map(|t| t.rebuilds).sum();
        let rebuild_s: f64 = survivors.iter().map(|t| t.rebuild_s).sum();
        cell_results.push(CellResult {
            mtbf_panels: pair.mtbf,
            procs: pair.procs,
            interval: cell.interval,
            auto_interval: cell.auto_interval,
            trials: c.trials,
            survived: survivors.len(),
            kills_scheduled: trials.iter().map(|t| t.kills.len()).sum(),
            failures: survivors.iter().map(|t| t.failures).sum(),
            recoveries: survivors.iter().map(|t| t.recoveries).sum(),
            expected_makespan,
            clean_makespan,
            overhead_pct,
            detects,
            detect_s_mean: if detects == 0 { f64::NAN } else { detect_s / detects as f64 },
            rebuilds,
            rebuild_s_mean: if rebuilds == 0 { f64::NAN } else { rebuild_s / rebuilds as f64 },
            store_peak_bytes: survivors.iter().map(|t| t.store_peak_bytes).max().unwrap_or(0),
            checkpoint_bytes: survivors.iter().map(|t| t.checkpoint_bytes).sum(),
        });
    }

    // Model validation: predicted vs measured on the failure-free
    // checkpointed references, within the documented tolerance.
    if let Some(tol) = c.check_tol {
        for b in &baselines {
            ensure!(
                b.rel_err <= tol,
                "checkpoint model validation failed: procs {} interval {}: \
                 measured {:.3e} vs predicted {:.3e} (rel err {:.3} > tol {tol})",
                b.procs,
                b.interval,
                b.measured,
                b.predicted,
                b.rel_err
            );
        }
    }

    Ok(CampaignOutcome { baselines, cells: cell_results, trials: trial_results })
}

/// Serialize a trial's kill schedule as one compact string
/// (`;`-separated [`ScheduledKill::label`]s).
pub fn kills_label(kills: &[ScheduledKill]) -> String {
    kills.iter().map(ScheduledKill::label).collect::<Vec<_>>().join(";")
}

impl CampaignOutcome {
    /// Emit the campaign as flat JSON records (schema documented in
    /// DESIGN.md): one `meta` record, then `baseline`, `cell` and
    /// `trial` records in deterministic order.
    pub fn emit(&self, c: &CampaignConfig, sink: &mut JsonSink) {
        // Sweep procs values whose cells fell back to the auto grid
        // because the base --grid does not fit them (see cell_cfg).
        let grid_reset_procs = c
            .procs
            .iter()
            .filter(|&&p| grid_resets_at(c, p))
            .map(|p| p.to_string())
            .collect::<Vec<_>>()
            .join(",");
        let (gpr, gpc) = (c.base.grid_rows, c.base.grid_cols);
        sink.rec(&[
            ("record", JsonVal::S("meta")),
            ("schema", JsonVal::I(3)),
            ("seed", JsonVal::S(&c.seed.to_string())),
            ("hazard", JsonVal::S(&c.hazard.label())),
            ("node_width", JsonVal::I(c.node_width as i64)),
            ("trials", JsonVal::I(c.trials as i64)),
            ("max_failures", JsonVal::I(c.max_failures as i64)),
            ("rows", JsonVal::I(c.base.rows as i64)),
            ("cols", JsonVal::I(c.base.cols as i64)),
            ("block", JsonVal::I(c.base.block as i64)),
            ("check_tol", JsonVal::F(c.check_tol.unwrap_or(f64::NAN))),
            ("base_grid", JsonVal::S(&format!("{gpr}x{gpc}"))),
            ("grid_reset_procs", JsonVal::S(&grid_reset_procs)),
        ]);
        for b in &self.baselines {
            sink.rec(&[
                ("record", JsonVal::S("baseline")),
                ("procs", JsonVal::I(b.procs as i64)),
                ("interval", JsonVal::I(b.interval as i64)),
                ("measured", JsonVal::F(b.measured)),
                ("predicted", JsonVal::F(b.predicted)),
                ("rel_err", JsonVal::F(b.rel_err)),
            ]);
        }
        for cell in &self.cells {
            sink.rec(&[
                ("record", JsonVal::S("cell")),
                ("mtbf", JsonVal::F(cell.mtbf_panels)),
                ("procs", JsonVal::I(cell.procs as i64)),
                ("interval", JsonVal::I(cell.interval as i64)),
                ("auto", JsonVal::I(cell.auto_interval as i64)),
                ("trials", JsonVal::I(cell.trials as i64)),
                ("survived", JsonVal::I(cell.survived as i64)),
                (
                    "survival_rate",
                    JsonVal::F(cell.survived as f64 / cell.trials as f64),
                ),
                ("kills_scheduled", JsonVal::I(cell.kills_scheduled as i64)),
                ("failures", JsonVal::I(cell.failures as i64)),
                ("recoveries", JsonVal::I(cell.recoveries as i64)),
                ("expected_makespan", JsonVal::F(cell.expected_makespan)),
                ("clean_makespan", JsonVal::F(cell.clean_makespan)),
                ("overhead_pct", JsonVal::F(cell.overhead_pct)),
                ("detects", JsonVal::I(cell.detects as i64)),
                ("detect_s_mean", JsonVal::F(cell.detect_s_mean)),
                ("rebuilds", JsonVal::I(cell.rebuilds as i64)),
                ("rebuild_s_mean", JsonVal::F(cell.rebuild_s_mean)),
                ("store_peak_bytes", JsonVal::I(cell.store_peak_bytes as i64)),
                ("checkpoint_bytes", JsonVal::I(cell.checkpoint_bytes as i64)),
            ]);
        }
        for t in &self.trials {
            let kills = kills_label(&t.kills);
            let err = t.error.clone().unwrap_or_default();
            sink.rec(&[
                ("record", JsonVal::S("trial")),
                ("mtbf", JsonVal::F(t.mtbf_panels)),
                ("procs", JsonVal::I(t.procs as i64)),
                ("interval", JsonVal::I(t.interval as i64)),
                ("auto", JsonVal::I(t.auto_interval as i64)),
                ("trial", JsonVal::I(t.trial as i64)),
                ("matrix_seed", JsonVal::S(&t.matrix_seed.to_string())),
                ("fault_seed", JsonVal::S(&t.fault_seed.to_string())),
                ("kills", JsonVal::S(&kills)),
                ("survived", JsonVal::I(t.survived as i64)),
                ("makespan", JsonVal::F(t.makespan)),
                ("failures", JsonVal::I(t.failures as i64)),
                ("recoveries", JsonVal::I(t.recoveries as i64)),
                ("detects", JsonVal::I(t.detects as i64)),
                ("detect_s", JsonVal::F(t.detect_s)),
                ("rebuilds", JsonVal::I(t.rebuilds as i64)),
                ("rebuild_s", JsonVal::F(t.rebuild_s)),
                ("store_peak_bytes", JsonVal::I(t.store_peak_bytes as i64)),
                ("checkpoint_bytes", JsonVal::I(t.checkpoint_bytes as i64)),
                ("error", JsonVal::S(&err)),
            ]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CampaignConfig {
        CampaignConfig {
            base: RunConfig {
                rows: 128,
                cols: 32,
                block: 16,
                procs: 2,
                workers: 1,
                ..Default::default()
            },
            procs: vec![2],
            mtbf_panels: vec![2.0],
            intervals: vec![IntervalChoice::Fixed(0), IntervalChoice::Fixed(1)],
            trials: 2,
            max_failures: 4,
            seed: 13,
            check_tol: None,
            jobs: 2,
            ..Default::default()
        }
    }

    #[test]
    fn interval_choice_parses() {
        assert_eq!("auto".parse::<IntervalChoice>().unwrap(), IntervalChoice::Auto);
        assert_eq!("4".parse::<IntervalChoice>().unwrap(), IntervalChoice::Fixed(4));
        assert!("soonish".parse::<IntervalChoice>().is_err());
    }

    #[test]
    fn tiny_campaign_runs_and_aggregates() {
        let c = tiny();
        let out = run_campaign(&c).unwrap();
        assert_eq!(out.cells.len(), 2);
        assert_eq!(out.trials.len(), 4);
        // Baselines: (2, 0) and (2, 1).
        assert_eq!(out.baselines.len(), 2);
        for cell in &out.cells {
            assert_eq!(cell.trials, 2);
            assert!(cell.survived <= cell.trials);
        }
        // Paired seeds: the same trial index sees the same schedule at
        // both intervals.
        assert_eq!(out.trials[0].kills, out.trials[2].kills);
        assert_eq!(out.trials[0].matrix_seed, out.trials[2].matrix_seed);
    }

    #[test]
    fn campaign_json_is_reproducible() {
        let c = tiny();
        let body = |out: &CampaignOutcome| {
            let mut sink = JsonSink::new();
            out.emit(&c, &mut sink);
            sink.body()
        };
        let a = body(&run_campaign(&c).unwrap());
        let b = body(&run_campaign(&c).unwrap());
        assert_eq!(a, b, "same seed must reproduce bit-identical JSON");
        assert!(a.contains("\"record\":\"meta\""));
        assert!(a.contains("\"record\":\"trial\""));
    }

    #[test]
    fn meta_records_grid_resets() {
        // Base grid 2x1 fits procs=2 but not procs=4: the sweep resets
        // the mismatched cells to the auto grid and the meta record
        // names the affected procs values instead of hiding the rewrite.
        let mut c = tiny();
        c.base.grid_rows = 2;
        c.base.grid_cols = 1;
        c.procs = vec![2, 4];
        c.intervals = vec![IntervalChoice::Fixed(0)];
        assert!(!grid_resets_at(&c, 2));
        assert!(grid_resets_at(&c, 4));
        let out = run_campaign(&c).unwrap();
        let mut sink = JsonSink::new();
        out.emit(&c, &mut sink);
        let body = sink.body();
        assert!(body.contains("\"schema\":3"), "{body}");
        assert!(body.contains("\"base_grid\":\"2x1\""), "{body}");
        assert!(body.contains("\"grid_reset_procs\":\"4\""), "{body}");
        // A fitting (or auto) base grid records no resets.
        let mut sink = JsonSink::new();
        let c2 = tiny();
        run_campaign(&c2).unwrap().emit(&c2, &mut sink);
        assert!(sink.body().contains("\"grid_reset_procs\":\"\""));
    }

    #[test]
    fn auto_interval_resolves_per_cell() {
        let mut c = tiny();
        c.mtbf_panels = vec![0.5]; // hot: kills all but certain
        c.intervals = vec![IntervalChoice::Auto];
        let out = run_campaign(&c).unwrap();
        for cell in &out.cells {
            assert!(cell.auto_interval);
            // The tuner contract: checkpoint iff the realized rate the
            // cell resolved against was positive.
            if cell.kills_scheduled > 0 {
                assert!(cell.interval >= 1);
            } else {
                assert_eq!(cell.interval, 0);
            }
        }
    }

    #[test]
    fn checkpoint_model_validates_on_clean_runs() {
        let mut c = tiny();
        c.check_tol = Some(0.5);
        let out = run_campaign(&c).unwrap();
        for b in &out.baselines {
            assert!(b.rel_err <= 0.5, "baseline {b:?}");
        }
    }
}
