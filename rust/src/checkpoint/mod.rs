//! Diskless-checkpointing comparator (paper §II, experiment E7).
//!
//! The classic alternative to the paper's ABFT scheme: every `interval`
//! panels each rank copies its full local state into a partner's memory
//! (Plank et al.'s diskless checkpointing). On failure, the replacement
//! restores the last checkpoint and *all* ranks roll back and re-execute
//! the panels since — a global-rollback cost the ABFT scheme avoids.
//!
//! The traffic side is measured for real (the CAQR driver's
//! `checkpoint_every` knob injects the copies into the run); this module
//! adds the analytic rollback model used to convert measured per-panel
//! times into recovery costs, plus memory-overhead accounting to compare
//! against [`crate::coordinator::RecoveryStore`] retention.

use crate::config::RunConfig;
use crate::fault::{tree_steps, FaultSpec};

/// Cost model for checkpoint/rollback recovery.
#[derive(Clone, Copy, Debug)]
pub struct CheckpointModel {
    /// Checkpoint interval in panels.
    pub interval: usize,
    /// Bytes of one rank's local state (one checkpoint copy).
    pub state_bytes: usize,
    /// Simulated seconds per panel (measured from a run).
    pub seconds_per_panel: f64,
    /// Link parameters for the restore transfer.
    pub alpha: f64,
    pub beta: f64,
}

/// Predicted recovery cost after a failure at `fail_panel`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RollbackCost {
    /// Panel index of the restored checkpoint.
    pub restored_panel: usize,
    /// Panels that must be re-executed (by every rank).
    pub replay_panels: usize,
    /// Restore transfer time (read the checkpoint back).
    pub restore_seconds: f64,
    /// Re-execution time.
    pub replay_seconds: f64,
    /// Total recovery time.
    pub total_seconds: f64,
}

impl CheckpointModel {
    /// Rollback cost for a failure detected during panel `fail_panel`.
    pub fn rollback(&self, fail_panel: usize) -> RollbackCost {
        assert!(self.interval > 0, "checkpoint interval must be positive");
        // Checkpoints are taken after panels interval-1, 2*interval-1, ...
        let completed = fail_panel; // panels fully done before the failure
        let restored_panel = (completed / self.interval) * self.interval;
        let replay_panels = fail_panel - restored_panel;
        let restore_seconds = self.alpha + self.state_bytes as f64 * self.beta;
        let replay_seconds = replay_panels as f64 * self.seconds_per_panel;
        RollbackCost {
            restored_panel,
            replay_panels,
            restore_seconds,
            replay_seconds,
            total_seconds: restore_seconds + replay_seconds,
        }
    }

    /// Steady-state memory overhead per rank: one full state copy.
    pub fn memory_overhead_bytes(&self) -> usize {
        self.state_bytes
    }

    /// Failure-free overhead per panel (amortized checkpoint transfer,
    /// dual-channel exchange with the partner).
    pub fn overhead_per_panel_seconds(&self) -> f64 {
        (self.alpha + self.state_bytes as f64 * self.beta) / self.interval as f64
    }

    /// Expected per-panel cost of running at this interval under a
    /// failure rate of `rate_per_panel` failures per panel: the amortized
    /// checkpoint transfer plus the expected rollback cost (restore
    /// transfer + mean replay of `(interval - 1) / 2` panels per
    /// failure). This is the objective the auto-tuner minimizes.
    pub fn expected_per_panel_cost(&self, rate_per_panel: f64) -> f64 {
        let transfer = self.alpha + self.state_bytes as f64 * self.beta;
        let mean_replay = (self.interval as f64 - 1.0) / 2.0 * self.seconds_per_panel;
        self.overhead_per_panel_seconds() + rate_per_panel * (transfer + mean_replay)
    }

    /// Pick the checkpoint interval minimizing
    /// [`CheckpointModel::expected_per_panel_cost`] for the given failure
    /// rate. Returns 0 (checkpointing off) when the measured rate is zero
    /// or negative — with no failures the no-checkpoint schedule is
    /// optimal — and otherwise the smallest argmin in
    /// `[1, max_interval]`. The objective is `transfer/I + c1(rate)*I +
    /// c0(rate)` in the interval `I`, so the argmin is monotone
    /// non-increasing in the rate: more failures, tighter checkpoints.
    pub fn auto_interval(
        state_bytes: usize,
        seconds_per_panel: f64,
        alpha: f64,
        beta: f64,
        rate_per_panel: f64,
        max_interval: usize,
    ) -> usize {
        if !(rate_per_panel > 0.0) || max_interval == 0 {
            return 0;
        }
        let mut best = (f64::INFINITY, 0);
        for interval in 1..=max_interval {
            let m = CheckpointModel { interval, state_bytes, seconds_per_panel, alpha, beta };
            let cost = m.expected_per_panel_cost(rate_per_panel);
            if cost < best.0 {
                best = (cost, interval);
            }
        }
        best.1
    }
}

/// Resolve `--checkpoint-every auto` for a run: estimate the per-panel
/// state size and duration from `cfg` and pick the interval minimizing
/// the expected per-panel cost at `rate_per_panel` failures per panel.
/// The duration estimate is deliberately rough (leading-order flop and
/// latency terms) — only the *argmin*, not the absolute cost, matters.
pub fn auto_checkpoint_interval(cfg: &RunConfig, rate_per_panel: f64) -> usize {
    let state_bytes = cfg.local_rows() * cfg.cols * 4; // one f32 local block
    CheckpointModel::auto_interval(
        state_bytes,
        estimate_seconds_per_panel(cfg),
        cfg.cost.alpha,
        cfg.cost.beta,
        rate_per_panel,
        cfg.panels(),
    )
}

/// Leading-order estimate of one panel iteration's duration under the
/// cost model: local panel QR + trailing update at the mean remaining
/// width, plus the reduction tree's latency terms.
fn estimate_seconds_per_panel(cfg: &RunConfig) -> f64 {
    let m = cfg.local_rows() as f64;
    let b = cfg.block as f64;
    let n = cfg.cols as f64;
    let flops = 2.0 * m * b * b + 4.0 * m * b * (n / 2.0);
    let steps = tree_steps(cfg.procs) as f64;
    let wire = steps * (cfg.cost.alpha + b * b * 4.0 * cfg.cost.beta + cfg.cost.o);
    flops / cfg.cost.flops_per_sec + wire
}

/// Expected failures per panel implied by a [`FaultSpec`] — the measured
/// rate the auto-tuner consumes. A materialized schedule (including the
/// compiled stochastic generators) counts its kills exactly; the
/// per-site coin model multiplies its probability by the number of
/// sites, capped by the failure budget.
pub fn failure_rate_estimate(spec: &FaultSpec, procs: usize, panels: usize) -> f64 {
    if panels == 0 {
        return 0.0;
    }
    match spec {
        FaultSpec::None => 0.0,
        FaultSpec::Schedule { kills } => kills.len() as f64 / panels as f64,
        FaultSpec::Random { prob, max_failures, .. } => {
            let sites = (procs * 2 * tree_steps(procs) * panels) as f64;
            (prob * sites).min(*max_failures as f64) / panels as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CheckpointModel {
        CheckpointModel {
            interval: 4,
            state_bytes: 1 << 20,
            seconds_per_panel: 0.01,
            alpha: 1e-6,
            beta: 1e-10,
        }
    }

    #[test]
    fn rollback_panel_math() {
        let m = model();
        let c = m.rollback(6);
        assert_eq!(c.restored_panel, 4);
        assert_eq!(c.replay_panels, 2);
        assert!((c.replay_seconds - 0.02).abs() < 1e-12);
        // Failure right after a checkpoint: nothing to replay.
        let c2 = m.rollback(4);
        assert_eq!(c2.replay_panels, 0);
        // Worst case: interval-1 panels lost.
        let c3 = m.rollback(7);
        assert_eq!(c3.replay_panels, 3);
    }

    #[test]
    fn shorter_interval_cheaper_recovery_higher_overhead() {
        let long = model();
        let short = CheckpointModel { interval: 1, ..model() };
        assert!(short.rollback(6).total_seconds <= long.rollback(6).total_seconds);
        assert!(short.overhead_per_panel_seconds() > long.overhead_per_panel_seconds());
    }

    #[test]
    #[should_panic(expected = "interval must be positive")]
    fn zero_interval_panics() {
        CheckpointModel { interval: 0, ..model() }.rollback(1);
    }

    #[test]
    fn worst_case_recovery_cost_is_monotone_in_interval() {
        // The worst case for interval I is a failure just before the next
        // checkpoint: I-1 panels replayed. That cost must never shrink as
        // the interval grows (the per-failure/per-panel trade the E7
        // comparator plots rests on this).
        let mut prev = f64::NEG_INFINITY;
        for interval in 1..=16 {
            let m = CheckpointModel { interval, ..model() };
            let worst = m.rollback(interval - 1); // replay = interval - 1
            assert_eq!(worst.replay_panels, interval - 1, "interval {interval}");
            assert!(
                worst.total_seconds >= prev,
                "interval {interval}: worst-case {} < previous {prev}",
                worst.total_seconds
            );
            prev = worst.total_seconds;
        }
    }

    #[test]
    fn mean_replay_grows_with_interval() {
        // Averaged over equally-likely failure panels, longer intervals
        // replay more: the mean of (p mod I) over a whole period is
        // (I-1)/2, strictly increasing in I.
        let mean = |interval: usize| {
            let m = CheckpointModel { interval, ..model() };
            let horizon = interval * 12;
            let total: usize = (0..horizon).map(|p| m.rollback(p).replay_panels).sum();
            total as f64 / horizon as f64
        };
        assert!(mean(2) < mean(4));
        assert!(mean(4) < mean(8));
    }

    #[test]
    fn interval_one_never_replays() {
        let m = CheckpointModel { interval: 1, ..model() };
        for p in 0..32 {
            let c = m.rollback(p);
            assert_eq!(c.replay_panels, 0, "panel {p}");
            assert_eq!(c.restored_panel, p);
            assert_eq!(c.total_seconds, c.restore_seconds);
        }
    }

    #[test]
    fn restore_transfer_edge_cases() {
        // Zero state: the restore costs exactly one latency term.
        let empty = CheckpointModel { state_bytes: 0, ..model() };
        let c = empty.rollback(5);
        assert_eq!(c.restore_seconds, empty.alpha);
        // The transfer term scales linearly in the state size.
        let small = CheckpointModel { state_bytes: 1 << 10, ..model() };
        let large = CheckpointModel { state_bytes: 1 << 20, ..model() };
        let (rs, rl) = (small.rollback(0).restore_seconds, large.rollback(0).restore_seconds);
        let expected = (large.state_bytes - small.state_bytes) as f64 * model().beta;
        assert!((rl - rs - expected).abs() < 1e-15);
        // Failure at panel 0: nothing completed, nothing replayed, but
        // the restore transfer is still paid.
        let c0 = model().rollback(0);
        assert_eq!(c0.restored_panel, 0);
        assert_eq!(c0.replay_panels, 0);
        assert!(c0.total_seconds > 0.0);
    }

    #[test]
    fn auto_interval_zero_rate_means_no_checkpoints() {
        // No measured failures: fall back to the no-checkpoint schedule.
        let pick = |rate| CheckpointModel::auto_interval(1 << 20, 0.01, 1e-6, 1e-10, rate, 64);
        assert_eq!(pick(0.0), 0);
        assert_eq!(pick(-1.0), 0);
        assert_eq!(pick(f64::NAN), 0);
        // Degenerate horizon: nothing to checkpoint.
        assert_eq!(CheckpointModel::auto_interval(1 << 20, 0.01, 1e-6, 1e-10, 0.5, 0), 0);
        // And any positive rate turns checkpointing on.
        assert!(pick(1e-6) >= 1);
    }

    #[test]
    fn auto_interval_monotone_non_increasing_in_rate() {
        let mut prev = usize::MAX;
        for i in 0..60 {
            let rate = 1e-6 * 1.5f64.powi(i);
            let k = CheckpointModel::auto_interval(1 << 20, 0.01, 1e-6, 1e-10, rate, 64);
            assert!(k >= 1, "positive rate must checkpoint (rate {rate})");
            assert!(k <= prev, "interval grew from {prev} to {k} at rate {rate}");
            prev = k;
        }
        // Saturation: overwhelming failure rates checkpoint every panel.
        assert_eq!(prev, 1);
    }

    #[test]
    fn auto_interval_matches_objective_argmin() {
        // The picked interval must actually minimize the objective, ties
        // broken toward the smallest interval.
        let (sb, spp, a, b, rate, max) = (1 << 18, 0.005, 1e-6, 1e-10, 0.02, 32);
        let k = CheckpointModel::auto_interval(sb, spp, a, b, rate, max);
        let cost = |interval: usize| {
            CheckpointModel { interval, state_bytes: sb, seconds_per_panel: spp, alpha: a, beta: b }
                .expected_per_panel_cost(rate)
        };
        for other in 1..=max {
            assert!(cost(k) <= cost(other), "interval {other} beats chosen {k}");
        }
    }

    #[test]
    fn failure_rate_estimates() {
        use crate::fault::{Hazard, StochasticSpec};
        assert_eq!(failure_rate_estimate(&FaultSpec::None, 4, 8), 0.0);
        let spec = StochasticSpec {
            hazard: Hazard::Poisson,
            mtbf_panels: 4.0,
            node_width: 1,
            max_failures: 100,
            seed: 3,
        };
        let fs = spec.fault_spec(4, 16);
        let FaultSpec::Schedule { ref kills } = fs else { panic!("expected schedule") };
        let rate = failure_rate_estimate(&fs, 4, 16);
        assert!((rate - kills.len() as f64 / 16.0).abs() < 1e-12);
        // Random: prob x sites, capped by the budget.
        let r = FaultSpec::Random { prob: 1.0, seed: 0, max_failures: 2 };
        assert!((failure_rate_estimate(&r, 4, 16) - 2.0 / 16.0).abs() < 1e-12);
        assert_eq!(failure_rate_estimate(&FaultSpec::None, 4, 0), 0.0);
    }

    #[test]
    fn auto_checkpoint_interval_uses_run_shape() {
        use crate::config::RunConfig;
        let cfg = RunConfig::default();
        assert_eq!(auto_checkpoint_interval(&cfg, 0.0), 0);
        let k = auto_checkpoint_interval(&cfg, 0.5);
        assert!((1..=cfg.panels()).contains(&k));
        // Higher rate never loosens the interval.
        assert!(auto_checkpoint_interval(&cfg, 5.0) <= k);
    }

    #[test]
    fn memory_and_amortized_overhead_accounting() {
        let m = model();
        assert_eq!(m.memory_overhead_bytes(), m.state_bytes);
        // Amortized per-panel overhead is the full transfer divided by
        // the interval; interval 1 pays it every panel.
        let per_panel = m.overhead_per_panel_seconds();
        let every = CheckpointModel { interval: 1, ..model() };
        assert!((every.overhead_per_panel_seconds() - per_panel * 4.0).abs() < 1e-12);
    }
}
