//! Diskless-checkpointing comparator (paper §II, experiment E7).
//!
//! The classic alternative to the paper's ABFT scheme: every `interval`
//! panels each rank copies its full local state into a partner's memory
//! (Plank et al.'s diskless checkpointing). On failure, the replacement
//! restores the last checkpoint and *all* ranks roll back and re-execute
//! the panels since — a global-rollback cost the ABFT scheme avoids.
//!
//! The traffic side is measured for real (the CAQR driver's
//! `checkpoint_every` knob injects the copies into the run); this module
//! adds the analytic rollback model used to convert measured per-panel
//! times into recovery costs, plus memory-overhead accounting to compare
//! against [`crate::coordinator::RecoveryStore`] retention.

/// Cost model for checkpoint/rollback recovery.
#[derive(Clone, Copy, Debug)]
pub struct CheckpointModel {
    /// Checkpoint interval in panels.
    pub interval: usize,
    /// Bytes of one rank's local state (one checkpoint copy).
    pub state_bytes: usize,
    /// Simulated seconds per panel (measured from a run).
    pub seconds_per_panel: f64,
    /// Link parameters for the restore transfer.
    pub alpha: f64,
    pub beta: f64,
}

/// Predicted recovery cost after a failure at `fail_panel`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RollbackCost {
    /// Panel index of the restored checkpoint.
    pub restored_panel: usize,
    /// Panels that must be re-executed (by every rank).
    pub replay_panels: usize,
    /// Restore transfer time (read the checkpoint back).
    pub restore_seconds: f64,
    /// Re-execution time.
    pub replay_seconds: f64,
    /// Total recovery time.
    pub total_seconds: f64,
}

impl CheckpointModel {
    /// Rollback cost for a failure detected during panel `fail_panel`.
    pub fn rollback(&self, fail_panel: usize) -> RollbackCost {
        assert!(self.interval > 0, "checkpoint interval must be positive");
        // Checkpoints are taken after panels interval-1, 2*interval-1, ...
        let completed = fail_panel; // panels fully done before the failure
        let restored_panel = (completed / self.interval) * self.interval;
        let replay_panels = fail_panel - restored_panel;
        let restore_seconds = self.alpha + self.state_bytes as f64 * self.beta;
        let replay_seconds = replay_panels as f64 * self.seconds_per_panel;
        RollbackCost {
            restored_panel,
            replay_panels,
            restore_seconds,
            replay_seconds,
            total_seconds: restore_seconds + replay_seconds,
        }
    }

    /// Steady-state memory overhead per rank: one full state copy.
    pub fn memory_overhead_bytes(&self) -> usize {
        self.state_bytes
    }

    /// Failure-free overhead per panel (amortized checkpoint transfer,
    /// dual-channel exchange with the partner).
    pub fn overhead_per_panel_seconds(&self) -> f64 {
        (self.alpha + self.state_bytes as f64 * self.beta) / self.interval as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CheckpointModel {
        CheckpointModel {
            interval: 4,
            state_bytes: 1 << 20,
            seconds_per_panel: 0.01,
            alpha: 1e-6,
            beta: 1e-10,
        }
    }

    #[test]
    fn rollback_panel_math() {
        let m = model();
        let c = m.rollback(6);
        assert_eq!(c.restored_panel, 4);
        assert_eq!(c.replay_panels, 2);
        assert!((c.replay_seconds - 0.02).abs() < 1e-12);
        // Failure right after a checkpoint: nothing to replay.
        let c2 = m.rollback(4);
        assert_eq!(c2.replay_panels, 0);
        // Worst case: interval-1 panels lost.
        let c3 = m.rollback(7);
        assert_eq!(c3.replay_panels, 3);
    }

    #[test]
    fn shorter_interval_cheaper_recovery_higher_overhead() {
        let long = model();
        let short = CheckpointModel { interval: 1, ..model() };
        assert!(short.rollback(6).total_seconds <= long.rollback(6).total_seconds);
        assert!(short.overhead_per_panel_seconds() > long.overhead_per_panel_seconds());
    }

    #[test]
    #[should_panic(expected = "interval must be positive")]
    fn zero_interval_panics() {
        CheckpointModel { interval: 0, ..model() }.rollback(1);
    }
}
