//! Run configuration: one typed struct, loadable from a simple
//! `key = value` config file and overridable from the CLI. Everything an
//! experiment varies lives here so benches/examples are driven by data,
//! not code edits.
//!
//! (Offline build: no serde/toml — the config format is a flat
//! `key = value` file with `#` comments, which covers every knob.)

pub mod flags;

pub use flags::Flags;

use std::path::PathBuf;

use anyhow::{bail, ensure, Result};

use crate::fault::FaultSpec;
use crate::ft::Semantics;
use crate::sim::{parse_straggler, CostModel};

/// Which trailing-update algorithm the coordinator runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Algorithm {
    /// Paper Algorithm 1 — baseline CAQR, no redundancy.
    Plain,
    /// Paper Algorithm 2 + FT-TSQR — the fault-tolerant variant.
    #[default]
    FaultTolerant,
}

impl std::str::FromStr for Algorithm {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "plain" | "alg1" => Ok(Self::Plain),
            "ft" | "fault-tolerant" | "alg2" => Ok(Self::FaultTolerant),
            other => Err(format!("unknown algorithm '{other}'")),
        }
    }
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Algorithm::Plain => "plain",
            Algorithm::FaultTolerant => "ft",
        })
    }
}

/// Row-broadcast collective schedule (how the panel column's WY factors
/// reach the other grid columns of its grid row — see
/// `coordinator/collective.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BcastKind {
    /// Pick per run: flat for tiny rows, segmented for large bundles,
    /// binomial otherwise.
    #[default]
    Auto,
    /// Root sends to every peer directly (the historical schedule).
    Flat,
    /// Binomial tree: `O(log Pc)` depth, relays forward.
    Binomial,
    /// Binomial tree with the bundle split into `seg_bytes` segments so
    /// relay forwarding overlaps reception.
    Segmented,
}

impl std::str::FromStr for BcastKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(Self::Auto),
            "flat" => Ok(Self::Flat),
            "binomial" | "tree" => Ok(Self::Binomial),
            "segmented" | "pipelined" => Ok(Self::Segmented),
            other => Err(format!("unknown bcast schedule '{other}'")),
        }
    }
}

impl std::fmt::Display for BcastKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BcastKind::Auto => "auto",
            BcastKind::Flat => "flat",
            BcastKind::Binomial => "binomial",
            BcastKind::Segmented => "segmented",
        })
    }
}

/// Compute-backend selection.
#[derive(Clone, Debug, PartialEq)]
pub enum BackendKind {
    /// Pure-Rust linalg (fast startup; used by big sweeps).
    Native,
    /// PJRT + AOT artifacts (the production numerics path).
    Xla { artifact_dir: PathBuf },
}

impl Default for BackendKind {
    fn default() -> Self {
        BackendKind::Native
    }
}

/// Full run description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Global matrix rows (M).
    pub rows: usize,
    /// Global matrix cols (N).
    pub cols: usize,
    /// Panel width (b).
    pub block: usize,
    /// Number of simulated processes (P); arranged as a `Pr x Pc`
    /// process grid (see `grid_rows`/`grid_cols`). Each grid row owns
    /// rows/Pr block rows; column blocks are block-cyclic over grid
    /// columns.
    pub procs: usize,
    /// Process-grid rows `Pr` (0 = auto). With both grid extents 0 the
    /// grid defaults to `procs x 1` — the original 1-D block-row
    /// layout, which the 2-D code reproduces bitwise.
    pub grid_rows: usize,
    /// Process-grid columns `Pc` (0 = auto; see `grid_rows`).
    pub grid_cols: usize,
    /// Worker-pool width driving the simulated ranks (0 = auto: the
    /// machine's core count, capped by P). P is *not* bounded by this —
    /// rank tasks park on communication instead of holding a thread.
    pub workers: usize,
    /// Intra-rank GEMM/QR band split width, carried by the run's
    /// backend as a [`crate::linalg::ParCtx`] ([`crate::Backend::set_par_ctx`]):
    /// 1 = serial kernels (the default — the rank worker pool usually
    /// owns the cores); N > 1 submits up to N band closures per large
    /// product to the same pool that drives the rank tasks (its compute
    /// lane), so the split never oversubscribes the host. Any width is
    /// bitwise-identical to serial.
    pub par: usize,
    /// Trailing-update algorithm (paper Algorithm 1 vs 2).
    pub algorithm: Algorithm,
    /// Failure-handling policy (FT-MPI / ULFM, paper §II).
    pub semantics: Semantics,
    /// Compute-backend selection.
    pub backend: BackendKind,
    /// Communication/computation cost parameters.
    pub cost: CostModel,
    /// Failure model for the run.
    pub fault: FaultSpec,
    /// Diskless-checkpoint interval in panels (0 = off) — the §II
    /// comparator baseline, experiment E7.
    pub checkpoint_every: usize,
    /// `--checkpoint-every auto`: pick the interval from the measured
    /// failure rate via [`crate::checkpoint::auto_checkpoint_interval`]
    /// when the run is prepared. `checkpoint_every` is then overwritten
    /// with the chosen value and this flag cleared, so a resolved config
    /// round-trips as a concrete interval.
    pub checkpoint_auto: bool,
    /// Straggler injection: `(rank, factor)` compute slowdowns (a slow
    /// rank, distinct from a killed one). Empty = no stragglers.
    pub stragglers: Vec<(usize, f64)>,
    /// Lookahead depth L of the pipelined panel loop: up to L + 1 panels
    /// in flight per rank. 0 = lockstep (bitwise the pre-pipeline
    /// schedule); L >= 1 overlaps the next panel's TSQR with the current
    /// panel's far-trailing update (factors stay bitwise identical on
    /// the native backend). Checkpoint boundaries act as barriers.
    pub lookahead: usize,
    /// Row-broadcast collective schedule (2-D grids only; `Pc = 1` runs
    /// never broadcast). The schedule moves bytes, never operand values:
    /// factors are bitwise-identical across all kinds.
    pub bcast: BcastKind,
    /// Segment size in bytes for the pipelined-segmented broadcast
    /// schedule (and the `Auto` large-bundle threshold).
    pub seg_bytes: usize,
    /// RNG seed for the input matrix.
    pub seed: u64,
    /// Verify the factorization against the Gram identity after the run.
    pub verify: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            rows: 256,
            cols: 64,
            block: 16,
            procs: 4,
            grid_rows: 0,
            grid_cols: 0,
            workers: 0,
            par: 1,
            algorithm: Algorithm::default(),
            semantics: Semantics::default(),
            backend: BackendKind::default(),
            cost: CostModel::default(),
            fault: FaultSpec::default(),
            checkpoint_every: 0,
            checkpoint_auto: false,
            stragglers: Vec::new(),
            lookahead: 0,
            bcast: BcastKind::Auto,
            seg_bytes: 65536,
            seed: 0,
            verify: true,
        }
    }
}

/// Parse a `PrxPc` grid-shape literal (e.g. `4x2`).
pub fn parse_grid(s: &str) -> Result<(usize, usize)> {
    let Some((pr, pc)) = s.split_once(['x', 'X']) else {
        bail!("grid must be PrxPc (e.g. 4x2), got '{s}'");
    };
    let pr: usize = pr.trim().parse().map_err(|_| {
        anyhow::anyhow!("grid rows must be a positive integer, got '{pr}'")
    })?;
    let pc: usize = pc.trim().parse().map_err(|_| {
        anyhow::anyhow!("grid cols must be a positive integer, got '{pc}'")
    })?;
    ensure!(pr >= 1 && pc >= 1, "grid extents must be >= 1, got {pr}x{pc}");
    Ok((pr, pc))
}

impl RunConfig {
    /// The resolved `Pr x Pc` process-grid shape. `0` extents are
    /// auto-filled: both zero gives `procs x 1` (the 1-D layout); one
    /// zero derives the missing extent from `procs`.
    pub fn grid_shape(&self) -> (usize, usize) {
        match (self.grid_rows, self.grid_cols) {
            (0, 0) => (self.procs, 1),
            (pr, 0) => (pr, self.procs / pr.max(1)),
            (0, pc) => (self.procs / pc.max(1), pc),
            (pr, pc) => (pr, pc),
        }
    }

    /// Rows owned by each rank (`rows / Pr`; with the default `Px1`
    /// grid this is the historical `rows / procs`).
    pub fn local_rows(&self) -> usize {
        self.rows / self.grid_shape().0
    }

    /// Number of panels in the CAQR outer loop.
    pub fn panels(&self) -> usize {
        self.cols.div_ceil(self.block)
    }

    /// The worker-pool width actually used: `workers`, or (when 0) the
    /// machine's available parallelism capped by the process count.
    pub fn effective_workers(&self) -> usize {
        if self.workers > 0 {
            self.workers
        } else {
            crate::sim::default_workers(self.procs)
        }
    }

    /// Validate all structural invariants the coordinator assumes.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.procs >= 1, "need at least one process");
        ensure!(self.par >= 1, "par must be >= 1 (1 = serial kernels)");
        ensure!(
            self.rows >= self.cols,
            "QR needs rows >= cols ({} < {})",
            self.rows,
            self.cols
        );
        ensure!(
            self.block >= 1 && self.block <= self.cols,
            "block must be in [1, cols]"
        );
        let (pr, pc) = self.grid_shape();
        ensure!(
            pr >= 1 && pc >= 1 && pr * pc == self.procs,
            "grid {pr}x{pc} must tile procs ({}) exactly",
            self.procs
        );
        ensure!(
            self.rows % pr == 0,
            "rows ({}) must divide evenly across the {pr} grid rows",
            self.rows,
        );
        ensure!(
            self.cols / self.block >= pc,
            "grid cols ({pc}) must not exceed the panel count ({}) — every \
             grid column must own at least one column block",
            self.cols / self.block.max(1),
        );
        ensure!(
            self.cols % self.block == 0,
            "cols ({}) must be a multiple of block ({})",
            self.cols,
            self.block
        );
        ensure!(
            self.local_rows() >= self.block,
            "local rows ({}) must be >= block ({}) so every panel's TSQR leaf is tall",
            self.local_rows(),
            self.block
        );
        ensure!(
            self.local_rows() % self.block == 0,
            "local rows ({}) must be a multiple of block ({}) so panel \
             boundaries align with rank boundaries",
            self.local_rows(),
            self.block
        );
        ensure!(
            self.seg_bytes >= 1,
            "seg_bytes must be >= 1 (one segment per byte at the extreme)"
        );
        for &(rank, factor) in &self.stragglers {
            ensure!(
                rank < self.procs,
                "straggler rank {rank} out of range (procs = {})",
                self.procs
            );
            ensure!(
                factor.is_finite() && factor >= 1.0,
                "straggler factor for rank {rank} must be finite and >= 1, got {factor}"
            );
        }
        Ok(())
    }

    /// Parse from a flat `key = value` file (see `to_kv` for the keys).
    pub fn from_kv(s: &str) -> Result<Self> {
        let mut c = RunConfig::default();
        for (lineno, raw) in s.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                bail!("config line {}: expected key = value", lineno + 1);
            };
            let (k, v) = (k.trim(), v.trim());
            match k {
                "rows" => c.rows = v.parse()?,
                "cols" => c.cols = v.parse()?,
                "block" => c.block = v.parse()?,
                "procs" => c.procs = v.parse()?,
                "grid" => (c.grid_rows, c.grid_cols) = parse_grid(v)?,
                "workers" => c.workers = v.parse()?,
                "par" => c.par = v.parse()?,
                "algorithm" => c.algorithm = v.parse().map_err(anyhow::Error::msg)?,
                "semantics" => c.semantics = v.parse().map_err(anyhow::Error::msg)?,
                "checkpoint_every" => {
                    if v == "auto" {
                        c.checkpoint_auto = true;
                    } else {
                        c.checkpoint_every = v.parse()?;
                        c.checkpoint_auto = false;
                    }
                }
                "straggler" => c.stragglers.push(parse_straggler(v)?),
                "lookahead" => c.lookahead = v.parse()?,
                "bcast" => c.bcast = v.parse().map_err(anyhow::Error::msg)?,
                "seg_bytes" => c.seg_bytes = v.parse()?,
                "seed" => c.seed = v.parse()?,
                "verify" => c.verify = v.parse()?,
                "artifact_dir" => c.backend = BackendKind::Xla { artifact_dir: v.into() },
                "alpha" => c.cost.alpha = v.parse()?,
                "beta" => c.cost.beta = v.parse()?,
                "overhead" => c.cost.o = v.parse()?,
                "flops_per_sec" => c.cost.flops_per_sec = v.parse()?,
                "dual_channel" => c.cost.dual_channel = v.parse()?,
                other => bail!("config line {}: unknown key '{other}'", lineno + 1),
            }
        }
        c.validate()?;
        Ok(c)
    }

    /// Serialize the scalar fields to the `key = value` format.
    pub fn to_kv(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("rows = {}\n", self.rows));
        out.push_str(&format!("cols = {}\n", self.cols));
        out.push_str(&format!("block = {}\n", self.block));
        out.push_str(&format!("procs = {}\n", self.procs));
        if self.grid_rows != 0 || self.grid_cols != 0 {
            let (pr, pc) = self.grid_shape();
            out.push_str(&format!("grid = {pr}x{pc}\n"));
        }
        out.push_str(&format!("workers = {}\n", self.workers));
        out.push_str(&format!("par = {}\n", self.par));
        out.push_str(&format!("algorithm = {}\n", self.algorithm));
        out.push_str(&format!("semantics = {}\n", self.semantics));
        if self.checkpoint_auto {
            out.push_str("checkpoint_every = auto\n");
        } else {
            out.push_str(&format!("checkpoint_every = {}\n", self.checkpoint_every));
        }
        for (rank, factor) in &self.stragglers {
            out.push_str(&format!("straggler = {rank}:{factor}\n"));
        }
        out.push_str(&format!("lookahead = {}\n", self.lookahead));
        out.push_str(&format!("bcast = {}\n", self.bcast));
        out.push_str(&format!("seg_bytes = {}\n", self.seg_bytes));
        out.push_str(&format!("seed = {}\n", self.seed));
        out.push_str(&format!("verify = {}\n", self.verify));
        if let BackendKind::Xla { artifact_dir } = &self.backend {
            out.push_str(&format!("artifact_dir = {}\n", artifact_dir.display()));
        }
        out.push_str(&format!("alpha = {}\n", self.cost.alpha));
        out.push_str(&format!("beta = {}\n", self.cost.beta));
        out.push_str(&format!("overhead = {}\n", self.cost.o));
        out.push_str(&format!("flops_per_sec = {}\n", self.cost.flops_per_sec));
        out.push_str(&format!("dual_channel = {}\n", self.cost.dual_channel));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn kv_roundtrip() {
        let c = RunConfig {
            rows: 1024,
            cols: 512,
            block: 32,
            procs: 8,
            lookahead: 2,
            ..Default::default()
        };
        let t = c.to_kv();
        let c2 = RunConfig::from_kv(&t).unwrap();
        assert_eq!(c2.rows, 1024);
        assert_eq!(c2.procs, 8);
        assert_eq!(c2.algorithm, Algorithm::FaultTolerant);
        assert_eq!(c2.lookahead, 2);
        assert_eq!(c2.cost.dual_channel, c.cost.dual_channel);
    }

    #[test]
    fn lookahead_defaults_to_lockstep_and_parses() {
        assert_eq!(RunConfig::default().lookahead, 0);
        let c = RunConfig::from_kv("rows = 256\ncols = 64\nlookahead = 4\n").unwrap();
        assert_eq!(c.lookahead, 4);
        assert!(RunConfig::from_kv("lookahead = nope\n").is_err());
        assert!(RunConfig::from_kv("lookahead = -1\n").is_err());
    }

    #[test]
    fn checkpoint_auto_and_stragglers_roundtrip() {
        let c = RunConfig {
            checkpoint_auto: true,
            stragglers: vec![(1, 10.0), (3, 2.5)],
            ..Default::default()
        };
        let c2 = RunConfig::from_kv(&c.to_kv()).unwrap();
        assert!(c2.checkpoint_auto);
        assert_eq!(c2.stragglers, vec![(1, 10.0), (3, 2.5)]);
        // A concrete interval after an `auto` line wins (last write).
        let c3 =
            RunConfig::from_kv("checkpoint_every = auto\ncheckpoint_every = 4\n").unwrap();
        assert!(!c3.checkpoint_auto);
        assert_eq!(c3.checkpoint_every, 4);
        assert!(RunConfig::from_kv("checkpoint_every = nope\n").is_err());
        assert!(RunConfig::from_kv("straggler = 1\n").is_err());
    }

    #[test]
    fn straggler_validation() {
        let c = RunConfig { stragglers: vec![(9, 2.0)], ..Default::default() };
        assert!(c.validate().is_err(), "rank out of range");
        let c = RunConfig { stragglers: vec![(1, 0.5)], ..Default::default() };
        assert!(c.validate().is_err(), "factor below 1");
        let c = RunConfig { stragglers: vec![(1, 10.0)], ..Default::default() };
        c.validate().unwrap();
    }

    #[test]
    fn kv_comments_and_unknown_keys() {
        let ok = "rows = 512 # comment\ncols=128\nblock = 32\nprocs = 4\n";
        let c = RunConfig::from_kv(ok).unwrap();
        assert_eq!(c.rows, 512);
        assert!(RunConfig::from_kv("bogus = 3\n").is_err());
        assert!(RunConfig::from_kv("rows\n").is_err());
    }

    #[test]
    fn rejects_uneven_rows() {
        let c = RunConfig { rows: 100, procs: 3, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_wide_matrix() {
        let c = RunConfig { rows: 32, cols: 64, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_short_local_blocks() {
        let c = RunConfig { rows: 64, cols: 64, block: 32, procs: 4, ..Default::default() };
        // local rows = 16 < block 32
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_misaligned_local_rows() {
        let c = RunConfig { rows: 192, cols: 64, block: 32, procs: 4, ..Default::default() };
        // local rows = 48, not a multiple of 32
        assert!(c.validate().is_err());
    }

    #[test]
    fn panels_count() {
        let c = RunConfig { cols: 64, block: 16, ..Default::default() };
        assert_eq!(c.panels(), 4);
    }

    #[test]
    fn grid_defaults_to_1d_and_parses() {
        let c = RunConfig::default();
        assert_eq!(c.grid_shape(), (c.procs, 1), "auto grid is the 1-D layout");
        assert_eq!(c.local_rows(), c.rows / c.procs);

        assert_eq!(parse_grid("4x2").unwrap(), (4, 2));
        assert_eq!(parse_grid("1X8").unwrap(), (1, 8));
        assert!(parse_grid("4").is_err());
        assert!(parse_grid("0x2").is_err());
        assert!(parse_grid("4xtwo").is_err());

        let c = RunConfig::from_kv("rows = 256\ncols = 64\ngrid = 2x2\n").unwrap();
        assert_eq!(c.grid_shape(), (2, 2));
        assert_eq!(c.local_rows(), 128);
        let c2 = RunConfig::from_kv(&c.to_kv()).unwrap();
        assert_eq!(c2.grid_shape(), (2, 2));
    }

    #[test]
    fn grid_validation() {
        // Grid must tile procs.
        let c = RunConfig { grid_rows: 3, grid_cols: 2, ..Default::default() };
        assert!(c.validate().is_err(), "3x2 != 4 procs");
        // Partial spec derives the other extent.
        let c = RunConfig { grid_rows: 2, ..Default::default() };
        assert_eq!(c.grid_shape(), (2, 2));
        c.validate().unwrap();
        // Rows must divide across grid rows, and local rows stay
        // block-aligned under the grid-aware m_local.
        let c = RunConfig { rows: 296, grid_rows: 4, grid_cols: 1, ..Default::default() };
        assert!(c.validate().is_err(), "local rows 74 not a multiple of 16");
        // More grid columns than panels leaves empty grid columns.
        let c = RunConfig {
            procs: 8,
            grid_rows: 1,
            grid_cols: 8,
            ..Default::default()
        };
        assert!(c.validate().is_err(), "8 grid cols > 4 panels");
        // A 2x2 grid on the default shape is fine.
        let c = RunConfig { grid_rows: 2, grid_cols: 2, ..Default::default() };
        c.validate().unwrap();
    }

    #[test]
    fn bcast_defaults_to_auto_and_parses() {
        let c = RunConfig::default();
        assert_eq!(c.bcast, BcastKind::Auto);
        assert_eq!(c.seg_bytes, 65536);
        let c = RunConfig::from_kv(
            "rows = 256\ncols = 64\nbcast = binomial\nseg_bytes = 4096\n",
        )
        .unwrap();
        assert_eq!(c.bcast, BcastKind::Binomial);
        assert_eq!(c.seg_bytes, 4096);
        let c2 = RunConfig::from_kv(&c.to_kv()).unwrap();
        assert_eq!(c2.bcast, BcastKind::Binomial);
        assert_eq!(c2.seg_bytes, 4096);
        assert_eq!("tree".parse::<BcastKind>().unwrap(), BcastKind::Binomial);
        assert_eq!("pipelined".parse::<BcastKind>().unwrap(), BcastKind::Segmented);
        assert!(RunConfig::from_kv("bcast = ring\n").is_err());
        let bad = RunConfig { seg_bytes: 0, ..Default::default() };
        assert!(bad.validate().is_err(), "zero seg_bytes rejected");
    }

    #[test]
    fn algorithm_parses() {
        assert_eq!("alg2".parse::<Algorithm>().unwrap(), Algorithm::FaultTolerant);
        assert_eq!("plain".parse::<Algorithm>().unwrap(), Algorithm::Plain);
    }
}
