//! Minimal `--key value` CLI flag parser, shared by every `ftcaqr`
//! subcommand (`run`, `tsqr`, `serve`, `info`).
//!
//! (Offline build: the crate set has no clap, so flag parsing is
//! hand-rolled. The grammar is deliberately tiny: `--key value` pairs
//! only, repeated keys accumulate, the last occurrence wins for scalar
//! lookups.)

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

/// Parsed `--key value` flags. Repeated keys accumulate.
pub struct Flags {
    values: HashMap<String, Vec<String>>,
}

impl Flags {
    /// Parse an argument list of strict `--key value` pairs.
    pub fn parse(args: &[String]) -> Result<Self> {
        let mut values: HashMap<String, Vec<String>> = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected argument '{a}' (flags are --key value)");
            };
            let val = args
                .get(i + 1)
                .with_context(|| format!("--{key} needs a value"))?;
            values.entry(key.to_string()).or_default().push(val.clone());
            i += 2;
        }
        Ok(Self { values })
    }

    /// Last value given for `key`, if any.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).and_then(|v| v.last()).map(String::as_str)
    }

    /// Every value given for `key`, in order (empty when absent).
    pub fn all(&self, key: &str) -> Vec<String> {
        self.values.get(key).cloned().unwrap_or_default()
    }

    /// Parse the last value of `key` as `T`, or return `default` when the
    /// flag is absent. A present-but-unparsable value is an error, never
    /// silently the default.
    pub fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
            None => Ok(default),
        }
    }

    /// Like [`Flags::num`] but accepting the literal `auto`, mapped to
    /// `None`: `--key auto` -> `Ok(None)`, `--key V` -> `Ok(Some(V))`,
    /// absent -> `Ok(default)`. Used by `--checkpoint-every auto`.
    pub fn num_or_auto<T: std::str::FromStr>(
        &self,
        key: &str,
        default: Option<T>,
    ) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            Some("auto") => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e} (or 'auto')")),
            None => Ok(default),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_pairs_and_accumulates_repeats() {
        let f = Flags::parse(&args(&[
            "--rows", "128", "--kill", "1@0:0", "--kill", "2@1:0", "--rows", "256",
        ]))
        .unwrap();
        assert_eq!(f.get("rows"), Some("256")); // last wins
        assert_eq!(f.all("kill"), vec!["1@0:0".to_string(), "2@1:0".to_string()]);
        assert_eq!(f.get("absent"), None);
        assert!(f.all("absent").is_empty());
    }

    #[test]
    fn num_defaults_and_parses() {
        let f = Flags::parse(&args(&["--procs", "8"])).unwrap();
        assert_eq!(f.num("procs", 4usize).unwrap(), 8);
        assert_eq!(f.num("workers", 2usize).unwrap(), 2); // absent -> default
    }

    #[test]
    fn num_rejects_garbage_instead_of_defaulting() {
        let f = Flags::parse(&args(&["--procs", "eight"])).unwrap();
        let err = f.num("procs", 4usize).unwrap_err().to_string();
        assert!(err.contains("--procs eight"), "{err}");
    }

    #[test]
    fn rejects_positional_and_dangling() {
        assert!(Flags::parse(&args(&["oops"])).is_err());
        let err = Flags::parse(&args(&["--rows"])).unwrap_err().to_string();
        assert!(err.contains("--rows needs a value"), "{err}");
    }

    #[test]
    fn lookahead_flag_parses_with_lockstep_default() {
        // Absent: the pipelined panel loop defaults to lockstep (L = 0).
        let f = Flags::parse(&args(&[])).unwrap();
        assert_eq!(f.num("lookahead", 0usize).unwrap(), 0);
        // Present: parsed as a depth.
        let f = Flags::parse(&args(&["--lookahead", "2"])).unwrap();
        assert_eq!(f.num("lookahead", 0usize).unwrap(), 2);
    }

    #[test]
    fn lookahead_flag_rejects_garbage_and_negatives() {
        let f = Flags::parse(&args(&["--lookahead", "deep"])).unwrap();
        let err = f.num("lookahead", 0usize).unwrap_err().to_string();
        assert!(err.contains("--lookahead deep"), "{err}");
        // usize parsing rejects negative depths rather than wrapping.
        let f = Flags::parse(&args(&["--lookahead", "-1"])).unwrap();
        assert!(f.num("lookahead", 0usize).is_err());
    }

    #[test]
    fn num_or_auto_distinguishes_auto_number_and_absent() {
        let f = Flags::parse(&args(&["--checkpoint-every", "auto"])).unwrap();
        assert_eq!(f.num_or_auto("checkpoint-every", Some(0usize)).unwrap(), None);
        let f = Flags::parse(&args(&["--checkpoint-every", "4"])).unwrap();
        assert_eq!(f.num_or_auto("checkpoint-every", Some(0usize)).unwrap(), Some(4));
        let f = Flags::parse(&args(&[])).unwrap();
        assert_eq!(f.num_or_auto("checkpoint-every", Some(2usize)).unwrap(), Some(2));
        assert_eq!(f.num_or_auto::<usize>("checkpoint-every", None).unwrap(), None);
        let f = Flags::parse(&args(&["--checkpoint-every", "soon"])).unwrap();
        let err = f.num_or_auto("checkpoint-every", Some(0usize)).unwrap_err().to_string();
        assert!(err.contains("auto"), "{err}");
    }

    #[test]
    fn empty_is_fine() {
        let f = Flags::parse(&[]).unwrap();
        assert_eq!(f.get("anything"), None);
    }
}
