//! Failure injection: *when* and *who* dies.
//!
//! A [`FaultPlan`] is consulted by each rank at well-defined sites
//! ([`FailSite`]: before a TSQR/update tree step of a given panel). This
//! mirrors how failures manifest in the paper's MPI setting: a process
//! disappears, and its buddies discover it at the next communication
//! involving it.
//!
//! Multi-failure scenarios compose from three knobs on [`ScheduledKill`]:
//!
//! * several independent kills in one schedule (k failures across
//!   panels/ranks);
//! * `incarnation`-targeted kills, which aim at a REBUILD replacement —
//!   "a failure *during recovery*";
//! * correlated `group` kills (a simulated node crash): when one member
//!   fires, every member dies at the same instant. Killing both members
//!   of a retention pair this way destroys both copies of the step's
//!   redundancy, which the coordinator must report as
//!   [`crate::ft::Fail::Unrecoverable`] rather than heal or hang.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::linalg::Rng64;

/// Where in the algorithm a rank currently is.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FailSite {
    /// Panel index of the CAQR outer loop.
    pub panel: usize,
    /// Step inside the TSQR / update tree.
    pub step: usize,
    /// Phase of the panel iteration.
    pub phase: Phase,
}

/// Algorithm phase (used to aim failures precisely in experiments).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Panel factorization (TSQR reduction tree).
    Tsqr,
    /// Row-broadcast of the panel's WY factors across the process grid
    /// (between the TSQR and the trailing update; a no-op on `Px1`
    /// grids). Senders fail before publishing the factor bundle,
    /// receivers before consuming it.
    Bcast,
    /// Trailing-matrix update tree.
    Update,
}

/// One scheduled kill: rank `rank` dies at `site` (once).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScheduledKill {
    /// Victim rank.
    pub rank: usize,
    /// Where in the algorithm the kill fires.
    pub site: FailSite,
    /// `Some(i)` restricts the kill to incarnation `i` of the rank —
    /// `Some(1)` kills the first REBUILD replacement mid-recovery.
    /// `None` fires for whichever incarnation reaches the site first.
    pub incarnation: Option<u32>,
    /// Correlated-failure group (a simulated node crash): when any
    /// member's kill fires, all members die simultaneously and the
    /// group's remaining kills are consumed.
    pub group: Option<u32>,
}

impl ScheduledKill {
    /// Kill `rank` at `(panel, step)` of `phase`, any incarnation.
    pub fn new(rank: usize, panel: usize, step: usize, phase: Phase) -> Self {
        Self {
            rank,
            site: FailSite { panel, step, phase },
            incarnation: None,
            group: None,
        }
    }

    /// Restrict the kill to one incarnation (1 = first replacement).
    pub fn at_incarnation(mut self, inc: u32) -> Self {
        self.incarnation = Some(inc);
        self
    }

    /// Join a correlated-failure group.
    pub fn in_group(mut self, group: u32) -> Self {
        self.group = Some(group);
        self
    }

    /// Parse `rank@panel:step[:phase[:incarnation]]` — the kill grammar
    /// shared by the `ftcaqr run --kill` flag and the `serve` jobs file.
    /// An incarnation of 1 aims the kill at the first REBUILD
    /// replacement (a failure during recovery).
    pub fn parse(spec: &str) -> Result<Self> {
        let (rank, rest) = spec
            .split_once('@')
            .with_context(|| format!("kill spec '{spec}' must be rank@panel:step[...]"))?;
        let (panel, step, phase, inc) = parse_site(spec, rest)?;
        let mut k = ScheduledKill::new(rank.parse()?, panel, step, phase);
        if let Some(i) = inc {
            k = k.at_incarnation(i);
        }
        Ok(k)
    }

    /// Compact textual form (`rank@panel:step:phase[#gN]`) — the inverse
    /// of [`ScheduledKill::parse`] plus a group annotation, used by the
    /// campaign JSON so a trial's whole schedule fits in one string.
    pub fn label(&self) -> String {
        let phase = match self.site.phase {
            Phase::Tsqr => "tsqr",
            Phase::Bcast => "bcast",
            Phase::Update => "update",
        };
        let mut s = format!("{}@{}:{}:{}", self.rank, self.site.panel, self.site.step, phase);
        if let Some(i) = self.incarnation {
            s.push_str(&format!(":{i}"));
        }
        if let Some(g) = self.group {
            s.push_str(&format!("#g{g}"));
        }
        s
    }
}

/// Parse `panel:step[:tsqr|bcast|update[:incarnation]]`.
fn parse_site(spec: &str, rest: &str) -> Result<(usize, usize, Phase, Option<u32>)> {
    let mut it = rest.split(':');
    let panel = it
        .next()
        .filter(|p| !p.is_empty())
        .with_context(|| format!("kill spec '{spec}': missing panel"))?
        .parse()?;
    let step = it
        .next()
        .with_context(|| format!("kill spec '{spec}': missing step"))?
        .parse()?;
    let phase = match it.next() {
        None | Some("update") => Phase::Update,
        Some("tsqr") => Phase::Tsqr,
        Some("bcast") => Phase::Bcast,
        Some(other) => {
            bail!("kill spec '{spec}': unknown phase '{other}' (tsqr|bcast|update)")
        }
    };
    let incarnation = it.next().map(str::parse).transpose()?;
    if it.next().is_some() {
        bail!("kill spec '{spec}': too many ':' fields");
    }
    Ok((panel, step, phase, incarnation))
}

/// Parse `a,b@panel:step[:phase]` into a correlated node-crash pair in
/// group `group` — both ranks die at the same instant; aimed at a
/// retention pair this destroys both redundancy copies and the run is
/// reported unrecoverable.
pub fn parse_kill_pair(spec: &str, group: u32) -> Result<[ScheduledKill; 2]> {
    let (ranks, rest) = spec
        .split_once('@')
        .with_context(|| format!("kill-pair spec '{spec}' must be a,b@panel:step[...]"))?;
    let (ra, rb) = ranks
        .split_once(',')
        .with_context(|| format!("kill-pair spec '{spec}': ranks must be a,b"))?;
    let (panel, step, phase, inc) = parse_site(spec, rest)?;
    if inc.is_some() {
        // Rejected rather than silently dropped: a correlated crash has
        // no incarnation targeting, and accepting ':N' would quietly run
        // a different experiment than the one asked for.
        bail!("kill-pair spec '{spec}': incarnation field not supported (a,b@panel:step[:phase])");
    }
    Ok([
        ScheduledKill::new(ra.parse()?, panel, step, phase).in_group(group),
        ScheduledKill::new(rb.parse()?, panel, step, phase).in_group(group),
    ])
}

/// The failure model for a run.
#[derive(Clone, Debug, Default)]
pub enum FaultSpec {
    /// No injected failures (baseline runs).
    #[default]
    None,
    /// Deterministic schedule (reproducible experiments E3/E6).
    Schedule { kills: Vec<ScheduledKill> },
    /// Independent per-site failure probability (stress testing).
    Random { prob: f64, seed: u64, max_failures: usize },
}

/// Inter-arrival law of a stochastic failure process, in units of the
/// rank's mean time between failures (campaigns sweep the MTBF).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Hazard {
    /// Memoryless exponential inter-arrivals (constant hazard rate) —
    /// the classic Poisson-process MTBF model.
    Poisson,
    /// Weibull inter-arrivals with the given shape; `shape < 1` models
    /// infant mortality (bursty early failures), `shape > 1` wear-out.
    /// `shape == 1` degenerates to [`Hazard::Poisson`] exactly.
    Weibull {
        /// Weibull shape parameter `k > 0`.
        shape: f64,
    },
}

impl Hazard {
    /// Stable textual label for logs and campaign JSON.
    pub fn label(&self) -> String {
        match self {
            Hazard::Poisson => "poisson".to_string(),
            Hazard::Weibull { shape } => format!("weibull({shape})"),
        }
    }
}

/// Pairwise reduction-tree depth for `procs` ranks: `ceil(log2 procs)`,
/// at least 1. This is the number of `step` values a panel's TSQR (and
/// update) tree exposes as failure sites, so stochastic arrivals inside
/// a panel are spread across `2 * tree_steps(procs)` sites.
pub fn tree_steps(procs: usize) -> usize {
    procs.max(2).next_power_of_two().trailing_zeros() as usize
}

/// An MTBF-driven failure-process generator. Unlike [`FaultSpec::Random`]
/// (an independent coin per visited site), a `StochasticSpec` *compiles*
/// to a concrete kill schedule up front: per-unit renewal processes are
/// sampled on the logical time axis (panels) and materialized into a
/// [`FaultSpec::Schedule`]. The schedule is a pure function of the spec
/// and the run shape — independent of worker-pool width or scheduler
/// interleaving — so one seed reproduces a campaign bit-for-bit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StochasticSpec {
    /// Inter-arrival law.
    pub hazard: Hazard,
    /// Mean time between failures of one unit (rank or node), measured
    /// in panels of the outer CAQR loop. For Weibull this is the *scale*
    /// parameter (the 63rd-percentile life), not the analytic mean —
    /// avoiding a gamma-function dependency.
    pub mtbf_panels: f64,
    /// Ranks per failure unit: 1 = independent per-rank failures; `w > 1`
    /// groups ranks `[u*w, (u+1)*w)` into nodes that crash together
    /// (correlated kills sharing a [`ScheduledKill::group`]).
    pub node_width: usize,
    /// Cap on generated kills; a correlated node crash is never split by
    /// the cap (generation stops before a partial group).
    pub max_failures: usize,
    /// Seed of the whole process; each unit gets an independent
    /// deterministic stream derived from it.
    pub seed: u64,
}

impl StochasticSpec {
    /// Draw one inter-arrival time (in panels) from the hazard law.
    fn sample(&self, rng: &mut Rng64) -> f64 {
        // uniform_open is in (0, 1], so ln is finite and the inverse
        // transforms below never yield NaN/inf.
        let u = rng.uniform_open();
        match self.hazard {
            Hazard::Poisson => -self.mtbf_panels * u.ln(),
            Hazard::Weibull { shape } => self.mtbf_panels * (-u.ln()).powf(1.0 / shape),
        }
    }

    /// Materialize the kill schedule for a `procs`-rank run of `panels`
    /// panels. Arrival times are continuous on `[0, panels)`: the integer
    /// part picks the panel, the fraction picks one of the
    /// `2 * tree_steps(procs)` sites inside it (TSQR steps first, then
    /// update steps). Arrivals are merged across units in (time, unit)
    /// order, so the result is deterministic for a fixed spec and shape.
    pub fn kills(&self, procs: usize, panels: usize) -> Vec<ScheduledKill> {
        assert!(procs >= 1, "stochastic spec needs at least one rank");
        assert!(
            self.mtbf_panels.is_finite() && self.mtbf_panels > 0.0,
            "mtbf_panels must be finite and positive"
        );
        if let Hazard::Weibull { shape } = self.hazard {
            assert!(shape.is_finite() && shape > 0.0, "Weibull shape must be positive");
        }
        let width = self.node_width.max(1);
        let units = procs.div_ceil(width);
        let horizon = panels as f64;
        let mut arrivals: Vec<(f64, usize)> = Vec::new();
        for unit in 0..units {
            let mut rng = Rng64::new(stream_seed(self.seed, unit as u64));
            let mut t = self.sample(&mut rng);
            while t < horizon {
                arrivals.push((t, unit));
                t += self.sample(&mut rng);
            }
        }
        // Total order: arrival time, units break exact ties. Times are
        // finite by construction, so partial_cmp cannot fail.
        arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));

        let steps = tree_steps(procs);
        let sites = 2 * steps;
        let mut kills = Vec::new();
        let mut group = 0u32;
        for (t, unit) in arrivals {
            let lo = unit * width;
            let hi = ((unit + 1) * width).min(procs);
            if kills.len() + (hi - lo) > self.max_failures {
                break; // never split a correlated group across the cap
            }
            let panel = (t.floor() as usize).min(panels.saturating_sub(1));
            let frac = (t - panel as f64).clamp(0.0, 1.0);
            let si = ((frac * sites as f64) as usize).min(sites - 1);
            let (phase, step) =
                if si < steps { (Phase::Tsqr, si) } else { (Phase::Update, si - steps) };
            if hi - lo > 1 {
                for r in lo..hi {
                    kills.push(ScheduledKill::new(r, panel, step, phase).in_group(group));
                }
                group += 1;
            } else {
                kills.push(ScheduledKill::new(lo, panel, step, phase));
            }
        }
        kills
    }

    /// The materialized schedule as a [`FaultSpec`], ready for
    /// [`FaultPlan::new`].
    pub fn fault_spec(&self, procs: usize, panels: usize) -> FaultSpec {
        FaultSpec::Schedule { kills: self.kills(procs, panels) }
    }
}

/// Derive the `idx`-th independent seed from `base` (splitmix64 stream —
/// same construction the service uses for per-job seeds, duplicated here
/// so `fault` stays dependency-free).
fn stream_seed(base: u64, idx: u64) -> u64 {
    let mut z = base.wrapping_add((idx.wrapping_add(1)).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Runtime fault injector shared by all ranks. Each scheduled kill fires
/// at most once (the `used` flags), so a REBUILT rank replaying the same
/// site does not die again.
pub struct FaultPlan {
    spec: FaultSpec,
    used: Vec<AtomicBool>,
    budget: std::sync::atomic::AtomicUsize,
    seed: u64,
}

impl FaultPlan {
    /// Build the runtime injector for a failure model.
    pub fn new(spec: FaultSpec) -> Arc<Self> {
        let (used_len, budget, seed) = match &spec {
            FaultSpec::None => (0, 0, 0),
            FaultSpec::Schedule { kills } => (kills.len(), kills.len(), 0),
            FaultSpec::Random { max_failures, seed, .. } => (0, *max_failures, *seed),
        };
        Arc::new(Self {
            spec,
            used: (0..used_len).map(|_| AtomicBool::new(false)).collect(),
            budget: std::sync::atomic::AtomicUsize::new(budget),
            seed,
        })
    }

    /// Convenience: kill `rank` at (panel, step) of `phase`.
    pub fn kill_at(rank: usize, panel: usize, step: usize, phase: Phase) -> Arc<Self> {
        Self::schedule(vec![ScheduledKill::new(rank, panel, step, phase)])
    }

    /// A deterministic multi-kill schedule.
    pub fn schedule(kills: Vec<ScheduledKill>) -> Arc<Self> {
        Self::new(FaultSpec::Schedule { kills })
    }

    /// Correlated node crash: both ranks die the instant either reaches
    /// the site (the buddy-pair scenario of the recovery tests).
    pub fn kill_pair_at(
        ranks: (usize, usize),
        panel: usize,
        step: usize,
        phase: Phase,
    ) -> Arc<Self> {
        Self::schedule(vec![
            ScheduledKill::new(ranks.0, panel, step, phase).in_group(0),
            ScheduledKill::new(ranks.1, panel, step, phase).in_group(0),
        ])
    }

    /// No injected failures.
    pub fn none() -> Arc<Self> {
        Self::new(FaultSpec::None)
    }

    /// The failure model this plan injects. Campaigns and the
    /// `--checkpoint-every auto` tuner estimate the failure rate from it.
    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Should `rank` die at `site`? Consumes the kill when it fires.
    /// (Incarnation 0 — see [`Self::should_fail_inc`].)
    pub fn should_fail(&self, rank: usize, site: FailSite) -> bool {
        self.should_fail_inc(rank, 0, site)
    }

    /// Incarnation-aware variant: scheduled kills may target a specific
    /// incarnation (a failure during recovery), and random coins mix in
    /// the incarnation so a REBUILT rank re-visiting the same site draws
    /// an independent coin (failures are i.i.d., not site-cursed).
    pub fn should_fail_inc(&self, rank: usize, incarnation: u32, site: FailSite) -> bool {
        match &self.spec {
            FaultSpec::None => false,
            FaultSpec::Schedule { kills } => {
                for (i, k) in kills.iter().enumerate() {
                    if k.rank == rank
                        && k.site == site
                        && k.incarnation.map_or(true, |want| want == incarnation)
                        && !self.used[i].swap(true, Ordering::SeqCst)
                    {
                        return true; // fire once
                    }
                }
                false
            }
            FaultSpec::Random { prob, .. } => {
                if self.budget.load(Ordering::SeqCst) == 0 {
                    return false;
                }
                // Deterministic per (rank, site) coin so replays agree.
                let mut h = std::collections::hash_map::DefaultHasher::new();
                use std::hash::{Hash, Hasher};
                (rank, incarnation, site, self.seed).hash(&mut h);
                let mut rng = Rng64::new(h.finish());
                if rng.chance(*prob) {
                    // burn budget; if we lost the race, don't fail.
                    let prev = self.budget.fetch_update(
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                        |b| b.checked_sub(1),
                    );
                    return prev.is_ok();
                }
                false
            }
        }
    }

    /// Ranks that die *with* `rank` when its kill at `site` fires — the
    /// other members of the kill's correlated group. Their own scheduled
    /// kills are consumed so REBUILD replacements do not re-fire them.
    /// Idempotent; empty for ungrouped kills and non-schedule specs.
    pub fn collateral_of(&self, rank: usize, site: FailSite) -> Vec<usize> {
        let FaultSpec::Schedule { kills } = &self.spec else {
            return Vec::new();
        };
        let Some(g) = kills
            .iter()
            .find(|k| k.rank == rank && k.site == site && k.group.is_some())
            .and_then(|k| k.group)
        else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for (i, k) in kills.iter().enumerate() {
            if k.group == Some(g) && k.rank != rank {
                self.used[i].store(true, Ordering::SeqCst);
                out.push(k.rank);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn site(panel: usize, step: usize) -> FailSite {
        FailSite { panel, step, phase: Phase::Update }
    }

    #[test]
    fn none_never_fails() {
        let p = FaultPlan::none();
        assert!(!p.should_fail(0, site(0, 0)));
    }

    #[test]
    fn scheduled_kill_fires_once() {
        let p = FaultPlan::kill_at(2, 1, 0, Phase::Update);
        assert!(!p.should_fail(2, site(0, 0)));
        assert!(!p.should_fail(1, site(1, 0)));
        assert!(p.should_fail(2, site(1, 0)));
        // replay after rebuild: must NOT fire again
        assert!(!p.should_fail(2, site(1, 0)));
    }

    #[test]
    fn incarnation_targeted_kill_spares_other_incarnations() {
        let p = FaultPlan::schedule(vec![
            ScheduledKill::new(1, 0, 0, Phase::Update).at_incarnation(1),
        ]);
        // Incarnation 0 sails through; incarnation 1 (the replacement)
        // dies; incarnation 2 survives the replay.
        assert!(!p.should_fail_inc(1, 0, site(0, 0)));
        assert!(p.should_fail_inc(1, 1, site(0, 0)));
        assert!(!p.should_fail_inc(1, 2, site(0, 0)));
    }

    #[test]
    fn group_kill_reports_collateral_and_consumes_it() {
        let p = FaultPlan::kill_pair_at((2, 3), 0, 1, Phase::Tsqr);
        let s = FailSite { panel: 0, step: 1, phase: Phase::Tsqr };
        assert!(p.should_fail_inc(2, 0, s));
        assert_eq!(p.collateral_of(2, s), vec![3]);
        // The partner's kill was consumed with the group.
        assert!(!p.should_fail_inc(3, 0, s));
        assert!(!p.should_fail_inc(3, 1, s));
        // Ungrouped queries yield no collateral.
        assert!(p.collateral_of(0, s).is_empty());
    }

    #[test]
    fn random_respects_budget() {
        let p = FaultPlan::new(FaultSpec::Random { prob: 1.0, seed: 1, max_failures: 2 });
        let mut fails = 0;
        for s in 0..10 {
            if p.should_fail(0, site(0, s)) {
                fails += 1;
            }
        }
        assert_eq!(fails, 2);
    }

    #[test]
    fn kill_spec_parses() {
        let k = ScheduledKill::parse("2@1:0:tsqr:1").unwrap();
        assert_eq!(k.rank, 2);
        assert_eq!(k.site, FailSite { panel: 1, step: 0, phase: Phase::Tsqr });
        assert_eq!(k.incarnation, Some(1));
        // Phase defaults to update; incarnation optional.
        let k = ScheduledKill::parse("7@3:2").unwrap();
        assert_eq!(k.site.phase, Phase::Update);
        assert_eq!(k.incarnation, None);
        assert!(ScheduledKill::parse("7").is_err());
        assert!(ScheduledKill::parse("7@").is_err());
        assert!(ScheduledKill::parse("7@1:2:bogus").is_err());
        assert!(ScheduledKill::parse("7@1:2:tsqr:0:9").is_err());
    }

    #[test]
    fn kill_pair_spec_parses() {
        let [a, b] = parse_kill_pair("2,3@0:1:tsqr", 5).unwrap();
        assert_eq!((a.rank, b.rank), (2, 3));
        assert_eq!(a.group, Some(5));
        assert_eq!(b.group, Some(5));
        assert_eq!(a.site, FailSite { panel: 0, step: 1, phase: Phase::Tsqr });
        assert!(parse_kill_pair("2@0:1", 0).is_err());
        // Incarnation targeting is a single-kill feature; a pair spec
        // carrying one must be rejected, not silently ignored.
        assert!(parse_kill_pair("2,3@0:1:tsqr:1", 0).is_err());
    }

    #[test]
    fn stochastic_schedule_is_deterministic() {
        let spec = StochasticSpec {
            hazard: Hazard::Poisson,
            mtbf_panels: 3.0,
            node_width: 1,
            max_failures: 64,
            seed: 42,
        };
        let a = spec.kills(4, 16);
        let b = spec.kills(4, 16);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "mtbf 3 over 4 ranks x 16 panels should produce kills");
    }

    #[test]
    fn weibull_shape_one_is_poisson() {
        // shape == 1 makes the Weibull inverse transform algebraically
        // identical to the exponential one, so the schedules must match
        // bit for bit.
        let base = StochasticSpec {
            hazard: Hazard::Poisson,
            mtbf_panels: 2.5,
            node_width: 1,
            max_failures: 128,
            seed: 7,
        };
        let weib = StochasticSpec { hazard: Hazard::Weibull { shape: 1.0 }, ..base };
        assert_eq!(base.kills(8, 32), weib.kills(8, 32));
    }

    #[test]
    fn stochastic_sites_are_in_range() {
        for &(procs, panels) in &[(1usize, 4usize), (3, 7), (8, 32)] {
            let spec = StochasticSpec {
                hazard: Hazard::Weibull { shape: 0.7 },
                mtbf_panels: 1.5,
                node_width: 1,
                max_failures: 1000,
                seed: 99,
            };
            for k in spec.kills(procs, panels) {
                assert!(k.rank < procs);
                assert!(k.site.panel < panels);
                assert!(k.site.step < tree_steps(procs), "step {} procs {}", k.site.step, procs);
            }
        }
    }

    #[test]
    fn node_width_groups_are_correlated_and_never_split() {
        let spec = StochasticSpec {
            hazard: Hazard::Poisson,
            mtbf_panels: 2.0,
            node_width: 2,
            max_failures: 5, // odd cap: the last pair must not be split
            seed: 11,
        };
        let kills = spec.kills(6, 64);
        assert!(kills.len() <= 4, "cap of 5 can hold at most two whole pairs");
        assert_eq!(kills.len() % 2, 0, "node crashes come in whole pairs");
        let mut groups = std::collections::HashSet::new();
        for pair in kills.chunks(2) {
            assert_eq!(pair[0].group, pair[1].group);
            assert_eq!(pair[0].site, pair[1].site);
            assert_eq!(pair[0].rank / 2, pair[1].rank / 2, "members share a node");
            assert!(groups.insert(pair[0].group), "each crash gets a fresh group");
        }
    }

    #[test]
    fn stochastic_rate_tracks_mtbf() {
        // 4 ranks, mtbf 8 panels, horizon 64 panels: ~32 expected kills.
        let spec = StochasticSpec {
            hazard: Hazard::Poisson,
            mtbf_panels: 8.0,
            node_width: 1,
            max_failures: 10_000,
            seed: 5,
        };
        let n = spec.kills(4, 64).len();
        assert!((8..=80).contains(&n), "got {n} kills, expected around 32");
    }

    #[test]
    fn kill_label_round_trips() {
        let kb = ScheduledKill::new(3, 2, 0, Phase::Bcast);
        assert_eq!(kb.label(), "3@2:0:bcast");
        assert_eq!(ScheduledKill::parse(&kb.label()).unwrap(), kb);
        let k = ScheduledKill::new(2, 1, 0, Phase::Tsqr);
        assert_eq!(k.label(), "2@1:0:tsqr");
        assert_eq!(ScheduledKill::parse(&k.label()).unwrap(), k);
        assert_eq!(k.clone().in_group(3).label(), "2@1:0:tsqr#g3");
        assert_eq!(k.at_incarnation(1).label(), "2@1:0:tsqr:1");
    }

    #[test]
    fn random_deterministic_per_site() {
        let mk = || FaultPlan::new(FaultSpec::Random { prob: 0.5, seed: 42, max_failures: 100 });
        let a: Vec<bool> = {
            let p = mk();
            (0..50).map(|s| p.should_fail(3, site(0, s))).collect()
        };
        let b: Vec<bool> = {
            let p = mk();
            (0..50).map(|s| p.should_fail(3, site(0, s))).collect()
        };
        assert_eq!(a, b);
        assert!(a.iter().any(|x| *x));
        assert!(a.iter().any(|x| !*x));
    }
}
