//! # ftcaqr — Fault-Tolerant Communication-Avoiding QR
//!
//! A reproduction of *"Fault Tolerant QR Factorization for General
//! Matrices"* (Camille Coti, 2016) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   FT-TSQR all-reduce panel factorization ([`coordinator::tsqr`]), the
//!   fault-tolerant pairwise trailing-matrix update tree
//!   (the update phase of [`coordinator::caqr`], the paper's Algorithms
//!   1 & 2), the CAQR
//!   panel driver ([`coordinator::caqr`]) and the single-buddy recovery
//!   protocol ([`coordinator::recovery`]) — all running on a simulated
//!   message-passing world ([`sim`]) with ULFM-style failure semantics.
//! * **L2/L1 (build time)** — the numeric ops (panel QR, TSQR merge,
//!   trailing updates, recovery recompute) are authored in JAX + Pallas,
//!   AOT-lowered to HLO text by `python/compile/aot.py`, and executed from
//!   Rust through the PJRT CPU client ([`runtime`]). Python is never on
//!   the request path.
//!
//! A pure-Rust oracle of every op lives in [`linalg`] and doubles as the
//! fast [`backend::NativeBackend`] used by the large simulation sweeps.
//!
//! ## Scheduler: how P = 512 ranks fit on a laptop
//!
//! The simulated world used to spawn one OS thread per rank, capping
//! experiments at a few dozen processes. Rank bodies are now *resumable
//! tasks* ([`sim::RankTask`]) driven by a bounded worker pool
//! ([`sim::sched`], [`sim::World::run_tasks`]): instead of blocking in
//! `recv`/`sendrecv`, a task **parks** on the non-blocking primitives
//! ([`sim::RankCtx::try_recv`], [`sim::RankCtx::begin_exchange`] /
//! [`sim::RankCtx::poll_exchange`]) and is woken when an event lands in
//! its mailbox. REBUILD replacements are spawned into the same pool
//! mid-run, and a global stall is reported as [`ft::Fail::Stalled`]
//! instead of hanging. See `rust/DESIGN.md` "Scheduler: parking and
//! wakeup" for the protocol, and `benches/scale.rs` for FT-TSQR sweeps
//! at P = 512 plus multi-failure CAQR recovery at scale.
//!
//! Multi-failure experiments compose from [`fault::ScheduledKill`]'s
//! three knobs: k independent kills, incarnation-targeted kills (a
//! failure *during* recovery) and correlated group kills (a node crash);
//! a correlated kill of both members of a retention pair is detected via
//! the store's progress frontier and reported as
//! [`ft::Fail::Unrecoverable`].
//!
//! ## Service: many jobs, one pool
//!
//! The [`service`] module turns the one-factorization-per-process
//! drivers into a multi-tenant system: a persistent [`sim::Pool`] drives
//! every tenant's rank tasks, a [`service::JobQueue`] admits jobs under
//! a bounded in-flight-ranks budget, same-shape tall-skinny TSQR jobs
//! are packed into batched tree sweeps, and each job completes through
//! an async [`service::JobHandle`] with bitwise-deterministic factors
//! and per-job metrics regardless of how tenants interleave. `ftcaqr
//! serve --jobs <file>` is the CLI front end; `benches/service.rs`
//! measures jobs/sec and p50/p99 latency against pool width.
//!
//! ## Campaigns: stochastic failures, stragglers, auto-tuning
//!
//! The [`campaign`] module closes the loop between the failure model and
//! the checkpoint comparator: [`fault::StochasticSpec`] compiles
//! MTBF-driven Poisson/Weibull failure processes (per-rank or correlated
//! per-node) into deterministic kill schedules, [`sim::Stragglers`]
//! injects slow-but-alive ranks, and `ftcaqr campaign` sweeps failure
//! rate x P x checkpoint interval, emitting survival-probability and
//! expected-makespan JSON. `--checkpoint-every auto` picks the interval
//! from the measured failure rate via
//! [`checkpoint::auto_checkpoint_interval`], and every campaign
//! validates the model's predicted makespan against the measured
//! failure-free baselines.

#![warn(missing_docs)]
// Unsafe code (the explicit-SIMD kernels in `linalg::simd`, the scoped
// task-lifetime erasure in `sim::sched`) must put every unsafe operation
// in a scoped `unsafe {}` block with its own SAFETY comment — even
// inside `unsafe fn`.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod backend;
pub mod campaign;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod ft;
pub mod linalg;
pub mod metrics;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod trace;

/// Debug tracing for the simulated protocol, enabled by setting
/// `FTCAQR_DEBUG=1` (used to diagnose distributed-protocol hangs).
#[macro_export]
macro_rules! simlog {
    ($($arg:tt)*) => {
        if std::env::var_os("FTCAQR_DEBUG").is_some() {
            eprintln!($($arg)*);
        }
    };
}

pub use backend::{Backend, ComputeBackend, NativeBackend};
pub use config::RunConfig;
pub use linalg::Matrix;
