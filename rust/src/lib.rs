//! # ftcaqr — Fault-Tolerant Communication-Avoiding QR
//!
//! A reproduction of *"Fault Tolerant QR Factorization for General
//! Matrices"* (Camille Coti, 2016) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: the
//!   FT-TSQR all-reduce panel factorization ([`coordinator::tsqr`]), the
//!   fault-tolerant pairwise trailing-matrix update tree
//!   ([`coordinator::update`], the paper's Algorithms 1 & 2), the CAQR
//!   panel driver ([`coordinator::caqr`]) and the single-buddy recovery
//!   protocol ([`coordinator::recovery`]) — all running on a simulated
//!   message-passing world ([`sim`]) with ULFM-style failure semantics.
//! * **L2/L1 (build time)** — the numeric ops (panel QR, TSQR merge,
//!   trailing updates, recovery recompute) are authored in JAX + Pallas,
//!   AOT-lowered to HLO text by `python/compile/aot.py`, and executed from
//!   Rust through the PJRT CPU client ([`runtime`]). Python is never on
//!   the request path.
//!
//! A pure-Rust oracle of every op lives in [`linalg`] and doubles as the
//! fast [`backend::NativeBackend`] used by the large simulation sweeps.

pub mod backend;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod ft;
pub mod linalg;
pub mod metrics;
pub mod runtime;
pub mod sim;
pub mod trace;

/// Debug tracing for the simulated protocol, enabled by setting
/// `FTCAQR_DEBUG=1` (used to diagnose distributed-protocol hangs).
#[macro_export]
macro_rules! simlog {
    ($($arg:tt)*) => {
        if std::env::var_os("FTCAQR_DEBUG").is_some() {
            eprintln!($($arg)*);
        }
    };
}

pub use backend::{Backend, ComputeBackend, NativeBackend};
pub use config::RunConfig;
pub use linalg::Matrix;
