//! Row-major dense `f32` matrix with the block/pad/crop operations the
//! distributed coordinator needs. Row-major matches XLA's default layout,
//! so [`crate::runtime`] converts to/from `xla::Literal` without copies of
//! the element order.
//!
//! Sub-blocks can be borrowed without copying through [`MatrixView`] /
//! [`MatrixViewMut`] (a strided window over the parent's buffer); the
//! tiled kernels in [`crate::linalg`] are written against views, so the
//! coordinator can update trailing blocks in place instead of round-
//! tripping them through `block` + `set_block` copies (see DESIGN.md
//! "Kernel architecture").

/// Deterministic xorshift64* PRNG (offline build: no `rand` crate).
#[derive(Clone, Debug)]
pub struct Rng64 {
    state: u64,
}

impl Rng64 {
    /// Seed the generator (any seed works; 0 is remapped).
    pub fn new(seed: u64) -> Self {
        // splitmix64 the seed so small seeds diverge immediately.
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        Self { state: (z ^ (z >> 31)) | 1 }
    }

    /// Next raw 64-bit draw.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in (0, 1] (safe for ln()).
    pub fn uniform_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform_open();
        let u2 = self.uniform();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Uniform usize in [0, n): Lemire's widening-multiply method with
    /// the rejection zone, so the draw is *exactly* uniform (the old
    /// `next_u64() % n` carried a modulo bias of up to `2⁶⁴ mod n`
    /// per bucket, which skews large-P fault-injection sampling).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "Rng64::below(0)");
        let n64 = n as u64;
        let mut m = u128::from(self.next_u64()) * u128::from(n64);
        let mut lo = m as u64;
        if lo < n64 {
            // Reject draws in the short leading zone so every bucket
            // receives exactly floor(2^64 / n) raw values.
            let zone = n64.wrapping_neg() % n64;
            while lo < zone {
                m = u128::from(self.next_u64()) * u128::from(n64);
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

/// Borrowed read-only sub-block of a [`Matrix`]: a strided window over
/// the parent's row-major buffer. Copy-free counterpart of
/// [`Matrix::block`].
#[derive(Clone, Copy)]
pub struct MatrixView<'a> {
    data: &'a [f32],
    rows: usize,
    cols: usize,
    stride: usize,
}

impl<'a> MatrixView<'a> {
    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Element `(i, j)`.
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.stride + j]
    }

    /// Row `i` as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &'a [f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.stride..i * self.stride + self.cols]
    }

    /// Materialize the window into an owned [`Matrix`].
    pub fn to_matrix(&self) -> Matrix {
        let mut out = Matrix::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            out.data[i * self.cols..(i + 1) * self.cols].copy_from_slice(self.row(i));
        }
        out
    }
}

/// Borrowed mutable sub-block of a [`Matrix`] (strided window). The
/// in-place kernels (`gemm_view_into`, `leaf_apply_into`, ...) write
/// through this instead of returning fresh allocations.
pub struct MatrixViewMut<'a> {
    data: &'a mut [f32],
    rows: usize,
    cols: usize,
    stride: usize,
}

impl<'a> MatrixViewMut<'a> {
    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Row `i` as a contiguous mutable slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.stride..i * self.stride + self.cols]
    }

    /// Split into the first `h1` rows and the rest (used by the GEMM
    /// row-panel thread split). Both halves keep the parent stride.
    pub fn split_rows(self, h1: usize) -> (MatrixViewMut<'a>, MatrixViewMut<'a>) {
        assert!(h1 <= self.rows, "split_rows past the end");
        let (rows, cols, stride) = (self.rows, self.cols, self.stride);
        if h1 == 0 {
            let head = MatrixViewMut { data: &mut [], rows: 0, cols, stride };
            return (head, self);
        }
        if h1 == rows {
            let tail = MatrixViewMut { data: &mut [], rows: 0, cols, stride };
            return (self, tail);
        }
        let (a, b) = self.data.split_at_mut(h1 * stride);
        (
            MatrixViewMut { data: a, rows: h1, cols, stride },
            MatrixViewMut { data: b, rows: rows - h1, cols, stride },
        )
    }
}

/// Dense row-major `f32` matrix.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows <= 8 && self.cols <= 8 {
            for i in 0..self.rows {
                write!(f, "\n  ")?;
                for j in 0..self.cols {
                    write!(f, "{:9.4} ", self[(i, j)])?;
                }
            }
        }
        Ok(())
    }
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity (square).
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Deterministic standard-normal matrix (xorshift64*, Box–Muller).
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Rng64::new(seed);
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Self { rows, cols, data }
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols, "from_rows length mismatch");
        Self { rows, cols, data: data.to_vec() }
    }

    /// Take ownership of a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_vec length mismatch");
        Self { rows, cols, data }
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Row-major element buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major element buffer.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the row-major element buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Approximate payload size in bytes (used by the sim cost model).
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Borrow the whole matrix as a view.
    pub fn as_view(&self) -> MatrixView<'_> {
        MatrixView { data: &self.data, rows: self.rows, cols: self.cols, stride: self.cols }
    }

    /// Borrow the whole matrix as a mutable view.
    pub fn as_view_mut(&mut self) -> MatrixViewMut<'_> {
        MatrixViewMut {
            data: &mut self.data,
            rows: self.rows,
            cols: self.cols,
            stride: self.cols,
        }
    }

    /// Borrow the sub-block `[r0, r0+h) x [c0, c0+w)` without copying.
    pub fn view(&self, r0: usize, c0: usize, h: usize, w: usize) -> MatrixView<'_> {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "view out of range");
        if h == 0 || w == 0 {
            return MatrixView { data: &[], rows: h, cols: w, stride: self.cols };
        }
        let start = r0 * self.cols + c0;
        let end = start + (h - 1) * self.cols + w;
        MatrixView { data: &self.data[start..end], rows: h, cols: w, stride: self.cols }
    }

    /// Mutably borrow the sub-block `[r0, r0+h) x [c0, c0+w)`.
    pub fn view_mut(&mut self, r0: usize, c0: usize, h: usize, w: usize) -> MatrixViewMut<'_> {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "view_mut out of range");
        if h == 0 || w == 0 {
            return MatrixViewMut { data: &mut [], rows: h, cols: w, stride: self.cols };
        }
        let start = r0 * self.cols + c0;
        let end = start + (h - 1) * self.cols + w;
        MatrixViewMut {
            data: &mut self.data[start..end],
            rows: h,
            cols: w,
            stride: self.cols,
        }
    }

    /// Copy of the sub-block `[r0, r0+h) x [c0, c0+w)`.
    pub fn block(&self, r0: usize, c0: usize, h: usize, w: usize) -> Matrix {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block out of range");
        let mut out = Matrix::zeros(h, w);
        for i in 0..h {
            let src = (r0 + i) * self.cols + c0;
            let dst = i * w;
            out.data[dst..dst + w].copy_from_slice(&self.data[src..src + w]);
        }
        out
    }

    /// `block` + `pad_to` in one copy: the sub-block `[r0, r0+h) x
    /// [c0, c0+w)` placed at the origin of a zero `(rows, cols)` matrix.
    /// This is the single-copy extraction the coordinator's panel loop
    /// uses instead of the old `block(...).pad_to(...)` double copy.
    pub fn block_padded(
        &self,
        r0: usize,
        c0: usize,
        h: usize,
        w: usize,
        rows: usize,
        cols: usize,
    ) -> Matrix {
        assert!(r0 + h <= self.rows && c0 + w <= self.cols, "block_padded out of range");
        assert!(rows >= h && cols >= w, "block_padded shrinks");
        let mut out = Matrix::zeros(rows, cols);
        for i in 0..h {
            let src = (r0 + i) * self.cols + c0;
            let dst = i * cols;
            out.data[dst..dst + w].copy_from_slice(&self.data[src..src + w]);
        }
        out
    }

    /// Write `src` into the sub-block starting at `(r0, c0)`.
    pub fn set_block(&mut self, r0: usize, c0: usize, src: &Matrix) {
        self.set_block_view(r0, c0, src.as_view());
    }

    /// Write a borrowed view into the sub-block starting at `(r0, c0)` —
    /// lets callers store a window of one matrix into another without an
    /// intermediate `block`/`crop_to` copy.
    pub fn set_block_view(&mut self, r0: usize, c0: usize, src: MatrixView<'_>) {
        assert!(
            r0 + src.rows() <= self.rows && c0 + src.cols() <= self.cols,
            "set_block out of range"
        );
        for i in 0..src.rows() {
            let dst = (r0 + i) * self.cols + c0;
            self.data[dst..dst + src.cols()].copy_from_slice(src.row(i));
        }
    }

    /// Zero-pad to `(rows, cols)` (both >= current). Exact for QR/update
    /// artifacts — see DESIGN.md "Shape strategy".
    pub fn pad_to(&self, rows: usize, cols: usize) -> Matrix {
        assert!(rows >= self.rows && cols >= self.cols, "pad_to shrinks");
        if (rows, cols) == self.shape() {
            return self.clone();
        }
        let mut out = Matrix::zeros(rows, cols);
        out.set_block(0, 0, self);
        out
    }

    /// Crop to the leading `(rows, cols)` block.
    pub fn crop_to(&self, rows: usize, cols: usize) -> Matrix {
        self.block(0, 0, rows, cols)
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Vertical concatenation `[self; other]`.
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "vstack col mismatch");
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Matrix::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f32 {
        (self.data.iter().map(|x| (*x as f64).powi(2)).sum::<f64>()).sqrt() as f32
    }

    /// Max |a_ij|.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Upper-triangular copy (rows below the main diagonal zeroed).
    pub fn triu(&self) -> Matrix {
        let mut out = self.clone();
        for i in 0..self.rows {
            for j in 0..self.cols.min(i) {
                out[(i, j)] = 0.0;
            }
        }
        out
    }

    /// True when every element below the main diagonal is ~0.
    pub fn is_upper_triangular(&self, tol: f32) -> bool {
        for i in 0..self.rows {
            for j in 0..self.cols.min(i) {
                if self[(i, j)].abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Elementwise `self - other`.
    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape(), "sub shape mismatch");
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// Elementwise `self + other`.
    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.shape(), other.shape(), "add shape mismatch");
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Matrix::from_vec(self.rows, self.cols, data)
    }

    /// Elementwise `self += other`, allocation-free (SIMD at the best
    /// level; bitwise-identical to the scalar loop).
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape(), "add_assign shape mismatch");
        super::simd::add_slices(super::simd::SimdLevel::best(), &mut self.data, &other.data);
    }

    /// Elementwise `self -= other`, allocation-free (SIMD at the best
    /// level; bitwise-identical to the scalar loop).
    pub fn sub_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape(), "sub_assign shape mismatch");
        super::simd::sub_slices(super::simd::SimdLevel::best(), &mut self.data, &other.data);
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_eye_shapes() {
        assert_eq!(Matrix::zeros(3, 5).shape(), (3, 5));
        let e = Matrix::eye(4);
        assert_eq!(e[(2, 2)], 1.0);
        assert_eq!(e[(2, 3)], 0.0);
    }

    #[test]
    fn randn_deterministic() {
        assert_eq!(Matrix::randn(6, 6, 42), Matrix::randn(6, 6, 42));
        assert_ne!(Matrix::randn(6, 6, 42), Matrix::randn(6, 6, 43));
    }

    #[test]
    fn block_roundtrip() {
        let a = Matrix::randn(8, 8, 1);
        let b = a.block(2, 3, 4, 5);
        assert_eq!(b.shape(), (4, 5));
        assert_eq!(b[(0, 0)], a[(2, 3)]);
        let mut c = Matrix::zeros(8, 8);
        c.set_block(2, 3, &b);
        assert_eq!(c[(5, 7)], a[(5, 7)]);
        assert_eq!(c[(0, 0)], 0.0);
    }

    #[test]
    fn view_matches_block() {
        let a = Matrix::randn(9, 7, 4);
        let v = a.view(2, 1, 5, 4);
        assert_eq!(v.shape(), (5, 4));
        assert_eq!(v.at(0, 0), a[(2, 1)]);
        assert_eq!(v.row(3), a.block(5, 1, 1, 4).data());
        assert_eq!(v.to_matrix(), a.block(2, 1, 5, 4));
        // empty windows are fine
        assert_eq!(a.view(9, 0, 0, 7).to_matrix(), Matrix::zeros(0, 7));
        assert_eq!(a.view(0, 7, 4, 0).to_matrix(), Matrix::zeros(4, 0));
    }

    #[test]
    fn view_mut_split_rows_writes_through() {
        let mut a = Matrix::zeros(6, 4);
        {
            let v = a.view_mut(1, 1, 4, 3);
            let (mut top, mut bot) = v.split_rows(2);
            top.row_mut(0).fill(1.0);
            bot.row_mut(1).fill(2.0);
        }
        assert_eq!(a[(1, 1)], 1.0);
        assert_eq!(a[(1, 3)], 1.0);
        assert_eq!(a[(1, 0)], 0.0, "outside the window untouched");
        assert_eq!(a[(4, 2)], 2.0);
        assert_eq!(a[(5, 2)], 0.0);
    }

    #[test]
    fn set_block_view_matches_set_block() {
        let src = Matrix::randn(6, 6, 9);
        let mut via_block = Matrix::zeros(8, 8);
        via_block.set_block(1, 2, &src.block(1, 1, 4, 3));
        let mut via_view = Matrix::zeros(8, 8);
        via_view.set_block_view(1, 2, src.view(1, 1, 4, 3));
        assert_eq!(via_block, via_view);
    }

    #[test]
    fn block_padded_matches_block_then_pad() {
        let a = Matrix::randn(10, 6, 3);
        let one = a.block_padded(2, 1, 5, 4, 8, 6);
        let two = a.block(2, 1, 5, 4).pad_to(8, 6);
        assert_eq!(one, two);
        // degenerate: no padding needed
        assert_eq!(a.block_padded(0, 0, 10, 6, 10, 6), a);
    }

    #[test]
    fn pad_crop_roundtrip() {
        let a = Matrix::randn(5, 3, 2);
        let p = a.pad_to(8, 4);
        assert_eq!(p.shape(), (8, 4));
        assert_eq!(p[(7, 3)], 0.0);
        assert_eq!(p.crop_to(5, 3), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::randn(4, 7, 3);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn vstack_shapes() {
        let a = Matrix::randn(3, 4, 1);
        let b = Matrix::randn(2, 4, 2);
        let v = a.vstack(&b);
        assert_eq!(v.shape(), (5, 4));
        assert_eq!(v[(4, 3)], b[(1, 3)]);
    }

    #[test]
    fn triu_works() {
        let a = Matrix::randn(4, 4, 9).triu();
        assert!(a.is_upper_triangular(0.0));
    }

    #[test]
    fn add_sub_inverse() {
        let a = Matrix::randn(3, 3, 5);
        let b = Matrix::randn(3, 3, 6);
        let c = a.add(&b).sub(&b);
        for (x, y) in c.data().iter().zip(a.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn assign_ops_match_pure_ops() {
        let a = Matrix::randn(4, 5, 7);
        let b = Matrix::randn(4, 5, 8);
        let mut c = a.clone();
        c.add_assign(&b);
        assert_eq!(c, a.add(&b));
        c.sub_assign(&b);
        assert_eq!(c, a.add(&b).sub(&b));
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng64::new(123);
        let n = 7;
        let mut counts = vec![0u32; n];
        let draws = 70_000;
        for _ in 0..draws {
            let v = rng.below(n);
            assert!(v < n);
            counts[v] += 1;
        }
        let expect = draws as f64 / n as f64;
        for (i, &c) in counts.iter().enumerate() {
            let dev = (c as f64 - expect).abs() / expect;
            assert!(dev < 0.05, "bucket {i}: {c} vs {expect} ({dev:.3})");
        }
        // huge n exercises the widening-multiply path's upper bits
        let big = usize::MAX / 2 + 3;
        for _ in 0..100 {
            assert!(rng.below(big) < big);
        }
    }
}
