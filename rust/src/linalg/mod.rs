//! Pure-Rust dense linear-algebra substrate.
//!
//! This module is the trusted oracle for every numeric operation the
//! distributed algorithm performs, and the implementation behind
//! [`crate::backend::NativeBackend`]. It deliberately mirrors the
//! conventions of the JAX reference (`python/compile/kernels/ref.py`):
//! row-major storage, LAPACK compact-WY reflectors (`Q = I - Y T Yᵀ`,
//! unit-lower `Y`, upper-triangular `T`), and no sign normalization of
//! `R` (tests compare `RᵀR`).

mod blas;
mod matrix;
mod par;
mod qr;
mod simd;

pub use blas::{
    gemm, gemm_into, gemm_path, gemm_ref_into, gemm_view, gemm_view_into,
    gemm_view_into_on, gemm_view_into_on_par, gemm_view_into_par,
    gemm_view_into_with, gemm_with, par_band_rows, trmm_upper, GemmPath, Trans,
};
pub use matrix::{Matrix, MatrixView, MatrixViewMut, Rng64};
pub use par::{ParCtx, ParExecutor, ParTask, ScopedThreads};
pub use qr::{
    dense_qr_r, householder_qr, householder_qr_blocked,
    householder_qr_blocked_par, householder_qr_par, householder_qr_ref,
    leaf_apply, leaf_apply_cols_into, leaf_apply_cols_into_par, leaf_apply_into,
    recover_block, recover_block_cols_into, recover_block_cols_into_par,
    recover_block_into, tree_update, tree_update_half, tree_update_half_cols,
    tree_update_half_cols_par, tree_update_into, tree_update_into_cols,
    tree_update_into_cols_par, tsqr_merge, PanelFactors, TreeStep,
};
pub use simd::SimdLevel;

/// Relative Frobenius distance `‖a − b‖_F / max(‖b‖_F, 1)`.
pub fn rel_err(a: &Matrix, b: &Matrix) -> f32 {
    assert_eq!(a.shape(), b.shape(), "rel_err shape mismatch");
    let mut num = 0f64;
    let mut den = 0f64;
    for (x, y) in a.data().iter().zip(b.data()) {
        num += ((x - y) as f64).powi(2);
        den += (*y as f64).powi(2);
    }
    (num.sqrt() / den.sqrt().max(1.0)) as f32
}

/// Gram-matrix residual `‖AᵀA − RᵀR‖_F / ‖AᵀA‖_F` — the sign-free check
/// that `R` is a valid QR triangle of `A`.
pub fn gram_residual(a: &Matrix, r: &Matrix) -> f32 {
    let ata = gemm(Trans::Yes, Trans::No, 1.0, a, a);
    let rtr = gemm(Trans::Yes, Trans::No, 1.0, r, r);
    rel_err(&rtr, &ata)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_err_zero_for_identical() {
        let a = Matrix::randn(8, 4, 1);
        assert_eq!(rel_err(&a, &a), 0.0);
    }

    #[test]
    fn gram_residual_small_for_true_qr() {
        let a = Matrix::randn(32, 8, 2);
        let r = dense_qr_r(&a);
        assert!(gram_residual(&a, &r) < 1e-4);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn rel_err_panics_on_shape_mismatch() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(3, 2);
        rel_err(&a, &b);
    }
}
