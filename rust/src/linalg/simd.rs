//! Explicit-SIMD kernels (`core::arch`) behind the tiled GEMM and the
//! QR column updates, **bitwise-pinned** to the scalar fallback.
//!
//! Every kernel here vectorizes *across independent output elements*
//! (the NR = 16 columns of the GEMM accumulator tile, the elements of an
//! axpy row), never across a reduction — so each output element performs
//! the exact same sequence of IEEE-754 operations as the scalar kernel:
//! one `mul` then one `add`/`sub` per k step, in the same k order. The
//! intrinsics used (`_mm256_mul_ps`/`_mm256_add_ps`, `vmulq_f32`/
//! `vaddq_f32`) lower to separate multiply and add instructions and are
//! **never contracted into an FMA** (LLVM only fuses when the source
//! permits it; explicit intrinsics do not), so SIMD output is
//! bit-identical to scalar output. `tests/kernel_props.rs` and the
//! in-module property tests pin this for every available level.
//!
//! Reductions (the Householder dot products and norms in
//! `qr::factor_panel`) deliberately stay scalar: vectorizing a sum
//! changes the association order and breaks the bitwise contract.
//!
//! Dispatch is by value of [`SimdLevel`]: the scalar kernel is the
//! always-available fallback and the oracle the property tests compare
//! against; [`SimdLevel::best`] is detected once per process. Pre-AVX
//! x86 falls back to scalar (the packed tile still autovectorizes to
//! SSE there).

use std::sync::OnceLock;

use super::blas::{MR, NR};

// The hand-unrolled kernels below are written for the 4 x 16 tile.
const _: () = assert!(MR == 4 && NR == 16, "SIMD kernels assume a 4x16 tile");

/// Instruction-set level a kernel runs at. Variants other than
/// [`SimdLevel::Scalar`] exist only on the architecture that provides
/// them; all levels produce bitwise-identical results.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Plain Rust loops — the always-available fallback and the
    /// bit-equality oracle.
    Scalar,
    /// 8-lane f32 AVX (`core::arch::x86_64`), runtime-detected.
    #[cfg(target_arch = "x86_64")]
    Avx,
    /// 4-lane f32 NEON (`core::arch::aarch64`), baseline on aarch64.
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl SimdLevel {
    /// Every level usable on this machine, scalar first. Property tests
    /// iterate this to pin each level against the scalar oracle.
    pub fn available() -> Vec<SimdLevel> {
        #[allow(unused_mut)]
        let mut levels = vec![SimdLevel::Scalar];
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx") {
            levels.push(SimdLevel::Avx);
        }
        #[cfg(target_arch = "aarch64")]
        levels.push(SimdLevel::Neon);
        levels
    }

    /// The widest available level, detected once and cached. This is
    /// what the production entry points dispatch to.
    pub fn best() -> SimdLevel {
        static BEST: OnceLock<SimdLevel> = OnceLock::new();
        *BEST.get_or_init(|| *SimdLevel::available().last().expect("scalar always present"))
    }

    /// Short lowercase name for bench JSON / logs.
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            SimdLevel::Avx => "avx",
            #[cfg(target_arch = "aarch64")]
            SimdLevel::Neon => "neon",
        }
    }
}

// --- GEMM register tile -------------------------------------------------

/// The register tile `acc[r][c] += a[r] * b[c]` over the packed k run,
/// at `lvl`. `ap`/`bp` are exact-length packed panels (see
/// `blas::pack_a` / `blas::pack_b`).
#[inline]
pub(crate) fn micro_kernel(lvl: SimdLevel, ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    match lvl {
        SimdLevel::Scalar => micro_kernel_scalar(ap, bp, acc),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: the Avx level is only ever constructed by
        // `SimdLevel::available` after `is_x86_feature_detected!("avx")`.
        SimdLevel::Avx => unsafe { micro_kernel_avx(ap, bp, acc) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is a baseline feature of the aarch64 target.
        SimdLevel::Neon => unsafe { micro_kernel_neon(ap, bp, acc) },
    }
}

/// Scalar register tile — the bit-equality oracle. Each `acc[r][j]`
/// receives exactly one `mul` + one `add` per k step, in k order; the
/// SIMD kernels reproduce this sequence lane-for-lane.
#[inline(always)]
pub(crate) fn micro_kernel_scalar(ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
        for r in 0..MR {
            let arp = av[r];
            for (x, &y) in acc[r].iter_mut().zip(bv) {
                *x += arp * y;
            }
        }
    }
}

/// AVX tile: each accumulator row is two 8-lane registers; every k step
/// broadcasts `a[r]` and issues `mul` then `add` (never FMA), matching
/// the scalar per-element op sequence exactly.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn micro_kernel_avx(ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    use core::arch::x86_64::*;
    // SAFETY: AVX support was runtime-verified before this level was
    // selected; every load/store below stays inside the fixed
    // `[[f32; 16]; 4]` accumulator or a `chunks_exact` window of the
    // packed panels, so all pointers are valid for 8 lanes.
    unsafe {
        let mut c00 = _mm256_loadu_ps(acc[0].as_ptr());
        let mut c01 = _mm256_loadu_ps(acc[0].as_ptr().add(8));
        let mut c10 = _mm256_loadu_ps(acc[1].as_ptr());
        let mut c11 = _mm256_loadu_ps(acc[1].as_ptr().add(8));
        let mut c20 = _mm256_loadu_ps(acc[2].as_ptr());
        let mut c21 = _mm256_loadu_ps(acc[2].as_ptr().add(8));
        let mut c30 = _mm256_loadu_ps(acc[3].as_ptr());
        let mut c31 = _mm256_loadu_ps(acc[3].as_ptr().add(8));
        for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
            let b0 = _mm256_loadu_ps(bv.as_ptr());
            let b1 = _mm256_loadu_ps(bv.as_ptr().add(8));
            let a0 = _mm256_set1_ps(av[0]);
            c00 = _mm256_add_ps(c00, _mm256_mul_ps(a0, b0));
            c01 = _mm256_add_ps(c01, _mm256_mul_ps(a0, b1));
            let a1 = _mm256_set1_ps(av[1]);
            c10 = _mm256_add_ps(c10, _mm256_mul_ps(a1, b0));
            c11 = _mm256_add_ps(c11, _mm256_mul_ps(a1, b1));
            let a2 = _mm256_set1_ps(av[2]);
            c20 = _mm256_add_ps(c20, _mm256_mul_ps(a2, b0));
            c21 = _mm256_add_ps(c21, _mm256_mul_ps(a2, b1));
            let a3 = _mm256_set1_ps(av[3]);
            c30 = _mm256_add_ps(c30, _mm256_mul_ps(a3, b0));
            c31 = _mm256_add_ps(c31, _mm256_mul_ps(a3, b1));
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), c00);
        _mm256_storeu_ps(acc[0].as_mut_ptr().add(8), c01);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), c10);
        _mm256_storeu_ps(acc[1].as_mut_ptr().add(8), c11);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), c20);
        _mm256_storeu_ps(acc[2].as_mut_ptr().add(8), c21);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), c30);
        _mm256_storeu_ps(acc[3].as_mut_ptr().add(8), c31);
    }
}

/// NEON tile: each accumulator row is four 4-lane registers; `vmulq` +
/// `vaddq` (separate instructions, never `fmla`) per k step.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn micro_kernel_neon(ap: &[f32], bp: &[f32], acc: &mut [[f32; NR]; MR]) {
    use core::arch::aarch64::*;
    // SAFETY: NEON is baseline on aarch64; every load/store stays
    // inside the fixed `[[f32; 16]; 4]` accumulator or a `chunks_exact`
    // window of the packed panels (valid for 4 lanes).
    unsafe {
        let mut c: [[float32x4_t; 4]; MR] = [[vdupq_n_f32(0.0); 4]; MR];
        for (r, row) in acc.iter().enumerate() {
            for (q, cv) in c[r].iter_mut().enumerate() {
                *cv = vld1q_f32(row.as_ptr().add(4 * q));
            }
        }
        for (av, bv) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)) {
            let b = [
                vld1q_f32(bv.as_ptr()),
                vld1q_f32(bv.as_ptr().add(4)),
                vld1q_f32(bv.as_ptr().add(8)),
                vld1q_f32(bv.as_ptr().add(12)),
            ];
            for r in 0..MR {
                let a = vdupq_n_f32(av[r]);
                for (cv, bq) in c[r].iter_mut().zip(b.iter()) {
                    *cv = vaddq_f32(*cv, vmulq_f32(a, *bq));
                }
            }
        }
        for (r, row) in acc.iter_mut().enumerate() {
            for (q, cv) in c[r].iter().enumerate() {
                vst1q_f32(row.as_mut_ptr().add(4 * q), *cv);
            }
        }
    }
}

// --- elementwise column kernels ----------------------------------------
//
// All bitwise-safe to vectorize: each output element is produced by the
// same one or two IEEE ops regardless of lane placement. Used by the
// Householder reflector apply (`qr::factor_panel`), the `tree_update_*`
// compositions (`Matrix::add_assign`/`sub_assign`), and the packing
// fast paths.

/// `dst[i] += src[i]` at `lvl` (slices must be equal length).
#[inline]
pub(crate) fn add_slices(lvl: SimdLevel, dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "add_slices length mismatch");
    match lvl {
        SimdLevel::Scalar => {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx is only constructed after runtime detection.
        SimdLevel::Avx => unsafe { add_slices_avx(dst, src) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdLevel::Neon => unsafe { add_slices_neon(dst, src) },
    }
}

/// `dst[i] -= src[i]` at `lvl` (slices must be equal length).
#[inline]
pub(crate) fn sub_slices(lvl: SimdLevel, dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "sub_slices length mismatch");
    match lvl {
        SimdLevel::Scalar => {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d -= s;
            }
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx is only constructed after runtime detection.
        SimdLevel::Avx => unsafe { sub_slices_avx(dst, src) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdLevel::Neon => unsafe { sub_slices_neon(dst, src) },
    }
}

/// `dst[i] -= f * src[i]` at `lvl` — the Householder reflector-apply
/// axpy, kept as `mul` then `sub` to match the scalar op sequence.
#[inline]
pub(crate) fn sub_scaled(lvl: SimdLevel, f: f32, src: &[f32], dst: &mut [f32]) {
    assert_eq!(dst.len(), src.len(), "sub_scaled length mismatch");
    match lvl {
        SimdLevel::Scalar => {
            for (d, &s) in dst.iter_mut().zip(src) {
                *d -= f * s;
            }
        }
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx is only constructed after runtime detection.
        SimdLevel::Avx => unsafe { sub_scaled_avx(f, src, dst) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on aarch64.
        SimdLevel::Neon => unsafe { sub_scaled_neon(f, src, dst) },
    }
}

/// `dst[i] = src[i]` at `lvl` — the packing copy (bit-exact at every
/// level by construction; vector registers just move more per cycle).
#[inline]
pub(crate) fn copy_slices(lvl: SimdLevel, src: &[f32], dst: &mut [f32]) {
    assert_eq!(dst.len(), src.len(), "copy_slices length mismatch");
    match lvl {
        SimdLevel::Scalar => dst.copy_from_slice(src),
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Avx is only constructed after runtime detection.
        SimdLevel::Avx => unsafe { copy_slices_avx(src, dst) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => dst.copy_from_slice(src),
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn add_slices_avx(dst: &mut [f32], src: &[f32]) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let mut i = 0;
    // SAFETY: `i + 8 <= n` guards every 8-lane access and the
    // dispatcher asserted the slices have equal length.
    unsafe {
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_add_ps(d, s));
            i += 8;
        }
    }
    for (d, &s) in dst[i..].iter_mut().zip(&src[i..]) {
        *d += s;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn sub_slices_avx(dst: &mut [f32], src: &[f32]) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let mut i = 0;
    // SAFETY: `i + 8 <= n` guards every 8-lane access and the
    // dispatcher asserted the slices have equal length.
    unsafe {
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_sub_ps(d, s));
            i += 8;
        }
    }
    for (d, &s) in dst[i..].iter_mut().zip(&src[i..]) {
        *d -= s;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn sub_scaled_avx(f: f32, src: &[f32], dst: &mut [f32]) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let mut i = 0;
    // SAFETY: `i + 8 <= n` guards every 8-lane access and the
    // dispatcher asserted the slices have equal length.
    unsafe {
        let vf = _mm256_set1_ps(f);
        while i + 8 <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(i));
            let s = _mm256_loadu_ps(src.as_ptr().add(i));
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_sub_ps(d, _mm256_mul_ps(vf, s)));
            i += 8;
        }
    }
    for (d, &s) in dst[i..].iter_mut().zip(&src[i..]) {
        *d -= f * s;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn copy_slices_avx(src: &[f32], dst: &mut [f32]) {
    use core::arch::x86_64::*;
    let n = dst.len();
    let mut i = 0;
    // SAFETY: `i + 8 <= n` guards every 8-lane access and the
    // dispatcher asserted the slices have equal length.
    unsafe {
        while i + 8 <= n {
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), _mm256_loadu_ps(src.as_ptr().add(i)));
            i += 8;
        }
    }
    dst[i..].copy_from_slice(&src[i..]);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn add_slices_neon(dst: &mut [f32], src: &[f32]) {
    use core::arch::aarch64::*;
    let n = dst.len();
    let mut i = 0;
    // SAFETY: `i + 4 <= n` guards every 4-lane access and the
    // dispatcher asserted the slices have equal length.
    unsafe {
        while i + 4 <= n {
            let d = vld1q_f32(dst.as_ptr().add(i));
            let s = vld1q_f32(src.as_ptr().add(i));
            vst1q_f32(dst.as_mut_ptr().add(i), vaddq_f32(d, s));
            i += 4;
        }
    }
    for (d, &s) in dst[i..].iter_mut().zip(&src[i..]) {
        *d += s;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn sub_slices_neon(dst: &mut [f32], src: &[f32]) {
    use core::arch::aarch64::*;
    let n = dst.len();
    let mut i = 0;
    // SAFETY: `i + 4 <= n` guards every 4-lane access and the
    // dispatcher asserted the slices have equal length.
    unsafe {
        while i + 4 <= n {
            let d = vld1q_f32(dst.as_ptr().add(i));
            let s = vld1q_f32(src.as_ptr().add(i));
            vst1q_f32(dst.as_mut_ptr().add(i), vsubq_f32(d, s));
            i += 4;
        }
    }
    for (d, &s) in dst[i..].iter_mut().zip(&src[i..]) {
        *d -= s;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn sub_scaled_neon(f: f32, src: &[f32], dst: &mut [f32]) {
    use core::arch::aarch64::*;
    let n = dst.len();
    let mut i = 0;
    // SAFETY: `i + 4 <= n` guards every 4-lane access and the
    // dispatcher asserted the slices have equal length.
    unsafe {
        let vf = vdupq_n_f32(f);
        while i + 4 <= n {
            let d = vld1q_f32(dst.as_ptr().add(i));
            let s = vld1q_f32(src.as_ptr().add(i));
            vst1q_f32(dst.as_mut_ptr().add(i), vsubq_f32(d, vmulq_f32(vf, s)));
            i += 4;
        }
    }
    for (d, &s) in dst[i..].iter_mut().zip(&src[i..]) {
        *d -= f * s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Rng64;

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng64::new(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn available_starts_scalar_and_contains_best() {
        let levels = SimdLevel::available();
        assert_eq!(levels[0], SimdLevel::Scalar);
        assert!(levels.contains(&SimdLevel::best()));
    }

    #[test]
    fn micro_kernel_levels_match_scalar_bitwise() {
        for lvl in SimdLevel::available() {
            for kc in [1usize, 2, 3, 7, 16, 33] {
                let ap = randv(kc * MR, 100 + kc as u64);
                let bp = randv(kc * NR, 200 + kc as u64);
                let seed_acc = randv(MR * NR, 300 + kc as u64);
                let load = |buf: &mut [[f32; NR]; MR]| {
                    for r in 0..MR {
                        buf[r].copy_from_slice(&seed_acc[r * NR..(r + 1) * NR]);
                    }
                };
                let mut want = [[0.0f32; NR]; MR];
                load(&mut want);
                micro_kernel_scalar(&ap, &bp, &mut want);
                let mut got = [[0.0f32; NR]; MR];
                load(&mut got);
                micro_kernel(lvl, &ap, &bp, &mut got);
                assert_eq!(
                    want.iter().flatten().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    got.iter().flatten().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "level {} kc {kc}",
                    lvl.name()
                );
            }
        }
    }

    #[test]
    fn elementwise_levels_match_scalar_bitwise() {
        // Odd lengths force the scalar-tail path; 0 and 1 are the
        // degenerate edges.
        for lvl in SimdLevel::available() {
            for n in [0usize, 1, 3, 4, 7, 8, 9, 15, 16, 17, 33, 64, 100] {
                let src = randv(n, 7 + n as u64);
                let base = randv(n, 11 + n as u64);
                let f = 0.7531f32;

                let mut want = base.clone();
                for (d, &s) in want.iter_mut().zip(&src) {
                    *d += s;
                }
                let mut got = base.clone();
                add_slices(lvl, &mut got, &src);
                assert_eq!(bits(&want), bits(&got), "add {} n={n}", lvl.name());

                let mut want = base.clone();
                for (d, &s) in want.iter_mut().zip(&src) {
                    *d -= s;
                }
                let mut got = base.clone();
                sub_slices(lvl, &mut got, &src);
                assert_eq!(bits(&want), bits(&got), "sub {} n={n}", lvl.name());

                let mut want = base.clone();
                for (d, &s) in want.iter_mut().zip(&src) {
                    *d -= f * s;
                }
                let mut got = base.clone();
                sub_scaled(lvl, f, &src, &mut got);
                assert_eq!(bits(&want), bits(&got), "axpy {} n={n}", lvl.name());

                let mut got = vec![0.0f32; n];
                copy_slices(lvl, &src, &mut got);
                assert_eq!(bits(&src), bits(&got), "copy {} n={n}", lvl.name());
            }
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }
}
