//! Householder QR and the five distributed ops, mirroring the JAX
//! reference (`python/compile/kernels/ref.py`) bit-for-bit in convention:
//! unit-lower `Y`, upper `T` with `Q = I − Y T Yᵀ`, unnormalized-sign `R`.

use super::blas::{gemm, gemm_into, Trans};
use super::Matrix;

/// Result of a panel factorization: `Q = I − Y T Yᵀ`, `A = Q [R; 0]`.
#[derive(Clone, Debug)]
pub struct PanelFactors {
    /// Unit-lower-trapezoidal Householder vectors, `(m, b)`.
    pub y: Matrix,
    /// Upper-triangular block reflector factor, `(b, b)`.
    pub t: Matrix,
    /// Upper-triangular factor, `(b, b)`.
    pub r: Matrix,
}

/// Result of one pairwise trailing-update tree step (paper Alg 1/2).
#[derive(Clone, Debug)]
pub struct TreeStep {
    /// `W = Tᵀ(C₀ + Y₁ᵀC₁)` — the redundancy payload kept for recovery.
    pub w: Matrix,
    /// Updated top rows `Ĉ₀ = C₀ − W`.
    pub c0: Matrix,
    /// Updated bottom rows `Ĉ₁ = C₁ − Y₁W`.
    pub c1: Matrix,
}

/// Householder QR of an `(m, b)` panel (`m >= b`).
///
/// Zero-row padding is exact: padded rows produce zero rows of `y` and do
/// not perturb `t`/`r` (relied on by the shape-ladder artifact strategy).
pub fn householder_qr(a: &Matrix) -> PanelFactors {
    let (m, b) = a.shape();
    assert!(m >= b, "householder_qr needs m >= b, got {m} x {b}");
    let mut work = a.clone();
    let mut y = Matrix::zeros(m, b);
    let mut taus = vec![0.0f32; b];

    for j in 0..b {
        // Householder vector for column j, rows j..m.
        let mut normx = 0f64;
        for i in j..m {
            normx += (work[(i, j)] as f64).powi(2);
        }
        let normx = normx.sqrt() as f32;
        let x0 = work[(j, j)];
        let sign = if x0 >= 0.0 { 1.0 } else { -1.0 };
        let beta = -sign * normx;
        let v0 = x0 - beta;

        // v (unnormalized) = x - beta e_j ; tau_un = 2 / vᵀv.
        let mut vtv = (v0 as f64).powi(2);
        for i in j + 1..m {
            vtv += (work[(i, j)] as f64).powi(2);
        }
        if vtv == 0.0 || v0 == 0.0 {
            // Column already reduced (or zero): H = I.
            taus[j] = 0.0;
            // ref.py leaves y[:, j] all-zero in this case.
            continue;
        }
        let tau = (2.0 * (v0 as f64).powi(2) / vtv) as f32;
        taus[j] = tau;

        // y[:, j] = v / v0, with y[j, j] = 1.
        y[(j, j)] = 1.0;
        for i in j + 1..m {
            y[(i, j)] = work[(i, j)] / v0;
        }

        // Apply H = I - tau v vᵀ to the trailing columns j..b of work.
        // w_row[c] = vᵀ work[:, c]
        for c in j..b {
            let mut dot = work[(j, c)]; // v[j] == 1
            for i in j + 1..m {
                dot += y[(i, j)] * work[(i, c)];
            }
            let f = tau * dot;
            work[(j, c)] -= f;
            for i in j + 1..m {
                let yij = y[(i, j)];
                work[(i, c)] -= f * yij;
            }
        }
        // Enforce the exact beta on the diagonal (numerically identical,
        // avoids drift in the strictly-lower part we zero below).
        work[(j, j)] = beta;
    }

    let r = work.block(0, 0, b, b).triu();

    // T accumulation: T[j,j] = tau_j; T[:j, j] = -tau_j T[:j,:j] (Yᵀy_j)[:j]
    let mut t = Matrix::zeros(b, b);
    for j in 0..b {
        t[(j, j)] = taus[j];
        if j == 0 || taus[j] == 0.0 {
            continue;
        }
        // z = Y[:, :j]ᵀ y[:, j]  (length j)
        let mut z = vec![0.0f32; j];
        for (p, zp) in z.iter_mut().enumerate() {
            let mut s = 0.0;
            for i in 0..y.rows() {
                s += y[(i, p)] * y[(i, j)];
            }
            *zp = s;
        }
        // col = -tau_j * T[:j, :j] @ z
        for i in 0..j {
            let mut s = 0.0;
            for (p, zp) in z.iter().enumerate() {
                s += t[(i, p)] * zp;
            }
            t[(i, j)] = -taus[j] * s;
        }
    }

    PanelFactors { y, t, r }
}

/// `R` factor of a full dense QR (oracle for tests / residual checks).
pub fn dense_qr_r(a: &Matrix) -> Matrix {
    let (m, n) = a.shape();
    assert!(m >= n);
    householder_qr(a).r.crop_to(n, n)
}

/// TSQR merge step: QR of the stacked pair `[r0; r1]`.
///
/// Returns `(y0, y1, t, r)`; for exactly-triangular inputs `y0 == I`
/// structurally (the paper's `[I; Y1]` reflector).
pub fn tsqr_merge(r0: &Matrix, r1: &Matrix) -> (Matrix, Matrix, Matrix, Matrix) {
    let b = r0.rows();
    assert_eq!(r0.shape(), (b, b));
    assert_eq!(r1.shape(), (b, b));
    let stacked = r0.vstack(r1);
    let f = householder_qr(&stacked);
    let y0 = f.y.block(0, 0, b, b);
    let y1 = f.y.block(b, 0, b, b);
    (y0, y1, f.t, f.r)
}

/// Apply the local `Qᵀ` to a trailing block: `Ĉ = C − Y (Tᵀ (Yᵀ C))`.
pub fn leaf_apply(y: &Matrix, t: &Matrix, c: &Matrix) -> Matrix {
    let p = gemm(Trans::Yes, Trans::No, 1.0, y, c); // (b, n)
    let w = gemm(Trans::Yes, Trans::No, 1.0, t, &p); // (b, n)
    let mut out = c.clone();
    gemm_into(Trans::No, Trans::No, -1.0, y, &w, 1.0, &mut out);
    out
}

/// One pairwise trailing-update tree step (paper Algorithms 1 & 2 core):
/// `W = Tᵀ(C₀ + Y₁ᵀC₁)`, `Ĉ₀ = C₀ − W`, `Ĉ₁ = C₁ − Y₁W`.
pub fn tree_update(c0: &Matrix, c1: &Matrix, y1: &Matrix, t: &Matrix) -> TreeStep {
    let mut s = c0.clone();
    gemm_into(Trans::Yes, Trans::No, 1.0, y1, c1, 1.0, &mut s);
    let w = gemm(Trans::Yes, Trans::No, 1.0, t, &s);
    let c0h = c0.sub(&w);
    let mut c1h = c1.clone();
    gemm_into(Trans::No, Trans::No, -1.0, y1, &w, 1.0, &mut c1h);
    TreeStep { w, c0: c0h, c1: c1h }
}

/// Single-buddy recovery recompute (paper III-C): `Ĉ = C − Y W`.
/// For the 'even' (top) member of a pair, pass `Y = I`.
pub fn recover_block(c: &Matrix, y: &Matrix, w: &Matrix) -> Matrix {
    let mut out = c.clone();
    gemm_into(Trans::No, Trans::No, -1.0, y, w, 1.0, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gram_residual, rel_err};

    fn q_from(y: &Matrix, t: &Matrix) -> Matrix {
        // Q = I - Y T Yᵀ
        let yt = gemm(Trans::No, Trans::No, 1.0, y, t);
        let mut q = Matrix::eye(y.rows());
        gemm_into(Trans::No, Trans::Yes, -1.0, &yt, y, 1.0, &mut q);
        q
    }

    #[test]
    fn qr_reconstructs_a() {
        let a = Matrix::randn(24, 8, 1);
        let f = householder_qr(&a);
        let q = q_from(&f.y, &f.t);
        let mut rfull = Matrix::zeros(24, 8);
        rfull.set_block(0, 0, &f.r);
        let qr = gemm(Trans::No, Trans::No, 1.0, &q, &rfull);
        assert!(rel_err(&qr, &a) < 1e-4, "rel err {}", rel_err(&qr, &a));
    }

    #[test]
    fn qr_q_orthogonal() {
        let a = Matrix::randn(16, 8, 2);
        let f = householder_qr(&a);
        let q = q_from(&f.y, &f.t);
        let qqt = gemm(Trans::No, Trans::Yes, 1.0, &q, &q);
        assert!(rel_err(&qqt, &Matrix::eye(16)) < 1e-4);
    }

    #[test]
    fn qr_y_unit_lower() {
        let a = Matrix::randn(12, 6, 3);
        let f = householder_qr(&a);
        for j in 0..6 {
            assert!((f.y[(j, j)] - 1.0).abs() < 1e-6);
            for i in 0..j {
                assert_eq!(f.y[(i, j)], 0.0);
            }
        }
        assert!(f.r.is_upper_triangular(0.0));
        assert!(f.t.is_upper_triangular(1e-6));
    }

    #[test]
    fn qr_zero_matrix_finite() {
        let f = householder_qr(&Matrix::zeros(8, 4));
        assert!(f.y.data().iter().all(|x| x.is_finite()));
        assert_eq!(f.r.fro_norm(), 0.0);
        assert_eq!(f.t.fro_norm(), 0.0);
    }

    #[test]
    fn qr_zero_row_padding_exact() {
        let a = Matrix::randn(24, 8, 7);
        let f1 = householder_qr(&a);
        let f2 = householder_qr(&a.pad_to(40, 8));
        assert!(rel_err(&f2.r, &f1.r) < 1e-5);
        assert!(rel_err(&f2.t, &f1.t) < 1e-5);
        assert!(rel_err(&f2.y.block(0, 0, 24, 8), &f1.y) < 1e-5);
        assert_eq!(f2.y.block(24, 0, 16, 8).fro_norm(), 0.0);
    }

    #[test]
    fn merge_y0_identity_for_triangular() {
        let r0 = Matrix::randn(8, 8, 1).triu();
        let r1 = Matrix::randn(8, 8, 2).triu();
        let (y0, _y1, _t, _r) = tsqr_merge(&r0, &r1);
        assert!(rel_err(&y0, &Matrix::eye(8)) < 1e-5);
    }

    #[test]
    fn merge_preserves_gram() {
        let r0 = Matrix::randn(8, 8, 3).triu();
        let r1 = Matrix::randn(8, 8, 4).triu();
        let (_y0, _y1, _t, r) = tsqr_merge(&r0, &r1);
        let stacked = r0.vstack(&r1);
        assert!(gram_residual(&stacked, &r) < 1e-4);
    }

    #[test]
    fn leaf_apply_matches_explicit_q() {
        let a = Matrix::randn(16, 4, 5);
        let f = householder_qr(&a);
        let c = Matrix::randn(16, 12, 6);
        let got = leaf_apply(&f.y, &f.t, &c);
        // explicit: Qᵀ C with Q = I - Y T Yᵀ → Qᵀ = I - Y Tᵀ Yᵀ
        let q = q_from(&f.y, &f.t);
        let want = gemm(Trans::Yes, Trans::No, 1.0, &q, &c);
        assert!(rel_err(&got, &want) < 1e-4);
    }

    #[test]
    fn tree_update_matches_stacked_apply() {
        let r0 = Matrix::randn(8, 8, 7).triu();
        let r1 = Matrix::randn(8, 8, 8).triu();
        let (y0, y1, t, _r) = tsqr_merge(&r0, &r1);
        let c0 = Matrix::randn(8, 16, 9);
        let c1 = Matrix::randn(8, 16, 10);
        let st = tree_update(&c0, &c1, &y1, &t);
        let yfull = y0.vstack(&y1);
        let cfull = c0.vstack(&c1);
        let want = leaf_apply(&yfull, &t, &cfull);
        assert!(rel_err(&st.c0, &want.block(0, 0, 8, 16)) < 1e-4);
        assert!(rel_err(&st.c1, &want.block(8, 0, 8, 16)) < 1e-4);
    }

    #[test]
    fn recovery_identity_both_sides() {
        // Paper III-C: both buddies can be reconstructed from (C', Y, W).
        let r0 = Matrix::randn(8, 8, 11).triu();
        let r1 = Matrix::randn(8, 8, 12).triu();
        let (_y0, y1, t, _r) = tsqr_merge(&r0, &r1);
        let c0 = Matrix::randn(8, 24, 13);
        let c1 = Matrix::randn(8, 24, 14);
        let st = tree_update(&c0, &c1, &y1, &t);
        let rec1 = recover_block(&c1, &y1, &st.w);
        assert!(rel_err(&rec1, &st.c1) < 1e-5);
        let rec0 = recover_block(&c0, &Matrix::eye(8), &st.w);
        assert!(rel_err(&rec0, &st.c0) < 1e-5);
    }

    #[test]
    fn zero_column_padding_exact_for_updates() {
        let a = Matrix::randn(16, 4, 15);
        let f = householder_qr(&a);
        let c = Matrix::randn(16, 10, 16);
        let want = leaf_apply(&f.y, &f.t, &c);
        let got = leaf_apply(&f.y, &f.t, &c.pad_to(16, 16)).crop_to(16, 10);
        assert!(rel_err(&got, &want) < 1e-5);
    }
}
