//! Householder QR and the five distributed ops, mirroring the JAX
//! reference (`python/compile/kernels/ref.py`) bit-for-bit in convention:
//! unit-lower `Y`, upper `T` with `Q = I − Y T Yᵀ`, unnormalized-sign `R`.
//!
//! The panel factorization is *blocked* (see DESIGN.md "Kernel
//! architecture"): width-[`NB`] sub-panels are factored by a slice-based
//! column kernel over a column-major scratch (contiguous column access,
//! no per-element `(i, j)` indexing), `T` is accumulated incrementally
//! via the compact-WY merge identity, and reflectors are applied to the
//! trailing sub-panels through level-3 [`gemm_view_into`] calls instead
//! of per-column rank-1 updates. The pre-blocking scalar implementation
//! survives as [`householder_qr_ref`], the oracle for
//! `tests/kernel_props.rs`.

use super::blas::{
    gemm, gemm_path, gemm_view, gemm_view_into_on_par, gemm_view_into_par, trmm_upper, Trans,
};
use super::matrix::{Matrix, MatrixView};
use super::par::ParCtx;
use super::simd::{self, SimdLevel};

/// Sub-panel width of the blocked QR: trailing columns are updated with
/// level-3 kernels every `NB` factored columns.
const NB: usize = 16;

/// Result of a panel factorization: `Q = I − Y T Yᵀ`, `A = Q [R; 0]`.
#[derive(Clone, Debug)]
pub struct PanelFactors {
    /// Unit-lower-trapezoidal Householder vectors, `(m, b)`.
    pub y: Matrix,
    /// Upper-triangular block reflector factor, `(b, b)`.
    pub t: Matrix,
    /// Upper-triangular factor, `(b, b)`.
    pub r: Matrix,
}

/// Result of one pairwise trailing-update tree step (paper Alg 1/2).
#[derive(Clone, Debug)]
pub struct TreeStep {
    /// `W = Tᵀ(C₀ + Y₁ᵀC₁)` — the redundancy payload kept for recovery.
    pub w: Matrix,
    /// Updated top rows `Ĉ₀ = C₀ − W`.
    pub c0: Matrix,
    /// Updated bottom rows `Ĉ₁ = C₁ − Y₁W`.
    pub c1: Matrix,
}

/// Householder QR of an `(m, b)` panel (`m >= b`), blocked at width
/// [`NB`].
///
/// Zero-row padding is exact: padded rows produce zero rows of `y` and do
/// not perturb `t`/`r` (relied on by the shape-ladder artifact strategy).
pub fn householder_qr(a: &Matrix) -> PanelFactors {
    householder_qr_blocked(a, NB)
}

/// [`householder_qr`] with the level-3 trailing updates split across
/// `par`. Bitwise identical to the serial call at any width (the gemm
/// band split never changes per-element accumulation order).
pub fn householder_qr_par(par: &ParCtx, a: &Matrix) -> PanelFactors {
    householder_qr_blocked_par(par, a, NB)
}

/// [`householder_qr`] with an explicit sub-panel width (exposed for the
/// property tests' `nb` sweeps; `nb >= b` degenerates to a single
/// unblocked panel).
pub fn householder_qr_blocked(a: &Matrix, nb: usize) -> PanelFactors {
    householder_qr_blocked_par(&ParCtx::serial(), a, nb)
}

/// [`householder_qr_blocked`] with the trailing updates split across
/// `par` (see [`householder_qr_par`]).
pub fn householder_qr_blocked_par(par: &ParCtx, a: &Matrix, nb: usize) -> PanelFactors {
    let (m, b) = a.shape();
    assert!(m >= b, "householder_qr needs m >= b, got {m} x {b}");
    assert!(nb >= 1, "householder_qr_blocked needs nb >= 1");
    let mut work = a.clone();
    let mut y = Matrix::zeros(m, b);
    let mut t = Matrix::zeros(b, b);

    let mut j0 = 0;
    while j0 < b {
        let w = nb.min(b - j0);
        let pm = m - j0;

        // 1. Gather the sub-panel (rows j0.., cols j0..j0+w) into a
        //    column-major scratch so the column kernel works on
        //    contiguous slices.
        let mut panel = vec![0.0f32; pm * w];
        for i in 0..pm {
            let src = work.view(j0 + i, j0, 1, w);
            for (c, &v) in src.row(0).iter().enumerate() {
                panel[c * pm + i] = v;
            }
        }
        let mut taus = vec![0.0f32; w];
        factor_panel(&mut panel, pm, w, &mut taus);

        // 2. Scatter back: R entries (on/above the panel diagonal) into
        //    `work`, reflector tails into `y` (unit diagonal explicit,
        //    matching the reference convention; degenerate columns keep
        //    an all-zero y column).
        for c in 0..w {
            let col = &panel[c * pm..(c + 1) * pm];
            for (i, &v) in col.iter().enumerate().take(c + 1) {
                work[(j0 + i, j0 + c)] = v;
            }
            if taus[c] != 0.0 {
                y[(j0 + c, j0 + c)] = 1.0;
                for i in c + 1..pm {
                    y[(j0 + i, j0 + c)] = col[i];
                }
            }
        }

        let yblk = y.view(j0, j0, pm, w);
        let tblk = build_panel_t(yblk, &taus);

        // 3. Level-3 trailing update: C -= Y (Tᵀ (Yᵀ C)) on the columns
        //    right of this sub-panel (replaces per-column rank-1 updates).
        let nt = b - (j0 + w);
        if nt > 0 {
            let p = gemm_view(Trans::Yes, Trans::No, 1.0, yblk, work.view(j0, j0 + w, pm, nt));
            let wm = trmm_upper(Trans::Yes, 1.0, &tblk, &p);
            gemm_view_into_par(
                par,
                Trans::No,
                Trans::No,
                -1.0,
                yblk,
                wm.as_view(),
                1.0,
                work.view_mut(j0, j0 + w, pm, nt),
            );
        }

        // 4. Incremental T: for Q = Q_prev Q_blk the compact-WY factor is
        //    [[T_prev, T12], [0, T_blk]] with
        //    T12 = -T_prev (Y_prevᵀ Y_blk) T_blk. Rows above j0 of Y_blk
        //    are structurally zero, so the gram restricts to rows j0...
        if j0 > 0 {
            let g12 = gemm_view(Trans::Yes, Trans::No, 1.0, y.view(j0, 0, pm, j0), yblk);
            let tprev = t.block(0, 0, j0, j0);
            let tmp = trmm_upper(Trans::No, -1.0, &tprev, &g12);
            let t12 = gemm(Trans::No, Trans::No, 1.0, &tmp, &tblk);
            t.set_block(0, j0, &t12);
        }
        t.set_block(j0, j0, &tblk);
        j0 += w;
    }

    let r = work.block(0, 0, b, b).triu();
    PanelFactors { y, t, r }
}

/// Unblocked column kernel over a column-major scratch: `panel` holds `w`
/// columns of `pm` contiguous values each. On return, column `c` carries
/// R entries in `[..=c]` and the reflector tail (`v / v0`) in `[c+1..]`.
fn factor_panel(panel: &mut [f32], pm: usize, w: usize, taus: &mut [f32]) {
    for j in 0..w {
        let (left, trailing) = panel.split_at_mut((j + 1) * pm);
        let col = &mut left[j * pm..];

        // Householder vector for rows j.. of column j.
        let mut normx = 0f64;
        for &x in &col[j..] {
            normx += (x as f64).powi(2);
        }
        let normx = normx.sqrt() as f32;
        let x0 = col[j];
        let sign = if x0 >= 0.0 { 1.0 } else { -1.0 };
        let beta = -sign * normx;
        let v0 = x0 - beta;

        // v (unnormalized) = x - beta e_j ; tau_un = 2 / vᵀv.
        let mut vtv = (v0 as f64).powi(2);
        for &x in &col[j + 1..] {
            vtv += (x as f64).powi(2);
        }
        if vtv == 0.0 || v0 == 0.0 {
            // Column segment already zero: H = I, y column stays zero.
            taus[j] = 0.0;
            for x in &mut col[j + 1..] {
                *x = 0.0;
            }
            continue;
        }
        let tau = (2.0 * (v0 as f64).powi(2) / vtv) as f32;
        taus[j] = tau;

        // Normalize in place: y = v / v0 (unit at j, stored implicitly),
        // exact beta on the diagonal.
        for x in &mut col[j + 1..] {
            *x /= v0;
        }
        col[j] = beta;

        // Apply H = I - tau v vᵀ to the trailing columns: contiguous
        // slice dot + axpy per column. The dot is a reduction and must
        // stay scalar (vector lanes would change the summation order);
        // the axpy is elementwise and runs at the best SIMD level,
        // bitwise-pinned to the scalar `*ci -= f * yi`.
        let lvl = SimdLevel::best();
        let ytail = &col[j + 1..];
        for cpanel in trailing.chunks_exact_mut(pm) {
            let (chead, ctail) = cpanel.split_at_mut(j + 1);
            let cj = &mut chead[j];
            let mut dot = *cj; // v[j] == 1
            for (yi, ci) in ytail.iter().zip(ctail.iter()) {
                dot += yi * ci;
            }
            let f = tau * dot;
            *cj -= f;
            simd::sub_scaled(lvl, f, ytail, ctail);
        }
    }
}

/// Compact-WY `T` for one sub-panel: `T[j,j] = tau_j`,
/// `T[:j, j] = -tau_j T[:j,:j] (YᵀY)[:j, j]` (the gram is computed once
/// with a level-3 call; the recurrence itself is O(w³) on a tiny tile).
fn build_panel_t(yblk: MatrixView<'_>, taus: &[f32]) -> Matrix {
    let w = taus.len();
    let g = gemm_view(Trans::Yes, Trans::No, 1.0, yblk, yblk);
    let mut t = Matrix::zeros(w, w);
    for j in 0..w {
        t[(j, j)] = taus[j];
        if j == 0 || taus[j] == 0.0 {
            continue;
        }
        for i in 0..j {
            let mut s = 0.0f32;
            for p in i..j {
                s += t[(i, p)] * g[(p, j)];
            }
            t[(i, j)] = -taus[j] * s;
        }
    }
    t
}

/// The pre-blocking scalar Householder QR, kept verbatim as the oracle
/// for `tests/kernel_props.rs` and the "before" baseline in
/// `benches/kernels.rs`. Identical conventions to [`householder_qr`];
/// results agree to f32 rounding.
pub fn householder_qr_ref(a: &Matrix) -> PanelFactors {
    let (m, b) = a.shape();
    assert!(m >= b, "householder_qr needs m >= b, got {m} x {b}");
    let mut work = a.clone();
    let mut y = Matrix::zeros(m, b);
    let mut taus = vec![0.0f32; b];

    for j in 0..b {
        let mut normx = 0f64;
        for i in j..m {
            normx += (work[(i, j)] as f64).powi(2);
        }
        let normx = normx.sqrt() as f32;
        let x0 = work[(j, j)];
        let sign = if x0 >= 0.0 { 1.0 } else { -1.0 };
        let beta = -sign * normx;
        let v0 = x0 - beta;

        let mut vtv = (v0 as f64).powi(2);
        for i in j + 1..m {
            vtv += (work[(i, j)] as f64).powi(2);
        }
        if vtv == 0.0 || v0 == 0.0 {
            taus[j] = 0.0;
            continue;
        }
        let tau = (2.0 * (v0 as f64).powi(2) / vtv) as f32;
        taus[j] = tau;

        y[(j, j)] = 1.0;
        for i in j + 1..m {
            y[(i, j)] = work[(i, j)] / v0;
        }

        for c in j..b {
            let mut dot = work[(j, c)];
            for i in j + 1..m {
                dot += y[(i, j)] * work[(i, c)];
            }
            let f = tau * dot;
            work[(j, c)] -= f;
            for i in j + 1..m {
                let yij = y[(i, j)];
                work[(i, c)] -= f * yij;
            }
        }
        work[(j, j)] = beta;
    }

    let r = work.block(0, 0, b, b).triu();

    let mut t = Matrix::zeros(b, b);
    for j in 0..b {
        t[(j, j)] = taus[j];
        if j == 0 || taus[j] == 0.0 {
            continue;
        }
        let mut z = vec![0.0f32; j];
        for (p, zp) in z.iter_mut().enumerate() {
            let mut s = 0.0;
            for i in 0..y.rows() {
                s += y[(i, p)] * y[(i, j)];
            }
            *zp = s;
        }
        for i in 0..j {
            let mut s = 0.0;
            for (p, zp) in z.iter().enumerate() {
                s += t[(i, p)] * zp;
            }
            t[(i, j)] = -taus[j] * s;
        }
    }

    PanelFactors { y, t, r }
}

/// `R` factor of a full dense QR (oracle for tests / residual checks).
pub fn dense_qr_r(a: &Matrix) -> Matrix {
    let (m, n) = a.shape();
    assert!(m >= n);
    householder_qr(a).r.crop_to(n, n)
}

/// TSQR merge step: QR of the stacked pair `[r0; r1]`.
///
/// Returns `(y0, y1, t, r)`; for exactly-triangular inputs `y0 == I`
/// structurally (the paper's `[I; Y1]` reflector).
pub fn tsqr_merge(r0: &Matrix, r1: &Matrix) -> (Matrix, Matrix, Matrix, Matrix) {
    let b = r0.rows();
    assert_eq!(r0.shape(), (b, b));
    assert_eq!(r1.shape(), (b, b));
    let stacked = r0.vstack(r1);
    let f = householder_qr(&stacked);
    let y0 = f.y.block(0, 0, b, b);
    let y1 = f.y.block(b, 0, b, b);
    (y0, y1, f.t, f.r)
}

/// Apply the local `Qᵀ` to a trailing block in place:
/// `C ← C − Y (Tᵀ (Yᵀ C))`. No copy of `C` is taken.
pub fn leaf_apply_into(y: &Matrix, t: &Matrix, c: &mut Matrix) {
    let n = c.cols();
    leaf_apply_cols_into(y, t, c, n);
}

/// Column-segment variant of [`leaf_apply_into`]: `c` holds a contiguous
/// column slice of a logically `full_n`-wide trailing block, and the
/// gemm dispatch is pinned to the full-width op volume — so applying the
/// reflectors segment by segment is **bitwise identical** to one
/// full-width application (the lookahead pipeline's determinism
/// contract). `full_n == c.cols()` degenerates to [`leaf_apply_into`].
pub fn leaf_apply_cols_into(y: &Matrix, t: &Matrix, c: &mut Matrix, full_n: usize) {
    leaf_apply_cols_into_par(&ParCtx::serial(), y, t, c, full_n);
}

/// [`leaf_apply_cols_into`] with the gemms split across `par` (bitwise
/// identical at any width; the pinned path composes with the band split
/// because neither changes per-element accumulation order).
pub fn leaf_apply_cols_into_par(
    par: &ParCtx,
    y: &Matrix,
    t: &Matrix,
    c: &mut Matrix,
    full_n: usize,
) {
    let (m, b) = y.shape();
    let n = c.cols();
    debug_assert!(n <= full_n, "segment wider than the full block");
    let mut p = Matrix::zeros(b, n);
    gemm_view_into_on_par(
        gemm_path(b, full_n, m),
        par,
        Trans::Yes,
        Trans::No,
        1.0,
        y.as_view(),
        c.as_view(),
        0.0,
        p.as_view_mut(),
    );
    let w = trmm_upper(Trans::Yes, 1.0, t, &p); // (b, n)
    gemm_view_into_on_par(
        gemm_path(m, full_n, b),
        par,
        Trans::No,
        Trans::No,
        -1.0,
        y.as_view(),
        w.as_view(),
        1.0,
        c.as_view_mut(),
    );
}

/// Copying wrapper over [`leaf_apply_into`]: `Ĉ = C − Y (Tᵀ (Yᵀ C))`.
pub fn leaf_apply(y: &Matrix, t: &Matrix, c: &Matrix) -> Matrix {
    let mut out = c.clone();
    leaf_apply_into(y, t, &mut out);
    out
}

/// One pairwise trailing-update tree step in place (paper Algorithms 1 &
/// 2 core): `W = Tᵀ(C₀ + Y₁ᵀC₁)`, `C₀ ← C₀ − W`, `C₁ ← C₁ − Y₁W`.
/// Returns `W` (the retained redundancy payload); neither `C` block is
/// copied.
pub fn tree_update_into(c0: &mut Matrix, c1: &mut Matrix, y1: &Matrix, t: &Matrix) -> Matrix {
    let n = c0.cols();
    tree_update_into_cols(c0, c1, y1, t, n)
}

/// Column-segment variant of [`tree_update_into`] with the gemm dispatch
/// pinned to a `full_n`-wide op (see [`leaf_apply_cols_into`] for the
/// bitwise contract). `full_n == c0.cols()` degenerates to the plain
/// variant.
pub fn tree_update_into_cols(
    c0: &mut Matrix,
    c1: &mut Matrix,
    y1: &Matrix,
    t: &Matrix,
    full_n: usize,
) -> Matrix {
    tree_update_into_cols_par(&ParCtx::serial(), c0, c1, y1, t, full_n)
}

/// [`tree_update_into_cols`] with the gemms split across `par` (bitwise
/// identical at any width).
pub fn tree_update_into_cols_par(
    par: &ParCtx,
    c0: &mut Matrix,
    c1: &mut Matrix,
    y1: &Matrix,
    t: &Matrix,
    full_n: usize,
) -> Matrix {
    let (b, n) = c0.shape();
    let path = gemm_path(b, full_n, b);
    let mut s = Matrix::zeros(b, n);
    gemm_view_into_on_par(
        path,
        par,
        Trans::Yes,
        Trans::No,
        1.0,
        y1.as_view(),
        c1.as_view(),
        0.0,
        s.as_view_mut(),
    );
    s.add_assign(c0);
    let w = trmm_upper(Trans::Yes, 1.0, t, &s);
    c0.sub_assign(&w);
    gemm_view_into_on_par(
        path,
        par,
        Trans::No,
        Trans::No,
        -1.0,
        y1.as_view(),
        w.as_view(),
        1.0,
        c1.as_view_mut(),
    );
    w
}

/// One member's half of the pair step: updates only the caller's rows
/// (`cp`) in place, reading the buddy's rows (`peer`) without copying or
/// mutating them. `W` is identical on both sides of the pair — the two
/// halves compute it with the same expression, so an FT exchange where
/// each member calls this with its own role reproduces
/// [`tree_update_into`] bit-for-bit on the rows each member keeps.
pub fn tree_update_half(
    cp: &mut Matrix,
    peer: &Matrix,
    y1: &Matrix,
    t: &Matrix,
    is_top: bool,
) -> Matrix {
    let n = cp.cols();
    tree_update_half_cols(cp, peer, y1, t, is_top, n)
}

/// Column-segment variant of [`tree_update_half`] with the gemm dispatch
/// pinned to a `full_n`-wide op (see [`leaf_apply_cols_into`] for the
/// bitwise contract). `full_n == cp.cols()` degenerates to the plain
/// variant.
pub fn tree_update_half_cols(
    cp: &mut Matrix,
    peer: &Matrix,
    y1: &Matrix,
    t: &Matrix,
    is_top: bool,
    full_n: usize,
) -> Matrix {
    tree_update_half_cols_par(&ParCtx::serial(), cp, peer, y1, t, is_top, full_n)
}

/// [`tree_update_half_cols`] with the gemms split across `par` (bitwise
/// identical at any width — both pair members may even use different
/// widths and still agree on `W` bit-for-bit).
#[allow(clippy::too_many_arguments)]
pub fn tree_update_half_cols_par(
    par: &ParCtx,
    cp: &mut Matrix,
    peer: &Matrix,
    y1: &Matrix,
    t: &Matrix,
    is_top: bool,
    full_n: usize,
) -> Matrix {
    let (b, n) = cp.shape();
    let path = gemm_path(b, full_n, b);
    let mut s = Matrix::zeros(b, n);
    if is_top {
        // cp = C₀, peer = C₁: s = Y₁ᵀC₁ + C₀, then C₀ ← C₀ − W.
        gemm_view_into_on_par(
            path,
            par,
            Trans::Yes,
            Trans::No,
            1.0,
            y1.as_view(),
            peer.as_view(),
            0.0,
            s.as_view_mut(),
        );
        s.add_assign(cp);
        let w = trmm_upper(Trans::Yes, 1.0, t, &s);
        cp.sub_assign(&w);
        w
    } else {
        // cp = C₁, peer = C₀: same s, then C₁ ← C₁ − Y₁W.
        gemm_view_into_on_par(
            path,
            par,
            Trans::Yes,
            Trans::No,
            1.0,
            y1.as_view(),
            cp.as_view(),
            0.0,
            s.as_view_mut(),
        );
        s.add_assign(peer);
        let w = trmm_upper(Trans::Yes, 1.0, t, &s);
        gemm_view_into_on_par(
            path,
            par,
            Trans::No,
            Trans::No,
            -1.0,
            y1.as_view(),
            w.as_view(),
            1.0,
            cp.as_view_mut(),
        );
        w
    }
}

/// Copying wrapper over [`tree_update_into`] (kept for the oracle tests
/// and the XLA artifact path, which returns all three outputs anyway).
pub fn tree_update(c0: &Matrix, c1: &Matrix, y1: &Matrix, t: &Matrix) -> TreeStep {
    let mut c0h = c0.clone();
    let mut c1h = c1.clone();
    let w = tree_update_into(&mut c0h, &mut c1h, y1, t);
    TreeStep { w, c0: c0h, c1: c1h }
}

/// Single-buddy recovery recompute in place (paper III-C):
/// `C ← C − Y W`. With `Y = Y₁` this is the exact [`gemm_into`]
/// expression of the live bottom-half update, so a replayed lower block
/// is bit-identical to the one the dead rank computed. (The top member's
/// `Y = I` case is an elementwise subtract — the coordinator routes it
/// through `Backend::recover_top_into` instead of multiplying by an
/// identity.)
pub fn recover_block_into(c: &mut Matrix, y: &Matrix, w: &Matrix) {
    let n = c.cols();
    recover_block_cols_into(c, y, w, n);
}

/// Column-segment variant of [`recover_block_into`] with the gemm
/// dispatch pinned to a `full_n`-wide op — a replayed segment takes the
/// exact kernel path the live segmented update took, so the recovered
/// rows stay bit-identical under the lookahead pipeline too.
pub fn recover_block_cols_into(c: &mut Matrix, y: &Matrix, w: &Matrix, full_n: usize) {
    recover_block_cols_into_par(&ParCtx::serial(), c, y, w, full_n);
}

/// [`recover_block_cols_into`] with the gemm split across `par` (bitwise
/// identical at any width — replay stays exact even when the recovering
/// rank uses a different split than the dead one did).
pub fn recover_block_cols_into_par(
    par: &ParCtx,
    c: &mut Matrix,
    y: &Matrix,
    w: &Matrix,
    full_n: usize,
) {
    let b = c.rows();
    gemm_view_into_on_par(
        gemm_path(b, full_n, y.cols()),
        par,
        Trans::No,
        Trans::No,
        -1.0,
        y.as_view(),
        w.as_view(),
        1.0,
        c.as_view_mut(),
    );
}

/// Copying wrapper over [`recover_block_into`]: `Ĉ = C − Y W`.
pub fn recover_block(c: &Matrix, y: &Matrix, w: &Matrix) -> Matrix {
    let mut out = c.clone();
    recover_block_into(&mut out, y, w);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm_into, gram_residual, rel_err};

    fn q_from(y: &Matrix, t: &Matrix) -> Matrix {
        // Q = I - Y T Yᵀ
        let yt = gemm(Trans::No, Trans::No, 1.0, y, t);
        let mut q = Matrix::eye(y.rows());
        gemm_into(Trans::No, Trans::Yes, -1.0, &yt, y, 1.0, &mut q);
        q
    }

    #[test]
    fn qr_reconstructs_a() {
        let a = Matrix::randn(24, 8, 1);
        let f = householder_qr(&a);
        let q = q_from(&f.y, &f.t);
        let mut rfull = Matrix::zeros(24, 8);
        rfull.set_block(0, 0, &f.r);
        let qr = gemm(Trans::No, Trans::No, 1.0, &q, &rfull);
        assert!(rel_err(&qr, &a) < 1e-4, "rel err {}", rel_err(&qr, &a));
    }

    #[test]
    fn qr_q_orthogonal() {
        let a = Matrix::randn(16, 8, 2);
        let f = householder_qr(&a);
        let q = q_from(&f.y, &f.t);
        let qqt = gemm(Trans::No, Trans::Yes, 1.0, &q, &q);
        assert!(rel_err(&qqt, &Matrix::eye(16)) < 1e-4);
    }

    #[test]
    fn qr_y_unit_lower() {
        let a = Matrix::randn(12, 6, 3);
        let f = householder_qr(&a);
        for j in 0..6 {
            assert!((f.y[(j, j)] - 1.0).abs() < 1e-6);
            for i in 0..j {
                assert_eq!(f.y[(i, j)], 0.0);
            }
        }
        assert!(f.r.is_upper_triangular(0.0));
        assert!(f.t.is_upper_triangular(1e-6));
    }

    #[test]
    fn qr_zero_matrix_finite() {
        let f = householder_qr(&Matrix::zeros(8, 4));
        assert!(f.y.data().iter().all(|x| x.is_finite()));
        assert_eq!(f.r.fro_norm(), 0.0);
        assert_eq!(f.t.fro_norm(), 0.0);
    }

    #[test]
    fn qr_zero_row_padding_exact() {
        let a = Matrix::randn(24, 8, 7);
        let f1 = householder_qr(&a);
        let f2 = householder_qr(&a.pad_to(40, 8));
        assert!(rel_err(&f2.r, &f1.r) < 1e-5);
        assert!(rel_err(&f2.t, &f1.t) < 1e-5);
        assert!(rel_err(&f2.y.block(0, 0, 24, 8), &f1.y) < 1e-5);
        assert_eq!(f2.y.block(24, 0, 16, 8).fro_norm(), 0.0);
    }

    #[test]
    fn qr_blocked_matches_reference_oracle() {
        // Cross-check the blocked rewrite against the scalar original on
        // a panel wider than NB (multiple sub-panels + T merges).
        let a = Matrix::randn(96, 48, 11);
        let blk = householder_qr(&a);
        let refr = householder_qr_ref(&a);
        assert!(rel_err(&blk.r, &refr.r) < 1e-4, "r: {}", rel_err(&blk.r, &refr.r));
        assert!(rel_err(&blk.t, &refr.t) < 1e-4, "t: {}", rel_err(&blk.t, &refr.t));
        assert!(rel_err(&blk.y, &refr.y) < 1e-4, "y: {}", rel_err(&blk.y, &refr.y));
    }

    #[test]
    fn merge_y0_identity_for_triangular() {
        let r0 = Matrix::randn(8, 8, 1).triu();
        let r1 = Matrix::randn(8, 8, 2).triu();
        let (y0, _y1, _t, _r) = tsqr_merge(&r0, &r1);
        assert!(rel_err(&y0, &Matrix::eye(8)) < 1e-5);
    }

    #[test]
    fn merge_preserves_gram() {
        let r0 = Matrix::randn(8, 8, 3).triu();
        let r1 = Matrix::randn(8, 8, 4).triu();
        let (_y0, _y1, _t, r) = tsqr_merge(&r0, &r1);
        let stacked = r0.vstack(&r1);
        assert!(gram_residual(&stacked, &r) < 1e-4);
    }

    #[test]
    fn leaf_apply_matches_explicit_q() {
        let a = Matrix::randn(16, 4, 5);
        let f = householder_qr(&a);
        let c = Matrix::randn(16, 12, 6);
        let got = leaf_apply(&f.y, &f.t, &c);
        // explicit: Qᵀ C with Q = I - Y T Yᵀ → Qᵀ = I - Y Tᵀ Yᵀ
        let q = q_from(&f.y, &f.t);
        let want = gemm(Trans::Yes, Trans::No, 1.0, &q, &c);
        assert!(rel_err(&got, &want) < 1e-4);
    }

    #[test]
    fn tree_update_matches_stacked_apply() {
        let r0 = Matrix::randn(8, 8, 7).triu();
        let r1 = Matrix::randn(8, 8, 8).triu();
        let (y0, y1, t, _r) = tsqr_merge(&r0, &r1);
        let c0 = Matrix::randn(8, 16, 9);
        let c1 = Matrix::randn(8, 16, 10);
        let st = tree_update(&c0, &c1, &y1, &t);
        let yfull = y0.vstack(&y1);
        let cfull = c0.vstack(&c1);
        let want = leaf_apply(&yfull, &t, &cfull);
        assert!(rel_err(&st.c0, &want.block(0, 0, 8, 16)) < 1e-4);
        assert!(rel_err(&st.c1, &want.block(8, 0, 8, 16)) < 1e-4);
    }

    #[test]
    fn tree_update_halves_match_full_bitwise() {
        // The FT exchange depends on both members' W (and their own
        // halves) being identical to the pair computation.
        let r0 = Matrix::randn(8, 8, 17).triu();
        let r1 = Matrix::randn(8, 8, 18).triu();
        let (_y0, y1, t, _r) = tsqr_merge(&r0, &r1);
        let c0 = Matrix::randn(8, 24, 19);
        let c1 = Matrix::randn(8, 24, 20);
        let st = tree_update(&c0, &c1, &y1, &t);
        let mut top = c0.clone();
        let w_top = tree_update_half(&mut top, &c1, &y1, &t, true);
        let mut bot = c1.clone();
        let w_bot = tree_update_half(&mut bot, &c0, &y1, &t, false);
        assert_eq!(w_top, st.w);
        assert_eq!(w_bot, st.w);
        assert_eq!(top, st.c0);
        assert_eq!(bot, st.c1);
    }

    #[test]
    fn recovery_identity_both_sides() {
        // Paper III-C: both buddies can be reconstructed from (C', Y, W).
        let r0 = Matrix::randn(8, 8, 11).triu();
        let r1 = Matrix::randn(8, 8, 12).triu();
        let (_y0, y1, t, _r) = tsqr_merge(&r0, &r1);
        let c0 = Matrix::randn(8, 24, 13);
        let c1 = Matrix::randn(8, 24, 14);
        let st = tree_update(&c0, &c1, &y1, &t);
        let rec1 = recover_block(&c1, &y1, &st.w);
        assert!(rel_err(&rec1, &st.c1) < 1e-5);
        let rec0 = recover_block(&c0, &Matrix::eye(8), &st.w);
        assert!(rel_err(&rec0, &st.c0) < 1e-5);
    }

    #[test]
    fn leaf_apply_cols_matches_full_bitwise() {
        // Shapes chosen so a 16-wide segment's own volume would dispatch
        // to the small gemm path while the 48-wide full block is tiled —
        // the pinned dispatch must keep them bitwise identical anyway.
        let a = Matrix::randn(64, 16, 21);
        let f = householder_qr(&a);
        let c = Matrix::randn(64, 48, 22);
        let mut full = c.clone();
        leaf_apply_into(&f.y, &f.t, &mut full);
        let mut split = Matrix::zeros(64, 48);
        for j in [0usize, 16, 32] {
            let mut seg = c.block(0, j, 64, 16);
            leaf_apply_cols_into(&f.y, &f.t, &mut seg, 48);
            split.set_block(0, j, &seg);
        }
        assert_eq!(full, split, "segmented leaf apply must be bitwise exact");
    }

    #[test]
    fn tree_update_cols_match_full_bitwise() {
        // b = 32, full n = 96: the full-width ops are tiled while a
        // 32-wide segment's own volume sits exactly at the small-path
        // threshold — the pinned dispatch must bridge the difference.
        let r0 = Matrix::randn(32, 32, 23).triu();
        let r1 = Matrix::randn(32, 32, 24).triu();
        let (_y0, y1, t, _r) = tsqr_merge(&r0, &r1);
        let c0 = Matrix::randn(32, 96, 25);
        let c1 = Matrix::randn(32, 96, 26);
        let st = tree_update(&c0, &c1, &y1, &t);
        for j in [0usize, 32, 64] {
            // Per-segment halves, paths pinned to the 96-wide op.
            let mut top = c0.block(0, j, 32, 32);
            let peer_bot = c1.block(0, j, 32, 32);
            let w_top = tree_update_half_cols(&mut top, &peer_bot, &y1, &t, true, 96);
            assert_eq!(w_top, st.w.block(0, j, 32, 32), "W seg at {j}");
            assert_eq!(top, st.c0.block(0, j, 32, 32), "c0 seg at {j}");
            let mut bot = c1.block(0, j, 32, 32);
            let peer_top = c0.block(0, j, 32, 32);
            let w_bot = tree_update_half_cols(&mut bot, &peer_top, &y1, &t, false, 96);
            assert_eq!(w_bot, st.w.block(0, j, 32, 32));
            assert_eq!(bot, st.c1.block(0, j, 32, 32), "c1 seg at {j}");
            // The pair form and the replay recompute agree per segment.
            let mut pair0 = c0.block(0, j, 32, 32);
            let mut pair1 = c1.block(0, j, 32, 32);
            let w = tree_update_into_cols(&mut pair0, &mut pair1, &y1, &t, 96);
            assert_eq!(w, w_bot);
            assert_eq!(pair0, top);
            assert_eq!(pair1, bot);
            let mut rec = c1.block(0, j, 32, 32);
            recover_block_cols_into(&mut rec, &y1, &w, 96);
            assert_eq!(rec, bot, "replayed segment at {j}");
        }
    }

    #[test]
    fn qr_par_matches_serial_bitwise() {
        // Tall enough that the step-3 trailing gemm crosses the
        // PAR_MIN_WORK threshold and genuinely band-splits.
        let a = Matrix::randn(2048, 128, 27);
        let serial = householder_qr(&a);
        let par = householder_qr_par(&ParCtx::threads(3), &a);
        assert_eq!(serial.y, par.y, "Y must not depend on the split");
        assert_eq!(serial.t, par.t, "T must not depend on the split");
        assert_eq!(serial.r, par.r, "R must not depend on the split");
    }

    #[test]
    fn zero_column_padding_exact_for_updates() {
        let a = Matrix::randn(16, 4, 15);
        let f = householder_qr(&a);
        let c = Matrix::randn(16, 10, 16);
        let want = leaf_apply(&f.y, &f.t, &c);
        let got = leaf_apply(&f.y, &f.t, &c.pad_to(16, 16)).crop_to(16, 10);
        assert!(rel_err(&got, &want) < 1e-5);
    }
}
