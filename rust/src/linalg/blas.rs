//! Minimal BLAS-3 kernels over [`Matrix`]: `C = alpha * op(A) op(B) (+ C)`.
//!
//! These back the [`crate::backend::NativeBackend`] hot path, so the inner
//! loops are written cache-friendly (ikj order over row-major data, with a
//! transposed copy when `op(A) = Aᵀ` so the innermost loop always streams
//! contiguous rows).

use super::Matrix;

/// Transpose flag for [`gemm`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trans {
    /// Use the operand as stored.
    No,
    /// Use the operand transposed.
    Yes,
}

/// `alpha * op(A) @ op(B)` into a fresh matrix.
pub fn gemm(ta: Trans, tb: Trans, alpha: f32, a: &Matrix, b: &Matrix) -> Matrix {
    let (m, _k) = op_shape(ta, a);
    let (_, n) = op_shape(tb, b);
    let mut c = Matrix::zeros(m, n);
    gemm_into(ta, tb, alpha, a, b, 0.0, &mut c);
    c
}

fn op_shape(t: Trans, m: &Matrix) -> (usize, usize) {
    match t {
        Trans::No => m.shape(),
        Trans::Yes => (m.cols(), m.rows()),
    }
}

/// `C = alpha * op(A) @ op(B) + beta * C` (the workhorse).
pub fn gemm_into(
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
    beta: f32,
    c: &mut Matrix,
) {
    let (m, ka) = op_shape(ta, a);
    let (kb, n) = op_shape(tb, b);
    assert_eq!(ka, kb, "gemm inner-dim mismatch: {ka} vs {kb}");
    assert_eq!(c.shape(), (m, n), "gemm output shape mismatch");
    let k = ka;

    // Materialize transposed operands once so the inner loop is always a
    // contiguous row-stream (ikj order). For the small b x b factors this
    // copy is negligible; for big C it never happens (C is never
    // transposed by our callers).
    let at;
    let a_eff: &Matrix = match ta {
        Trans::No => a,
        Trans::Yes => {
            at = a.transpose();
            &at
        }
    };
    let bt;
    let b_eff: &Matrix = match tb {
        Trans::No => b,
        Trans::Yes => {
            bt = b.transpose();
            &bt
        }
    };

    if beta == 0.0 {
        c.data_mut().fill(0.0);
    } else if beta != 1.0 {
        for x in c.data_mut() {
            *x *= beta;
        }
    }

    let ad = a_eff.data();
    let bd = b_eff.data();
    let cd = c.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut cd[i * n..(i + 1) * n];
        for (p, &aip) in arow.iter().enumerate() {
            let f = alpha * aip;
            if f == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            for (cij, &bpj) in crow.iter_mut().zip(brow) {
                *cij += f * bpj;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    fn close(a: &Matrix, b: &Matrix) {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn gemm_nn_matches_naive() {
        let a = Matrix::randn(7, 5, 1);
        let b = Matrix::randn(5, 9, 2);
        close(&gemm(Trans::No, Trans::No, 1.0, &a, &b), &naive(&a, &b));
    }

    #[test]
    fn gemm_tn_matches_naive() {
        let a = Matrix::randn(5, 7, 3);
        let b = Matrix::randn(5, 9, 4);
        close(
            &gemm(Trans::Yes, Trans::No, 1.0, &a, &b),
            &naive(&a.transpose(), &b),
        );
    }

    #[test]
    fn gemm_nt_matches_naive() {
        let a = Matrix::randn(4, 6, 5);
        let b = Matrix::randn(8, 6, 6);
        close(
            &gemm(Trans::No, Trans::Yes, 1.0, &a, &b),
            &naive(&a, &b.transpose()),
        );
    }

    #[test]
    fn gemm_alpha_beta() {
        let a = Matrix::randn(3, 3, 7);
        let b = Matrix::randn(3, 3, 8);
        let mut c = Matrix::eye(3);
        gemm_into(Trans::No, Trans::No, 2.0, &a, &b, 3.0, &mut c);
        let mut want = naive(&a, &b);
        for x in want.data_mut() {
            *x *= 2.0;
        }
        let want = want.add(&{
            let mut e = Matrix::eye(3);
            for x in e.data_mut() {
                *x *= 3.0;
            }
            e
        });
        close(&c, &want);
    }

    #[test]
    #[should_panic(expected = "inner-dim mismatch")]
    fn gemm_dim_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        gemm(Trans::No, Trans::No, 1.0, &a, &b);
    }
}
