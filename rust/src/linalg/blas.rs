//! Level-3 kernels over [`Matrix`] / [`MatrixView`]: `C = alpha * op(A)
//! op(B) + beta * C`, plus triangular specializations.
//!
//! These back the [`crate::backend::NativeBackend`] hot path. The GEMM is
//! a BLIS-style tiled/packed kernel (see DESIGN.md "Kernel architecture"):
//! operands are packed into cache-sized `MC x KC` / `KC x NC` blocks, and
//! an `MR x NR` register micro-kernel (runtime-dispatched AVX/NEON with a
//! scalar oracle, `linalg::simd`) streams contiguous packed panels with
//! the accumulator tile held in vector registers. Packing
//! reads through strided [`MatrixView`]s, so transposed operands and
//! sub-block views cost a pack pass (O(mk + kn)), never an extra
//! materialized copy of the operand.
//!
//! The pre-tile ikj kernel is kept as [`gemm_ref_into`]: it is the
//! correctness oracle for the property tests and the "before" baseline in
//! `benches/kernels.rs`.

use super::matrix::{Matrix, MatrixView, MatrixViewMut};
use super::par::{ParCtx, ParTask};
use super::simd::{self, SimdLevel};

/// Transpose flag for [`gemm`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trans {
    /// Use the operand as stored.
    No,
    /// Use the operand transposed.
    Yes,
}

// --- tile geometry (f32, sized for ~32K L1 / ~512K L2 caches) ----------

/// Rows of op(A) packed per block.
const MC: usize = 64;
/// Inner (k) depth packed per block.
const KC: usize = 256;
/// Columns of op(B) packed per block.
const NC: usize = 256;
/// Micro-kernel rows (accumulator tile height).
pub(crate) const MR: usize = 4;
/// Micro-kernel columns (accumulator tile width: two AVX f32x8 vectors,
/// or four NEON f32x4 vectors — see `linalg::simd`).
pub(crate) const NR: usize = 16;
/// Minimum `m * n * k` before the row-panel thread split engages.
const PAR_MIN_WORK: usize = 1 << 21;
/// At or below this op volume the pack-buffer setup dominates the math:
/// take the direct (allocation-free) strided loop instead. Dispatch
/// depends only on the shape, so a given op always takes the same path —
/// replay bit-equality is unaffected.
const SMALL_WORK: usize = 32 * 32 * 32;

/// `alpha * op(A) @ op(B)` into a fresh matrix (serial, best SIMD).
pub fn gemm(ta: Trans, tb: Trans, alpha: f32, a: &Matrix, b: &Matrix) -> Matrix {
    gemm_with(&ParCtx::serial(), SimdLevel::best(), ta, tb, alpha, a, b)
}

/// [`gemm`] with the parallel context and SIMD level chosen by the
/// caller. Benches and property tests use this to compare kernel
/// variants; results are bitwise identical across every `(par, lvl)`
/// combination (see `linalg::simd` module docs).
pub fn gemm_with(
    par: &ParCtx,
    lvl: SimdLevel,
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
) -> Matrix {
    let (m, _k) = op_shape(ta, a.shape());
    let (_, n) = op_shape(tb, b.shape());
    let mut c = Matrix::zeros(m, n);
    gemm_view_into_with(par, lvl, ta, tb, alpha, a.as_view(), b.as_view(), 0.0, c.as_view_mut());
    c
}

fn op_shape(t: Trans, (r, c): (usize, usize)) -> (usize, usize) {
    match t {
        Trans::No => (r, c),
        Trans::Yes => (c, r),
    }
}

/// `C = alpha * op(A) @ op(B) + beta * C` (the workhorse).
pub fn gemm_into(
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
    beta: f32,
    c: &mut Matrix,
) {
    gemm_view_into(ta, tb, alpha, a.as_view(), b.as_view(), beta, c.as_view_mut());
}

/// `alpha * op(A) @ op(B)` over borrowed views, into a fresh matrix.
pub fn gemm_view(ta: Trans, tb: Trans, alpha: f32, a: MatrixView<'_>, b: MatrixView<'_>) -> Matrix {
    let (m, _k) = op_shape(ta, a.shape());
    let (_, n) = op_shape(tb, b.shape());
    let mut c = Matrix::zeros(m, n);
    gemm_view_into(ta, tb, alpha, a, b, 0.0, c.as_view_mut());
    c
}

/// Which kernel body a gemm call runs. Per-element accumulation order
/// differs between the two (direct ikj vs register-tile-per-KC-block),
/// so callers that split one logical product into column segments must
/// pin the path to the *full-width* op's choice ([`gemm_path`] +
/// [`gemm_view_into_on`]) to stay bitwise identical to the unsplit call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmPath {
    /// Allocation-free strided ikj loop (tiny products).
    Small,
    /// BLIS-style packed/tiled kernel (everything else).
    Tiled,
}

/// The path [`gemm_view_into`] takes for an `(m, n, k)` op volume.
pub fn gemm_path(m: usize, n: usize, k: usize) -> GemmPath {
    // The coordinator issues hordes of tiny b x b products (T algebra,
    // TSQR merges); packing would cost more than the flops.
    if m * n * k <= SMALL_WORK {
        GemmPath::Small
    } else {
        GemmPath::Tiled
    }
}

/// View-based `C = alpha * op(A) @ op(B) + beta * C`: the zero-copy entry
/// point — `A`, `B` and `C` may all be strided windows into larger
/// matrices, so callers update trailing blocks in place.
///
/// Results are bit-deterministic and independent of the parallel split
/// and SIMD level: each output row's accumulation order depends only on
/// the k-blocking, never on which band, register tile, or vector lane
/// the row lands in.
pub fn gemm_view_into(
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: MatrixView<'_>,
    b: MatrixView<'_>,
    beta: f32,
    c: MatrixViewMut<'_>,
) {
    gemm_view_into_par(&ParCtx::serial(), ta, tb, alpha, a, b, beta, c);
}

/// [`gemm_view_into`] splitting large products across `par` (the band
/// split engages above [`PAR_MIN_WORK`]; smaller ops run inline).
#[allow(clippy::too_many_arguments)]
pub fn gemm_view_into_par(
    par: &ParCtx,
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: MatrixView<'_>,
    b: MatrixView<'_>,
    beta: f32,
    c: MatrixViewMut<'_>,
) {
    let (m, k) = op_shape(ta, a.shape());
    let n = op_shape(tb, b.shape()).1;
    gemm_view_into_core(gemm_path(m, n, k), SimdLevel::best(), par, ta, tb, alpha, a, b, beta, c);
}

/// [`gemm_view_into`] with both the parallel context and the SIMD level
/// chosen by the caller (property tests pin non-best levels and strided
/// views through this).
#[allow(clippy::too_many_arguments)]
pub fn gemm_view_into_with(
    par: &ParCtx,
    lvl: SimdLevel,
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: MatrixView<'_>,
    b: MatrixView<'_>,
    beta: f32,
    c: MatrixViewMut<'_>,
) {
    let (m, k) = op_shape(ta, a.shape());
    let n = op_shape(tb, b.shape()).1;
    gemm_view_into_core(gemm_path(m, n, k), lvl, par, ta, tb, alpha, a, b, beta, c);
}

/// [`gemm_view_into`] with the small/tiled dispatch pinned by the caller.
///
/// Per output element both paths accumulate over `k` in the same order,
/// and the tiled path's per-element result is independent of how the
/// columns of `C` are partitioned into packing blocks — so a caller that
/// computes a column segment of a wider product through the *same* path
/// the full-width call would take gets bitwise-identical values for
/// those columns. This is the foundation of the lookahead pipeline's
/// `L > 0 ≡ L = 0` determinism guarantee (see DESIGN.md "Lookahead
/// dataflow engine").
pub fn gemm_view_into_on(
    path: GemmPath,
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: MatrixView<'_>,
    b: MatrixView<'_>,
    beta: f32,
    c: MatrixViewMut<'_>,
) {
    gemm_view_into_core(path, SimdLevel::best(), &ParCtx::serial(), ta, tb, alpha, a, b, beta, c);
}

/// [`gemm_view_into_on`] splitting across `par` (the pinned-path variant
/// the `qr` column kernels use when a parallel context travels with the
/// job).
#[allow(clippy::too_many_arguments)]
pub fn gemm_view_into_on_par(
    path: GemmPath,
    par: &ParCtx,
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: MatrixView<'_>,
    b: MatrixView<'_>,
    beta: f32,
    c: MatrixViewMut<'_>,
) {
    gemm_view_into_core(path, SimdLevel::best(), par, ta, tb, alpha, a, b, beta, c);
}

/// Shared dispatch body behind every gemm entry point.
#[allow(clippy::too_many_arguments)]
fn gemm_view_into_core(
    path: GemmPath,
    lvl: SimdLevel,
    par: &ParCtx,
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: MatrixView<'_>,
    b: MatrixView<'_>,
    beta: f32,
    mut c: MatrixViewMut<'_>,
) {
    let (m, ka) = op_shape(ta, a.shape());
    let (kb, n) = op_shape(tb, b.shape());
    assert_eq!(ka, kb, "gemm inner-dim mismatch: {ka} vs {kb}");
    assert_eq!(c.shape(), (m, n), "gemm output shape mismatch");
    let k = ka;

    scale_rows(&mut c, beta);
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }

    if path == GemmPath::Small {
        gemm_small(ta, tb, alpha, a, b, &mut c);
        return;
    }

    if par.width() > 1 && m >= 2 * MR && m * n * k >= PAR_MIN_WORK {
        gemm_parallel(lvl, ta, tb, alpha, a, b, par, c);
    } else {
        gemm_band(lvl, ta, tb, alpha, a, b, c);
    }
}

/// Balanced row-band split for the parallel driver: distribute the
/// `ceil(m / MR)` register strips over at most `bands` bands so no band
/// exceeds `ceil(strips / bands)` strips (the old `m.div_ceil(bands)`
/// rounding could hand the tail band every remainder row). Returns the
/// row count of each band; counts sum to `m` and every band is
/// non-empty (fewer bands are returned when `m` has fewer strips).
pub fn par_band_rows(m: usize, bands: usize) -> Vec<usize> {
    let strips = m.div_ceil(MR);
    let bands = bands.max(1).min(strips.max(1));
    let base = strips / bands;
    let rem = strips % bands;
    let mut rows = Vec::with_capacity(bands);
    let mut used = 0usize;
    for i in 0..bands {
        let s = base + usize::from(i < rem);
        // Only the last band can hit the clamp: every earlier prefix
        // covers at most strips-1 strips, i.e. fewer than m rows.
        let r = (s * MR).min(m - used);
        rows.push(r);
        used += r;
    }
    debug_assert_eq!(used, m);
    rows
}

/// Band-split driver. All of `op(B)` is packed **once** up front into
/// a single buffer (one segment per `(jc, pc)` block) shared read-only
/// by every band task; `C` is divided into contiguous row bands
/// ([`par_band_rows`]) and each band becomes one [`ParTask`] handed to
/// the caller's [`ParCtx`] — the job's worker pool, scoped threads, or
/// inline — walking the same `jc`/`pc` block order as the serial path
/// over its rows. No duplicated B packing, one A-pack buffer per band.
/// Per-row accumulation order is unchanged, so results stay
/// bit-identical to the serial path at any width.
fn gemm_parallel(
    lvl: SimdLevel,
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: MatrixView<'_>,
    b: MatrixView<'_>,
    par: &ParCtx,
    c: MatrixViewMut<'_>,
) {
    let m = c.rows();
    let n = c.cols();
    let k = op_shape(ta, a.shape()).1;
    let jblocks = n.div_ceil(NC);
    let kblocks = k.div_ceil(KC);

    // Pack every op(B) block once (segment offsets precomputed; total is
    // op(B) rounded up to NR columns — comparable to the old transposed
    // copy the pre-tile kernel materialized).
    let mut offs = Vec::with_capacity(jblocks * kblocks);
    let mut total = 0usize;
    for jb in 0..jblocks {
        let nc = NC.min(n - jb * NC);
        for pb in 0..kblocks {
            let kc = KC.min(k - pb * KC);
            offs.push(total);
            total += kc * nc.div_ceil(NR) * NR;
        }
    }
    let mut bpack = vec![0.0f32; total];
    for jb in 0..jblocks {
        let nc = NC.min(n - jb * NC);
        for pb in 0..kblocks {
            let kc = KC.min(k - pb * KC);
            let off = offs[jb * kblocks + pb];
            let len = kc * nc.div_ceil(NR) * NR;
            pack_b(lvl, &mut bpack[off..off + len], b, tb, pb * KC, kc, jb * NC, nc);
        }
    }

    // One contiguous, strip-balanced row band of C per task.
    let rows = par_band_rows(m, par.width());
    let mut parts: Vec<(usize, MatrixViewMut<'_>)> = Vec::with_capacity(rows.len());
    let mut rest = c;
    let mut row0 = 0;
    for (i, &r) in rows.iter().enumerate() {
        if i + 1 == rows.len() {
            parts.push((row0, rest));
            break;
        }
        let (head, tail) = rest.split_rows(r);
        parts.push((row0, head));
        row0 += r;
        rest = tail;
    }

    let bpack = &bpack[..];
    let offs = &offs[..];
    let tasks: Vec<ParTask<'_>> = parts
        .into_iter()
        .map(|(r0, mut band)| {
            Box::new(move || {
                let bm = band.rows();
                let kc_cap = KC.min(k);
                let mut abuf =
                    vec![0.0f32; MC.min(bm).div_ceil(MR) * MR * kc_cap];
                for jb in 0..jblocks {
                    let jc = jb * NC;
                    let nc = NC.min(n - jc);
                    for pb in 0..kblocks {
                        let pc = pb * KC;
                        let kc = KC.min(k - pc);
                        let bp = &bpack[offs[jb * kblocks + pb]..];
                        let mut ic = 0;
                        while ic < bm {
                            let mc = MC.min(bm - ic);
                            pack_a(&mut abuf, a, ta, r0 + ic, mc, pc, kc);
                            macro_kernel(
                                lvl, &abuf, bp, kc, mc, nc, alpha, &mut band, ic, jc,
                            );
                            ic += MC;
                        }
                    }
                }
            }) as ParTask<'_>
        })
        .collect();
    par.run(tasks);
}

/// Scale every row of `c` by `beta` (`0.0` zero-fills).
fn scale_rows(c: &mut MatrixViewMut<'_>, beta: f32) {
    if beta == 1.0 {
        return;
    }
    for i in 0..c.rows() {
        let row = c.row_mut(i);
        if beta == 0.0 {
            row.fill(0.0);
        } else {
            for x in row {
                *x *= beta;
            }
        }
    }
}

/// Element `(i, p)` of `op(A)` where `i` indexes rows of the op result.
#[inline(always)]
fn op_at(t: Trans, m: MatrixView<'_>, i: usize, p: usize) -> f32 {
    match t {
        Trans::No => m.at(i, p),
        Trans::Yes => m.at(p, i),
    }
}

/// Allocation-free path for small products: ikj over the views, with the
/// reference kernel's zero-skip (structural zeros of small triangular /
/// identity operands cost nothing).
fn gemm_small(
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: MatrixView<'_>,
    b: MatrixView<'_>,
    c: &mut MatrixViewMut<'_>,
) {
    let (m, k) = op_shape(ta, a.shape());
    let n = c.cols();
    for i in 0..m {
        let crow = c.row_mut(i);
        for p in 0..k {
            let f = alpha * op_at(ta, a, i, p);
            if f == 0.0 {
                continue;
            }
            match tb {
                Trans::No => {
                    for (cij, &bpj) in crow.iter_mut().zip(b.row(p)) {
                        *cij += f * bpj;
                    }
                }
                Trans::Yes => {
                    for (j, cij) in crow.iter_mut().enumerate().take(n) {
                        *cij += f * b.at(j, p);
                    }
                }
            }
        }
    }
}

/// Serial tiled kernel over the whole of `C` (the thread split uses
/// [`gemm_parallel`] instead, which shares the packed `B` across bands).
fn gemm_band(
    lvl: SimdLevel,
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: MatrixView<'_>,
    b: MatrixView<'_>,
    mut c: MatrixViewMut<'_>,
) {
    let m = c.rows();
    let n = c.cols();
    let k = op_shape(ta, a.shape()).1;
    // Packed panels: A as MR-row strips (MR values contiguous per k), B as
    // NR-column strips (NR values contiguous per k). Edges are zero-padded
    // so the micro-kernel always runs a full MR x NR tile. Buffers are
    // sized to the problem (capped at one block) so mid-size ops don't pay
    // the full 320 KB block allocation.
    let kc_cap = KC.min(k);
    let mut abuf = vec![0.0f32; MC.min(m).div_ceil(MR) * MR * kc_cap];
    let mut bbuf = vec![0.0f32; kc_cap * NC.min(n).div_ceil(NR) * NR];

    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc = KC.min(k - pc);
            pack_b(lvl, &mut bbuf, b, tb, pc, kc, jc, nc);
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                pack_a(&mut abuf, a, ta, ic, mc, pc, kc);
                macro_kernel(lvl, &abuf, &bbuf, kc, mc, nc, alpha, &mut c, ic, jc);
                ic += MC;
            }
            pc += KC;
        }
        jc += NC;
    }
}

/// Pack `op(A)[i0..i0+mc, p0..p0+kc]` into MR-row panels. Full panels of
/// a transposed operand are contiguous MR-wide row chunks of `A`, copied
/// directly; everything else (untransposed A, zero-padded edge panels)
/// takes the strided gather.
fn pack_a(
    buf: &mut [f32],
    a: MatrixView<'_>,
    ta: Trans,
    i0: usize,
    mc: usize,
    p0: usize,
    kc: usize,
) {
    let panels = mc.div_ceil(MR);
    for ir in 0..panels {
        let base = ir * kc * MR;
        if ta == Trans::Yes && (ir + 1) * MR <= mc {
            let c0 = i0 + ir * MR;
            for p in 0..kc {
                let off = base + p * MR;
                buf[off..off + MR].copy_from_slice(&a.row(p0 + p)[c0..c0 + MR]);
            }
            continue;
        }
        for p in 0..kc {
            let off = base + p * MR;
            for r in 0..MR {
                let i = ir * MR + r;
                buf[off + r] =
                    if i < mc { op_at(ta, a, i0 + i, p0 + p) } else { 0.0 };
            }
        }
    }
}

/// Pack `op(B)[p0..p0+kc, j0..j0+nc]` into NR-column panels. Full panels
/// of an untransposed operand are contiguous NR-wide row chunks of `B`,
/// moved with the SIMD copy at `lvl` (bit-exact by construction);
/// transposed operands and zero-padded edge panels take the strided
/// gather.
fn pack_b(
    lvl: SimdLevel,
    buf: &mut [f32],
    b: MatrixView<'_>,
    tb: Trans,
    p0: usize,
    kc: usize,
    j0: usize,
    nc: usize,
) {
    let panels = nc.div_ceil(NR);
    for jr in 0..panels {
        let base = jr * kc * NR;
        if tb == Trans::No && (jr + 1) * NR <= nc {
            let c0 = j0 + jr * NR;
            for p in 0..kc {
                let off = base + p * NR;
                simd::copy_slices(lvl, &b.row(p0 + p)[c0..c0 + NR], &mut buf[off..off + NR]);
            }
            continue;
        }
        for p in 0..kc {
            let off = base + p * NR;
            for cc in 0..NR {
                let j = jr * NR + cc;
                buf[off + cc] =
                    if j < nc { op_at(tb, b, p0 + p, j0 + j) } else { 0.0 };
            }
        }
    }
}

/// Drive the micro-kernel over every MR x NR tile of one packed block and
/// accumulate `alpha * tile` into `C`.
#[allow(clippy::too_many_arguments)]
fn macro_kernel(
    lvl: SimdLevel,
    abuf: &[f32],
    bbuf: &[f32],
    kc: usize,
    mc: usize,
    nc: usize,
    alpha: f32,
    c: &mut MatrixViewMut<'_>,
    ic: usize,
    jc: usize,
) {
    let mpanels = mc.div_ceil(MR);
    let npanels = nc.div_ceil(NR);
    let mut acc = [[0.0f32; NR]; MR];
    for jr in 0..npanels {
        let bp = &bbuf[jr * kc * NR..(jr + 1) * kc * NR];
        for ir in 0..mpanels {
            let ap = &abuf[ir * kc * MR..(ir + 1) * kc * MR];
            for row in acc.iter_mut() {
                row.fill(0.0);
            }
            simd::micro_kernel(lvl, ap, bp, &mut acc);
            let rmax = MR.min(mc - ir * MR);
            let cmax = NR.min(nc - jr * NR);
            for (r, arow) in acc.iter().enumerate().take(rmax) {
                let j0 = jc + jr * NR;
                let crow = &mut c.row_mut(ic + ir * MR + r)[j0..j0 + cmax];
                for (cij, v) in crow.iter_mut().zip(&arow[..cmax]) {
                    *cij += alpha * v;
                }
            }
        }
    }
}

// The register micro-kernel lives in `linalg::simd`: the scalar oracle
// plus runtime-dispatched AVX/NEON variants pinned bitwise to it.

/// Upper-triangular multiply `alpha * op(T) @ B` with `T` upper
/// triangular: the trmm-style specialization for the `T` and `R` factors.
/// Skips the structural-zero half of `T` (half the flops of a dense
/// `gemm`) while streaming contiguous rows of `B`.
pub fn trmm_upper(tt: Trans, alpha: f32, t: &Matrix, b: &Matrix) -> Matrix {
    let bt = t.rows();
    assert_eq!(t.shape(), (bt, bt), "trmm_upper needs a square T");
    assert_eq!(b.rows(), bt, "trmm_upper inner-dim mismatch");
    let n = b.cols();
    let mut out = Matrix::zeros(bt, n);
    let bd = b.data();
    let od = out.data_mut();
    for i in 0..bt {
        let orow = &mut od[i * n..(i + 1) * n];
        let prange = match tt {
            Trans::No => i..bt,      // row i of U
            Trans::Yes => 0..i + 1,  // column i of U (row i of Uᵀ)
        };
        for p in prange {
            let tip = match tt {
                Trans::No => t[(i, p)],
                Trans::Yes => t[(p, i)],
            };
            let f = alpha * tip;
            if f == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            for (o, &x) in orow.iter_mut().zip(brow) {
                *o += f * x;
            }
        }
    }
    out
}

/// The pre-tile ikj kernel, kept verbatim as the correctness oracle for
/// the property tests and the "before" baseline in `benches/kernels.rs`.
/// Semantics match [`gemm_into`] up to f32 summation order.
pub fn gemm_ref_into(
    ta: Trans,
    tb: Trans,
    alpha: f32,
    a: &Matrix,
    b: &Matrix,
    beta: f32,
    c: &mut Matrix,
) {
    let (m, ka) = op_shape(ta, a.shape());
    let (kb, n) = op_shape(tb, b.shape());
    assert_eq!(ka, kb, "gemm inner-dim mismatch: {ka} vs {kb}");
    assert_eq!(c.shape(), (m, n), "gemm output shape mismatch");
    let k = ka;

    // Materialize transposed operands once so the inner loop is always a
    // contiguous row-stream (ikj order).
    let at;
    let a_eff: &Matrix = match ta {
        Trans::No => a,
        Trans::Yes => {
            at = a.transpose();
            &at
        }
    };
    let bt;
    let b_eff: &Matrix = match tb {
        Trans::No => b,
        Trans::Yes => {
            bt = b.transpose();
            &bt
        }
    };

    if beta == 0.0 {
        c.data_mut().fill(0.0);
    } else if beta != 1.0 {
        for x in c.data_mut() {
            *x *= beta;
        }
    }

    let ad = a_eff.data();
    let bd = b_eff.data();
    let cd = c.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut cd[i * n..(i + 1) * n];
        for (p, &aip) in arow.iter().enumerate() {
            let f = alpha * aip;
            if f == 0.0 {
                continue;
            }
            let brow = &bd[p * n..(p + 1) * n];
            for (cij, &bpj) in crow.iter_mut().zip(brow) {
                *cij += f * bpj;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[(i, p)] * b[(p, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    fn close(a: &Matrix, b: &Matrix) {
        assert_eq!(a.shape(), b.shape());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn gemm_nn_matches_naive() {
        let a = Matrix::randn(7, 5, 1);
        let b = Matrix::randn(5, 9, 2);
        close(&gemm(Trans::No, Trans::No, 1.0, &a, &b), &naive(&a, &b));
    }

    #[test]
    fn gemm_tn_matches_naive() {
        let a = Matrix::randn(5, 7, 3);
        let b = Matrix::randn(5, 9, 4);
        close(
            &gemm(Trans::Yes, Trans::No, 1.0, &a, &b),
            &naive(&a.transpose(), &b),
        );
    }

    #[test]
    fn gemm_nt_matches_naive() {
        let a = Matrix::randn(4, 6, 5);
        let b = Matrix::randn(8, 6, 6);
        close(
            &gemm(Trans::No, Trans::Yes, 1.0, &a, &b),
            &naive(&a, &b.transpose()),
        );
    }

    #[test]
    fn gemm_alpha_beta() {
        let a = Matrix::randn(3, 3, 7);
        let b = Matrix::randn(3, 3, 8);
        let mut c = Matrix::eye(3);
        gemm_into(Trans::No, Trans::No, 2.0, &a, &b, 3.0, &mut c);
        let mut want = naive(&a, &b);
        for x in want.data_mut() {
            *x *= 2.0;
        }
        let want = want.add(&{
            let mut e = Matrix::eye(3);
            for x in e.data_mut() {
                *x *= 3.0;
            }
            e
        });
        close(&c, &want);
    }

    #[test]
    fn gemm_tile_boundaries_match_reference() {
        // Shapes straddling every tile constant: MR/NR edges, > MC rows,
        // > KC depth, > NC cols.
        for &(m, k, n) in
            &[(1, 1, 1), (3, 5, 7), (4, 16, 16), (65, 257, 17), (130, 300, 33)]
        {
            let a = Matrix::randn(m, k, (m * 31 + k) as u64);
            let b = Matrix::randn(k, n, (k * 17 + n) as u64);
            let got = gemm(Trans::No, Trans::No, 1.0, &a, &b);
            let mut want = Matrix::zeros(m, n);
            gemm_ref_into(Trans::No, Trans::No, 1.0, &a, &b, 0.0, &mut want);
            assert!(
                crate::linalg::rel_err(&got, &want) < 1e-4,
                "({m},{k},{n}): {}",
                crate::linalg::rel_err(&got, &want)
            );
        }
    }

    #[test]
    fn gemm_empty_dims_are_noops() {
        // k = 0: C = beta * C, no contribution.
        let a = Matrix::zeros(3, 0);
        let b = Matrix::zeros(0, 4);
        let mut c = Matrix::eye(3).pad_to(3, 4);
        gemm_into(Trans::No, Trans::No, 1.0, &a, &b, 1.0, &mut c);
        assert_eq!(c, Matrix::eye(3).pad_to(3, 4));
        // m = 0 / n = 0 products exist and are empty.
        assert_eq!(
            gemm(Trans::No, Trans::No, 1.0, &Matrix::zeros(0, 5), &Matrix::zeros(5, 4))
                .shape(),
            (0, 4)
        );
    }

    #[test]
    fn gemm_par_split_matches_serial_bitwise() {
        let a = Matrix::randn(150, 64, 1);
        let b = Matrix::randn(64, 220, 2);
        let serial = gemm(Trans::No, Trans::No, 1.0, &a, &b);
        for width in [2, 3, 7] {
            let par = gemm_with(
                &ParCtx::threads(width),
                SimdLevel::best(),
                Trans::No,
                Trans::No,
                1.0,
                &a,
                &b,
            );
            assert_eq!(serial, par, "width {width} split must not change results");
        }
    }

    #[test]
    fn gemm_simd_levels_match_scalar_bitwise() {
        // Big enough for the tiled path with edge tiles in both dims.
        let a = Matrix::randn(67, 70, 11);
        let b = Matrix::randn(70, 83, 12);
        let scalar =
            gemm_with(&ParCtx::serial(), SimdLevel::Scalar, Trans::No, Trans::No, 1.0, &a, &b);
        for lvl in SimdLevel::available() {
            let got = gemm_with(&ParCtx::serial(), lvl, Trans::No, Trans::No, 1.0, &a, &b);
            assert_eq!(scalar, got, "level {} must be bitwise scalar", lvl.name());
        }
    }

    #[test]
    fn par_band_rows_never_overfills_a_band() {
        for m in [4usize, 8, 12, 16, 20, 33, 64, 65, 127, 128, 150, 1000] {
            for bands in 1..=8 {
                let rows = par_band_rows(m, bands);
                assert_eq!(rows.iter().sum::<usize>(), m, "m={m} bands={bands}");
                assert!(rows.len() <= bands);
                let strips = m.div_ceil(MR);
                let cap = strips.div_ceil(rows.len()) * MR;
                for &r in &rows {
                    assert!(r > 0, "empty band at m={m} bands={bands}");
                    assert!(
                        r <= cap,
                        "band of {r} rows exceeds {cap}-row cap at m={m} bands={bands}"
                    );
                }
            }
        }
    }

    #[test]
    fn gemm_views_match_block_copies() {
        let big_a = Matrix::randn(12, 10, 3);
        let big_b = Matrix::randn(11, 9, 4);
        let av = big_a.view(2, 1, 6, 5);
        let bv = big_b.view(3, 2, 5, 7);
        let got = gemm_view(Trans::No, Trans::No, 1.0, av, bv);
        let want =
            gemm(Trans::No, Trans::No, 1.0, &big_a.block(2, 1, 6, 5), &big_b.block(3, 2, 5, 7));
        assert_eq!(got, want, "strided packing must match copied blocks");
    }

    #[test]
    fn trmm_matches_gemm_on_triangles() {
        let t = Matrix::randn(8, 8, 5).triu();
        let b = Matrix::randn(8, 12, 6);
        close(
            &trmm_upper(Trans::No, 1.0, &t, &b),
            &gemm(Trans::No, Trans::No, 1.0, &t, &b),
        );
        close(
            &trmm_upper(Trans::Yes, -2.0, &t, &b),
            &gemm(Trans::Yes, Trans::No, -2.0, &t, &b),
        );
    }

    #[test]
    #[should_panic(expected = "inner-dim mismatch")]
    fn gemm_dim_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(4, 2);
        gemm(Trans::No, Trans::No, 1.0, &a, &b);
    }

    #[test]
    fn gemm_path_matches_dispatch_threshold() {
        assert_eq!(gemm_path(16, 16, 64), GemmPath::Small); // 16384 <= 32768
        assert_eq!(gemm_path(32, 32, 32), GemmPath::Small); // boundary inclusive
        assert_eq!(gemm_path(16, 48, 64), GemmPath::Tiled); // 49152 > 32768
    }

    #[test]
    fn gemm_column_split_with_pinned_path_is_bitwise() {
        // A column segment of a product, computed through the path the
        // FULL-width call takes, must be bitwise identical to the full
        // call's columns — even when the segment's own volume would have
        // dispatched differently. Exercised with beta = 1 (accumulating
        // onto C), where the small and tiled paths genuinely differ.
        let (m, k, n, n1) = (32, 32, 48, 16);
        let a = Matrix::randn(m, k, 1);
        let b = Matrix::randn(k, n, 2);
        let c0 = Matrix::randn(m, n, 3);
        assert_eq!(gemm_path(m, n, k), GemmPath::Tiled);
        assert_eq!(gemm_path(m, n1, k), GemmPath::Small, "split would re-dispatch");

        let mut full = c0.clone();
        gemm_into(Trans::No, Trans::No, -1.0, &a, &b, 1.0, &mut full);

        let mut split = c0.clone();
        let path = gemm_path(m, n, k);
        let mut j = 0;
        while j < n {
            let w = n1.min(n - j);
            gemm_view_into_on(
                path,
                Trans::No,
                Trans::No,
                -1.0,
                a.as_view(),
                b.view(0, j, k, w),
                1.0,
                split.view_mut(0, j, m, w),
            );
            j += w;
        }
        assert_eq!(full, split, "pinned column split must be bitwise exact");
    }
}
