//! Intra-rank parallel execution context: the replacement for the old
//! `set_par_threads` process-global.
//!
//! The GEMM row-panel split used to read a process-wide atomic, which
//! raced when concurrent service tenants wanted different splits and
//! could oversubscribe the machine (pool workers *plus* ad-hoc scoped
//! threads). A [`ParCtx`] instead travels with the job: drivers derive
//! one from `RunConfig::par` and the run's own worker pool
//! ([`crate::sim::sched::Pool::par_ctx`]), install it on the job's
//! [`crate::backend::Backend`], and the kernels split work by handing
//! closures to the context. Results are bitwise independent of the
//! context (see DESIGN.md "SIMD micro-kernels & pool-integrated
//! parallelism"), so it is purely a resource-placement knob.
//!
//! The executor trait lives in `linalg` (not `sim`) so the kernels do
//! not depend on the scheduler; `sim::sched::Pool` implements it.

use std::sync::Arc;

/// One unit of kernel work handed to a [`ParExecutor`]. Borrows the
/// caller's operands (`'s`), so executors must not let it escape the
/// `run_scoped` call that received it.
pub type ParTask<'s> = Box<dyn FnOnce() + Send + 's>;

/// Something that can execute a batch of borrowed closures and return
/// only when **every one of them has run** (structured / scoped
/// parallelism). Implementations may run tasks on any thread, including
/// the calling one; tasks are pure compute and never block.
pub trait ParExecutor: Send + Sync {
    /// Run every task in `tasks` to completion before returning. If a
    /// task panics, the panic must propagate to this caller (after the
    /// remaining tasks have been accounted for).
    fn run_scoped<'s>(&self, tasks: Vec<ParTask<'s>>);
}

/// A [`ParExecutor`] that spawns one plain scoped `std::thread` per
/// task — the standalone-CLI replacement for the old `set_par_threads`
/// behavior, used when no simulation pool owns the cores.
pub struct ScopedThreads;

impl ParExecutor for ScopedThreads {
    fn run_scoped<'s>(&self, tasks: Vec<ParTask<'s>>) {
        std::thread::scope(|scope| {
            for t in tasks {
                scope.spawn(t);
            }
        });
    }
}

/// Cloneable handle bundling a [`ParExecutor`] with the split width the
/// caller asked for (`RunConfig::par`). `width() <= 1` means serial; the
/// kernels then never build a task batch at all.
#[derive(Clone)]
pub struct ParCtx {
    exec: Option<Arc<dyn ParExecutor>>,
    width: usize,
}

impl Default for ParCtx {
    fn default() -> Self {
        Self::serial()
    }
}

impl std::fmt::Debug for ParCtx {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ParCtx")
            .field("width", &self.width)
            .field("executor", &self.exec.is_some())
            .finish()
    }
}

impl ParCtx {
    /// The serial context: kernels run inline on the calling thread.
    pub fn serial() -> Self {
        Self { exec: None, width: 1 }
    }

    /// Split across `n` plain scoped threads ([`ScopedThreads`]).
    /// `n <= 1` degenerates to [`ParCtx::serial`].
    pub fn threads(n: usize) -> Self {
        if n <= 1 {
            Self::serial()
        } else {
            Self { exec: Some(Arc::new(ScopedThreads)), width: n }
        }
    }

    /// Split across a caller-supplied executor (e.g. a simulation
    /// worker pool). `width <= 1` degenerates to [`ParCtx::serial`].
    pub fn with_executor(exec: Arc<dyn ParExecutor>, width: usize) -> Self {
        if width <= 1 {
            Self::serial()
        } else {
            Self { exec: Some(exec), width }
        }
    }

    /// The requested split width (1 = serial).
    pub fn width(&self) -> usize {
        self.width
    }

    /// True when [`ParCtx::run`] would execute inline.
    pub fn is_serial(&self) -> bool {
        self.width <= 1 || self.exec.is_none()
    }

    /// Execute every task, returning when all are complete. Inline (in
    /// order) for the serial context or a single task; otherwise
    /// delegated to the executor.
    pub fn run<'s>(&self, tasks: Vec<ParTask<'s>>) {
        match &self.exec {
            Some(exec) if tasks.len() > 1 => exec.run_scoped(tasks),
            _ => {
                for t in tasks {
                    t();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn serial_runs_inline_in_order() {
        let ctx = ParCtx::serial();
        let order = std::sync::Mutex::new(Vec::new());
        ctx.run(vec![
            Box::new(|| order.lock().unwrap().push(1)) as ParTask<'_>,
            Box::new(|| order.lock().unwrap().push(2)),
        ]);
        assert_eq!(*order.lock().unwrap(), vec![1, 2]);
        assert!(ctx.is_serial());
        assert_eq!(ctx.width(), 1);
    }

    #[test]
    fn threads_runs_every_task() {
        let ctx = ParCtx::threads(3);
        assert_eq!(ctx.width(), 3);
        assert!(!ctx.is_serial());
        let hits = AtomicUsize::new(0);
        let tasks: Vec<ParTask<'_>> = (0..7)
            .map(|_| Box::new(|| {
                hits.fetch_add(1, Ordering::SeqCst);
            }) as ParTask<'_>)
            .collect();
        ctx.run(tasks);
        assert_eq!(hits.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn width_one_degenerates_to_serial() {
        assert!(ParCtx::threads(1).is_serial());
        assert!(ParCtx::threads(0).is_serial());
        assert!(ParCtx::with_executor(Arc::new(ScopedThreads), 1).is_serial());
    }
}
