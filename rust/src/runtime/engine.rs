//! The PJRT execution engine: one dedicated OS thread owns the
//! `PjRtClient` and a cache of compiled executables; everyone else sends
//! [`ExecRequest`]s over an mpsc channel and blocks on a reply channel.
//!
//! Why a thread and not a shared object: the `xla` crate's PJRT handles
//! are raw C++ pointers with no `Send`/`Sync` story; confining them to
//! one thread makes the rest of the system trivially `Send` and matches
//! how a serving runtime would pin a device context anyway.
//!
//! The PJRT binding itself is only available in deployment images, so the
//! real execution loop is gated behind the `pjrt` cargo feature (which
//! additionally requires adding the vendored `xla` binding to
//! `Cargo.toml` — it is not on crates.io). Without the feature the
//! engine still starts (manifest loading, shape selection and
//! `ftcaqr info` all work), but every exec request fails fast with a
//! clear error instead of a link failure at build time.

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use super::manifest::{ArtifactEntry, Manifest};
use crate::linalg::Matrix;

/// A single execute call: artifact name + positional inputs.
pub struct ExecRequest {
    /// Artifact name (file stem from the manifest).
    pub artifact: String,
    /// Positional inputs, already padded to the artifact's shapes.
    pub inputs: Vec<Matrix>,
    /// Where the engine thread sends the outputs.
    pub reply: std::sync::mpsc::Sender<Result<Vec<Matrix>>>,
}

/// Cumulative engine counters (lock-free reads).
#[derive(Debug, Default)]
pub struct EngineStats {
    /// Artifact executions served.
    pub executions: AtomicU64,
    /// Compilations performed (cache misses).
    pub compilations: AtomicU64,
    /// Nanoseconds spent executing.
    pub exec_nanos: AtomicU64,
    /// Nanoseconds spent compiling.
    pub compile_nanos: AtomicU64,
}

impl EngineStats {
    /// (executions, compilations, exec seconds, compile seconds)
    pub fn snapshot(&self) -> (u64, u64, f64, f64) {
        (
            self.executions.load(Ordering::Relaxed),
            self.compilations.load(Ordering::Relaxed),
            self.exec_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            self.compile_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        )
    }
}

/// Cloneable handle used by coordinator ranks to run artifacts.
#[derive(Clone)]
pub struct EngineHandle {
    tx: std::sync::mpsc::Sender<ExecRequest>,
    manifest: Arc<Manifest>,
    stats: Arc<EngineStats>,
}

impl EngineHandle {
    /// The artifact manifest the engine serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Cumulative engine counters.
    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Execute an artifact (blocks until the engine thread replies).
    pub fn exec(&self, entry: &ArtifactEntry, inputs: Vec<Matrix>) -> Result<Vec<Matrix>> {
        let (reply, rx) = std::sync::mpsc::channel();
        self.tx
            .send(ExecRequest { artifact: entry.name(), inputs, reply })
            .map_err(|_| anyhow!("engine thread is gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped the reply"))?
    }

    /// Pre-compile a set of artifacts (hides compile latency at startup).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for n in names {
            let entry = self
                .manifest
                .artifacts
                .iter()
                .find(|e| e.name() == *n)
                .ok_or_else(|| anyhow!("unknown artifact {n}"))?;
            let inputs: Vec<Matrix> =
                entry.inputs.iter().map(|s| Matrix::zeros(s[0], s[1])).collect();
            self.exec(entry, inputs)?;
        }
        Ok(())
    }
}

/// The engine thread itself. Dropping the last [`EngineHandle`] shuts the
/// thread down (the request channel closes).
pub struct Engine;

impl Engine {
    /// Start the engine over an artifact directory.
    pub fn start(artifact_dir: impl AsRef<std::path::Path>) -> Result<EngineHandle> {
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        let stats = Arc::new(EngineStats::default());
        let (tx, rx) = std::sync::mpsc::channel::<ExecRequest>();
        let m2 = manifest.clone();
        let s2 = stats.clone();
        std::thread::Builder::new()
            .name("pjrt-engine".into())
            .spawn(move || {
                if let Err(e) = engine_loop(rx, m2, s2) {
                    eprintln!("ftcaqr: engine thread exited with error: {e:#}");
                }
            })
            .context("spawning engine thread")?;
        Ok(EngineHandle { tx, manifest, stats })
    }
}

/// Stub loop (no `pjrt` feature): answer every request with an error so
/// callers get a diagnosable failure instead of a missing-linker build.
#[cfg(not(feature = "pjrt"))]
fn engine_loop(
    rx: std::sync::mpsc::Receiver<ExecRequest>,
    _manifest: Arc<Manifest>,
    _stats: Arc<EngineStats>,
) -> Result<()> {
    while let Ok(req) = rx.recv() {
        let _ = req.reply.send(Err(anyhow!(
            "artifact {}: ftcaqr was built without the `pjrt` feature; \
             the XLA backend is unavailable (use --backend native, or build \
             with `--features pjrt` in a deployment image)",
            req.artifact
        )));
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn engine_loop(
    rx: std::sync::mpsc::Receiver<ExecRequest>,
    manifest: Arc<Manifest>,
    stats: Arc<EngineStats>,
) -> Result<()> {
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
    crate::simlog!(
        "pjrt engine up: platform={} devices={}",
        client.platform_name(),
        client.device_count()
    );
    let by_name: HashMap<String, ArtifactEntry> = manifest
        .artifacts
        .iter()
        .map(|e| (e.name(), e.clone()))
        .collect();
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(req) = rx.recv() {
        let result = serve_one(&client, &manifest, &by_name, &mut cache, &stats, &req);
        let _ = req.reply.send(result);
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn serve_one(
    client: &xla::PjRtClient,
    manifest: &Manifest,
    by_name: &HashMap<String, ArtifactEntry>,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    stats: &EngineStats,
    req: &ExecRequest,
) -> Result<Vec<Matrix>> {
    let entry = by_name
        .get(&req.artifact)
        .ok_or_else(|| anyhow!("unknown artifact {}", req.artifact))?;

    if !cache.contains_key(&req.artifact) {
        let t0 = std::time::Instant::now();
        let path = manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", req.artifact))?;
        stats.compilations.fetch_add(1, Ordering::Relaxed);
        stats
            .compile_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        cache.insert(req.artifact.clone(), exe);
    }
    let exe = &cache[&req.artifact];

    // Validate + convert inputs.
    if req.inputs.len() != entry.inputs.len() {
        return Err(anyhow!(
            "{}: expected {} inputs, got {}",
            req.artifact,
            entry.inputs.len(),
            req.inputs.len()
        ));
    }
    let mut literals = Vec::with_capacity(req.inputs.len());
    for (i, (m, want)) in req.inputs.iter().zip(&entry.inputs).enumerate() {
        let (r, c) = m.shape();
        if [r, c] != want[..] {
            return Err(anyhow!(
                "{} input {i}: shape ({r},{c}) != artifact {:?}",
                req.artifact,
                want
            ));
        }
        let lit = xla::Literal::vec1(m.data())
            .reshape(&[r as i64, c as i64])
            .map_err(|e| anyhow!("reshape input {i}: {e:?}"))?;
        literals.push(lit);
    }

    let t0 = std::time::Instant::now();
    let bufs = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow!("execute {}: {e:?}", req.artifact))?;
    let tuple = bufs[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal {}: {e:?}", req.artifact))?;
    stats.executions.fetch_add(1, Ordering::Relaxed);
    stats
        .exec_nanos
        .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);

    // All artifacts are lowered with return_tuple=True.
    let parts = tuple
        .to_tuple()
        .map_err(|e| anyhow!("untuple {}: {e:?}", req.artifact))?;
    if parts.len() != entry.outputs.len() {
        return Err(anyhow!(
            "{}: artifact declares {} outputs, runtime returned {}",
            req.artifact,
            entry.outputs.len(),
            parts.len()
        ));
    }
    let mut out = Vec::with_capacity(parts.len());
    for (lit, shape) in parts.into_iter().zip(&entry.outputs) {
        let v = lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec {}: {e:?}", req.artifact))?;
        let (r, c) = (shape[0], shape[1]);
        out.push(Matrix::from_vec(r, c, v));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    //! Engine execution tests live in `rust/tests/runtime_xla.rs` (they
    //! need built artifacts); here we only check startup failure modes.
    use super::*;

    #[test]
    fn start_fails_without_manifest() {
        let dir = std::env::temp_dir().join("ftcaqr-no-manifest");
        let _ = std::fs::create_dir_all(&dir);
        assert!(Engine::start(&dir).is_err());
    }
}
