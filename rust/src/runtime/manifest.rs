//! `artifacts/manifest.txt` parsing and shape-ladder selection.
//!
//! Every artifact is an HLO-text module with *static* shapes. A request
//! for `(op, dims)` is served by the smallest artifact whose padded dims
//! dominate the request: `b` must match exactly (it is a configuration
//! parameter, chosen from the ladder at config time), `m` and `n` are
//! padded up (zero-padding is numerically exact for all five ops).
//!
//! Format (written by `python/compile/aot.py`, one line per artifact):
//! ```text
//! artifact|<op>|<file>|k=v,k=v|RxC;RxC|RxC;RxC
//! ```
//! (A JSON twin exists for humans; the Rust loader parses the text form
//! because the offline crate set has no JSON parser.)

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One lowered (op, shape) entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub op: String,
    /// Shape parameters the artifact was lowered with (e.g. m/b/n).
    pub params: BTreeMap<String, usize>,
    /// HLO-text file name, relative to the artifact dir.
    pub file: String,
    /// Input shapes, in argument order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes, in tuple order.
    pub outputs: Vec<Vec<usize>>,
}

impl ArtifactEntry {
    /// Unique artifact key (file stem).
    pub fn name(&self) -> String {
        self.file.trim_end_matches(".hlo.txt").to_string()
    }
}

/// Parsed manifest plus the directory it lives in.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Lowering profile the artifacts were built with.
    pub profile: String,
    /// JAX version that produced the HLO.
    pub jax_version: String,
    /// Pallas tile size baked into the kernels.
    pub tile: usize,
    /// All lowered (op, shape) entries.
    pub artifacts: Vec<ArtifactEntry>,
    /// Directory the manifest (and HLO files) live in.
    pub dir: PathBuf,
}

fn parse_shapes(s: &str) -> Result<Vec<Vec<usize>>> {
    if s.is_empty() {
        return Ok(vec![]);
    }
    s.split(';')
        .map(|shape| {
            shape
                .split('x')
                .map(|d| d.parse::<usize>().context("bad dim"))
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Load `dir/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let mut m = Manifest {
            profile: String::new(),
            jax_version: String::new(),
            tile: 0,
            artifacts: Vec::new(),
            dir,
        };
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(v) = line.strip_prefix("profile=") {
                m.profile = v.to_string();
            } else if let Some(v) = line.strip_prefix("jax=") {
                m.jax_version = v.to_string();
            } else if let Some(v) = line.strip_prefix("tile=") {
                m.tile = v.parse().context("bad tile")?;
            } else if let Some(rest) = line.strip_prefix("artifact|") {
                let parts: Vec<&str> = rest.split('|').collect();
                if parts.len() != 5 {
                    bail!("manifest line {}: expected 5 fields", lineno + 1);
                }
                let mut params = BTreeMap::new();
                for kv in parts[2].split(',').filter(|s| !s.is_empty()) {
                    let (k, v) = kv
                        .split_once('=')
                        .with_context(|| format!("bad param '{kv}'"))?;
                    params.insert(k.to_string(), v.parse()?);
                }
                m.artifacts.push(ArtifactEntry {
                    op: parts[0].to_string(),
                    file: parts[1].to_string(),
                    params,
                    inputs: parse_shapes(parts[3])?,
                    outputs: parse_shapes(parts[4])?,
                });
            } else {
                bail!("manifest line {}: unrecognized '{line}'", lineno + 1);
            }
        }
        Ok(m)
    }

    /// Absolute path of an entry's HLO text.
    pub fn path_of(&self, e: &ArtifactEntry) -> PathBuf {
        self.dir.join(&e.file)
    }

    /// Entries for one op.
    pub fn entries(&self, op: &str) -> impl Iterator<Item = &ArtifactEntry> {
        let op = op.to_string();
        self.artifacts.iter().filter(move |e| e.op == op)
    }

    /// Select the smallest artifact for `op` that fits `want`.
    ///
    /// `b` (when present in `want`) must match exactly; every other
    /// parameter must satisfy `artifact >= want` and the artifact with
    /// the smallest padded volume (product of params) wins.
    pub fn select(&self, op: &str, want: &BTreeMap<&str, usize>) -> Result<&ArtifactEntry> {
        let mut best: Option<(&ArtifactEntry, usize)> = None;
        'outer: for e in self.entries(op) {
            let mut volume = 1usize;
            for (k, v) in want {
                let have = match e.params.get(*k) {
                    Some(h) => *h,
                    None => continue 'outer,
                };
                let fits = if *k == "b" { have == *v } else { have >= *v };
                if !fits {
                    continue 'outer;
                }
                volume = volume.saturating_mul(have);
            }
            match best {
                Some((_, bv)) if bv <= volume => {}
                _ => best = Some((e, volume)),
            }
        }
        match best {
            Some((e, _)) => Ok(e),
            None => bail!(
                "no artifact for op={op} want={want:?}; available: {:?}",
                self.entries(op).map(|e| &e.params).collect::<Vec<_>>()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# ftcaqr manifest v1
profile=test
jax=0.8.2
tile=128
artifact|tsqr_merge|tsqr_merge_b8.hlo.txt|b=8|8x8;8x8|8x8;8x8;8x8;8x8
artifact|leaf_apply|leaf_apply_b16_m64_n32.hlo.txt|b=16,m=64,n=32|64x16;16x16;64x32|64x32
artifact|leaf_apply|leaf_apply_b16_m64_n64.hlo.txt|b=16,m=64,n=64|64x16;16x16;64x64|64x64
artifact|leaf_apply|leaf_apply_b16_m128_n32.hlo.txt|b=16,m=128,n=32|128x16;16x16;128x32|128x32
";

    fn sample() -> Manifest {
        Manifest::parse(SAMPLE, PathBuf::new()).unwrap()
    }

    #[test]
    fn parses_header_and_entries() {
        let m = sample();
        assert_eq!(m.profile, "test");
        assert_eq!(m.tile, 128);
        assert_eq!(m.artifacts.len(), 4);
        let e = &m.artifacts[1];
        assert_eq!(e.op, "leaf_apply");
        assert_eq!(e.params["n"], 32);
        assert_eq!(e.inputs, vec![vec![64, 16], vec![16, 16], vec![64, 32]]);
        assert_eq!(e.outputs, vec![vec![64, 32]]);
        assert_eq!(e.name(), "leaf_apply_b16_m64_n32");
    }

    #[test]
    fn select_exact_match() {
        let m = sample();
        let want = BTreeMap::from([("b", 16), ("m", 64), ("n", 32)]);
        assert_eq!(m.select("leaf_apply", &want).unwrap().params["m"], 64);
    }

    #[test]
    fn select_pads_up_minimal() {
        let m = sample();
        let want = BTreeMap::from([("b", 16), ("m", 60), ("n", 40)]);
        let e = m.select("leaf_apply", &want).unwrap();
        assert_eq!(e.params["m"], 64);
        assert_eq!(e.params["n"], 64);
    }

    #[test]
    fn select_b_is_exact() {
        let m = sample();
        let want = BTreeMap::from([("b", 4)]);
        assert!(m.select("tsqr_merge", &want).is_err());
    }

    #[test]
    fn select_missing_op_errors() {
        let m = sample();
        assert!(m.select("panel_qr", &BTreeMap::new()).is_err());
    }

    #[test]
    fn bad_lines_rejected() {
        assert!(Manifest::parse("artifact|x|y\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("garbage\n", PathBuf::new()).is_err());
    }

    #[test]
    fn load_real_manifest_if_present() {
        // Integration-ish: when `make artifacts` has run, validate it.
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.artifacts.is_empty());
        for e in &m.artifacts {
            assert!(m.path_of(e).exists(), "missing {}", e.file);
            assert!(!e.outputs.is_empty());
        }
        for op in ["panel_qr", "tsqr_merge", "leaf_apply", "tree_update", "recover"] {
            assert!(m.entries(op).next().is_some(), "no {op} artifacts");
        }
    }
}
