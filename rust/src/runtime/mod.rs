//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! and execute them from the Rust hot path.
//!
//! Layout:
//! * [`manifest`] — parse `artifacts/manifest.json`, select the smallest
//!   artifact that fits a requested shape (zero-padding is exact, see
//!   DESIGN.md "Shape strategy").
//! * [`engine`] — a dedicated OS thread owning the `PjRtClient` and the
//!   compiled-executable cache; callers talk to it over an mpsc request
//!   channel and await a oneshot reply. PJRT handles never cross threads,
//!   and the rest of the system stays `Send`.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, EngineHandle, EngineStats};
pub use manifest::{ArtifactEntry, Manifest};
