//! ULFM / FT-MPI error-handling semantics (paper §II).
//!
//! The paper frames recovery in terms of the four FT-MPI communicator
//! semantics; [`Semantics`] selects which one the coordinator applies when
//! a failure is detected:
//!
//! * `Shrink`  — survivors renumber into a smaller communicator; the dead
//!   rank's *data* must still be reconstructed somewhere, so its block is
//!   adopted by a survivor.
//! * `Blank`   — the hole stays; operations addressed to the dead rank
//!   return [`Fail::RankFailed`] and the algorithm routes around it.
//! * `Rebuild` — a replacement process is spawned with the dead process's
//!   rank and recovered state (the mode the paper's protocol targets).
//! * `Abort`   — conventional non-FT behaviour: the whole run fails.

/// Communicator-level failure-handling policy (FT-MPI / ULFM, paper §II).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Semantics {
    /// Survivors renumber into a smaller communicator.
    Shrink,
    /// The hole stays; operations addressed to it error.
    Blank,
    /// A replacement process is spawned with recovered state.
    #[default]
    Rebuild,
    /// Conventional non-FT behaviour: the whole run fails.
    Abort,
}

impl std::str::FromStr for Semantics {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_ascii_lowercase().as_str() {
            "shrink" => Ok(Self::Shrink),
            "blank" => Ok(Self::Blank),
            "rebuild" => Ok(Self::Rebuild),
            "abort" => Ok(Self::Abort),
            other => Err(format!("unknown semantics '{other}'")),
        }
    }
}

impl std::fmt::Display for Semantics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Semantics::Shrink => "shrink",
            Semantics::Blank => "blank",
            Semantics::Rebuild => "rebuild",
            Semantics::Abort => "abort",
        };
        f.write_str(s)
    }
}

/// Failure conditions surfaced to the algorithms.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Fail {
    /// A communication involved rank `rank`, which is dead (ULFM-style
    /// detection: errors surface only at operations that touch the dead
    /// process, paper §II).
    RankFailed { rank: usize },
    /// This rank was itself killed by the fault injector.
    Killed,
    /// The run was aborted (Semantics::Abort after a failure).
    Aborted,
    /// The simulated world shut down underneath us.
    WorldGone,
    /// The scheduler detected a global stall: every live task parked
    /// with no event in flight. A protocol bug surfaced as an error
    /// instead of a hang.
    Stalled,
    /// The rank's task panicked mid-poll (an infrastructure bug, e.g. a
    /// backend failure). The pool fails the task and kills the rank
    /// instead of wedging every waiter on the job.
    TaskPanicked,
    /// Recovery is impossible: rank `rank` completed a step whose
    /// retained redundancy was lost together with the step buddy — both
    /// copies of the paper's `{W, T, C', Y₁}` inventory are gone
    /// (e.g. a correlated buddy-pair kill, or a buddy killed while the
    /// rebuild was still replaying).
    Unrecoverable {
        /// The rank whose state can no longer be reconstructed.
        rank: usize,
        /// Process-grid coordinates `(row, col)` of `rank`, so a
        /// multi-panel grid failure is attributable from the error
        /// alone.
        grid: (usize, usize),
        /// Panel whose retained redundancy was lost.
        panel: usize,
        /// Tree step within the panel.
        step: usize,
        /// Update-segment lane (0 for TSQR / whole-width traffic).
        lane: u32,
    },
}

impl std::fmt::Display for Fail {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Fail::RankFailed { rank } => write!(f, "rank {rank} failed"),
            Fail::Killed => write!(f, "killed by fault injector"),
            Fail::Aborted => write!(f, "run aborted"),
            Fail::WorldGone => write!(f, "world shut down"),
            Fail::Stalled => write!(f, "scheduler stall: every live task parked"),
            Fail::TaskPanicked => write!(f, "rank task panicked (infrastructure bug)"),
            Fail::Unrecoverable { rank, grid, panel, step, lane } => {
                write!(
                    f,
                    "rank {rank} (grid {},{}) unrecoverable: buddy redundancy \
                     lost at panel {panel} step {step} lane {lane}",
                    grid.0, grid.1
                )
            }
        }
    }
}

impl std::error::Error for Fail {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn semantics_parse_roundtrip() {
        for s in [Semantics::Shrink, Semantics::Blank, Semantics::Rebuild, Semantics::Abort] {
            assert_eq!(s.to_string().parse::<Semantics>().unwrap(), s);
        }
        assert!("bogus".parse::<Semantics>().is_err());
    }

    #[test]
    fn default_is_rebuild() {
        assert_eq!(Semantics::default(), Semantics::Rebuild);
    }

    #[test]
    fn fail_display() {
        assert_eq!(Fail::RankFailed { rank: 3 }.to_string(), "rank 3 failed");
        let u = Fail::Unrecoverable {
            rank: 5,
            grid: (1, 2),
            panel: 3,
            step: 1,
            lane: 4,
        };
        let s = u.to_string();
        assert!(s.contains("grid 1,2"), "{s}");
        assert!(s.contains("panel 3 step 1 lane 4"), "{s}");
    }
}
