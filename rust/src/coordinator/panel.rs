//! Panel geometry for 1-D block-row CAQR.
//!
//! The global `rows x cols` matrix is distributed by block rows: rank `r`
//! owns rows `[r*m_local, (r+1)*m_local)`. Panel `k` covers columns
//! `[k*b, (k+1)*b)` and *active* rows `[k*b, rows)`; ranks whose rows lie
//! entirely above the active region have retired from the computation.

use crate::config::RunConfig;

/// Geometry of one panel iteration for one rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PanelGeom {
    /// Panel index.
    pub k: usize,
    /// First participating rank (owns the diagonal block).
    pub owner: usize,
    /// Participant count (`procs - owner`).
    pub q: usize,
    /// This rank's tree index (`rank - owner`); only valid when
    /// `participates`.
    pub idx: usize,
    /// Whether this rank still holds active rows.
    pub participates: bool,
    /// First active row within the local block.
    pub start: usize,
    /// Active row count within the local block.
    pub active_m: usize,
    /// First trailing column (`(k+1)*b`).
    pub trail_col: usize,
    /// Trailing width (`cols - (k+1)*b`).
    pub n_trail: usize,
}

/// Compute panel `k`'s geometry for `rank` under `cfg`.
pub fn geometry(cfg: &RunConfig, rank: usize, k: usize) -> PanelGeom {
    let b = cfg.block;
    let m_local = cfg.local_rows();
    let diag_row = k * b;
    let owner = diag_row / m_local;
    let participates = rank >= owner;
    let start = if rank == owner { diag_row - owner * m_local } else { 0 };
    let active_m = if participates { m_local - start } else { 0 };
    PanelGeom {
        k,
        owner,
        q: cfg.procs - owner,
        idx: rank.saturating_sub(owner),
        participates,
        start,
        active_m,
        trail_col: (k + 1) * b,
        n_trail: cfg.cols - (k + 1) * b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RunConfig {
        RunConfig { rows: 512, cols: 128, block: 32, procs: 4, ..Default::default() }
        // m_local = 128, panels = 4
    }

    #[test]
    fn first_panel_everyone_participates() {
        let c = cfg();
        for r in 0..4 {
            let g = geometry(&c, r, 0);
            assert!(g.participates);
            assert_eq!(g.owner, 0);
            assert_eq!(g.q, 4);
            assert_eq!(g.idx, r);
            assert_eq!(g.start, if r == 0 { 0 } else { 0 });
            assert_eq!(g.active_m, 128);
            assert_eq!(g.n_trail, 96);
        }
    }

    #[test]
    fn owner_rows_shrink_with_panels() {
        let c = cfg();
        // panel 1: diag row 32 still inside rank 0's block.
        let g = geometry(&c, 0, 1);
        assert_eq!(g.owner, 0);
        assert_eq!(g.start, 32);
        assert_eq!(g.active_m, 96);
        // panel 3: diag row 96.
        let g3 = geometry(&c, 0, 3);
        assert_eq!(g3.start, 96);
        assert_eq!(g3.active_m, 32);
        assert_eq!(g3.n_trail, 0);
    }

    #[test]
    fn retirement() {
        // Taller config so ownership moves past rank 0.
        let c = RunConfig {
            rows: 256,
            cols: 128,
            block: 32,
            procs: 4,
            ..Default::default()
        };
        // m_local = 64 -> panel 2 diag row = 64 -> owner = rank 1.
        let g = geometry(&c, 0, 2);
        assert!(!g.participates);
        assert_eq!(g.owner, 1);
        let g1 = geometry(&c, 1, 2);
        assert!(g1.participates);
        assert_eq!(g1.idx, 0);
        assert_eq!(g1.q, 3);
        assert_eq!(g1.start, 0);
        let g3 = geometry(&c, 3, 3);
        assert_eq!(g3.idx, 2);
        assert_eq!(g3.start, 0);
    }

    #[test]
    fn active_m_is_block_multiple_when_config_valid() {
        let c = cfg();
        for k in 0..c.panels() {
            for r in 0..c.procs {
                let g = geometry(&c, r, k);
                if g.participates {
                    assert_eq!(g.active_m % c.block, 0, "k={k} r={r}");
                    assert!(g.active_m >= c.block);
                }
            }
        }
    }
}
