//! Panel geometry for block-cyclic CAQR on a `Pr x Pc` process grid.
//!
//! Rows are block-distributed over grid rows (grid row `gr` owns rows
//! `[gr*m_local, (gr+1)*m_local)` with `m_local = rows / Pr`); width-`b`
//! column blocks are block-cyclic over grid columns (block `j` lives on
//! grid column `j % Pc`). Panel `k` covers columns `[k*b, (k+1)*b)` and
//! *active* rows `[k*b, rows)`: its TSQR runs down grid column `k % Pc`
//! over the grid rows at or below the diagonal, and every grid column
//! runs the mirrored update tree over the same grid rows on its own
//! local trailing columns. Grid rows whose rows lie entirely above the
//! active region have retired from the computation.
//!
//! With `Pc = 1` (the default grid) every field collapses to the
//! original 1-D block-row geometry: `owner == owner_row`, local column
//! indices equal global ones, and `n_trail` is the full trailing width.

use crate::config::RunConfig;
use crate::coordinator::grid::Grid;

/// Geometry of one panel iteration for one rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PanelGeom {
    /// Panel index.
    pub k: usize,
    /// Rank holding the diagonal block (`rank_at(owner_row, panel_gcol)`).
    pub owner: usize,
    /// First participating grid row (owns the diagonal rows).
    pub owner_row: usize,
    /// Participant count down a grid column (`Pr - owner_row`) — the
    /// size of both the TSQR tree and every grid column's update tree.
    pub q: usize,
    /// This rank's tree index (`grid row - owner_row`); only valid when
    /// `participates`.
    pub idx: usize,
    /// Whether this rank still holds active rows (its grid row is at or
    /// below the diagonal).
    pub participates: bool,
    /// This rank's grid column.
    pub gcol: usize,
    /// Grid column owning panel `k`'s column block (`k % Pc`).
    pub panel_gcol: usize,
    /// Whether this rank factorizes the panel (`gcol == panel_gcol`,
    /// and `participates`).
    pub in_panel_col: bool,
    /// Local column of the panel block on the panel grid column
    /// (`(k / Pc) * b`). Only meaningful when `in_panel_col`.
    pub panel_lcol: usize,
    /// First active row within the local block.
    pub start: usize,
    /// Active row count within the local block.
    pub active_m: usize,
    /// First trailing column *in this rank's local column space*: local
    /// columns at or beyond this belong to global blocks `> k`.
    pub trail_col: usize,
    /// Local trailing width — columns of this rank's blocks with global
    /// index `> k`. (`Pc = 1`: the full `cols - (k+1)*b`.)
    pub n_trail: usize,
    /// Global trailing width (`cols - (k+1)*b`). Kernel dispatch is
    /// pinned to this width on every grid column, so any `Pr x Pc`
    /// produces factors bitwise-identical to `Pr x 1`.
    pub full_trail: usize,
}

/// Compute panel `k`'s geometry for `rank` under `cfg`.
pub fn geometry(cfg: &RunConfig, rank: usize, k: usize) -> PanelGeom {
    let b = cfg.block;
    let grid = Grid::from_cfg(cfg);
    let m_local = cfg.local_rows();
    let (grow, gcol) = grid.coords(rank);
    let diag_row = k * b;
    let owner_row = diag_row / m_local;
    let panel_gcol = grid.col_owner(k);
    let participates = grow >= owner_row;
    let start = if grow == owner_row { diag_row - owner_row * m_local } else { 0 };
    let active_m = if participates { m_local - start } else { 0 };
    let nblocks = cfg.panels();
    // Local blocks with global index <= k owned by this grid column sit
    // (compactly) before the trailing ones.
    let lead_blocks = grid.blocks_before(gcol, k + 1);
    PanelGeom {
        k,
        owner: grid.rank_at(owner_row, panel_gcol),
        owner_row,
        q: grid.rows() - owner_row,
        idx: grow.saturating_sub(owner_row),
        participates,
        gcol,
        panel_gcol,
        in_panel_col: participates && gcol == panel_gcol,
        panel_lcol: grid.local_block(k) * b,
        start,
        active_m,
        trail_col: lead_blocks * b,
        n_trail: (grid.local_blocks(gcol, nblocks) - lead_blocks) * b,
        full_trail: cfg.cols - (k + 1) * b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RunConfig {
        RunConfig { rows: 512, cols: 128, block: 32, procs: 4, ..Default::default() }
        // m_local = 128, panels = 4, default grid 4x1
    }

    #[test]
    fn first_panel_everyone_participates() {
        let c = cfg();
        for r in 0..4 {
            let g = geometry(&c, r, 0);
            assert!(g.participates);
            assert!(g.in_panel_col);
            assert_eq!(g.owner, 0);
            assert_eq!(g.owner_row, 0);
            assert_eq!(g.q, 4);
            assert_eq!(g.idx, r);
            assert_eq!(g.start, 0);
            assert_eq!(g.active_m, 128);
            assert_eq!(g.n_trail, 96);
            assert_eq!(g.full_trail, 96);
        }
    }

    #[test]
    fn owner_rows_shrink_with_panels() {
        let c = cfg();
        // panel 1: diag row 32 still inside rank 0's block.
        let g = geometry(&c, 0, 1);
        assert_eq!(g.owner, 0);
        assert_eq!(g.start, 32);
        assert_eq!(g.active_m, 96);
        assert_eq!(g.panel_lcol, 32);
        // panel 3: diag row 96.
        let g3 = geometry(&c, 0, 3);
        assert_eq!(g3.start, 96);
        assert_eq!(g3.active_m, 32);
        assert_eq!(g3.n_trail, 0);
    }

    #[test]
    fn retirement() {
        // Taller config so ownership moves past rank 0.
        let c = RunConfig {
            rows: 256,
            cols: 128,
            block: 32,
            procs: 4,
            ..Default::default()
        };
        // m_local = 64 -> panel 2 diag row = 64 -> owner = rank 1.
        let g = geometry(&c, 0, 2);
        assert!(!g.participates);
        assert_eq!(g.owner, 1);
        let g1 = geometry(&c, 1, 2);
        assert!(g1.participates);
        assert_eq!(g1.idx, 0);
        assert_eq!(g1.q, 3);
        assert_eq!(g1.start, 0);
        let g3 = geometry(&c, 3, 3);
        assert_eq!(g3.idx, 2);
        assert_eq!(g3.start, 0);
    }

    #[test]
    fn active_m_is_block_multiple_when_config_valid() {
        let c = cfg();
        for k in 0..c.panels() {
            for r in 0..c.procs {
                let g = geometry(&c, r, k);
                if g.participates {
                    assert_eq!(g.active_m % c.block, 0, "k={k} r={r}");
                    assert!(g.active_m >= c.block);
                }
            }
        }
    }

    #[test]
    fn grid_geometry_2x2() {
        // 2x2 grid: m_local = 256, 4 panels cycling over 2 grid cols.
        let c = RunConfig {
            rows: 512,
            cols: 128,
            block: 32,
            procs: 4,
            grid_rows: 2,
            grid_cols: 2,
            ..Default::default()
        };
        // Panel 0 lives on grid col 0; ranks 0 and 2 factorize it.
        let g = geometry(&c, 0, 0);
        assert!(g.in_panel_col);
        assert_eq!((g.owner_row, g.q, g.idx), (0, 2, 0));
        assert_eq!(g.panel_lcol, 0);
        // Grid col 0 owns blocks {0, 2}: after panel 0 one trailing
        // block remains locally, two globally beyond it.
        assert_eq!((g.trail_col, g.n_trail, g.full_trail), (32, 32, 96));
        // Rank 1 (grid col 1, blocks {1, 3}) receives the broadcast.
        let g1 = geometry(&c, 1, 0);
        assert!(g1.participates && !g1.in_panel_col);
        assert_eq!(g1.panel_gcol, 0);
        assert_eq!((g1.trail_col, g1.n_trail), (0, 64));
        assert_eq!(g1.idx, 0);
        // Panel 1 cycles to grid col 1; rank 3 is its lower tree member.
        let g3 = geometry(&c, 3, 1);
        assert!(g3.in_panel_col);
        assert_eq!((g3.idx, g3.q), (1, 2));
        assert_eq!(g3.panel_lcol, 0);
        assert_eq!(g3.owner, 1);
        // Grid col 1 owns {1, 3}: one local trailing block after panel 1.
        assert_eq!((g3.trail_col, g3.n_trail, g3.full_trail), (32, 32, 64));
    }

    #[test]
    fn px1_grid_matches_1d_fields() {
        // Explicit Px1 grid must be field-for-field the 1-D geometry.
        let c = cfg();
        let c_grid = RunConfig { grid_rows: 4, grid_cols: 1, ..cfg() };
        for k in 0..c.panels() {
            for r in 0..c.procs {
                assert_eq!(geometry(&c, r, k), geometry(&c_grid, r, k), "k={k} r={r}");
            }
        }
    }
}
