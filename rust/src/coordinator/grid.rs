//! Process-grid layout: the tile-ownership map and global↔local index
//! algebra for 2-D block-cyclic CAQR.
//!
//! The `P = Pr x Pc` simulated world is arranged as a process grid in
//! row-major rank order: rank `r` sits at grid coordinates
//! `(r / Pc, r % Pc)`. The two matrix dimensions are distributed
//! differently, matching Demmel/Grigori/Hoemmen/Langou's CAQR layout:
//!
//! - **Rows** are block-distributed over *grid rows*: grid row `gr` owns
//!   the contiguous rows `[gr*m_local, (gr+1)*m_local)` with
//!   `m_local = rows / Pr`. Every rank in a grid row therefore holds the
//!   same global row range, which is what lets the trailing update run
//!   the same reduction tree in every grid column with the same row
//!   alignment as the panel column's TSQR.
//! - **Columns** are block-cyclic over *grid columns*: the width-`b`
//!   column block `j` is owned by grid column `j % Pc`, stored locally at
//!   block index `j / Pc`. Cyclic ownership keeps late panels spread
//!   across the grid instead of piling the trailing work onto whichever
//!   column owns the right edge.
//!
//! `Pc = 1` collapses to the original 1-D block-row layout: rank == grid
//! row, every rank owns every column block, and all index conversions
//! are identities — the refactored coordinator is bitwise-identical to
//! the pre-grid code there.

use crate::config::RunConfig;

/// A `Pr x Pc` process grid (row-major rank order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Grid {
    pr: usize,
    pc: usize,
}

impl Grid {
    /// Build a `pr x pc` grid. Both extents must be >= 1.
    pub fn new(pr: usize, pc: usize) -> Self {
        assert!(pr >= 1 && pc >= 1, "grid extents must be >= 1 ({pr}x{pc})");
        Grid { pr, pc }
    }

    /// The grid a run config describes (`cfg.grid_shape()`).
    pub fn from_cfg(cfg: &RunConfig) -> Self {
        let (pr, pc) = cfg.grid_shape();
        Grid::new(pr, pc)
    }

    /// Grid rows `Pr`.
    pub fn rows(&self) -> usize {
        self.pr
    }

    /// Grid columns `Pc`.
    pub fn cols(&self) -> usize {
        self.pc
    }

    /// Total process count `Pr * Pc`.
    pub fn size(&self) -> usize {
        self.pr * self.pc
    }

    /// Rank at grid coordinates `(gr, gc)` (row-major).
    pub fn rank_at(&self, gr: usize, gc: usize) -> usize {
        debug_assert!(gr < self.pr && gc < self.pc, "({gr},{gc}) outside {self:?}");
        gr * self.pc + gc
    }

    /// Grid coordinates `(gr, gc)` of `rank`.
    pub fn coords(&self, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.size(), "rank {rank} outside {self:?}");
        (rank / self.pc, rank % self.pc)
    }

    /// Grid column owning global column block `j` (block-cyclic).
    pub fn col_owner(&self, j: usize) -> usize {
        j % self.pc
    }

    /// Local block index of global column block `j` on its owner.
    pub fn local_block(&self, j: usize) -> usize {
        j / self.pc
    }

    /// Global column block stored at local block index `lb` on grid
    /// column `gc` — the inverse of [`Grid::local_block`] restricted to
    /// `gc`'s blocks.
    pub fn global_block(&self, lb: usize, gc: usize) -> usize {
        debug_assert!(gc < self.pc, "grid col {gc} outside {self:?}");
        lb * self.pc + gc
    }

    /// Number of blocks among the global blocks `[0, nblocks)` owned by
    /// grid column `gc`. Block-cyclic: counts differ by at most one
    /// across grid columns.
    pub fn blocks_before(&self, gc: usize, nblocks: usize) -> usize {
        debug_assert!(gc < self.pc, "grid col {gc} outside {self:?}");
        if gc >= nblocks {
            0
        } else {
            (nblocks - gc).div_ceil(self.pc)
        }
    }

    /// Total column blocks owned by grid column `gc` when the matrix has
    /// `nblocks` column blocks.
    pub fn local_blocks(&self, gc: usize, nblocks: usize) -> usize {
        self.blocks_before(gc, nblocks)
    }

    /// Local column count (elements, not blocks) on grid column `gc`.
    pub fn local_cols(&self, gc: usize, cols: usize, block: usize) -> usize {
        self.local_blocks(gc, cols / block) * block
    }

    /// Grid row owning global matrix row `i` (block row distribution,
    /// `m_local` rows per grid row).
    pub fn row_owner(&self, i: usize, m_local: usize) -> usize {
        i / m_local
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adversarial (m, n, Pr, Pc, block) sweep shared by the ownership
    /// properties: shapes are chosen so rows divide Pr and cols divide
    /// block (the invariants `RunConfig::validate` enforces), but
    /// otherwise stress tall/square grids, Pc > panel count, prime-ish
    /// extents and single-block matrices.
    fn shapes() -> Vec<(usize, usize, usize, usize, usize)> {
        vec![
            (256, 64, 4, 1, 16),  // the 1-D special case
            (256, 64, 1, 4, 16),  // pure column grid
            (256, 64, 2, 2, 16),  // square
            (512, 96, 4, 2, 16),  // tall grid, 6 panels over 2 grid cols
            (512, 96, 2, 3, 16),  // 6 panels over 3 grid cols
            (384, 80, 3, 4, 16),  // 5 panels over 4 grid cols (uneven cyclic)
            (128, 16, 8, 7, 8),   // Pc > panels: cols 16 / block 8 = 2 blocks, 7 grid cols
            (64, 64, 1, 1, 64),   // single tile
            (1024, 512, 16, 4, 32),
            (960, 224, 5, 7, 16), // prime-ish grid extents, 14 panels
        ]
    }

    #[test]
    fn ownership_is_a_bijection_over_tiles() {
        for (m, n, pr, pc, b) in shapes() {
            let g = Grid::new(pr, pc);
            let m_local = m / pr;
            let (rtiles, ctiles) = (m / b, n / b);
            // Every tile (ri, cj) maps to exactly one rank, and the
            // per-rank tile sets partition the tile space.
            let mut owned = vec![0usize; g.size()];
            for ri in 0..rtiles {
                for cj in 0..ctiles {
                    let gr = g.row_owner(ri * b, m_local);
                    let gc = g.col_owner(cj);
                    let r = g.rank_at(gr, gc);
                    assert!(r < g.size(), "{m}x{n} {pr}x{pc} b{b}: tile ({ri},{cj})");
                    owned[r] += 1;
                }
            }
            assert_eq!(
                owned.iter().sum::<usize>(),
                rtiles * ctiles,
                "{m}x{n} {pr}x{pc} b{b}: tiles lost or double-counted"
            );
            // Per-rank count must equal the closed-form local extents.
            for rank in 0..g.size() {
                let (gr, gc) = g.coords(rank);
                let want = (m_local / b) * g.local_blocks(gc, ctiles);
                assert_eq!(owned[rank], want, "{m}x{n} {pr}x{pc} b{b}: rank {rank} (gr={gr})");
            }
        }
    }

    #[test]
    fn global_local_round_trips() {
        for (_m, n, pr, pc, b) in shapes() {
            let g = Grid::new(pr, pc);
            let nblocks = n / b;
            for j in 0..nblocks {
                let gc = g.col_owner(j);
                let lb = g.local_block(j);
                assert_eq!(g.global_block(lb, gc), j, "{pr}x{pc}: block {j}");
                assert!(lb < g.local_blocks(gc, nblocks), "{pr}x{pc}: block {j}");
                // blocks_before is consistent with local_block: block j is
                // the (lb+1)-th block owned by gc among [0, j+1).
                assert_eq!(g.blocks_before(gc, j + 1), lb + 1, "{pr}x{pc}: block {j}");
            }
            // And the local side round-trips back to distinct globals.
            for gc in 0..pc {
                for lb in 0..g.local_blocks(gc, nblocks) {
                    let j = g.global_block(lb, gc);
                    assert!(j < nblocks);
                    assert_eq!(g.col_owner(j), gc);
                    assert_eq!(g.local_block(j), lb);
                }
            }
        }
    }

    #[test]
    fn cyclic_imbalance_is_at_most_one_tile() {
        for (m, n, pr, pc, b) in shapes() {
            let g = Grid::new(pr, pc);
            let nblocks = n / b;
            let counts: Vec<usize> =
                (0..pc).map(|gc| g.local_blocks(gc, nblocks)).collect();
            let (lo, hi) = (
                *counts.iter().min().unwrap(),
                *counts.iter().max().unwrap(),
            );
            assert!(
                hi - lo <= 1,
                "{m}x{n} {pr}x{pc} b{b}: column-tile imbalance {hi}-{lo} > 1"
            );
            assert_eq!(counts.iter().sum::<usize>(), nblocks);
            // Rows are block-distributed exactly evenly, so the cyclic
            // dimension is the only imbalance source.
            assert_eq!(m % pr, 0);
        }
    }

    #[test]
    fn rank_coord_round_trip_row_major() {
        for (_, _, pr, pc, _) in shapes() {
            let g = Grid::new(pr, pc);
            for rank in 0..g.size() {
                let (gr, gc) = g.coords(rank);
                assert_eq!(g.rank_at(gr, gc), rank);
            }
            // Row-major: grid row gr occupies the contiguous rank range
            // [gr*Pc, (gr+1)*Pc) — with Pc = 1 rank == grid row, the 1-D
            // compatibility anchor.
            if pc == 1 {
                for rank in 0..g.size() {
                    assert_eq!(g.coords(rank), (rank, 0));
                }
            }
        }
    }

    #[test]
    fn local_cols_match_block_counts() {
        for (_m, n, pr, pc, b) in shapes() {
            let g = Grid::new(pr, pc);
            let total: usize = (0..pc).map(|gc| g.local_cols(gc, n, b)).sum();
            assert_eq!(total, n, "{pr}x{pc}: local columns must tile the matrix");
        }
    }
}
