//! The CAQR panel driver and per-rank algorithm bodies.
//!
//! `run_caqr` builds the simulated world, distributes block rows, runs
//! every rank's panel loop (TSQR + trailing update, plain or FT), joins
//! the tasks — including any REBUILD replacements spawned by recovery —
//! assembles the reduced matrix, and verifies the Gram identity.
//!
//! Conventions (see DESIGN.md):
//! * pair stacking: the smaller tree index owns the globally-upper rows
//!   and is the top (`R0`/`C0'`) of every stacked merge; the top member
//!   continues up the tree, the bottom leaves after its step.
//! * Algorithm 1 (plain): bottom sends `C'₁`, top computes the pair
//!   update and returns `Ĉ'₁` — two serialized one-way messages.
//! * Algorithm 2 (FT): both members already hold the merge factors (the
//!   FT-TSQR exchanged R's), `sendrecv` their `C'` rows, and both
//!   compute `W` and their own update; `{W, T, C', Y₁}` is retained for
//!   single-buddy recovery (paper §III-C).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;
use std::sync::Mutex;
use std::thread::JoinHandle;

use crate::backend::Backend;
use crate::config::{Algorithm, RunConfig};
use crate::fault::{FailSite, FaultPlan, Phase};
use crate::ft::Fail;
use crate::linalg::{gram_residual, Matrix};
use crate::metrics::Report;
use crate::sim::{CostModel, MsgData, Tag, TagKind, World};
use crate::trace::Trace;

use super::panel::{geometry, PanelGeom};
use super::store::{RecoveryStore, RevivalGate};
use super::tree::{self, Role};

/// Immutable context shared by every rank task (original and rebuilt).
pub struct Shared {
    pub cfg: RunConfig,
    pub backend: Arc<Backend>,
    pub store: Arc<RecoveryStore>,
    pub gate: Arc<RevivalGate>,
    pub trace: Arc<Trace>,
    pub world: Arc<World>,
    /// Per-rank initial blocks — the "subpart of the initial matrix" the
    /// paper's recovery re-reads (stable storage / parallel FS stand-in).
    pub initial: Vec<Matrix>,
    /// Final local blocks, written by each rank on completion.
    pub results: Mutex<HashMap<usize, Matrix>>,
    /// Join handles of REBUILD replacement tasks.
    pub revived: Mutex<Vec<JoinHandle<Result<(), Fail>>>>,
}

/// Outcome of a full factorization run.
#[derive(Debug)]
pub struct CaqrOutcome {
    /// The assembled reduced matrix (rows x cols; `[R; 0]`).
    pub reduced: Matrix,
    /// Upper-triangular `R` (cols x cols).
    pub r: Matrix,
    /// `‖AᵀA − RᵀR‖_F / ‖AᵀA‖_F` when `cfg.verify`.
    pub residual: Option<f32>,
    /// Frobenius norm of the strictly-lower part of `reduced` (should
    /// be ~0).
    pub lower_defect: f32,
    /// Metrics snapshot.
    pub report: Report,
    /// Peak bytes of buddy-retained redundancy state.
    pub store_peak_bytes: u64,
    /// Wallclock of the simulated run.
    pub elapsed: std::time::Duration,
    /// Flops issued through the backend.
    pub backend_flops: u64,
}

/// One rank's per-panel working state.
pub(crate) struct Ranker {
    pub shared: Arc<Shared>,
    pub ctx: crate::sim::RankCtx,
    /// True for a REBUILD replacement replaying history.
    pub resume: bool,
    /// The local block-row (m_local x cols), updated in place.
    pub local: Matrix,
}

impl Ranker {
    pub(crate) fn rank(&self) -> usize {
        self.ctx.rank
    }

    fn cfg(&self) -> &RunConfig {
        &self.shared.cfg
    }

    /// Full panel loop; returns the final local block.
    pub fn run(mut self) -> Result<(), Fail> {
        let out = self.run_inner();
        if let Err(e) = &out {
            // A rank that exits abnormally (Abort cascade, unrecoverable
            // failure) must look dead to its peers, or they would block
            // forever waiting for its messages — MPI_Abort semantics.
            if *e != Fail::Killed {
                self.ctx.router().kill(self.ctx.rank);
            }
        }
        out
    }

    fn run_inner(&mut self) -> Result<(), Fail> {
        let panels = self.cfg().panels();
        for k in 0..panels {
            let g = geometry(self.cfg(), self.rank(), k);
            crate::simlog!("[r{} inc] panel {k} start (resume={})", self.rank(), self.resume);
            if !g.participates {
                continue;
            }
            let factors = self.panel_tsqr(&g)?;
            if g.n_trail > 0 {
                self.panel_update(&g, &factors)?;
            }
            // Diskless-checkpoint baseline traffic (E7), if configured.
            self.maybe_checkpoint(&g)?;
            // NOTE: retained state is kept for the whole run. Replay of a
            // failed rank walks its entire history (paper III-C recovers
            // one step from one buddy; the full-state rebuild composes
            // those per-step recoveries), so early retirement would leave
            // a later replay with nothing to read — see the E7 bench for
            // the measured memory cost vs diskless checkpointing.
        }
        if self.resume {
            self.ctx.metrics.record_recovery();
            self.shared.trace.emit(self.ctx.clock, self.rank(), 0, 0, "recovery_done", 0.0);
        }
        crate::simlog!("[r{}] done", self.rank());
        self.shared
            .results
            .lock()
            .unwrap()
            .insert(self.rank(), self.local.clone());
        Ok(())
    }

    /// Panel factorization: local leaf QR + reduction tree (plain) or
    /// all-exchange tree (FT, paper §III-B). Returns the leaf factors
    /// and the per-step merge factors needed by the trailing update.
    fn panel_tsqr(&mut self, g: &PanelGeom) -> Result<PanelFactorsSet, Fail> {
        let b = self.cfg().block;
        let m_local = self.cfg().local_rows();

        // Leaf factorization of the active panel rows (zero-row padded).
        let apanel = self
            .local
            .block(g.start, g.k * b, g.active_m, b)
            .pad_to(m_local, b);
        let leaf = self
            .shared
            .backend
            .panel_qr(&apanel)
            
            .map_err(|e| self.backend_err("panel_qr", e))?;
        self.ctx.compute(crate::backend::flops::panel_qr(m_local, b));

        let mut r = leaf.r.clone();
        let nsteps = tree::steps(g.q);
        let mut merges: Vec<Option<(Matrix, Matrix)>> = vec![None; nsteps];

        match self.cfg().algorithm {
            Algorithm::FaultTolerant => {
                for s in 0..nsteps {
                    let site = FailSite { panel: g.k, step: s, phase: Phase::Tsqr };
                    self.ctx.maybe_fail(site)?;
                    let Some(bidx) = tree::exchange_pair(g.idx, s, g.q) else {
                        continue;
                    };
                    let buddy = bidx + g.owner;
                    let tag = Tag::new(TagKind::TsqrR, g.k, s);

                    // Replay path: take the completed merge from the
                    // buddy's retained memory (recovery, paper III-C).
                    if self.resume {
                        if let Some(ret) =
                            self.fetch_retained(buddy, g.k, Phase::Tsqr, s)
                        {
                            if tree::reduce_active(g.idx, s) {
                                merges[s] = Some((ret.y1.clone(), ret.t.clone()));
                            }
                            self.retain_tsqr(g, s, buddy, &ret.y1, &ret.t, &ret.r_merged);
                            r = ret.r_merged;
                            continue;
                        }
                    }

                    let peer = self
                        .exchange(buddy, tag, MsgData::Mat(r.clone()))
                        ?
                        .into_mat();
                    let (rtop, rbot) =
                        if tree::is_top(g.idx, bidx) { (&r, &peer) } else { (&peer, &r) };
                    let mf = self
                        .shared
                        .backend
                        .tsqr_merge(rtop, rbot)
                        
                        .map_err(|e| self.backend_err("tsqr_merge", e))?;
                    self.ctx.compute(crate::backend::flops::tsqr_merge(b));
                    self.shared.trace.emit(
                        self.ctx.clock,
                        self.rank(),
                        g.k,
                        s,
                        "redundancy",
                        tree::expected_redundancy(s) as f64,
                    );
                    if tree::reduce_active(g.idx, s) {
                        merges[s] = Some((mf.y1.clone(), mf.t.clone()));
                    }
                    self.retain_tsqr(g, s, buddy, &mf.y1, &mf.t, &mf.r);
                    r = mf.r;
                }
            }
            Algorithm::Plain => {
                for s in 0..nsteps {
                    if !tree::reduce_active(g.idx, s) {
                        break;
                    }
                    let site = FailSite { panel: g.k, step: s, phase: Phase::Tsqr };
                    self.ctx.maybe_fail(site)?;
                    let (role, bidx) = tree::reduce_pair(g.idx, s, g.q);
                    let buddy = bidx + g.owner;
                    let tag = Tag::new(TagKind::TsqrR, g.k, s);
                    match role {
                        Role::Idle => continue,
                        Role::Upper => {
                            let peer = self.recv_plain(buddy, tag)?.into_mat();
                            let mf = self
                                .shared
                                .backend
                                .tsqr_merge(&r, &peer)
                                
                                .map_err(|e| self.backend_err("tsqr_merge", e))?;
                            self.ctx.compute(crate::backend::flops::tsqr_merge(b));
                            merges[s] = Some((mf.y1.clone(), mf.t.clone()));
                            r = mf.r;
                        }
                        Role::Lower => {
                            self.send_plain(buddy, tag, MsgData::Mat(r.clone()))?;
                            break;
                        }
                    }
                }
            }
        }

        // Write the panel columns of the reduced matrix: the owner holds
        // R; everyone else's active panel rows are eliminated (zero).
        let mut panel_out = Matrix::zeros(g.active_m, b);
        if g.idx == 0 {
            panel_out.set_block(0, 0, &r);
        }
        self.local.set_block(g.start, g.k * b, &panel_out);

        Ok(PanelFactorsSet { leaf_y: leaf.y, leaf_t: leaf.t, merges })
    }

    /// Trailing-matrix update: local leaf apply + pairwise tree
    /// (paper Algorithms 1 and 2).
    fn panel_update(&mut self, g: &PanelGeom, f: &PanelFactorsSet) -> Result<(), Fail> {
        let b = self.cfg().block;
        let m_local = self.cfg().local_rows();

        // Leaf: apply the local reflectors to the whole trailing block.
        let c = self
            .local
            .block(g.start, g.trail_col, g.active_m, g.n_trail)
            .pad_to(m_local, g.n_trail);
        let chat = self
            .shared
            .backend
            .leaf_apply(&f.leaf_y, &f.leaf_t, &c)
            
            .map_err(|e| self.backend_err("leaf_apply", e))?;
        self.ctx.compute(crate::backend::flops::leaf_apply(m_local, b, g.n_trail));
        self.local
            .set_block(g.start, g.trail_col, &chat.crop_to(g.active_m, g.n_trail));

        // Tree over the top-b rows of each participant's active block.
        let mut cp = self.local.block(g.start, g.trail_col, b, g.n_trail);
        for s in 0..tree::steps(g.q) {
            if !tree::reduce_active(g.idx, s) {
                break;
            }
            let (role, bidx) = tree::reduce_pair(g.idx, s, g.q);
            if role == Role::Idle {
                continue;
            }
            let site = FailSite { panel: g.k, step: s, phase: Phase::Update };
            self.ctx.maybe_fail(site)?;
            let buddy = bidx + g.owner;
            let tag = Tag::new(TagKind::UpdateC, g.k, s);

            match self.cfg().algorithm {
                Algorithm::FaultTolerant => {
                    let (y1, t) = f.merges[s]
                        .clone()
                        .expect("FT rank holds merge factors for its tree steps");

                    // Replay path: recompute our rows from the buddy's
                    // retained {W, Y1} — the paper's recovery equation.
                    if self.resume {
                        if let Some(ret) =
                            self.fetch_retained(buddy, g.k, Phase::Update, s)
                        {
                            let pre = cp.clone();
                            cp = self.recover_rows(&pre, role, &ret)?;
                            self.retain_update(g, s, buddy, &ret.w, &y1, &t, &pre, &pre);
                            if role == Role::Lower {
                                break;
                            }
                            continue;
                        }
                    }

                    let peer_c = self
                        .exchange(buddy, tag, MsgData::Mat(cp.clone()))
                        ?
                        .into_mat();
                    let (c0, c1) =
                        if role == Role::Upper { (&cp, &peer_c) } else { (&peer_c, &cp) };
                    let stp = self
                        .shared
                        .backend
                        .tree_update(c0, c1, &y1, &t)
                        
                        .map_err(|e| self.backend_err("tree_update", e))?;
                    // Both members do the full pair computation — the
                    // paper's traded energy cost (E4).
                    self.ctx.compute(crate::backend::flops::tree_update(b, g.n_trail));
                    self.shared.trace.emit(
                        self.ctx.clock,
                        self.rank(),
                        g.k,
                        s,
                        "update_exchange",
                        buddy as f64,
                    );
                    self.retain_update(g, s, buddy, &stp.w, &y1, &t, c0, c1);
                    cp = if role == Role::Upper { stp.c0 } else { stp.c1 };
                    if role == Role::Lower {
                        break;
                    }
                }
                Algorithm::Plain => match role {
                    Role::Idle => unreachable!("idle handled above"),
                    Role::Upper => {
                        let (y1, t) = f.merges[s]
                            .clone()
                            .expect("plain upper holds merge factors");
                        let peer_c = self.recv_plain(buddy, tag)?.into_mat();
                        let stp = self
                            .shared
                            .backend
                            .tree_update(&cp, &peer_c, &y1, &t)
                            
                            .map_err(|e| self.backend_err("tree_update", e))?;
                        self.ctx.compute(crate::backend::flops::tree_update(b, g.n_trail));
                        // Return the buddy's updated rows (Ĉ'₁ = C'₁−Y₁W;
                        // same bytes as the paper's W message).
                        self.send_plain(
                            buddy,
                            Tag::new(TagKind::UpdateW, g.k, s),
                            MsgData::Mat(stp.c1),
                        )?;
                        cp = stp.c0;
                    }
                    Role::Lower => {
                        self.send_plain(buddy, tag, MsgData::Mat(cp.clone()))?;
                        cp = self
                            .recv_plain(buddy, Tag::new(TagKind::UpdateW, g.k, s))
                            ?
                            .into_mat();
                        break;
                    }
                },
            }
        }
        self.local.set_block(g.start, g.trail_col, &cp);
        Ok(())
    }

    pub(crate) fn backend_err(&self, op: &str, e: anyhow::Error) -> Fail {
        // Backend errors are infrastructure bugs, not simulated failures.
        panic!("backend {op} failed on rank {}: {e:#}", self.ctx.rank);
    }
}

/// Leaf + merge factors for one panel on one rank.
pub(crate) struct PanelFactorsSet {
    pub leaf_y: Matrix,
    pub leaf_t: Matrix,
    /// (Y1, T) per tree step where this rank is a reduce-tree member.
    pub merges: Vec<Option<(Matrix, Matrix)>>,
}

/// Run a full factorization under `cfg`.
pub fn run_caqr(
    cfg: RunConfig,
    backend: Arc<Backend>,
    fault: Arc<FaultPlan>,
    trace: Arc<Trace>,
) -> Result<CaqrOutcome> {
    cfg.validate()?;
    let t0 = std::time::Instant::now();
    let a = Matrix::randn(cfg.rows, cfg.cols, cfg.seed);
    run_caqr_on(cfg, a, backend, fault, trace, t0)
}

/// Run on a caller-supplied matrix (tests want specific inputs).
pub fn run_caqr_matrix(
    cfg: RunConfig,
    a: Matrix,
    backend: Arc<Backend>,
    fault: Arc<FaultPlan>,
    trace: Arc<Trace>,
) -> Result<CaqrOutcome> {
    cfg.validate()?;
    let t0 = std::time::Instant::now();
    run_caqr_on(cfg, a, backend, fault, trace, t0)
}

fn run_caqr_on(
    cfg: RunConfig,
    a: Matrix,
    backend: Arc<Backend>,
    fault: Arc<FaultPlan>,
    trace: Arc<Trace>,
    t0: std::time::Instant,
) -> Result<CaqrOutcome> {
    assert_eq!(a.shape(), (cfg.rows, cfg.cols), "input matrix shape mismatch");
    let m_local = cfg.local_rows();
    let initial: Vec<Matrix> = (0..cfg.procs)
        .map(|r| a.block(r * m_local, 0, m_local, cfg.cols))
        .collect();

    let world = World::new(cfg.procs, cfg.cost, fault);
    let flops0 = backend.flops();
    let shared = Arc::new(Shared {
        cfg: cfg.clone(),
        backend,
        store: RecoveryStore::new(),
        gate: RevivalGate::new(),
        trace,
        world: world.clone(),
        initial: initial.clone(),
        results: Mutex::new(HashMap::new()),
        revived: Mutex::new(Vec::new()),
    });

    // Spawn the original incarnation of every rank.
    let handles: Vec<_> = (0..cfg.procs)
        .map(|r| {
            let sh = shared.clone();
            let ctx = world.ctx(r);
            let local = initial[r].clone();
            std::thread::Builder::new()
                .name(format!("rank-{r}"))
                .spawn(move || Ranker { shared: sh, ctx, resume: false, local }.run())
                .expect("spawn rank thread")
        })
        .collect();

    let mut failures: Vec<Fail> = Vec::new();
    for h in handles {
        match h.join().expect("rank task panicked") {
            Ok(()) => {}
            Err(Fail::Killed) => {} // replaced via REBUILD (or aborted below)
            Err(e) => failures.push(e),
        }
    }
    // Drain replacement tasks (they may spawn further replacements).
    loop {
        let next = { shared.revived.lock().unwrap().pop() };
        match next {
            Some(h) => match h.join().expect("revived task panicked") {
                Ok(()) | Err(Fail::Killed) => {}
                Err(e) => failures.push(e),
            },
            None => break,
        }
    }

    let results = shared.results.lock().unwrap();
    if results.len() != cfg.procs {
        let missing: Vec<usize> =
            (0..cfg.procs).filter(|r| !results.contains_key(r)).collect();
        anyhow::bail!(
            "run did not complete: missing ranks {missing:?}, failures: {failures:?}"
        );
    }

    // Assemble the reduced matrix [R; 0].
    let mut reduced = Matrix::zeros(cfg.rows, cfg.cols);
    for r in 0..cfg.procs {
        reduced.set_block(r * m_local, 0, &results[&r]);
    }
    drop(results);

    let r = reduced.crop_to(cfg.cols, cfg.cols).triu();
    let lower_defect = {
        let strict = reduced.sub(&{
            let mut t = Matrix::zeros(cfg.rows, cfg.cols);
            t.set_block(0, 0, &r);
            t
        });
        strict.fro_norm()
    };
    let residual = cfg.verify.then(|| gram_residual(&a, &r));

    Ok(CaqrOutcome {
        reduced,
        r,
        residual,
        lower_defect,
        report: world.metrics.snapshot(),
        store_peak_bytes: shared.store.peak_bytes(),
        elapsed: t0.elapsed(),
        backend_flops: shared.backend.flops() - flops0,
    })
}

/// Convenience: run with default trace/no faults on the native backend.
pub fn run_caqr_simple(cfg: RunConfig) -> Result<CaqrOutcome> {
    run_caqr(cfg, Backend::native(), FaultPlan::none(), Trace::disabled())
}

/// Default cost model re-export for binaries.
pub fn default_cost() -> CostModel {
    CostModel::default()
}
