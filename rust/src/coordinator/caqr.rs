//! The CAQR panel driver and per-rank algorithm bodies.
//!
//! `run_caqr` builds the simulated world, distributes the matrix over
//! the `Pr x Pc` process grid (rows block-distributed over grid rows,
//! column blocks block-cyclic over grid columns — see
//! [`super::grid::Grid`]), runs every rank's panel loop (TSQR down the
//! panel's grid column, WY factors row-broadcast to the other grid
//! columns, trailing update in every column; plain or FT) as a
//! resumable task on the bounded worker pool — including any REBUILD
//! replacement tasks spawned by recovery — assembles the reduced matrix,
//! and verifies the Gram identity. Rank bodies are *lookahead dataflow
//! engines* ([`Ranker`]): up to `RunConfig::lookahead + 1` panels are in
//! flight per rank, each an independent sub-machine that parks on its
//! own exchanges/receives instead of blocking an OS thread — so
//! P = 256–1024 rank runs fit on a laptop core count, and with
//! `lookahead >= 1` a rank starts panel `k+1`'s TSQR as soon as panel
//! `k`'s reflectors have reached its next-panel column block, while the
//! far-trailing update segments drain concurrently (see `DESIGN.md`
//! "Lookahead dataflow engine" and "Scheduler: parking and wakeup").
//! `lookahead = 0` reproduces the lockstep schedule bitwise; any depth
//! produces bitwise-identical factors on the native backend.
//!
//! Conventions (see `DESIGN.md` "Pair stacking and message patterns"):
//! * pair stacking: the smaller tree index owns the globally-upper rows
//!   and is the top (`R0`/`C0'`) of every stacked merge; the top member
//!   continues up the tree, the bottom leaves after its step.
//! * Algorithm 1 (plain): bottom sends `C'₁`, top computes the pair
//!   update and returns `Ĉ'₁` — two serialized one-way messages.
//! * Algorithm 2 (FT): both members already hold the merge factors (the
//!   FT-TSQR exchanged R's), `sendrecv` their `C'` rows, and both
//!   compute `W` and their own update; `{W, T, C', Y₁}` is retained for
//!   single-buddy recovery (paper §III-C).

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use anyhow::Result;
use std::sync::Mutex;

use crate::backend::Backend;
use crate::config::{Algorithm, RunConfig};
use crate::fault::{FailSite, FaultPlan, Phase};
use crate::ft::Fail;
use crate::linalg::{gram_residual, Matrix};
use crate::metrics::{PhasePath, Report};
use crate::sim::{
    CostModel, MsgData, RankCtx, RankTask, Spawner, Stragglers, Tag, TagKind, TaskPoll, World,
};
use crate::trace::{Span, SpanKind, Trace};

use super::collective::BcastSched;
use super::grid::Grid;
use super::panel::{geometry, PanelGeom};
use super::recovery::FtOp;
use super::store::{RecoveryStore, RevivalGate};
use super::tree::{self, Role};

/// Immutable context shared by every rank task (original and rebuilt).
pub struct Shared {
    /// The run description.
    pub cfg: RunConfig,
    /// Compute backend serving the five numeric ops.
    pub backend: Arc<Backend>,
    /// Buddy-retained redundancy state (paper §III-C).
    pub store: Arc<RecoveryStore>,
    /// REBUILD arbitration: one winner per dead incarnation.
    pub gate: Arc<RevivalGate>,
    /// Structured event trace.
    pub trace: Arc<Trace>,
    /// The simulated machine.
    pub world: Arc<World>,
    /// Per-rank initial blocks — the "subpart of the initial matrix" the
    /// paper's recovery re-reads (stable storage / parallel FS stand-in).
    pub initial: Vec<Matrix>,
    /// Final local blocks, written by each rank on completion.
    pub results: Mutex<HashMap<usize, Matrix>>,
    /// First unrecoverable failure observed; poisons the whole run (no
    /// further REBUILDs, every detector aborts).
    pub poison: Mutex<Option<Fail>>,
    /// Ranks parked waiting for a buddy's retained-state insert (a
    /// replaying replacement that outran its wall-clock-slower buddy).
    pub(crate) store_watchers: Mutex<HashSet<usize>>,
}

impl Shared {
    /// The poisoning failure, if the run has been declared unrecoverable.
    pub fn poisoned(&self) -> Option<Fail> {
        self.poison.lock().unwrap().clone()
    }

    pub(crate) fn poison_with(&self, f: Fail) {
        let mut g = self.poison.lock().unwrap();
        if g.is_none() {
            *g = Some(f);
        }
    }

    /// Register `rank` to be poked on the next retained-state insert.
    pub(crate) fn watch_store(&self, rank: usize) {
        self.store_watchers.lock().unwrap().insert(rank);
    }

    /// Poke every watcher (called after each retained-state insert).
    pub(crate) fn notify_store_watchers(&self) {
        let drained: Vec<usize> = {
            let mut g = self.store_watchers.lock().unwrap();
            g.drain().collect()
        };
        for r in drained {
            self.world.router().notify(r);
        }
    }
}

/// Outcome of a full factorization run.
#[derive(Debug)]
pub struct CaqrOutcome {
    /// The assembled reduced matrix (rows x cols; `[R; 0]`).
    pub reduced: Matrix,
    /// Upper-triangular `R` (cols x cols).
    pub r: Matrix,
    /// `‖AᵀA − RᵀR‖_F / ‖AᵀA‖_F` when `cfg.verify`.
    pub residual: Option<f32>,
    /// Frobenius norm of the strictly-lower part of `reduced` (should
    /// be ~0).
    pub lower_defect: f32,
    /// Metrics snapshot.
    pub report: Report,
    /// Peak bytes of buddy-retained redundancy state.
    pub store_peak_bytes: u64,
    /// Wallclock of the simulated run.
    pub elapsed: std::time::Duration,
    /// Flops issued through the backend.
    pub backend_flops: u64,
}

/// TSQR-phase working state for one panel on one rank. The factor
/// matrices are `Arc`-shared with the retention store and any in-flight
/// message payloads — handing `R` to the exchange or the buddy store
/// bumps a refcount instead of deep-copying the buffer.
pub(crate) struct TsqrPhase {
    g: PanelGeom,
    leaf_y: Arc<Matrix>,
    leaf_t: Arc<Matrix>,
    r: Arc<Matrix>,
    /// (Y1, T) per tree step where this rank is a reduce-tree member.
    merges: Vec<Option<(Arc<Matrix>, Arc<Matrix>)>>,
    s: usize,
    wait: TsqrWait,
    /// Clock at phase entry — the begin timestamp of the PanelTsqr span.
    t0: f64,
}

enum TsqrWait {
    /// Ready to enter tree step `s`.
    Enter,
    /// FT exchange in flight.
    Ft(FtOp),
    /// Plain upper member waiting for the lower member's R.
    PlainRecv { buddy: usize, tag: Tag },
}

/// One column segment of a panel's trailing update in flight: the tree
/// runs over the top-b rows of columns `[col0, col0 + ncols)`, routed on
/// `lane`. Under the lockstep schedule (`lookahead = 0`) there is exactly
/// one segment spanning the whole trailing width on lane 0 — bitwise the
/// pre-pipeline update; under lookahead each trailing column block is its
/// own segment (lane = global column-block index).
pub(crate) struct SegRun {
    col0: usize,
    ncols: usize,
    lane: u32,
    /// The top-b rows of this segment of the rank's active trailing
    /// block, updated in place by each tree step.
    cp: Matrix,
    s: usize,
    wait: UpdateWait,
    /// Clock at segment entry — the begin timestamp of its span.
    t0: f64,
}

enum UpdateWait {
    Enter,
    Ft { op: FtOp, role: Role, y1: Arc<Matrix>, t: Arc<Matrix> },
    PlainUpper { buddy: usize, tag: Tag, y1: Arc<Matrix>, t: Arc<Matrix> },
    PlainLowerW { buddy: usize, tag: Tag },
}

/// Update-phase working state for one panel on one rank: the leaf
/// factors (applied segment by segment), the per-step merge factors, and
/// the segment queue. Segments run in ascending column order; the engine
/// releases the panel's *near* segment first, which is what unlocks the
/// next panel's TSQR under lookahead.
pub(crate) struct UpdatePhase {
    leaf_y: Arc<Matrix>,
    leaf_t: Arc<Matrix>,
    /// (Y1, T) per tree step where this rank is a reduce-tree member.
    merges: Vec<Option<(Arc<Matrix>, Arc<Matrix>)>>,
    /// Segments not yet started: (first column, width, lane), ascending.
    todo: std::collections::VecDeque<(usize, usize, u32)>,
    /// The segment in progress, if any.
    cur: Option<SegRun>,
    /// First column NOT yet fully updated by this panel — the in-rank
    /// dataflow frontier the next panel's stages gate on.
    covered_end: usize,
}

/// How a rank outside the panel's grid column waits for the panel's WY
/// factor bundle to arrive along its grid row (`Pc > 1` only; with
/// `Pc = 1` every rank is in the panel column and this stage is never
/// entered, keeping the 1-D path bitwise and metrics identical).
enum BcastWait {
    /// FT mode: pull from the published store copy of the rank ahead of
    /// us in the collective schedule ([`BcastSched`]) — the root for its
    /// direct children, a republishing relay otherwise. The pull is
    /// charged serialized behind the publisher's `ord` earlier readers,
    /// segmented by `nseg`; `fallback_ord` is the conservative ordinal
    /// against the *root's* copy when the relay's incarnation dies.
    Store {
        parent: usize,
        root: usize,
        ord: usize,
        fallback_ord: usize,
        nseg: usize,
        /// Grid-row ranks that pull *our* republished copy.
        children: Vec<usize>,
    },
    /// Plain mode: the bundle's segments in flight from the tree parent
    /// (`tag.step` carries the segment index). Each segment is forwarded
    /// to `children` the moment it lands — the pipelined relay — and
    /// accumulated into `got` until all `nseg` segments (and `expect`
    /// matrices) have arrived.
    Plain {
        sender: usize,
        k: usize,
        panel_gcol: u32,
        seg: usize,
        nseg: usize,
        got: Vec<Arc<Matrix>>,
        expect: usize,
        children: Vec<usize>,
    },
}

/// Pipeline stage of one in-flight panel on one rank. The `f64` riding
/// with the waiting stages is the stage-entry clock — the begin
/// timestamp of the span emitted when the stage completes.
enum Stage {
    /// Panel factorization tree in progress (panel grid column only).
    Tsqr(TsqrPhase),
    /// Waiting for the panel column's factors along the grid row
    /// (off-panel-column ranks with local trailing blocks).
    Bcast(BcastWait, f64),
    /// Trailing update draining segment by segment.
    Update(UpdatePhase),
    /// Diskless-checkpoint exchange in flight (always the oldest unit —
    /// checkpoints are admission barriers).
    Checkpoint(FtOp, f64),
    /// All of this panel's work on this rank is done.
    Complete,
}

/// One in-flight panel on one rank: its geometry plus the stage the
/// rank's work on it has reached. Units live in [`Ranker::units`] oldest
/// first and — because every segment gates on the previous panel's same
/// segment — complete strictly in panel order.
struct Unit {
    g: PanelGeom,
    stage: Stage,
}

impl Unit {
    /// Has this panel's trailing update fully reached *global* column
    /// block `jblock` (columns `[jblock*b, (jblock+1)*b)`) — i.e. may
    /// the next panel touch this rank's columns up to there? The
    /// update's `covered_end` frontier is in local columns, so the
    /// global block is converted through this rank's grid column
    /// (`Pc = 1`: the identity, bitwise the 1-D gate).
    fn covers_done(&self, jblock: usize, grid: Grid, b: usize) -> bool {
        match &self.stage {
            Stage::Complete | Stage::Checkpoint(..) => true,
            Stage::Tsqr(_) | Stage::Bcast(..) => false,
            Stage::Update(up) => {
                up.covered_end >= grid.blocks_before(self.g.gcol, jblock + 1) * b
            }
        }
    }
}

/// The trailing-update segment list for one panel (see [`SegRun`]).
fn update_segments(
    cfg: &RunConfig,
    g: &PanelGeom,
) -> std::collections::VecDeque<(usize, usize, u32)> {
    let mut out = std::collections::VecDeque::new();
    if g.n_trail == 0 {
        return out;
    }
    if cfg.lookahead == 0 {
        // Lockstep: one segment spanning the rank's whole local trailing
        // width on lane 0 — bitwise the pre-pipeline schedule (same
        // message sizes, tags and kernel call shapes).
        out.push_back((g.trail_col, g.n_trail, 0));
    } else {
        let b = cfg.block;
        let grid = Grid::from_cfg(cfg);
        for i in 0..g.n_trail / b {
            let col0 = g.trail_col + i * b;
            // Lanes are *global* column-block indices so the lane part
            // of tags and retained-state keys is grid-shape independent
            // (`Pc = 1`: local == global, the 1-D lanes).
            out.push_back((col0, b, grid.global_block(col0 / b, g.gcol) as u32));
        }
    }
    out
}

/// Outcome of stepping a phase state machine.
enum Stepped {
    /// A non-blocking primitive reported "nothing yet" — park.
    Parked,
    /// The phase completed.
    Finished,
}

/// Outcome of stepping a broadcast receiver.
enum BcastStep {
    /// Bundle not available yet — park with the wait state.
    Parked(BcastWait),
    /// The factor bundle arrived.
    Got(Vec<Arc<Matrix>>),
}

/// The tree steps for which a rank at tree index `idx` holds `(Y₁, T)`
/// merge factors after its TSQR — exactly the `merges` slots that are
/// `Some`, so a row-broadcast bundle's layout is computable on both
/// sides without a header. FT mode fills a slot whenever the rank was an
/// active reduce-tree node with an in-range exchange buddy; plain mode
/// only when it was the pair's upper member (the lower leaves the tree
/// without merging). The update tree only ever reads slots where the
/// rank is Upper or Lower at that step, and both are covered in both
/// modes (every reduce pair is an exchange pair).
fn merge_slots(algorithm: Algorithm, idx: usize, q: usize) -> Vec<usize> {
    (0..tree::steps(q))
        .filter(|&s| match algorithm {
            Algorithm::FaultTolerant => {
                tree::reduce_active(idx, s) && tree::exchange_pair(idx, s, q).is_some()
            }
            Algorithm::Plain => {
                tree::reduce_active(idx, s)
                    && tree::reduce_pair(idx, s, q).0 == Role::Upper
            }
        })
        .collect()
}

/// Per-matrix byte sizes of a panel's row-broadcast bundle, in bundle
/// order — a pure function of the run geometry (all grid-row members
/// share `idx`/`q`), so the sender, every relay and every receiver
/// derive the identical layout (and hence the identical
/// [`BcastSched`] segment plan) without exchanging a header: leaf `Y`
/// is the zero-padded panel block `(m_local, b)`, leaf `T` and every
/// merge `(Y₁, T)` are `(b, b)`.
fn bundle_sizes(cfg: &RunConfig, g: &PanelGeom) -> Vec<usize> {
    let b = cfg.block;
    let elt = std::mem::size_of::<f32>();
    let mut sizes = vec![cfg.local_rows() * b * elt, b * b * elt];
    for _ in merge_slots(cfg.algorithm, g.idx, g.q) {
        sizes.push(b * b * elt);
        sizes.push(b * b * elt);
    }
    sizes
}

/// One rank's resumable panel-loop body (original or REBUILD
/// replacement): a lookahead dataflow engine over in-flight panel
/// [`Unit`]s. With `RunConfig::lookahead = L`, up to `L + 1` panels are
/// in flight per rank: a rank that has applied panel `k`'s reflectors to
/// its next-panel column block (the *near* segment) starts panel
/// `k + 1`'s TSQR immediately while the far-trailing segments drain
/// concurrently. `L = 0` reproduces the lockstep schedule bitwise; for
/// any `L` the factors are bitwise identical (see DESIGN.md "Lookahead
/// dataflow engine").
pub(crate) struct Ranker {
    pub(crate) shared: Arc<Shared>,
    /// True for a REBUILD replacement replaying history.
    pub(crate) resume: bool,
    /// The local block-row (m_local x cols), updated in place.
    pub(crate) local: Matrix,
    /// In-flight panels, oldest first (consecutive panel indices).
    units: std::collections::VecDeque<Unit>,
    /// Next panel index not yet admitted.
    next_k: usize,
    /// Completion latch (drive must not run after finish).
    done: bool,
    /// A REBUILD replacement's first-poll clock — the begin timestamp of
    /// its RecoveryReplay span and the origin of its rebuild latency.
    replay_t0: Option<f64>,
}

impl RankTask for Ranker {
    fn poll(&mut self, ctx: &mut RankCtx, sp: &Spawner) -> TaskPoll {
        match self.drive(ctx, sp) {
            Ok(true) => TaskPoll::Ready(Ok(())),
            Ok(false) => TaskPoll::Pending,
            Err(e) => {
                if let Fail::Unrecoverable { .. } = &e {
                    // Poison BEFORE killing ourselves so detectors see it.
                    self.shared.poison_with(e.clone());
                }
                // A rank that exits abnormally (Abort cascade,
                // unrecoverable failure) must look dead to its peers, or
                // they would park forever waiting for its messages —
                // MPI_Abort semantics.
                if e != Fail::Killed {
                    ctx.router().kill(ctx.rank);
                }
                TaskPoll::Ready(Err(e))
            }
        }
    }
}

impl Ranker {
    pub(crate) fn new(shared: Arc<Shared>, resume: bool, local: Matrix) -> Self {
        Self {
            shared,
            resume,
            local,
            units: std::collections::VecDeque::new(),
            next_k: 0,
            done: false,
            replay_t0: None,
        }
    }

    fn cfg(&self) -> &RunConfig {
        &self.shared.cfg
    }

    fn grid(&self) -> Grid {
        Grid::from_cfg(&self.shared.cfg)
    }

    /// The collective schedule for panel `g.k`'s row-broadcast — a pure
    /// function of `(grid, panel, bundle geometry)`, so every rank in
    /// the grid row plans the identical relay tree independently.
    fn bcast_sched(&self, g: &PanelGeom) -> BcastSched {
        BcastSched::plan(self.cfg(), &self.grid(), g.k, &bundle_sizes(self.cfg(), g))
    }

    /// Record one completed span ending at the current clock and charge
    /// its duration to the matching per-phase busy-time bucket. The span
    /// write is one lock-free ring push (nothing when tracing is off);
    /// the phase charge is one atomic CAS — neither touches the
    /// simulated clock, so tracing cannot perturb the schedule.
    pub(crate) fn emit_span(
        &self,
        ctx: &RankCtx,
        kind: SpanKind,
        t0: f64,
        panel: usize,
        lane: usize,
        value: f64,
    ) {
        let t1 = ctx.clock;
        let phase = match kind {
            SpanKind::PanelTsqr => Some(PhasePath::Tsqr),
            SpanKind::BcastFactors => Some(PhasePath::Bcast),
            SpanKind::UpdateSegment => Some(PhasePath::Update),
            SpanKind::CheckpointWrite => Some(PhasePath::Checkpoint),
            SpanKind::RecoveryDetect | SpanKind::RecoveryFetch => Some(PhasePath::Recovery),
            // The replay span covers the replacement's whole life — its
            // replayed TSQR/update work already lands in those buckets,
            // and its wall time is the rebuild latency metric.
            SpanKind::RecoveryReplay => None,
        };
        if let Some(p) = phase {
            ctx.metrics.record_phase(p, (t1 - t0).max(0.0));
        }
        if self.shared.trace.is_enabled() {
            let (gr, gc) = self.grid().coords(ctx.rank);
            self.shared.trace.span(Span {
                kind,
                t0,
                t1,
                rank: ctx.rank,
                inc: ctx.incarnation(),
                panel,
                lane,
                gr,
                gc,
                recovery: self.resume || kind.is_recovery(),
                value,
            });
        }
    }

    /// Run the dataflow engine forward as far as possible: retire
    /// completed panels, admit new ones while the pipeline has room, and
    /// advance every in-flight unit (oldest first) until a full pass
    /// makes no progress. `Ok(true)` = the rank completed; `Ok(false)` =
    /// parked (every runnable sub-machine is waiting on a message).
    fn drive(&mut self, ctx: &mut RankCtx, sp: &Spawner) -> Result<bool, Fail> {
        assert!(!self.done, "drive called after completion");
        if self.resume && self.replay_t0.is_none() {
            self.replay_t0 = Some(ctx.clock);
        }
        loop {
            let mut progressed = false;
            self.retire_front();
            while self.can_admit() {
                self.admit(ctx)?;
                self.retire_front();
                progressed = true;
            }
            if self.units.is_empty() {
                // No work in flight and nothing left to admit: done.
                debug_assert!(self.next_k >= self.cfg().panels());
                self.finish(ctx);
                self.done = true;
                return Ok(true);
            }
            // Newest unit first: the panel factorization and the near
            // segment produce the messages other ranks wait on, so they
            // get the clock before the far-trailing drain — the classic
            // lookahead priority. (Order never affects the numerics,
            // only which work a rank's serial clock charges first.)
            for i in (0..self.units.len()).rev() {
                progressed |= self.step_unit(i, ctx, sp)?;
            }
            if !progressed {
                return Ok(false);
            }
        }
    }

    /// Pop completed panels off the front of the pipeline (units
    /// complete strictly in panel order, so only the front can retire).
    fn retire_front(&mut self) {
        while matches!(self.units.front().map(|u| &u.stage), Some(Stage::Complete)) {
            self.units.pop_front();
        }
    }

    /// May panel `next_k` enter the pipeline now? Gates: panels remain;
    /// pipeline depth `L + 1` not exceeded; no pending checkpoint
    /// barrier; and the previous panel's update has reached the new
    /// panel's column block (the lookahead dataflow dependency).
    fn can_admit(&self) -> bool {
        let cfg = self.cfg();
        if self.next_k >= cfg.panels() {
            return false;
        }
        if self.units.len() > cfg.lookahead {
            return false;
        }
        // Checkpoint barrier: a checkpoint-due panel must complete (and
        // exchange its snapshot) before any later panel starts, so the
        // snapshot bytes match the lockstep schedule exactly.
        let every = cfg.checkpoint_every;
        if every > 0 && self.units.iter().any(|u| (u.g.k + 1) % every == 0) {
            return false;
        }
        match self.units.back() {
            None => true,
            Some(prev) => prev.covers_done(self.next_k, self.grid(), cfg.block),
        }
    }

    /// Enter panel `next_k`: start its TSQR leaf factorization (panel
    /// grid column), wait for the row-broadcast factors (other columns
    /// with trailing blocks), skip straight to the checkpoint barrier
    /// (row-active ranks with nothing to update this panel), or — for a
    /// retired rank (participation is monotone) — leave the loop.
    fn admit(&mut self, ctx: &mut RankCtx) -> Result<(), Fail> {
        let k = self.next_k;
        let g = geometry(self.cfg(), ctx.rank, k);
        if !g.participates {
            // Owner rows only grow: once retired, retired for good.
            self.next_k = self.cfg().panels();
            return Ok(());
        }
        self.next_k = k + 1;
        crate::simlog!(
            "[r{} inc] panel {k} start (resume={}, inflight={})",
            ctx.rank,
            self.resume,
            self.units.len()
        );
        let stage = if g.in_panel_col {
            Stage::Tsqr(self.begin_tsqr(ctx, g))
        } else if g.n_trail > 0 {
            self.begin_bcast(ctx, g)?
        } else {
            // Off the panel column with no local trailing blocks: this
            // rank has no numeric work in panel `k` — only the
            // checkpoint barrier (if due) involves it, and the pairs
            // align because every row-active rank reaches it.
            self.after_update(ctx, g)
        };
        self.units.push_back(Unit { g, stage });
        Ok(())
    }

    /// Advance one in-flight unit as far as it can go. Returns whether
    /// any state changed (message consumed, compute done, stage moved).
    fn step_unit(&mut self, i: usize, ctx: &mut RankCtx, sp: &Spawner) -> Result<bool, Fail> {
        let g = self.units[i].g;
        let stage = std::mem::replace(&mut self.units[i].stage, Stage::Complete);
        let mut moved = false;
        let next = match stage {
            Stage::Tsqr(mut ph) => match self.step_tsqr(&mut ph, ctx, sp, &mut moved)? {
                Stepped::Parked => Stage::Tsqr(ph),
                Stepped::Finished => {
                    moved = true;
                    self.emit_span(
                        ctx,
                        SpanKind::PanelTsqr,
                        ph.t0,
                        g.k,
                        0,
                        tree::steps(g.q) as f64,
                    );
                    self.after_tsqr(ctx, ph)?
                }
            },
            Stage::Bcast(wait, t0) => match self.step_bcast(g, wait, ctx, sp)? {
                BcastStep::Parked(w) => Stage::Bcast(w, t0),
                BcastStep::Got(mats) => {
                    moved = true;
                    // Receiver side: value 1 (the sender publish is 0).
                    self.emit_span(ctx, SpanKind::BcastFactors, t0, g.k, 0, 1.0);
                    self.begin_update_from_bcast(g, mats)
                }
            },
            Stage::Update(mut up) => {
                if self.step_update(i, g, &mut up, ctx, sp, &mut moved)? {
                    moved = true;
                    self.after_update(ctx, g)
                } else {
                    Stage::Update(up)
                }
            }
            Stage::Checkpoint(mut op, t0) => {
                if i != 0 {
                    // Older panels are still unpopped; the checkpoint
                    // pairs within a quiesced pipeline — wait for the
                    // front to retire (next engine pass).
                    Stage::Checkpoint(op, t0)
                } else {
                    match self.poll_ft(&mut op, ctx, sp)? {
                        None => Stage::Checkpoint(op, t0),
                        Some(_peer_copy) => {
                            moved = true;
                            // Runtime metadata: lets a replacement of a
                            // rank killed right after this exchange skip
                            // it instead of re-pairing with a partner
                            // that has moved on.
                            self.shared.store.note_checkpoint(ctx.rank, g.k);
                            let bytes = op.payload_nbytes();
                            ctx.metrics.record_checkpoint(bytes);
                            self.shared.trace.emit(
                                ctx.clock,
                                ctx.rank,
                                g.k,
                                0,
                                "checkpoint",
                                op.peer() as f64,
                            );
                            self.emit_span(
                                ctx,
                                SpanKind::CheckpointWrite,
                                t0,
                                g.k,
                                0,
                                bytes as f64,
                            );
                            Stage::Complete
                        }
                    }
                }
            }
            Stage::Complete => Stage::Complete,
        };
        self.units[i].stage = next;
        Ok(moved)
    }

    fn finish(&mut self, ctx: &mut RankCtx) {
        if self.resume {
            ctx.metrics.record_recovery();
            // Attributed completion: the last panel this replacement
            // worked (panel field), its incarnation (step field), and
            // the spawn-to-finish replay time as the rebuild latency.
            let t0 = self.replay_t0.unwrap_or(ctx.clock);
            let rebuild_s = (ctx.clock - t0).max(0.0);
            ctx.metrics.record_rebuild(rebuild_s);
            let panel = self.next_k.saturating_sub(1);
            self.shared.trace.emit(
                ctx.clock,
                ctx.rank,
                panel,
                ctx.incarnation() as usize,
                "recovery_done",
                rebuild_s,
            );
            self.emit_span(
                ctx,
                SpanKind::RecoveryReplay,
                t0,
                panel,
                0,
                ctx.incarnation() as f64,
            );
        }
        crate::simlog!("[r{}] done", ctx.rank);
        // The task is done with its block — move it out instead of
        // cloning a whole local matrix per rank.
        let local = std::mem::replace(&mut self.local, Matrix::zeros(0, 0));
        self.shared.results.lock().unwrap().insert(ctx.rank, local);
    }

    /// Leaf factorization of the active panel rows (zero-row padded) —
    /// the local, non-blocking prologue of the TSQR phase. Panel-grid-
    /// column ranks only; the panel block sits at local column
    /// `g.panel_lcol` of the compact block-cyclic storage.
    fn begin_tsqr(&self, ctx: &mut RankCtx, g: PanelGeom) -> TsqrPhase {
        debug_assert!(g.in_panel_col);
        let t0 = ctx.clock;
        let b = self.cfg().block;
        let m_local = self.cfg().local_rows();
        let apanel =
            self.local.block_padded(g.start, g.panel_lcol, g.active_m, b, m_local, b);
        let leaf = self
            .shared
            .backend
            .panel_qr(&apanel)
            .unwrap_or_else(|e| self.backend_err(ctx.rank, "panel_qr", e));
        ctx.compute(crate::backend::flops::panel_qr(m_local, b));
        let nsteps = tree::steps(g.q);
        TsqrPhase {
            g,
            // Arc from birth: the update phase, the broadcast bundle and
            // the store all share these buffers (publish = refcount bump).
            leaf_y: Arc::new(leaf.y),
            leaf_t: Arc::new(leaf.t),
            r: Arc::new(leaf.r),
            merges: vec![None; nsteps],
            s: 0,
            wait: TsqrWait::Enter,
            t0,
        }
    }

    /// Panel factorization tree: plain reduction or FT all-exchange
    /// (paper §III-B), with the replay shortcut for REBUILD replacements.
    fn step_tsqr(
        &self,
        ph: &mut TsqrPhase,
        ctx: &mut RankCtx,
        sp: &Spawner,
        moved: &mut bool,
    ) -> Result<Stepped, Fail> {
        let b = self.cfg().block;
        let nsteps = tree::steps(ph.g.q);
        loop {
            match std::mem::replace(&mut ph.wait, TsqrWait::Enter) {
                TsqrWait::Enter => {
                    if ph.s == nsteps {
                        return Ok(Stepped::Finished);
                    }
                    let g = ph.g;
                    let s = ph.s;
                    match self.cfg().algorithm {
                        Algorithm::FaultTolerant => {
                            let site = FailSite { panel: g.k, step: s, phase: Phase::Tsqr };
                            self.maybe_fail(ctx, site)?;
                            let Some(bidx) = tree::exchange_pair(g.idx, s, g.q) else {
                                ph.s += 1;
                                *moved = true;
                                continue;
                            };
                            // TSQR buddies run down the panel's grid
                            // column: same column, grid row owner_row +
                            // buddy-index (`Pc = 1`: rank owner + bidx).
                            let buddy =
                                self.grid().rank_at(g.owner_row + bidx, g.panel_gcol);
                            let tag = Tag::grid(
                                TagKind::TsqrR,
                                g.k,
                                s,
                                0,
                                g.panel_gcol as u32,
                            );

                            // Replay path: take the completed merge from
                            // the buddy's retained memory (paper III-C).
                            if self.resume {
                                match self.fetch_retained(
                                    ctx,
                                    sp,
                                    buddy,
                                    g.k,
                                    Phase::Tsqr,
                                    s,
                                    0,
                                    g.panel_gcol as u32,
                                )? {
                                    Fetch::Hit(ret) => {
                                        if tree::reduce_active(g.idx, s) {
                                            ph.merges[s] =
                                                Some((ret.y1.clone(), ret.t.clone()));
                                        }
                                        self.retain_tsqr(
                                            ctx.rank,
                                            ctx.incarnation(),
                                            &g,
                                            s,
                                            buddy,
                                            &ret.y1,
                                            &ret.t,
                                            &ret.r_merged,
                                        );
                                        // Same Arc the buddy holds: the
                                        // replayed R is bit-identical by
                                        // construction.
                                        ph.r = ret.r_merged;
                                        ph.s += 1;
                                        *moved = true;
                                        continue;
                                    }
                                    Fetch::Wait => return Ok(Stepped::Parked),
                                    Fetch::Live => {}
                                }
                            }
                            ph.wait =
                                TsqrWait::Ft(FtOp::new(buddy, tag, MsgData::Mat(ph.r.clone())));
                            *moved = true;
                        }
                        Algorithm::Plain => {
                            if !tree::reduce_active(g.idx, s) {
                                return Ok(Stepped::Finished);
                            }
                            let site = FailSite { panel: g.k, step: s, phase: Phase::Tsqr };
                            self.maybe_fail(ctx, site)?;
                            let (role, bidx) = tree::reduce_pair(g.idx, s, g.q);
                            let buddy =
                                self.grid().rank_at(g.owner_row + bidx, g.panel_gcol);
                            let tag = Tag::grid(
                                TagKind::TsqrR,
                                g.k,
                                s,
                                0,
                                g.panel_gcol as u32,
                            );
                            match role {
                                Role::Idle => {
                                    ph.s += 1;
                                    *moved = true;
                                }
                                Role::Upper => {
                                    ph.wait = TsqrWait::PlainRecv { buddy, tag };
                                    *moved = true;
                                }
                                Role::Lower => {
                                    self.send_plain(
                                        ctx,
                                        buddy,
                                        tag,
                                        MsgData::Mat(ph.r.clone()),
                                    )?;
                                    *moved = true;
                                    return Ok(Stepped::Finished);
                                }
                            }
                        }
                    }
                }
                TsqrWait::Ft(mut op) => match self.poll_ft(&mut op, ctx, sp)? {
                    None => {
                        ph.wait = TsqrWait::Ft(op);
                        return Ok(Stepped::Parked);
                    }
                    Some(d) => {
                        let tag =
                            Tag::grid(TagKind::TsqrR, ph.g.k, ph.s, 0, ph.g.panel_gcol as u32);
                        let peer = d.into_mat_for(&tag);
                        let g = ph.g;
                        let s = ph.s;
                        let buddy = op.peer();
                        let bidx = self.grid().coords(buddy).0 - g.owner_row;
                        let mf = {
                            let (rtop, rbot) = if tree::is_top(g.idx, bidx) {
                                (ph.r.as_ref(), peer.as_ref())
                            } else {
                                (peer.as_ref(), ph.r.as_ref())
                            };
                            self.shared
                                .backend
                                .tsqr_merge(rtop, rbot)
                                .unwrap_or_else(|e| self.backend_err(ctx.rank, "tsqr_merge", e))
                        };
                        ctx.compute(crate::backend::flops::tsqr_merge(b));
                        self.shared.trace.emit(
                            ctx.clock,
                            ctx.rank,
                            g.k,
                            s,
                            "redundancy",
                            tree::expected_redundancy(s) as f64,
                        );
                        // One allocation per factor; every holder (tree
                        // state, retention store, next exchange payload)
                        // shares it.
                        let y1 = Arc::new(mf.y1);
                        let t = Arc::new(mf.t);
                        let r = Arc::new(mf.r);
                        if tree::reduce_active(g.idx, s) {
                            ph.merges[s] = Some((y1.clone(), t.clone()));
                        }
                        self.retain_tsqr(
                            ctx.rank,
                            ctx.incarnation(),
                            &g,
                            s,
                            buddy,
                            &y1,
                            &t,
                            &r,
                        );
                        ph.r = r;
                        ph.s += 1;
                        *moved = true;
                    }
                },
                TsqrWait::PlainRecv { buddy, tag } => {
                    match self.recv_plain_poll(ctx, buddy, tag)? {
                        None => {
                            ph.wait = TsqrWait::PlainRecv { buddy, tag };
                            return Ok(Stepped::Parked);
                        }
                        Some(d) => {
                            let peer = d.into_mat_for(&tag);
                            let mf = self
                                .shared
                                .backend
                                .tsqr_merge(ph.r.as_ref(), peer.as_ref())
                                .unwrap_or_else(|e| self.backend_err(ctx.rank, "tsqr_merge", e));
                            ctx.compute(crate::backend::flops::tsqr_merge(b));
                            ph.merges[ph.s] = Some((Arc::new(mf.y1), Arc::new(mf.t)));
                            ph.r = Arc::new(mf.r);
                            ph.s += 1;
                            *moved = true;
                        }
                    }
                }
            }
        }
    }

    /// Write the panel columns of the reduced matrix (the owner holds R;
    /// everyone else's active panel rows are eliminated), row-broadcast
    /// the WY factors to the other grid columns (`Pc > 1`), then hand
    /// over to the trailing update / checkpoint / completion.
    fn after_tsqr(&mut self, ctx: &mut RankCtx, ph: TsqrPhase) -> Result<Stage, Fail> {
        let g = ph.g;
        let b = self.cfg().block;
        let mut panel_out = Matrix::zeros(g.active_m, b);
        if g.idx == 0 {
            panel_out.set_block(0, 0, ph.r.as_ref());
        }
        self.local.set_block(g.start, g.panel_lcol, &panel_out);

        // Row-broadcast: grid columns other than the panel's own hold
        // `full_trail - n_trail` trailing columns between them; their
        // members on this grid row need the leaf + merge factors to run
        // the same update tree. (`Pc = 1`: full_trail == n_trail, no
        // broadcast — bitwise and metrics identical to the 1-D path.)
        if g.full_trail > g.n_trail {
            let bt0 = ctx.clock;
            self.bcast_factors(ctx, &g, &ph)?;
            // Sender side: value 0 (the receiver pull is 1).
            self.emit_span(ctx, SpanKind::BcastFactors, bt0, g.k, 0, 0.0);
        }

        Ok(if g.n_trail > 0 {
            Stage::Update(UpdatePhase {
                leaf_y: ph.leaf_y,
                leaf_t: ph.leaf_t,
                merges: ph.merges,
                todo: update_segments(self.cfg(), &g),
                cur: None,
                covered_end: g.trail_col,
            })
        } else {
            self.after_update(ctx, g)
        })
    }

    /// Publish (FT) or send (plain) the panel's WY factor bundle along
    /// the grid row: `[leaf Y, leaf T]` then `(Y₁, T)` for every merge
    /// slot this tree index holds — the layout both sides derive from
    /// [`merge_slots`]. Runs synchronously at the end of the sender's
    /// TSQR, with its own `Phase::Bcast` kill site *before* the publish
    /// (a mid-row-broadcast death leaves every receiver parked until the
    /// replacement's TSQR replay republishes).
    fn bcast_factors(
        &self,
        ctx: &mut RankCtx,
        g: &PanelGeom,
        ph: &TsqrPhase,
    ) -> Result<(), Fail> {
        let site = FailSite { panel: g.k, step: 0, phase: Phase::Bcast };
        self.maybe_fail(ctx, site)?;
        let slots = merge_slots(self.cfg().algorithm, g.idx, g.q);
        let mut mats: Vec<Arc<Matrix>> = Vec::with_capacity(2 + 2 * slots.len());
        // Pure refcount bumps: the phase state, the store and every
        // message payload share the factor buffers (no per-panel copy).
        mats.push(ph.leaf_y.clone());
        mats.push(ph.leaf_t.clone());
        for &s in &slots {
            let (y1, t) = ph.merges[s].clone().expect("merge slot filled (merge_slots)");
            mats.push(y1);
            mats.push(t);
        }
        let sched = self.bcast_sched(g);
        debug_assert_eq!(
            mats.iter().map(|m| m.nbytes()).collect::<Vec<_>>(),
            bundle_sizes(self.cfg(), g),
            "bundle layout must be pure geometry (panel {})",
            g.k
        );
        debug_assert_eq!(sched.root_gcol(), g.panel_gcol);
        ctx.metrics.set_bcast_depth(sched.depth() as u64);
        match self.cfg().algorithm {
            Algorithm::FaultTolerant => {
                crate::simlog!("[r{}] bcast publish panel {}", ctx.rank, g.k);
                self.retain_bcast(ctx.rank, ctx.incarnation(), g.k, ctx.clock, mats);
            }
            Algorithm::Plain => {
                // Real row messages along the schedule's tree edges —
                // the root sends only to its own children (everyone else
                // is served by a relay). Segment-major order: every
                // child's segment `s` leaves before any child's `s + 1`,
                // so relays start forwarding while the root is still
                // serializing the bundle's tail.
                let grid = self.grid();
                let (grow, _) = grid.coords(ctx.rank);
                let mut off = 0usize;
                for s in 0..sched.nseg() {
                    let cnt = sched.seg_count(s);
                    let seg_mats = &mats[off..off + cnt];
                    off += cnt;
                    let tag =
                        Tag::grid(TagKind::BcastFactors, g.k, s, 0, g.panel_gcol as u32);
                    for c in sched.children(0) {
                        let peer = grid.rank_at(grow, sched.gcol(c));
                        self.send_bcast_plain(ctx, peer, tag, seg_mats.to_vec())?;
                    }
                }
                debug_assert_eq!(off, mats.len(), "segments must cover the bundle");
            }
        }
        Ok(())
    }

    /// Enter the broadcast-wait stage: this rank is off the panel's grid
    /// column but owns trailing blocks, so it needs the factors from its
    /// grid row's panel-column member. The receiver has its own
    /// `Phase::Bcast` kill site (dying here exercises recovery of a rank
    /// that never entered the panel's communication at all).
    fn begin_bcast(&self, ctx: &mut RankCtx, g: PanelGeom) -> Result<Stage, Fail> {
        debug_assert!(!g.in_panel_col && g.n_trail > 0);
        let site = FailSite { panel: g.k, step: 0, phase: Phase::Bcast };
        self.maybe_fail(ctx, site)?;
        let sched = self.bcast_sched(&g);
        let grid = self.grid();
        let (grow, _) = grid.coords(ctx.rank);
        let v = sched.vindex(g.gcol).expect("receiver is a schedule member");
        let parent = grid.rank_at(grow, sched.gcol(sched.parent(v)));
        let root = grid.rank_at(grow, sched.root_gcol());
        let children: Vec<usize> = sched
            .children(v)
            .into_iter()
            .map(|c| grid.rank_at(grow, sched.gcol(c)))
            .collect();
        let expect = 2 + 2 * merge_slots(self.cfg().algorithm, g.idx, g.q).len();
        let wait = match self.cfg().algorithm {
            Algorithm::FaultTolerant => BcastWait::Store {
                parent,
                root,
                ord: sched.pull_ord(v),
                fallback_ord: sched.fallback_ord(v),
                nseg: sched.nseg(),
                children,
            },
            Algorithm::Plain => BcastWait::Plain {
                sender: parent,
                k: g.k,
                panel_gcol: g.panel_gcol as u32,
                seg: 0,
                nseg: sched.nseg(),
                got: Vec::with_capacity(expect),
                expect,
                children,
            },
        };
        Ok(Stage::Bcast(wait, ctx.clock))
    }

    /// Poll the broadcast wait: a store pull (FT) or the plain segment
    /// receives — in both modes a member with schedule children relays
    /// the bundle onward (republish into the store / forward the
    /// segments) before its own update begins.
    fn step_bcast(
        &self,
        g: PanelGeom,
        wait: BcastWait,
        ctx: &mut RankCtx,
        sp: &Spawner,
    ) -> Result<BcastStep, Fail> {
        match wait {
            BcastWait::Store { parent, root, ord, fallback_ord, nseg, children } => {
                match self.fetch_bcast(ctx, sp, parent, root, g.k, ord, fallback_ord, nseg)? {
                    Some(mats) => {
                        // Relay republish: our schedule children pull our
                        // copy, not the root's. Incarnation-gated, so a
                        // replaying replacement republishes harmlessly.
                        if !children.is_empty() {
                            self.retain_bcast(
                                ctx.rank,
                                ctx.incarnation(),
                                g.k,
                                ctx.clock,
                                mats.clone(),
                            );
                        }
                        Ok(BcastStep::Got(mats))
                    }
                    None => Ok(BcastStep::Parked(BcastWait::Store {
                        parent,
                        root,
                        ord,
                        fallback_ord,
                        nseg,
                        children,
                    })),
                }
            }
            BcastWait::Plain {
                sender,
                k,
                panel_gcol,
                mut seg,
                nseg,
                mut got,
                expect,
                children,
            } => {
                while seg < nseg {
                    let tag = Tag::grid(TagKind::BcastFactors, k, seg, 0, panel_gcol);
                    match self.recv_plain_poll(ctx, sender, tag)? {
                        None => {
                            return Ok(BcastStep::Parked(BcastWait::Plain {
                                sender,
                                k,
                                panel_gcol,
                                seg,
                                nseg,
                                got,
                                expect,
                                children,
                            }))
                        }
                        Some(d) => {
                            let mats = d.into_mats_for(&tag);
                            // Pipelined relay: forward this segment to our
                            // own children before waiting for the next.
                            for &child in &children {
                                self.send_bcast_plain(ctx, child, tag, mats.clone())?;
                            }
                            got.extend(mats);
                            seg += 1;
                        }
                    }
                }
                assert_eq!(
                    got.len(),
                    expect,
                    "bcast segments must reassemble the full bundle (panel {k})"
                );
                Ok(BcastStep::Got(got))
            }
        }
    }

    /// Enter the trailing update with factors received over the grid row
    /// instead of computed locally — the receiving half of the
    /// row-broadcast. The bundle layout is re-derived from
    /// [`merge_slots`] with this rank's own (identical) tree index.
    fn begin_update_from_bcast(&self, g: PanelGeom, mats: Vec<Arc<Matrix>>) -> Stage {
        let nsteps = tree::steps(g.q);
        let slots = merge_slots(self.cfg().algorithm, g.idx, g.q);
        assert_eq!(
            mats.len(),
            2 + 2 * slots.len(),
            "bcast bundle shape mismatch (panel {}, idx {}, q {})",
            g.k,
            g.idx,
            g.q
        );
        let mut it = mats.into_iter();
        // The received Arcs are used as-is: the update phase shares the
        // routed (or store-published) buffers instead of deep-copying.
        let leaf_y = it.next().expect("leaf Y");
        let leaf_t = it.next().expect("leaf T");
        let mut merges = vec![None; nsteps];
        for s in slots {
            let y1 = it.next().expect("merge Y1");
            let t = it.next().expect("merge T");
            merges[s] = Some((y1, t));
        }
        Stage::Update(UpdatePhase {
            leaf_y,
            leaf_t,
            merges,
            todo: update_segments(self.cfg(), &g),
            cur: None,
            covered_end: g.trail_col,
        })
    }

    /// Diskless-checkpoint baseline traffic (E7), if configured; else the
    /// panel is complete.
    fn after_update(&mut self, ctx: &RankCtx, g: PanelGeom) -> Stage {
        // NOTE: retained state is kept for the whole run. Replay of a
        // failed rank walks its entire history (paper III-C recovers one
        // step from one buddy; the full-state rebuild composes those
        // per-step recoveries), so early retirement would leave a later
        // replay with nothing to read — see the E7 bench for the measured
        // memory cost vs diskless checkpointing.
        let every = self.cfg().checkpoint_every;
        if every == 0 || (g.k + 1) % every != 0 {
            return Stage::Complete;
        }
        // Pair within the ranks still participating in this panel —
        // retired ranks have left the computation and exchange nothing.
        let pidx = g.idx ^ 1;
        if pidx >= g.q {
            return Stage::Complete;
        }
        // Replay shortcut: if the pre-death incarnation had already
        // exchanged this checkpoint — recorded directly, or implied by
        // any progress in a later panel (checkpoints are admission
        // barriers in both schedules) — the partner completed its half
        // long ago and will never exchange it again; re-entering would
        // park forever.
        if self.resume
            && (self.shared.store.has_checkpointed(ctx.rank, g.k)
                || self.shared.store.has_progress_at_or_after(ctx.rank, g.k + 1))
        {
            return Stage::Complete;
        }
        // Checkpoint pairs run down each rank's OWN grid column (the
        // snapshot is the rank's local block; only a same-column peer
        // holds equally-shaped state). `Pc = 1`: rank owner + pidx.
        let partner = self.grid().rank_at(g.owner_row + pidx, g.gcol);
        let tag = Tag::grid(TagKind::Checkpoint, g.k, 0, 0, g.gcol as u32);
        // One snapshot copy into an Arc; the exchange's retransmit buffer
        // and the routed envelope share it instead of re-copying.
        let op = FtOp::new(partner, tag, MsgData::mat(self.local.clone()));
        Stage::Checkpoint(op, ctx.clock)
    }

    /// Drain the panel's trailing update segment by segment: each segment
    /// applies the leaf reflectors to its columns (kernel dispatch pinned
    /// to the full trailing width — bitwise identical to one whole-width
    /// application), then runs the pair tree over its top-b rows.
    /// Returns `Ok(true)` when every segment has completed.
    #[allow(clippy::too_many_arguments)]
    fn step_update(
        &mut self,
        i: usize,
        g: PanelGeom,
        up: &mut UpdatePhase,
        ctx: &mut RankCtx,
        sp: &Spawner,
        moved: &mut bool,
    ) -> Result<bool, Fail> {
        let b = self.cfg().block;
        loop {
            if up.cur.is_none() {
                let Some(&(col0, ncols, lane)) = up.todo.front() else {
                    return Ok(true);
                };
                // In-rank dataflow gate: the previous panel's update must
                // have fully reached this segment's columns before panel
                // `g.k`'s transform touches them. The gate compares
                // *global* column blocks (covers_done converts back).
                let jlast = self.grid().global_block((col0 + ncols) / b - 1, g.gcol);
                if i > 0 && !self.units[i - 1].covers_done(jlast, self.grid(), b) {
                    return Ok(false);
                }
                // Segment prologue: leaf reflectors onto its columns,
                // then extract the top-b rows for the tree.
                let t0 = ctx.clock;
                let m_local = self.cfg().local_rows();
                let mut cseg = self
                    .local
                    .block_padded(g.start, col0, g.active_m, ncols, m_local, ncols);
                // Kernel dispatch pinned to the GLOBAL trailing width:
                // every grid column takes the same code path regardless
                // of how many columns it owns locally, so any `Pr x Pc`
                // is bitwise-identical to `Pr x 1` (column-independent
                // reflector application).
                self.shared
                    .backend
                    .leaf_apply_cols_into(&up.leaf_y, &up.leaf_t, &mut cseg, g.full_trail)
                    .unwrap_or_else(|e| self.backend_err(ctx.rank, "leaf_apply", e));
                ctx.compute(crate::backend::flops::leaf_apply(m_local, b, ncols));
                self.local
                    .set_block_view(g.start, col0, cseg.view(0, 0, g.active_m, ncols));
                let cp = self.local.block(g.start, col0, b, ncols);
                up.todo.pop_front();
                up.cur =
                    Some(SegRun { col0, ncols, lane, cp, s: 0, wait: UpdateWait::Enter, t0 });
                *moved = true;
            }
            let merges = &up.merges;
            let seg = up.cur.as_mut().expect("segment in flight");
            match self.step_segment(g, merges, seg, ctx, sp, moved)? {
                Stepped::Parked => return Ok(false),
                Stepped::Finished => {
                    let seg = up.cur.take().expect("segment in flight");
                    self.local.set_block(g.start, seg.col0, &seg.cp);
                    self.emit_span(
                        ctx,
                        SpanKind::UpdateSegment,
                        seg.t0,
                        g.k,
                        seg.lane as usize,
                        seg.ncols as f64,
                    );
                    up.covered_end = seg.col0 + seg.ncols;
                    *moved = true;
                }
            }
        }
    }

    /// Trailing-matrix update tree over one column segment (paper
    /// Algorithms 1 and 2), with the replay shortcut (`Ĉ' = C' − Y W`)
    /// for REBUILD replacements. Tags and retained state are routed on
    /// the segment's lane so concurrent segments never cross-talk.
    #[allow(clippy::too_many_arguments)]
    fn step_segment(
        &self,
        g: PanelGeom,
        merges: &[Option<(Arc<Matrix>, Arc<Matrix>)>],
        seg: &mut SegRun,
        ctx: &mut RankCtx,
        sp: &Spawner,
        moved: &mut bool,
    ) -> Result<Stepped, Fail> {
        let b = self.cfg().block;
        loop {
            match std::mem::replace(&mut seg.wait, UpdateWait::Enter) {
                UpdateWait::Enter => {
                    let s = seg.s;
                    if s == tree::steps(g.q) || !tree::reduce_active(g.idx, s) {
                        return Ok(Stepped::Finished);
                    }
                    let (role, bidx) = tree::reduce_pair(g.idx, s, g.q);
                    if role == Role::Idle {
                        seg.s += 1;
                        *moved = true;
                        continue;
                    }
                    let site = FailSite { panel: g.k, step: s, phase: Phase::Update };
                    self.maybe_fail(ctx, site)?;
                    // The update tree mirrors the TSQR pairing but runs
                    // down this rank's OWN grid column; the tag carries
                    // the grid column so same-(panel, step, lane) trees
                    // in different columns never cross-talk.
                    let buddy = self.grid().rank_at(g.owner_row + bidx, g.gcol);
                    let tag = Tag::grid(TagKind::UpdateC, g.k, s, seg.lane, g.gcol as u32);

                    match self.cfg().algorithm {
                        Algorithm::FaultTolerant => {
                            let (y1, t) = merges[s]
                                .clone()
                                .expect("FT rank holds merge factors for its tree steps");

                            // Replay path: recompute our rows from the
                            // buddy's retained {W, Y1} — the paper's
                            // recovery equation, applied in place.
                            if self.resume {
                                match self.fetch_retained(
                                    ctx,
                                    sp,
                                    buddy,
                                    g.k,
                                    Phase::Update,
                                    s,
                                    seg.lane,
                                    g.gcol as u32,
                                )? {
                                    Fetch::Hit(ret) => {
                                        self.recover_rows(
                                            ctx,
                                            &mut seg.cp,
                                            role,
                                            &ret,
                                            g.full_trail,
                                        );
                                        self.retain_update(
                                            ctx.rank,
                                            ctx.incarnation(),
                                            &g,
                                            s,
                                            seg.lane,
                                            buddy,
                                            &ret.w,
                                            &y1,
                                            &t,
                                        );
                                        *moved = true;
                                        if role == Role::Lower {
                                            return Ok(Stepped::Finished);
                                        }
                                        seg.s += 1;
                                        continue;
                                    }
                                    Fetch::Wait => return Ok(Stepped::Parked),
                                    Fetch::Live => {}
                                }
                            }
                            // One snapshot copy of our rows into the
                            // shared payload (the exchange may have to
                            // retransmit it after a peer REBUILD).
                            let op = FtOp::new(buddy, tag, MsgData::mat(seg.cp.clone()));
                            seg.wait = UpdateWait::Ft { op, role, y1, t };
                            *moved = true;
                        }
                        Algorithm::Plain => match role {
                            Role::Idle => unreachable!("idle handled above"),
                            Role::Upper => {
                                let (y1, t) = merges[s]
                                    .clone()
                                    .expect("plain upper holds merge factors");
                                seg.wait = UpdateWait::PlainUpper { buddy, tag, y1, t };
                                *moved = true;
                            }
                            Role::Lower => {
                                // Our rows travel to the top member and
                                // come back updated — move them into the
                                // message instead of cloning.
                                let cp = std::mem::replace(&mut seg.cp, Matrix::zeros(0, 0));
                                self.send_plain(ctx, buddy, tag, MsgData::mat(cp))?;
                                seg.wait = UpdateWait::PlainLowerW {
                                    buddy,
                                    tag: Tag::grid(
                                        TagKind::UpdateW,
                                        g.k,
                                        s,
                                        seg.lane,
                                        g.gcol as u32,
                                    ),
                                };
                                *moved = true;
                            }
                        },
                    }
                }
                UpdateWait::Ft { mut op, role, y1, t } => {
                    match self.poll_ft(&mut op, ctx, sp)? {
                        None => {
                            seg.wait = UpdateWait::Ft { op, role, y1, t };
                            return Ok(Stepped::Parked);
                        }
                        Some(d) => {
                            // Peer rows are read-only for our half of the
                            // pair step: borrow them straight out of the
                            // message, update our rows in place.
                            let tag =
                                Tag::grid(TagKind::UpdateC, g.k, seg.s, seg.lane, g.gcol as u32);
                            let peer_c = d.into_mat_for(&tag);
                            let s = seg.s;
                            let w = self
                                .shared
                                .backend
                                .tree_update_half_cols(
                                    &mut seg.cp,
                                    peer_c.as_ref(),
                                    &y1,
                                    &t,
                                    role == Role::Upper,
                                    g.full_trail,
                                )
                                .unwrap_or_else(|e| {
                                    self.backend_err(ctx.rank, "tree_update", e)
                                });
                            // Both members are charged the full pair
                            // computation — the paper's traded energy
                            // cost (E4) — regardless of the host-side
                            // half-update optimization.
                            ctx.compute(crate::backend::flops::tree_update(b, seg.ncols));
                            self.shared.trace.emit(
                                ctx.clock,
                                ctx.rank,
                                g.k,
                                s,
                                "update_exchange",
                                op.peer() as f64,
                            );
                            let w = Arc::new(w);
                            self.retain_update(
                                ctx.rank,
                                ctx.incarnation(),
                                &g,
                                s,
                                seg.lane,
                                op.peer(),
                                &w,
                                &y1,
                                &t,
                            );
                            *moved = true;
                            if role == Role::Lower {
                                return Ok(Stepped::Finished);
                            }
                            seg.s += 1;
                        }
                    }
                }
                UpdateWait::PlainUpper { buddy, tag, y1, t } => {
                    match self.recv_plain_poll(ctx, buddy, tag)? {
                        None => {
                            seg.wait = UpdateWait::PlainUpper { buddy, tag, y1, t };
                            return Ok(Stepped::Parked);
                        }
                        Some(d) => {
                            // The lower member moved its rows into the
                            // message, so this unwrap is copy-free; both
                            // halves update in place.
                            let mut peer_c = d.into_mat_owned();
                            let s = seg.s;
                            let _w = self
                                .shared
                                .backend
                                .tree_update_into_cols(
                                    &mut seg.cp,
                                    &mut peer_c,
                                    &y1,
                                    &t,
                                    g.full_trail,
                                )
                                .unwrap_or_else(|e| self.backend_err(ctx.rank, "tree_update", e));
                            ctx.compute(crate::backend::flops::tree_update(b, seg.ncols));
                            // Return the buddy's updated rows (Ĉ'₁ =
                            // C'₁−Y₁W; same bytes as the paper's W
                            // message), moved into the reply.
                            self.send_plain(
                                ctx,
                                buddy,
                                Tag::grid(TagKind::UpdateW, g.k, s, seg.lane, g.gcol as u32),
                                MsgData::mat(peer_c),
                            )?;
                            seg.s += 1;
                            *moved = true;
                        }
                    }
                }
                UpdateWait::PlainLowerW { buddy, tag } => {
                    match self.recv_plain_poll(ctx, buddy, tag)? {
                        None => {
                            seg.wait = UpdateWait::PlainLowerW { buddy, tag };
                            return Ok(Stepped::Parked);
                        }
                        Some(d) => {
                            seg.cp = d.into_mat_owned();
                            *moved = true;
                            return Ok(Stepped::Finished);
                        }
                    }
                }
            }
        }
    }

    pub(crate) fn backend_err(&self, rank: usize, op: &str, e: anyhow::Error) -> ! {
        // Backend errors are infrastructure bugs, not simulated failures.
        panic!("backend {op} failed on rank {rank}: {e:#}");
    }
}

/// The crash flight recorder: the last few records per rank, appended
/// to fatal error reports (unrecoverable / stalled / panicked runs)
/// when tracing is on.
fn flight_dump(shared: &Shared) -> String {
    if shared.trace.is_enabled() {
        format!("\n{}", shared.trace.flight_recorder(8))
    } else {
        String::new()
    }
}

/// Outcome of a replay lookup in the buddy store (see
/// [`Ranker::fetch_retained`]).
pub(crate) enum Fetch {
    /// Retained state found: recover from it.
    Hit(super::store::Retained),
    /// The step was never completed — re-enter it live.
    Live,
    /// The buddy is behind in wall-clock; park until it retains.
    Wait,
}

/// Run a full factorization under `cfg`.
pub fn run_caqr(
    cfg: RunConfig,
    backend: Arc<Backend>,
    fault: Arc<FaultPlan>,
    trace: Arc<Trace>,
) -> Result<CaqrOutcome> {
    cfg.validate()?;
    let t0 = std::time::Instant::now();
    let a = Matrix::randn(cfg.rows, cfg.cols, cfg.seed);
    run_caqr_on(cfg, a, backend, fault, trace, t0)
}

/// Run on a caller-supplied matrix (tests want specific inputs).
pub fn run_caqr_matrix(
    cfg: RunConfig,
    a: Matrix,
    backend: Arc<Backend>,
    fault: Arc<FaultPlan>,
    trace: Arc<Trace>,
) -> Result<CaqrOutcome> {
    cfg.validate()?;
    let t0 = std::time::Instant::now();
    run_caqr_on(cfg, a, backend, fault, trace, t0)
}

/// A fully-prepared CAQR run: the world, the shared coordinator state
/// and the initial rank tasks — everything needed to either drive it
/// synchronously ([`run_caqr`]) or submit it into a caller-provided
/// persistent [`crate::sim::Pool`] (the multi-tenant service). The input
/// matrix rides along so [`CaqrJob::finalize`] can Gram-verify.
pub(crate) struct CaqrJob {
    pub(crate) cfg: RunConfig,
    pub(crate) a: Matrix,
    pub(crate) shared: Arc<Shared>,
    pub(crate) world: Arc<World>,
    pub(crate) tasks: Vec<(usize, Box<dyn RankTask>)>,
    pub(crate) flops0: u64,
    pub(crate) t0: std::time::Instant,
}

impl CaqrJob {
    /// Build the world, shared state and initial rank tasks for one run.
    /// `t0` is the wallclock origin reported in the outcome (callers that
    /// time matrix generation pass an earlier instant).
    pub(crate) fn prepare(
        mut cfg: RunConfig,
        a: Matrix,
        backend: Arc<Backend>,
        fault: Arc<FaultPlan>,
        trace: Arc<Trace>,
        t0: std::time::Instant,
    ) -> Result<Self> {
        cfg.validate()?;
        // Resolve `--checkpoint-every auto` against the failure rate the
        // injected fault plan implies, so every driver (run, serve,
        // campaign) tunes the same way. The resolved config — with a
        // concrete interval — is what the checkpoint barriers see.
        if cfg.checkpoint_auto {
            let rate = crate::checkpoint::failure_rate_estimate(
                fault.spec(),
                cfg.procs,
                cfg.panels(),
            );
            cfg.checkpoint_every = crate::checkpoint::auto_checkpoint_interval(&cfg, rate);
            cfg.checkpoint_auto = false;
        }
        anyhow::ensure!(
            a.shape() == (cfg.rows, cfg.cols),
            "input matrix shape mismatch: got {:?}, cfg says ({}, {})",
            a.shape(),
            cfg.rows,
            cfg.cols
        );
        // Scatter over the process grid: each rank's initial block is the
        // compact (m_local x local_cols) gather of the tiles it owns —
        // its grid row's row range crossed with its grid column's cyclic
        // column blocks. `Pc = 1`: the historical contiguous block-row.
        let grid = Grid::from_cfg(&cfg);
        let m_local = cfg.local_rows();
        let b = cfg.block;
        let initial: Vec<Matrix> = (0..cfg.procs)
            .map(|r| {
                let (gr, gc) = grid.coords(r);
                let lcols = grid.local_cols(gc, cfg.cols, b);
                let mut m = Matrix::zeros(m_local, lcols);
                for lb in 0..lcols / b {
                    let j = grid.global_block(lb, gc);
                    m.set_block(0, lb * b, &a.block(gr * m_local, j * b, m_local, b));
                }
                m
            })
            .collect();

        let world = World::new_with_stragglers(
            cfg.procs,
            cfg.cost,
            fault,
            Stragglers::new(cfg.stragglers.clone()),
        );
        let flops0 = backend.flops();
        // Size the per-rank trace rings up front so the hot path never
        // takes the grow lock (no-op when tracing is disabled).
        trace.ensure_ranks(cfg.procs);
        let shared = Arc::new(Shared {
            cfg: cfg.clone(),
            backend,
            store: RecoveryStore::new(),
            gate: RevivalGate::new(),
            trace,
            world: world.clone(),
            initial,
            results: Mutex::new(HashMap::new()),
            poison: Mutex::new(None),
            store_watchers: Mutex::new(HashSet::new()),
        });

        // The original incarnation of every rank; REBUILD replacements are
        // spawned into the same job's task group mid-run. Each task owns a
        // (necessarily deep) copy of its block — it mutates it — while
        // `shared.initial` stays pristine for replays.
        let tasks: Vec<(usize, Box<dyn RankTask>)> = (0..cfg.procs)
            .map(|r| {
                let t = Ranker::new(shared.clone(), false, shared.initial[r].clone());
                (r, Box::new(t) as Box<dyn RankTask>)
            })
            .collect();
        Ok(Self { cfg, a, shared, world, tasks, flops0, t0 })
    }

    /// Turn the raw task results into a [`CaqrOutcome`]: classify
    /// failures, surface poisoning, assemble `[R; 0]` and verify. Runs
    /// wherever the job completed — the submitting thread for the
    /// synchronous drivers, a pool worker for service jobs.
    pub(crate) fn finalize(
        cfg: &RunConfig,
        a: &Matrix,
        shared: &Arc<Shared>,
        world: &Arc<World>,
        results: Vec<(usize, Result<(), Fail>)>,
        flops0: u64,
        t0: std::time::Instant,
    ) -> Result<CaqrOutcome> {
        let mut failures: Vec<Fail> = Vec::new();
        for (_rank, res) in results {
            match res {
                Ok(()) => {}
                Err(Fail::Killed) => {} // replaced via REBUILD (or aborted below)
                Err(e) => failures.push(e),
            }
        }
        if let Some(p) = shared.poisoned() {
            anyhow::bail!(
                "run unrecoverable: {p} (both copies of a step's redundancy lost; \
                 other failures: {failures:?}){}",
                flight_dump(shared)
            );
        }

        let m_local = cfg.local_rows();
        let results = shared.results.lock().unwrap();
        if results.len() != cfg.procs {
            let missing: Vec<usize> =
                (0..cfg.procs).filter(|r| !results.contains_key(r)).collect();
            anyhow::bail!(
                "run did not complete: missing ranks {missing:?}, failures: {failures:?}{}",
                flight_dump(shared)
            );
        }

        // Assemble the reduced matrix [R; 0]: scatter each rank's compact
        // local blocks back to their global tile positions (the inverse
        // of the prepare-time gather).
        let grid = Grid::from_cfg(cfg);
        let b = cfg.block;
        let mut reduced = Matrix::zeros(cfg.rows, cfg.cols);
        for r in 0..cfg.procs {
            let (gr, gc) = grid.coords(r);
            let local = &results[&r];
            for lb in 0..local.cols() / b {
                let j = grid.global_block(lb, gc);
                reduced.set_block(gr * m_local, j * b, &local.block(0, lb * b, m_local, b));
            }
        }
        drop(results);

        let r = reduced.crop_to(cfg.cols, cfg.cols).triu();
        let lower_defect = {
            let strict = reduced.sub(&{
                let mut t = Matrix::zeros(cfg.rows, cfg.cols);
                t.set_block(0, 0, &r);
                t
            });
            strict.fro_norm()
        };
        let residual = cfg.verify.then(|| gram_residual(a, &r));

        // Fold the retention-store high-water into the metrics so every
        // report consumer (service, campaign, Prometheus) sees it.
        world.metrics.set_store_peak(shared.store.peak_bytes());
        Ok(CaqrOutcome {
            reduced,
            r,
            residual,
            lower_defect,
            report: world.metrics.snapshot(),
            store_peak_bytes: shared.store.peak_bytes(),
            elapsed: t0.elapsed(),
            backend_flops: shared.backend.flops() - flops0,
        })
    }
}

fn run_caqr_on(
    cfg: RunConfig,
    a: Matrix,
    backend: Arc<Backend>,
    fault: Arc<FaultPlan>,
    trace: Arc<Trace>,
    t0: std::time::Instant,
) -> Result<CaqrOutcome> {
    // One pool drives both the rank tasks and the backend's intra-rank
    // GEMM/QR band split (`cfg.par`): band closures ride the pool's
    // compute lane, so a run never oversubscribes the host with nested
    // scoped threads. The split is backend-scoped (`Backend::set_par_ctx`)
    // rather than a process global, so concurrent runs with different
    // `par` no longer race — and it never changes results: every
    // parallel path is bitwise-identical to serial.
    let workers = cfg.effective_workers();
    let pool = crate::sim::Pool::new(workers);
    backend.set_par_ctx(pool.par_ctx(cfg.par));
    // Restore the serial default on every exit path so the caller's
    // backend does not keep an executor for a pool that died with this
    // call. (Submitting to a dropped pool is safe — help-first runs the
    // bands on the submitting thread — but serial is the honest state.)
    struct SerialOnExit(Arc<Backend>);
    impl Drop for SerialOnExit {
        fn drop(&mut self) {
            self.0.set_par_ctx(crate::linalg::ParCtx::serial());
        }
    }
    let _reset = SerialOnExit(backend.clone());
    let CaqrJob { cfg, a, shared, world, tasks, flops0, t0 } =
        CaqrJob::prepare(cfg, a, backend, fault, trace, t0)?;
    let results = pool.run(&world, tasks);
    world.router().set_waker(None);
    CaqrJob::finalize(&cfg, &a, &shared, &world, results, flops0, t0)
}

/// Convenience: run with default trace/no faults on the native backend.
pub fn run_caqr_simple(cfg: RunConfig) -> Result<CaqrOutcome> {
    run_caqr(cfg, Backend::native(), FaultPlan::none(), Trace::disabled())
}

/// Default cost model re-export for binaries.
pub fn default_cost() -> CostModel {
    CostModel::default()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The row-broadcast bundle layout is computed independently by the
    /// sender (packing) and every receiver (unpacking); it must be a
    /// pure function of (algorithm, tree index, tree size). Pin the
    /// invariants the unpack side relies on: slots are strictly
    /// increasing, every Upper/Lower reduce-tree step a receiver's
    /// update tree will read is present, and no slot repeats.
    #[test]
    fn merge_slot_layout_covers_the_update_tree() {
        for q in 1..=9usize {
            for idx in 0..q {
                for alg in [Algorithm::FaultTolerant, Algorithm::Plain] {
                    let slots = merge_slots(alg, idx, q);
                    assert!(
                        slots.windows(2).all(|w| w[0] < w[1]),
                        "slots must be sorted unique (alg {alg:?} idx {idx} q {q})"
                    );
                    for s in 0..tree::steps(q) {
                        let needed = match alg {
                            // The FT update tree walks every step where
                            // the rank is an active reduce node with a
                            // partner; plain only merges as Upper.
                            Algorithm::FaultTolerant => {
                                tree::reduce_active(idx, s)
                                    && tree::exchange_pair(idx, s, q).is_some()
                            }
                            Algorithm::Plain => {
                                tree::reduce_active(idx, s)
                                    && tree::reduce_pair(idx, s, q).0 == Role::Upper
                            }
                        };
                        assert_eq!(
                            slots.contains(&s),
                            needed,
                            "slot {s} mismatch (alg {alg:?} idx {idx} q {q})"
                        );
                    }
                }
            }
        }
    }

    /// FT slots are a superset of plain slots at every (idx, q): the
    /// all-exchange tree merges on both sides of each pair, so a bundle
    /// packed by an FT sender always carries what a plain receiver at
    /// the same index would need.
    #[test]
    fn ft_slots_cover_plain_slots() {
        for q in 1..=9usize {
            for idx in 0..q {
                let ft = merge_slots(Algorithm::FaultTolerant, idx, q);
                for s in merge_slots(Algorithm::Plain, idx, q) {
                    assert!(ft.contains(&s), "plain slot {s} missing from FT (idx {idx} q {q})");
                }
            }
        }
    }
}
