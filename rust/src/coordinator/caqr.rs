//! The CAQR panel driver and per-rank algorithm bodies.
//!
//! `run_caqr` builds the simulated world, distributes block rows, runs
//! every rank's panel loop (TSQR + trailing update, plain or FT) as a
//! resumable task on the bounded worker pool — including any REBUILD
//! replacement tasks spawned by recovery — assembles the reduced matrix,
//! and verifies the Gram identity. Rank bodies are explicit state
//! machines ([`Ranker`]): they park on in-flight exchanges/receives
//! instead of blocking an OS thread, so P = 256–1024 rank runs fit on a
//! laptop core count (see `DESIGN.md` "Scheduler: parking and wakeup").
//!
//! Conventions (see `DESIGN.md` "Pair stacking and message patterns"):
//! * pair stacking: the smaller tree index owns the globally-upper rows
//!   and is the top (`R0`/`C0'`) of every stacked merge; the top member
//!   continues up the tree, the bottom leaves after its step.
//! * Algorithm 1 (plain): bottom sends `C'₁`, top computes the pair
//!   update and returns `Ĉ'₁` — two serialized one-way messages.
//! * Algorithm 2 (FT): both members already hold the merge factors (the
//!   FT-TSQR exchanged R's), `sendrecv` their `C'` rows, and both
//!   compute `W` and their own update; `{W, T, C', Y₁}` is retained for
//!   single-buddy recovery (paper §III-C).

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use anyhow::Result;
use std::sync::Mutex;

use crate::backend::Backend;
use crate::config::{Algorithm, RunConfig};
use crate::fault::{FailSite, FaultPlan, Phase};
use crate::ft::Fail;
use crate::linalg::{gram_residual, Matrix};
use crate::metrics::Report;
use crate::sim::{CostModel, MsgData, RankCtx, RankTask, Spawner, Tag, TagKind, TaskPoll, World};
use crate::trace::Trace;

use super::panel::{geometry, PanelGeom};
use super::recovery::FtOp;
use super::store::{RecoveryStore, RevivalGate};
use super::tree::{self, Role};

/// Immutable context shared by every rank task (original and rebuilt).
pub struct Shared {
    /// The run description.
    pub cfg: RunConfig,
    /// Compute backend serving the five numeric ops.
    pub backend: Arc<Backend>,
    /// Buddy-retained redundancy state (paper §III-C).
    pub store: Arc<RecoveryStore>,
    /// REBUILD arbitration: one winner per dead incarnation.
    pub gate: Arc<RevivalGate>,
    /// Structured event trace.
    pub trace: Arc<Trace>,
    /// The simulated machine.
    pub world: Arc<World>,
    /// Per-rank initial blocks — the "subpart of the initial matrix" the
    /// paper's recovery re-reads (stable storage / parallel FS stand-in).
    pub initial: Vec<Matrix>,
    /// Final local blocks, written by each rank on completion.
    pub results: Mutex<HashMap<usize, Matrix>>,
    /// First unrecoverable failure observed; poisons the whole run (no
    /// further REBUILDs, every detector aborts).
    pub poison: Mutex<Option<Fail>>,
    /// Ranks parked waiting for a buddy's retained-state insert (a
    /// replaying replacement that outran its wall-clock-slower buddy).
    pub(crate) store_watchers: Mutex<HashSet<usize>>,
}

impl Shared {
    /// The poisoning failure, if the run has been declared unrecoverable.
    pub fn poisoned(&self) -> Option<Fail> {
        self.poison.lock().unwrap().clone()
    }

    pub(crate) fn poison_with(&self, f: Fail) {
        let mut g = self.poison.lock().unwrap();
        if g.is_none() {
            *g = Some(f);
        }
    }

    /// Register `rank` to be poked on the next retained-state insert.
    pub(crate) fn watch_store(&self, rank: usize) {
        self.store_watchers.lock().unwrap().insert(rank);
    }

    /// Poke every watcher (called after each retained-state insert).
    pub(crate) fn notify_store_watchers(&self) {
        let drained: Vec<usize> = {
            let mut g = self.store_watchers.lock().unwrap();
            g.drain().collect()
        };
        for r in drained {
            self.world.router().notify(r);
        }
    }
}

/// Outcome of a full factorization run.
#[derive(Debug)]
pub struct CaqrOutcome {
    /// The assembled reduced matrix (rows x cols; `[R; 0]`).
    pub reduced: Matrix,
    /// Upper-triangular `R` (cols x cols).
    pub r: Matrix,
    /// `‖AᵀA − RᵀR‖_F / ‖AᵀA‖_F` when `cfg.verify`.
    pub residual: Option<f32>,
    /// Frobenius norm of the strictly-lower part of `reduced` (should
    /// be ~0).
    pub lower_defect: f32,
    /// Metrics snapshot.
    pub report: Report,
    /// Peak bytes of buddy-retained redundancy state.
    pub store_peak_bytes: u64,
    /// Wallclock of the simulated run.
    pub elapsed: std::time::Duration,
    /// Flops issued through the backend.
    pub backend_flops: u64,
}

/// TSQR-phase working state for one panel on one rank. The factor
/// matrices are `Arc`-shared with the retention store and any in-flight
/// message payloads — handing `R` to the exchange or the buddy store
/// bumps a refcount instead of deep-copying the buffer.
pub(crate) struct TsqrPhase {
    g: PanelGeom,
    leaf_y: Matrix,
    leaf_t: Matrix,
    r: Arc<Matrix>,
    /// (Y1, T) per tree step where this rank is a reduce-tree member.
    merges: Vec<Option<(Arc<Matrix>, Arc<Matrix>)>>,
    s: usize,
    wait: TsqrWait,
}

enum TsqrWait {
    /// Ready to enter tree step `s`.
    Enter,
    /// FT exchange in flight.
    Ft(FtOp),
    /// Plain upper member waiting for the lower member's R.
    PlainRecv { buddy: usize, tag: Tag },
}

/// Update-phase working state for one panel on one rank.
pub(crate) struct UpdatePhase {
    g: PanelGeom,
    merges: Vec<Option<(Arc<Matrix>, Arc<Matrix>)>>,
    /// The top-b rows of this rank's active trailing block, updated in
    /// place by each tree step (never cloned into the step kernels).
    cp: Matrix,
    s: usize,
    wait: UpdateWait,
}

enum UpdateWait {
    Enter,
    Ft { op: FtOp, role: Role, y1: Arc<Matrix>, t: Arc<Matrix> },
    PlainUpper { buddy: usize, tag: Tag, y1: Arc<Matrix>, t: Arc<Matrix> },
    PlainLowerW { buddy: usize, tag: Tag },
}

/// Where one rank task currently is in the panel loop.
enum State {
    /// About to start panel `k` (or finish, when `k == panels`).
    Panel { k: usize },
    Tsqr(TsqrPhase),
    Update(UpdatePhase),
    Checkpoint { g: PanelGeom, op: FtOp },
    Done,
}

/// Outcome of stepping a phase state machine.
enum Stepped {
    /// A non-blocking primitive reported "nothing yet" — park.
    Parked,
    /// The phase completed.
    Finished,
}

/// One rank's resumable panel-loop body (original or REBUILD replacement).
pub(crate) struct Ranker {
    pub(crate) shared: Arc<Shared>,
    /// True for a REBUILD replacement replaying history.
    pub(crate) resume: bool,
    /// The local block-row (m_local x cols), updated in place.
    pub(crate) local: Matrix,
    state: State,
}

impl RankTask for Ranker {
    fn poll(&mut self, ctx: &mut RankCtx, sp: &Spawner) -> TaskPoll {
        match self.drive(ctx, sp) {
            Ok(true) => TaskPoll::Ready(Ok(())),
            Ok(false) => TaskPoll::Pending,
            Err(e) => {
                if let Fail::Unrecoverable { .. } = &e {
                    // Poison BEFORE killing ourselves so detectors see it.
                    self.shared.poison_with(e.clone());
                }
                // A rank that exits abnormally (Abort cascade,
                // unrecoverable failure) must look dead to its peers, or
                // they would park forever waiting for its messages —
                // MPI_Abort semantics.
                if e != Fail::Killed {
                    ctx.router().kill(ctx.rank);
                }
                TaskPoll::Ready(Err(e))
            }
        }
    }
}

impl Ranker {
    pub(crate) fn new(shared: Arc<Shared>, resume: bool, local: Matrix) -> Self {
        Self { shared, resume, local, state: State::Panel { k: 0 } }
    }

    fn cfg(&self) -> &RunConfig {
        &self.shared.cfg
    }

    /// Run the state machine forward as far as possible.
    /// `Ok(true)` = the rank completed; `Ok(false)` = parked.
    fn drive(&mut self, ctx: &mut RankCtx, sp: &Spawner) -> Result<bool, Fail> {
        loop {
            let state = std::mem::replace(&mut self.state, State::Done);
            match state {
                State::Panel { k } => {
                    if k == self.cfg().panels() {
                        self.finish(ctx);
                        return Ok(true);
                    }
                    let g = geometry(self.cfg(), ctx.rank, k);
                    crate::simlog!(
                        "[r{} inc] panel {k} start (resume={})",
                        ctx.rank,
                        self.resume
                    );
                    if !g.participates {
                        self.state = State::Panel { k: k + 1 };
                        continue;
                    }
                    let ph = self.begin_tsqr(ctx, g);
                    self.state = State::Tsqr(ph);
                }
                State::Tsqr(mut ph) => match self.step_tsqr(&mut ph, ctx, sp)? {
                    Stepped::Parked => {
                        self.state = State::Tsqr(ph);
                        return Ok(false);
                    }
                    Stepped::Finished => {
                        self.state = self.after_tsqr(ctx, ph);
                    }
                },
                State::Update(mut ph) => match self.step_update(&mut ph, ctx, sp)? {
                    Stepped::Parked => {
                        self.state = State::Update(ph);
                        return Ok(false);
                    }
                    Stepped::Finished => {
                        let g = ph.g;
                        self.local.set_block(g.start, g.trail_col, &ph.cp);
                        self.state = self.next_after_panel(ctx.rank, g);
                    }
                },
                State::Checkpoint { g, mut op } => match self.poll_ft(&mut op, ctx, sp)? {
                    None => {
                        self.state = State::Checkpoint { g, op };
                        return Ok(false);
                    }
                    Some(_peer_copy) => {
                        self.shared.trace.emit(
                            ctx.clock,
                            ctx.rank,
                            g.k,
                            0,
                            "checkpoint",
                            op.peer() as f64,
                        );
                        self.state = State::Panel { k: g.k + 1 };
                    }
                },
                State::Done => unreachable!("drive called after completion"),
            }
        }
    }

    fn finish(&mut self, ctx: &mut RankCtx) {
        if self.resume {
            ctx.metrics.record_recovery();
            self.shared.trace.emit(ctx.clock, ctx.rank, 0, 0, "recovery_done", 0.0);
        }
        crate::simlog!("[r{}] done", ctx.rank);
        // The task is done with its block — move it out instead of
        // cloning a whole local matrix per rank.
        let local = std::mem::replace(&mut self.local, Matrix::zeros(0, 0));
        self.shared.results.lock().unwrap().insert(ctx.rank, local);
    }

    /// Leaf factorization of the active panel rows (zero-row padded) —
    /// the local, non-blocking prologue of the TSQR phase.
    fn begin_tsqr(&mut self, ctx: &mut RankCtx, g: PanelGeom) -> TsqrPhase {
        let b = self.cfg().block;
        let m_local = self.cfg().local_rows();
        let apanel =
            self.local.block_padded(g.start, g.k * b, g.active_m, b, m_local, b);
        let leaf = self
            .shared
            .backend
            .panel_qr(&apanel)
            .unwrap_or_else(|e| self.backend_err(ctx.rank, "panel_qr", e));
        ctx.compute(crate::backend::flops::panel_qr(m_local, b));
        let nsteps = tree::steps(g.q);
        TsqrPhase {
            g,
            leaf_y: leaf.y,
            leaf_t: leaf.t,
            r: Arc::new(leaf.r),
            merges: vec![None; nsteps],
            s: 0,
            wait: TsqrWait::Enter,
        }
    }

    /// Panel factorization tree: plain reduction or FT all-exchange
    /// (paper §III-B), with the replay shortcut for REBUILD replacements.
    fn step_tsqr(
        &mut self,
        ph: &mut TsqrPhase,
        ctx: &mut RankCtx,
        sp: &Spawner,
    ) -> Result<Stepped, Fail> {
        let b = self.cfg().block;
        let nsteps = tree::steps(ph.g.q);
        loop {
            match std::mem::replace(&mut ph.wait, TsqrWait::Enter) {
                TsqrWait::Enter => {
                    if ph.s == nsteps {
                        return Ok(Stepped::Finished);
                    }
                    let g = ph.g;
                    let s = ph.s;
                    match self.cfg().algorithm {
                        Algorithm::FaultTolerant => {
                            let site = FailSite { panel: g.k, step: s, phase: Phase::Tsqr };
                            self.maybe_fail(ctx, site)?;
                            let Some(bidx) = tree::exchange_pair(g.idx, s, g.q) else {
                                ph.s += 1;
                                continue;
                            };
                            let buddy = bidx + g.owner;
                            let tag = Tag::new(TagKind::TsqrR, g.k, s);

                            // Replay path: take the completed merge from
                            // the buddy's retained memory (paper III-C).
                            if self.resume {
                                match self.fetch_retained(ctx, sp, buddy, g.k, Phase::Tsqr, s)? {
                                    Fetch::Hit(ret) => {
                                        if tree::reduce_active(g.idx, s) {
                                            ph.merges[s] =
                                                Some((ret.y1.clone(), ret.t.clone()));
                                        }
                                        self.retain_tsqr(
                                            ctx.rank,
                                            ctx.incarnation(),
                                            &g,
                                            s,
                                            buddy,
                                            &ret.y1,
                                            &ret.t,
                                            &ret.r_merged,
                                        );
                                        // Same Arc the buddy holds: the
                                        // replayed R is bit-identical by
                                        // construction.
                                        ph.r = ret.r_merged;
                                        ph.s += 1;
                                        continue;
                                    }
                                    Fetch::Wait => return Ok(Stepped::Parked),
                                    Fetch::Live => {}
                                }
                            }
                            ph.wait =
                                TsqrWait::Ft(FtOp::new(buddy, tag, MsgData::Mat(ph.r.clone())));
                        }
                        Algorithm::Plain => {
                            if !tree::reduce_active(g.idx, s) {
                                return Ok(Stepped::Finished);
                            }
                            let site = FailSite { panel: g.k, step: s, phase: Phase::Tsqr };
                            self.maybe_fail(ctx, site)?;
                            let (role, bidx) = tree::reduce_pair(g.idx, s, g.q);
                            let buddy = bidx + g.owner;
                            let tag = Tag::new(TagKind::TsqrR, g.k, s);
                            match role {
                                Role::Idle => {
                                    ph.s += 1;
                                }
                                Role::Upper => {
                                    ph.wait = TsqrWait::PlainRecv { buddy, tag };
                                }
                                Role::Lower => {
                                    self.send_plain(
                                        ctx,
                                        buddy,
                                        tag,
                                        MsgData::Mat(ph.r.clone()),
                                    )?;
                                    return Ok(Stepped::Finished);
                                }
                            }
                        }
                    }
                }
                TsqrWait::Ft(mut op) => match self.poll_ft(&mut op, ctx, sp)? {
                    None => {
                        ph.wait = TsqrWait::Ft(op);
                        return Ok(Stepped::Parked);
                    }
                    Some(d) => {
                        let peer = d.into_mat();
                        let g = ph.g;
                        let s = ph.s;
                        let buddy = op.peer();
                        let bidx = buddy - g.owner;
                        let mf = {
                            let (rtop, rbot) = if tree::is_top(g.idx, bidx) {
                                (ph.r.as_ref(), peer.as_ref())
                            } else {
                                (peer.as_ref(), ph.r.as_ref())
                            };
                            self.shared
                                .backend
                                .tsqr_merge(rtop, rbot)
                                .unwrap_or_else(|e| self.backend_err(ctx.rank, "tsqr_merge", e))
                        };
                        ctx.compute(crate::backend::flops::tsqr_merge(b));
                        self.shared.trace.emit(
                            ctx.clock,
                            ctx.rank,
                            g.k,
                            s,
                            "redundancy",
                            tree::expected_redundancy(s) as f64,
                        );
                        // One allocation per factor; every holder (tree
                        // state, retention store, next exchange payload)
                        // shares it.
                        let y1 = Arc::new(mf.y1);
                        let t = Arc::new(mf.t);
                        let r = Arc::new(mf.r);
                        if tree::reduce_active(g.idx, s) {
                            ph.merges[s] = Some((y1.clone(), t.clone()));
                        }
                        self.retain_tsqr(
                            ctx.rank,
                            ctx.incarnation(),
                            &g,
                            s,
                            buddy,
                            &y1,
                            &t,
                            &r,
                        );
                        ph.r = r;
                        ph.s += 1;
                    }
                },
                TsqrWait::PlainRecv { buddy, tag } => {
                    match self.recv_plain_poll(ctx, buddy, tag)? {
                        None => {
                            ph.wait = TsqrWait::PlainRecv { buddy, tag };
                            return Ok(Stepped::Parked);
                        }
                        Some(d) => {
                            let peer = d.into_mat();
                            let mf = self
                                .shared
                                .backend
                                .tsqr_merge(ph.r.as_ref(), peer.as_ref())
                                .unwrap_or_else(|e| self.backend_err(ctx.rank, "tsqr_merge", e));
                            ctx.compute(crate::backend::flops::tsqr_merge(b));
                            ph.merges[ph.s] = Some((Arc::new(mf.y1), Arc::new(mf.t)));
                            ph.r = Arc::new(mf.r);
                            ph.s += 1;
                        }
                    }
                }
            }
        }
    }

    /// Write the panel columns of the reduced matrix (the owner holds R;
    /// everyone else's active panel rows are eliminated), then move on to
    /// the trailing update / checkpoint / next panel.
    fn after_tsqr(&mut self, ctx: &mut RankCtx, ph: TsqrPhase) -> State {
        let g = ph.g;
        let b = self.cfg().block;
        let mut panel_out = Matrix::zeros(g.active_m, b);
        if g.idx == 0 {
            panel_out.set_block(0, 0, ph.r.as_ref());
        }
        self.local.set_block(g.start, g.k * b, &panel_out);

        if g.n_trail > 0 {
            let ph2 = self.begin_update(ctx, g, &ph.leaf_y, &ph.leaf_t, ph.merges);
            State::Update(ph2)
        } else {
            self.next_after_panel(ctx.rank, g)
        }
    }

    /// Diskless-checkpoint baseline traffic (E7), if configured; else
    /// straight to the next panel.
    fn next_after_panel(&mut self, rank: usize, g: PanelGeom) -> State {
        // NOTE: retained state is kept for the whole run. Replay of a
        // failed rank walks its entire history (paper III-C recovers one
        // step from one buddy; the full-state rebuild composes those
        // per-step recoveries), so early retirement would leave a later
        // replay with nothing to read — see the E7 bench for the measured
        // memory cost vs diskless checkpointing.
        let every = self.cfg().checkpoint_every;
        if every == 0 || (g.k + 1) % every != 0 {
            return State::Panel { k: g.k + 1 };
        }
        // Pair within the ranks still participating in this panel —
        // retired ranks have left the computation and exchange nothing.
        let pidx = g.idx ^ 1;
        if pidx >= g.q {
            return State::Panel { k: g.k + 1 };
        }
        // Replay shortcut: if the pre-death incarnation had already moved
        // past this panel (its frontier shows a later-panel step), the
        // partner completed its half of this checkpoint long ago and will
        // never exchange it again — re-entering would park forever.
        if self.resume && self.shared.store.has_completed(rank, g.k + 1, Phase::Tsqr, 0) {
            return State::Panel { k: g.k + 1 };
        }
        let partner = g.owner + pidx;
        let tag = Tag::new(TagKind::Checkpoint, g.k, 0);
        // One snapshot copy into an Arc; the exchange's retransmit buffer
        // and the routed envelope share it instead of re-copying.
        let op = FtOp::new(partner, tag, MsgData::mat(self.local.clone()));
        State::Checkpoint { g, op }
    }

    /// Leaf: apply the local reflectors to the whole trailing block —
    /// the local, non-blocking prologue of the update phase. The trailing
    /// block is extracted once (zero-row padded), updated in place, and
    /// written back through a view — no `crop_to` round-trip copy.
    fn begin_update(
        &mut self,
        ctx: &mut RankCtx,
        g: PanelGeom,
        leaf_y: &Matrix,
        leaf_t: &Matrix,
        merges: Vec<Option<(Arc<Matrix>, Arc<Matrix>)>>,
    ) -> UpdatePhase {
        let b = self.cfg().block;
        let m_local = self.cfg().local_rows();
        let mut c = self.local.block_padded(
            g.start,
            g.trail_col,
            g.active_m,
            g.n_trail,
            m_local,
            g.n_trail,
        );
        self.shared
            .backend
            .leaf_apply_into(leaf_y, leaf_t, &mut c)
            .unwrap_or_else(|e| self.backend_err(ctx.rank, "leaf_apply", e));
        ctx.compute(crate::backend::flops::leaf_apply(m_local, b, g.n_trail));
        self.local
            .set_block_view(g.start, g.trail_col, c.view(0, 0, g.active_m, g.n_trail));

        // Tree over the top-b rows of each participant's active block.
        let cp = self.local.block(g.start, g.trail_col, b, g.n_trail);
        UpdatePhase { g, merges, cp, s: 0, wait: UpdateWait::Enter }
    }

    /// Trailing-matrix update tree (paper Algorithms 1 and 2), with the
    /// replay shortcut (`Ĉ' = C' − Y W`) for REBUILD replacements.
    fn step_update(
        &mut self,
        ph: &mut UpdatePhase,
        ctx: &mut RankCtx,
        sp: &Spawner,
    ) -> Result<Stepped, Fail> {
        let b = self.cfg().block;
        loop {
            match std::mem::replace(&mut ph.wait, UpdateWait::Enter) {
                UpdateWait::Enter => {
                    let g = ph.g;
                    let s = ph.s;
                    if s == tree::steps(g.q) || !tree::reduce_active(g.idx, s) {
                        return Ok(Stepped::Finished);
                    }
                    let (role, bidx) = tree::reduce_pair(g.idx, s, g.q);
                    if role == Role::Idle {
                        ph.s += 1;
                        continue;
                    }
                    let site = FailSite { panel: g.k, step: s, phase: Phase::Update };
                    self.maybe_fail(ctx, site)?;
                    let buddy = bidx + g.owner;
                    let tag = Tag::new(TagKind::UpdateC, g.k, s);

                    match self.cfg().algorithm {
                        Algorithm::FaultTolerant => {
                            let (y1, t) = ph.merges[s]
                                .clone()
                                .expect("FT rank holds merge factors for its tree steps");

                            // Replay path: recompute our rows from the
                            // buddy's retained {W, Y1} — the paper's
                            // recovery equation, applied in place.
                            if self.resume {
                                match self.fetch_retained(ctx, sp, buddy, g.k, Phase::Update, s)? {
                                    Fetch::Hit(ret) => {
                                        self.recover_rows(ctx, &mut ph.cp, role, &ret);
                                        self.retain_update(
                                            ctx.rank,
                                            ctx.incarnation(),
                                            &g,
                                            s,
                                            buddy,
                                            &ret.w,
                                            &y1,
                                            &t,
                                        );
                                        if role == Role::Lower {
                                            return Ok(Stepped::Finished);
                                        }
                                        ph.s += 1;
                                        continue;
                                    }
                                    Fetch::Wait => return Ok(Stepped::Parked),
                                    Fetch::Live => {}
                                }
                            }
                            // One snapshot copy of our rows into the
                            // shared payload (the exchange may have to
                            // retransmit it after a peer REBUILD).
                            let op = FtOp::new(buddy, tag, MsgData::mat(ph.cp.clone()));
                            ph.wait = UpdateWait::Ft { op, role, y1, t };
                        }
                        Algorithm::Plain => match role {
                            Role::Idle => unreachable!("idle handled above"),
                            Role::Upper => {
                                let (y1, t) = ph.merges[s]
                                    .clone()
                                    .expect("plain upper holds merge factors");
                                ph.wait = UpdateWait::PlainUpper { buddy, tag, y1, t };
                            }
                            Role::Lower => {
                                // Our rows travel to the top member and
                                // come back updated — move them into the
                                // message instead of cloning.
                                let cp = std::mem::replace(&mut ph.cp, Matrix::zeros(0, 0));
                                self.send_plain(ctx, buddy, tag, MsgData::mat(cp))?;
                                ph.wait = UpdateWait::PlainLowerW {
                                    buddy,
                                    tag: Tag::new(TagKind::UpdateW, g.k, s),
                                };
                            }
                        },
                    }
                }
                UpdateWait::Ft { mut op, role, y1, t } => {
                    match self.poll_ft(&mut op, ctx, sp)? {
                        None => {
                            ph.wait = UpdateWait::Ft { op, role, y1, t };
                            return Ok(Stepped::Parked);
                        }
                        Some(d) => {
                            // Peer rows are read-only for our half of the
                            // pair step: borrow them straight out of the
                            // message, update our rows in place.
                            let peer_c = d.into_mat();
                            let g = ph.g;
                            let s = ph.s;
                            let w = self
                                .shared
                                .backend
                                .tree_update_half(
                                    &mut ph.cp,
                                    peer_c.as_ref(),
                                    &y1,
                                    &t,
                                    role == Role::Upper,
                                )
                                .unwrap_or_else(|e| {
                                    self.backend_err(ctx.rank, "tree_update", e)
                                });
                            // Both members are charged the full pair
                            // computation — the paper's traded energy
                            // cost (E4) — regardless of the host-side
                            // half-update optimization.
                            ctx.compute(crate::backend::flops::tree_update(b, g.n_trail));
                            self.shared.trace.emit(
                                ctx.clock,
                                ctx.rank,
                                g.k,
                                s,
                                "update_exchange",
                                op.peer() as f64,
                            );
                            let w = Arc::new(w);
                            self.retain_update(
                                ctx.rank,
                                ctx.incarnation(),
                                &g,
                                s,
                                op.peer(),
                                &w,
                                &y1,
                                &t,
                            );
                            if role == Role::Lower {
                                return Ok(Stepped::Finished);
                            }
                            ph.s += 1;
                        }
                    }
                }
                UpdateWait::PlainUpper { buddy, tag, y1, t } => {
                    match self.recv_plain_poll(ctx, buddy, tag)? {
                        None => {
                            ph.wait = UpdateWait::PlainUpper { buddy, tag, y1, t };
                            return Ok(Stepped::Parked);
                        }
                        Some(d) => {
                            // The lower member moved its rows into the
                            // message, so this unwrap is copy-free; both
                            // halves update in place.
                            let mut peer_c = d.into_mat_owned();
                            let g = ph.g;
                            let s = ph.s;
                            let _w = self
                                .shared
                                .backend
                                .tree_update_into(&mut ph.cp, &mut peer_c, &y1, &t)
                                .unwrap_or_else(|e| self.backend_err(ctx.rank, "tree_update", e));
                            ctx.compute(crate::backend::flops::tree_update(b, g.n_trail));
                            // Return the buddy's updated rows (Ĉ'₁ =
                            // C'₁−Y₁W; same bytes as the paper's W
                            // message), moved into the reply.
                            self.send_plain(
                                ctx,
                                buddy,
                                Tag::new(TagKind::UpdateW, g.k, s),
                                MsgData::mat(peer_c),
                            )?;
                            ph.s += 1;
                        }
                    }
                }
                UpdateWait::PlainLowerW { buddy, tag } => {
                    match self.recv_plain_poll(ctx, buddy, tag)? {
                        None => {
                            ph.wait = UpdateWait::PlainLowerW { buddy, tag };
                            return Ok(Stepped::Parked);
                        }
                        Some(d) => {
                            ph.cp = d.into_mat_owned();
                            return Ok(Stepped::Finished);
                        }
                    }
                }
            }
        }
    }

    pub(crate) fn backend_err(&self, rank: usize, op: &str, e: anyhow::Error) -> ! {
        // Backend errors are infrastructure bugs, not simulated failures.
        panic!("backend {op} failed on rank {rank}: {e:#}");
    }
}

/// Outcome of a replay lookup in the buddy store (see
/// [`Ranker::fetch_retained`]).
pub(crate) enum Fetch {
    /// Retained state found: recover from it.
    Hit(super::store::Retained),
    /// The step was never completed — re-enter it live.
    Live,
    /// The buddy is behind in wall-clock; park until it retains.
    Wait,
}

/// Run a full factorization under `cfg`.
pub fn run_caqr(
    cfg: RunConfig,
    backend: Arc<Backend>,
    fault: Arc<FaultPlan>,
    trace: Arc<Trace>,
) -> Result<CaqrOutcome> {
    cfg.validate()?;
    let t0 = std::time::Instant::now();
    let a = Matrix::randn(cfg.rows, cfg.cols, cfg.seed);
    run_caqr_on(cfg, a, backend, fault, trace, t0)
}

/// Run on a caller-supplied matrix (tests want specific inputs).
pub fn run_caqr_matrix(
    cfg: RunConfig,
    a: Matrix,
    backend: Arc<Backend>,
    fault: Arc<FaultPlan>,
    trace: Arc<Trace>,
) -> Result<CaqrOutcome> {
    cfg.validate()?;
    let t0 = std::time::Instant::now();
    run_caqr_on(cfg, a, backend, fault, trace, t0)
}

/// A fully-prepared CAQR run: the world, the shared coordinator state
/// and the initial rank tasks — everything needed to either drive it
/// synchronously ([`run_caqr`]) or submit it into a caller-provided
/// persistent [`crate::sim::Pool`] (the multi-tenant service). The input
/// matrix rides along so [`CaqrJob::finalize`] can Gram-verify.
pub(crate) struct CaqrJob {
    pub(crate) cfg: RunConfig,
    pub(crate) a: Matrix,
    pub(crate) shared: Arc<Shared>,
    pub(crate) world: Arc<World>,
    pub(crate) tasks: Vec<(usize, Box<dyn RankTask>)>,
    pub(crate) flops0: u64,
    pub(crate) t0: std::time::Instant,
}

impl CaqrJob {
    /// Build the world, shared state and initial rank tasks for one run.
    /// `t0` is the wallclock origin reported in the outcome (callers that
    /// time matrix generation pass an earlier instant).
    pub(crate) fn prepare(
        cfg: RunConfig,
        a: Matrix,
        backend: Arc<Backend>,
        fault: Arc<FaultPlan>,
        trace: Arc<Trace>,
        t0: std::time::Instant,
    ) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(
            a.shape() == (cfg.rows, cfg.cols),
            "input matrix shape mismatch: got {:?}, cfg says ({}, {})",
            a.shape(),
            cfg.rows,
            cfg.cols
        );
        let m_local = cfg.local_rows();
        let initial: Vec<Matrix> = (0..cfg.procs)
            .map(|r| a.block(r * m_local, 0, m_local, cfg.cols))
            .collect();

        let world = World::new(cfg.procs, cfg.cost, fault);
        let flops0 = backend.flops();
        let shared = Arc::new(Shared {
            cfg: cfg.clone(),
            backend,
            store: RecoveryStore::new(),
            gate: RevivalGate::new(),
            trace,
            world: world.clone(),
            initial,
            results: Mutex::new(HashMap::new()),
            poison: Mutex::new(None),
            store_watchers: Mutex::new(HashSet::new()),
        });

        // The original incarnation of every rank; REBUILD replacements are
        // spawned into the same job's task group mid-run. Each task owns a
        // (necessarily deep) copy of its block — it mutates it — while
        // `shared.initial` stays pristine for replays.
        let tasks: Vec<(usize, Box<dyn RankTask>)> = (0..cfg.procs)
            .map(|r| {
                let t = Ranker::new(shared.clone(), false, shared.initial[r].clone());
                (r, Box::new(t) as Box<dyn RankTask>)
            })
            .collect();
        Ok(Self { cfg, a, shared, world, tasks, flops0, t0 })
    }

    /// Turn the raw task results into a [`CaqrOutcome`]: classify
    /// failures, surface poisoning, assemble `[R; 0]` and verify. Runs
    /// wherever the job completed — the submitting thread for the
    /// synchronous drivers, a pool worker for service jobs.
    pub(crate) fn finalize(
        cfg: &RunConfig,
        a: &Matrix,
        shared: &Arc<Shared>,
        world: &Arc<World>,
        results: Vec<(usize, Result<(), Fail>)>,
        flops0: u64,
        t0: std::time::Instant,
    ) -> Result<CaqrOutcome> {
        let mut failures: Vec<Fail> = Vec::new();
        for (_rank, res) in results {
            match res {
                Ok(()) => {}
                Err(Fail::Killed) => {} // replaced via REBUILD (or aborted below)
                Err(e) => failures.push(e),
            }
        }
        if let Some(p) = shared.poisoned() {
            anyhow::bail!(
                "run unrecoverable: {p} (both copies of a step's redundancy lost; \
                 other failures: {failures:?})"
            );
        }

        let m_local = cfg.local_rows();
        let results = shared.results.lock().unwrap();
        if results.len() != cfg.procs {
            let missing: Vec<usize> =
                (0..cfg.procs).filter(|r| !results.contains_key(r)).collect();
            anyhow::bail!(
                "run did not complete: missing ranks {missing:?}, failures: {failures:?}"
            );
        }

        // Assemble the reduced matrix [R; 0].
        let mut reduced = Matrix::zeros(cfg.rows, cfg.cols);
        for r in 0..cfg.procs {
            reduced.set_block(r * m_local, 0, &results[&r]);
        }
        drop(results);

        let r = reduced.crop_to(cfg.cols, cfg.cols).triu();
        let lower_defect = {
            let strict = reduced.sub(&{
                let mut t = Matrix::zeros(cfg.rows, cfg.cols);
                t.set_block(0, 0, &r);
                t
            });
            strict.fro_norm()
        };
        let residual = cfg.verify.then(|| gram_residual(a, &r));

        Ok(CaqrOutcome {
            reduced,
            r,
            residual,
            lower_defect,
            report: world.metrics.snapshot(),
            store_peak_bytes: shared.store.peak_bytes(),
            elapsed: t0.elapsed(),
            backend_flops: shared.backend.flops() - flops0,
        })
    }
}

fn run_caqr_on(
    cfg: RunConfig,
    a: Matrix,
    backend: Arc<Backend>,
    fault: Arc<FaultPlan>,
    trace: Arc<Trace>,
    t0: std::time::Instant,
) -> Result<CaqrOutcome> {
    // The GEMM split knob is process-wide; apply this run's value and
    // restore the previous one on every exit path (including bail!).
    // Concurrent runs with different `par` race only on thread count,
    // never on results (the kernels are bit-deterministic either way).
    struct ParGuard(usize);
    impl Drop for ParGuard {
        fn drop(&mut self) {
            crate::linalg::set_par_threads(self.0);
        }
    }
    let _par_guard = ParGuard(crate::linalg::par_threads());
    crate::linalg::set_par_threads(cfg.par);
    let workers = cfg.effective_workers();
    let CaqrJob { cfg, a, shared, world, tasks, flops0, t0 } =
        CaqrJob::prepare(cfg, a, backend, fault, trace, t0)?;
    let results = world.run_tasks(workers, tasks);
    CaqrJob::finalize(&cfg, &a, &shared, &world, results, flops0, t0)
}

/// Convenience: run with default trace/no faults on the native backend.
pub fn run_caqr_simple(cfg: RunConfig) -> Result<CaqrOutcome> {
    run_caqr(cfg, Backend::native(), FaultPlan::none(), Trace::disabled())
}

/// Default cost model re-export for binaries.
pub fn default_cost() -> CostModel {
    CostModel::default()
}
