//! Standalone TSQR driver (paper §III-B, Fig 2): factorize one tall-skinny
//! panel across P simulated ranks, either with the plain binary-tree
//! reduction or the fault-tolerant all-exchange tree, and measure the
//! redundancy of each intermediate R along the way.
//!
//! This is experiment E1's engine; the full CAQR driver embeds the same
//! logic per panel, but the standalone version exposes the per-step
//! redundancy series that reproduces Fig 2.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;
use std::sync::Mutex;

use crate::backend::Backend;
use crate::fault::FaultPlan;
use crate::ft::Fail;
use crate::linalg::Matrix;
use crate::metrics::Report;
use crate::sim::{CostModel, MsgData, Tag, TagKind, World};

use super::tree::{self, Role};

/// Which reduction to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TsqrMode {
    /// Binary-tree reduction: one holder of the final R (the root).
    Plain,
    /// All-exchange (hypercube): every rank finishes with the final R;
    /// redundancy doubles per step (paper Fig 2).
    FaultTolerant,
}

/// Result of a standalone TSQR run.
#[derive(Debug)]
pub struct TsqrOutcome {
    /// Final R factor (root's copy).
    pub r: Matrix,
    /// `redundancy[s]` = number of ranks holding the root-path merged R
    /// after step `s`.
    pub redundancy: Vec<usize>,
    /// Number of ranks whose final R equals the root's (1 for plain,
    /// P for FT with P a power of two).
    pub final_holders: usize,
    pub report: Report,
    pub elapsed: std::time::Duration,
}

/// Run TSQR over `procs` ranks, each holding an `(m_local, b)` block of
/// the stacked matrix `a` (`rows = procs * m_local`).
pub fn run_tsqr(
    a: &Matrix,
    procs: usize,
    mode: TsqrMode,
    backend: Arc<Backend>,
    cost: CostModel,
) -> Result<TsqrOutcome> {
    let (rows, b) = a.shape();
    anyhow::ensure!(rows % procs == 0, "rows must divide procs");
    let m_local = rows / procs;
    anyhow::ensure!(m_local >= b, "blocks must be tall (m_local >= b)");

    let t0 = std::time::Instant::now();
    let world = World::new(procs, cost, FaultPlan::none());
    let nsteps = tree::steps(procs);
    // rs_by_step[s][rank] = rank's intermediate R after step s.
    let rs_by_step: Arc<Mutex<Vec<HashMap<usize, Matrix>>>> =
        Arc::new(Mutex::new(vec![HashMap::new(); nsteps + 1]));

    let blocks: Vec<Matrix> =
        (0..procs).map(|r| a.block(r * m_local, 0, m_local, b)).collect();

    let backend2 = backend.clone();
    let rs2 = rs_by_step.clone();
    let results = world
        .run_all(move |mut ctx| {
            let backend = backend2.clone();
            let rs_by_step = rs2.clone();
            let block = blocks[ctx.rank].clone();
            {
                let q = ctx.router().alive_count();
                let idx = ctx.rank;
                let f = backend
                    .panel_qr(&block)
                    
                    .map_err(|_| Fail::WorldGone)?;
                ctx.compute(crate::backend::flops::panel_qr(m_local, b));
                let mut r = f.r;
                rs_by_step.lock().unwrap()[0].insert(idx, r.clone());

                for s in 0..tree::steps(q) {
                    let tag = Tag::new(TagKind::TsqrR, 0, s);
                    match mode {
                        TsqrMode::FaultTolerant => {
                            if let Some(bidx) = tree::exchange_pair(idx, s, q) {
                                let peer = ctx
                                    .sendrecv(bidx, tag, MsgData::Mat(r.clone()))
                                    ?
                                    .into_mat();
                                let (rt, rb) = if tree::is_top(idx, bidx) {
                                    (&r, &peer)
                                } else {
                                    (&peer, &r)
                                };
                                let mf = backend
                                    .tsqr_merge(rt, rb)
                                    
                                    .map_err(|_| Fail::WorldGone)?;
                                ctx.compute(crate::backend::flops::tsqr_merge(b));
                                r = mf.r;
                            }
                        }
                        TsqrMode::Plain => {
                            if tree::reduce_active(idx, s) {
                                let (role, bidx) = tree::reduce_pair(idx, s, q);
                                match role {
                                    Role::Idle => {}
                                    Role::Upper => {
                                        let peer =
                                            ctx.recv(bidx, tag)?.into_mat();
                                        let mf = backend
                                            .tsqr_merge(&r, &peer)
                                            
                                            .map_err(|_| Fail::WorldGone)?;
                                        ctx.compute(crate::backend::flops::tsqr_merge(b));
                                        r = mf.r;
                                    }
                                    Role::Lower => {
                                        ctx.send(bidx, tag, MsgData::Mat(r.clone()))?;
                                    }
                                }
                            }
                        }
                    }
                    rs_by_step.lock().unwrap()[s + 1].insert(idx, r.clone());
                }
                Ok(r)
            }
        })
        ;

    let finals: Vec<Matrix> = results
        .into_iter()
        .map(|res| res.expect("tsqr rank failed"))
        .collect();
    let root_r = finals[0].clone();

    // Redundancy series: after step s, how many ranks hold the value the
    // ROOT holds at that step (the root-path merge)?
    let rs = rs_by_step.lock().unwrap();
    let mut redundancy = Vec::with_capacity(nsteps);
    for s in 1..=nsteps {
        let root_val = &rs[s][&0];
        let holders = rs[s].values().filter(|m| *m == root_val).count();
        redundancy.push(holders);
    }
    let final_holders = finals.iter().filter(|m| **m == root_r).count();

    Ok(TsqrOutcome {
        r: root_r,
        redundancy,
        final_holders,
        report: world.metrics.snapshot(),
        elapsed: t0.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gram_residual;

    #[test]
    fn plain_and_ft_agree_and_are_correct() {
        let a = Matrix::randn(128, 8, 3);
        let be = Backend::native();
        let plain = run_tsqr(&a, 4, TsqrMode::Plain, be.clone(), CostModel::default())
            
            .unwrap();
        let ft = run_tsqr(&a, 4, TsqrMode::FaultTolerant, be, CostModel::default())
            
            .unwrap();
        assert!(gram_residual(&a, &plain.r) < 1e-4);
        assert!(gram_residual(&a, &ft.r) < 1e-4);
        // Same tree, same merges: identical R.
        assert_eq!(plain.r, ft.r);
    }

    #[test]
    fn ft_redundancy_doubles_fig2() {
        let a = Matrix::randn(256, 8, 5);
        let be = Backend::native();
        let ft = run_tsqr(&a, 8, TsqrMode::FaultTolerant, be, CostModel::default())
            
            .unwrap();
        // Paper Fig 2: redundancy 2, 4, 8 after steps 0, 1, 2.
        assert_eq!(ft.redundancy, vec![2, 4, 8]);
        assert_eq!(ft.final_holders, 8);
    }

    #[test]
    fn plain_redundancy_stays_one() {
        let a = Matrix::randn(256, 8, 5);
        let be = Backend::native();
        let p = run_tsqr(&a, 8, TsqrMode::Plain, be, CostModel::default())
            
            .unwrap();
        // Only the root-path holder has the merged value at each step.
        assert!(p.redundancy.iter().all(|&h| h == 1), "{:?}", p.redundancy);
        assert_eq!(p.final_holders, 1);
    }

    #[test]
    fn non_power_of_two_root_correct() {
        let a = Matrix::randn(96, 4, 7);
        let be = Backend::native();
        for mode in [TsqrMode::Plain, TsqrMode::FaultTolerant] {
            let out = run_tsqr(&a, 6, mode, be.clone(), CostModel::default())
                
                .unwrap();
            assert!(gram_residual(&a, &out.r) < 1e-4, "mode {mode:?}");
        }
    }

    #[test]
    fn ft_critical_path_close_to_plain() {
        // Paper §III-B: the exchange-based tree adds no significant
        // critical-path cost on dual-channel links.
        let a = Matrix::randn(512, 16, 9);
        let be = Backend::native();
        let plain = run_tsqr(&a, 8, TsqrMode::Plain, be.clone(), CostModel::default())
            
            .unwrap();
        let ft = run_tsqr(&a, 8, TsqrMode::FaultTolerant, be, CostModel::default())
            
            .unwrap();
        let cp_plain = plain.report.critical_path;
        let cp_ft = ft.report.critical_path;
        // FT pays extra *compute* on non-root paths but the exchanges
        // overlap; allow a modest bound.
        assert!(
            cp_ft <= cp_plain * 1.5 + 1e-6,
            "cp_ft={cp_ft} cp_plain={cp_plain}"
        );
    }
}
