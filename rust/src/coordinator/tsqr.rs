//! Standalone TSQR driver (paper §III-B, Fig 2): factorize one tall-skinny
//! panel across P simulated ranks, either with the plain binary-tree
//! reduction or the fault-tolerant all-exchange tree, and measure the
//! redundancy of each intermediate R along the way.
//!
//! This is experiment E1's engine; the full CAQR driver embeds the same
//! logic per panel, but the standalone version exposes the per-step
//! redundancy series that reproduces Fig 2.
//!
//! Rank bodies are resumable [`RankTask`]s on the bounded worker pool
//! ([`crate::sim::sched`]), so sweeps run at P = 512 and beyond on a
//! laptop core count — see `benches/scale.rs`.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;
use std::sync::Mutex;

use crate::backend::Backend;
use crate::fault::FaultPlan;
use crate::ft::Fail;
use crate::linalg::Matrix;
use crate::metrics::Report;
use crate::sim::{
    CostModel, ExchangeOp, MsgData, RankCtx, RankTask, Spawner, Tag, TagKind, TaskPoll, World,
};

use super::tree::{self, Role};

/// Which reduction to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TsqrMode {
    /// Binary-tree reduction: one holder of the final R (the root).
    Plain,
    /// All-exchange (hypercube): every rank finishes with the final R;
    /// redundancy doubles per step (paper Fig 2).
    FaultTolerant,
}

/// Result of a standalone TSQR run.
#[derive(Debug)]
pub struct TsqrOutcome {
    /// Final R factor (root's copy).
    pub r: Matrix,
    /// `redundancy[s]` = number of ranks holding the root-path merged R
    /// after step `s`.
    pub redundancy: Vec<usize>,
    /// Number of ranks whose final R equals the root's (1 for plain,
    /// P for FT with P a power of two).
    pub final_holders: usize,
    /// Metrics snapshot of the simulated run.
    pub report: Report,
    /// Wallclock of the simulated run.
    pub elapsed: std::time::Duration,
}

/// Where one TSQR task is parked (or about to run next).
enum TsqrWait {
    /// Local leaf factorization not done yet.
    Leaf,
    /// Ready to enter tree step `s`.
    Enter,
    /// FT exchange in flight.
    Exch(ExchangeOp),
    /// Plain upper member waiting for the lower member's R.
    Recv { buddy: usize, tag: Tag },
}

/// One rank's resumable TSQR body. The intermediate `R` is `Arc`-shared:
/// the redundancy bookkeeping (every rank's R per step) and the exchange
/// payloads all point at one buffer per merge instead of deep-copying it
/// at each recording/sending site.
struct TsqrTask {
    mode: TsqrMode,
    backend: Arc<Backend>,
    q: usize,
    b: usize,
    m_local: usize,
    block: Matrix,
    /// `rs_by_step[s][rank]` = rank's intermediate R after step s.
    rs_by_step: Arc<Mutex<Vec<HashMap<usize, Arc<Matrix>>>>>,
    finals: Arc<Mutex<HashMap<usize, Arc<Matrix>>>>,
    r: Option<Arc<Matrix>>,
    s: usize,
    wait: TsqrWait,
}

impl TsqrTask {
    fn record_step(&self, idx: usize) {
        self.rs_by_step.lock().unwrap()[self.s + 1]
            .insert(idx, self.r.clone().expect("r set after leaf"));
    }

    fn drive(&mut self, ctx: &mut RankCtx) -> Result<bool, Fail> {
        loop {
            match std::mem::replace(&mut self.wait, TsqrWait::Enter) {
                TsqrWait::Leaf => {
                    let f = self.backend.panel_qr(&self.block).map_err(|_| Fail::WorldGone)?;
                    ctx.compute(crate::backend::flops::panel_qr(self.m_local, self.b));
                    let r = Arc::new(f.r);
                    self.rs_by_step.lock().unwrap()[0].insert(ctx.rank, r.clone());
                    self.r = Some(r);
                    self.s = 0;
                }
                TsqrWait::Enter => {
                    if self.s == tree::steps(self.q) {
                        self.finals
                            .lock()
                            .unwrap()
                            .insert(ctx.rank, self.r.clone().expect("final r"));
                        return Ok(true);
                    }
                    let s = self.s;
                    let idx = ctx.rank;
                    let tag = Tag::new(TagKind::TsqrR, 0, s);
                    match self.mode {
                        TsqrMode::FaultTolerant => {
                            if let Some(bidx) = tree::exchange_pair(idx, s, self.q) {
                                let mine = self.r.clone().expect("r set");
                                let op = ctx.begin_exchange(bidx, tag, MsgData::Mat(mine))?;
                                self.wait = TsqrWait::Exch(op);
                            } else {
                                self.record_step(idx);
                                self.s += 1;
                            }
                        }
                        TsqrMode::Plain => {
                            if tree::reduce_active(idx, s) {
                                let (role, bidx) = tree::reduce_pair(idx, s, self.q);
                                match role {
                                    Role::Idle => {
                                        self.record_step(idx);
                                        self.s += 1;
                                    }
                                    Role::Upper => {
                                        self.wait = TsqrWait::Recv { buddy: bidx, tag };
                                    }
                                    Role::Lower => {
                                        let mine = self.r.clone().expect("r set");
                                        ctx.send(bidx, tag, MsgData::Mat(mine))?;
                                        self.record_step(idx);
                                        self.s += 1;
                                    }
                                }
                            } else {
                                self.record_step(idx);
                                self.s += 1;
                            }
                        }
                    }
                }
                TsqrWait::Exch(mut op) => match ctx.poll_exchange(&mut op)? {
                    None => {
                        self.wait = TsqrWait::Exch(op);
                        return Ok(false);
                    }
                    Some(d) => {
                        let peer_r = d.into_mat();
                        let idx = ctx.rank;
                        let bidx = op.peer();
                        let mf = {
                            let r = self.r.as_ref().expect("r set");
                            let (rt, rb) = if tree::is_top(idx, bidx) {
                                (r.as_ref(), peer_r.as_ref())
                            } else {
                                (peer_r.as_ref(), r.as_ref())
                            };
                            self.backend.tsqr_merge(rt, rb).map_err(|_| Fail::WorldGone)?
                        };
                        ctx.compute(crate::backend::flops::tsqr_merge(self.b));
                        self.r = Some(Arc::new(mf.r));
                        self.record_step(idx);
                        self.s += 1;
                    }
                },
                TsqrWait::Recv { buddy, tag } => match ctx.try_recv(buddy, tag)? {
                    None => {
                        self.wait = TsqrWait::Recv { buddy, tag };
                        return Ok(false);
                    }
                    Some(d) => {
                        let peer = d.into_mat();
                        let mf = {
                            let r = self.r.as_ref().expect("r set");
                            self.backend
                                .tsqr_merge(r.as_ref(), peer.as_ref())
                                .map_err(|_| Fail::WorldGone)?
                        };
                        ctx.compute(crate::backend::flops::tsqr_merge(self.b));
                        self.r = Some(Arc::new(mf.r));
                        self.record_step(ctx.rank);
                        self.s += 1;
                    }
                },
            }
        }
    }
}

impl RankTask for TsqrTask {
    fn poll(&mut self, ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
        match self.drive(ctx) {
            Ok(true) => TaskPoll::Ready(Ok(())),
            Ok(false) => TaskPoll::Pending,
            Err(e) => TaskPoll::Ready(Err(e)),
        }
    }
}

/// Shape invariants shared by every standalone-TSQR entry point: the
/// synchronous drivers here, the service's `JobSpec` validation, and
/// the batched lane (`service::batch`) all call this one function so
/// the checks — and their wording — cannot drift.
pub(crate) fn validate_shape(rows: usize, block: usize, procs: usize) -> Result<()> {
    anyhow::ensure!(procs >= 1, "need at least one process");
    anyhow::ensure!(block >= 1, "block must be >= 1");
    anyhow::ensure!(
        rows % procs == 0,
        "procs ({procs}) must divide rows ({rows}) evenly"
    );
    anyhow::ensure!(
        rows / procs >= block,
        "blocks must be tall (rows/procs >= block, got {}/{procs} < {block})",
        rows
    );
    Ok(())
}

/// A fully-prepared standalone TSQR run: world + rank tasks + the shared
/// result cells. Both synchronous entry points (`run_tsqr`,
/// `run_tsqr_pooled`) drive this one object. NOTE: the service's batched
/// lane (`service::batch::BatchTsqrTask`) is a *separate* tree walk that
/// carries a bundle of R's per message — any change to the merge order
/// or stacking convention here must be mirrored there, or batched
/// results stop being bitwise-identical to solo runs (pinned by
/// `tests/service.rs` and the batch module's own tests).
pub(crate) struct TsqrJob {
    pub(crate) world: Arc<World>,
    pub(crate) tasks: Vec<(usize, Box<dyn RankTask>)>,
    rs_by_step: Arc<Mutex<Vec<HashMap<usize, Arc<Matrix>>>>>,
    finals: Arc<Mutex<HashMap<usize, Arc<Matrix>>>>,
    nsteps: usize,
    t0: std::time::Instant,
}

impl TsqrJob {
    /// Distribute `a` into per-rank blocks and build the rank tasks.
    pub(crate) fn prepare(
        a: &Matrix,
        procs: usize,
        mode: TsqrMode,
        backend: Arc<Backend>,
        cost: CostModel,
    ) -> Result<Self> {
        let (rows, b) = a.shape();
        validate_shape(rows, b, procs)?;
        let m_local = rows / procs;

        let t0 = std::time::Instant::now();
        let world = World::new(procs, cost, FaultPlan::none());
        let nsteps = tree::steps(procs);
        let rs_by_step: Arc<Mutex<Vec<HashMap<usize, Arc<Matrix>>>>> =
            Arc::new(Mutex::new(vec![HashMap::new(); nsteps + 1]));
        let finals: Arc<Mutex<HashMap<usize, Arc<Matrix>>>> =
            Arc::new(Mutex::new(HashMap::new()));

        let tasks: Vec<(usize, Box<dyn RankTask>)> = (0..procs)
            .map(|r| {
                let task = TsqrTask {
                    mode,
                    backend: backend.clone(),
                    q: procs,
                    b,
                    m_local,
                    block: a.block(r * m_local, 0, m_local, b),
                    rs_by_step: rs_by_step.clone(),
                    finals: finals.clone(),
                    r: None,
                    s: 0,
                    wait: TsqrWait::Leaf,
                };
                (r, Box::new(task) as Box<dyn RankTask>)
            })
            .collect();
        Ok(Self { world, tasks, rs_by_step, finals, nsteps, t0 })
    }

    /// Assemble the outcome (root R, redundancy series, metrics) from the
    /// per-rank results. `tasks` must have been drained and driven to
    /// completion by a pool before this is called.
    pub(crate) fn finalize(
        world: &Arc<World>,
        rs_by_step: &Arc<Mutex<Vec<HashMap<usize, Arc<Matrix>>>>>,
        finals: &Arc<Mutex<HashMap<usize, Arc<Matrix>>>>,
        nsteps: usize,
        t0: std::time::Instant,
        results: Vec<(usize, Result<(), Fail>)>,
    ) -> Result<TsqrOutcome> {
        for (rank, res) in results {
            res.map_err(|e| anyhow::anyhow!("tsqr rank {rank} failed: {e}"))?;
        }

        let finals = finals.lock().unwrap();
        let root_r = finals[&0].clone();

        // Redundancy series: after step s, how many ranks hold the value
        // the ROOT holds at that step (the root-path merge)? Compared by
        // value — Arc sharing is an optimization, not the identity
        // criterion.
        let rs = rs_by_step.lock().unwrap();
        let mut redundancy = Vec::with_capacity(nsteps);
        for s in 1..=nsteps {
            let root_val = &rs[s][&0];
            let holders = rs[s].values().filter(|m| *m == root_val).count();
            redundancy.push(holders);
        }
        let final_holders =
            finals.values().filter(|m| m.as_ref() == root_r.as_ref()).count();

        Ok(TsqrOutcome {
            r: root_r.as_ref().clone(),
            redundancy,
            final_holders,
            report: world.metrics.snapshot(),
            elapsed: t0.elapsed(),
        })
    }

}

/// Run TSQR over `procs` ranks, each holding an `(m_local, b)` block of
/// the stacked matrix `a` (`rows = procs * m_local`), with an
/// automatically sized worker pool. Thin wrapper over the pooled path —
/// the single driver body lives in [`TsqrJob`].
pub fn run_tsqr(
    a: &Matrix,
    procs: usize,
    mode: TsqrMode,
    backend: Arc<Backend>,
    cost: CostModel,
) -> Result<TsqrOutcome> {
    run_tsqr_pooled(a, procs, mode, backend, cost, crate::sim::default_workers(procs))
}

/// [`run_tsqr`] with an explicit worker-pool width — the scale sweeps
/// pin this to the core count to show P = 512 ranks on a fixed pool.
pub fn run_tsqr_pooled(
    a: &Matrix,
    procs: usize,
    mode: TsqrMode,
    backend: Arc<Backend>,
    cost: CostModel,
    workers: usize,
) -> Result<TsqrOutcome> {
    let TsqrJob { world, tasks, rs_by_step, finals, nsteps, t0 } =
        TsqrJob::prepare(a, procs, mode, backend, cost)?;
    let results = world.run_tasks(workers, tasks);
    TsqrJob::finalize(&world, &rs_by_step, &finals, nsteps, t0, results)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gram_residual;

    #[test]
    fn plain_and_ft_agree_and_are_correct() {
        let a = Matrix::randn(128, 8, 3);
        let be = Backend::native();
        let plain = run_tsqr(&a, 4, TsqrMode::Plain, be.clone(), CostModel::default())
            .unwrap();
        let ft = run_tsqr(&a, 4, TsqrMode::FaultTolerant, be, CostModel::default())
            .unwrap();
        assert!(gram_residual(&a, &plain.r) < 1e-4);
        assert!(gram_residual(&a, &ft.r) < 1e-4);
        // Same tree, same merges: identical R.
        assert_eq!(plain.r, ft.r);
    }

    #[test]
    fn ft_redundancy_doubles_fig2() {
        let a = Matrix::randn(256, 8, 5);
        let be = Backend::native();
        let ft = run_tsqr(&a, 8, TsqrMode::FaultTolerant, be, CostModel::default())
            .unwrap();
        // Paper Fig 2: redundancy 2, 4, 8 after steps 0, 1, 2.
        assert_eq!(ft.redundancy, vec![2, 4, 8]);
        assert_eq!(ft.final_holders, 8);
    }

    #[test]
    fn plain_redundancy_stays_one() {
        let a = Matrix::randn(256, 8, 5);
        let be = Backend::native();
        let p = run_tsqr(&a, 8, TsqrMode::Plain, be, CostModel::default())
            .unwrap();
        // Only the root-path holder has the merged value at each step.
        assert!(p.redundancy.iter().all(|&h| h == 1), "{:?}", p.redundancy);
        assert_eq!(p.final_holders, 1);
    }

    #[test]
    fn non_power_of_two_root_correct() {
        let a = Matrix::randn(96, 4, 7);
        let be = Backend::native();
        for mode in [TsqrMode::Plain, TsqrMode::FaultTolerant] {
            let out = run_tsqr(&a, 6, mode, be.clone(), CostModel::default())
                .unwrap();
            assert!(gram_residual(&a, &out.r) < 1e-4, "mode {mode:?}");
        }
    }

    #[test]
    fn ft_critical_path_close_to_plain() {
        // Paper §III-B: the exchange-based tree adds no significant
        // critical-path cost on dual-channel links.
        let a = Matrix::randn(512, 16, 9);
        let be = Backend::native();
        let plain = run_tsqr(&a, 8, TsqrMode::Plain, be.clone(), CostModel::default())
            .unwrap();
        let ft = run_tsqr(&a, 8, TsqrMode::FaultTolerant, be, CostModel::default())
            .unwrap();
        let cp_plain = plain.report.critical_path;
        let cp_ft = ft.report.critical_path;
        // FT pays extra *compute* on non-root paths but the exchanges
        // overlap; allow a modest bound.
        assert!(
            cp_ft <= cp_plain * 1.5 + 1e-6,
            "cp_ft={cp_ft} cp_plain={cp_plain}"
        );
    }

    #[test]
    fn large_p_on_fixed_pool() {
        // The tentpole check in miniature: P = 256 simulated ranks on a
        // 4-thread pool (the full P = 512 sweep lives in benches/scale.rs).
        let procs = 256;
        let b = 4;
        let a = Matrix::randn(procs * b, b, 11);
        let out = run_tsqr_pooled(
            &a,
            procs,
            TsqrMode::FaultTolerant,
            Backend::native(),
            CostModel::default(),
            4,
        )
        .unwrap();
        assert!(gram_residual(&a, &out.r) < 1e-3);
        assert_eq!(out.final_holders, procs);
        assert_eq!(out.redundancy, vec![2, 4, 8, 16, 32, 64, 128, 256]);
    }
}
