//! Collective schedules for the row broadcast of WY panel factors.
//!
//! After a panel column finishes its TSQR, each of its grid rows must
//! move the row's factor bundle `{leaf Y, leaf T, (Y₁, T) per merge
//! step}` to every other grid column that still owns trailing columns.
//! The historical schedule was *flat*: the root sends (or, in FT mode,
//! publishes once and every receiver pulls) `Pc - 1` full copies, so the
//! root's NIC serializes `O(Pc)` bundle transmissions and the critical
//! path grows like `Pc·(α + Bβ)` — erasing the latency savings CAQR's
//! communication-avoiding analysis (Demmel/Grigori/Hoemmen/Langou)
//! counts on. A [`BcastSched`] plans the alternative shapes:
//!
//! * **Flat** — root to every peer directly (the historical schedule).
//! * **Binomial** — relays forward: virtual member `v` (root = 0)
//!   receives from `v` with its highest set bit cleared and forwards to
//!   `v + 2^j` for every `2^j` above its own highest bit. Depth
//!   `⌈log₂ n⌉`, so the root serializes only `⌈log₂ n⌉` sends.
//! * **Segmented** — the binomial tree with the bundle split into
//!   `seg_bytes`-sized segments, so a relay forwards segment `s` while
//!   segment `s + 1` is still arriving (pipelined on the logical
//!   clock).
//!
//! The schedule is a **pure function** of `(grid, root, panel,
//! per-matrix sizes, config)` — deterministic and replayable. Both the
//! sender and every receiver plan independently and must agree, which
//! works because the bundle's matrix sizes are themselves pure geometry
//! (see `caqr::bundle_sizes`). The schedule moves bytes, never operand
//! values: factors are bitwise-identical across all kinds.
//!
//! Virtual numbering rotates with the root so the relay pattern shifts
//! as panels cycle over grid columns: member `v` is the grid column at
//! rotated distance `v` from the root, restricted to columns that still
//! own trailing blocks at this panel.

use crate::config::{BcastKind, RunConfig};

use super::grid::Grid;

/// One grid row's broadcast schedule for one panel (all grid rows share
/// it: members are grid *columns*, and every row runs the same shape).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BcastSched {
    /// Resolved schedule kind (never [`BcastKind::Auto`]).
    kind: BcastKind,
    /// Member grid columns in virtual order; `members[0]` is the root
    /// (the panel's grid column), the rest ascend by rotated distance.
    members: Vec<usize>,
    /// Matrices per segment, in bundle order (`len()` = segment count;
    /// flat/binomial schedules always use one segment).
    seg_counts: Vec<usize>,
}

/// Greedy bundle split: walk the matrices in order, starting a new
/// segment whenever adding the next matrix would push a non-empty
/// segment past `seg_bytes`. Matrices are never split, so a single
/// oversized matrix becomes its own segment. Returns per-segment matrix
/// counts (at least one segment, even for an empty bundle).
pub fn plan_segments(sizes: &[usize], seg_bytes: usize) -> Vec<usize> {
    let mut counts = Vec::new();
    let (mut cur, mut cur_bytes) = (0usize, 0usize);
    for &sz in sizes {
        if cur > 0 && cur_bytes + sz > seg_bytes {
            counts.push(cur);
            (cur, cur_bytes) = (0, 0);
        }
        cur += 1;
        cur_bytes += sz;
    }
    if cur > 0 || counts.is_empty() {
        counts.push(cur);
    }
    counts
}

/// Highest set bit of `v` (`v > 0`).
fn highest_bit(v: usize) -> usize {
    1usize << (usize::BITS - 1 - v.leading_zeros())
}

impl BcastSched {
    /// Plan panel `k`'s row-broadcast schedule. `sizes` are the bundle's
    /// per-matrix byte sizes in send order — pure geometry, so senders
    /// and receivers plan identically without exchanging metadata.
    pub fn plan(cfg: &RunConfig, grid: &Grid, k: usize, sizes: &[usize]) -> Self {
        let pc = grid.cols();
        let root = grid.col_owner(k);
        let nblocks = cfg.panels();
        // Members: the root plus every other grid column that still owns
        // trailing blocks at panel k (matching the receivers' own
        // `n_trail > 0` admission gate).
        let mut rest: Vec<usize> = (0..pc)
            .filter(|&gc| {
                gc != root && grid.local_blocks(gc, nblocks) > grid.blocks_before(gc, k + 1)
            })
            .collect();
        rest.sort_by_key(|&gc| (gc + pc - root) % pc);
        let mut members = Vec::with_capacity(rest.len() + 1);
        members.push(root);
        members.extend(rest);

        let bytes: usize = sizes.iter().sum();
        let kind = match cfg.bcast {
            BcastKind::Auto => {
                if members.len() <= 2 {
                    // One receiver (or none): every shape is one hop.
                    BcastKind::Flat
                } else if bytes > cfg.seg_bytes {
                    BcastKind::Segmented
                } else {
                    BcastKind::Binomial
                }
            }
            k => k,
        };
        let seg_counts = if kind == BcastKind::Segmented {
            plan_segments(sizes, cfg.seg_bytes)
        } else {
            vec![sizes.len()]
        };
        Self { kind, members, seg_counts }
    }

    /// The resolved schedule kind (never `Auto`).
    pub fn kind(&self) -> BcastKind {
        self.kind
    }

    /// Member count (root included).
    pub fn len(&self) -> usize {
        self.members.len()
    }

    /// True when the schedule has no receivers.
    pub fn is_empty(&self) -> bool {
        self.members.len() <= 1
    }

    /// Segment count (1 for flat/binomial).
    pub fn nseg(&self) -> usize {
        self.seg_counts.len()
    }

    /// Matrices in segment `s` of the bundle.
    pub fn seg_count(&self, s: usize) -> usize {
        self.seg_counts[s]
    }

    /// The root's grid column.
    pub fn root_gcol(&self) -> usize {
        self.members[0]
    }

    /// Grid column of virtual member `v`.
    pub fn gcol(&self, v: usize) -> usize {
        self.members[v]
    }

    /// Virtual index of grid column `gcol`, when it is a member.
    pub fn vindex(&self, gcol: usize) -> Option<usize> {
        self.members.iter().position(|&g| g == gcol)
    }

    /// Virtual parent of member `v > 0`.
    pub fn parent(&self, v: usize) -> usize {
        debug_assert!(v > 0 && v < self.members.len());
        match self.kind {
            BcastKind::Flat => 0,
            _ => v - highest_bit(v),
        }
    }

    /// Virtual children of member `v`, in forwarding (ordinal) order.
    pub fn children(&self, v: usize) -> Vec<usize> {
        let n = self.members.len();
        match self.kind {
            BcastKind::Flat => {
                if v == 0 {
                    (1..n).collect()
                } else {
                    Vec::new()
                }
            }
            _ => {
                let mut out = Vec::new();
                let mut j = if v == 0 { 1 } else { highest_bit(v) << 1 };
                while v + j < n {
                    out.push(v + j);
                    j <<= 1;
                }
                out
            }
        }
    }

    /// `v`'s ordinal among its parent's children — the serialization
    /// position its pull (or its parent's forward) waits behind.
    pub fn pull_ord(&self, v: usize) -> usize {
        self.children(self.parent(v))
            .iter()
            .position(|&c| c == v)
            .expect("v is one of its parent's children")
    }

    /// Serialization ordinal when member `v` falls back to pulling the
    /// *root's* published copy (its relay died): behind every earlier
    /// virtual member in the worst case.
    pub fn fallback_ord(&self, v: usize) -> usize {
        debug_assert!(v > 0);
        v - 1
    }

    /// Tree depth in hops (flat: 1; binomial: `max popcount` over the
    /// member range = `⌈log₂ n⌉`).
    pub fn depth(&self) -> usize {
        let n = self.members.len();
        match self.kind {
            BcastKind::Flat => usize::from(n > 1),
            _ => (0..n).map(|v| v.count_ones() as usize).max().unwrap_or(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(pc: usize, bcast: BcastKind) -> RunConfig {
        RunConfig {
            rows: 256,
            cols: 16 * pc * 2, // 2 panels per grid column
            block: 16,
            procs: 2 * pc,
            grid_rows: 2,
            grid_cols: pc,
            bcast,
            ..Default::default()
        }
    }

    fn sched(pc: usize, k: usize, bcast: BcastKind) -> BcastSched {
        let c = cfg(pc, bcast);
        BcastSched::plan(&c, &Grid::from_cfg(&c), k, &[1024, 64])
    }

    #[test]
    fn binomial_topology_eight_members() {
        let s = sched(8, 0, BcastKind::Binomial);
        assert_eq!(s.len(), 8);
        assert_eq!(s.kind(), BcastKind::Binomial);
        assert_eq!(s.children(0), vec![1, 2, 4]);
        assert_eq!(s.children(1), vec![3, 5]);
        assert_eq!(s.children(2), vec![6]);
        assert_eq!(s.children(3), vec![7]);
        assert!(s.children(4).is_empty() && s.children(7).is_empty());
        assert_eq!(s.parent(5), 1);
        assert_eq!(s.parent(6), 2);
        assert_eq!(s.parent(7), 3);
        assert_eq!(s.pull_ord(1), 0);
        assert_eq!(s.pull_ord(2), 1);
        assert_eq!(s.pull_ord(4), 2);
        assert_eq!(s.pull_ord(5), 1);
        assert_eq!(s.depth(), 3);
        assert_eq!(s.nseg(), 1);
    }

    #[test]
    fn flat_topology() {
        let s = sched(8, 0, BcastKind::Flat);
        assert_eq!(s.children(0), (1..8).collect::<Vec<_>>());
        for v in 1..8 {
            assert_eq!(s.parent(v), 0);
            assert_eq!(s.pull_ord(v), v - 1);
            assert!(s.children(v).is_empty());
        }
        assert_eq!(s.depth(), 1);
    }

    #[test]
    fn every_member_is_exactly_one_child() {
        for kind in [BcastKind::Flat, BcastKind::Binomial] {
            for pc in 1..=9 {
                let s = sched(pc, 0, kind);
                let n = s.len();
                let mut seen = vec![0usize; n];
                for v in 0..n {
                    for c in s.children(v) {
                        assert!(c > v, "children come after their relay");
                        seen[c] += 1;
                        assert_eq!(s.parent(c), v);
                    }
                }
                assert_eq!(seen[0], 0, "root has no parent");
                assert!(seen[1..].iter().all(|&c| c == 1), "{kind:?} pc={pc}: {seen:?}");
            }
        }
    }

    #[test]
    fn members_rotate_with_the_root() {
        // Panel 1 on a 4-column grid roots at grid column 1; the rest
        // follow in rotated order.
        let s = sched(4, 1, BcastKind::Binomial);
        assert_eq!(s.root_gcol(), 1);
        assert_eq!(s.gcol(1), 2);
        assert_eq!(s.vindex(3), Some(2));
        assert_eq!(s.vindex(0), Some(3));
        // Plans are pure functions: replanning gives the same schedule.
        assert_eq!(s, sched(4, 1, BcastKind::Binomial));
    }

    #[test]
    fn members_drop_retired_columns() {
        // cols = 2*pc panels; by panel k = nblocks - 1 only the columns
        // owning the last block remain.
        let pc = 4;
        let c = cfg(pc, BcastKind::Binomial);
        let nblocks = c.panels();
        let s = BcastSched::plan(&c, &Grid::from_cfg(&c), nblocks - 1, &[64]);
        assert_eq!(s.len(), 1, "no trailing columns at the last panel");
        assert!(s.is_empty());
        let s = BcastSched::plan(&c, &Grid::from_cfg(&c), nblocks - 2, &[64]);
        assert_eq!(s.len(), 2, "one trailing column at the next-to-last panel");
    }

    #[test]
    fn auto_resolution() {
        // <= 2 members: flat.
        let s = sched(2, 0, BcastKind::Auto);
        assert_eq!(s.kind(), BcastKind::Flat);
        // Small bundle on a wide grid: binomial.
        let s = sched(8, 0, BcastKind::Auto);
        assert_eq!(s.kind(), BcastKind::Binomial);
        // Large bundle: segmented.
        let c = cfg(8, BcastKind::Auto);
        let big = vec![c.seg_bytes / 2 + 1; 4];
        let s = BcastSched::plan(&c, &Grid::from_cfg(&c), 0, &big);
        assert_eq!(s.kind(), BcastKind::Segmented);
        assert_eq!(s.nseg(), 4, "greedy split: one oversized half per segment");
    }

    #[test]
    fn segment_partition_is_greedy_and_total() {
        assert_eq!(plan_segments(&[10, 10, 10], 20), vec![2, 1]);
        assert_eq!(plan_segments(&[30, 10, 10], 20), vec![1, 2]);
        assert_eq!(plan_segments(&[10; 6], 100), vec![6]);
        assert_eq!(plan_segments(&[10; 4], 10), vec![1, 1, 1, 1]);
        assert_eq!(plan_segments(&[], 10), vec![0], "empty bundle still one segment");
        // Counts always sum to the matrix count.
        for seg in [1usize, 7, 64, 1 << 20] {
            let sizes = [100, 3, 700, 64, 64, 9000, 1];
            let counts = plan_segments(&sizes, seg);
            assert_eq!(counts.iter().sum::<usize>(), sizes.len(), "seg_bytes={seg}");
        }
    }

    #[test]
    fn segmented_uses_binomial_topology() {
        let c = cfg(8, BcastKind::Segmented);
        let s = BcastSched::plan(&c, &Grid::from_cfg(&c), 0, &[1024, 64]);
        assert_eq!(s.kind(), BcastKind::Segmented);
        assert_eq!(s.children(0), vec![1, 2, 4]);
        assert_eq!(s.depth(), 3);
        assert_eq!(s.nseg(), 1, "bundle under seg_bytes: a single segment");
        assert_eq!(s.seg_count(0), 2);
    }
}
