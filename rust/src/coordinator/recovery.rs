//! Failure detection, REBUILD and single-buddy state reconstruction
//! (paper §III-C), plus the retention hooks that feed the buddy store.
//!
//! Detection is ULFM-style: a communication touching a dead rank returns
//! [`Fail::RankFailed`]. Under `Semantics::Rebuild`, the first detector
//! wins the `RevivalGate`, drops the dead rank's (lost) retained memory,
//! revives its mailbox, and spawns a replacement *task* through the
//! job-scoped [`Spawner`] — under the multi-tenant service the
//! replacement therefore lands in its own job's task group on the shared
//! pool, never in a neighbor's; the replacement replays from the rank's
//! initial block: local
//! factorizations are recomputed, completed pair steps are reconstructed
//! from the buddy's retained `{W, T, Y₁, R̃}` via `Ĉ' = C' − Y W`, and
//! the interrupted step is simply re-entered live — the detector's
//! exchange stays parked until the replacement arrives.
//!
//! Multi-failure semantics: the store's per-rank *progress frontier*
//! (which steps a rank ever completed, surviving its death) lets a
//! replaying replacement distinguish three miss cases —
//!
//! * the step never completed → re-enter it live;
//! * the buddy is merely behind in wall-clock → park until it retains;
//! * both pair members completed the step and both copies are gone
//!   (correlated buddy-pair kill, or a buddy killed mid-recovery) →
//!   [`Fail::Unrecoverable`]: the paper's single-buddy protocol cannot
//!   reconstruct the state, so the run is poisoned and aborts instead of
//!   hanging or silently recomputing outside the protocol.

use std::sync::Arc;

use crate::config::Algorithm;
use crate::fault::{FailSite, Phase};
use crate::ft::{Fail, Semantics};
use crate::linalg::Matrix;
use crate::sim::{ExchangeOp, MsgData, RankCtx, Spawner, Tag, TagKind};
use crate::trace::SpanKind;

use super::caqr::{Fetch, Ranker};
use super::grid::Grid;
use super::panel::PanelGeom;
use super::store::Retained;
use super::tree::Role;

/// A fault-tolerant pairwise exchange in flight: wraps the sim-level
/// [`ExchangeOp`] with ULFM failure handling (REBUILD arbitration and
/// retry). Created per tree step / checkpoint, polled until it yields
/// the peer's payload.
pub(crate) struct FtOp {
    peer: usize,
    tag: Tag,
    payload: MsgData,
    inner: Option<ExchangeOp>,
}

impl FtOp {
    pub(crate) fn new(peer: usize, tag: Tag, payload: MsgData) -> Self {
        Self { peer, tag, payload, inner: None }
    }

    pub(crate) fn peer(&self) -> usize {
        self.peer
    }

    /// Payload size in bytes (checkpoint byte accounting).
    pub(crate) fn payload_nbytes(&self) -> usize {
        self.payload.nbytes()
    }
}

impl Ranker {
    /// Fault-injection wrapper: when the kill fires, the dead process's
    /// retained memory is lost with it — and with every correlated group
    /// member killed at the same instant (a simulated node crash).
    ///
    /// Ordering matters: the store drops (and the epoch bumps that reject
    /// straggling retains from the dying incarnations) happen BEFORE the
    /// router broadcasts the death, so a detector-spawned replacement can
    /// never read memory that died with the process.
    pub(crate) fn maybe_fail(&self, ctx: &mut RankCtx, site: FailSite) -> Result<(), Fail> {
        let router = ctx.router().clone();
        let inc = router.incarnation(ctx.rank);
        if !ctx.fault.should_fail_inc(ctx.rank, inc, site) {
            return Ok(());
        }
        let collateral = ctx.fault.collateral_of(ctx.rank, site);
        self.shared.store.drop_owner_dead(ctx.rank, inc);
        for &other in &collateral {
            if other != ctx.rank {
                self.shared
                    .store
                    .drop_owner_dead(other, router.incarnation(other));
            }
        }
        // Now make the deaths visible (mirrors `RankCtx::maybe_fail`).
        // The kill clock is recorded so the eventual detector's claim can
        // be turned into a time-to-detect latency.
        ctx.metrics.record_failure_at(ctx.rank, ctx.clock);
        router.kill(ctx.rank);
        for other in collateral {
            if other != ctx.rank && router.is_alive(other) {
                ctx.metrics.record_failure_at(other, ctx.clock);
                router.kill(other);
            }
        }
        Err(Fail::Killed)
    }

    /// Drive an FT exchange with failure handling. `Ok(None)` parks the
    /// task — either on the exchange itself or waiting out a REBUILD
    /// performed by another detector; the next mailbox event re-polls.
    pub(crate) fn poll_ft(
        &self,
        op: &mut FtOp,
        ctx: &mut RankCtx,
        sp: &Spawner,
    ) -> Result<Option<MsgData>, Fail> {
        loop {
            if op.inner.is_none() {
                crate::simlog!("[r{}] exch-> peer={} {:?}", ctx.rank, op.peer, op.tag);
                match ctx.begin_exchange(op.peer, op.tag, op.payload.clone()) {
                    Ok(x) => op.inner = Some(x),
                    Err(Fail::RankFailed { rank }) => {
                        if self.on_peer_failure_at(
                            ctx,
                            sp,
                            rank,
                            op.tag.panel as usize,
                            op.tag.step as usize,
                        )? {
                            continue;
                        }
                        return Ok(None);
                    }
                    Err(e) => return Err(e),
                }
            }
            match ctx.poll_exchange(op.inner.as_mut().expect("inner exchange set")) {
                Ok(Some(d)) => {
                    crate::simlog!("[r{}] exch<- peer={} {:?}", ctx.rank, op.peer, op.tag);
                    op.inner = None;
                    return Ok(Some(d));
                }
                Ok(None) => return Ok(None),
                Err(Fail::RankFailed { rank }) => {
                    crate::simlog!(
                        "[r{}] detected rank {rank} dead at {:?}",
                        ctx.rank,
                        op.tag
                    );
                    op.inner = None;
                    if self.on_peer_failure_at(
                        ctx,
                        sp,
                        rank,
                        op.tag.panel as usize,
                        op.tag.step as usize,
                    )? {
                        continue;
                    }
                    return Ok(None);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Plain-mode receive: no recovery (the baseline has no redundancy);
    /// failures follow the configured semantics (Abort by default).
    pub(crate) fn recv_plain_poll(
        &self,
        ctx: &mut RankCtx,
        src: usize,
        tag: Tag,
    ) -> Result<Option<MsgData>, Fail> {
        debug_assert!(
            self.shared.cfg.algorithm == Algorithm::Plain,
            "recv_plain in FT mode"
        );
        match ctx.try_recv(src, tag) {
            Ok(v) => Ok(v),
            Err(Fail::RankFailed { rank }) => match self.shared.cfg.semantics {
                Semantics::Abort => Err(Fail::Aborted),
                _ => Err(Fail::RankFailed { rank }),
            },
            Err(e) => Err(e),
        }
    }

    /// Plain-mode send, mapped through the configured semantics.
    pub(crate) fn send_plain(
        &self,
        ctx: &mut RankCtx,
        dst: usize,
        tag: Tag,
        data: MsgData,
    ) -> Result<(), Fail> {
        match ctx.send(dst, tag, data) {
            Ok(()) => Ok(()),
            Err(Fail::RankFailed { .. })
                if self.shared.cfg.semantics == Semantics::Abort =>
            {
                Err(Fail::Aborted)
            }
            Err(e) => Err(e),
        }
    }

    /// Plain-mode broadcast-edge send: charges the sender's serialization
    /// (`o + Bβ` per copy, [`crate::sim::CostModel::relay_send_time`]) so
    /// a flat root honestly pays for every copy it fans out, and feeds the
    /// collective counters. One tree edge = one hop.
    pub(crate) fn send_bcast_plain(
        &self,
        ctx: &mut RankCtx,
        dst: usize,
        tag: Tag,
        mats: Vec<Arc<Matrix>>,
    ) -> Result<(), Fail> {
        let data = MsgData::Mats(mats);
        let bytes = data.nbytes();
        match ctx.send_serialized(dst, tag, data) {
            Ok(()) => {
                ctx.metrics.record_bcast(bytes as u64, 1);
                Ok(())
            }
            Err(Fail::RankFailed { .. })
                if self.shared.cfg.semantics == Semantics::Abort =>
            {
                Err(Fail::Aborted)
            }
            Err(e) => Err(e),
        }
    }

    /// Handle a detected peer failure according to the semantics.
    /// `Ok(true)` = the peer is alive again (either already rebuilt or
    /// revived by us) — retry the operation now; `Ok(false)` = another
    /// detector is rebuilding — park until its Revive notice arrives.
    /// `panel`/`step` attribute the operation that tripped the detection
    /// (the exchange tag, or the replay site a fetch was serving).
    pub(crate) fn on_peer_failure_at(
        &self,
        ctx: &mut RankCtx,
        sp: &Spawner,
        dead: usize,
        panel: usize,
        step: usize,
    ) -> Result<bool, Fail> {
        if self.shared.poisoned().is_some() {
            // An unrecoverable failure elsewhere: join the abort cascade
            // instead of spawning further replacements.
            return Err(Fail::Aborted);
        }
        match self.shared.cfg.semantics {
            Semantics::Abort => Err(Fail::Aborted),
            Semantics::Shrink | Semantics::Blank => {
                // The CAQR driver does not renumber mid-factorization;
                // these semantics are exercised at the sim level (see
                // examples/semantics.rs). Surface the failure.
                Err(Fail::RankFailed { rank: dead })
            }
            Semantics::Rebuild => {
                // Snapshot the incarnation we observed as dead BEFORE the
                // liveness re-check: if another detector already rebuilt
                // the rank, we must not claim the next incarnation (that
                // would spawn a second replacement and orphan the first).
                let inc_dead = self.shared.world.router().incarnation(dead);
                if self.shared.world.router().is_alive(dead) {
                    // Already rebuilt — just retry the operation.
                    return Ok(true);
                }
                if self.shared.gate.claim(dead, inc_dead + 1) {
                    crate::simlog!(
                        "[r{}] REBUILD rank {dead} (inc {})",
                        ctx.rank,
                        inc_dead + 1
                    );
                    // Detection latency: detector's claim clock minus the
                    // recorded kill clock for `dead`.
                    ctx.metrics.record_detect(dead, ctx.clock);
                    self.shared.trace.emit(
                        ctx.clock,
                        ctx.rank,
                        panel,
                        step,
                        "recovery_start",
                        dead as f64,
                    );
                    // Point span: detection has no duration on the
                    // logical clock, but it anchors the recovery track.
                    self.emit_span(
                        ctx,
                        SpanKind::RecoveryDetect,
                        ctx.clock,
                        panel,
                        0,
                        dead as f64,
                    );
                    // The dead process's memory is gone (and stays gone:
                    // the epoch bump rejects straggling retains from the
                    // dead incarnation's still-unwinding task).
                    self.shared.store.drop_owner_dead(dead, inc_dead);
                    // REBUILD: fresh mailbox; the replacement's clock
                    // starts at the detector's (failure-detection time).
                    let new_ctx = self.shared.world.revive(dead, ctx.clock);
                    let sh = self.shared.clone();
                    let local = sh.initial[dead].clone();
                    sp.spawn(new_ctx, Box::new(Ranker::new(sh, true, local)));
                    Ok(true)
                } else {
                    // Someone else is rebuilding; its Revive notice will
                    // land in our mailbox and wake us to retry.
                    Ok(false)
                }
            }
        }
    }

    /// The fully-attributed [`Fail::Unrecoverable`] for a lost-redundancy
    /// site on this rank: grid coordinates plus the panel/step/lane of
    /// the site whose retained copies are gone.
    pub(crate) fn unrecoverable(
        &self,
        rank: usize,
        panel: usize,
        step: usize,
        lane: u32,
    ) -> Fail {
        Fail::Unrecoverable {
            rank,
            grid: Grid::from_cfg(&self.shared.cfg).coords(rank),
            panel,
            step,
            lane,
        }
    }

    /// Read a buddy's retained step data during replay, charging the
    /// simulated transfer (one message from one process — paper III-C).
    /// See the module docs for the three miss cases. `lane` is the
    /// update-segment lane of the lookahead pipeline (0 for TSQR steps
    /// and the lockstep whole-width update); `gcol` is the grid column
    /// whose reduction tree the step belongs to (the live-exchange tags
    /// are routed on it).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn fetch_retained(
        &self,
        ctx: &mut RankCtx,
        sp: &Spawner,
        buddy: usize,
        panel: usize,
        phase: Phase,
        step: usize,
        lane: u32,
        gcol: u32,
    ) -> Result<Fetch, Fail> {
        if let Some(ret) = self.shared.store.get(buddy, panel, phase, step, lane) {
            self.charge_fetch(ctx, buddy, panel, phase, step, lane, &ret);
            return Ok(Fetch::Hit(ret));
        }
        if self.shared.store.has_completed(ctx.rank, panel, phase, step, lane) {
            if self.shared.store.has_completed(buddy, panel, phase, step, lane) {
                // The buddy completed this step too, yet its entry is
                // missing — only a death removes entries, so BOTH copies
                // of the redundancy are gone. Unrecoverable (paper III-C
                // reconstructs from exactly one surviving pair member).
                crate::simlog!(
                    "[r{}] replay LOST ({buddy},{panel},{phase:?},{step}) -> unrecoverable",
                    ctx.rank
                );
                return Err(self.unrecoverable(ctx.rank, panel, step, lane));
            }
            // The buddy never completed the step. If its (rebuilt) task
            // has already pushed us a live half for this step, join the
            // live exchange; otherwise wait for the buddy to either
            // retain the step or die trying.
            let live_tag = Tag::grid(
                match phase {
                    Phase::Tsqr => TagKind::TsqrR,
                    Phase::Update => TagKind::UpdateC,
                    Phase::Bcast => unreachable!("bcast bundles are store-only"),
                },
                panel,
                step,
                lane,
                gcol,
            );
            if ctx.has_pending(buddy, live_tag) {
                crate::simlog!(
                    "[r{}] replay JOIN-LIVE ({buddy},{panel},{phase:?},{step})",
                    ctx.rank
                );
                return Ok(Fetch::Live);
            }
            if !self.shared.world.router().is_alive(buddy) {
                // Become the buddy's detector so its replay can start;
                // either way we park and re-check on the next wakeup.
                let _revived_now = self.on_peer_failure_at(ctx, sp, buddy, panel, step)?;
            }
            self.shared.watch_store(ctx.rank);
            // Close the insert/watch race: the buddy may have retained
            // between our miss and the registration.
            if let Some(ret) = self.shared.store.get(buddy, panel, phase, step, lane) {
                self.charge_fetch(ctx, buddy, panel, phase, step, lane, &ret);
                return Ok(Fetch::Hit(ret));
            }
            crate::simlog!(
                "[r{}] replay WAIT ({buddy},{panel},{phase:?},{step})",
                ctx.rank
            );
            return Ok(Fetch::Wait);
        }
        crate::simlog!(
            "[r{}] replay MISS ({buddy},{panel},{phase:?},{step}) -> live",
            ctx.rank
        );
        Ok(Fetch::Live)
    }

    #[allow(clippy::too_many_arguments)]
    fn charge_fetch(
        &self,
        ctx: &mut RankCtx,
        buddy: usize,
        panel: usize,
        phase: Phase,
        step: usize,
        lane: u32,
        ret: &Retained,
    ) {
        let t0 = ctx.clock;
        let bytes = ret.nbytes();
        ctx.charge_local_recv(bytes);
        self.shared.trace.emit(
            ctx.clock,
            ctx.rank,
            panel,
            step,
            "recovery_fetch",
            buddy as f64,
        );
        self.emit_span(ctx, SpanKind::RecoveryFetch, t0, panel, lane as usize, buddy as f64);
        crate::simlog!("[r{}] replay hit ({buddy},{panel},{phase:?},{step})", ctx.rank);
    }

    /// Recompute this rank's update rows from buddy-retained `{W, Y1}`
    /// **in place**: `C' ← C' − Y W` with `Y = I` for the top member
    /// (paper III-C). No copy of the `C'` rows is taken. `full_n` pins
    /// the kernel dispatch to the panel's full trailing width so a
    /// replayed pipeline segment is bit-identical to the live one.
    pub(crate) fn recover_rows(
        &self,
        ctx: &mut RankCtx,
        cp: &mut Matrix,
        role: Role,
        ret: &Retained,
        full_n: usize,
    ) {
        let (b, n) = cp.shape();
        match role {
            // Top member: Ĉ₀ = C₀ − W — the live top half's exact
            // elementwise expression (no dense multiply by an identity).
            Role::Upper => self
                .shared
                .backend
                .recover_top_into(cp, &ret.w)
                .unwrap_or_else(|e| panic!("recover op failed: {e:#}")),
            Role::Lower => self
                .shared
                .backend
                .recover_into_cols(cp, &ret.y1, &ret.w, full_n)
                .unwrap_or_else(|e| panic!("recover op failed: {e:#}")),
            Role::Idle => unreachable!("idle roles never reach recovery"),
        }
        ctx.compute(crate::backend::flops::recover(b, n));
    }

    /// Retain the FT-TSQR step outcome (both pair members hold the
    /// merged factors after the exchange, §III-B). The `Arc` clones share
    /// buffers with the caller's working state — retention is
    /// refcount-priced, the byte accounting is not (see [`Retained`]).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn retain_tsqr(
        &self,
        rank: usize,
        inc: u32,
        g: &PanelGeom,
        step: usize,
        buddy: usize,
        y1: &Arc<Matrix>,
        t: &Arc<Matrix>,
        r_merged: &Arc<Matrix>,
    ) {
        self.shared.store.insert(
            rank,
            inc,
            g.k,
            Phase::Tsqr,
            step,
            0,
            Retained {
                buddy,
                w: Arc::new(Matrix::zeros(0, 0)),
                y1: y1.clone(),
                t: t.clone(),
                r_merged: r_merged.clone(),
            },
        );
        self.shared.notify_store_watchers();
    }

    /// Pull the panel's row-broadcast factor bundle (FT mode, `Pc > 1`)
    /// from `parent` — the rank ahead of us in the collective schedule
    /// ([`super::collective::BcastSched`]): the grid row's panel-column
    /// member for the root's direct children, an intermediate relay that
    /// republished the bundle otherwise. `ord` is this reader's
    /// serialization ordinal behind the parent's other pullers; `nseg`
    /// segments the charge so deep readers overlap with the publisher's
    /// serialization ([`crate::sim::CostModel::bcast_pull_time`]).
    ///
    /// `Ok(None)` parks the receiver — the parent either hasn't published
    /// yet, or died and its replacement will republish during its replay.
    /// A *dead* parent additionally triggers the fallback-to-root
    /// invariant: the root's copy (published before any relay could hold
    /// one) serves the reader directly at the conservative flat ordinal
    /// `fallback_ord`, so no receiver ever waits on a relay's replay once
    /// the root's copy exists. There is no unrecoverable case here:
    /// unlike a pair step's `{W, T, Y₁}`, the bundle is re-derivable from
    /// the root's own replay (whose step fetches have their own
    /// unrecoverable check).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn fetch_bcast(
        &self,
        ctx: &mut RankCtx,
        sp: &Spawner,
        parent: usize,
        root: usize,
        panel: usize,
        ord: usize,
        fallback_ord: usize,
        nseg: usize,
    ) -> Result<Option<Vec<Arc<Matrix>>>, Fail> {
        if let Some((ts, mats)) = self.shared.store.get_bcast(parent, panel) {
            self.charge_bcast(ctx, parent, panel, ts, ord, nseg, &mats);
            return Ok(Some(mats));
        }
        if !self.shared.world.router().is_alive(parent) {
            // Become the parent's detector so its replay can start; the
            // claim outcome doesn't gate the root fallback below — the
            // root's copy is valid to read either way.
            let _revived_now = self.on_peer_failure_at(ctx, sp, parent, panel, 0)?;
            if parent != root {
                if let Some((ts, mats)) = self.shared.store.get_bcast(root, panel) {
                    crate::simlog!(
                        "[r{}] bcast FALLBACK to root {root} (panel {panel}, relay {parent} dead)",
                        ctx.rank
                    );
                    self.charge_bcast(ctx, root, panel, ts, fallback_ord, nseg, &mats);
                    return Ok(Some(mats));
                }
            }
        }
        self.shared.watch_store(ctx.rank);
        // Close the insert/watch race: the parent may have published
        // between our miss and the registration.
        if let Some((ts, mats)) = self.shared.store.get_bcast(parent, panel) {
            self.charge_bcast(ctx, parent, panel, ts, ord, nseg, &mats);
            return Ok(Some(mats));
        }
        crate::simlog!("[r{}] bcast WAIT (panel {panel} from {parent})", ctx.rank);
        Ok(None)
    }

    #[allow(clippy::too_many_arguments)]
    fn charge_bcast(
        &self,
        ctx: &mut RankCtx,
        owner: usize,
        panel: usize,
        publish_ts: f64,
        ord: usize,
        nseg: usize,
        mats: &[Arc<Matrix>],
    ) {
        let bytes: usize = mats.iter().map(|m| m.nbytes()).sum();
        ctx.charge_bcast_pull(publish_ts, ord, bytes, nseg);
        ctx.metrics.record_bcast(bytes as u64, 1);
        self.shared.trace.emit(ctx.clock, ctx.rank, panel, 0, "bcast_fetch", owner as f64);
        crate::simlog!("[r{}] bcast hit (panel {panel} from {owner})", ctx.rank);
    }

    /// Publish the row-broadcast factor bundle for `panel` (FT mode; the
    /// one-sided counterpart of the plain mode's real row messages) and
    /// wake any grid-row peers parked on it. `ts` is the publisher's
    /// clock at publication — readers' pull charges serialize behind it.
    /// Both the root (after its TSQR) and the schedule's relay ranks (as
    /// their own pull completes) publish, so a relay's children read the
    /// relay's copy, not the root's.
    pub(crate) fn retain_bcast(
        &self,
        rank: usize,
        inc: u32,
        panel: usize,
        ts: f64,
        mats: Vec<Arc<Matrix>>,
    ) {
        self.shared.store.insert_bcast(rank, inc, panel, ts, mats);
        self.shared.notify_store_watchers();
    }

    /// Retain the FT update step inventory `{W, T, Y₁}` (paper III-C's
    /// end-of-step list; the C' copies of the paper's inventory are
    /// replayed from the initial block, so only the factors are stored —
    /// the byte accounting intentionally reflects what recovery reads).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn retain_update(
        &self,
        rank: usize,
        inc: u32,
        g: &PanelGeom,
        step: usize,
        lane: u32,
        buddy: usize,
        w: &Arc<Matrix>,
        y1: &Arc<Matrix>,
        t: &Arc<Matrix>,
    ) {
        self.shared.store.insert(
            rank,
            inc,
            g.k,
            Phase::Update,
            step,
            lane,
            Retained {
                buddy,
                w: w.clone(),
                y1: y1.clone(),
                t: t.clone(),
                r_merged: Arc::new(Matrix::zeros(0, 0)),
            },
        );
        self.shared.notify_store_watchers();
    }
}
