//! Failure detection, REBUILD and single-buddy state reconstruction
//! (paper §III-C), plus the retention hooks that feed the buddy store.
//!
//! Detection is ULFM-style: a communication touching a dead rank returns
//! [`Fail::RankFailed`]. Under `Semantics::Rebuild`, the first detector
//! wins the [`RevivalGate`], drops the dead rank's (lost) retained
//! memory, revives its mailbox, and spawns a replacement task that
//! replays from the rank's initial block: local factorizations are
//! recomputed, completed pair steps are reconstructed from the buddy's
//! retained `{W, T, Y₁, R̃}` via `Ĉ' = C' − Y W` (the `recover`
//! artifact), and the interrupted step is simply re-entered live — the
//! detector retries its exchange until the replacement arrives.

use crate::config::Algorithm;
use crate::fault::Phase;
use crate::ft::{Fail, Semantics};
use crate::linalg::Matrix;
use crate::sim::{MsgData, Tag};

use super::caqr::Ranker;
use super::panel::PanelGeom;
use super::store::Retained;
use super::tree::Role;

impl Ranker {
    /// FT exchange with failure handling: retries after arranging (or
    /// waiting for) the peer's REBUILD.
    pub(crate) fn exchange(
        &mut self,
        peer: usize,
        tag: Tag,
        data: MsgData,
    ) -> Result<MsgData, Fail> {
        crate::simlog!("[r{}] exch-> peer={peer} {tag:?}", self.rank());
        loop {
            match self.ctx.sendrecv(peer, tag, data.clone()) {
                Ok(d) => {
                    crate::simlog!("[r{}] exch<- peer={peer} {tag:?}", self.rank());
                    return Ok(d);
                }
                Err(Fail::RankFailed { rank }) => {
                    crate::simlog!("[r{}] detected rank {rank} dead at {tag:?}", self.rank());
                    self.on_peer_failure(rank)?;
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Plain-mode receive: no recovery (the baseline has no redundancy);
    /// failures follow the configured semantics (Abort by default).
    pub(crate) fn recv_plain(&mut self, src: usize, tag: Tag) -> Result<MsgData, Fail> {
        match self.ctx.recv(src, tag) {
            Ok(d) => Ok(d),
            Err(Fail::RankFailed { rank }) => {
                if self.shared.cfg.algorithm == Algorithm::FaultTolerant {
                    // Plain-mode helpers are only used by Algorithm::Plain.
                    unreachable!("recv_plain in FT mode");
                }
                match self.shared.cfg.semantics {
                    Semantics::Abort => Err(Fail::Aborted),
                    _ => Err(Fail::RankFailed { rank }),
                }
            }
            Err(e) => Err(e),
        }
    }

    pub(crate) fn send_plain(&mut self, dst: usize, tag: Tag, data: MsgData) -> Result<(), Fail> {
        match self.ctx.send(dst, tag, data) {
            Ok(()) => Ok(()),
            Err(Fail::RankFailed { .. }) if self.shared.cfg.semantics == Semantics::Abort => {
                Err(Fail::Aborted)
            }
            Err(e) => Err(e),
        }
    }

    /// Handle a detected peer failure according to the semantics.
    pub(crate) fn on_peer_failure(&mut self, dead: usize) -> Result<(), Fail> {
        match self.shared.cfg.semantics {
            Semantics::Abort => Err(Fail::Aborted),
            Semantics::Shrink | Semantics::Blank => {
                // The CAQR driver does not renumber mid-factorization;
                // these semantics are exercised at the sim level (see
                // examples/semantics.rs). Surface the failure.
                Err(Fail::RankFailed { rank: dead })
            }
            Semantics::Rebuild => {
                // Snapshot the incarnation we observed as dead BEFORE the
                // liveness re-check: if another detector already rebuilt
                // the rank, we must not claim the next incarnation (that
                // would spawn a second replacement and orphan the first).
                let inc_dead = self.shared.world.router().incarnation(dead);
                if self.shared.world.router().is_alive(dead) {
                    // Already rebuilt — just retry the operation.
                    return Ok(());
                }
                if self.shared.gate.claim(dead, inc_dead + 1) {
                    crate::simlog!("[r{}] REBUILD rank {dead} (inc {})", self.rank(), inc_dead + 1);
                    self.shared.trace.emit(
                        self.ctx.clock,
                        self.rank(),
                        0,
                        0,
                        "recovery_start",
                        dead as f64,
                    );
                    // The dead process's memory is gone.
                    self.shared.store.drop_owner(dead);
                    // REBUILD: fresh mailbox; the replacement's clock
                    // starts at the detector's (failure-detection time).
                    let ctx = self.shared.world.revive(dead, self.ctx.clock);
                    let sh = self.shared.clone();
                    let local = sh.initial[dead].clone();
                    let h = std::thread::Builder::new()
                        .name(format!("rank-{dead}-rebuilt"))
                        .spawn(move || {
                            Ranker { shared: sh, ctx, resume: true, local }.run()
                        })
                        .expect("spawn rebuilt rank thread");
                    self.shared.revived.lock().unwrap().push(h);
                } else {
                    // Someone else is rebuilding; wait for liveness.
                    while !self.shared.world.router().is_alive(dead) {
                        std::thread::yield_now();
                    }
                }
                Ok(())
            }
        }
    }

    /// Read a buddy's retained step data during replay, charging the
    /// simulated transfer (one message from one process — paper III-C).
    pub(crate) fn fetch_retained(
        &mut self,
        buddy: usize,
        panel: usize,
        phase: Phase,
        step: usize,
    ) -> Option<Retained> {
        let Some(ret) = self.shared.store.get(buddy, panel, phase, step) else {
            crate::simlog!(
                "[r{}] replay MISS ({buddy},{panel},{phase:?},{step}) -> live",
                self.rank()
            );
            return None;
        };
        let bytes = ret.nbytes();
        self.ctx.clock = self.ctx.cost.recv_time(self.ctx.clock, self.ctx.clock, bytes);
        self.ctx.metrics.record_message(bytes);
        self.shared.trace.emit(
            self.ctx.clock,
            self.rank(),
            panel,
            step,
            "recovery_fetch",
            buddy as f64,
        );
        crate::simlog!("[r{}] replay hit ({buddy},{panel},{phase:?},{step})", self.rank());
        Some(ret)
    }

    /// Recompute this rank's update rows from buddy-retained `{W, Y1}`:
    /// `Ĉ' = C' − Y W` with `Y = I` for the top member (paper III-C).
    pub(crate) fn recover_rows(
        &mut self,
        cp: &Matrix,
        role: Role,
        ret: &Retained,
    ) -> Result<Matrix, Fail> {
        let b = cp.rows();
        let y = match role {
            Role::Upper => Matrix::eye(b),
            Role::Lower => ret.y1.clone(),
            Role::Idle => unreachable!("idle roles never reach recovery"),
        };
        let out = self
            .shared
            .backend
            .recover(cp, &y, &ret.w)
            
            .unwrap_or_else(|e| panic!("recover op failed: {e:#}"));
        self.ctx.compute(crate::backend::flops::recover(b, cp.cols()));
        Ok(out)
    }

    /// Retain the FT-TSQR step outcome (both pair members hold the
    /// merged factors after the exchange, §III-B).
    pub(crate) fn retain_tsqr(
        &mut self,
        g: &PanelGeom,
        step: usize,
        buddy: usize,
        y1: &Matrix,
        t: &Matrix,
        r_merged: &Matrix,
    ) {
        self.shared.store.insert(
            self.rank(),
            g.k,
            Phase::Tsqr,
            step,
            Retained {
                buddy,
                w: Matrix::zeros(0, 0),
                y1: y1.clone(),
                t: t.clone(),
                r_merged: r_merged.clone(),
            },
        );
    }

    /// Retain the FT update step inventory `{W, T, C'₀, C'₁, Y₁}`
    /// (paper III-C's end-of-step list).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn retain_update(
        &mut self,
        g: &PanelGeom,
        step: usize,
        buddy: usize,
        w: &Matrix,
        y1: &Matrix,
        t: &Matrix,
        _c0: &Matrix,
        _c1: &Matrix,
    ) {
        // C' copies are part of the paper's inventory; recovery as
        // implemented replays C' from the initial block, so only the
        // factors are stored (the byte accounting intentionally reflects
        // what recovery actually reads).
        self.shared.store.insert(
            self.rank(),
            g.k,
            Phase::Update,
            step,
            Retained {
                buddy,
                w: w.clone(),
                y1: y1.clone(),
                t: t.clone(),
                r_merged: Matrix::zeros(0, 0),
            },
        );
    }

    /// Diskless-checkpoint baseline (§II / E7): every `interval` panels,
    /// exchange a full copy of the local block with a partner.
    pub(crate) fn maybe_checkpoint(&mut self, g: &PanelGeom) -> Result<(), Fail> {
        let every = self.shared.cfg.checkpoint_every;
        if every == 0 || (g.k + 1) % every != 0 {
            return Ok(());
        }
        // Pair within the ranks still participating in this panel —
        // retired ranks have left the computation and exchange nothing.
        let pidx = g.idx ^ 1;
        if pidx >= g.q {
            return Ok(());
        }
        let partner = g.owner + pidx;
        let tag = Tag::new(crate::sim::TagKind::Checkpoint, g.k, 0);
        let _peer = self
            .exchange(partner, tag, MsgData::Mat(self.local.clone()))
            ?;
        self.shared.trace.emit(
            self.ctx.clock,
            self.rank(),
            g.k,
            0,
            "checkpoint",
            partner as f64,
        );
        Ok(())
    }
}
