//! Buddy-held redundancy state (paper §III-C) and the recovery manager.
//!
//! At the end of every FT step, each member of a pair retains
//! `{W, T, C'_own, C'_peer, Y1}` — the paper's inventory that makes the
//! buddy's state recomputable from *one* process. [`RecoveryStore`]
//! models that per-process retained memory: entries are written by their
//! owning rank as it executes and read (with simulated communication
//! charged) by a rebuilt rank during replay. Update-phase entries are
//! keyed by a *lane* as well — the column-block segment of the lookahead
//! pipeline (lane 0 for the whole-width lockstep update).
//!
//! [`RevivalGate`] arbitrates REBUILD: the first detector of a dead
//! rank revives it and spawns the replay task; concurrent detectors just
//! retry their operation once the revival is visible. The store also
//! tracks each rank's *progress frontier* — which steps a rank ever
//! completed, surviving the rank's death — the runtime metadata that
//! lets a replay tell a slow buddy from lost redundancy (see `DESIGN.md`
//! "Multi-failure recovery semantics"). Since the lookahead refactor the
//! frontier is a **per-panel vector**, not a single scalar: a pipelined
//! rank completes panel `k+1` TSQR steps while panel `k` far-trailing
//! segments are still in flight, so cross-panel "earlier sites covered"
//! inference is only valid *within* a panel (where each rank's execution
//! stays totally ordered: TSQR steps, then update lanes in ascending
//! column order).

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::Mutex;

use crate::fault::Phase;
use crate::linalg::Matrix;

/// Key: (owning rank, panel, phase, tree step, update lane).
pub type StepKey = (usize, usize, Phase, usize, u32);

/// What a rank retains after an FT exchange step (paper III-C).
///
/// Matrix fields are [`Arc`]-shared with the producing step's working
/// state: retaining costs a refcount, not a buffer copy, and
/// [`RecoveryStore::get`]'s clone of the whole entry is equally cheap.
/// The byte accounting ([`Retained::nbytes`]) still charges the full
/// buffer sizes — it models *per-process retained memory*, which a real
/// deployment cannot share across address spaces.
#[derive(Clone, Debug)]
pub struct Retained {
    /// The buddy of this step.
    pub buddy: usize,
    /// `W = Tᵀ(C₀' + Y₁ᵀC₁')` (update steps; zero-sized for TSQR steps).
    pub w: Arc<Matrix>,
    /// Bottom reflector block of the pair's merge.
    pub y1: Arc<Matrix>,
    /// T factor of the pair's merge.
    pub t: Arc<Matrix>,
    /// Merged R (TSQR steps; the buddy resumes from it directly).
    pub r_merged: Arc<Matrix>,
}

impl Retained {
    /// Payload size of a recovery read (what the fetch is charged as).
    pub fn nbytes(&self) -> usize {
        self.w.nbytes() + self.y1.nbytes() + self.t.nbytes() + self.r_merged.nbytes()
    }
}

/// All ranks' retained redundancy state. In a real deployment each entry
/// lives in its owner's memory; the shared map here stands in for the
/// buddy answering a recovery request, and every read is charged as a
/// simulated message by the caller.
#[derive(Default)]
pub struct RecoveryStore {
    entries: Mutex<HashMap<StepKey, Retained>>,
    /// Total bytes currently retained (the FT scheme's memory overhead,
    /// compared against diskless checkpointing in E7).
    bytes: AtomicU64,
    /// High-water mark of `bytes`.
    peak_bytes: AtomicU64,
    /// Recovery reads served.
    reads: AtomicU64,
    /// Per-rank, per-panel execution frontier: the highest within-panel
    /// site each rank has ever *completed* (monotone across incarnations
    /// — runtime metadata, so unlike `entries` it survives the rank's
    /// death). Per-panel because the lookahead pipeline interleaves
    /// panels: completing a step of panel `k+1` says nothing about panel
    /// `k`'s still-draining far segments. A replay that misses an entry
    /// at or below its own frontier for that panel has lost both copies
    /// of the step's redundancy: unrecoverable.
    progress: Mutex<HashMap<usize, HashMap<usize, u64>>>,
    /// Checkpoints each rank has completed (runtime metadata, survives
    /// the rank's death like `progress`): closes the replay window where
    /// a rank dies after exchanging a checkpoint but before retaining
    /// anything in the next panel — without this its replacement would
    /// re-enter the checkpoint against a partner that has long moved on
    /// and park forever.
    checkpoints: Mutex<HashMap<usize, HashSet<usize>>>,
    /// Lowest incarnation per rank whose inserts are still accepted.
    /// [`RecoveryStore::drop_owner_dead`] bumps it past the dying
    /// incarnation *before* the death becomes visible, so a straggling
    /// retain from the killed task can never resurrect memory that died
    /// with the process (the entry is rejected; the progress frontier is
    /// still advanced — the step really did complete before the crash).
    accept_from: Mutex<HashMap<usize, u32>>,
    /// Row-broadcast factor bundles, keyed `(publisher rank, panel)`:
    /// the panel grid column's `{leaf Y, leaf T, (Y₁, T) per merge step}`
    /// that the same grid row's other columns pull to run their update
    /// trees (2-D grids only). The value carries the publisher's logical
    /// clock at publish time — the cost model serializes readers behind
    /// it (see `CostModel::bcast_pull_time`). Under a tree schedule,
    /// *relays* republish the bundle under their own key as they receive
    /// it. Like `entries`, a bundle lives in its publisher's memory and
    /// dies with it — receivers then fall back to the root's copy, or
    /// park until a replacement's TSQR replay republishes.
    bcast: Mutex<HashMap<(usize, usize), (f64, Vec<Arc<Matrix>>)>>,
}

/// Total order on one rank's sites *within one panel*, matching per-rank
/// execution order under both schedules: TSQR steps first, then update
/// lanes in ascending column order, tree steps innermost. (Lane 0 is the
/// lockstep whole-width update; the pipeline's segments use the global
/// column-block index, always >= panel + 1.)
fn panel_site_index(phase: Phase, step: usize, lane: u32) -> u64 {
    match phase {
        Phase::Tsqr => step as u64,
        // The row-broadcast publish sits between the panel column's TSQR
        // and every grid column's update lanes in per-rank execution
        // order (`Pc = 1` grids never emit this site).
        Phase::Bcast => 1u64 << 30,
        Phase::Update => (1u64 << 40) | ((lane as u64) << 20) | (step as u64 & 0xf_ffff),
    }
}

impl RecoveryStore {
    /// An empty store.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Record rank `owner`'s retained state for a step, written by the
    /// owner's incarnation `inc`; also advances `owner`'s completion
    /// frontier for `panel` (a step is retained exactly when it
    /// completes). The entry is silently rejected — though the frontier
    /// still advances — when `inc` predates the last declared death of
    /// the rank (see [`RecoveryStore::drop_owner_dead`]).
    #[allow(clippy::too_many_arguments)]
    pub fn insert(
        &self,
        owner: usize,
        inc: u32,
        panel: usize,
        phase: Phase,
        step: usize,
        lane: u32,
        r: Retained,
    ) {
        {
            // Lock order everywhere: accept_from before entries.
            let gate = self.accept_from.lock().unwrap();
            let min = gate.get(&owner).copied().unwrap_or(0);
            if inc >= min {
                let sz = r.nbytes() as u64;
                let mut g = self.entries.lock().unwrap();
                if let Some(old) = g.insert((owner, panel, phase, step, lane), r) {
                    self.bytes.fetch_sub(old.nbytes() as u64, Ordering::Relaxed);
                }
                let now = self.bytes.fetch_add(sz, Ordering::Relaxed) + sz;
                self.peak_bytes.fetch_max(now, Ordering::Relaxed);
            }
        }
        let idx = panel_site_index(phase, step, lane);
        let mut p = self.progress.lock().unwrap();
        let e = p.entry(owner).or_default().entry(panel).or_insert(0);
        *e = (*e).max(idx);
    }

    /// Publish rank `owner`'s row-broadcast factor bundle for `panel`
    /// (the panel grid column's leaf + merge factors, pulled by the same
    /// grid row's other columns). `ts` is the publisher's logical clock
    /// at publish time — readers serialize behind it in the cost model.
    /// Incarnation-gated like [`RecoveryStore::insert`]; also advances
    /// the publisher's frontier past the `Phase::Bcast` site.
    pub fn insert_bcast(
        &self,
        owner: usize,
        inc: u32,
        panel: usize,
        ts: f64,
        mats: Vec<Arc<Matrix>>,
    ) {
        {
            // Lock order everywhere: accept_from before entries/bcast.
            let gate = self.accept_from.lock().unwrap();
            let min = gate.get(&owner).copied().unwrap_or(0);
            if inc >= min {
                let sz: u64 = mats.iter().map(|m| m.nbytes() as u64).sum();
                let mut g = self.bcast.lock().unwrap();
                if let Some((_, old)) = g.insert((owner, panel), (ts, mats)) {
                    let old_sz: u64 = old.iter().map(|m| m.nbytes() as u64).sum();
                    self.bytes.fetch_sub(old_sz, Ordering::Relaxed);
                }
                let now = self.bytes.fetch_add(sz, Ordering::Relaxed) + sz;
                self.peak_bytes.fetch_max(now, Ordering::Relaxed);
            }
        }
        let idx = panel_site_index(Phase::Bcast, 0, 0);
        let mut p = self.progress.lock().unwrap();
        let e = p.entry(owner).or_default().entry(panel).or_insert(0);
        *e = (*e).max(idx);
    }

    /// Read `owner`'s broadcast bundle for `panel`, if still retained:
    /// `(publish clock, matrices)`. Returns a clone of the `Arc` list;
    /// the caller charges the simulated transfer.
    pub fn get_bcast(&self, owner: usize, panel: usize) -> Option<(f64, Vec<Arc<Matrix>>)> {
        let out = self.bcast.lock().unwrap().get(&(owner, panel)).cloned();
        if out.is_some() {
            self.reads.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// Has `owner` (in any incarnation) ever completed the given step of
    /// the given panel? Queried by a replaying replacement on a
    /// retained-state miss to distinguish "step never ran — re-enter it
    /// live" from "step ran and both redundancy copies are gone —
    /// unrecoverable". Within a panel, completion of a later site covers
    /// all earlier ones (per-rank in-panel execution is totally
    /// ordered); across panels no inference is made — the lookahead
    /// pipeline interleaves them.
    pub fn has_completed(
        &self,
        owner: usize,
        panel: usize,
        phase: Phase,
        step: usize,
        lane: u32,
    ) -> bool {
        self.progress
            .lock()
            .unwrap()
            .get(&owner)
            .and_then(|panels| panels.get(&panel))
            .is_some_and(|&max| max >= panel_site_index(phase, step, lane))
    }

    /// Record that `owner` completed (exchanged) the diskless checkpoint
    /// after `panel`.
    pub fn note_checkpoint(&self, owner: usize, panel: usize) {
        self.checkpoints.lock().unwrap().entry(owner).or_default().insert(panel);
    }

    /// Has `owner` (in any incarnation) completed the checkpoint after
    /// `panel`?
    pub fn has_checkpointed(&self, owner: usize, panel: usize) -> bool {
        self.checkpoints
            .lock()
            .unwrap()
            .get(&owner)
            .is_some_and(|set| set.contains(&panel))
    }

    /// Has `owner` ever completed *any* step of `panel` or a later one?
    /// The checkpoint-replay shortcut: a pre-death incarnation that had
    /// already entered panel `k+1` must have finished (and exchanged)
    /// every checkpoint up to and including panel `k`'s — the checkpoint
    /// is an admission barrier in both schedules.
    pub fn has_progress_at_or_after(&self, owner: usize, panel: usize) -> bool {
        self.progress
            .lock()
            .unwrap()
            .get(&owner)
            .is_some_and(|panels| panels.keys().any(|&p| p >= panel))
    }

    /// Read rank `owner`'s retained state (a rebuilt rank asking its
    /// step-buddy for recovery data). Returns a clone; the caller charges
    /// the simulated transfer.
    pub fn get(
        &self,
        owner: usize,
        panel: usize,
        phase: Phase,
        step: usize,
        lane: u32,
    ) -> Option<Retained> {
        let out =
            self.entries.lock().unwrap().get(&(owner, panel, phase, step, lane)).cloned();
        if out.is_some() {
            self.reads.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// A process died: its retained memory is lost with it — the step
    /// entries *and* any broadcast bundles it had published.
    pub fn drop_owner(&self, owner: usize) {
        {
            let mut g = self.entries.lock().unwrap();
            let dead: Vec<StepKey> = g.keys().filter(|k| k.0 == owner).cloned().collect();
            for k in dead {
                if let Some(old) = g.remove(&k) {
                    self.bytes.fetch_sub(old.nbytes() as u64, Ordering::Relaxed);
                }
            }
        }
        let mut g = self.bcast.lock().unwrap();
        let dead: Vec<(usize, usize)> =
            g.keys().filter(|k| k.0 == owner).cloned().collect();
        for k in dead {
            if let Some((_, old)) = g.remove(&k) {
                let sz: u64 = old.iter().map(|m| m.nbytes() as u64).sum();
                self.bytes.fetch_sub(sz, Ordering::Relaxed);
            }
        }
    }

    /// Incarnation `dead_inc` of `owner` died: wipe its retained memory
    /// AND refuse any straggling insert from that (or an earlier)
    /// incarnation. Must be called *before* the death is made visible on
    /// the router, so no replacement can ever read memory that died.
    pub fn drop_owner_dead(&self, owner: usize, dead_inc: u32) {
        {
            let mut gate = self.accept_from.lock().unwrap();
            let e = gate.entry(owner).or_insert(0);
            *e = (*e).max(dead_inc + 1);
        }
        self.drop_owner(owner);
    }

    /// Drop retained state older than `panel` (panels complete =>
    /// redundancy for them is no longer needed once a global checkpoint
    /// of R's rows exists). Keeps memory bounded in long runs.
    pub fn retire_before(&self, panel: usize) {
        {
            let mut g = self.entries.lock().unwrap();
            let dead: Vec<StepKey> = g.keys().filter(|k| k.1 < panel).cloned().collect();
            for k in dead {
                if let Some(old) = g.remove(&k) {
                    self.bytes.fetch_sub(old.nbytes() as u64, Ordering::Relaxed);
                }
            }
        }
        let mut g = self.bcast.lock().unwrap();
        let dead: Vec<(usize, usize)> =
            g.keys().filter(|k| k.1 < panel).cloned().collect();
        for k in dead {
            if let Some((_, old)) = g.remove(&k) {
                let sz: u64 = old.iter().map(|m| m.nbytes() as u64).sum();
                self.bytes.fetch_sub(sz, Ordering::Relaxed);
            }
        }
    }

    /// Bytes currently retained.
    pub fn current_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// High-water mark of retained bytes.
    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes.load(Ordering::Relaxed)
    }

    /// Recovery reads served so far.
    pub fn reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }

    /// Number of retained step entries.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when nothing is retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Arbitrates rank revival so exactly one detector performs REBUILD.
#[derive(Default)]
pub struct RevivalGate {
    in_progress: Mutex<HashMap<usize, u32>>,
}

impl RevivalGate {
    /// A gate with no revivals in progress.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Returns true if the caller won the right to revive `rank` for the
    /// given incarnation (i.e. it must perform the REBUILD).
    pub fn claim(&self, rank: usize, incarnation: u32) -> bool {
        let mut g = self.in_progress.lock().unwrap();
        match g.get(&rank) {
            Some(&inc) if inc >= incarnation => false,
            _ => {
                g.insert(rank, incarnation);
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn retained(bytes_rows: usize) -> Retained {
        Retained {
            buddy: 1,
            w: Arc::new(Matrix::zeros(bytes_rows, 4)),
            y1: Arc::new(Matrix::zeros(4, 4)),
            t: Arc::new(Matrix::zeros(4, 4)),
            r_merged: Arc::new(Matrix::zeros(4, 4)),
        }
    }

    #[test]
    fn insert_get_roundtrip() {
        let s = RecoveryStore::new();
        s.insert(2, 0, 0, Phase::Update, 1, 0, retained(4));
        let r = s.get(2, 0, Phase::Update, 1, 0).unwrap();
        assert_eq!(r.buddy, 1);
        assert!(s.get(2, 0, Phase::Update, 0, 0).is_none());
        assert_eq!(s.reads(), 1);
    }

    #[test]
    fn lanes_are_distinct_entries() {
        let s = RecoveryStore::new();
        s.insert(0, 0, 0, Phase::Update, 0, 1, retained(4));
        s.insert(0, 0, 0, Phase::Update, 0, 2, retained(8));
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(0, 0, Phase::Update, 0, 1).unwrap().w.rows(), 4);
        assert_eq!(s.get(0, 0, Phase::Update, 0, 2).unwrap().w.rows(), 8);
        assert!(s.get(0, 0, Phase::Update, 0, 0).is_none());
    }

    #[test]
    fn byte_accounting_tracks_peak() {
        let s = RecoveryStore::new();
        s.insert(0, 0, 0, Phase::Tsqr, 0, 0, retained(4));
        let b1 = s.current_bytes();
        assert!(b1 > 0);
        s.insert(0, 0, 1, Phase::Tsqr, 0, 0, retained(4));
        let b2 = s.current_bytes();
        assert_eq!(b2, 2 * b1);
        s.retire_before(1);
        assert_eq!(s.current_bytes(), b1);
        assert_eq!(s.peak_bytes(), b2);
    }

    #[test]
    fn reinsert_replaces() {
        let s = RecoveryStore::new();
        s.insert(0, 0, 0, Phase::Update, 0, 0, retained(4));
        s.insert(0, 0, 0, Phase::Update, 0, 0, retained(8));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(0, 0, Phase::Update, 0, 0).unwrap().w.rows(), 8);
    }

    #[test]
    fn frontier_is_per_panel() {
        let s = RecoveryStore::new();
        // A pipelined rank completes panel 1's first TSQR step while
        // panel 0's far update segments are still in flight.
        s.insert(2, 0, 1, Phase::Tsqr, 1, 0, retained(4));
        assert!(s.has_completed(2, 1, Phase::Tsqr, 1, 0));
        assert!(s.has_completed(2, 1, Phase::Tsqr, 0, 0), "earlier in-panel sites covered");
        assert!(
            !s.has_completed(2, 0, Phase::Update, 0, 1),
            "no cross-panel inference under pipelining"
        );
        assert!(!s.has_completed(2, 1, Phase::Update, 0, 2), "later sites not covered");
        assert!(!s.has_completed(3, 1, Phase::Tsqr, 0, 0), "other ranks untouched");
        // Within a panel, update lanes are ordered after TSQR and by
        // ascending lane.
        s.insert(2, 0, 0, Phase::Update, 0, 2, retained(4));
        assert!(s.has_completed(2, 0, Phase::Tsqr, 5, 0));
        assert!(s.has_completed(2, 0, Phase::Update, 3, 1), "earlier lane covered");
        assert!(!s.has_completed(2, 0, Phase::Update, 0, 3), "later lane not");
    }

    #[test]
    fn checkpoint_completion_survives_death() {
        let s = RecoveryStore::new();
        assert!(!s.has_checkpointed(2, 1));
        s.note_checkpoint(2, 1);
        assert!(s.has_checkpointed(2, 1));
        assert!(!s.has_checkpointed(2, 3));
        // Runtime metadata: a death wipes entries, not the record.
        s.drop_owner_dead(2, 0);
        assert!(s.has_checkpointed(2, 1));
    }

    #[test]
    fn progress_at_or_after_covers_checkpoint_shortcut() {
        let s = RecoveryStore::new();
        assert!(!s.has_progress_at_or_after(1, 0));
        s.insert(1, 0, 2, Phase::Tsqr, 0, 0, retained(4));
        assert!(s.has_progress_at_or_after(1, 2));
        assert!(s.has_progress_at_or_after(1, 1));
        assert!(!s.has_progress_at_or_after(1, 3));
        assert!(!s.has_progress_at_or_after(0, 0), "other ranks untouched");
    }

    #[test]
    fn progress_frontier_survives_drop_owner() {
        let s = RecoveryStore::new();
        s.insert(2, 0, 1, Phase::Tsqr, 1, 0, retained(4));
        // Death wipes the retained data but NOT the runtime's knowledge
        // of how far the rank had progressed.
        s.drop_owner(2);
        assert!(s.get(2, 1, Phase::Tsqr, 1, 0).is_none());
        assert!(s.has_completed(2, 1, Phase::Tsqr, 1, 0));
    }

    #[test]
    fn dead_incarnation_inserts_rejected_but_progress_advances() {
        let s = RecoveryStore::new();
        s.insert(2, 0, 0, Phase::Tsqr, 0, 0, retained(4));
        // Incarnation 0 dies; its memory is gone and stays gone even if a
        // straggling retain from the killed task lands afterwards.
        s.drop_owner_dead(2, 0);
        assert!(s.get(2, 0, Phase::Tsqr, 0, 0).is_none());
        s.insert(2, 0, 0, Phase::Tsqr, 1, 0, retained(4));
        assert!(s.get(2, 0, Phase::Tsqr, 1, 0).is_none(), "stale insert resurrected");
        // ...but the runtime still learns the step completed pre-crash.
        assert!(s.has_completed(2, 0, Phase::Tsqr, 1, 0));
        // The replacement (incarnation 1) retains normally.
        s.insert(2, 1, 0, Phase::Tsqr, 1, 0, retained(4));
        assert!(s.get(2, 0, Phase::Tsqr, 1, 0).is_some());
    }

    fn bundle() -> Vec<Arc<Matrix>> {
        vec![Arc::new(Matrix::zeros(8, 4)), Arc::new(Matrix::zeros(4, 4))]
    }

    #[test]
    fn bcast_bundle_roundtrip_and_death_wipe() {
        let s = RecoveryStore::new();
        assert!(s.get_bcast(1, 0).is_none());
        s.insert_bcast(1, 0, 0, 2.5, bundle());
        let (ts, got) = s.get_bcast(1, 0).expect("published bundle readable");
        assert_eq!(got.len(), 2);
        assert_eq!(ts, 2.5, "publish clock rides with the bundle");
        assert!(s.current_bytes() > 0);
        assert_eq!(s.reads(), 1);
        // The publish advances the frontier past the bcast site: after
        // TSQR, before any update lane.
        assert!(s.has_completed(1, 0, Phase::Bcast, 0, 0));
        assert!(s.has_completed(1, 0, Phase::Tsqr, 9, 0), "tsqr sites covered");
        assert!(!s.has_completed(1, 0, Phase::Update, 0, 1), "update sites not");
        // Death wipes the bundle (it lived in the publisher's memory)…
        s.drop_owner_dead(1, 0);
        assert!(s.get_bcast(1, 0).is_none());
        assert_eq!(s.current_bytes(), 0);
        // …and rejects a straggling republish from the dead incarnation,
        // while the replacement's republish lands.
        s.insert_bcast(1, 0, 0, 3.0, bundle());
        assert!(s.get_bcast(1, 0).is_none(), "stale publish resurrected");
        s.insert_bcast(1, 1, 0, 4.0, bundle());
        assert!(s.get_bcast(1, 0).is_some());
    }

    #[test]
    fn bcast_republish_replaces_and_reaccounts() {
        // A relay (or a replayed root) republishing under the same key
        // replaces the bundle and its timestamp without double-counting
        // the bytes.
        let s = RecoveryStore::new();
        s.insert_bcast(2, 0, 1, 1.0, bundle());
        let one = s.current_bytes();
        s.insert_bcast(2, 0, 1, 9.0, bundle());
        assert_eq!(s.current_bytes(), one);
        let (ts, _) = s.get_bcast(2, 1).unwrap();
        assert_eq!(ts, 9.0, "latest publish clock wins");
    }

    #[test]
    fn bcast_bundles_retire_with_their_panel() {
        let s = RecoveryStore::new();
        s.insert_bcast(0, 0, 0, 0.0, bundle());
        s.insert_bcast(0, 0, 2, 0.0, bundle());
        let per = s.current_bytes() / 2;
        s.retire_before(1);
        assert!(s.get_bcast(0, 0).is_none());
        assert!(s.get_bcast(0, 2).is_some());
        assert_eq!(s.current_bytes(), per);
    }

    #[test]
    fn revival_gate_single_winner() {
        let g = RevivalGate::new();
        assert!(g.claim(3, 1));
        assert!(!g.claim(3, 1));
        // next incarnation can be claimed again
        assert!(g.claim(3, 2));
    }
}
