//! Buddy-held redundancy state (paper §III-C) and the recovery manager.
//!
//! At the end of every FT step, each member of a pair retains
//! `{W, T, C'_own, C'_peer, Y1}` — the paper's inventory that makes the
//! buddy's state recomputable from *one* process. [`RecoveryStore`]
//! models that per-process retained memory: entries are written by their
//! owning rank as it executes and read (with simulated communication
//! charged) by a rebuilt rank during replay.
//!
//! [`RevivalGate`] arbitrates REBUILD: the first detector of a dead
//! rank revives it and spawns the replay task; concurrent detectors just
//! retry their operation once the revival is visible. The store also
//! tracks each rank's *progress frontier* (completed steps, surviving
//! the rank's death) — the runtime metadata that lets a replay tell a
//! slow buddy from lost redundancy (see `DESIGN.md` "Multi-failure
//! recovery semantics").

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::Mutex;

use crate::fault::Phase;
use crate::linalg::Matrix;

/// Key: (owning rank, panel, phase, tree step).
pub type StepKey = (usize, usize, Phase, usize);

/// What a rank retains after an FT exchange step (paper III-C).
///
/// Matrix fields are [`Arc`]-shared with the producing step's working
/// state: retaining costs a refcount, not a buffer copy, and
/// [`RecoveryStore::get`]'s clone of the whole entry is equally cheap.
/// The byte accounting ([`Retained::nbytes`]) still charges the full
/// buffer sizes — it models *per-process retained memory*, which a real
/// deployment cannot share across address spaces.
#[derive(Clone, Debug)]
pub struct Retained {
    /// The buddy of this step.
    pub buddy: usize,
    /// `W = Tᵀ(C₀' + Y₁ᵀC₁')` (update steps; zero-sized for TSQR steps).
    pub w: Arc<Matrix>,
    /// Bottom reflector block of the pair's merge.
    pub y1: Arc<Matrix>,
    /// T factor of the pair's merge.
    pub t: Arc<Matrix>,
    /// Merged R (TSQR steps; the buddy resumes from it directly).
    pub r_merged: Arc<Matrix>,
}

impl Retained {
    /// Payload size of a recovery read (what the fetch is charged as).
    pub fn nbytes(&self) -> usize {
        self.w.nbytes() + self.y1.nbytes() + self.t.nbytes() + self.r_merged.nbytes()
    }
}

/// All ranks' retained redundancy state. In a real deployment each entry
/// lives in its owner's memory; the shared map here stands in for the
/// buddy answering a recovery request, and every read is charged as a
/// simulated message by the caller.
#[derive(Default)]
pub struct RecoveryStore {
    entries: Mutex<HashMap<StepKey, Retained>>,
    /// Total bytes currently retained (the FT scheme's memory overhead,
    /// compared against diskless checkpointing in E7).
    bytes: AtomicU64,
    /// High-water mark of `bytes`.
    peak_bytes: AtomicU64,
    /// Recovery reads served.
    reads: AtomicU64,
    /// Per-rank execution frontier: the highest step each rank has ever
    /// *completed* (monotone across incarnations — this is runtime
    /// metadata, so unlike `entries` it survives the rank's death). A
    /// replay that misses an entry *below* its own frontier has lost
    /// both copies of the step's redundancy: unrecoverable.
    progress: Mutex<HashMap<usize, u64>>,
    /// Lowest incarnation per rank whose inserts are still accepted.
    /// [`RecoveryStore::drop_owner_dead`] bumps it past the dying
    /// incarnation *before* the death becomes visible, so a straggling
    /// retain from the killed task can never resurrect memory that died
    /// with the process (the entry is rejected; the progress frontier is
    /// still advanced — the step really did complete before the crash).
    accept_from: Mutex<HashMap<usize, u32>>,
}

/// Total order on fail/retention sites matching execution order: panels
/// outermost, TSQR before Update within a panel, tree steps innermost.
fn site_index(panel: usize, phase: Phase, step: usize) -> u64 {
    let ph = match phase {
        Phase::Tsqr => 0u64,
        Phase::Update => 1u64,
    };
    ((panel as u64) << 32) | (ph << 24) | (step as u64 & 0xff_ffff)
}

impl RecoveryStore {
    /// An empty store.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Record rank `owner`'s retained state for a step, written by the
    /// owner's incarnation `inc`; also advances `owner`'s completion
    /// frontier (a step is retained exactly when it completes). The
    /// entry is silently rejected — though the frontier still advances —
    /// when `inc` predates the last declared death of the rank (see
    /// [`RecoveryStore::drop_owner_dead`]).
    pub fn insert(
        &self,
        owner: usize,
        inc: u32,
        panel: usize,
        phase: Phase,
        step: usize,
        r: Retained,
    ) {
        {
            // Lock order everywhere: accept_from before entries.
            let gate = self.accept_from.lock().unwrap();
            let min = gate.get(&owner).copied().unwrap_or(0);
            if inc >= min {
                let sz = r.nbytes() as u64;
                let mut g = self.entries.lock().unwrap();
                if let Some(old) = g.insert((owner, panel, phase, step), r) {
                    self.bytes.fetch_sub(old.nbytes() as u64, Ordering::Relaxed);
                }
                let now = self.bytes.fetch_add(sz, Ordering::Relaxed) + sz;
                self.peak_bytes.fetch_max(now, Ordering::Relaxed);
            }
        }
        let idx = site_index(panel, phase, step);
        let mut p = self.progress.lock().unwrap();
        let e = p.entry(owner).or_insert(0);
        *e = (*e).max(idx);
    }

    /// Has `owner` (in any incarnation) ever completed the given step?
    /// Queried by a replaying replacement on a retained-state miss to
    /// distinguish "step never ran — re-enter it live" from "step ran
    /// and both redundancy copies are gone — unrecoverable".
    pub fn has_completed(&self, owner: usize, panel: usize, phase: Phase, step: usize) -> bool {
        self.progress
            .lock()
            .unwrap()
            .get(&owner)
            .is_some_and(|&max| max >= site_index(panel, phase, step))
    }

    /// Read rank `owner`'s retained state (a rebuilt rank asking its
    /// step-buddy for recovery data). Returns a clone; the caller charges
    /// the simulated transfer.
    pub fn get(&self, owner: usize, panel: usize, phase: Phase, step: usize) -> Option<Retained> {
        let out = self.entries.lock().unwrap().get(&(owner, panel, phase, step)).cloned();
        if out.is_some() {
            self.reads.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// A process died: its retained memory is lost with it.
    pub fn drop_owner(&self, owner: usize) {
        let mut g = self.entries.lock().unwrap();
        let dead: Vec<StepKey> = g.keys().filter(|k| k.0 == owner).cloned().collect();
        for k in dead {
            if let Some(old) = g.remove(&k) {
                self.bytes.fetch_sub(old.nbytes() as u64, Ordering::Relaxed);
            }
        }
    }

    /// Incarnation `dead_inc` of `owner` died: wipe its retained memory
    /// AND refuse any straggling insert from that (or an earlier)
    /// incarnation. Must be called *before* the death is made visible on
    /// the router, so no replacement can ever read memory that died.
    pub fn drop_owner_dead(&self, owner: usize, dead_inc: u32) {
        {
            let mut gate = self.accept_from.lock().unwrap();
            let e = gate.entry(owner).or_insert(0);
            *e = (*e).max(dead_inc + 1);
        }
        self.drop_owner(owner);
    }

    /// Drop retained state older than `panel` (panels complete =>
    /// redundancy for them is no longer needed once a global checkpoint
    /// of R's rows exists). Keeps memory bounded in long runs.
    pub fn retire_before(&self, panel: usize) {
        let mut g = self.entries.lock().unwrap();
        let dead: Vec<StepKey> = g.keys().filter(|k| k.1 < panel).cloned().collect();
        for k in dead {
            if let Some(old) = g.remove(&k) {
                self.bytes.fetch_sub(old.nbytes() as u64, Ordering::Relaxed);
            }
        }
    }

    /// Bytes currently retained.
    pub fn current_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// High-water mark of retained bytes.
    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes.load(Ordering::Relaxed)
    }

    /// Recovery reads served so far.
    pub fn reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }

    /// Number of retained step entries.
    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    /// True when nothing is retained.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Arbitrates rank revival so exactly one detector performs REBUILD.
#[derive(Default)]
pub struct RevivalGate {
    in_progress: Mutex<HashMap<usize, u32>>,
}

impl RevivalGate {
    /// A gate with no revivals in progress.
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Returns true if the caller won the right to revive `rank` for the
    /// given incarnation (i.e. it must perform the REBUILD).
    pub fn claim(&self, rank: usize, incarnation: u32) -> bool {
        let mut g = self.in_progress.lock().unwrap();
        match g.get(&rank) {
            Some(&inc) if inc >= incarnation => false,
            _ => {
                g.insert(rank, incarnation);
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn retained(bytes_rows: usize) -> Retained {
        Retained {
            buddy: 1,
            w: Arc::new(Matrix::zeros(bytes_rows, 4)),
            y1: Arc::new(Matrix::zeros(4, 4)),
            t: Arc::new(Matrix::zeros(4, 4)),
            r_merged: Arc::new(Matrix::zeros(4, 4)),
        }
    }

    #[test]
    fn insert_get_roundtrip() {
        let s = RecoveryStore::new();
        s.insert(2, 0, 0, Phase::Update, 1, retained(4));
        let r = s.get(2, 0, Phase::Update, 1).unwrap();
        assert_eq!(r.buddy, 1);
        assert!(s.get(2, 0, Phase::Update, 0).is_none());
        assert_eq!(s.reads(), 1);
    }

    #[test]
    fn byte_accounting_tracks_peak() {
        let s = RecoveryStore::new();
        s.insert(0, 0, 0, Phase::Tsqr, 0, retained(4));
        let b1 = s.current_bytes();
        assert!(b1 > 0);
        s.insert(0, 0, 1, Phase::Tsqr, 0, retained(4));
        let b2 = s.current_bytes();
        assert_eq!(b2, 2 * b1);
        s.retire_before(1);
        assert_eq!(s.current_bytes(), b1);
        assert_eq!(s.peak_bytes(), b2);
    }

    #[test]
    fn reinsert_replaces() {
        let s = RecoveryStore::new();
        s.insert(0, 0, 0, Phase::Update, 0, retained(4));
        s.insert(0, 0, 0, Phase::Update, 0, retained(8));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(0, 0, Phase::Update, 0).unwrap().w.rows(), 8);
    }

    #[test]
    fn progress_frontier_survives_drop_owner() {
        let s = RecoveryStore::new();
        s.insert(2, 0, 1, Phase::Tsqr, 1, retained(4));
        assert!(s.has_completed(2, 1, Phase::Tsqr, 1));
        assert!(s.has_completed(2, 0, Phase::Update, 3), "earlier sites covered");
        assert!(!s.has_completed(2, 1, Phase::Update, 0), "later sites not");
        assert!(!s.has_completed(3, 0, Phase::Tsqr, 0), "other ranks untouched");
        // Death wipes the retained data but NOT the runtime's knowledge
        // of how far the rank had progressed.
        s.drop_owner(2);
        assert!(s.get(2, 1, Phase::Tsqr, 1).is_none());
        assert!(s.has_completed(2, 1, Phase::Tsqr, 1));
    }

    #[test]
    fn dead_incarnation_inserts_rejected_but_progress_advances() {
        let s = RecoveryStore::new();
        s.insert(2, 0, 0, Phase::Tsqr, 0, retained(4));
        // Incarnation 0 dies; its memory is gone and stays gone even if a
        // straggling retain from the killed task lands afterwards.
        s.drop_owner_dead(2, 0);
        assert!(s.get(2, 0, Phase::Tsqr, 0).is_none());
        s.insert(2, 0, 0, Phase::Tsqr, 1, retained(4));
        assert!(s.get(2, 0, Phase::Tsqr, 1).is_none(), "stale insert resurrected");
        // ...but the runtime still learns the step completed pre-crash.
        assert!(s.has_completed(2, 0, Phase::Tsqr, 1));
        // The replacement (incarnation 1) retains normally.
        s.insert(2, 1, 0, Phase::Tsqr, 1, retained(4));
        assert!(s.get(2, 0, Phase::Tsqr, 1).is_some());
    }

    #[test]
    fn revival_gate_single_winner() {
        let g = RevivalGate::new();
        assert!(g.claim(3, 1));
        assert!(!g.claim(3, 1));
        // next incarnation can be claimed again
        assert!(g.claim(3, 2));
    }
}
