//! Buddy-held redundancy state (paper §III-C) and the recovery manager.
//!
//! At the end of every FT step, each member of a pair retains
//! `{W, T, C'_own, C'_peer, Y1}` — the paper's inventory that makes the
//! buddy's state recomputable from *one* process. [`RecoveryStore`]
//! models that per-process retained memory: entries are written by their
//! owning rank as it executes and read (with simulated communication
//! charged) by a rebuilt rank during replay.
//!
//! [`RecoveryManager`] arbitrates REBUILD: the first detector of a dead
//! rank revives it and spawns the replay task; concurrent detectors just
//! retry their operation once the revival is visible.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use std::sync::Mutex;

use crate::fault::Phase;
use crate::linalg::Matrix;

/// Key: (owning rank, panel, phase, tree step).
pub type StepKey = (usize, usize, Phase, usize);

/// What a rank retains after an FT exchange step (paper III-C).
#[derive(Clone, Debug)]
pub struct Retained {
    /// The buddy of this step.
    pub buddy: usize,
    /// `W = Tᵀ(C₀' + Y₁ᵀC₁')` (update steps; zero-sized for TSQR steps).
    pub w: Matrix,
    /// Bottom reflector block of the pair's merge.
    pub y1: Matrix,
    /// T factor of the pair's merge.
    pub t: Matrix,
    /// Merged R (TSQR steps; the buddy resumes from it directly).
    pub r_merged: Matrix,
}

impl Retained {
    pub fn nbytes(&self) -> usize {
        self.w.nbytes() + self.y1.nbytes() + self.t.nbytes() + self.r_merged.nbytes()
    }
}

/// All ranks' retained redundancy state. In a real deployment each entry
/// lives in its owner's memory; the shared map here stands in for the
/// buddy answering a recovery request, and every read is charged as a
/// simulated message by the caller.
#[derive(Default)]
pub struct RecoveryStore {
    entries: Mutex<HashMap<StepKey, Retained>>,
    /// Total bytes currently retained (the FT scheme's memory overhead,
    /// compared against diskless checkpointing in E7).
    bytes: AtomicU64,
    /// High-water mark of `bytes`.
    peak_bytes: AtomicU64,
    /// Recovery reads served.
    reads: AtomicU64,
}

impl RecoveryStore {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Record rank `owner`'s retained state for a step.
    pub fn insert(&self, owner: usize, panel: usize, phase: Phase, step: usize, r: Retained) {
        let sz = r.nbytes() as u64;
        let mut g = self.entries.lock().unwrap();
        if let Some(old) = g.insert((owner, panel, phase, step), r) {
            self.bytes.fetch_sub(old.nbytes() as u64, Ordering::Relaxed);
        }
        let now = self.bytes.fetch_add(sz, Ordering::Relaxed) + sz;
        self.peak_bytes.fetch_max(now, Ordering::Relaxed);
    }

    /// Read rank `owner`'s retained state (a rebuilt rank asking its
    /// step-buddy for recovery data). Returns a clone; the caller charges
    /// the simulated transfer.
    pub fn get(&self, owner: usize, panel: usize, phase: Phase, step: usize) -> Option<Retained> {
        let out = self.entries.lock().unwrap().get(&(owner, panel, phase, step)).cloned();
        if out.is_some() {
            self.reads.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// A process died: its retained memory is lost with it.
    pub fn drop_owner(&self, owner: usize) {
        let mut g = self.entries.lock().unwrap();
        let dead: Vec<StepKey> = g.keys().filter(|k| k.0 == owner).cloned().collect();
        for k in dead {
            if let Some(old) = g.remove(&k) {
                self.bytes.fetch_sub(old.nbytes() as u64, Ordering::Relaxed);
            }
        }
    }

    /// Drop retained state older than `panel` (panels complete =>
    /// redundancy for them is no longer needed once a global checkpoint
    /// of R's rows exists). Keeps memory bounded in long runs.
    pub fn retire_before(&self, panel: usize) {
        let mut g = self.entries.lock().unwrap();
        let dead: Vec<StepKey> = g.keys().filter(|k| k.1 < panel).cloned().collect();
        for k in dead {
            if let Some(old) = g.remove(&k) {
                self.bytes.fetch_sub(old.nbytes() as u64, Ordering::Relaxed);
            }
        }
    }

    pub fn current_bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes.load(Ordering::Relaxed)
    }

    pub fn reads(&self) -> u64 {
        self.reads.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Arbitrates rank revival so exactly one detector performs REBUILD.
#[derive(Default)]
pub struct RevivalGate {
    in_progress: Mutex<HashMap<usize, u32>>,
}

impl RevivalGate {
    pub fn new() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Returns true if the caller won the right to revive `rank` for the
    /// given incarnation (i.e. it must perform the REBUILD).
    pub fn claim(&self, rank: usize, incarnation: u32) -> bool {
        let mut g = self.in_progress.lock().unwrap();
        match g.get(&rank) {
            Some(&inc) if inc >= incarnation => false,
            _ => {
                g.insert(rank, incarnation);
                true
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn retained(bytes_rows: usize) -> Retained {
        Retained {
            buddy: 1,
            w: Matrix::zeros(bytes_rows, 4),
            y1: Matrix::zeros(4, 4),
            t: Matrix::zeros(4, 4),
            r_merged: Matrix::zeros(4, 4),
        }
    }

    #[test]
    fn insert_get_roundtrip() {
        let s = RecoveryStore::new();
        s.insert(2, 0, Phase::Update, 1, retained(4));
        let r = s.get(2, 0, Phase::Update, 1).unwrap();
        assert_eq!(r.buddy, 1);
        assert!(s.get(2, 0, Phase::Update, 0).is_none());
        assert_eq!(s.reads(), 1);
    }

    #[test]
    fn byte_accounting_tracks_peak() {
        let s = RecoveryStore::new();
        s.insert(0, 0, Phase::Tsqr, 0, retained(4));
        let b1 = s.current_bytes();
        assert!(b1 > 0);
        s.insert(0, 1, Phase::Tsqr, 0, retained(4));
        let b2 = s.current_bytes();
        assert_eq!(b2, 2 * b1);
        s.retire_before(1);
        assert_eq!(s.current_bytes(), b1);
        assert_eq!(s.peak_bytes(), b2);
    }

    #[test]
    fn reinsert_replaces() {
        let s = RecoveryStore::new();
        s.insert(0, 0, Phase::Update, 0, retained(4));
        s.insert(0, 0, Phase::Update, 0, retained(8));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(0, 0, Phase::Update, 0).unwrap().w.rows(), 8);
    }

    #[test]
    fn revival_gate_single_winner() {
        let g = RevivalGate::new();
        assert!(g.claim(3, 1));
        assert!(!g.claim(3, 1));
        // next incarnation can be claimed again
        assert!(g.claim(3, 2));
    }
}
