//! The paper's coordination layer: FT-TSQR panel factorization, the
//! fault-tolerant trailing-matrix update tree (Algorithms 1 & 2), the
//! CAQR panel driver, and the single-buddy recovery protocol.
//!
//! Module map (paper section → code):
//! * §III-A CAQR panel/update organization → [`caqr`], [`panel`], with
//!   the 2-D block-cyclic process-grid layout in [`grid`]
//! * §III-B FT-TSQR all-exchange reduction  → [`tsqr`] (standalone) and
//!   the TSQR phase inside [`caqr`]
//! * §III-C Algorithms 1 & 2 + recovery     → [`caqr`], [`recovery`],
//!   [`store`]
//! * tree shapes shared by all of the above → [`tree`]
//! * row-broadcast collective schedules     → [`collective`]

pub mod caqr;
pub mod collective;
pub mod grid;
pub mod panel;
pub mod recovery;
pub mod store;
pub mod tree;
pub mod tsqr;

pub use caqr::{run_caqr, run_caqr_matrix, run_caqr_simple, CaqrOutcome, Shared};
pub use collective::BcastSched;
pub use grid::Grid;
pub use panel::{geometry, PanelGeom};
pub use store::{RecoveryStore, Retained, RevivalGate};
pub use tsqr::{run_tsqr, run_tsqr_pooled, TsqrMode, TsqrOutcome};
