//! Tree topology for the panel reduction and trailing-update phases.
//!
//! Participants of panel `k` are ranks `owner..P`, relabeled to indices
//! `0..q`. Two pairings are used (paper §III):
//!
//! * **Reduce tree** (plain TSQR / both update variants): at step `s`,
//!   index `i` with `i % 2^(s+1) == 0` is the *upper* member and merges
//!   with `j = i + 2^s` (skipped when `j >= q` — the odd node is promoted
//!   unchanged). The upper member continues, the lower leaves.
//! * **All-exchange (hypercube) pairing** (FT-TSQR, §III-B / Fig 2):
//!   at step `s` *every* index pairs with `i ^ 2^s` (skipped when the
//!   buddy is `>= q`), both compute the merge, and the number of holders
//!   of each intermediate R doubles per step.
//!
//! Correctness of the skip rule: an index that is a multiple of `2^s`
//! always holds the complete merge of its sub-block `[i, i + 2^s) ∩ [0, q)`
//! after step `s-1`, so the root (index 0) always accumulates every leaf.

/// Role of an index in a pairing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Upper member: holds the top of the stacked pair, continues.
    Upper,
    /// Lower member: holds the bottom, leaves the reduce tree after
    /// this step.
    Lower,
    /// Not paired this step (odd node promoted / buddy out of range).
    Idle,
}

/// Number of tree steps for `q` participants: `ceil(log2(q))`.
pub fn steps(q: usize) -> usize {
    assert!(q >= 1);
    (usize::BITS - (q - 1).leading_zeros()) as usize
}

/// Reduce-tree pairing of index `i` at step `s` among `q` participants.
/// Returns `(role, buddy)`; buddy is meaningful unless `Idle`.
pub fn reduce_pair(i: usize, s: usize, q: usize) -> (Role, usize) {
    debug_assert!(i < q);
    let span = 1usize << s;
    let block = span << 1;
    if i % block == 0 {
        let j = i + span;
        if j < q {
            (Role::Upper, j)
        } else {
            (Role::Idle, i)
        }
    } else if i % block == span {
        (Role::Lower, i - span)
    } else {
        // Left the tree at an earlier step.
        (Role::Idle, i)
    }
}

/// True if index `i` is still an active reduce-tree node entering step
/// `s` (i.e. it has not been a `Lower` at any earlier step).
pub fn reduce_active(i: usize, s: usize) -> bool {
    i % (1usize << s) == 0
}

/// Hypercube (all-exchange) buddy of `i` at step `s`; `None` when the
/// buddy index falls outside `[0, q)`.
pub fn exchange_pair(i: usize, s: usize, q: usize) -> Option<usize> {
    debug_assert!(i < q);
    let j = i ^ (1usize << s);
    (j < q).then_some(j)
}

/// Stack order for a pair: the smaller index owns the globally-upper
/// rows, so it is the top (`R0`/`C0`) of the stacked merge.
pub fn is_top(i: usize, j: usize) -> bool {
    i < j
}

/// Redundancy of the intermediate R after step `s` of the FT all-exchange
/// tree with `q` a power of two: `2^(s+1)` (paper Fig 2).
pub fn expected_redundancy(s: usize) -> usize {
    1usize << (s + 1)
}

/// The set of reduce-tree steps in which index `i` participates (as
/// Upper or Lower) among `q` participants — the replay schedule a
/// rebuilt rank walks during recovery.
pub fn participation(i: usize, q: usize) -> Vec<(usize, Role, usize)> {
    let mut out = Vec::new();
    for s in 0..steps(q) {
        if !reduce_active(i, s) {
            break;
        }
        let (role, buddy) = reduce_pair(i, s, q);
        match role {
            Role::Idle => continue,
            Role::Upper => out.push((s, Role::Upper, buddy)),
            Role::Lower => {
                out.push((s, Role::Lower, buddy));
                break;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steps_counts() {
        assert_eq!(steps(1), 0);
        assert_eq!(steps(2), 1);
        assert_eq!(steps(3), 2);
        assert_eq!(steps(4), 2);
        assert_eq!(steps(5), 3);
        assert_eq!(steps(8), 3);
    }

    #[test]
    fn reduce_tree_four() {
        // step 0: (0,1), (2,3); step 1: (0,2)
        assert_eq!(reduce_pair(0, 0, 4), (Role::Upper, 1));
        assert_eq!(reduce_pair(1, 0, 4), (Role::Lower, 0));
        assert_eq!(reduce_pair(2, 0, 4), (Role::Upper, 3));
        assert_eq!(reduce_pair(3, 0, 4), (Role::Lower, 2));
        assert_eq!(reduce_pair(0, 1, 4), (Role::Upper, 2));
        assert_eq!(reduce_pair(2, 1, 4), (Role::Lower, 0));
        assert_eq!(reduce_pair(1, 1, 4).0, Role::Idle);
    }

    #[test]
    fn reduce_tree_odd_promotes() {
        // q = 5: step 0: (0,1),(2,3), 4 idle; step 1: (0,2), 4 idle;
        // step 2: (0,4).
        assert_eq!(reduce_pair(4, 0, 5).0, Role::Idle);
        assert_eq!(reduce_pair(4, 1, 5).0, Role::Idle);
        assert_eq!(reduce_pair(0, 2, 5), (Role::Upper, 4));
        assert_eq!(reduce_pair(4, 2, 5), (Role::Lower, 0));
    }

    #[test]
    fn every_nonroot_leaves_exactly_once() {
        for q in 1..=33 {
            for i in 1..q {
                let lowers: Vec<_> = participation(i, q)
                    .into_iter()
                    .filter(|(_, r, _)| *r == Role::Lower)
                    .collect();
                assert_eq!(lowers.len(), 1, "i={i} q={q}");
            }
            // root never leaves
            assert!(participation(0, q)
                .iter()
                .all(|(_, r, _)| *r == Role::Upper));
        }
    }

    #[test]
    fn reduce_pairs_are_consistent() {
        // If i sees (Upper, j) then j must see (Lower, i) at the same step.
        for q in 2..=17 {
            for s in 0..steps(q) {
                for i in 0..q {
                    let (role, j) = reduce_pair(i, s, q);
                    match role {
                        Role::Upper => assert_eq!(reduce_pair(j, s, q), (Role::Lower, i)),
                        Role::Lower => assert_eq!(reduce_pair(j, s, q), (Role::Upper, i)),
                        Role::Idle => {}
                    }
                }
            }
        }
    }

    #[test]
    fn exchange_pairing_is_involution() {
        for q in 2..=16 {
            for s in 0..steps(q) {
                for i in 0..q {
                    if let Some(j) = exchange_pair(i, s, q) {
                        assert_eq!(exchange_pair(j, s, q), Some(i));
                        assert_ne!(i, j);
                    }
                }
            }
        }
    }

    #[test]
    fn exchange_covers_reduce_pairs() {
        // Every reduce-tree pair is also an exchange pair (the FT tree is
        // a superset), so FT members always hold the merge factors the
        // update tree needs.
        for q in 2..=16 {
            for s in 0..steps(q) {
                for i in 0..q {
                    if let (Role::Upper, j) = reduce_pair(i, s, q) {
                        assert_eq!(exchange_pair(i, s, q), Some(j), "i={i} s={s} q={q}");
                    }
                }
            }
        }
    }

    #[test]
    fn redundancy_doubles() {
        assert_eq!(expected_redundancy(0), 2);
        assert_eq!(expected_redundancy(1), 4);
        assert_eq!(expected_redundancy(2), 8);
    }

    #[test]
    fn participation_examples() {
        // q=8, i=5: step0 Lower with 4.
        assert_eq!(participation(5, 8), vec![(0, Role::Lower, 4)]);
        // q=8, i=4: step0 Upper with 5, step1 Lower... 4 % 4 == 0 so
        // step1: Upper? 4 % 4 == 0 -> upper with 6; step2: 4 % 8 == 4 ->
        // lower with 0.
        assert_eq!(
            participation(4, 8),
            vec![(0, Role::Upper, 5), (1, Role::Upper, 6), (2, Role::Lower, 0)]
        );
    }
}
