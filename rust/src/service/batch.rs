//! Batched TSQR lane: k same-shape tall-skinny jobs packed into one tree
//! sweep.
//!
//! Real workloads (the Demmel et al. CAQR setting, arXiv:0809.2407) are
//! dominated by many small/medium tall-skinny panels arriving
//! concurrently. Factorizing each with its own P-rank tree pays the full
//! per-step message budget k times; but the tree *shape* depends only on
//! `(rows, block, procs, mode)`, so jobs with identical shapes can ride
//! the same sweep: each rank holds one leaf block per job, and each tree
//! step exchanges a single [`MsgData::Mats`] bundle carrying every job's
//! intermediate R. Message/exchange *counts* are paid once per batch;
//! bytes and flops still scale with k.
//!
//! Numerics are untouched: per job, the leaf factorization and the merge
//! sequence (pairings, top/bottom stacking order) are exactly those of
//! the standalone driver ([`crate::coordinator::tsqr`]), so every job's
//! final R is **bitwise identical** to running that job alone — packing
//! changes who shares an envelope, never what gets merged.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::backend::Backend;
use crate::coordinator::tree::{self, Role};
use crate::coordinator::TsqrMode;
use crate::fault::FaultPlan;
use crate::ft::Fail;
use crate::linalg::Matrix;
use crate::sim::{
    CostModel, ExchangeOp, MsgData, RankCtx, RankTask, Spawner, Tag, TagKind, TaskPoll, World,
};

/// rank -> that rank's final R per job (index parallel to the batch).
pub(crate) type BatchFinals = Arc<Mutex<HashMap<usize, Vec<Arc<Matrix>>>>>;

/// Build the world + rank tasks for one batched sweep over `inputs`
/// (one stacked `rows x b` matrix per job; all shapes must match).
#[allow(clippy::type_complexity)]
pub(crate) fn prepare(
    inputs: &[Matrix],
    procs: usize,
    mode: TsqrMode,
    backend: Arc<Backend>,
    cost: CostModel,
) -> Result<(Arc<World>, Vec<(usize, Box<dyn RankTask>)>, BatchFinals)> {
    anyhow::ensure!(!inputs.is_empty(), "batch needs at least one job");
    let (rows, b) = inputs[0].shape();
    for (j, m) in inputs.iter().enumerate() {
        anyhow::ensure!(
            m.shape() == (rows, b),
            "batch job {j} shape {:?} does not match the lane shape ({rows}, {b})",
            m.shape()
        );
    }
    crate::coordinator::tsqr::validate_shape(rows, b, procs)?;
    let m_local = rows / procs;

    let world = World::new(procs, cost, FaultPlan::none());
    let finals: BatchFinals = Arc::new(Mutex::new(HashMap::new()));
    let tasks: Vec<(usize, Box<dyn RankTask>)> = (0..procs)
        .map(|r| {
            let task = BatchTsqrTask {
                mode,
                backend: backend.clone(),
                q: procs,
                b,
                m_local,
                blocks: inputs.iter().map(|a| a.block(r * m_local, 0, m_local, b)).collect(),
                rs: Vec::new(),
                finals: finals.clone(),
                s: 0,
                wait: Wait::Leaf,
            };
            (r, Box::new(task) as Box<dyn RankTask>)
        })
        .collect();
    Ok((world, tasks, finals))
}

/// Where one batched rank task is parked (or about to run next).
enum Wait {
    /// Per-job leaf factorizations not done yet.
    Leaf,
    /// Ready to enter tree step `s`.
    Enter,
    /// FT bundle exchange in flight.
    Exch(ExchangeOp),
    /// Plain upper member waiting for the lower member's bundle.
    Recv { buddy: usize, tag: Tag },
}

/// One rank's resumable body for the whole batch: the per-job state is a
/// vector of intermediate R factors advanced in lockstep through the
/// shared tree.
struct BatchTsqrTask {
    mode: TsqrMode,
    backend: Arc<Backend>,
    q: usize,
    b: usize,
    m_local: usize,
    /// One leaf block per job; drained after the leaf factorizations.
    blocks: Vec<Matrix>,
    /// Current intermediate R per job.
    rs: Vec<Arc<Matrix>>,
    finals: BatchFinals,
    s: usize,
    wait: Wait,
}

impl BatchTsqrTask {
    /// Merge the peer's bundle into ours, one job at a time, preserving
    /// the standalone driver's stacking order.
    fn merge_all(
        &mut self,
        ctx: &mut RankCtx,
        peer: Vec<Arc<Matrix>>,
        self_is_top: bool,
    ) -> Result<(), Fail> {
        assert_eq!(
            peer.len(),
            self.rs.len(),
            "batch bundle size mismatch (peer {} vs local {})",
            peer.len(),
            self.rs.len()
        );
        for (j, pr) in peer.iter().enumerate() {
            let mf = {
                let mine = self.rs[j].as_ref();
                let (rt, rb) = if self_is_top { (mine, pr.as_ref()) } else { (pr.as_ref(), mine) };
                self.backend.tsqr_merge(rt, rb).map_err(|_| Fail::WorldGone)?
            };
            ctx.compute(crate::backend::flops::tsqr_merge(self.b));
            self.rs[j] = Arc::new(mf.r);
        }
        Ok(())
    }

    fn drive(&mut self, ctx: &mut RankCtx) -> Result<bool, Fail> {
        loop {
            match std::mem::replace(&mut self.wait, Wait::Enter) {
                Wait::Leaf => {
                    for block in &self.blocks {
                        let f = self.backend.panel_qr(block).map_err(|_| Fail::WorldGone)?;
                        ctx.compute(crate::backend::flops::panel_qr(self.m_local, self.b));
                        self.rs.push(Arc::new(f.r));
                    }
                    self.blocks = Vec::new(); // inputs no longer needed
                    self.s = 0;
                }
                Wait::Enter => {
                    if self.s == tree::steps(self.q) {
                        self.finals.lock().unwrap().insert(ctx.rank, self.rs.clone());
                        return Ok(true);
                    }
                    let s = self.s;
                    let idx = ctx.rank;
                    let tag = Tag::new(TagKind::TsqrR, 0, s);
                    match self.mode {
                        TsqrMode::FaultTolerant => {
                            if let Some(bidx) = tree::exchange_pair(idx, s, self.q) {
                                let op = ctx.begin_exchange(
                                    bidx,
                                    tag,
                                    MsgData::Mats(self.rs.clone()),
                                )?;
                                self.wait = Wait::Exch(op);
                            } else {
                                self.s += 1;
                            }
                        }
                        TsqrMode::Plain => {
                            if tree::reduce_active(idx, s) {
                                let (role, bidx) = tree::reduce_pair(idx, s, self.q);
                                match role {
                                    Role::Idle => self.s += 1,
                                    Role::Upper => self.wait = Wait::Recv { buddy: bidx, tag },
                                    Role::Lower => {
                                        ctx.send(bidx, tag, MsgData::Mats(self.rs.clone()))?;
                                        self.s += 1;
                                    }
                                }
                            } else {
                                self.s += 1;
                            }
                        }
                    }
                }
                Wait::Exch(mut op) => match ctx.poll_exchange(&mut op)? {
                    None => {
                        self.wait = Wait::Exch(op);
                        return Ok(false);
                    }
                    Some(d) => {
                        let bidx = op.peer();
                        let top = tree::is_top(ctx.rank, bidx);
                        self.merge_all(ctx, d.into_mats(), top)?;
                        self.s += 1;
                    }
                },
                Wait::Recv { buddy, tag } => match ctx.try_recv(buddy, tag)? {
                    None => {
                        self.wait = Wait::Recv { buddy, tag };
                        return Ok(false);
                    }
                    Some(d) => {
                        // Plain-tree upper member: our rows stack on top.
                        self.merge_all(ctx, d.into_mats(), true)?;
                        self.s += 1;
                    }
                },
            }
        }
    }
}

impl RankTask for BatchTsqrTask {
    fn poll(&mut self, ctx: &mut RankCtx, _sp: &Spawner) -> TaskPoll {
        match self.drive(ctx) {
            Ok(true) => TaskPoll::Ready(Ok(())),
            Ok(false) => TaskPoll::Pending,
            Err(e) => TaskPoll::Ready(Err(e)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{run_tsqr_pooled, TsqrMode};
    use crate::linalg::gram_residual;
    use crate::sim::Pool;

    fn run_batch(
        inputs: &[Matrix],
        procs: usize,
        mode: TsqrMode,
    ) -> (Vec<Matrix>, crate::metrics::Report) {
        let (world, tasks, finals) =
            prepare(inputs, procs, mode, Backend::native(), CostModel::default()).unwrap();
        let pool = Pool::new(2);
        let results = pool.run(&world, tasks);
        assert!(results.iter().all(|(_, r)| r.is_ok()), "{results:?}");
        let finals = finals.lock().unwrap();
        let root = finals[&0].iter().map(|r| r.as_ref().clone()).collect();
        (root, world.metrics.snapshot())
    }

    #[test]
    fn batched_jobs_match_solo_bitwise() {
        let procs = 8;
        let inputs: Vec<Matrix> =
            (0..4).map(|j| Matrix::randn(procs * 8, 8, 100 + j)).collect();
        for mode in [TsqrMode::FaultTolerant, TsqrMode::Plain] {
            let (rs, _) = run_batch(&inputs, procs, mode);
            for (j, a) in inputs.iter().enumerate() {
                let solo = run_tsqr_pooled(
                    a,
                    procs,
                    mode,
                    Backend::native(),
                    CostModel::default(),
                    2,
                )
                .unwrap();
                assert_eq!(rs[j], solo.r, "job {j} mode {mode:?}");
                assert!(gram_residual(a, &rs[j]) < 1e-3);
            }
        }
    }

    #[test]
    fn batching_amortizes_message_counts() {
        let procs = 8;
        let k = 6;
        let inputs: Vec<Matrix> =
            (0..k).map(|j| Matrix::randn(procs * 8, 8, 200 + j)).collect();
        let (_, batched) = run_batch(&inputs, procs, TsqrMode::FaultTolerant);
        let solo = run_tsqr_pooled(
            &inputs[0],
            procs,
            TsqrMode::FaultTolerant,
            Backend::native(),
            CostModel::default(),
            2,
        )
        .unwrap();
        // One sweep's worth of exchanges regardless of k...
        assert_eq!(batched.exchanges, solo.report.exchanges);
        // ...while the bytes scale with the batch width.
        assert_eq!(batched.bytes, solo.report.bytes * k as u64);
    }

    #[test]
    fn mismatched_shapes_rejected() {
        let a = Matrix::randn(64, 8, 1);
        let b = Matrix::randn(64, 4, 2);
        assert!(prepare(&[a, b], 8, TsqrMode::FaultTolerant, Backend::native(), CostModel::default())
            .is_err());
    }
}
