//! Multi-tenant factorization service: many concurrent (FT-)CAQR/TSQR
//! jobs multiplexed over one persistent scheduler pool.
//!
//! The one-shot drivers (`run_caqr`, `run_tsqr`) build and tear down a
//! private worker pool per call, so a process could only ever run one
//! factorization at a time. The [`Service`] instead owns a single
//! long-lived [`Pool`] and treats each factorization as a *job*:
//!
//! 1. **Submit** — [`Service::submit`] validates a [`JobSpec`], enqueues
//!    it and returns an async [`JobHandle`] immediately.
//! 2. **Admit** — the [`JobQueue`] releases jobs FIFO under an admission
//!    cap on *in-flight simulated ranks* (`max_inflight_ranks`), so a
//!    burst of large jobs cannot oversubscribe memory; a job wider than
//!    the cap is still admitted when the service is idle.
//! 3. **Run** — the job's world + rank tasks are submitted into the
//!    shared pool, interleaving with every other tenant's tasks.
//!    Same-shape tall-skinny TSQR jobs can be packed into one batched
//!    tree sweep ([`batch`]) that pays the per-step message count once.
//! 4. **Complete** — the job finalizes on a pool worker and its
//!    [`JobOutcome`] is delivered through the handle; per-job metrics are
//!    folded into the service totals and the queue is pumped again.
//!
//! **Isolation.** Every job gets its own [`World`] (mailboxes, router,
//! metrics, fault plan, recovery store) and its own compute backend, and
//! its input matrix and fault schedule are derived from the job's own
//! seed/spec — so a job's factors are **bitwise identical** no matter
//! how its tasks interleave with neighbors, and a job poisoned by
//! [`Fail::Unrecoverable`] (both copies of a redundancy pair lost) fails
//! *individually* while every other tenant keeps running. A job that
//! deadlocks is failed with [`Fail::Stalled`] by the pool's per-job
//! stall detector, never wedging the service.

pub mod batch;

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::backend::Backend;
use crate::config::RunConfig;
use crate::coordinator::caqr::CaqrJob;
use crate::coordinator::{CaqrOutcome, TsqrMode};
use crate::fault::{self, FaultPlan, ScheduledKill};
use crate::ft::Fail;
use crate::linalg::Matrix;
use crate::metrics::Report;
use crate::sim::{default_workers, CostModel, Pool};
use crate::trace::Trace;

/// Service-wide knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Worker threads in the shared pool (0 = machine core count).
    pub workers: usize,
    /// Admission cap: total simulated ranks in flight (0 = unbounded).
    /// A single job wider than the cap still runs — alone.
    pub max_inflight_ranks: usize,
    /// Max same-shape TSQR jobs packed into one batched sweep
    /// (<= 1 disables batching).
    pub batch_max: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { workers: 0, max_inflight_ranks: 256, batch_max: 1 }
    }
}

/// One job's description. Matrices are generated from the spec's seed at
/// launch time, so a spec fully determines the job's inputs and faults —
/// the bitwise-determinism contract rests on this.
#[derive(Clone, Debug)]
pub enum JobSpec {
    /// A full (FT-)CAQR factorization, with optional injected kills.
    Caqr {
        /// The run description (matrix shape, procs, algorithm, seed...).
        cfg: RunConfig,
        /// Failure schedule for this job only.
        kills: Vec<ScheduledKill>,
    },
    /// A standalone tall-skinny TSQR sweep (batchable when same-shape).
    Tsqr {
        /// Stacked panel rows.
        rows: usize,
        /// Panel width.
        block: usize,
        /// Simulated ranks.
        procs: usize,
        /// Plain binary tree vs FT all-exchange.
        mode: TsqrMode,
        /// Input-matrix RNG seed.
        seed: u64,
    },
}

impl JobSpec {
    /// Simulated ranks this job occupies while in flight.
    pub fn procs(&self) -> usize {
        match self {
            JobSpec::Caqr { cfg, .. } => cfg.procs,
            JobSpec::Tsqr { procs, .. } => *procs,
        }
    }

    fn validate(&self) -> Result<()> {
        match self {
            JobSpec::Caqr { cfg, .. } => cfg.validate(),
            JobSpec::Tsqr { rows, block, procs, .. } => {
                crate::coordinator::tsqr::validate_shape(*rows, *block, *procs)
            }
        }
    }

    /// Batch key: jobs sharing it can ride one tree sweep.
    fn lane(&self) -> Option<(usize, usize, usize, TsqrMode)> {
        match self {
            JobSpec::Tsqr { rows, block, procs, mode, .. } => {
                Some((*rows, *block, *procs, *mode))
            }
            JobSpec::Caqr { .. } => None,
        }
    }
}

/// Successful job payload.
#[derive(Debug)]
pub enum JobOutput {
    /// Full CAQR outcome (factors, residual, per-job report).
    Caqr(CaqrOutcome),
    /// Standalone TSQR outcome.
    Tsqr {
        /// Final R factor (bitwise identical to a solo run of the job).
        r: Matrix,
        /// How many jobs shared the sweep (1 = unbatched).
        batch_size: usize,
    },
}

/// Why a job failed. `fail` is `Some(Fail::Unrecoverable { .. })` for a
/// poisoned job — both copies of a redundancy pair were lost and the
/// paper's single-buddy protocol cannot reconstruct the state.
#[derive(Clone, Debug)]
pub struct JobError {
    /// The simulated failure condition, when one poisoned the job.
    pub fail: Option<Fail>,
    /// Human-readable description.
    pub message: String,
}

/// Delivered once per job through its [`JobHandle`].
#[derive(Debug)]
pub struct JobOutcome {
    /// The id [`Service::submit`] returned.
    pub id: u64,
    /// The factors, or the per-job failure (neighbors are unaffected).
    pub output: Result<JobOutput, JobError>,
    /// This job's own metrics (its world's counters; batched TSQR jobs
    /// share their sweep's report).
    pub report: Report,
    /// Seconds spent queued before admission.
    pub queued_s: f64,
    /// Seconds from admission to completion.
    pub run_s: f64,
}

impl JobOutcome {
    /// True when the job was poisoned by lost redundancy.
    pub fn unrecoverable(&self) -> bool {
        matches!(
            &self.output,
            Err(JobError { fail: Some(Fail::Unrecoverable { .. }), .. })
        )
    }
}

/// Async result handle returned by [`Service::submit`].
pub struct JobHandle {
    id: u64,
    rx: Receiver<JobOutcome>,
}

impl JobHandle {
    /// The job's service-assigned id.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the job completes. In-flight jobs finish even while
    /// the service is being dropped; jobs still *pending admission* when
    /// the service is dropped are cancelled, and waiting on one of those
    /// panics — wait on every handle before dropping the service.
    pub fn wait(self) -> JobOutcome {
        self.rx.recv().expect("job was cancelled: service dropped before it was admitted")
    }

    /// Non-blocking poll: the outcome if the job already completed.
    pub fn try_wait(&self) -> Option<JobOutcome> {
        self.rx.try_recv().ok()
    }
}

struct Pending {
    id: u64,
    spec: JobSpec,
    tx: Sender<JobOutcome>,
    enqueued: Instant,
}

/// Admission-control state: FIFO pending queue + in-flight accounting.
pub struct JobQueue {
    pending: VecDeque<Pending>,
    inflight_ranks: usize,
    inflight_jobs: usize,
    next_id: u64,
}

impl JobQueue {
    fn new() -> Self {
        Self { pending: VecDeque::new(), inflight_ranks: 0, inflight_jobs: 0, next_id: 0 }
    }

    /// Would a job of `procs` simulated ranks be admitted now under
    /// `cap`? An idle service admits anything (a job wider than the cap
    /// must not starve); otherwise the rank budget is enforced.
    fn admits(&self, procs: usize, cap: usize) -> bool {
        self.inflight_jobs == 0 || cap == 0 || self.inflight_ranks + procs <= cap
    }
}

/// Point-in-time queue observability snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QueueStats {
    /// Jobs waiting for admission.
    pub pending: usize,
    /// Jobs currently running on the pool.
    pub inflight_jobs: usize,
    /// Simulated ranks currently in flight.
    pub inflight_ranks: usize,
}

#[derive(Default)]
struct Totals {
    jobs_ok: u64,
    jobs_failed: u64,
    report: Report,
}

/// Aggregated service counters (sum over completed jobs).
#[derive(Clone, Debug, Default)]
pub struct ServiceTotals {
    /// Jobs that completed successfully.
    pub jobs_ok: u64,
    /// Jobs that failed (poisoned, stalled, invalid).
    pub jobs_failed: u64,
    /// Summed per-job reports (critical path = max over jobs).
    pub report: Report,
}

/// The multi-tenant factorization service. See the module docs for the
/// job lifecycle. Cloneable handles are not needed — submit from one
/// owner, wait on the [`JobHandle`]s anywhere.
pub struct Service {
    inner: Arc<Inner>,
}

struct Inner {
    cfg: ServiceConfig,
    pool: Pool,
    q: Mutex<JobQueue>,
    totals: Mutex<Totals>,
}

/// What the pump decided to start (admission already accounted).
enum Admitted {
    Caqr(Pending),
    /// 1..=batch_max same-lane TSQR jobs sharing one sweep.
    TsqrLane(Vec<Pending>),
}

impl Service {
    /// Start a service: spins up the persistent pool immediately.
    pub fn new(cfg: ServiceConfig) -> Self {
        let workers =
            if cfg.workers > 0 { cfg.workers } else { default_workers(usize::MAX) };
        let inner = Inner {
            cfg,
            pool: Pool::new(workers),
            q: Mutex::new(JobQueue::new()),
            totals: Mutex::new(Totals::default()),
        };
        Service { inner: Arc::new(inner) }
    }

    /// The shared pool's worker-thread count.
    pub fn workers(&self) -> usize {
        self.inner.pool.workers()
    }

    /// Validate and enqueue a job; returns its async handle. The job
    /// starts as soon as admission control allows.
    pub fn submit(&self, spec: JobSpec) -> Result<JobHandle> {
        spec.validate()?;
        let (tx, rx) = channel();
        let id = {
            let mut q = self.inner.q.lock().unwrap();
            let id = q.next_id;
            q.next_id += 1;
            q.pending.push_back(Pending { id, spec, tx, enqueued: Instant::now() });
            id
        };
        Inner::pump(&self.inner);
        Ok(JobHandle { id, rx })
    }

    /// Enqueue a burst of jobs under one queue lock before the first
    /// admission pump runs — this is what lets the batched TSQR lane see
    /// the whole burst at once instead of launching the head solo.
    /// Handles are returned in submission order.
    pub fn submit_all(&self, specs: Vec<JobSpec>) -> Result<Vec<JobHandle>> {
        for s in &specs {
            s.validate()?;
        }
        let handles = {
            let mut q = self.inner.q.lock().unwrap();
            specs
                .into_iter()
                .map(|spec| {
                    let (tx, rx) = channel();
                    let id = q.next_id;
                    q.next_id += 1;
                    q.pending.push_back(Pending { id, spec, tx, enqueued: Instant::now() });
                    JobHandle { id, rx }
                })
                .collect()
        };
        Inner::pump(&self.inner);
        Ok(handles)
    }

    /// Aggregated counters over all completed jobs.
    pub fn totals(&self) -> ServiceTotals {
        let t = self.inner.totals.lock().unwrap();
        ServiceTotals {
            jobs_ok: t.jobs_ok,
            jobs_failed: t.jobs_failed,
            report: t.report.clone(),
        }
    }

    /// Current queue/in-flight occupancy.
    pub fn queue_stats(&self) -> QueueStats {
        let q = self.inner.q.lock().unwrap();
        QueueStats {
            pending: q.pending.len(),
            inflight_jobs: q.inflight_jobs,
            inflight_ranks: q.inflight_ranks,
        }
    }

    /// Prometheus text-exposition snapshot of the whole service: the
    /// aggregated per-job report (labelled `scope="service"`) plus job
    /// counters and queue-occupancy gauges. `ftcaqr serve` rewrites its
    /// `--metrics-out` file from this after every completed job, so a
    /// scrape-by-file integration always sees a consistent snapshot.
    pub fn metrics_text(&self) -> String {
        use crate::metrics::prom::{fmt_labels, render, sample};
        let t = self.totals();
        let qs = self.queue_stats();
        let l = fmt_labels(&[("scope", "service")]);
        let mut out = render(&t.report, &[("scope", "service")]);
        out.push_str(&sample(
            "ftcaqr_jobs_ok_total",
            "counter",
            "Jobs completed successfully.",
            &l,
            &t.jobs_ok.to_string(),
        ));
        out.push_str(&sample(
            "ftcaqr_jobs_failed_total",
            "counter",
            "Jobs that failed (poisoned, stalled, invalid).",
            &l,
            &t.jobs_failed.to_string(),
        ));
        out.push_str(&sample(
            "ftcaqr_queue_pending",
            "gauge",
            "Jobs waiting for admission.",
            &l,
            &qs.pending.to_string(),
        ));
        out.push_str(&sample(
            "ftcaqr_inflight_jobs",
            "gauge",
            "Jobs currently running on the pool.",
            &l,
            &qs.inflight_jobs.to_string(),
        ));
        out.push_str(&sample(
            "ftcaqr_inflight_ranks",
            "gauge",
            "Simulated ranks currently in flight.",
            &l,
            &qs.inflight_ranks.to_string(),
        ));
        out
    }
}

impl Inner {
    /// Admit and launch jobs until the head of the queue no longer fits.
    /// Called after every submit and every completion; safe from pool
    /// worker threads (never holds the queue lock across a launch).
    ///
    /// Launch work (input generation, block slicing) deliberately runs
    /// at admission time — on the submitting thread or the completing
    /// worker — rather than at enqueue: materializing inputs only for
    /// *admitted* jobs is what lets `max_inflight_ranks` bound memory
    /// for a deep pending queue. The cost is that a completion on a
    /// narrow pool spends one worker preparing the next tenant; that
    /// time is honestly part of the end-to-end latency the bench
    /// reports.
    fn pump(self: &Arc<Self>) {
        loop {
            let admitted = {
                let mut q = self.q.lock().unwrap();
                let Some(front) = q.pending.front() else { return };
                let procs = front.spec.procs();
                if !q.admits(procs, self.cfg.max_inflight_ranks) {
                    return;
                }
                let p = q.pending.pop_front().expect("front checked");
                match p.spec.lane() {
                    Some(lane) => {
                        // Batched lane: pull later same-shape TSQR jobs
                        // forward to share this sweep (bounded by
                        // batch_max; other jobs keep their order).
                        let mut group = vec![p];
                        if self.cfg.batch_max > 1 {
                            let mut i = 0;
                            while i < q.pending.len() && group.len() < self.cfg.batch_max {
                                if q.pending[i].spec.lane() == Some(lane) {
                                    group.push(q.pending.remove(i).expect("index checked"));
                                } else {
                                    i += 1;
                                }
                            }
                        }
                        q.inflight_ranks += procs;
                        q.inflight_jobs += group.len();
                        Admitted::TsqrLane(group)
                    }
                    None => {
                        q.inflight_ranks += procs;
                        q.inflight_jobs += 1;
                        Admitted::Caqr(p)
                    }
                }
            };
            match admitted {
                Admitted::Caqr(p) => self.launch_caqr(p),
                Admitted::TsqrLane(group) => self.launch_tsqr_lane(group),
            }
        }
    }

    /// Fold a completed world's report into the totals.
    fn account(&self, report: &Report, ok: u64, failed: u64) {
        let mut t = self.totals.lock().unwrap();
        t.report.absorb(report);
        t.jobs_ok += ok;
        t.jobs_failed += failed;
    }

    /// Release a finished job group's admission budget. Must happen
    /// BEFORE the group's outcomes are sent: a caller synchronized on
    /// `JobHandle::wait` may read `queue_stats`/`totals` immediately,
    /// and must not observe the finished job still in flight.
    fn release(&self, procs: usize, njobs: usize) {
        let mut q = self.q.lock().unwrap();
        q.inflight_ranks -= procs;
        q.inflight_jobs -= njobs;
    }

    /// Release a finished job group's admission budget and re-pump.
    fn release_and_pump(self: &Arc<Self>, procs: usize, njobs: usize) {
        self.release(procs, njobs);
        self.pump();
    }

    fn launch_caqr(self: &Arc<Self>, p: Pending) {
        let Pending { id, spec, tx, enqueued } = p;
        let JobSpec::Caqr { cfg, kills } = spec else { unreachable!("caqr lane") };
        let procs = cfg.procs;
        let queued_s = enqueued.elapsed().as_secs_f64();
        let t_run = Instant::now();
        let fault =
            if kills.is_empty() { FaultPlan::none() } else { FaultPlan::schedule(kills) };
        // Per-job backend + input derived from the job's own seed: flop
        // accounting and numerics are isolated from every other tenant.
        // The job's `par` split runs on the service's shared pool via
        // the compute lane (help-first, so tenants can never deadlock or
        // oversubscribe the host) and is scoped to this job's backend —
        // tenants with different `par` no longer race, and any width is
        // bitwise-identical to serial.
        let a = Matrix::randn(cfg.rows, cfg.cols, cfg.seed);
        let backend = Backend::native();
        backend.set_par_ctx(self.pool.par_ctx(cfg.par));
        let prep = CaqrJob::prepare(cfg, a, backend, fault, Trace::disabled(), t_run);
        let job = match prep {
            Ok(j) => j,
            Err(e) => {
                self.account(&Report::default(), 0, 1);
                let _ = tx.send(JobOutcome {
                    id,
                    output: Err(JobError { fail: None, message: format!("{e:#}") }),
                    report: Report::default(),
                    queued_s,
                    run_s: 0.0,
                });
                self.release_and_pump(procs, 1);
                return;
            }
        };
        let CaqrJob { cfg, a, shared, world, tasks, flops0, t0 } = job;
        // Weak: completion closures live inside the pool that this
        // service owns — a strong Arc here would be a cycle and would
        // run the pool's Drop on one of its own workers.
        let inner = Arc::downgrade(self);
        let world_arg = world.clone();
        self.pool.submit(&world_arg, tasks, move |results| {
            let poisoned = shared.poisoned();
            let output =
                match CaqrJob::finalize(&cfg, &a, &shared, &world, results, flops0, t0) {
                    Ok(o) => Ok(JobOutput::Caqr(o)),
                    Err(e) => {
                        Err(JobError { fail: poisoned, message: format!("{e:#}") })
                    }
                };
            // Snapshot after finalize: that's where the retention-store
            // high-water is folded into the job's metrics.
            let report = world.metrics.snapshot();
            let (ok, failed) = if output.is_ok() { (1, 0) } else { (0, 1) };
            // Order matters: totals and the admission budget must be
            // settled before the outcome is delivered (a waiter may read
            // them the moment `wait` returns); the pump — which may do
            // heavy launch work for the next tenant — runs after.
            let inner = inner.upgrade();
            if let Some(inner) = &inner {
                inner.account(&report, ok, failed);
                inner.release(procs, 1);
            }
            let _ = tx.send(JobOutcome {
                id,
                output,
                report,
                queued_s,
                run_s: t_run.elapsed().as_secs_f64(),
            });
            if let Some(inner) = &inner {
                inner.pump();
            }
        });
    }

    fn launch_tsqr_lane(self: &Arc<Self>, group: Vec<Pending>) {
        let (rows, block, procs, mode) = match &group[0].spec {
            JobSpec::Tsqr { rows, block, procs, mode, .. } => (*rows, *block, *procs, *mode),
            JobSpec::Caqr { .. } => unreachable!("tsqr lane"),
        };
        let n = group.len();
        let t_run = Instant::now();
        let inputs: Vec<Matrix> = group
            .iter()
            .map(|p| match &p.spec {
                JobSpec::Tsqr { seed, .. } => Matrix::randn(rows, block, *seed),
                JobSpec::Caqr { .. } => unreachable!("tsqr lane"),
            })
            .collect();
        let meta: Vec<(u64, Sender<JobOutcome>, f64)> = group
            .into_iter()
            .map(|p| (p.id, p.tx, p.enqueued.elapsed().as_secs_f64()))
            .collect();
        // Tall-skinny lanes stay serial (default backend ParCtx): each
        // rank's block is far below the parallel-GEMM work threshold, so
        // a split would only add latch traffic on the shared pool.
        let prep =
            batch::prepare(&inputs, procs, mode, Backend::native(), CostModel::default());
        let (world, tasks, finals) = match prep {
            Ok(parts) => parts,
            Err(e) => {
                let msg = format!("{e:#}");
                self.account(&Report::default(), 0, n as u64);
                for (id, tx, queued_s) in meta {
                    let _ = tx.send(JobOutcome {
                        id,
                        output: Err(JobError { fail: None, message: msg.clone() }),
                        report: Report::default(),
                        queued_s,
                        run_s: 0.0,
                    });
                }
                self.release_and_pump(procs, n);
                return;
            }
        };
        // Weak for the same cycle-avoidance reason as the CAQR lane.
        let inner = Arc::downgrade(self);
        let world_arg = world.clone();
        self.pool.submit(&world_arg, tasks, move |results| {
            let report = world.metrics.snapshot();
            let first_err =
                results.into_iter().find_map(|(rank, r)| r.err().map(|e| (rank, e)));
            let finals = finals.lock().unwrap();
            let root = finals.get(&0);
            let run_s = t_run.elapsed().as_secs_f64();
            let (mut ok, mut failed) = (0u64, 0u64);
            // Build every outcome first so totals/budget can settle
            // before any waiter is unblocked by a send (same ordering
            // contract as the CAQR lane).
            let deliveries: Vec<(Sender<JobOutcome>, JobOutcome)> = meta
                .into_iter()
                .enumerate()
                .map(|(j, (id, tx, queued_s))| {
                    let output = match (&first_err, root) {
                        (None, Some(rs)) => {
                            ok += 1;
                            Ok(JobOutput::Tsqr {
                                r: rs[j].as_ref().clone(),
                                batch_size: n,
                            })
                        }
                        _ => {
                            failed += 1;
                            let message = match &first_err {
                                Some((rank, e)) => format!("tsqr rank {rank} failed: {e}"),
                                None => "tsqr sweep produced no root result".to_string(),
                            };
                            Err(JobError {
                                fail: first_err.as_ref().map(|(_, e)| e.clone()),
                                message,
                            })
                        }
                    };
                    let outcome =
                        JobOutcome { id, output, report: report.clone(), queued_s, run_s };
                    (tx, outcome)
                })
                .collect();
            let inner = inner.upgrade();
            if let Some(inner) = &inner {
                inner.account(&report, ok, failed);
                inner.release(procs, n);
            }
            for (tx, outcome) in deliveries {
                let _ = tx.send(outcome);
            }
            if let Some(inner) = &inner {
                inner.pump();
            }
        });
    }
}

/// Derive a per-job RNG seed from a base seed and a job index
/// (splitmix64): deterministic, well-mixed streams for generated
/// workloads (the `serve` jobs file and the throughput bench).
pub fn seed_for(base: u64, job_index: u64) -> u64 {
    let mut z = base
        .wrapping_add(job_index.wrapping_mul(0x9E37_79B9_7F4A_7C15))
        .wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Parse a `serve` jobs file: one job per line, `#` comments.
///
/// ```text
/// caqr rows=256 cols=64 block=16 procs=4 seed=1 kill=1@0:0:update
/// caqr rows=512 cols=128 block=32 procs=4 lookahead=2 seed=9
/// tsqr rows=128 block=8 procs=8 mode=ft seed=7
/// ```
pub fn parse_jobs(text: &str) -> Result<Vec<JobSpec>> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        out.push(
            parse_job_line(line)
                .with_context(|| format!("jobs file line {}", lineno + 1))?,
        );
    }
    Ok(out)
}

/// Parse one jobs-file line (`caqr ...` or `tsqr ...`, `key=value`
/// tokens; kills use the shared [`ScheduledKill::parse`] grammar).
pub fn parse_job_line(line: &str) -> Result<JobSpec> {
    let mut it = line.split_whitespace();
    let kind = it.next().context("empty job line")?;
    let mut kv: Vec<(&str, &str)> = Vec::new();
    for tok in it {
        let pair = tok
            .split_once('=')
            .with_context(|| format!("token '{tok}' must be key=value"))?;
        kv.push(pair);
    }
    match kind {
        "caqr" => {
            let mut cfg = RunConfig::default();
            let mut kills = Vec::new();
            let mut pair_group = 0u32;
            for (k, v) in kv {
                match k {
                    "rows" => cfg.rows = v.parse()?,
                    "cols" => cfg.cols = v.parse()?,
                    "block" => cfg.block = v.parse()?,
                    "procs" => cfg.procs = v.parse()?,
                    "grid" => {
                        let (pr, pc) = crate::config::parse_grid(v)?;
                        cfg.grid_rows = pr;
                        cfg.grid_cols = pc;
                    }
                    "seed" => cfg.seed = v.parse()?,
                    "verify" => cfg.verify = v.parse()?,
                    "checkpoint-every" => {
                        if v == "auto" {
                            cfg.checkpoint_auto = true;
                        } else {
                            cfg.checkpoint_every = v.parse()?;
                            cfg.checkpoint_auto = false;
                        }
                    }
                    "straggler" => {
                        cfg.stragglers.push(crate::sim::parse_straggler(v)?)
                    }
                    "lookahead" => cfg.lookahead = v.parse()?,
                    "bcast" => cfg.bcast = v.parse().map_err(anyhow::Error::msg)?,
                    "seg-bytes" => cfg.seg_bytes = v.parse()?,
                    "par" => cfg.par = v.parse()?,
                    "algorithm" => {
                        cfg.algorithm = v.parse().map_err(anyhow::Error::msg)?
                    }
                    "kill" => kills.push(ScheduledKill::parse(v)?),
                    "kill-pair" => {
                        let pair = fault::parse_kill_pair(v, pair_group)?;
                        pair_group += 1;
                        kills.extend(pair);
                    }
                    other => bail!("unknown caqr job key '{other}'"),
                }
            }
            Ok(JobSpec::Caqr { cfg, kills })
        }
        "tsqr" => {
            let (mut rows, mut block, mut procs) = (512usize, 16usize, 8usize);
            let mut mode = TsqrMode::FaultTolerant;
            let mut seed = 0u64;
            for (k, v) in kv {
                match k {
                    "rows" => rows = v.parse()?,
                    "block" => block = v.parse()?,
                    "procs" => procs = v.parse()?,
                    "seed" => seed = v.parse()?,
                    "mode" => {
                        mode = match v {
                            "plain" => TsqrMode::Plain,
                            "ft" => TsqrMode::FaultTolerant,
                            other => bail!("unknown tsqr mode '{other}' (ft|plain)"),
                        }
                    }
                    other => bail!("unknown tsqr job key '{other}'"),
                }
            }
            Ok(JobSpec::Tsqr { rows, block, procs, mode, seed })
        }
        other => bail!("unknown job kind '{other}' (caqr|tsqr)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;

    #[test]
    fn admission_math() {
        let mut q = JobQueue::new();
        // Idle service admits anything, even wider than the cap.
        assert!(q.admits(512, 64));
        q.inflight_jobs = 1;
        q.inflight_ranks = 48;
        assert!(q.admits(16, 64)); // 48 + 16 == 64: fits
        assert!(!q.admits(17, 64)); // would exceed
        assert!(q.admits(1000, 0)); // cap 0 = unbounded
    }

    #[test]
    fn job_line_parses_caqr_with_kills() {
        let spec =
            parse_job_line("caqr rows=256 cols=64 block=16 procs=4 seed=9 kill=1@0:0:update")
                .unwrap();
        let JobSpec::Caqr { cfg, kills } = spec else { panic!("caqr expected") };
        assert_eq!((cfg.rows, cfg.cols, cfg.block, cfg.procs, cfg.seed), (256, 64, 16, 4, 9));
        assert_eq!(cfg.algorithm, Algorithm::FaultTolerant);
        assert_eq!(cfg.lookahead, 0, "jobs default to lockstep");
        assert_eq!(kills.len(), 1);
        assert_eq!(kills[0].rank, 1);
    }

    #[test]
    fn job_line_parses_lookahead() {
        let spec = parse_job_line("caqr rows=256 cols=64 block=16 procs=4 par=2").unwrap();
        let JobSpec::Caqr { cfg, .. } = &spec else { panic!("caqr") };
        assert_eq!(cfg.par, 2);
        let spec = parse_job_line("caqr rows=256 cols=64 block=16 procs=4 lookahead=2").unwrap();
        let JobSpec::Caqr { cfg, .. } = spec else { panic!("caqr expected") };
        assert_eq!(cfg.lookahead, 2);
        assert!(parse_job_line("caqr lookahead=deep").is_err());
    }

    #[test]
    fn job_line_parses_bcast_schedule() {
        let spec = parse_job_line(
            "caqr rows=256 cols=64 block=16 procs=8 grid=2x4 bcast=binomial seg-bytes=4096",
        )
        .unwrap();
        let JobSpec::Caqr { cfg, .. } = spec else { panic!("caqr expected") };
        assert_eq!(cfg.bcast, crate::config::BcastKind::Binomial);
        assert_eq!(cfg.seg_bytes, 4096);
        assert!(parse_job_line("caqr bcast=ring").is_err());
    }

    #[test]
    fn job_line_parses_grid() {
        let spec =
            parse_job_line("caqr rows=256 cols=64 block=16 procs=4 grid=2x2").unwrap();
        let JobSpec::Caqr { cfg, .. } = spec else { panic!("caqr expected") };
        assert_eq!((cfg.grid_rows, cfg.grid_cols), (2, 2));
        assert_eq!(cfg.grid_shape(), (2, 2));
        assert!(parse_job_line("caqr procs=4 grid=3").is_err(), "PrxPc shape required");
    }

    #[test]
    fn job_line_parses_checkpoint_auto_and_stragglers() {
        let spec = parse_job_line(
            "caqr rows=256 cols=64 block=16 procs=4 checkpoint-every=auto \
             straggler=1:10 straggler=2:1.5",
        )
        .unwrap();
        let JobSpec::Caqr { cfg, .. } = spec else { panic!("caqr expected") };
        assert!(cfg.checkpoint_auto);
        assert_eq!(cfg.stragglers, vec![(1, 10.0), (2, 1.5)]);
        // A concrete interval still parses and clears the auto flag.
        let spec = parse_job_line("caqr rows=256 cols=64 block=16 checkpoint-every=2").unwrap();
        let JobSpec::Caqr { cfg, .. } = spec else { panic!("caqr expected") };
        assert!(!cfg.checkpoint_auto);
        assert_eq!(cfg.checkpoint_every, 2);
        assert!(parse_job_line("caqr straggler=1").is_err());
        assert!(parse_job_line("caqr checkpoint-every=soon").is_err());
    }

    #[test]
    fn job_line_parses_tsqr_and_rejects_garbage() {
        let spec = parse_job_line("tsqr rows=128 block=8 procs=8 mode=plain seed=3").unwrap();
        let JobSpec::Tsqr { rows, block, procs, mode, seed } = spec else {
            panic!("tsqr expected")
        };
        assert_eq!((rows, block, procs, seed), (128, 8, 8, 3));
        assert_eq!(mode, TsqrMode::Plain);
        assert!(parse_job_line("tsqr rows").is_err());
        assert!(parse_job_line("qr rows=1").is_err());
        assert!(parse_job_line("tsqr bogus=1").is_err());
    }

    #[test]
    fn jobs_file_skips_comments_and_reports_line_numbers() {
        let text = "# header\n\ncaqr procs=4 rows=128 cols=32 block=16\ntsqr procs=8 rows=64 block=8\n";
        let specs = parse_jobs(text).unwrap();
        assert_eq!(specs.len(), 2);
        let err = parse_jobs("caqr rows=128\nbroken line\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 2"), "{err:#}");
    }

    #[test]
    fn seeds_are_deterministic_and_distinct() {
        assert_eq!(seed_for(7, 3), seed_for(7, 3));
        let s: std::collections::HashSet<u64> =
            (0..64).map(|i| seed_for(42, i)).collect();
        assert_eq!(s.len(), 64);
    }

    #[test]
    fn spec_validation_catches_bad_shapes() {
        let bad = JobSpec::Tsqr {
            rows: 100,
            block: 8,
            procs: 8, // 100 % 8 != 0
            mode: TsqrMode::FaultTolerant,
            seed: 0,
        };
        assert!(bad.validate().is_err());
        // `par > 1` is allowed: the band split is backend-scoped and
        // rides the service pool's compute lane, so tenants with
        // different widths cannot race.
        let cfg = RunConfig { par: 2, ..Default::default() };
        assert!(JobSpec::Caqr { cfg, kills: vec![] }.validate().is_ok());
    }

    #[test]
    fn par_split_tenant_matches_serial_tenant_bitwise() {
        // Two tenants, identical job except `par`: the pooled band
        // split must not perturb a single bit of the factors.
        let svc = Service::new(ServiceConfig {
            workers: 2,
            max_inflight_ranks: 64,
            batch_max: 1,
        });
        let serial = RunConfig { par: 1, ..Default::default() };
        let split = RunConfig { par: 3, ..serial.clone() };
        let h1 = svc.submit(JobSpec::Caqr { cfg: serial, kills: vec![] }).unwrap();
        let h2 = svc.submit(JobSpec::Caqr { cfg: split, kills: vec![] }).unwrap();
        let (o1, o2) = (h1.wait(), h2.wait());
        let r1 = match o1.output.expect("serial tenant") {
            JobOutput::Caqr(o) => o.r,
            other => panic!("unexpected output {other:?}"),
        };
        let r2 = match o2.output.expect("par tenant") {
            JobOutput::Caqr(o) => o.r,
            other => panic!("unexpected output {other:?}"),
        };
        assert_eq!(r1, r2, "par split changed the factorization bits");
    }

    #[test]
    fn two_tenants_end_to_end() {
        // Smoke: one CAQR + one TSQR job through a 2-worker service.
        let svc = Service::new(ServiceConfig {
            workers: 2,
            max_inflight_ranks: 64,
            batch_max: 1,
        });
        let h1 = svc
            .submit(JobSpec::Caqr { cfg: RunConfig::default(), kills: vec![] })
            .unwrap();
        let h2 = svc
            .submit(JobSpec::Tsqr {
                rows: 64,
                block: 8,
                procs: 8,
                mode: TsqrMode::FaultTolerant,
                seed: 5,
            })
            .unwrap();
        let o1 = h1.wait();
        let o2 = h2.wait();
        assert!(o1.output.is_ok(), "{:?}", o1.output.err());
        assert!(o2.output.is_ok(), "{:?}", o2.output.err());
        let t = svc.totals();
        assert_eq!(t.jobs_ok, 2);
        assert_eq!(t.jobs_failed, 0);
        assert!(t.report.messages + t.report.exchanges > 0);
        assert_eq!(svc.queue_stats(), QueueStats { pending: 0, inflight_jobs: 0, inflight_ranks: 0 });
        let text = svc.metrics_text();
        assert!(text.contains("ftcaqr_jobs_ok_total{scope=\"service\"} 2"), "{text}");
        assert!(text.contains("ftcaqr_queue_pending{scope=\"service\"} 0"), "{text}");
        assert!(text.contains("ftcaqr_messages_total{scope=\"service\"}"), "{text}");
    }
}
