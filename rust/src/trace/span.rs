//! Typed spans and the per-rank lock-free ring buffers behind [`Trace`].
//!
//! A [`Span`] is one begin/end interval on the logical clock, attributed
//! by rank x incarnation x panel x lane x grid coordinates. Spans (and
//! legacy [`TraceEvent`]s, wrapped as [`Record::Event`]) are recorded
//! into one bounded single-writer ring per rank: the hot path takes no
//! global lock, memory is bounded by `capacity` records per rank, and
//! overflow drops the *oldest* records while counting every drop, so a
//! truncated trace is always detectable.
//!
//! Writer/reader protocol: the scheduler polls at most one task per rank
//! at a time and REBUILD incarnations are sequential, so each ring has
//! one effective writer; readers (exporters, the flight recorder, the
//! compatibility views) run after the pool has quiesced. Both sides are
//! nevertheless fully sound under arbitrary interleaving: every slot is
//! guarded by a per-slot atomic claim, and a contended access skips the
//! slot (counted as dropped) instead of racing.
//!
//! [`Trace`]: super::Trace
//! [`TraceEvent`]: super::TraceEvent

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use super::TraceEvent;

/// What a [`Span`] measures. Recovery kinds are flagged in the Perfetto
/// export so failure handling stands out on the rank tracks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// One panel's TSQR: leaf QR plus the pairwise merge tree.
    PanelTsqr,
    /// Row-broadcast of a panel's `{Y1, T}` factors across the grid row.
    BcastFactors,
    /// One trailing-update segment (a lane's columns) for one panel.
    UpdateSegment,
    /// Pairwise checkpoint exchange of the local trailing matrix.
    CheckpointWrite,
    /// Failure detection: a survivor claims the revival of a dead rank
    /// (a point span — detection has no duration on the logical clock).
    RecoveryDetect,
    /// A replayed rank fetching retained data from its buddy.
    RecoveryFetch,
    /// A REBUILD replacement's whole life: spawn to finish.
    RecoveryReplay,
}

impl SpanKind {
    /// Stable lowercase name used by the exporters.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::PanelTsqr => "panel_tsqr",
            SpanKind::BcastFactors => "bcast_factors",
            SpanKind::UpdateSegment => "update_segment",
            SpanKind::CheckpointWrite => "checkpoint_write",
            SpanKind::RecoveryDetect => "recovery_detect",
            SpanKind::RecoveryFetch => "recovery_fetch",
            SpanKind::RecoveryReplay => "recovery_replay",
        }
    }

    /// True for the kinds that only occur while handling a failure.
    pub fn is_recovery(self) -> bool {
        matches!(
            self,
            SpanKind::RecoveryDetect | SpanKind::RecoveryFetch | SpanKind::RecoveryReplay
        )
    }

    /// Perfetto category: the phase bucket for normal spans, `recovery`
    /// for the failure-handling kinds.
    pub fn category(self) -> &'static str {
        match self {
            SpanKind::PanelTsqr => "tsqr",
            SpanKind::BcastFactors => "bcast",
            SpanKind::UpdateSegment => "update",
            SpanKind::CheckpointWrite => "checkpoint",
            SpanKind::RecoveryDetect | SpanKind::RecoveryFetch | SpanKind::RecoveryReplay => {
                "recovery"
            }
        }
    }
}

/// One interval on a rank's logical clock, fully attributed.
#[derive(Clone, Debug, PartialEq)]
pub struct Span {
    /// What was measured.
    pub kind: SpanKind,
    /// Begin, logical seconds.
    pub t0: f64,
    /// End, logical seconds (`t0 == t1` for point spans).
    pub t1: f64,
    /// Emitting rank.
    pub rank: usize,
    /// The rank's incarnation (0 = original, bumped per REBUILD).
    pub inc: u32,
    /// CAQR panel index the span belongs to.
    pub panel: usize,
    /// Update lane (0 for non-update spans).
    pub lane: usize,
    /// Process-grid row of the emitting rank.
    pub gr: usize,
    /// Process-grid column of the emitting rank.
    pub gc: usize,
    /// True when the span is part of failure handling — either a
    /// recovery kind, or a normal-kind span replayed by a REBUILD
    /// replacement.
    pub recovery: bool,
    /// Kind-specific detail: dead rank for detect, buddy for fetch,
    /// payload bytes for checkpoint, merge-step count for TSQR.
    pub value: f64,
}

/// One ring-buffer record: a typed span or a legacy flat event.
#[derive(Clone, Debug, PartialEq)]
pub enum Record {
    /// A typed begin/end span.
    Span(Span),
    /// A legacy `Trace::emit` event, kept for the compatibility views.
    Event(TraceEvent),
}

impl Record {
    /// The record's (begin) timestamp, logical seconds.
    pub fn t(&self) -> f64 {
        match self {
            Record::Span(s) => s.t0,
            Record::Event(e) => e.t,
        }
    }
}

/// Slot states for the per-slot claim byte.
const SLOT_FREE: u8 = 0;
const SLOT_BUSY: u8 = 1;

struct SlotCell {
    /// Claim byte: [`SLOT_BUSY`] while one side holds exclusive access
    /// to `rec`. Contenders skip rather than wait.
    state: AtomicU8,
    rec: UnsafeCell<Option<Record>>,
}

/// Bounded drop-oldest ring for one rank. Lock-free: a push is one
/// relaxed `fetch_add` plus one per-slot claim, and never blocks.
pub(crate) struct RankRing {
    slots: Box<[SlotCell]>,
    /// Total records ever pushed (monotone); `pushed - capacity` of them
    /// (when positive) have been overwritten, i.e. dropped-oldest.
    pushed: AtomicU64,
    /// Records abandoned because the target slot was concurrently
    /// claimed (requires a writer lapped by a whole ring — counted so a
    /// lost record is never silent).
    contended: AtomicU64,
}

// SAFETY: all access to each `SlotCell::rec` is mediated by its `state`
// claim byte — a slot is read or written only between a successful
// SLOT_FREE -> SLOT_BUSY compare-exchange (Acquire) and the matching
// SLOT_BUSY -> SLOT_FREE store (Release), so no two threads ever touch
// the same `UnsafeCell` concurrently and writes are published to the
// next claimant.
unsafe impl Sync for RankRing {}

impl RankRing {
    pub(crate) fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        let slots = (0..cap)
            .map(|_| SlotCell { state: AtomicU8::new(SLOT_FREE), rec: UnsafeCell::new(None) })
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Self { slots, pushed: AtomicU64::new(0), contended: AtomicU64::new(0) }
    }

    /// Append one record, overwriting the oldest when full.
    pub(crate) fn push(&self, rec: Record) {
        let seq = self.pushed.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(seq % self.slots.len() as u64) as usize];
        if slot
            .state
            .compare_exchange(SLOT_FREE, SLOT_BUSY, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            // SAFETY: the claim byte grants exclusive access (see the
            // `unsafe impl Sync` rationale above).
            unsafe { *slot.rec.get() = Some(rec) };
            slot.state.store(SLOT_FREE, Ordering::Release);
        } else {
            self.contended.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Records currently held, oldest first.
    pub(crate) fn snapshot(&self) -> Vec<Record> {
        let n = self.pushed.load(Ordering::Acquire);
        let cap = self.slots.len() as u64;
        let start = n.saturating_sub(cap);
        let mut out = Vec::with_capacity((n - start) as usize);
        for seq in start..n {
            let slot = &self.slots[(seq % cap) as usize];
            if slot
                .state
                .compare_exchange(SLOT_FREE, SLOT_BUSY, Ordering::Acquire, Ordering::Relaxed)
                .is_ok()
            {
                // SAFETY: the claim byte grants exclusive access.
                let rec = unsafe { (*slot.rec.get()).clone() };
                slot.state.store(SLOT_FREE, Ordering::Release);
                if let Some(r) = rec {
                    out.push(r);
                }
            }
        }
        out
    }

    /// Records dropped so far: overwritten-oldest plus claim conflicts.
    pub(crate) fn dropped(&self) -> u64 {
        let n = self.pushed.load(Ordering::Relaxed);
        n.saturating_sub(self.slots.len() as u64) + self.contended.load(Ordering::Relaxed)
    }

    /// Total records ever pushed.
    pub(crate) fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(t: f64) -> Record {
        Record::Event(TraceEvent { t, rank: 0, panel: 0, step: 0, kind: "x", value: 0.0 })
    }

    #[test]
    fn ring_keeps_newest_and_counts_drops() {
        let r = RankRing::new(4);
        for i in 0..10 {
            r.push(ev(i as f64));
        }
        let snap = r.snapshot();
        assert_eq!(snap.len(), 4);
        // Drop-oldest: records 0..6 gone, 6..10 retained in order.
        assert_eq!(snap.iter().map(Record::t).collect::<Vec<_>>(), vec![6.0, 7.0, 8.0, 9.0]);
        assert_eq!(r.dropped(), 6);
        assert_eq!(r.pushed(), 10);
    }

    #[test]
    fn ring_under_capacity_drops_nothing() {
        let r = RankRing::new(8);
        for i in 0..3 {
            r.push(ev(i as f64));
        }
        assert_eq!(r.snapshot().len(), 3);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn span_kind_names_are_stable() {
        assert_eq!(SpanKind::PanelTsqr.name(), "panel_tsqr");
        assert_eq!(SpanKind::RecoveryReplay.name(), "recovery_replay");
        assert!(SpanKind::RecoveryFetch.is_recovery());
        assert!(!SpanKind::UpdateSegment.is_recovery());
        assert_eq!(SpanKind::CheckpointWrite.category(), "checkpoint");
        assert_eq!(SpanKind::RecoveryDetect.category(), "recovery");
    }
}
