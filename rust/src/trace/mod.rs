//! Span-based tracing: every tree step, failure, and recovery is
//! recorded with its logical timestamps so runs can be profiled and the
//! per-step series behind the paper's figures (e.g. Fig 2's redundancy
//! doubling) exported as JSON.
//!
//! The subsystem has two record types — typed [`Span`]s (begin/end on
//! the logical clock, attributed by rank x incarnation x panel x lane x
//! grid) and legacy flat [`TraceEvent`]s — both landing in bounded
//! per-rank lock-free ring buffers ([`span::RankRing`]): the hot path
//! never takes a global mutex, [`Trace::disabled`] is a single branch,
//! and overflow drops the oldest records while counting the drops.
//! Exporters: [`Trace::to_perfetto`] (Chrome `trace_event` JSON, one
//! track per rank, recovery spans flagged by category) and the legacy
//! [`Trace::to_json`] flat-event dump. [`Trace::flight_recorder`]
//! renders the last-N records per rank for crash reports.
//!
//! Spans are a pure function of the seeded run: the export walks ranks
//! in order and each ring in emission order, so same seed means a
//! byte-identical export.

pub mod span;

use std::sync::{Arc, RwLock};

use span::RankRing;
pub use span::{Record, Span, SpanKind};

/// Ring capacity (records per rank) for [`Trace::new`].
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// One legacy flat trace record (kept as a compatibility view; new
/// instrumentation should emit typed [`Span`]s).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Logical time (dual-channel cost model seconds).
    pub t: f64,
    /// Emitting rank.
    pub rank: usize,
    /// CAQR panel index.
    pub panel: usize,
    /// Tree step.
    pub step: usize,
    /// Event kind, e.g. "tsqr_merge", "update_exchange", "failure",
    /// "recovery_start", "recovery_done", "redundancy".
    pub kind: &'static str,
    /// Free-form detail (e.g. redundancy count, buddy rank).
    pub value: f64,
}

/// Shared trace: per-rank bounded ring buffers behind an `Arc`.
///
/// The rank -> ring map is an `RwLock<Vec<_>>` taken for *read* on the
/// hot path (uncontended: it is only taken for write when a new rank
/// first records, which [`Trace::ensure_ranks`] front-loads to job
/// prepare time). Each ring itself is lock-free.
pub struct Trace {
    rings: RwLock<Vec<Arc<RankRing>>>,
    capacity: usize,
    enabled: bool,
}

impl Default for Trace {
    /// A disabled trace (matches the pre-span `#[derive(Default)]`,
    /// where the default `enabled` was `false`).
    fn default() -> Self {
        Self { rings: RwLock::new(Vec::new()), capacity: DEFAULT_RING_CAPACITY, enabled: false }
    }
}

impl Trace {
    /// An enabled trace with [`DEFAULT_RING_CAPACITY`] records per rank.
    pub fn new() -> Arc<Self> {
        Self::with_capacity(DEFAULT_RING_CAPACITY)
    }

    /// An enabled trace holding at most `capacity` records per rank
    /// (oldest dropped beyond that, see [`Trace::dropped`]).
    pub fn with_capacity(capacity: usize) -> Arc<Self> {
        Arc::new(Self {
            rings: RwLock::new(Vec::new()),
            capacity: capacity.max(1),
            enabled: true,
        })
    }

    /// A disabled trace: every record call is a single branch, no
    /// allocation, no lock.
    pub fn disabled() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// True when recording (i.e. not [`Trace::disabled`]).
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Pre-size the rank -> ring map so the recording hot path never
    /// takes the map's write lock. Called at job prepare time; a rank
    /// beyond the pre-sized range still works (the map grows lazily).
    pub fn ensure_ranks(&self, ranks: usize) {
        if !self.enabled {
            return;
        }
        let mut g = self.rings.write().unwrap();
        while g.len() < ranks {
            let ring = Arc::new(RankRing::new(self.capacity));
            g.push(ring);
        }
    }

    /// The rank's ring, growing the map if needed.
    fn ring(&self, rank: usize) -> Arc<RankRing> {
        {
            let g = self.rings.read().unwrap();
            if let Some(r) = g.get(rank) {
                return r.clone();
            }
        }
        let mut g = self.rings.write().unwrap();
        while g.len() <= rank {
            let ring = Arc::new(RankRing::new(self.capacity));
            g.push(ring);
        }
        g[rank].clone()
    }

    /// Record one completed span (no-op when disabled).
    #[inline]
    pub fn span(&self, s: Span) {
        if self.enabled {
            self.ring(s.rank).push(Record::Span(s));
        }
    }

    /// Append one legacy event (no-op when the trace is disabled).
    #[inline]
    pub fn record(&self, ev: TraceEvent) {
        if self.enabled {
            self.ring(ev.rank).push(Record::Event(ev));
        }
    }

    /// Legacy flat-event emit, routed into the emitting rank's ring.
    #[inline]
    pub fn emit(
        &self,
        t: f64,
        rank: usize,
        panel: usize,
        step: usize,
        kind: &'static str,
        value: f64,
    ) {
        self.record(TraceEvent { t, rank, panel, step, kind, value });
    }

    /// All records currently held, rank-major (rank 0's ring oldest
    /// first, then rank 1's, ...).
    fn records(&self) -> Vec<Record> {
        let rings: Vec<Arc<RankRing>> = self.rings.read().unwrap().clone();
        rings.iter().flat_map(|r| r.snapshot()).collect()
    }

    /// Per-rank snapshots: `(rank, records, dropped)` for every rank
    /// that has a ring, in rank order.
    fn per_rank(&self) -> Vec<(usize, Vec<Record>, u64)> {
        let rings: Vec<Arc<RankRing>> = self.rings.read().unwrap().clone();
        rings.iter().enumerate().map(|(i, r)| (i, r.snapshot(), r.dropped())).collect()
    }

    /// Number of records currently held (drops excluded).
    pub fn len(&self) -> usize {
        self.rings.read().unwrap().iter().map(|r| r.snapshot().len()).sum()
    }

    /// True when nothing is held.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total records dropped across all ranks (ring overflow).
    pub fn dropped(&self) -> u64 {
        self.rings.read().unwrap().iter().map(|r| r.dropped()).sum()
    }

    /// All legacy events of one kind, rank-major then emission order
    /// within a rank (a compatibility view over the rings).
    pub fn of_kind(&self, kind: &str) -> Vec<TraceEvent> {
        self.events().into_iter().filter(|e| e.kind == kind).collect()
    }

    /// All legacy events, rank-major (compatibility view).
    pub fn events(&self) -> Vec<TraceEvent> {
        self.records()
            .into_iter()
            .filter_map(|r| match r {
                Record::Event(e) => Some(e),
                Record::Span(_) => None,
            })
            .collect()
    }

    /// All typed spans, rank-major.
    pub fn spans(&self) -> Vec<Span> {
        self.records()
            .into_iter()
            .filter_map(|r| match r {
                Record::Span(s) => Some(s),
                Record::Event(_) => None,
            })
            .collect()
    }

    /// Serialize the legacy flat events to JSON (hand-rolled: offline
    /// build). Spans are not included; see [`Trace::to_perfetto`].
    pub fn to_json(&self) -> String {
        let evs = self.events();
        let mut out = String::from("[\n");
        for (i, e) in evs.iter().enumerate() {
            out.push_str(&format!(
                "  {{\"t\": {}, \"rank\": {}, \"panel\": {}, \"step\": {}, \
                 \"kind\": \"{}\", \"value\": {}}}{}\n",
                e.t,
                e.rank,
                e.panel,
                e.step,
                e.kind,
                e.value,
                if i + 1 < evs.len() { "," } else { "" }
            ));
        }
        out.push(']');
        out
    }

    /// Export as Chrome `trace_event` / Perfetto JSON: one track (tid)
    /// per rank, `ph:"X"` duration events for spans (recovery spans
    /// carry the `recovery` category and a `recovery: 1` arg), `ph:"i"`
    /// instants for legacy events, and a `dropped_records` instant when
    /// a ring overflowed. Timestamps are logical-clock microseconds.
    ///
    /// The walk is rank-major and each ring is in emission order, so the
    /// output is a pure function of the seeded run (byte-identical
    /// across same-seed runs).
    pub fn to_perfetto(&self) -> String {
        let mut out = String::from("{\"traceEvents\": [\n");
        let mut first = true;
        let mut push = |out: &mut String, line: String| {
            if !std::mem::take(&mut first) {
                out.push_str(",\n");
            }
            out.push_str("  ");
            out.push_str(&line);
        };
        for (rank, records, dropped) in self.per_rank() {
            push(
                &mut out,
                format!(
                    "{{\"ph\": \"M\", \"pid\": 0, \"tid\": {rank}, \"name\": \"thread_name\", \
                     \"args\": {{\"name\": \"rank {rank}\"}}}}"
                ),
            );
            for rec in &records {
                match rec {
                    Record::Span(s) => push(
                        &mut out,
                        format!(
                            "{{\"ph\": \"X\", \"pid\": 0, \"tid\": {}, \"name\": \"{}\", \
                             \"cat\": \"{}\", \"ts\": {}, \"dur\": {}, \"args\": {{\
                             \"inc\": {}, \"panel\": {}, \"lane\": {}, \"gr\": {}, \"gc\": {}, \
                             \"recovery\": {}, \"value\": {}}}}}",
                            s.rank,
                            s.kind.name(),
                            s.kind.category(),
                            json_f(s.t0 * 1e6),
                            json_f((s.t1 - s.t0) * 1e6),
                            s.inc,
                            s.panel,
                            s.lane,
                            s.gr,
                            s.gc,
                            u8::from(s.recovery),
                            json_f(s.value),
                        ),
                    ),
                    Record::Event(e) => push(
                        &mut out,
                        format!(
                            "{{\"ph\": \"i\", \"pid\": 0, \"tid\": {}, \"name\": \"{}\", \
                             \"cat\": \"event\", \"ts\": {}, \"s\": \"t\", \"args\": {{\
                             \"panel\": {}, \"step\": {}, \"value\": {}}}}}",
                            e.rank,
                            e.kind,
                            json_f(e.t * 1e6),
                            e.panel,
                            e.step,
                            json_f(e.value),
                        ),
                    ),
                }
            }
            if dropped > 0 {
                push(
                    &mut out,
                    format!(
                        "{{\"ph\": \"i\", \"pid\": 0, \"tid\": {rank}, \"name\": \
                         \"dropped_records\", \"cat\": \"event\", \"ts\": 0e0, \"s\": \"t\", \
                         \"args\": {{\"count\": {dropped}}}}}"
                    ),
                );
            }
        }
        out.push_str("\n], \"displayTimeUnit\": \"ns\"}\n");
        out
    }

    /// Render the last `last_n` records per rank as a compact text block
    /// for crash reports (`Fail::Unrecoverable` / `Stalled` /
    /// `TaskPanicked` error messages).
    pub fn flight_recorder(&self, last_n: usize) -> String {
        if !self.enabled {
            return String::from("flight recorder: tracing disabled");
        }
        let mut out = format!("flight recorder (last {last_n} records/rank):");
        for (rank, records, dropped) in self.per_rank() {
            out.push_str(&format!("\n  r{rank}:"));
            let start = records.len().saturating_sub(last_n);
            if records.is_empty() {
                out.push_str(" (no records)");
            }
            for rec in &records[start..] {
                match rec {
                    Record::Span(s) => out.push_str(&format!(
                        " {}[p{} l{} i{} t{:.3e}..{:.3e}{}]",
                        s.kind.name(),
                        s.panel,
                        s.lane,
                        s.inc,
                        s.t0,
                        s.t1,
                        if s.recovery { " R" } else { "" }
                    )),
                    Record::Event(e) => out.push_str(&format!(
                        " {}(p{} s{} t{:.3e} v={})",
                        e.kind, e.panel, e.step, e.t, e.value
                    )),
                }
            }
            if dropped > 0 {
                out.push_str(&format!(" [+{dropped} dropped]"));
            }
        }
        out
    }
}

/// Deterministic float rendering for the Perfetto export: finite values
/// in `{:e}` form (valid JSON numbers), non-finite as `null` — the same
/// convention as the bench `JsonSink`.
fn json_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        String::from("null")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_filter() {
        let t = Trace::new();
        t.emit(0.0, 0, 0, 0, "redundancy", 1.0);
        t.emit(1.0, 1, 0, 1, "redundancy", 2.0);
        t.emit(2.0, 0, 0, 0, "failure", 0.0);
        assert_eq!(t.len(), 3);
        assert_eq!(t.of_kind("redundancy").len(), 2);
        assert_eq!(t.of_kind("failure")[0].t, 2.0);
    }

    #[test]
    fn disabled_trace_records_nothing() {
        let t = Trace::disabled();
        t.emit(0.0, 0, 0, 0, "x", 0.0);
        t.span(Span {
            kind: SpanKind::PanelTsqr,
            t0: 0.0,
            t1: 1.0,
            rank: 0,
            inc: 0,
            panel: 0,
            lane: 0,
            gr: 0,
            gc: 0,
            recovery: false,
            value: 0.0,
        });
        assert!(t.is_empty());
        assert!(!t.is_enabled());
    }

    #[test]
    fn json_shape_is_sane() {
        let t = Trace::new();
        t.emit(0.5, 2, 1, 3, "tsqr_merge", 4.0);
        let j = t.to_json();
        assert!(j.starts_with('[') && j.ends_with(']'));
        assert!(j.contains("\"rank\": 2"));
        assert!(j.contains("\"kind\": \"tsqr_merge\""));
        // no trailing comma before the closing bracket
        assert!(!j.contains(",\n]"));
    }

    #[test]
    fn spans_and_events_are_separated_by_view() {
        let t = Trace::new();
        t.emit(0.0, 1, 2, 3, "checkpoint", 1.0);
        t.span(Span {
            kind: SpanKind::UpdateSegment,
            t0: 1.0,
            t1: 2.0,
            rank: 0,
            inc: 0,
            panel: 2,
            lane: 1,
            gr: 0,
            gc: 0,
            recovery: false,
            value: 8.0,
        });
        assert_eq!(t.len(), 2);
        assert_eq!(t.events().len(), 1);
        assert_eq!(t.spans().len(), 1);
        assert_eq!(t.spans()[0].kind, SpanKind::UpdateSegment);
    }

    #[test]
    fn ring_overflow_is_counted_and_drops_oldest() {
        let t = Trace::with_capacity(4);
        for i in 0..10 {
            t.emit(i as f64, 0, i, 0, "e", 0.0);
        }
        assert_eq!(t.len(), 4);
        assert_eq!(t.dropped(), 6);
        // Oldest dropped: the first surviving event is #6.
        assert_eq!(t.events()[0].t, 6.0);
        assert!(t.to_perfetto().contains("dropped_records"));
    }

    #[test]
    fn perfetto_export_shape() {
        let t = Trace::new();
        t.ensure_ranks(2);
        t.span(Span {
            kind: SpanKind::RecoveryFetch,
            t0: 1e-6,
            t1: 3e-6,
            rank: 1,
            inc: 1,
            panel: 4,
            lane: 0,
            gr: 1,
            gc: 0,
            recovery: true,
            value: 0.0,
        });
        t.emit(2e-6, 0, 0, 0, "failure", 3.0);
        let j = t.to_perfetto();
        assert!(j.starts_with("{\"traceEvents\": ["));
        assert!(j.trim_end().ends_with('}'));
        assert!(j.contains("\"thread_name\""));
        assert!(j.contains("\"rank 1\""));
        assert!(j.contains("\"cat\": \"recovery\""));
        assert!(j.contains("\"recovery\": 1"));
        assert!(j.contains("\"ph\": \"i\""));
        // Same content again is byte-identical (pure function of state).
        assert_eq!(j, t.to_perfetto());
    }

    #[test]
    fn flight_recorder_renders_last_records() {
        let t = Trace::with_capacity(8);
        for i in 0..5 {
            t.emit(i as f64, 0, i, 0, "e", 0.0);
        }
        let fr = t.flight_recorder(2);
        assert!(fr.contains("r0:"));
        assert!(fr.contains("e(p4"));
        assert!(!fr.contains("e(p0"), "only the last N records appear: {fr}");
        assert!(Trace::disabled().flight_recorder(4).contains("disabled"));
    }
}
