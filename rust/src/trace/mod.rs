//! Structured event trace: every tree step, failure, and recovery is
//! recorded with its logical timestamp so the bench harness can emit the
//! per-step series behind the paper's figures (e.g. Fig 2's redundancy
//! doubling) as JSON/CSV.

use std::sync::Arc;

use std::sync::Mutex;

/// One trace record.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Logical time (dual-channel cost model seconds).
    pub t: f64,
    /// Emitting rank.
    pub rank: usize,
    /// CAQR panel index.
    pub panel: usize,
    /// Tree step.
    pub step: usize,
    /// Event kind, e.g. "tsqr_merge", "update_exchange", "failure",
    /// "recovery_start", "recovery_done", "redundancy".
    pub kind: &'static str,
    /// Free-form detail (e.g. redundancy count, buddy rank).
    pub value: f64,
}

/// Append-only shared trace.
#[derive(Default)]
pub struct Trace {
    events: Mutex<Vec<TraceEvent>>,
    enabled: bool,
}

impl Trace {
    /// An enabled trace.
    pub fn new() -> Arc<Self> {
        Arc::new(Self { events: Mutex::new(Vec::new()), enabled: true })
    }

    /// A disabled trace (hot paths skip the lock entirely).
    pub fn disabled() -> Arc<Self> {
        Arc::new(Self { events: Mutex::new(Vec::new()), enabled: false })
    }

    /// Append one event (no-op when the trace is disabled).
    #[inline]
    pub fn record(&self, ev: TraceEvent) {
        if self.enabled {
            self.events.lock().unwrap().push(ev);
        }
    }

    pub fn emit(
        &self,
        t: f64,
        rank: usize,
        panel: usize,
        step: usize,
        kind: &'static str,
        value: f64,
    ) {
        self.record(TraceEvent { t, rank, panel, step, kind, value });
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All events of one kind, in insertion order.
    pub fn of_kind(&self, kind: &str) -> Vec<TraceEvent> {
        self.events.lock().unwrap().iter().filter(|e| e.kind == kind).cloned().collect()
    }

    /// Full copy of the log.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Serialize the whole trace to JSON (hand-rolled: offline build).
    pub fn to_json(&self) -> String {
        let evs = self.events.lock().unwrap();
        let mut out = String::from("[\n");
        for (i, e) in evs.iter().enumerate() {
            out.push_str(&format!(
                "  {{\"t\": {}, \"rank\": {}, \"panel\": {}, \"step\": {}, \
                 \"kind\": \"{}\", \"value\": {}}}{}\n",
                e.t,
                e.rank,
                e.panel,
                e.step,
                e.kind,
                e.value,
                if i + 1 < evs.len() { "," } else { "" }
            ));
        }
        out.push(']');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_filter() {
        let t = Trace::new();
        t.emit(0.0, 0, 0, 0, "redundancy", 1.0);
        t.emit(1.0, 1, 0, 1, "redundancy", 2.0);
        t.emit(2.0, 0, 0, 0, "failure", 0.0);
        assert_eq!(t.len(), 3);
        assert_eq!(t.of_kind("redundancy").len(), 2);
        assert_eq!(t.of_kind("failure")[0].t, 2.0);
    }

    #[test]
    fn disabled_trace_records_nothing() {
        let t = Trace::disabled();
        t.emit(0.0, 0, 0, 0, "x", 0.0);
        assert!(t.is_empty());
    }

    #[test]
    fn json_shape_is_sane() {
        let t = Trace::new();
        t.emit(0.5, 2, 1, 3, "tsqr_merge", 4.0);
        let j = t.to_json();
        assert!(j.starts_with('[') && j.ends_with(']'));
        assert!(j.contains("\"rank\": 2"));
        assert!(j.contains("\"kind\": \"tsqr_merge\""));
        // no trailing comma before the closing bracket
        assert!(!j.contains(",\n]"));
    }
}
