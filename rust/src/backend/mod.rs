//! Compute backends: the five numeric ops behind one interface.
//!
//! * [`NativeBackend`] — the pure-Rust [`crate::linalg`] oracle. Fast to
//!   spin up; used by the large simulation sweeps and property tests.
//! * [`XlaBackend`] — executes the AOT HLO artifacts through the PJRT
//!   engine, zero-padding each request up to the manifest's shape ladder
//!   (exact; see DESIGN.md "Shape strategy"). This is the production
//!   path: the numerics a real deployment would run are the JAX/Pallas
//!   kernels, not the Rust oracle.
//!
//! [`Backend`] is an enum rather than a trait object so the coordinator's
//! async call-sites need no `async_trait` machinery.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::linalg::{self, Matrix, PanelFactors, ParCtx, TreeStep};
use crate::runtime::EngineHandle;

/// Merge factors returned by [`Backend::tsqr_merge`].
#[derive(Clone, Debug)]
pub struct MergeFactors {
    /// Top reflector block (structurally `I` for triangular inputs).
    pub y0: Matrix,
    /// Bottom reflector block — the `Y₁` of the paper's Algorithm 1/2.
    pub y1: Matrix,
    /// Upper-triangular block reflector factor.
    pub t: Matrix,
    /// Merged upper-triangular factor.
    pub r: Matrix,
}

/// Pure-Rust backend (the linalg oracle) with flop accounting.
#[derive(Default)]
pub struct NativeBackend {
    flops: AtomicU64,
    /// Intra-rank parallel context for the heavy linalg ops. Backend-
    /// scoped (not a process global) so concurrent jobs — service
    /// tenants, campaign trials — each carry their own split without
    /// racing. Defaults to serial; bitwise-identical at any width.
    par: RwLock<ParCtx>,
}

/// PJRT-backed backend: pads to the artifact ladder, executes, crops.
pub struct XlaBackend {
    engine: EngineHandle,
    flops: AtomicU64,
}

/// The compute interface used by every coordinator rank.
pub enum Backend {
    /// Pure-Rust linalg oracle.
    Native(NativeBackend),
    /// PJRT-backed AOT artifacts.
    Xla(XlaBackend),
}

/// Flop-count models (count multiply-adds as 2 flops), used for the
/// paper's energy-overhead experiment (E4) and the §Perf roofline notes.
pub mod flops {
    /// Householder panel QR of (m, b): ~2mb² + accumulation of T (~mb²).
    pub fn panel_qr(m: usize, b: usize) -> u64 {
        (3 * m * b * b) as u64
    }
    /// Merge of two (b, b) triangles: QR of (2b, b).
    pub fn tsqr_merge(b: usize) -> u64 {
        panel_qr(2 * b, b)
    }
    /// W = Tᵀ(YᵀC); Ĉ = C − YW over (m,b)x(m,n): 4mnb + 2nb².
    pub fn leaf_apply(m: usize, b: usize, n: usize) -> u64 {
        (4 * m * n * b + 2 * n * b * b) as u64
    }
    /// Pair step over (b, n) halves: 6nb² + O(nb).
    pub fn tree_update(b: usize, n: usize) -> u64 {
        (6 * n * b * b + 2 * n * b) as u64
    }
    /// Recovery recompute Ĉ = C − YW: 2nb².
    pub fn recover(b: usize, n: usize) -> u64 {
        (2 * n * b * b) as u64
    }
}

impl NativeBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

impl XlaBackend {
    pub fn new(engine: EngineHandle) -> Self {
        Self { engine, flops: AtomicU64::new(0) }
    }

    pub fn engine(&self) -> &EngineHandle {
        &self.engine
    }
}

impl Backend {
    /// Convenience constructors.
    pub fn native() -> Arc<Backend> {
        Arc::new(Backend::Native(NativeBackend::new()))
    }

    pub fn xla(engine: EngineHandle) -> Arc<Backend> {
        Arc::new(Backend::Xla(XlaBackend::new(engine)))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native(_) => "native",
            Backend::Xla(_) => "xla",
        }
    }

    /// Install the intra-rank parallel context used by the native
    /// linalg ops (GEMM band split, blocked-QR trailing update). A
    /// no-op on the XLA backend, whose parallelism lives inside the
    /// PJRT runtime. The split never changes results: every parallel
    /// path is bitwise-identical to the serial one.
    pub fn set_par_ctx(&self, par: ParCtx) {
        if let Backend::Native(b) = self {
            *b.par.write().unwrap() = par;
        }
    }

    /// The backend's current intra-rank parallel context (serial on
    /// XLA and on a freshly constructed native backend).
    pub fn par_ctx(&self) -> ParCtx {
        match self {
            Backend::Native(b) => b.par.read().unwrap().clone(),
            Backend::Xla(_) => ParCtx::serial(),
        }
    }

    /// Cumulative flops issued through this backend.
    pub fn flops(&self) -> u64 {
        match self {
            Backend::Native(b) => b.flops.load(Ordering::Relaxed),
            Backend::Xla(b) => b.flops.load(Ordering::Relaxed),
        }
    }

    fn add_flops(&self, f: u64) {
        match self {
            Backend::Native(b) => b.flops.fetch_add(f, Ordering::Relaxed),
            Backend::Xla(b) => b.flops.fetch_add(f, Ordering::Relaxed),
        };
    }

    /// Local panel factorization `(m, b) → (Y, T, R)`.
    pub fn panel_qr(&self, a: &Matrix) -> Result<PanelFactors> {
        let (m, b) = a.shape();
        self.add_flops(flops::panel_qr(m, b));
        match self {
            Backend::Native(_) => Ok(linalg::householder_qr_par(&self.par_ctx(), a)),
            Backend::Xla(x) => {
                let want = BTreeMap::from([("m", m), ("b", b)]);
                let entry = x.engine.manifest().select("panel_qr", &want)?.clone();
                let (pm, pb) = (entry.params["m"], entry.params["b"]);
                let out = x.engine.exec(&entry, vec![a.pad_to(pm, pb)])?;
                let [y, t, r]: [Matrix; 3] = out
                    .try_into()
                    .map_err(|_| anyhow::anyhow!("panel_qr arity"))?;
                Ok(PanelFactors { y: y.crop_to(m, b), t, r })
            }
        }
    }

    /// TSQR merge step on a pair of `(b, b)` triangles.
    pub fn tsqr_merge(&self, r0: &Matrix, r1: &Matrix) -> Result<MergeFactors> {
        let b = r0.rows();
        self.add_flops(flops::tsqr_merge(b));
        match self {
            Backend::Native(_) => {
                let (y0, y1, t, r) = linalg::tsqr_merge(r0, r1);
                Ok(MergeFactors { y0, y1, t, r })
            }
            Backend::Xla(x) => {
                let want = BTreeMap::from([("b", b)]);
                let entry = x.engine.manifest().select("tsqr_merge", &want)?.clone();
                let out = x.engine.exec(&entry, vec![r0.clone(), r1.clone()])?;
                let [y0, y1, t, r]: [Matrix; 4] = out
                    .try_into()
                    .map_err(|_| anyhow::anyhow!("tsqr_merge arity"))?;
                Ok(MergeFactors { y0, y1, t, r })
            }
        }
    }

    /// Apply local `Qᵀ` to the trailing block **in place** — the
    /// coordinator hot path (no copy of `C` on the native backend; the
    /// XLA path necessarily materializes the artifact output and writes
    /// it back).
    pub fn leaf_apply_into(&self, y: &Matrix, t: &Matrix, c: &mut Matrix) -> Result<()> {
        let n = c.cols();
        self.leaf_apply_cols_into(y, t, c, n)
    }

    /// [`Backend::leaf_apply_into`] on a column segment of a logically
    /// `full_n`-wide trailing block, kernel dispatch pinned to the
    /// full-width op — the lookahead pipeline's segment-by-segment
    /// application is bitwise identical to one full-width call on the
    /// native backend. (The XLA path pads to its shape ladder instead;
    /// cross-`L` bitwise equality is a native-backend guarantee.)
    pub fn leaf_apply_cols_into(
        &self,
        y: &Matrix,
        t: &Matrix,
        c: &mut Matrix,
        full_n: usize,
    ) -> Result<()> {
        match self {
            Backend::Native(_) => {
                let (m, b) = y.shape();
                self.add_flops(flops::leaf_apply(m, b, c.cols()));
                linalg::leaf_apply_cols_into_par(&self.par_ctx(), y, t, c, full_n);
                Ok(())
            }
            Backend::Xla(_) => {
                *c = self.leaf_apply(y, t, c)?;
                Ok(())
            }
        }
    }

    /// One member's half of a pairwise update step, in place: updates the
    /// caller's rows `cp` from the buddy's (read-only) `peer` rows and
    /// returns the retained `W`. Flops are charged at the full pair cost
    /// — both members redundantly compute `W` (the paper's traded energy
    /// cost, E4) — even though the native backend skips the peer's half
    /// of the row update.
    pub fn tree_update_half(
        &self,
        cp: &mut Matrix,
        peer: &Matrix,
        y1: &Matrix,
        t: &Matrix,
        is_top: bool,
    ) -> Result<Matrix> {
        let n = cp.cols();
        self.tree_update_half_cols(cp, peer, y1, t, is_top, n)
    }

    /// [`Backend::tree_update_half`] on a column segment of a logically
    /// `full_n`-wide update, dispatch pinned to the full-width op (see
    /// [`Backend::leaf_apply_cols_into`] for the bitwise contract).
    #[allow(clippy::too_many_arguments)]
    pub fn tree_update_half_cols(
        &self,
        cp: &mut Matrix,
        peer: &Matrix,
        y1: &Matrix,
        t: &Matrix,
        is_top: bool,
        full_n: usize,
    ) -> Result<Matrix> {
        match self {
            Backend::Native(_) => {
                let (b, n) = cp.shape();
                self.add_flops(flops::tree_update(b, n));
                Ok(linalg::tree_update_half_cols_par(
                    &self.par_ctx(),
                    cp,
                    peer,
                    y1,
                    t,
                    is_top,
                    full_n,
                ))
            }
            Backend::Xla(_) => {
                let st = if is_top {
                    self.tree_update(cp, peer, y1, t)?
                } else {
                    self.tree_update(peer, cp, y1, t)?
                };
                *cp = if is_top { st.c0 } else { st.c1 };
                Ok(st.w)
            }
        }
    }

    /// Full pairwise update step in place: both halves updated, `W`
    /// returned (Algorithm 1's top member, which must send the buddy's
    /// updated rows back).
    pub fn tree_update_into(
        &self,
        c0: &mut Matrix,
        c1: &mut Matrix,
        y1: &Matrix,
        t: &Matrix,
    ) -> Result<Matrix> {
        let n = c0.cols();
        self.tree_update_into_cols(c0, c1, y1, t, n)
    }

    /// [`Backend::tree_update_into`] on a column segment of a logically
    /// `full_n`-wide update, dispatch pinned to the full-width op (see
    /// [`Backend::leaf_apply_cols_into`] for the bitwise contract).
    pub fn tree_update_into_cols(
        &self,
        c0: &mut Matrix,
        c1: &mut Matrix,
        y1: &Matrix,
        t: &Matrix,
        full_n: usize,
    ) -> Result<Matrix> {
        match self {
            Backend::Native(_) => {
                let (b, n) = c0.shape();
                self.add_flops(flops::tree_update(b, n));
                Ok(linalg::tree_update_into_cols_par(&self.par_ctx(), c0, c1, y1, t, full_n))
            }
            Backend::Xla(_) => {
                let st = self.tree_update(c0, c1, y1, t)?;
                *c0 = st.c0;
                *c1 = st.c1;
                Ok(st.w)
            }
        }
    }

    /// Top-member recovery `C ← C − W` (the `Y = I` case of the paper's
    /// recovery equation): a plain elementwise subtract on the native
    /// backend — the exact expression the live top half executes, so the
    /// replayed block is bit-identical — and the padded recover artifact
    /// with an explicit identity on XLA.
    pub fn recover_top_into(&self, c: &mut Matrix, w: &Matrix) -> Result<()> {
        match self {
            Backend::Native(_) => {
                let (b, n) = c.shape();
                self.add_flops(flops::recover(b, n));
                c.sub_assign(w);
                Ok(())
            }
            Backend::Xla(_) => {
                let y = Matrix::eye(c.rows());
                *c = self.recover(c, &y, w)?;
                Ok(())
            }
        }
    }

    /// Single-buddy recovery recompute `C ← C − Y W` in place (paper
    /// III-C). Shares the kernel with the live bottom-half update, so
    /// replayed blocks are bit-identical to the originals.
    pub fn recover_into(&self, c: &mut Matrix, y: &Matrix, w: &Matrix) -> Result<()> {
        let n = c.cols();
        self.recover_into_cols(c, y, w, n)
    }

    /// [`Backend::recover_into`] on a column segment of a logically
    /// `full_n`-wide update — replay takes the exact kernel path the live
    /// segmented update took (see [`Backend::leaf_apply_cols_into`]).
    pub fn recover_into_cols(
        &self,
        c: &mut Matrix,
        y: &Matrix,
        w: &Matrix,
        full_n: usize,
    ) -> Result<()> {
        match self {
            Backend::Native(_) => {
                let (b, n) = c.shape();
                self.add_flops(flops::recover(b, n));
                linalg::recover_block_cols_into_par(&self.par_ctx(), c, y, w, full_n);
                Ok(())
            }
            Backend::Xla(_) => {
                *c = self.recover(c, y, w)?;
                Ok(())
            }
        }
    }

    /// Apply local `Qᵀ` to the trailing block.
    pub fn leaf_apply(&self, y: &Matrix, t: &Matrix, c: &Matrix) -> Result<Matrix> {
        let (m, b) = y.shape();
        let n = c.cols();
        self.add_flops(flops::leaf_apply(m, b, n));
        match self {
            Backend::Native(_) => Ok(linalg::leaf_apply(y, t, c)),
            Backend::Xla(x) => {
                let want = BTreeMap::from([("m", m), ("b", b), ("n", n)]);
                let entry = x.engine.manifest().select("leaf_apply", &want)?.clone();
                let (pm, pn) = (entry.params["m"], entry.params["n"]);
                let out = x
                    .engine
                    .exec(&entry, vec![y.pad_to(pm, b), t.clone(), c.pad_to(pm, pn)])
                    ?;
                let [ch]: [Matrix; 1] =
                    out.try_into().map_err(|_| anyhow::anyhow!("leaf_apply arity"))?;
                Ok(ch.crop_to(m, n))
            }
        }
    }

    /// One pairwise trailing-update tree step (paper Alg 1/2).
    pub fn tree_update(
        &self,
        c0: &Matrix,
        c1: &Matrix,
        y1: &Matrix,
        t: &Matrix,
    ) -> Result<TreeStep> {
        let (b, n) = c0.shape();
        self.add_flops(flops::tree_update(b, n));
        match self {
            Backend::Native(_) => Ok(linalg::tree_update(c0, c1, y1, t)),
            Backend::Xla(x) => {
                let want = BTreeMap::from([("b", b), ("n", n)]);
                let entry = x.engine.manifest().select("tree_update", &want)?.clone();
                let pn = entry.params["n"];
                let out = x
                    .engine
                    .exec(
                        &entry,
                        vec![c0.pad_to(b, pn), c1.pad_to(b, pn), y1.clone(), t.clone()],
                    )
                    ?;
                let [w, o0, o1]: [Matrix; 3] =
                    out.try_into().map_err(|_| anyhow::anyhow!("tree_update arity"))?;
                Ok(TreeStep {
                    w: w.crop_to(b, n),
                    c0: o0.crop_to(b, n),
                    c1: o1.crop_to(b, n),
                })
            }
        }
    }

    /// Single-buddy recovery recompute `Ĉ = C − Y W` (paper III-C).
    pub fn recover(&self, c: &Matrix, y: &Matrix, w: &Matrix) -> Result<Matrix> {
        let (b, n) = c.shape();
        self.add_flops(flops::recover(b, n));
        match self {
            Backend::Native(_) => Ok(linalg::recover_block(c, y, w)),
            Backend::Xla(x) => {
                let want = BTreeMap::from([("b", b), ("n", n)]);
                let entry = x.engine.manifest().select("recover", &want)?.clone();
                let pn = entry.params["n"];
                let out = x
                    .engine
                    .exec(
                        &entry,
                        vec![c.pad_to(b, pn), y.clone(), w.pad_to(b, pn)],
                    )
                    ?;
                let [ch]: [Matrix; 1] =
                    out.try_into().map_err(|_| anyhow::anyhow!("recover arity"))?;
                Ok(ch.crop_to(b, n))
            }
        }
    }
}

/// Trait alias kept for documentation: anything that can serve the five
/// ops. (The concrete dispatch goes through [`Backend`].)
pub trait ComputeBackend {}
impl ComputeBackend for Backend {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;

    #[test]
    fn native_backend_matches_linalg() {
        let be = Backend::native();
        let a = Matrix::randn(32, 8, 1);
        let f = be.panel_qr(&a).unwrap();
        let g = linalg::householder_qr(&a);
        assert_eq!(f.r, g.r);
        assert_eq!(be.name(), "native");
        assert!(be.flops() > 0);
    }

    #[test]
    fn inplace_ops_match_copying_ops() {
        let be = Backend::native();
        let f = be.panel_qr(&Matrix::randn(32, 8, 2)).unwrap();
        let c = Matrix::randn(32, 12, 3);
        let want = be.leaf_apply(&f.y, &f.t, &c).unwrap();
        let mut got = c.clone();
        be.leaf_apply_into(&f.y, &f.t, &mut got).unwrap();
        assert_eq!(got, want);

        let r0 = Matrix::randn(8, 8, 4).triu();
        let r1 = Matrix::randn(8, 8, 5).triu();
        let mf = be.tsqr_merge(&r0, &r1).unwrap();
        let c0 = Matrix::randn(8, 10, 6);
        let c1 = Matrix::randn(8, 10, 7);
        let st = be.tree_update(&c0, &c1, &mf.y1, &mf.t).unwrap();
        let mut top = c0.clone();
        let w = be.tree_update_half(&mut top, &c1, &mf.y1, &mf.t, true).unwrap();
        assert_eq!(w, st.w);
        assert_eq!(top, st.c0);
        let mut bot = c1.clone();
        let w2 = be.tree_update_half(&mut bot, &c0, &mf.y1, &mf.t, false).unwrap();
        assert_eq!(w2, st.w);
        assert_eq!(bot, st.c1);

        let mut rec = c1.clone();
        be.recover_into(&mut rec, &mf.y1, &st.w).unwrap();
        assert_eq!(rec, st.c1);

        // Top-member recovery is the live top half's exact expression.
        let mut rec0 = c0.clone();
        be.recover_top_into(&mut rec0, &st.w).unwrap();
        assert_eq!(rec0, st.c0);
    }

    #[test]
    fn par_ctx_backend_matches_serial_bitwise() {
        let serial = Backend::native();
        let par = Backend::native();
        par.set_par_ctx(ParCtx::threads(3));
        assert!(serial.par_ctx().is_serial());
        assert_eq!(par.par_ctx().width(), 3);

        // Tall panel so the blocked-QR trailing update crosses the
        // parallel work threshold.
        let a = Matrix::randn(2048, 128, 9);
        let f0 = serial.panel_qr(&a).unwrap();
        let f1 = par.panel_qr(&a).unwrap();
        assert_eq!(f0.y, f1.y);
        assert_eq!(f0.t, f1.t);
        assert_eq!(f0.r, f1.r);

        // Resetting to serial restores the default context.
        par.set_par_ctx(ParCtx::serial());
        assert!(par.par_ctx().is_serial());
    }

    #[test]
    fn flop_model_monotone() {
        assert!(flops::leaf_apply(128, 32, 512) > flops::leaf_apply(64, 32, 512));
        assert!(flops::tree_update(32, 512) > flops::tree_update(16, 512));
        assert!(flops::tsqr_merge(32) > flops::tsqr_merge(16));
    }
}
