//! E3 bench: recovery cost vs failure position and process count
//! (paper §III-C). Reports the critical-path penalty of one failure,
//! the number of single-buddy fetches, and recovery traffic.

#[path = "common/mod.rs"]
mod common;

use ftcaqr::backend::Backend;
use ftcaqr::config::RunConfig;
use ftcaqr::coordinator::run_caqr_matrix;
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::linalg::Matrix;
use ftcaqr::trace::Trace;

fn main() {
    common::header("E3: recovery cost vs failure panel (P=8, 1024x256, b=32)");
    let cfg = RunConfig { rows: 1024, cols: 256, block: 32, procs: 8, ..Default::default() };
    let a = Matrix::randn(cfg.rows, cfg.cols, 7);
    let clean = run_caqr_matrix(
        cfg.clone(),
        a.clone(),
        Backend::native(),
        FaultPlan::none(),
        Trace::disabled(),
    )
    .unwrap();
    println!("failure-free cp: {:.3} us\n", clean.report.critical_path * 1e6);
    println!(
        "{:>11} {:>12} {:>11} {:>9} {:>13} {:>10}",
        "fail panel", "cp (us)", "overhead", "fetches", "extra bytes", "identical"
    );
    for panel in 0..cfg.panels() {
        let trace = Trace::new();
        let fault = FaultPlan::schedule(vec![ScheduledKill::new(5, panel, 0, Phase::Update)]);
        let out =
            run_caqr_matrix(cfg.clone(), a.clone(), Backend::native(), fault, trace.clone())
                .unwrap();
        if out.report.failures == 0 {
            continue; // site unreachable for this rank/panel
        }
        println!(
            "{panel:>11} {:>12.3} {:>10.2}% {:>9} {:>13} {:>10}",
            out.report.critical_path * 1e6,
            (out.report.critical_path / clean.report.critical_path - 1.0) * 100.0,
            trace.of_kind("recovery_fetch").len(),
            out.report.bytes as i64 - clean.report.bytes as i64,
            out.r == clean.r,
        );
    }

    common::header("E3b: recovery cost vs process count (failure at mid-panel)");
    println!(
        "{:>5} {:>14} {:>14} {:>10} {:>9}",
        "P", "clean cp us", "failed cp us", "overhead", "fetches"
    );
    for procs in [4usize, 8, 16] {
        let cfg = RunConfig {
            rows: procs * 128,
            cols: 256,
            block: 32,
            procs,
            ..Default::default()
        };
        let a = Matrix::randn(cfg.rows, cfg.cols, 11);
        let clean = run_caqr_matrix(
            cfg.clone(),
            a.clone(),
            Backend::native(),
            FaultPlan::none(),
            Trace::disabled(),
        )
        .unwrap();
        let trace = Trace::new();
        let fault =
            FaultPlan::schedule(vec![ScheduledKill::new(procs / 2, 4, 0, Phase::Update)]);
        let out =
            run_caqr_matrix(cfg, a, Backend::native(), fault, trace.clone()).unwrap();
        println!(
            "{procs:>5} {:>14.3} {:>14.3} {:>9.2}% {:>9}",
            clean.report.critical_path * 1e6,
            out.report.critical_path * 1e6,
            (out.report.critical_path / clean.report.critical_path - 1.0) * 100.0,
            trace.of_kind("recovery_fetch").len(),
        );
    }

    common::header("recovery wallclock (one failure, native)");
    let (med, mean, sd) = common::time_case(1, 5, || {
        let cfg =
            RunConfig { rows: 1024, cols: 256, block: 32, procs: 8, ..Default::default() };
        let a = Matrix::randn(cfg.rows, cfg.cols, 7);
        let fault = FaultPlan::schedule(vec![ScheduledKill::new(5, 4, 0, Phase::Update)]);
        let _ = run_caqr_matrix(cfg, a, Backend::native(), fault, Trace::disabled()).unwrap();
    });
    common::row("recovery/P8/1024x256/panel4", med, mean, sd, "");
}
