//! Minimal criterion-style bench harness (offline build: no criterion).
//! Each bench target is a `harness = false` binary that prints a table of
//! median / mean / stddev wallclock per case, plus the simulated-metric
//! columns the paper's experiments report.
//!
//! Shared across every bench (one include, no copy-paste):
//! * timing — [`time_case`], [`wall`], [`fmt_time`]
//! * layout — [`header`], [`row`]
//! * environment — [`pool`], [`smoke`], [`artifacts_present`]
//! * machine-readable output — [`JsonSink`] (hand-rolled JSON, no serde)
#![allow(dead_code)] // each bench includes this module and uses a subset

use std::time::Instant;

/// Run `f` repeatedly and return (median, mean, stddev) seconds.
pub fn time_case<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / samples.len() as f64;
    (median, mean, var.sqrt())
}

/// Wall-clock one invocation of `f`: returns `(f's result, seconds)`.
pub fn wall<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Pretty-print seconds.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

pub fn row(label: &str, med: f64, mean: f64, sd: f64, extra: &str) {
    println!(
        "{label:<44} median {:>10}  mean {:>10}  sd {:>9}  {extra}",
        fmt_time(med),
        fmt_time(mean),
        fmt_time(sd)
    );
}

/// Machine pool width (available parallelism) for scale benches.
pub fn pool() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Reduced-size CI mode: set `FTCAQR_BENCH_SMOKE=1` to shrink sweeps so
/// the bench doubles as a smoke test (see `.github/workflows/ci.yml`,
/// job `bench-smoke`).
pub fn smoke() -> bool {
    std::env::var("FTCAQR_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

/// Guard for XLA-dependent benches.
pub fn artifacts_present() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.txt")
        .exists()
}

/// One JSON field value (hand-rolled: the offline crate set has no serde).
pub enum JsonVal<'a> {
    /// String field.
    S(&'a str),
    /// Float field (written with enough digits to round-trip).
    F(f64),
    /// Integer field.
    I(i64),
}

/// Collects flat JSON records and writes them as an array — to the path
/// in `FTCAQR_BENCH_JSON` if set, else to `<bench>.json` under the crate
/// root. This is the machine-readable channel CI archives so the perf
/// trajectory is tracked across PRs.
pub struct JsonSink {
    records: Vec<String>,
}

impl JsonSink {
    pub fn new() -> Self {
        Self { records: Vec::new() }
    }

    /// Append one flat object.
    pub fn rec(&mut self, fields: &[(&str, JsonVal<'_>)]) {
        let body: Vec<String> = fields
            .iter()
            .map(|(k, v)| {
                let val = match v {
                    JsonVal::S(s) => format!("\"{}\"", escape(s)),
                    JsonVal::F(f) if f.is_finite() => format!("{f:e}"),
                    JsonVal::F(_) => "null".to_string(),
                    JsonVal::I(i) => i.to_string(),
                };
                format!("\"{}\":{}", escape(k), val)
            })
            .collect();
        self.records.push(format!("{{{}}}", body.join(",")));
    }

    /// Write the array and report where it went. Returns the path used.
    pub fn finish(self, bench: &str) -> std::path::PathBuf {
        let path = match std::env::var("FTCAQR_BENCH_JSON") {
            Ok(p) => std::path::PathBuf::from(p),
            Err(_) => std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
                .join(format!("{bench}.json")),
        };
        let body = format!("[\n{}\n]\n", self.records.join(",\n"));
        match std::fs::write(&path, &body) {
            Ok(()) => println!(
                "\njson: {} records -> {}",
                self.records.len(),
                path.display()
            ),
            Err(e) => println!("\njson: write to {} failed: {e}", path.display()),
        }
        path
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}
