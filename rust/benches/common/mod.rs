//! Minimal criterion-style bench harness (offline build: no criterion).
//! Each bench target is a `harness = false` binary that prints a table of
//! median / mean / stddev wallclock per case, plus the simulated-metric
//! columns the paper's experiments report.
//!
//! Shared across every bench (one include, no copy-paste):
//! * timing — [`time_case`], [`wall`], [`fmt_time`]
//! * layout — [`header`], [`row`]
//! * environment — [`pool`], [`smoke`], [`artifacts_present`]
//! * machine-readable output — [`JsonSink`] (hand-rolled JSON, no serde)
#![allow(dead_code)] // each bench includes this module and uses a subset

use std::time::Instant;

/// Run `f` repeatedly and return (median, mean, stddev) seconds.
pub fn time_case<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (f64, f64, f64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / samples.len() as f64;
    (median, mean, var.sqrt())
}

/// Wall-clock one invocation of `f`: returns `(f's result, seconds)`.
pub fn wall<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Pretty-print seconds.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

pub fn row(label: &str, med: f64, mean: f64, sd: f64, extra: &str) {
    println!(
        "{label:<44} median {:>10}  mean {:>10}  sd {:>9}  {extra}",
        fmt_time(med),
        fmt_time(mean),
        fmt_time(sd)
    );
}

/// Machine pool width (available parallelism) for scale benches.
pub fn pool() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Reduced-size CI mode: set `FTCAQR_BENCH_SMOKE=1` to shrink sweeps so
/// the bench doubles as a smoke test (see `.github/workflows/ci.yml`,
/// job `bench-smoke`).
pub fn smoke() -> bool {
    std::env::var("FTCAQR_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

/// Guard for XLA-dependent benches.
pub fn artifacts_present() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.txt")
        .exists()
}

/// Machine-readable output: one shared implementation in the library
/// (the `campaign` subcommand writes the same format) — re-exported here
/// so every bench keeps its `common::JsonSink` spelling.
pub use ftcaqr::metrics::json::{JsonSink, JsonVal};
