//! E2 + E4 bench: trailing-matrix update — Algorithm 1 (plain) vs
//! Algorithm 2 (FT). Critical path (dual- and single-channel), message
//! pattern, bytes, and the energy proxy (flops, paper C4).

#[path = "common/mod.rs"]
mod common;

use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::run_caqr_simple;
use ftcaqr::sim::CostModel;

fn cfg(procs: usize, cols: usize, alg: Algorithm, cost: CostModel) -> RunConfig {
    RunConfig {
        rows: procs * 128,
        cols,
        block: 32,
        procs,
        algorithm: alg,
        cost,
        verify: false,
        ..Default::default()
    }
}

fn main() {
    common::header("E2: update-tree overhead, Alg 2 (FT) vs Alg 1 (plain), dual-channel");
    println!(
        "{:>5} {:>6} | {:>12} {:>12} {:>8} | {:>8} {:>8} | {:>12} {:>12} | {:>9}",
        "P", "cols", "cp plain us", "cp ft us", "ratio", "msgs", "exchs", "bytes plain", "bytes ft", "flop f/p"
    );
    for procs in [2usize, 4, 8, 16, 32] {
        for cols in [128usize, 256, 512] {
            if cols > procs * 128 {
                continue;
            }
            let p = run_caqr_simple(cfg(procs, cols, Algorithm::Plain, CostModel::default()))
                .unwrap();
            let f = run_caqr_simple(cfg(
                procs,
                cols,
                Algorithm::FaultTolerant,
                CostModel::default(),
            ))
            .unwrap();
            println!(
                "{procs:>5} {cols:>6} | {:>12.3} {:>12.3} {:>8.3} | {:>8} {:>8} | {:>12} {:>12} | {:>9.3}",
                p.report.critical_path * 1e6,
                f.report.critical_path * 1e6,
                f.report.critical_path / p.report.critical_path,
                p.report.messages,
                f.report.exchanges,
                p.report.bytes,
                f.report.bytes,
                f.backend_flops as f64 / p.backend_flops as f64,
            );
        }
    }

    common::header("E2b: same, single-channel links (overlap assumption removed)");
    println!("{:>5} {:>6} | {:>12} {:>12} {:>8}", "P", "cols", "cp plain us", "cp ft us", "ratio");
    for procs in [4usize, 8, 16] {
        let cols = 256;
        let p = run_caqr_simple(cfg(procs, cols, Algorithm::Plain, CostModel::single_channel()))
            .unwrap();
        let f = run_caqr_simple(cfg(
            procs,
            cols,
            Algorithm::FaultTolerant,
            CostModel::single_channel(),
        ))
        .unwrap();
        println!(
            "{procs:>5} {cols:>6} | {:>12.3} {:>12.3} {:>8.3}",
            p.report.critical_path * 1e6,
            f.report.critical_path * 1e6,
            f.report.critical_path / p.report.critical_path,
        );
    }

    common::header("E4: energy proxy — flops by algorithm (both buddies compute in FT)");
    println!("{:>5} {:>6} | {:>14} {:>14} {:>9}", "P", "cols", "flops plain", "flops ft", "overhead");
    for procs in [4usize, 8, 16] {
        for cols in [128usize, 256] {
            let p = run_caqr_simple(cfg(procs, cols, Algorithm::Plain, CostModel::default()))
                .unwrap();
            let f = run_caqr_simple(cfg(
                procs,
                cols,
                Algorithm::FaultTolerant,
                CostModel::default(),
            ))
            .unwrap();
            println!(
                "{procs:>5} {cols:>6} | {:>14} {:>14} {:>8.1}%",
                p.backend_flops,
                f.backend_flops,
                (f.backend_flops as f64 / p.backend_flops as f64 - 1.0) * 100.0
            );
        }
    }

    common::header("update wallclock (native)");
    for alg in [Algorithm::Plain, Algorithm::FaultTolerant] {
        let c = cfg(8, 256, alg, CostModel::default());
        let (med, mean, sd) =
            common::time_case(1, 5, || drop(run_caqr_simple(c.clone()).unwrap()));
        common::row(&format!("caqr/{alg:?}/P8/1024x256"), med, mean, sd, "");
    }
}
