//! E7 bench: the paper's ABFT scheme vs diskless checkpointing (§II).
//!
//! Failure-free overhead is measured from real runs (checkpoint traffic
//! flows through the simulated fabric); recovery cost for checkpointing
//! uses the rollback model calibrated with the measured per-panel time,
//! compared against the measured ABFT single-failure recovery.

#[path = "common/mod.rs"]
mod common;

use ftcaqr::backend::Backend;
use ftcaqr::checkpoint::CheckpointModel;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::run_caqr_matrix;
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::linalg::Matrix;
use ftcaqr::trace::Trace;

fn main() {
    let procs = 8usize;
    let cfg0 = RunConfig {
        rows: 1024,
        cols: 256,
        block: 32,
        procs,
        verify: false,
        ..Default::default()
    };
    let a = Matrix::randn(cfg0.rows, cfg0.cols, 3);
    let run = |cfg: RunConfig, fault| {
        run_caqr_matrix(cfg, a.clone(), Backend::native(), fault, Trace::disabled()).unwrap()
    };

    common::header("E7: failure-free overhead — ABFT (Alg 2) vs diskless checkpointing");
    let plain = run(RunConfig { algorithm: Algorithm::Plain, ..cfg0.clone() }, FaultPlan::none());
    let abft = run(cfg0.clone(), FaultPlan::none());
    println!(
        "{:<26} cp {:>10.3} us   bytes {:>10}   mem {:>10}",
        "baseline (Alg 1)",
        plain.report.critical_path * 1e6,
        plain.report.bytes,
        0
    );
    println!(
        "{:<26} cp {:>10.3} us   bytes {:>10}   mem {:>10}",
        "ABFT (Alg 2, paper)",
        abft.report.critical_path * 1e6,
        abft.report.bytes,
        abft.store_peak_bytes
    );
    for interval in [1usize, 2, 4] {
        let c = RunConfig {
            algorithm: Algorithm::Plain,
            checkpoint_every: interval,
            ..cfg0.clone()
        };
        let out = run(c, FaultPlan::none());
        let state_bytes = cfg0.local_rows() * cfg0.cols * 4;
        println!(
            "{:<26} cp {:>10.3} us   bytes {:>10}   mem {:>10}",
            format!("ckpt every {interval} panel(s)"),
            out.report.critical_path * 1e6,
            out.report.bytes,
            state_bytes
        );
    }

    common::header("E7b: recovery cost — measured ABFT vs modeled rollback");
    let panels = cfg0.panels();
    let per_panel = plain.report.critical_path / panels as f64;
    let state_bytes = cfg0.local_rows() * cfg0.cols * 4;
    println!(
        "{:>11} | {:>16} | {:>14} {:>14} {:>14}",
        "fail panel", "ABFT cp-overhead", "ckpt i=1", "ckpt i=2", "ckpt i=4"
    );
    for panel in [1usize, 3, 5, 7] {
        let fault = FaultPlan::schedule(vec![ScheduledKill::new(5, panel, 0, Phase::Update)]);
        let failed = run(cfg0.clone(), fault);
        if failed.report.failures == 0 {
            continue;
        }
        let abft_overhead = failed.report.critical_path - abft.report.critical_path;
        let model = |interval| {
            CheckpointModel {
                interval,
                state_bytes,
                seconds_per_panel: per_panel,
                alpha: cfg0.cost.alpha,
                beta: cfg0.cost.beta,
            }
            .rollback(panel)
            .total_seconds
        };
        println!(
            "{panel:>11} | {:>13.3} us | {:>11.3} us {:>11.3} us {:>11.3} us",
            abft_overhead.max(0.0) * 1e6,
            model(1) * 1e6,
            model(2) * 1e6,
            model(4) * 1e6,
        );
    }
    println!(
        "\nABFT recovery touches only the failed rank's history (one buddy per\n\
         step); checkpoint rollback re-executes whole panels on ALL ranks and\n\
         loses up to interval-1 panels of work — the paper's §II motivation."
    );
}
