//! Scale bench: the pooled scheduler driving P = 64…512 simulated ranks
//! on a fixed-size worker pool (no thread-per-rank), plus multi-failure
//! CAQR recovery at large P.
//!
//! This is the tentpole demonstration for the ROADMAP's "heavy traffic,
//! fast as the hardware allows" direction: rank bodies are resumable
//! tasks that park on communication, so the simulated world is bounded
//! by memory, not by OS threads.
//!
//! ```text
//! cargo bench --bench scale
//! ```

#[path = "common/mod.rs"]
mod common;

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::{run_caqr_matrix, run_tsqr_pooled, TsqrMode};
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::linalg::Matrix;
use ftcaqr::sim::CostModel;
use ftcaqr::trace::Trace;

fn tsqr_sweep() {
    let workers = common::pool();
    common::header(&format!(
        "FT-TSQR scale sweep on a fixed {workers}-worker pool (no thread-per-rank)"
    ));
    println!(
        "{:>6} {:>4} {:>9} | {:>12} {:>10} {:>12} | {:>12} {:>12}",
        "procs", "b", "workers", "wall", "exchs", "cp (us)", "redund[last]", "holders"
    );
    for procs in [64usize, 128, 256, 512] {
        let b = 8usize;
        let m_local = 8usize;
        let a = Matrix::randn(procs * m_local, b, 99);
        let be = Backend::native();
        let (out, wall) = common::wall(|| {
            run_tsqr_pooled(&a, procs, TsqrMode::FaultTolerant, be, CostModel::default(), workers)
                .expect("ft-tsqr sweep")
        });
        assert_eq!(
            out.final_holders, procs,
            "every rank must finish holding the final R"
        );
        println!(
            "{procs:>6} {b:>4} {workers:>9} | {:>12} {:>10} {:>12.3} | {:>12} {:>12}",
            common::fmt_time(wall),
            out.report.exchanges,
            out.report.critical_path * 1e6,
            out.redundancy.last().copied().unwrap_or(0),
            out.final_holders,
        );
    }
    println!("\nP=512 ranks complete on {workers} pool threads: parked tasks");
    println!("cost a queue slot, not an OS thread.");
}

fn caqr_multi_failure() {
    common::header("multi-failure FT-CAQR at scale (k=3 kills, Gram-verified)");
    println!(
        "{:>6} {:>11} {:>7} | {:>12} {:>9} {:>9} {:>12} {:>11}",
        "procs", "matrix", "kills", "wall", "fails", "recov", "cp (us)", "residual"
    );
    for procs in [64usize, 128] {
        let b = 8usize;
        let cfg = RunConfig {
            rows: procs * 2 * b,
            cols: 4 * b,
            block: b,
            procs,
            algorithm: Algorithm::FaultTolerant,
            verify: true,
            ..Default::default()
        };
        let a = Matrix::randn(cfg.rows, cfg.cols, 7);
        // k = 3 independent kills spread across panels, phases and the
        // tree: disjoint failures must all recover in one run.
        let kills = vec![
            ScheduledKill::new(procs / 3, 0, 0, Phase::Update),
            ScheduledKill::new(procs / 2, 1, 1, Phase::Tsqr),
            ScheduledKill::new(procs - 2, 2, 0, Phase::Update),
        ];
        let nkills = kills.len();
        let (out, wall) = common::wall(|| {
            run_caqr_matrix(
                cfg.clone(),
                a,
                Backend::native(),
                FaultPlan::schedule(kills),
                Trace::disabled(),
            )
            .expect("multi-failure CAQR run")
        });
        let res = out.residual.expect("verify on");
        assert!(
            res < 1e-3,
            "P={procs}: Gram residual {res} too large after multi-failure recovery"
        );
        assert_eq!(out.report.failures, nkills as u64, "P={procs}");
        println!(
            "{procs:>6} {:>11} {:>7} | {:>12} {:>9} {:>9} {:>12.3} {:>11.2e}",
            format!("{}x{}", cfg.rows, cfg.cols),
            nkills,
            common::fmt_time(wall),
            out.report.failures,
            out.report.recoveries,
            out.report.critical_path * 1e6,
            res,
        );
    }
    println!("\nEvery failed rank was rebuilt from single-buddy retained state;");
    println!("the Gram identity held after all recoveries.");
}

fn main() {
    tsqr_sweep();
    caqr_multi_failure();
}
