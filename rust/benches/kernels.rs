//! Kernel-level bench (§Perf L1/L2): per-op latency of the AOT JAX/Pallas
//! artifacts through PJRT vs the native oracle, plus engine
//! compile-vs-exec accounting. This is the profile that drives the
//! performance pass.

#[path = "common/mod.rs"]
mod common;

use std::collections::BTreeMap;

use ftcaqr::backend::Backend;
use ftcaqr::linalg::{self, Matrix};
use ftcaqr::runtime::Engine;

fn main() {
    common::header("kernel micro-bench: native oracle");
    let a128 = Matrix::randn(128, 32, 1);
    let (med, mean, sd) = common::time_case(3, 15, || {
        let _ = linalg::householder_qr(&a128);
    });
    common::row("native/panel_qr/128x32", med, mean, sd, "");
    let f = linalg::householder_qr(&a128);
    let c = Matrix::randn(128, 512, 2);
    let (med, mean, sd) = common::time_case(3, 15, || {
        let _ = linalg::leaf_apply(&f.y, &f.t, &c);
    });
    let flops = ftcaqr::backend::flops::leaf_apply(128, 32, 512) as f64;
    common::row(
        "native/leaf_apply/128x32x512",
        med,
        mean,
        sd,
        &format!("{:.2} GFLOP/s", flops / med / 1e9),
    );
    let r0 = Matrix::randn(32, 32, 3).triu();
    let r1 = Matrix::randn(32, 32, 4).triu();
    let (med, mean, sd) = common::time_case(3, 15, || {
        let _ = linalg::tsqr_merge(&r0, &r1);
    });
    common::row("native/tsqr_merge/b32", med, mean, sd, "");

    if !common::artifacts_present() {
        println!("\n(artifacts/ missing — skipping XLA kernel rows)");
        return;
    }
    common::header("kernel micro-bench: XLA artifacts (PJRT CPU, interpret-mode Pallas)");
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = Engine::start(&dir).unwrap();
    let xla = Backend::xla(engine.clone());

    // Warm the cache first so compile time is excluded from the rows.
    let _ = xla.panel_qr(&a128).unwrap();
    let _ = xla.leaf_apply(&f.y, &f.t, &c).unwrap();
    let _ = xla.tsqr_merge(&r0, &r1).unwrap();
    let st = linalg::tree_update(
        &Matrix::randn(32, 512, 5),
        &Matrix::randn(32, 512, 6),
        &r1,
        &f.t.crop_to(32, 32),
    );

    let (med, mean, sd) = common::time_case(2, 10, || {
        let _ = xla.panel_qr(&a128).unwrap();
    });
    common::row("xla/panel_qr/128x32", med, mean, sd, "");
    let (med, mean, sd) = common::time_case(2, 10, || {
        let _ = xla.leaf_apply(&f.y, &f.t, &c).unwrap();
    });
    common::row(
        "xla/leaf_apply/128x32x512",
        med,
        mean,
        sd,
        &format!("{:.2} GFLOP/s", flops / med / 1e9),
    );
    let (med, mean, sd) = common::time_case(2, 10, || {
        let _ = xla.tsqr_merge(&r0, &r1).unwrap();
    });
    common::row("xla/tsqr_merge/b32", med, mean, sd, "");
    let c0 = Matrix::randn(32, 512, 7);
    let c1 = Matrix::randn(32, 512, 8);
    let (med, mean, sd) = common::time_case(2, 10, || {
        let _ = xla.tree_update(&c0, &c1, &r1, &st.w.crop_to(32, 32)).unwrap();
    });
    common::row("xla/tree_update/b32xn512", med, mean, sd, "");

    // Raw engine exec (no pad/crop) to isolate runtime overhead.
    let want = BTreeMap::from([("b", 32usize), ("n", 512usize)]);
    let entry = engine.manifest().select("tree_update", &want).unwrap().clone();
    let y1 = r1.clone();
    let t32 = st.w.crop_to(32, 32);
    let (med, mean, sd) = common::time_case(2, 10, || {
        let _ = engine
            .exec(&entry, vec![c0.clone(), c1.clone(), y1.clone(), t32.clone()])
            .unwrap();
    });
    common::row("xla/raw_exec/tree_update", med, mean, sd, "");

    let (execs, compiles, exec_s, compile_s) = engine.stats().snapshot();
    println!(
        "\nengine totals: {execs} execs ({:.3} ms avg), {compiles} compiles ({:.1} ms avg)",
        exec_s / execs.max(1) as f64 * 1e3,
        compile_s / compiles.max(1) as f64 * 1e3
    );
}
