//! Kernel-level bench (§Perf L1/L2): the compute hot path before/after
//! the tiled rewrite, plus per-op latency of the AOT JAX/Pallas artifacts
//! through PJRT vs the native oracle.
//!
//! Sections:
//! * GEMM n x n x n sweep (64..1024): pre-tile ikj reference
//!   (`gemm_ref_into`) vs tiled/packed kernel, GFLOP/s and speedup.
//! * SIMD sweep (64..1024): scalar micro-kernel vs the runtime-detected
//!   best SIMD level vs the pool-split parallel path, GFLOP/s each.
//!   **This sweep is also the CI bitwise gate** (runs in smoke mode):
//!   every SIMD level and the parallel split must reproduce the scalar
//!   serial product bit-for-bit, or the bench aborts and `bench-smoke`
//!   fails.
//! * Panel QR: scalar reference (`householder_qr_ref`) vs blocked.
//! * tree_update: clone-returning pair step vs in-place half update.
//! * Optional GEMM band-split sweep (`ParCtx::threads`).
//! * XLA artifact rows (engine compile-vs-exec accounting) when present.
//!
//! Every row is also emitted as a JSON record (`FTCAQR_BENCH_JSON`, CI's
//! `bench-smoke` artifact), so the perf trajectory is tracked from this
//! PR on. `FTCAQR_BENCH_SMOKE=1` shrinks the sweep for CI.

#[path = "common/mod.rs"]
mod common;

use std::collections::BTreeMap;

use common::JsonVal::{F, I, S};

use ftcaqr::backend::Backend;
use ftcaqr::linalg::{
    self, gemm_into, gemm_ref_into, gemm_view_into_par, gemm_view_into_with, gemm_with,
    Matrix, ParCtx, SimdLevel, Trans,
};
use ftcaqr::runtime::Engine;

fn gemm_sweep(sink: &mut common::JsonSink) {
    common::header("GEMM n x n x n: pre-tile ikj reference vs tiled/packed (1 thread)");
    println!(
        "{:>6} | {:>12} {:>12} | {:>10} {:>10} | {:>8}",
        "n", "ref med", "tiled med", "ref GF/s", "tile GF/s", "speedup"
    );
    let sizes: &[usize] =
        if common::smoke() { &[64, 128] } else { &[64, 128, 256, 512, 1024] };
    for &n in sizes {
        let a = Matrix::randn(n, n, 1);
        let b = Matrix::randn(n, n, 2);
        let mut c = Matrix::zeros(n, n);
        let iters = if n >= 512 { 3 } else { 9 };
        let (ref_med, _, _) = common::time_case(1, iters, || {
            gemm_ref_into(Trans::No, Trans::No, 1.0, &a, &b, 0.0, &mut c)
        });
        let (tile_med, _, _) = common::time_case(1, iters, || {
            gemm_into(Trans::No, Trans::No, 1.0, &a, &b, 0.0, &mut c)
        });
        let flops = 2.0 * (n as f64).powi(3);
        let (gf_ref, gf_tile) = (flops / ref_med / 1e9, flops / tile_med / 1e9);
        let speedup = ref_med / tile_med;
        println!(
            "{n:>6} | {:>12} {:>12} | {gf_ref:>10.2} {gf_tile:>10.2} | {speedup:>7.2}x",
            common::fmt_time(ref_med),
            common::fmt_time(tile_med),
        );
        sink.rec(&[
            ("bench", S("gemm")),
            ("n", I(n as i64)),
            ("ref_s", F(ref_med)),
            ("tiled_s", F(tile_med)),
            ("ref_gflops", F(gf_ref)),
            ("tiled_gflops", F(gf_tile)),
            ("speedup", F(speedup)),
        ]);
    }
}

/// Scalar vs best-SIMD vs pool-split parallel GEMM, plus the bitwise
/// gate: every level and the parallel split must equal the scalar serial
/// product bit-for-bit (the determinism contract the whole replay /
/// lookahead machinery rests on). Runs in smoke mode — this is the CI
/// regression gate for the SIMD kernels.
fn simd_sweep(sink: &mut common::JsonSink) {
    let best = SimdLevel::best();
    let threads = common::pool().min(4);
    common::header(&format!(
        "GEMM n x n x n: scalar vs SIMD ({}) vs parallel ({threads} bands) — bitwise-gated",
        best.name()
    ));
    println!(
        "{:>6} | {:>10} {:>10} {:>10} | {:>8} {:>8}",
        "n", "scal GF/s", "simd GF/s", "par GF/s", "simd x", "par x"
    );
    let sizes: &[usize] =
        if common::smoke() { &[64, 128] } else { &[64, 128, 256, 512, 1024] };
    for &n in sizes {
        let a = Matrix::randn(n, n, 1);
        let b = Matrix::randn(n, n, 2);

        // Bitwise gate first: every available SIMD level and the band
        // split must reproduce the scalar serial product exactly.
        let serial = ParCtx::serial();
        let want = gemm_with(&serial, SimdLevel::Scalar, Trans::No, Trans::No, 1.0, &a, &b);
        for lvl in SimdLevel::available() {
            let got = gemm_with(&serial, lvl, Trans::No, Trans::No, 1.0, &a, &b);
            assert_eq!(
                got,
                want,
                "SIMD level {} diverged bitwise from scalar at n={n}",
                lvl.name()
            );
        }
        let par = ParCtx::threads(threads);
        let got = gemm_with(&par, best, Trans::No, Trans::No, 1.0, &a, &b);
        assert_eq!(got, want, "parallel GEMM diverged bitwise from scalar at n={n}");

        let mut c = Matrix::zeros(n, n);
        let iters = if n >= 512 { 3 } else { 9 };
        let (scal_med, _, _) = common::time_case(1, iters, || {
            gemm_view_into_with(
                &serial,
                SimdLevel::Scalar,
                Trans::No,
                Trans::No,
                1.0,
                a.as_view(),
                b.as_view(),
                0.0,
                c.as_view_mut(),
            )
        });
        let (simd_med, _, _) = common::time_case(1, iters, || {
            gemm_view_into_with(
                &serial,
                best,
                Trans::No,
                Trans::No,
                1.0,
                a.as_view(),
                b.as_view(),
                0.0,
                c.as_view_mut(),
            )
        });
        let (par_med, _, _) = common::time_case(1, iters, || {
            gemm_view_into_par(
                &par,
                Trans::No,
                Trans::No,
                1.0,
                a.as_view(),
                b.as_view(),
                0.0,
                c.as_view_mut(),
            )
        });
        let flops = 2.0 * (n as f64).powi(3);
        let (gf_scal, gf_simd, gf_par) =
            (flops / scal_med / 1e9, flops / simd_med / 1e9, flops / par_med / 1e9);
        println!(
            "{n:>6} | {gf_scal:>10.2} {gf_simd:>10.2} {gf_par:>10.2} | {:>7.2}x {:>7.2}x",
            scal_med / simd_med,
            scal_med / par_med,
        );
        sink.rec(&[
            ("bench", S("gemm_simd")),
            ("n", I(n as i64)),
            ("simd", S(best.name())),
            ("threads", I(threads as i64)),
            ("scalar_s", F(scal_med)),
            ("simd_s", F(simd_med)),
            ("par_s", F(par_med)),
            ("scalar_gflops", F(gf_scal)),
            ("simd_gflops", F(gf_simd)),
            ("par_gflops", F(gf_par)),
        ]);
    }
    println!("bitwise gate: all SIMD levels and the band split match scalar exactly");
}

fn panel_qr_sweep(sink: &mut common::JsonSink) {
    common::header("panel QR (m x b): scalar reference vs blocked level-3");
    println!(
        "{:>12} | {:>12} {:>12} | {:>8}",
        "m x b", "ref med", "blocked med", "speedup"
    );
    let shapes: &[(usize, usize)] = if common::smoke() {
        &[(128, 32)]
    } else {
        &[(128, 32), (256, 64), (512, 64), (1024, 128)]
    };
    for &(m, b) in shapes {
        let a = Matrix::randn(m, b, 3);
        let iters = if m >= 512 { 3 } else { 9 };
        let (ref_med, _, _) = common::time_case(1, iters, || {
            let _ = linalg::householder_qr_ref(&a);
        });
        let (blk_med, _, _) = common::time_case(1, iters, || {
            let _ = linalg::householder_qr(&a);
        });
        let speedup = ref_med / blk_med;
        println!(
            "{:>12} | {:>12} {:>12} | {speedup:>7.2}x",
            format!("{m}x{b}"),
            common::fmt_time(ref_med),
            common::fmt_time(blk_med),
        );
        sink.rec(&[
            ("bench", S("panel_qr")),
            ("m", I(m as i64)),
            ("b", I(b as i64)),
            ("ref_s", F(ref_med)),
            ("blocked_s", F(blk_med)),
            ("speedup", F(speedup)),
        ]);
    }
}

fn tree_update_sweep(sink: &mut common::JsonSink) {
    common::header("tree_update (b=32): clone-returning pair step vs in-place half");
    println!(
        "{:>6} | {:>12} {:>12} | {:>8}",
        "n", "full med", "half med", "speedup"
    );
    let b = 32usize;
    let r0 = Matrix::randn(b, b, 4).triu();
    let r1 = Matrix::randn(b, b, 5).triu();
    let (_y0, y1, t, _r) = linalg::tsqr_merge(&r0, &r1);
    let sizes: &[usize] = if common::smoke() { &[256] } else { &[256, 1024, 4096] };
    for &n in sizes {
        let c0 = Matrix::randn(b, n, 6);
        let c1 = Matrix::randn(b, n, 7);
        let iters = if n >= 4096 { 5 } else { 11 };
        let (full_med, _, _) = common::time_case(1, iters, || {
            let _ = linalg::tree_update(&c0, &c1, &y1, &t);
        });
        // The in-place half still pays one clone here so each iteration
        // starts from the same rows — the live coordinator pays none.
        let (half_med, _, _) = common::time_case(1, iters, || {
            let mut cp = c0.clone();
            let _ = linalg::tree_update_half(&mut cp, &c1, &y1, &t, true);
        });
        let speedup = full_med / half_med;
        println!(
            "{n:>6} | {:>12} {:>12} | {speedup:>7.2}x",
            common::fmt_time(full_med),
            common::fmt_time(half_med),
        );
        sink.rec(&[
            ("bench", S("tree_update")),
            ("b", I(b as i64)),
            ("n", I(n as i64)),
            ("full_s", F(full_med)),
            ("half_s", F(half_med)),
            ("speedup", F(speedup)),
        ]);
    }
}

fn par_sweep(sink: &mut common::JsonSink) {
    let n = 1024usize;
    common::header("GEMM band split (ParCtx::threads), n=1024");
    println!("{:>8} | {:>12} | {:>10}", "threads", "median", "GF/s");
    let a = Matrix::randn(n, n, 1);
    let b = Matrix::randn(n, n, 2);
    let mut c = Matrix::zeros(n, n);
    let flops = 2.0 * (n as f64).powi(3);
    for threads in [1usize, 2, 4] {
        if threads > common::pool() {
            continue;
        }
        let par = ParCtx::threads(threads);
        let (med, _, _) = common::time_case(1, 3, || {
            gemm_view_into_par(
                &par,
                Trans::No,
                Trans::No,
                1.0,
                a.as_view(),
                b.as_view(),
                0.0,
                c.as_view_mut(),
            )
        });
        println!(
            "{threads:>8} | {:>12} | {:>10.2}",
            common::fmt_time(med),
            flops / med / 1e9
        );
        sink.rec(&[
            ("bench", S("gemm_par")),
            ("n", I(n as i64)),
            ("threads", I(threads as i64)),
            ("tiled_s", F(med)),
            ("tiled_gflops", F(flops / med / 1e9)),
        ]);
    }
}

fn xla_rows() {
    if !common::artifacts_present() {
        println!("\n(artifacts/ missing — skipping XLA kernel rows)");
        return;
    }
    common::header("kernel micro-bench: XLA artifacts (PJRT CPU, interpret-mode Pallas)");
    let a128 = Matrix::randn(128, 32, 1);
    let f = linalg::householder_qr(&a128);
    let c = Matrix::randn(128, 512, 2);
    let r0 = Matrix::randn(32, 32, 3).triu();
    let r1 = Matrix::randn(32, 32, 4).triu();
    let flops = ftcaqr::backend::flops::leaf_apply(128, 32, 512) as f64;

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = Engine::start(&dir).unwrap();
    let xla = Backend::xla(engine.clone());

    // Warm the cache first so compile time is excluded from the rows.
    let _ = xla.panel_qr(&a128).unwrap();
    let _ = xla.leaf_apply(&f.y, &f.t, &c).unwrap();
    let _ = xla.tsqr_merge(&r0, &r1).unwrap();
    let st = linalg::tree_update(
        &Matrix::randn(32, 512, 5),
        &Matrix::randn(32, 512, 6),
        &r1,
        &f.t.crop_to(32, 32),
    );

    let (med, mean, sd) = common::time_case(2, 10, || {
        let _ = xla.panel_qr(&a128).unwrap();
    });
    common::row("xla/panel_qr/128x32", med, mean, sd, "");
    let (med, mean, sd) = common::time_case(2, 10, || {
        let _ = xla.leaf_apply(&f.y, &f.t, &c).unwrap();
    });
    common::row(
        "xla/leaf_apply/128x32x512",
        med,
        mean,
        sd,
        &format!("{:.2} GFLOP/s", flops / med / 1e9),
    );
    let (med, mean, sd) = common::time_case(2, 10, || {
        let _ = xla.tsqr_merge(&r0, &r1).unwrap();
    });
    common::row("xla/tsqr_merge/b32", med, mean, sd, "");
    let c0 = Matrix::randn(32, 512, 7);
    let c1 = Matrix::randn(32, 512, 8);
    let (med, mean, sd) = common::time_case(2, 10, || {
        let _ = xla.tree_update(&c0, &c1, &r1, &st.w.crop_to(32, 32)).unwrap();
    });
    common::row("xla/tree_update/b32xn512", med, mean, sd, "");

    // Raw engine exec (no pad/crop) to isolate runtime overhead.
    let want = BTreeMap::from([("b", 32usize), ("n", 512usize)]);
    let entry = engine.manifest().select("tree_update", &want).unwrap().clone();
    let y1 = r1.clone();
    let t32 = st.w.crop_to(32, 32);
    let (med, mean, sd) = common::time_case(2, 10, || {
        let _ = engine
            .exec(&entry, vec![c0.clone(), c1.clone(), y1.clone(), t32.clone()])
            .unwrap();
    });
    common::row("xla/raw_exec/tree_update", med, mean, sd, "");

    let (execs, compiles, exec_s, compile_s) = engine.stats().snapshot();
    println!(
        "\nengine totals: {execs} execs ({:.3} ms avg), {compiles} compiles ({:.1} ms avg)",
        exec_s / execs.max(1) as f64 * 1e3,
        compile_s / compiles.max(1) as f64 * 1e3
    );
}

fn main() {
    let mut sink = common::JsonSink::new();
    gemm_sweep(&mut sink);
    // Always runs: the SIMD sweep doubles as the CI bitwise gate.
    simd_sweep(&mut sink);
    panel_qr_sweep(&mut sink);
    tree_update_sweep(&mut sink);
    if !common::smoke() {
        par_sweep(&mut sink);
        xla_rows();
    }
    sink.finish("kernels");
}
