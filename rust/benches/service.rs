//! Service-level throughput bench: many concurrent (FT-)CAQR/TSQR jobs
//! multiplexed over one persistent pool.
//!
//! Sections:
//! * Throughput sweep — a mixed workload (two CAQR shapes + one
//!   tall-skinny TSQR shape) at several pool widths, failure-free and
//!   with recoverable kills injected into a subset of the CAQR jobs;
//!   reports jobs/sec and p50/p99 end-to-end job latency.
//! * Batched lane — the same burst of same-shape TSQR jobs with
//!   batching off vs on, showing the per-step message amortization.
//!
//! Every row is also emitted as a JSON record (`FTCAQR_BENCH_JSON`,
//! CI's `service-smoke` artifact) in the same machine-readable format as
//! `benches/kernels.rs`. `FTCAQR_BENCH_SMOKE=1` shrinks the sweep.
//!
//! ```text
//! cargo bench --bench service
//! ```

#[path = "common/mod.rs"]
mod common;

use std::time::Instant;

use common::JsonVal::{F, I};

use ftcaqr::config::RunConfig;
use ftcaqr::coordinator::TsqrMode;
use ftcaqr::fault::{Phase, ScheduledKill};
use ftcaqr::service::{seed_for, JobOutcome, JobSpec, Service, ServiceConfig};

/// Mixed workload: small 4-rank CAQR, medium 8-rank CAQR, 16-rank FT
/// TSQR — seeds derived per job index so every run is reproducible.
/// With `faults`, every fourth CAQR job gets one recoverable kill.
fn mixed_jobs(n: usize, faults: bool) -> Vec<JobSpec> {
    (0..n)
        .map(|i| {
            let seed = seed_for(0xC0FFEE, i as u64);
            let kills = if faults && i % 4 == 0 {
                vec![ScheduledKill::new(1, 0, 0, Phase::Update)]
            } else {
                Vec::new()
            };
            match i % 3 {
                0 => JobSpec::Caqr {
                    cfg: RunConfig {
                        rows: 128,
                        cols: 32,
                        block: 16,
                        procs: 4,
                        seed,
                        verify: false,
                        ..Default::default()
                    },
                    kills,
                },
                1 => JobSpec::Caqr {
                    cfg: RunConfig {
                        rows: 256,
                        cols: 64,
                        block: 16,
                        procs: 8,
                        seed,
                        verify: false,
                        ..Default::default()
                    },
                    kills,
                },
                _ => JobSpec::Tsqr {
                    rows: 128,
                    block: 8,
                    procs: 16,
                    mode: TsqrMode::FaultTolerant,
                    seed,
                },
            }
        })
        .collect()
}

fn pctl(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn run_workload(svc: &Service, specs: Vec<JobSpec>) -> (Vec<JobOutcome>, f64) {
    let t0 = Instant::now();
    let handles = svc.submit_all(specs).expect("submit workload");
    let outcomes: Vec<JobOutcome> = handles.into_iter().map(|h| h.wait()).collect();
    (outcomes, t0.elapsed().as_secs_f64())
}

fn throughput_sweep(sink: &mut common::JsonSink) {
    let njobs = if common::smoke() { 12 } else { 48 };
    let widths: &[usize] = if common::smoke() { &[2, 4] } else { &[1, 2, 4, 8] };
    common::header(&format!(
        "service throughput: {njobs} mixed jobs (CAQR 4/8 ranks + TSQR 16 ranks) vs pool width"
    ));
    println!(
        "{:>7} {:>7} | {:>10} {:>9} | {:>10} {:>10} | {:>7} {:>7}",
        "workers", "faults", "wall", "jobs/s", "p50 lat", "p99 lat", "fails", "recov"
    );
    for &w in widths {
        for faults in [false, true] {
            let specs = mixed_jobs(njobs, faults);
            let svc = Service::new(ServiceConfig {
                workers: w,
                max_inflight_ranks: 64,
                batch_max: 4,
            });
            let (outcomes, wall) = run_workload(&svc, specs);
            let ok = outcomes.iter().filter(|o| o.output.is_ok()).count();
            assert_eq!(
                ok, njobs,
                "all jobs must complete (injected kills are recoverable)"
            );
            let mut lat: Vec<f64> =
                outcomes.iter().map(|o| o.queued_s + o.run_s).collect();
            lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let (p50, p99) = (pctl(&lat, 0.5), pctl(&lat, 0.99));
            let jps = njobs as f64 / wall;
            let totals = svc.totals();
            println!(
                "{w:>7} {:>7} | {:>10} {jps:>9.1} | {:>10} {:>10} | {:>7} {:>7}",
                if faults { "yes" } else { "no" },
                common::fmt_time(wall),
                common::fmt_time(p50),
                common::fmt_time(p99),
                totals.report.failures,
                totals.report.recoveries,
            );
            sink.rec(&[
                ("bench", common::JsonVal::S("service-throughput")),
                ("workers", I(w as i64)),
                ("jobs", I(njobs as i64)),
                ("faults", I(faults as i64)),
                ("wall_s", F(wall)),
                ("jobs_per_s", F(jps)),
                ("p50_s", F(p50)),
                ("p99_s", F(p99)),
                ("messages", I(totals.report.messages as i64)),
                ("exchanges", I(totals.report.exchanges as i64)),
                ("bytes", I(totals.report.bytes as i64)),
                ("failures", I(totals.report.failures as i64)),
                ("recoveries", I(totals.report.recoveries as i64)),
            ]);
        }
    }
    println!("\nJob latency includes queueing: admission control bounds in-flight");
    println!("simulated ranks at 64, so wide bursts wait instead of oversubscribing.");
}

fn batch_lane(sink: &mut common::JsonSink) {
    let k = if common::smoke() { 4 } else { 12 };
    common::header(&format!(
        "batched TSQR lane: {k} same-shape jobs, batching off vs on"
    ));
    println!(
        "{:>6} | {:>10} | {:>10} {:>12} | {:>9}",
        "batch", "wall", "exchanges", "bytes", "sweeps"
    );
    let mut base_exchanges = 0u64;
    for batch in [1usize, k] {
        let specs: Vec<JobSpec> = (0..k)
            .map(|i| JobSpec::Tsqr {
                rows: 256,
                block: 8,
                procs: 32,
                mode: TsqrMode::FaultTolerant,
                seed: seed_for(0xBA7C4, i as u64),
            })
            .collect();
        let svc = Service::new(ServiceConfig {
            workers: 4,
            max_inflight_ranks: 0,
            batch_max: batch,
        });
        let (outcomes, wall) = run_workload(&svc, specs);
        assert!(outcomes.iter().all(|o| o.output.is_ok()));
        let totals = svc.totals();
        if batch == 1 {
            base_exchanges = totals.report.exchanges;
        } else {
            assert!(
                totals.report.exchanges < base_exchanges,
                "batching must amortize exchange counts ({} !< {base_exchanges})",
                totals.report.exchanges
            );
        }
        let sweeps = k.div_ceil(batch);
        println!(
            "{batch:>6} | {:>10} | {:>10} {:>12} | {sweeps:>9}",
            common::fmt_time(wall),
            totals.report.exchanges,
            totals.report.bytes,
        );
        sink.rec(&[
            ("bench", common::JsonVal::S("service-batch")),
            ("batch", I(batch as i64)),
            ("jobs", I(k as i64)),
            ("wall_s", F(wall)),
            ("exchanges", I(totals.report.exchanges as i64)),
            ("bytes", I(totals.report.bytes as i64)),
        ]);
    }
    println!("\nOne bundle exchange per tree step carries every job's R: the");
    println!("per-step message budget is paid once per batch, bytes scale with k.");
}

fn main() {
    let mut sink = common::JsonSink::new();
    throughput_sweep(&mut sink);
    batch_lane(&mut sink);
    sink.finish("service");
}
