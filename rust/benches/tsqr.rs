//! E1 bench: TSQR — plain reduction vs FT all-exchange (paper §III-B,
//! Fig 2). Regenerates the redundancy series and the overhead columns.

#[path = "common/mod.rs"]
mod common;

use ftcaqr::backend::Backend;
use ftcaqr::coordinator::{run_tsqr, TsqrMode};
use ftcaqr::linalg::Matrix;
use ftcaqr::sim::CostModel;

fn main() {
    common::header("E1 / Fig 2: TSQR plain vs fault-tolerant");
    println!(
        "{:>6} {:>6} {:>8} | {:>12} {:>12} {:>9} | {:>10} {:>10} | {:>20}",
        "procs", "m_loc", "b", "cp plain us", "cp ft us", "ratio", "msgs", "exchs", "redundancy(step)"
    );
    for procs in [2usize, 4, 8, 16, 32] {
        for b in [8usize, 16, 32] {
            let m_local = 64.max(b);
            let a = Matrix::randn(procs * m_local, b, 99);
            let be = Backend::native();
            let p =
                run_tsqr(&a, procs, TsqrMode::Plain, be.clone(), CostModel::default()).unwrap();
            let f = run_tsqr(&a, procs, TsqrMode::FaultTolerant, be, CostModel::default())
                .unwrap();
            println!(
                "{procs:>6} {m_local:>6} {b:>8} | {:>12.3} {:>12.3} {:>9.3} | {:>10} {:>10} | {:>20}",
                p.report.critical_path * 1e6,
                f.report.critical_path * 1e6,
                f.report.critical_path / p.report.critical_path,
                p.report.messages,
                f.report.exchanges,
                format!("{:?}", f.redundancy),
            );
        }
    }

    common::header("TSQR wallclock (native backend)");
    for procs in [4usize, 8, 16] {
        let a = Matrix::randn(procs * 128, 32, 5);
        for (name, mode) in [("plain", TsqrMode::Plain), ("ft", TsqrMode::FaultTolerant)] {
            let (med, mean, sd) = common::time_case(1, 5, || {
                let be = Backend::native();
                let _ = run_tsqr(&a, procs, mode, be, CostModel::default()).unwrap();
            });
            common::row(&format!("tsqr/{name}/P{procs}/m128/b32"), med, mean, sd, "");
        }
    }
}
