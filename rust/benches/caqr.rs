//! E6 bench: end-to-end CAQR throughput — native vs XLA backends, plain
//! vs FT, with scaling over P. This is the headline table.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::caqr::run_caqr;
use ftcaqr::fault::FaultPlan;
use ftcaqr::runtime::Engine;
use ftcaqr::trace::Trace;

fn bench_backend(name: &str, be: impl Fn() -> Arc<Backend>) {
    println!(
        "{:>8} {:>5} {:>11} | {:>12} {:>12} {:>14}",
        "backend", "P", "matrix", "wall (ms)", "cp (us)", "host GFLOP/s"
    );
    for (procs, rows, cols) in [(4usize, 512usize, 128usize), (8, 1024, 256), (8, 1024, 512)] {
        for alg in [Algorithm::Plain, Algorithm::FaultTolerant] {
            let cfg = RunConfig {
                rows,
                cols,
                block: 32,
                procs,
                algorithm: alg,
                verify: false,
                ..Default::default()
            };
            let backend = be();
            let (out, wall) = common::wall(|| {
                run_caqr(cfg, backend, FaultPlan::none(), Trace::disabled()).unwrap()
            });
            println!(
                "{:>8} {procs:>5} {:>11} | {:>12.2} {:>12.3} {:>14.2}",
                format!("{name}/{alg:?}").chars().take(8).collect::<String>(),
                format!("{rows}x{cols}"),
                wall * 1e3,
                out.report.critical_path * 1e6,
                out.backend_flops as f64 / 1e9 / wall,
            );
        }
    }
}

fn main() {
    common::header("E6: end-to-end CAQR (native backend)");
    bench_backend("nat", Backend::native);

    if common::artifacts_present() {
        common::header("E6: end-to-end CAQR (XLA backend, AOT JAX/Pallas artifacts)");
        let engine = Engine::start(
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap();
        bench_backend("xla", move || Backend::xla(engine.clone()));
    } else {
        println!("(artifacts/ missing — skipping XLA rows; run `make artifacts`)");
    }

    common::header("E6b: repeat-run stability (native, FT, P=8, 1024x256)");
    let (med, mean, sd) = common::time_case(2, 7, || {
        let cfg = RunConfig {
            rows: 1024,
            cols: 256,
            block: 32,
            procs: 8,
            verify: false,
            ..Default::default()
        };
        let _ = run_caqr(cfg, Backend::native(), FaultPlan::none(), Trace::disabled()).unwrap();
    });
    common::row("caqr/ft/P8/1024x256", med, mean, sd, "");
}
