//! E6 bench: end-to-end CAQR throughput — native vs XLA backends, plain
//! vs FT, with scaling over P — plus the lookahead-pipeline sweep
//! (simulated makespan vs depth L, failure-free and single-kill),
//! emitting kernels.rs-style JSON for the CI perf trail.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use common::JsonVal;
use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, BcastKind, RunConfig};
use ftcaqr::coordinator::caqr::run_caqr;
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::linalg::Matrix;
use ftcaqr::runtime::Engine;
use ftcaqr::trace::Trace;

/// Per shape: plain vs FT (failure-free FT overhead % on the simulated
/// makespan) and, on the FT config, tracing on vs off (wall-clock cost
/// of recording spans). Gates the observability contract: tracing must
/// leave both the factors and the simulated makespan bitwise unchanged.
fn bench_backend(name: &str, be: impl Fn() -> Arc<Backend>, sink: &mut common::JsonSink) {
    println!(
        "{:>8} {:>5} {:>11} | {:>12} {:>12} {:>14}",
        "backend", "P", "matrix", "wall (ms)", "cp (us)", "host GFLOP/s"
    );
    let shapes: &[(usize, usize, usize)] = if common::smoke() {
        &[(4, 512, 128)]
    } else {
        &[(4, 512, 128), (8, 1024, 256), (8, 1024, 512)]
    };
    for &(procs, rows, cols) in shapes {
        let mk_cfg = |alg| RunConfig {
            rows,
            cols,
            block: 32,
            procs,
            algorithm: alg,
            verify: false,
            ..Default::default()
        };
        let mut cp = [0.0f64; 2]; // [plain, ft] simulated makespan
        let mut ft_wall = 0.0f64;
        let mut ft_r: Option<Matrix> = None;
        for (i, alg) in [Algorithm::Plain, Algorithm::FaultTolerant].into_iter().enumerate() {
            let backend = be();
            let (out, wall) = common::wall(|| {
                run_caqr(mk_cfg(alg), backend, FaultPlan::none(), Trace::disabled()).unwrap()
            });
            cp[i] = out.report.critical_path;
            if alg == Algorithm::FaultTolerant {
                ft_wall = wall;
                ft_r = Some(out.r);
            }
            println!(
                "{:>8} {procs:>5} {:>11} | {:>12.2} {:>12.3} {:>14.2}",
                format!("{name}/{alg:?}").chars().take(8).collect::<String>(),
                format!("{rows}x{cols}"),
                wall * 1e3,
                out.report.critical_path * 1e6,
                out.backend_flops as f64 / 1e9 / wall,
            );
        }
        // Same FT run with span recording enabled: observability must be
        // invisible to both the numerics and the simulated clock.
        let trace = Trace::new();
        let backend = be();
        let (traced, traced_wall) = common::wall(|| {
            run_caqr(mk_cfg(Algorithm::FaultTolerant), backend, FaultPlan::none(), trace).unwrap()
        });
        assert_eq!(
            ft_r.as_ref().unwrap(),
            &traced.r,
            "tracing changed the factors ({rows}x{cols} P={procs} {name})"
        );
        assert_eq!(
            cp[1], traced.report.critical_path,
            "tracing changed the simulated makespan ({rows}x{cols} P={procs} {name})"
        );
        let ft_overhead_pct = (cp[1] / cp[0] - 1.0) * 100.0;
        let trace_overhead_pct = (traced_wall / ft_wall - 1.0) * 100.0;
        println!(
            "{:>8} {procs:>5} {:>11} | FT overhead {ft_overhead_pct:+.2}% (makespan), \
             tracing {trace_overhead_pct:+.2}% (wall)",
            format!("{name}/ovh").chars().take(8).collect::<String>(),
            format!("{rows}x{cols}"),
        );
        sink.rec(&[
            ("bench", JsonVal::S("caqr_overhead")),
            ("backend", JsonVal::S(name)),
            ("rows", JsonVal::I(rows as i64)),
            ("cols", JsonVal::I(cols as i64)),
            ("procs", JsonVal::I(procs as i64)),
            ("plain_makespan_s", JsonVal::F(cp[0])),
            ("ft_makespan_s", JsonVal::F(cp[1])),
            ("ft_overhead_pct", JsonVal::F(ft_overhead_pct)),
            ("ft_wall_s", JsonVal::F(ft_wall)),
            ("traced_wall_s", JsonVal::F(traced_wall)),
            ("trace_wall_overhead_pct", JsonVal::F(trace_overhead_pct)),
        ]);
    }
}

/// Lookahead sweep: L in {0, 1, 2, 4} at two matrix shapes, failure-free
/// and with one mid-run kill + REBUILD. Asserts the pipeline's bitwise
/// determinism contract (factors identical to L = 0) and reports the
/// simulated makespan (critical path) each depth achieves.
fn bench_lookahead(sink: &mut common::JsonSink) {
    common::header("E6c: lookahead pipeline (simulated makespan vs depth L)");
    let shapes: &[(usize, usize, usize, usize)] = if common::smoke() {
        &[(256, 64, 16, 4)]
    } else {
        &[(512, 128, 32, 4), (1024, 256, 32, 8)]
    };
    println!(
        "{:>11} {:>5} {:>2} {:>6} | {:>12} {:>12} {:>12} {:>10}",
        "matrix", "P", "L", "kill", "makespan(us)", "compute(us)", "comm(us)", "wall(ms)"
    );
    for &(rows, cols, block, procs) in shapes {
        for faulted in [false, true] {
            let mut r0: Option<Matrix> = None;
            for lookahead in [0usize, 1, 2, 4] {
                let cfg = RunConfig {
                    rows,
                    cols,
                    block,
                    procs,
                    lookahead,
                    algorithm: Algorithm::FaultTolerant,
                    verify: false,
                    ..Default::default()
                };
                let fault = if faulted {
                    FaultPlan::schedule(vec![ScheduledKill::new(
                        procs - 1,
                        1,
                        0,
                        Phase::Update,
                    )])
                } else {
                    FaultPlan::none()
                };
                let a = Matrix::randn(rows, cols, 7);
                let (out, wall) = common::wall(|| {
                    ftcaqr::coordinator::run_caqr_matrix(
                        cfg,
                        a.clone(),
                        Backend::native(),
                        fault,
                        Trace::disabled(),
                    )
                    .unwrap()
                });
                match &r0 {
                    None => r0 = Some(out.r.clone()),
                    Some(base) => assert_eq!(
                        base, &out.r,
                        "L={lookahead} changed the factors ({rows}x{cols} faulted={faulted})"
                    ),
                }
                println!(
                    "{:>11} {procs:>5} {lookahead:>2} {:>6} | {:>12.3} {:>12.3} {:>12.3} {:>10.2}",
                    format!("{rows}x{cols}"),
                    if faulted { "1" } else { "-" },
                    out.report.critical_path * 1e6,
                    out.report.compute_path * 1e6,
                    out.report.comm_path * 1e6,
                    wall * 1e3,
                );
                sink.rec(&[
                    ("bench", JsonVal::S("caqr_lookahead")),
                    ("rows", JsonVal::I(rows as i64)),
                    ("cols", JsonVal::I(cols as i64)),
                    ("block", JsonVal::I(block as i64)),
                    ("procs", JsonVal::I(procs as i64)),
                    ("lookahead", JsonVal::I(lookahead as i64)),
                    ("faulted", JsonVal::I(faulted as i64)),
                    ("makespan_s", JsonVal::F(out.report.critical_path)),
                    ("compute_path_s", JsonVal::F(out.report.compute_path)),
                    ("comm_path_s", JsonVal::F(out.report.comm_path)),
                    ("exchanges", JsonVal::I(out.report.exchanges as i64)),
                    ("bytes", JsonVal::I(out.report.bytes as i64)),
                    ("wall_s", JsonVal::F(wall)),
                ]);
            }
        }
    }
}

/// Grid-shape sweep: at a fixed process count P, compare 1 x P, P x 1
/// and the near-square grid, failure-free and with one mid-run kill.
/// Gates the layout's bitwise contract — the explicit P x 1 grid must
/// reproduce the implicit 1-D default exactly — and Gram-checks every
/// other shape (the TSQR tree depends on Pr, so different Pr gives a
/// numerically different, equally valid R). Reports makespan / compute
/// / comm per shape.
fn bench_grid(sink: &mut common::JsonSink) {
    common::header("E6d: process-grid sweep (Pr x Pc at fixed P)");
    let shapes: &[(usize, usize, usize, usize)] = if common::smoke() {
        &[(256, 64, 16, 4)]
    } else {
        &[(512, 128, 32, 4), (1024, 256, 32, 8)]
    };
    println!(
        "{:>11} {:>5} {:>6} {:>6} | {:>12} {:>12} {:>12} {:>10}",
        "matrix", "P", "grid", "kill", "makespan(us)", "compute(us)", "comm(us)", "wall(ms)"
    );
    for &(rows, cols, block, procs) in shapes {
        // (0, 0) is the auto grid (P x 1): the 1-D baseline every
        // explicit shape must match bitwise.
        let near = {
            let mut pr = (procs as f64).sqrt() as usize;
            while procs % pr != 0 {
                pr -= 1;
            }
            (pr, procs / pr)
        };
        let grids = [(0usize, 0usize), (procs, 1), (1, procs), near];
        for faulted in [false, true] {
            let mut r0: Option<Matrix> = None;
            for (gr, gc) in grids {
                let cfg = RunConfig {
                    rows,
                    cols,
                    block,
                    procs,
                    grid_rows: gr,
                    grid_cols: gc,
                    algorithm: Algorithm::FaultTolerant,
                    verify: true,
                    ..Default::default()
                };
                let (pr, pc) = cfg.grid_shape();
                let fault = if faulted {
                    FaultPlan::schedule(vec![ScheduledKill::new(
                        procs - 1,
                        1,
                        0,
                        Phase::Update,
                    )])
                } else {
                    FaultPlan::none()
                };
                let a = Matrix::randn(rows, cols, 7);
                let (out, wall) = common::wall(|| {
                    ftcaqr::coordinator::run_caqr_matrix(
                        cfg.clone(),
                        a.clone(),
                        Backend::native(),
                        fault,
                        Trace::disabled(),
                    )
                    .unwrap()
                });
                if pc == 1 {
                    // 1-D-equivalent shapes must agree to the bit.
                    match &r0 {
                        None => r0 = Some(out.r.clone()),
                        Some(base) => assert_eq!(
                            base, &out.r,
                            "explicit {pr}x1 grid diverged from the 1-D path \
                             ({rows}x{cols} faulted={faulted})"
                        ),
                    }
                }
                let res = out.residual.expect("verify=true always computes the Gram residual");
                assert!(
                    res < 1e-3,
                    "grid {pr}x{pc} failed the Gram check: residual {res:.3e} \
                     ({rows}x{cols} faulted={faulted})"
                );
                println!(
                    "{:>11} {procs:>5} {:>6} {:>6} | {:>12.3} {:>12.3} {:>12.3} {:>10.2}",
                    format!("{rows}x{cols}"),
                    format!("{pr}x{pc}"),
                    if faulted { "1" } else { "-" },
                    out.report.critical_path * 1e6,
                    out.report.compute_path * 1e6,
                    out.report.comm_path * 1e6,
                    wall * 1e3,
                );
                sink.rec(&[
                    ("bench", JsonVal::S("caqr_grid")),
                    ("rows", JsonVal::I(rows as i64)),
                    ("cols", JsonVal::I(cols as i64)),
                    ("block", JsonVal::I(block as i64)),
                    ("procs", JsonVal::I(procs as i64)),
                    ("grid_rows", JsonVal::I(pr as i64)),
                    ("grid_cols", JsonVal::I(pc as i64)),
                    ("faulted", JsonVal::I(faulted as i64)),
                    ("makespan_s", JsonVal::F(out.report.critical_path)),
                    ("compute_path_s", JsonVal::F(out.report.compute_path)),
                    ("comm_path_s", JsonVal::F(out.report.comm_path)),
                    ("exchanges", JsonVal::I(out.report.exchanges as i64)),
                    ("bytes", JsonVal::I(out.report.bytes as i64)),
                    ("wall_s", JsonVal::F(wall)),
                ]);
            }
        }
    }
}

/// Row-broadcast collective sweep: flat vs binomial vs segmented at
/// Pr = 2, Pc in {4, 8, 16} (smoke: {4, 8}), on a bandwidth-dominated
/// cost model (beta raised to 1e-9 so the root's serialized bundle
/// transmissions dominate the comm path) and a wide matrix (two block
/// columns per grid column) so most panels broadcast over every grid
/// column. Gates the collective engine's contract from both sides: the
/// schedule moves bytes, never operand values — factors bitwise
/// identical across kinds, clean and under a mid-broadcast relay kill —
/// while the tree shapes strictly cut the simulated communication
/// critical path vs flat once Pc >= 8.
fn bench_bcast(sink: &mut common::JsonSink) {
    common::header("E6e: row-broadcast collective sweep (flat / binomial / segmented)");
    let pcs: &[usize] = if common::smoke() { &[4, 8] } else { &[4, 8, 16] };
    println!(
        "{:>11} {:>5} {:>6} {:>10} | {:>12} {:>12} {:>8} {:>6} {:>10}",
        "matrix", "P", "grid", "bcast", "makespan(us)", "comm(us)", "hops", "depth", "wall(ms)"
    );
    for &pc in pcs {
        let (rows, block) = (256usize, 16usize);
        let cols = block * pc * 2;
        let procs = 2 * pc;
        let mk = |kind| {
            let mut c = RunConfig {
                rows,
                cols,
                block,
                procs,
                grid_rows: 2,
                grid_cols: pc,
                algorithm: Algorithm::FaultTolerant,
                bcast: kind,
                // Below the leaf-Y matrix (128 x 16 f32 = 8 KiB): the
                // segmented runs really split the bundle.
                seg_bytes: 4096,
                verify: false,
                ..Default::default()
            };
            c.cost.beta = 1e-9;
            c
        };
        let a = Matrix::randn(rows, cols, 7);
        let mut flat_comm = 0.0f64;
        let mut r0: Option<Matrix> = None;
        for kind in [BcastKind::Flat, BcastKind::Binomial, BcastKind::Segmented] {
            let (out, wall) = common::wall(|| {
                ftcaqr::coordinator::run_caqr_matrix(
                    mk(kind),
                    a.clone(),
                    Backend::native(),
                    FaultPlan::none(),
                    Trace::disabled(),
                )
                .unwrap()
            });
            match &r0 {
                None => r0 = Some(out.reduced.clone()),
                Some(base) => assert_eq!(
                    base, &out.reduced,
                    "{kind:?} changed the factors ({rows}x{cols} Pc={pc})"
                ),
            }
            if kind == BcastKind::Flat {
                flat_comm = out.report.comm_path;
            } else if pc >= 8 {
                assert!(
                    out.report.comm_path < flat_comm,
                    "{kind:?} comm path {:.3e}s not under flat's {:.3e}s at Pc={pc}",
                    out.report.comm_path,
                    flat_comm,
                );
            }
            println!(
                "{:>11} {procs:>5} {:>6} {:>10} | {:>12.3} {:>12.3} {:>8} {:>6} {:>10.2}",
                format!("{rows}x{cols}"),
                format!("2x{pc}"),
                kind.to_string(),
                out.report.critical_path * 1e6,
                out.report.comm_path * 1e6,
                out.report.bcast_hops,
                out.report.bcast_depth,
                wall * 1e3,
            );
            let ks = kind.to_string();
            sink.rec(&[
                ("bench", JsonVal::S("caqr_bcast")),
                ("rows", JsonVal::I(rows as i64)),
                ("cols", JsonVal::I(cols as i64)),
                ("block", JsonVal::I(block as i64)),
                ("procs", JsonVal::I(procs as i64)),
                ("pc", JsonVal::I(pc as i64)),
                ("bcast", JsonVal::S(&ks)),
                ("makespan_s", JsonVal::F(out.report.critical_path)),
                ("comm_path_s", JsonVal::F(out.report.comm_path)),
                ("bcast_bytes", JsonVal::I(out.report.bcast_bytes as i64)),
                ("bcast_hops", JsonVal::I(out.report.bcast_hops as i64)),
                ("bcast_depth", JsonVal::I(out.report.bcast_depth as i64)),
                ("messages", JsonVal::I(out.report.messages as i64)),
                ("wall_s", JsonVal::F(wall)),
            ]);
        }
        // The same contract under fire: rank 1 is the relay feeding
        // virtual member 3 in panel 0's binomial tree; kill it at its
        // Bcast site and the recovered run must still match bitwise.
        let out = ftcaqr::coordinator::run_caqr_matrix(
            mk(BcastKind::Binomial),
            a.clone(),
            Backend::native(),
            FaultPlan::schedule(vec![ScheduledKill::new(1, 0, 0, Phase::Bcast)]),
            Trace::disabled(),
        )
        .unwrap();
        assert_eq!(
            r0.as_ref().unwrap(),
            &out.reduced,
            "relay kill changed the factors ({rows}x{cols} Pc={pc})"
        );
    }
}

fn main() {
    let mut sink = common::JsonSink::new();
    common::header("E6: end-to-end CAQR (native backend)");
    bench_backend("nat", Backend::native, &mut sink);

    if common::artifacts_present() {
        common::header("E6: end-to-end CAQR (XLA backend, AOT JAX/Pallas artifacts)");
        let engine = Engine::start(
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        )
        .unwrap();
        bench_backend("xla", move || Backend::xla(engine.clone()), &mut sink);
    } else {
        println!("(artifacts/ missing — skipping XLA rows; run `make artifacts`)");
    }

    common::header("E6b: repeat-run stability (native, FT, P=8, 1024x256)");
    let (warm, iters, rows) = if common::smoke() { (1, 2, 512) } else { (2, 7, 1024) };
    let (med, mean, sd) = common::time_case(warm, iters, || {
        let cfg = RunConfig {
            rows,
            cols: 256,
            block: 32,
            procs: 8,
            verify: false,
            ..Default::default()
        };
        let _ = run_caqr(cfg, Backend::native(), FaultPlan::none(), Trace::disabled()).unwrap();
    });
    common::row("caqr/ft/P8", med, mean, sd, "");

    bench_lookahead(&mut sink);
    bench_grid(&mut sink);
    bench_bcast(&mut sink);
    sink.finish("caqr");
}
