//! Integration: the 2-D block-cyclic process grid. Pins the layout
//! contract (an explicit Pr x 1 grid is the 1-D path, to the bit, and a
//! Pr x Pc grid reproduces the Pr x 1 factors exactly — the TSQR tree
//! only depends on Pr), exercises grid-aware buddy recovery under
//! single kills, correlated cross-column kills, and kills landing mid
//! row-broadcast on both the sender and the receiver side, and checks
//! that the lookahead pipeline and the plain algorithm compose with
//! grid layouts.

use ftcaqr::backend::Backend;
use ftcaqr::config::{Algorithm, RunConfig};
use ftcaqr::coordinator::run_caqr_matrix;
use ftcaqr::fault::{FaultPlan, Phase, ScheduledKill};
use ftcaqr::ft::Semantics;
use ftcaqr::linalg::Matrix;
use ftcaqr::trace::Trace;

fn cfg(procs: usize, pr: usize, pc: usize) -> RunConfig {
    RunConfig {
        rows: 256,
        cols: 64,
        block: 16,
        procs,
        grid_rows: pr,
        grid_cols: pc,
        algorithm: Algorithm::FaultTolerant,
        semantics: Semantics::Rebuild,
        ..Default::default()
    }
}

fn run_with(
    c: &RunConfig,
    a: &Matrix,
    fault: std::sync::Arc<FaultPlan>,
) -> anyhow::Result<ftcaqr::coordinator::CaqrOutcome> {
    run_caqr_matrix(c.clone(), a.clone(), Backend::native(), fault, Trace::disabled())
}

#[test]
fn explicit_px1_grid_is_bitwise_the_1d_path() {
    // grid_rows/grid_cols (0, 0) is the auto procs x 1 layout — the
    // pre-grid 1-D code path. Spelling it out as an explicit P x 1 grid
    // must change nothing, to the bit, clean and under a kill.
    let auto = cfg(4, 0, 0);
    let explicit = cfg(4, 4, 1);
    let a = Matrix::randn(auto.rows, auto.cols, 71);
    for fault in [
        FaultPlan::none(),
        FaultPlan::schedule(vec![ScheduledKill::new(2, 1, 0, Phase::Update)]),
    ] {
        let base = run_with(&auto, &a, fault.clone()).unwrap();
        let gridded = run_with(&explicit, &a, fault).unwrap();
        assert_eq!(base.r, gridded.r);
        assert_eq!(base.reduced, gridded.reduced);
    }
}

#[test]
fn cross_pc_factors_match_at_fixed_pr() {
    // The TSQR reduction tree runs down a grid column of Pr ranks, and
    // trailing-update kernel dispatch is pinned to the global trailing
    // width — so widening the grid from 2 x 1 (2 procs) to 2 x 2
    // (4 procs) redistributes the columns without perturbing a single
    // flop. The factors must be bitwise identical.
    let narrow = cfg(2, 2, 1);
    let wide = cfg(4, 2, 2);
    let a = Matrix::randn(narrow.rows, narrow.cols, 73);
    let n = run_with(&narrow, &a, FaultPlan::none()).unwrap();
    let w = run_with(&wide, &a, FaultPlan::none()).unwrap();
    assert_eq!(n.r, w.r);
    assert_eq!(n.reduced, w.reduced);
}

#[test]
fn grid_2x2_single_kill_recovers_bitwise() {
    // One rank dies mid-update on a 2 x 2 grid; its replacement is
    // rebuilt from its single column-buddy and the result is bitwise
    // the clean run.
    let c = cfg(4, 2, 2);
    let a = Matrix::randn(c.rows, c.cols, 79);
    let clean = run_with(&c, &a, FaultPlan::none()).unwrap();
    let failed = run_with(
        &c,
        &a,
        FaultPlan::schedule(vec![ScheduledKill::new(3, 1, 0, Phase::Update)]),
    )
    .unwrap();
    assert_eq!(failed.report.failures, 1);
    assert_eq!(failed.report.recoveries, 1);
    assert_eq!(clean.r, failed.r);
    assert_eq!(clean.reduced, failed.reduced);
}

#[test]
fn grid_2x2_kill_mid_row_broadcast_sender_side() {
    // Panel 0 lives in grid column 0; rank 0 factors it and then
    // broadcasts {Y, T} along its grid row. Kill rank 0 at the Bcast
    // site — after TSQR completes, before the bundle is published. The
    // off-column receiver (rank 1) must park on the missing bundle, the
    // replacement's TSQR replay must republish it, and the run must
    // finish bitwise identical to the clean one.
    let c = cfg(4, 2, 2);
    let a = Matrix::randn(c.rows, c.cols, 83);
    let clean = run_with(&c, &a, FaultPlan::none()).unwrap();
    let failed = run_with(
        &c,
        &a,
        FaultPlan::schedule(vec![ScheduledKill::new(0, 0, 0, Phase::Bcast)]),
    )
    .unwrap();
    assert_eq!(failed.report.failures, 1);
    assert_eq!(failed.report.recoveries, 1);
    assert_eq!(clean.r, failed.r);
    assert_eq!(clean.reduced, failed.reduced);
}

#[test]
fn grid_2x2_kill_mid_row_broadcast_receiver_side() {
    // The dual: an off-panel-column rank dies at its own Bcast site
    // while waiting for the factor bundle. Its replacement re-enters
    // the wait, pulls the (by now retained) bundle, and completes.
    let c = cfg(4, 2, 2);
    let a = Matrix::randn(c.rows, c.cols, 89);
    let clean = run_with(&c, &a, FaultPlan::none()).unwrap();
    let failed = run_with(
        &c,
        &a,
        FaultPlan::schedule(vec![ScheduledKill::new(1, 0, 0, Phase::Bcast)]),
    )
    .unwrap();
    assert_eq!(failed.report.failures, 1);
    assert_eq!(failed.report.recoveries, 1);
    assert_eq!(clean.r, failed.r);
    assert_eq!(clean.reduced, failed.reduced);
}

#[test]
fn grid_4x4_survives_correlated_multi_failure() {
    // A 4 x 4 grid under a compound plan: two independent kills in
    // different panels/phases plus a correlated same-instant crash of
    // two ranks in the SAME grid row. Row neighbors are never buddy
    // pairs — retention runs down grid columns — so every loss still
    // has one surviving copy and the run must complete with a clean
    // Gram residual.
    let procs = 16;
    let c = RunConfig {
        rows: 256,
        cols: 64,
        block: 16,
        procs,
        grid_rows: 4,
        grid_cols: 4,
        algorithm: Algorithm::FaultTolerant,
        semantics: Semantics::Rebuild,
        ..Default::default()
    };
    let a = Matrix::randn(c.rows, c.cols, 97);
    let clean = run_with(&c, &a, FaultPlan::none()).unwrap();
    let mut kills = vec![
        ScheduledKill::new(10, 0, 0, Phase::Update),
        ScheduledKill::new(3, 2, 0, Phase::Bcast),
    ];
    // Ranks 6 = (1,2) and 7 = (1,3) both own trailing blocks of panel 1
    // (grid columns 2 and 3 hold global blocks 2 and 3), so both are in
    // their update phase when the correlated crash lands.
    kills.extend(ftcaqr::fault::parse_kill_pair("6,7@1:0:update", 0).unwrap());
    let failed = run_with(&c, &a, FaultPlan::schedule(kills)).unwrap();
    assert_eq!(failed.report.failures, 4);
    assert_eq!(failed.report.recoveries, 4);
    assert_eq!(clean.r, failed.r);
    assert_eq!(clean.reduced, failed.reduced);
    let res = failed.residual.expect("verify on");
    assert!(res < 1e-3, "residual {res}");
}

#[test]
fn lookahead_composes_with_grid() {
    // The lookahead pipeline's bitwise contract must hold per grid
    // shape: on a 2 x 2 grid, L = 2 with a mid-run kill reproduces the
    // lockstep factors exactly.
    let mut lockstep = cfg(4, 2, 2);
    lockstep.lookahead = 0;
    let mut deep = lockstep.clone();
    deep.lookahead = 2;
    let a = Matrix::randn(lockstep.rows, lockstep.cols, 101);
    let fault = || FaultPlan::schedule(vec![ScheduledKill::new(2, 1, 0, Phase::Update)]);
    let l0 = run_with(&lockstep, &a, fault()).unwrap();
    let l2 = run_with(&deep, &a, fault()).unwrap();
    assert_eq!(l0.r, l2.r);
    assert_eq!(l0.reduced, l2.reduced);
}

#[test]
fn plain_algorithm_runs_on_2d_grid() {
    // The non-FT baseline uses real row-broadcast messages instead of
    // the retention store; it must produce a valid factorization on a
    // 2-D grid and match its own 1-D layout bitwise.
    let mut narrow = cfg(2, 2, 1);
    narrow.algorithm = Algorithm::Plain;
    let mut wide = cfg(4, 2, 2);
    wide.algorithm = Algorithm::Plain;
    let a = Matrix::randn(narrow.rows, narrow.cols, 103);
    let n = run_with(&narrow, &a, FaultPlan::none()).unwrap();
    let w = run_with(&wide, &a, FaultPlan::none()).unwrap();
    assert_eq!(n.r, w.r);
    assert_eq!(n.reduced, w.reduced);
    let res = w.residual.expect("verify on");
    assert!(res < 1e-3, "residual {res}");
}
